"""Layer-1 correctness: Pallas kernels vs pure-jnp oracles.

hypothesis sweeps shapes/ratios/scales; exact agreement is required (same
tie-breaks, same accumulation dtype) because the AOT artifacts embed the
Pallas path while training/scoring used the oracle path.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels import attention as k_attn
from compile.kernels import nm_prune as k_prune
from compile.kernels import nm_spmm as k_spmm
from compile.kernels import quant_matmul as k_quant

RATIOS = [(2, 4), (4, 8), (8, 16)]


def rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


@pytest.mark.parametrize("n,m", RATIOS)
def test_nm_mask_is_exact(n, m):
    rng = np.random.default_rng(0)
    x = rand(rng, 16, 64)
    mask = ref.nm_mask(jnp.abs(x), n, m)
    g = mask.reshape(16, 64 // m, m)
    counts = jnp.sum(g, axis=-1)
    assert jnp.all(counts == n), "mask must be exactly N per M-group"


def test_nm_mask_tie_break_lower_index():
    x = jnp.asarray([[1.0, 1.0, 1.0, 1.0]])
    mask = ref.nm_mask(x, 2, 4)
    assert mask.tolist() == [[1.0, 1.0, 0.0, 0.0]]


@settings(max_examples=20, deadline=None)
@given(
    t_tiles=st.integers(1, 3),
    groups=st.integers(1, 6),
    ratio=st.sampled_from(RATIOS),
    seed=st.integers(0, 2**31 - 1),
    scaled=st.booleans(),
)
def test_prune_kernel_matches_ref(t_tiles, groups, ratio, seed, scaled):
    n, m = ratio
    t, d = t_tiles * k_prune.TOKEN_TILE, groups * m
    rng = np.random.default_rng(seed)
    x = rand(rng, t, d)
    scale = (
        jnp.asarray(rng.uniform(0.5, 3.0, d).astype(np.float32))
        if scaled
        else jnp.ones((d,), jnp.float32)
    )
    got = k_prune.nm_prune(x, scale, n, m)
    want = ref.nm_prune(x, scale, n, m)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=15, deadline=None)
@given(
    ratio=st.sampled_from(RATIOS),
    dout=st.sampled_from([8, 48, 96, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_prune_matmul_matches_ref(ratio, dout, seed):
    n, m = ratio
    rng = np.random.default_rng(seed)
    x = rand(rng, 32, 64)
    w = rand(rng, 64, dout)
    scale = jnp.asarray(rng.uniform(0.5, 2.0, 64).astype(np.float32))
    got = k_spmm.nm_prune_matmul(x, w, scale, n, m)
    want = ref.nm_prune_matmul(x, w, scale, n, m)
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)


def test_keep_dense_flag_bypasses_pruning():
    rng = np.random.default_rng(1)
    x = rand(rng, 16, 32)
    w = rand(rng, 32, 16)
    ones = jnp.ones((32,), jnp.float32)
    keep = jnp.ones((), jnp.float32)
    got = k_spmm.nm_prune_matmul(x, w, ones, 2, 4, keep)
    np.testing.assert_allclose(got, ref.matmul(x, w), atol=1e-5)


def test_dense_matmul_kernel():
    rng = np.random.default_rng(2)
    x = rand(rng, 32, 48)
    w = rand(rng, 48, 96)
    np.testing.assert_allclose(
        k_spmm.matmul(x, w), ref.matmul(x, w), atol=1e-5, rtol=1e-5
    )


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    x_scale=st.floats(0.01, 0.2),
)
def test_w8a8_matmul_matches_ref(seed, x_scale):
    rng = np.random.default_rng(seed)
    x = rand(rng, 16, 32)
    wq = jnp.asarray(rng.integers(-127, 128, (32, 24)).astype(np.int8))
    ws = jnp.asarray(rng.uniform(1e-3, 2e-2, 24).astype(np.float32))
    got = k_quant.w8a8_matmul(x, wq, ws, x_scale)
    want = ref.w8a8_matmul(x, wq, ws, jnp.float32(x_scale))
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("n,m", RATIOS)
def test_w8a8_nm_fused_matches_ref(n, m):
    rng = np.random.default_rng(7)
    x = rand(rng, 16, 32)
    wq = jnp.asarray(rng.integers(-127, 128, (32, 24)).astype(np.int8))
    ws = jnp.asarray(rng.uniform(1e-3, 2e-2, 24).astype(np.float32))
    scale = jnp.asarray(rng.uniform(0.5, 2.0, 32).astype(np.float32))
    got = k_quant.w8a8_nm_prune_matmul(x, wq, ws, 0.05, scale, n, m)
    want = ref.w8a8_nm_prune_matmul(
        x, wq, ws, jnp.float32(0.05), scale, n, m
    )
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)


@settings(max_examples=8, deadline=None)
@given(
    b=st.integers(1, 3),
    s=st.sampled_from([8, 16, 32]),
    hq=st.sampled_from([2, 4]),
    group=st.sampled_from([1, 2]),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_kernel_matches_ref(b, s, hq, group, seed):
    hkv = max(hq // group, 1)
    dh = 8
    rng = np.random.default_rng(seed)
    q = rand(rng, b, s, hq, dh)
    k = rand(rng, b, s, hkv, dh)
    v = rand(rng, b, s, hkv, dh)
    got = k_attn.causal_attention(q, k, v)
    want = ref.causal_attention(q, k, v)
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)


def test_compress_decompress_roundtrip():
    rng = np.random.default_rng(3)
    x = rand(rng, 8, 32)
    for n, m in RATIOS:
        xp = ref.nm_prune(x, jnp.ones((32,)), n, m)
        vals, idx = ref.nm_compress(xp, n, m)
        back = ref.nm_decompress(vals, idx, m)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(xp))


def test_rope_rotation_preserves_norm():
    rng = np.random.default_rng(4)
    x = rand(rng, 2, 8, 2, 16)
    pos = jnp.broadcast_to(jnp.arange(8)[None, :], (2, 8))
    r = ref.rope(x, pos)
    np.testing.assert_allclose(
        jnp.linalg.norm(r, axis=-1), jnp.linalg.norm(x, axis=-1),
        atol=1e-4, rtol=1e-4,
    )
