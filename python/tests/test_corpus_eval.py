"""Corpus / task-generator / binary-format tests."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import corpus, evalgen, params_io
from compile import tokenizer as tok


def test_world_deterministic():
    a, b = corpus.World(1), corpus.World(1)
    assert (a.fact == b.fact).all()
    assert (a.gram_a == b.gram_a).all()
    c = corpus.World(2)
    assert (a.fact != c.fact).any()


def test_skills_produce_valid_tokens():
    rng = np.random.Generator(np.random.PCG64(0))
    for name, fn in corpus.SKILLS.items():
        for _ in range(20):
            s = fn(rng, corpus.WORLD)
            assert len(s) > 0, name
            assert all(0 <= t < tok.VOCAB_SIZE for t in s), name


def test_pack_batch_shape_and_bos():
    rng = np.random.Generator(np.random.PCG64(1))
    b = corpus.pack_batch(rng, corpus.WORLD, ("arith", "boolean"), 4, 32)
    assert b.shape == (4, 32)
    assert (b[:, 0] == tok.BOS).all()
    assert b.dtype == np.int32


def test_chain_example_semantics():
    rng = np.random.Generator(np.random.PCG64(2))
    for _ in range(50):
        toks, t, f = corpus.chain_example(rng)
        assert toks[0] == tok.QRY and toks[6] == tok.ANS
        assert toks[7] == tok.digit(t) and toks[8] == tok.digit(f)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000))
def test_kv_recall_answer_is_consistent(seed):
    rng = np.random.Generator(np.random.PCG64(seed))
    s = corpus.gen_kv_recall(rng, corpus.WORLD)
    q = s.index(tok.QRY)
    qkey = s[q + 1]
    ans = s[q + 3]
    # find the value paired with qkey in the context
    pairs = {s[i]: s[i + 1] for i in range(0, q, 2)}
    assert pairs[qkey] == ans


def test_mc_tasks_golds_and_choices():
    for tid, (name, (fn, n_choices, _)) in enumerate(
            evalgen.MC_TASKS.items()):
        rng = evalgen._rng(tid)
        samples = fn(rng, 25)
        assert len(samples) == 25, name
        for ctx, choices, gold in samples:
            assert len(choices) == n_choices, name
            assert 0 <= gold < n_choices, name
            assert len(ctx) + max(len(c) for c in choices) <= evalgen.SEQ

def test_facts_tasks_agree_with_world():
    rng = evalgen._rng(3)
    for ctx, choices, gold in evalgen.task_mmlu(rng, 30):
        e = ctx[2] - tok.ENT0
        r = ctx[3] - tok.REL0
        assert choices[gold][0] == tok.ent(int(corpus.WORLD.fact[r, e]))


def test_longbench_fits_window():
    rng = evalgen._rng(100)
    for row in evalgen.task_longbench_kv(rng, 8):
        assert len(row["tokens"]) <= evalgen.LONG_SEQ
    for row in evalgen.task_longbench_induction(rng, 4):
        assert len(row["tokens"]) <= evalgen.LONG_SEQ


def test_eval_binary_roundtrip(tmp_path):
    rng = evalgen._rng(0)
    samples = evalgen.task_boolq(rng, 10)
    rows = evalgen._mc_rows(samples)
    p = tmp_path / "x.aev"
    params_io.write_eval_mc(str(p), 64, 2, rows, dict(n_samples=10))
    back = params_io.read_eval(str(p))
    assert back["kind"] == 0
    assert back["n_samples"] == 10
    assert back["n_choices"] == 2
    assert back["rows"].shape == (20, 64)
    sid, cid, ss, sl, gold = back["metas"][0]
    assert (sid, cid) == (0, 0)
    assert sl == 1


def test_gen_binary_roundtrip(tmp_path):
    rng = evalgen._rng(1)
    rows = evalgen.task_gsm8k(rng, 5)
    p = tmp_path / "g.aev"
    params_io.write_eval_gen(str(p), 64, rows, dict(n_samples=5))
    back = params_io.read_eval(str(p))
    assert back["kind"] == 1
    assert len(back["metas"]) == 5
    sid, plen, gold, mg = back["metas"][0]
    assert len(gold) == 2 and mg == 4
    assert plen == len(rows[0]["tokens"])


def test_weights_binary_roundtrip(tmp_path):
    rng = np.random.default_rng(0)
    tensors = [
        ("a.f32", rng.normal(size=(3, 4)).astype(np.float32)),
        ("b.i32", rng.integers(0, 10, (2,)).astype(np.int32)),
        ("c.i8", rng.integers(-5, 5, (4, 2, 2)).astype(np.int8)),
    ]
    p = tmp_path / "w.atw"
    params_io.write_weights(str(p), tensors)
    back = params_io.read_weights(str(p))
    assert [n for n, _ in back] == ["a.f32", "b.i32", "c.i8"]
    for (n1, t1), (n2, t2) in zip(tensors, back):
        assert t1.dtype == t2.dtype
        np.testing.assert_array_equal(t1, t2)


def test_flatten_order_matches_jax():
    """flatten_for_artifact must match jax's dict pytree leaf order (the
    lowered executable's parameter order)."""
    import jax
    tree = {"b": {"y": np.zeros(2), "x": np.zeros(3)}, "a": np.zeros(1)}
    ours = [n for n, _ in params_io.flatten_for_artifact(tree)]
    leaves = jax.tree_util.tree_leaves_with_path(tree)
    jax_names = [
        ".".join(str(k.key) for k in path) for path, _ in leaves
    ]
    assert ours == jax_names == ["a", "b.x", "b.y"]
