"""Amber algorithm tests: scoring, sensitivity/skip policy, smoothquant
folding identity, W8A8 quantization, weight-sparsity baselines."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model as M
from compile.configs import ModelConfig, DENSE_MODULES
from compile.amber import quant, scoring, sensitivity, smoothquant, topk
from compile.amber import weight_sparsity as ws

CFG = ModelConfig(name="t", vocab_size=64, d_model=32, n_layers=2,
                  n_q_heads=2, n_kv_heads=1, head_dim=16, d_ff=64)


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.PRNGKey(3))


@pytest.fixture(scope="module")
def tokens():
    rng = np.random.default_rng(5)
    return jnp.asarray(rng.integers(1, 64, (4, 16)), jnp.int32)


# ---------------------------------------------------------------- scoring

def test_wanda_scales_min_normalized():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(32, 16)).astype(np.float32))
    s = scoring.wanda_scales(w)
    assert s.shape == (32,)
    assert float(jnp.min(s)) == pytest.approx(1.0, rel=1e-4)
    assert jnp.all(s >= 1.0 - 1e-6)


def test_robust_norm_clips_outliers():
    rng = np.random.default_rng(1)
    w = rng.normal(size=(64, 32)).astype(np.float32)
    w2 = w.copy()
    w2[0, 0] = 1000.0  # single extreme outlier
    s1 = scoring.robust_norm_scales(jnp.asarray(w))
    s2 = scoring.robust_norm_scales(jnp.asarray(w2))
    # robust scoring must be nearly insensitive to the single outlier
    ratio = float(s2[0] / s1[0])
    assert ratio < 2.0, f"outlier leaked into robust score: {ratio}"
    # while plain wanda scoring explodes
    w1 = scoring.wanda_scales(jnp.asarray(w))
    w2s = scoring.wanda_scales(jnp.asarray(w2))
    assert float(w2s[0] / w1[0]) > 10.0


def test_build_aux_scales_shapes(params):
    aux = scoring.build_aux_scales(CFG, params, "robust")
    assert aux["scale_q"].shape == (2, 32)
    assert aux["scale_o"].shape == (2, CFG.q_dim)
    assert aux["scale_d"].shape == (2, 64)
    ones = scoring.build_aux_scales(CFG, params, "ones")
    assert float(jnp.max(jnp.abs(ones["scale_q"] - 1.0))) == 0.0


def test_scored_pruning_reduces_output_error(params):
    """The Wanda-like score (Eq. 2) must beat naive top-k on the metric it
    optimizes: ||Wx - Wx'||_2, with weight columns of varied norms."""
    rng = np.random.default_rng(2)
    din, dout = 64, 32
    # weights with strongly varying input-channel norms
    col_scale = rng.uniform(0.05, 3.0, size=(din, 1))
    w = jnp.asarray((rng.normal(size=(din, dout)) * col_scale)
                    .astype(np.float32))
    x = jnp.asarray(rng.normal(size=(128, din)).astype(np.float32))
    y = x @ w
    s = scoring.wanda_scales(w)
    from compile.kernels import ref
    err_naive, err_scored = 0.0, 0.0
    xn = ref.nm_prune(x, jnp.ones((din,)), 2, 4)
    xs = ref.nm_prune(x, s, 2, 4)
    err_naive = float(jnp.linalg.norm(xn @ w - y))
    err_scored = float(jnp.linalg.norm(xs @ w - y))
    assert err_scored < err_naive


# ------------------------------------------------------------ sensitivity

def test_sensitivity_sweep_and_policy(params, tokens):
    errs = sensitivity.sensitivity_sweep(CFG, params, tokens, (2, 4))
    assert errs.shape == (2, len(DENSE_MODULES))
    assert (errs >= 0).all()
    skip = sensitivity.select_skip_layers(errs, 1)
    assert len(skip) == 1
    keep = sensitivity.build_keep_dense(CFG, skip)
    keep = np.asarray(keep)
    # k/v/o/up never pruned
    for mod in ("k_proj", "v_proj", "o_proj", "up_proj"):
        assert (keep[:, M.MODULE_IDX[mod]] == 1.0).all()
    # down always pruned
    assert (keep[:, M.MODULE_IDX["down_proj"]] == 0.0).all()
    # q/gate pruned except in skip layers
    for li in range(CFG.n_layers):
        expect = 1.0 if li in skip else 0.0
        assert keep[li, M.MODULE_IDX["q_proj"]] == expect


def test_no_skip_prunes_everything():
    keep = np.asarray(sensitivity.build_keep_dense(CFG, [], no_skip=True))
    assert (keep == 0.0).all()


def test_coverage_accounting():
    keep = sensitivity.build_keep_dense(CFG, [])
    cov = sensitivity.coverage(CFG, keep)
    fl = sensitivity.linear_flops_prefill(CFG, 1)
    expect = (fl["q_proj"] + fl["gate_proj"] + fl["down_proj"]) / sum(
        fl.values())
    assert cov == pytest.approx(expect)


# ------------------------------------------------------------ smoothquant

def test_smoothing_identity():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(8, 16)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(16, 8)).astype(np.float32))
    s = smoothquant.smoothquant_scale(
        jnp.max(jnp.abs(x), axis=0), jnp.max(jnp.abs(w), axis=1), 0.5)
    xs, wss = smoothquant.apply_smoothing(x, w, s)
    np.testing.assert_allclose(np.asarray(xs @ wss), np.asarray(x @ w),
                               atol=1e-4, rtol=1e-4)


def test_inverted_scale_expands_activations():
    x_absmax = jnp.asarray([4.0, 2.0, 8.0])
    w_absmax = jnp.asarray([1.0, 1.0, 1.0])
    s = smoothquant.smoothquant_scale(x_absmax, w_absmax, 0.10)
    s_hat = smoothquant.outstanding_scale(x_absmax, w_absmax, 0.10)
    np.testing.assert_allclose(np.asarray(s_hat), 1.0 / np.asarray(s),
                               rtol=1e-6)
    # dividing activations by s_hat (<1 for outlier channels) expands them
    assert float(s_hat[2]) < 1.0


def test_fold_into_params_preserves_forward(params, tokens):
    """Folding s into ln gains + consumer weights must preserve the
    function exactly for q/k/v and gate/up."""
    base = M.forward(CFG, params, tokens)
    s = jnp.asarray(np.random.default_rng(4).uniform(0.5, 2.0, 32)
                    .astype(np.float32))
    p2 = smoothquant.fold_into_params(params, 0, "q_proj", s)
    out = M.forward(CFG, p2, tokens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(base),
                               atol=2e-4, rtol=2e-4)
    p3 = smoothquant.fold_into_params(params, 1, "gate_proj", s)
    out3 = M.forward(CFG, p3, tokens)
    np.testing.assert_allclose(np.asarray(out3), np.asarray(base),
                               atol=2e-4, rtol=2e-4)


def test_fold_down_proj_preserves_forward(params, tokens):
    base = M.forward(CFG, params, tokens)
    s = jnp.asarray(np.random.default_rng(5).uniform(0.5, 2.0, CFG.d_ff)
                    .astype(np.float32))
    p2 = smoothquant.fold_into_params(params, 0, "down_proj", s)
    out = M.forward(CFG, p2, tokens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(base),
                               atol=2e-4, rtol=2e-4)


# ------------------------------------------------------------------ quant

def test_weight_quant_roundtrip_error():
    rng = np.random.default_rng(6)
    w = jnp.asarray(rng.normal(size=(32, 16)).astype(np.float32) * 0.2)
    wq, s = quant.quantize_weight(w)
    wd = quant.dequantize_weight(wq, s)
    assert float(jnp.max(jnp.abs(wd - w))) <= float(jnp.max(s)) * 0.51


def test_skip_policy_families():
    sa = quant.skip_policy("tiny-lm-a", 6)
    assert (0, "q_proj") in sa          # first layers fully skipped
    assert (5, "down_proj") in sa       # down always skipped
    assert (5, "q_proj") not in sa
    sb = quant.skip_policy("tiny-lm-b", 6)
    assert (0, "q_proj") not in sb
    assert (3, "down_proj") in sb
    sm = quant.skip_policy("tiny-moe", 4)
    assert (2, "gate_proj") in sm


def test_collect_stats_and_qparams(params, tokens):
    stats = quant.collect_activation_stats(CFG, params, [tokens], None)
    for mod in DENSE_MODULES:
        assert stats[mod][0]["tmax"] > 0
    qp = quant.build_qparams(CFG, params, stats, "tiny-lm-b")
    assert qp["wq"]["q_proj"].dtype == jnp.int8
    assert qp["wq"]["q_proj"].shape == (2, 32, CFG.q_dim)
    assert not qp["quantized"]["down_proj"][0]
    assert qp["quantized"]["q_proj"][0]
    # quantized matmul close to fp
    from compile.kernels import ref
    x = jnp.asarray(np.random.default_rng(7).normal(size=(8, 32))
                    .astype(np.float32))
    y = ref.w8a8_matmul(
        x, qp["wq"]["q_proj"][0], qp["w_scale"]["q_proj"][0],
        jnp.float32(qp["x_scale"]["q_proj"][0]))
    yf = x @ params["wq"][0]
    rel = float(jnp.linalg.norm(y - yf) / jnp.linalg.norm(yf))
    assert rel < 0.1, rel


# -------------------------------------------------------- weight sparsity

@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), ratio=st.sampled_from([(2, 4),
                                                              (4, 8)]))
def test_weight_masks_are_nm(seed, ratio):
    n, m = ratio
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(32, 16)).astype(np.float32))
    for pruned in [
        ws.magnitude_prune(w, n, m),
        ws.wanda_prune(w, jnp.asarray(rng.uniform(0.1, 2.0, 32)
                                      .astype(np.float32)), n, m),
    ]:
        g = np.asarray(pruned).reshape(32 // m, m, 16)
        nz = (g != 0).sum(axis=1)
        assert (nz <= n).all()


def test_sparsegpt_beats_magnitude_on_reconstruction():
    """SparseGPT's OBS update must beat plain magnitude pruning on
    calibration-set reconstruction error."""
    rng = np.random.default_rng(8)
    x = rng.normal(size=(256, 32)).astype(np.float32)
    # correlated inputs (where OBS compensation matters)
    x[:, 1] = 0.9 * x[:, 0] + 0.1 * x[:, 1]
    w = jnp.asarray(rng.normal(size=(32, 16)).astype(np.float32))
    h = x.T @ x
    y = x @ np.asarray(w)
    w_sg = ws.sparsegpt_prune(w, h, 2, 4)
    w_mag = ws.magnitude_prune(w, 2, 4)
    e_sg = np.linalg.norm(x @ np.asarray(w_sg) - y)
    e_mag = np.linalg.norm(x @ np.asarray(w_mag) - y)
    assert e_sg < e_mag, f"sparsegpt {e_sg} !< magnitude {e_mag}"


def test_prune_model_weights_all_methods(params, tokens):
    calib = ws.collect_weight_calibration(
        CFG, params, [tokens], lambda p, t: M.loss_fn(CFG, p, t))
    for method in ("magnitude", "wanda", "sparsegpt", "prunerzero"):
        p2 = ws.prune_model_weights(CFG, params, calib, method, 2, 4)
        # every linear is 2:4 along d_in
        for wname in ("wq", "wk", "wv", "wo", "wg", "wu", "wd"):
            w = np.asarray(p2[wname][0])
            g = w.reshape(w.shape[0] // 4, 4, w.shape[1])
            assert ((g != 0).sum(axis=1) <= 2).all(), (method, wname)
        # model still runs
        out = M.forward(CFG, p2, tokens)
        assert np.isfinite(np.asarray(out)).all()


# ------------------------------------------------------------------ topk

def test_naive_mask_validity():
    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.normal(size=(8, 32)).astype(np.float32))
    mask = topk.naive_mask(x, 2, 4)
    assert topk.is_valid_nm(mask, 2, 4)
    assert topk.density(mask, 2, 4) == pytest.approx(0.5)
