"""Layer-2 model tests: shapes, decode==prefill consistency, pallas==ref
parity of the full forward, sparse-variant behaviors, MoE."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile import model as M
from compile import model_moe as MM
from compile.configs import ModelConfig

CFG = ModelConfig(name="t", vocab_size=64, d_model=32, n_layers=2,
                  n_q_heads=2, n_kv_heads=1, head_dim=16, d_ff=64)
MOE = ModelConfig(name="tm", vocab_size=64, d_model=32, n_layers=2,
                  n_q_heads=2, n_kv_heads=1, head_dim=16, d_ff=0,
                  n_experts=2, top_k_experts=1, d_ff_expert=32)


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def moe_params():
    return MM.init_params(MOE, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def tokens():
    rng = np.random.default_rng(0)
    return jnp.asarray(rng.integers(1, 64, (2, 16)), jnp.int32)


def test_forward_shapes(params, tokens):
    logits, ks, vs = M.forward(CFG, params, tokens, return_kv=True)
    assert logits.shape == (2, 16, 64)
    assert ks.shape == (2, 2, 16, 1, 16)  # [L, B, S, Hkv, Dh]
    assert vs.shape == ks.shape


def test_pallas_forward_matches_ref(params, tokens):
    a = M.forward(CFG, params, tokens)
    b = M.forward(CFG, params, tokens, use_pallas=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=2e-4, rtol=2e-4)


def test_pallas_sparse_forward_matches_ref(params, tokens):
    aux = M.default_aux(CFG)
    aux["keep_dense"] = jnp.zeros_like(aux["keep_dense"])
    a = M.forward(CFG, params, tokens, variant="nm", nm=(2, 4), aux=aux)
    b = M.forward(CFG, params, tokens, variant="nm", nm=(2, 4), aux=aux,
                  use_pallas=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=2e-4, rtol=2e-4)


def test_sparse_with_all_keep_equals_dense(params, tokens):
    aux = M.default_aux(CFG)  # keep_dense all ones
    a = M.forward(CFG, params, tokens, variant="nm", nm=(2, 4), aux=aux)
    b = M.forward(CFG, params, tokens)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=1e-5, rtol=1e-5)


def test_sparse_perturbs_monotonically(params, tokens):
    """2:4 must perturb the logits at least as much as 8:16 (on average)."""
    aux = M.default_aux(CFG)
    aux["keep_dense"] = jnp.zeros_like(aux["keep_dense"])
    base = M.forward(CFG, params, tokens)

    def err(nm):
        y = M.forward(CFG, params, tokens, variant="nm", nm=nm, aux=aux)
        return float(jnp.linalg.norm(y - base) / jnp.linalg.norm(base))

    e24, e48, e816 = err((2, 4)), err((4, 8)), err((8, 16))
    assert e24 > e816, f"{e24} !> {e816}"
    assert e24 > 0 and e816 > 0


def test_decode_matches_prefill(params, tokens):
    """Teacher-forced decode over the cache == prefill logits."""
    b, s = tokens.shape
    cache = 24
    logits_all, ks, vs = M.forward(CFG, params, tokens, return_kv=True)
    # seed cache with prefix of length s-2
    pre = s - 2
    kc = jnp.zeros((CFG.n_layers, b, cache, CFG.n_kv_heads, CFG.head_dim))
    vc = jnp.zeros_like(kc)
    lg, kp, vp = M.forward(CFG, params, tokens[:, :pre], return_kv=True)
    kc = kc.at[:, :, :pre].set(kp)
    vc = vc.at[:, :, :pre].set(vp)
    for i in range(pre, s):
        lg_step, kc, vc = M.decode_step(
            CFG, params, tokens[:, i],
            jnp.full((b,), i, jnp.int32), kc, vc,
            jnp.full((b,), i + 1, jnp.int32))
        np.testing.assert_allclose(
            np.asarray(lg_step), np.asarray(logits_all[:, i]),
            atol=5e-4, rtol=5e-4)


def test_moe_forward_and_router(moe_params, tokens):
    logits = MM.forward(MOE, moe_params, tokens)
    assert logits.shape == (2, 16, 64)
    # nm variant runs and differs from dense when pruning everything
    aux = MM.moe_aux(MOE)
    aux["keep_dense"] = jnp.zeros_like(aux["keep_dense"])
    sp = MM.forward(MOE, moe_params, tokens, variant="nm", nm=(2, 4),
                    aux=aux)
    assert not np.allclose(np.asarray(sp), np.asarray(logits))


def test_moe_decode_matches_prefill(moe_params, tokens):
    b, s = tokens.shape
    cache = 20
    logits_all, ks, vs = MM.forward(MOE, moe_params, tokens,
                                    return_kv=True)
    kc = jnp.zeros((MOE.n_layers, b, cache, MOE.n_kv_heads, MOE.head_dim))
    vc = jnp.zeros_like(kc)
    pre = s - 1
    lg, kp, vp = MM.forward(MOE, moe_params, tokens[:, :pre],
                            return_kv=True)
    kc = kc.at[:, :, :pre].set(kp)
    vc = vc.at[:, :, :pre].set(vp)
    lg_step, _, _ = MM.decode_step(
        MOE, moe_params, tokens[:, pre],
        jnp.full((b,), pre, jnp.int32), kc, vc,
        jnp.full((b,), s, jnp.int32))
    np.testing.assert_allclose(np.asarray(lg_step),
                               np.asarray(logits_all[:, pre]),
                               atol=5e-4, rtol=5e-4)


def test_loss_decreases_direction(params, tokens):
    """Gradient step on the LM loss reduces the loss (sanity)."""
    loss0, grads = jax.value_and_grad(
        lambda p: M.loss_fn(CFG, p, tokens))(params)
    params2 = jax.tree_util.tree_map(lambda p, g: p - 0.01 * g, params,
                                     grads)
    loss1 = M.loss_fn(CFG, params2, tokens)
    assert float(loss1) < float(loss0)
