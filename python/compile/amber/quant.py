"""W8A8 post-training quantization (paper §Outstanding-sparse setup).

Standard PTQ mirroring the paper:
  * weights:     symmetric per-output-channel int8 (computed offline);
  * activations: symmetric per-tensor *static* int8, scale calibrated on a
    small calibration set (the paper uses 50 BoolQ samples; we use 50
    boolean-skill samples from the synthetic corpus);
  * skip policies per model (paper: LLaMA skips the first 5 layers' linears
    and all down_proj; Qwen2 skips all down_proj).

Outputs a ``qparams`` structure the L2 model consumes:
    qparams["wq"][module][layer]        int8 [d_in, d_out]
    qparams["w_scale"][module][layer]   f32 [d_out]
    qparams["x_scale"][module][layer]   f32 scalar
    qparams["quantized"][module][layer] bool (skip policy)
"""

import numpy as np
import jax
import jax.numpy as jnp

from ..configs import DENSE_MODULES
from ..model import MODULE_IDX

WMAP = {"q_proj": "wq", "k_proj": "wk", "v_proj": "wv", "o_proj": "wo",
        "gate_proj": "wg", "up_proj": "wu", "down_proj": "wd"}


def quantize_weight(w):
    """Symmetric per-output-channel int8. w [d_in, d_out] ->
    (wq int8, scale [d_out])."""
    absmax = jnp.max(jnp.abs(w), axis=0)
    scale = jnp.maximum(absmax / 127.0, 1e-8)
    wq = jnp.clip(jnp.round(w / scale[None, :]), -127, 127).astype(jnp.int8)
    return wq, scale.astype(jnp.float32)


def dequantize_weight(wq, scale):
    return wq.astype(jnp.float32) * scale[None, :]


def act_scale_from_stats(absmax_scalar):
    """Per-tensor activation scale from calibrated |x|max."""
    return float(max(absmax_scalar / 127.0, 1e-8))


def skip_policy(model_name, n_layers):
    """Paper's per-model quantization skip lists -> set of (layer, module).

    LLaMA3.1-8B  -> tiny-lm-a: first 5 layers fully skipped (scaled to the
                    first ceil(5/32 * L) layers) + all down_proj.
    Qwen2-7B     -> tiny-lm-b: all down_proj skipped.
    Qwen3-30B    -> tiny-moe:  gate_proj never quantized.
    """
    skips = set()
    if model_name == "tiny-lm-a":
        n_first = max(1, round(5 / 32 * n_layers))
        for li in range(n_first):
            for m in DENSE_MODULES:
                skips.add((li, m))
        for li in range(n_layers):
            skips.add((li, "down_proj"))
    elif model_name == "tiny-lm-b":
        for li in range(n_layers):
            skips.add((li, "down_proj"))
    else:  # moe-style
        for li in range(n_layers):
            skips.add((li, "gate_proj"))
    return skips


def collect_activation_stats(cfg, params, batches, forward_fn):
    """Run calibration batches through the *reference* forward, capturing
    per-module input activations via jax interception-free bookkeeping:
    we re-run the forward manually layer by layer (cheap at tiny scale).

    Returns stats[module][layer] = dict(absmax=[d_in], tensor_absmax=float)
    """
    from ..kernels import ref
    from ..model import rmsnorm, attention_block, Projector

    stats = {m: [dict(absmax=None, tmax=0.0) for _ in range(cfg.n_layers)]
             for m in DENSE_MODULES}

    def upd(module, layer, x):
        x2 = np.asarray(x).reshape(-1, x.shape[-1])
        am = np.max(np.abs(x2), axis=0)
        st = stats[module][layer]
        st["absmax"] = am if st["absmax"] is None else np.maximum(
            st["absmax"], am)
        st["tmax"] = max(st["tmax"], float(am.max()))

    for tokens in batches:
        b, s = tokens.shape
        pos = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
        x = params["embed"][tokens]
        for layer in range(cfg.n_layers):
            proj = Projector(cfg, "dense", False, layer=layer)
            h = rmsnorm(x, params["ln_attn"][layer], cfg.rmsnorm_eps)
            upd("q_proj", layer, h)
            upd("k_proj", layer, h)
            upd("v_proj", layer, h)
            a, _ = attention_block(cfg, proj, params, layer, h, pos)
            # o_proj input: recompute the pre-projection attention output
            q = ref.rope((h @ params["wq"][layer]).reshape(
                b, s, cfg.n_q_heads, cfg.head_dim), pos, cfg.rope_theta)
            k = ref.rope((h @ params["wk"][layer]).reshape(
                b, s, cfg.n_kv_heads, cfg.head_dim), pos, cfg.rope_theta)
            v = (h @ params["wv"][layer]).reshape(
                b, s, cfg.n_kv_heads, cfg.head_dim)
            o_in = ref.causal_attention(q, k, v).reshape(b, s, cfg.q_dim)
            upd("o_proj", layer, o_in)
            x = x + a
            h = rmsnorm(x, params["ln_mlp"][layer], cfg.rmsnorm_eps)
            upd("gate_proj", layer, h)
            upd("up_proj", layer, h)
            g = h @ params["wg"][layer]
            u = h @ params["wu"][layer]
            hh = jax.nn.silu(g) * u
            upd("down_proj", layer, hh)
            x = x + hh @ params["wd"][layer]
    return stats


def build_qparams(cfg, params, stats, model_name):
    """Quantize all linear weights + attach calibrated activation scales."""
    skips = skip_policy(model_name, cfg.n_layers)
    qp = {"wq": {}, "w_scale": {}, "x_scale": {}, "quantized": {}}
    for module in DENSE_MODULES:
        wname = WMAP[module]
        wqs, wss, xss, qs = [], [], [], []
        for layer in range(cfg.n_layers):
            w = params[wname][layer]
            wq, ws = quantize_weight(w)
            wqs.append(wq)
            wss.append(ws)
            xss.append(act_scale_from_stats(stats[module][layer]["tmax"]))
            qs.append((layer, module) not in skips)
        qp["wq"][module] = jnp.stack(wqs)
        qp["w_scale"][module] = jnp.stack(wss)
        qp["x_scale"][module] = np.array(xss, dtype=np.float32)
        qp["quantized"][module] = np.array(qs, dtype=bool)
    return qp
