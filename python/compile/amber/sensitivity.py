"""Layer-skipping sensitivity analysis (paper Eq. 6-8, Appendix D).

For each (layer, module) candidate we prune *only that projection's input
activations* to N:M, run the full forward pass, and measure the relative
perturbation of the final hidden states:

    e_q(Y, Y') = ||Y - Y'||_2 / (||Y||_2 + eps)            (Eq. 8)

The skip policy then mirrors the paper's §Experimental Setup:
  * k_proj / v_proj: non-prunable outright — under GQA their FLOPs share is
    tiny, so pruning them buys ~nothing and only adds error;
  * o_proj / up_proj: preserved (highest mean sensitivity, Appendix D);
  * down_proj: pruned in ALL layers (consistently lowest sensitivity);
  * q_proj / gate_proj: pruned except in the top-`n_skip` most sensitive
    layers (selective skipping).

Outputs feed three places: the keep_dense aux tensor baked into artifacts,
the rust coverage accounting, and the Fig. 6 / Appendix D repro harness.
"""

import json

import jax.numpy as jnp
import numpy as np

from ..configs import DENSE_MODULES
from ..kernels import ref
from ..model import MODULE_IDX, default_aux
from .. import model as model_mod
from .. import model_moe as moe_mod

EPS = 1e-8

# modules that may ever be pruned, per the policy above
CANDIDATES = ("q_proj", "gate_proj", "down_proj")
ALWAYS_KEPT = ("k_proj", "v_proj", "o_proj", "up_proj")


def final_hidden(cfg, params, tokens, aux, nm, is_moe=False):
    """Forward returning final-layer hidden logits (the Y of Eq. 8).

    Uses the reference (non-pallas) path: sensitivity analysis is offline.
    """
    fwd = moe_mod.forward if is_moe else model_mod.forward
    kwargs = dict(variant="nm", nm=nm, aux=aux) if nm else dict()
    return fwd(cfg, params, tokens, **kwargs)


def perturbation_error(y, y_prime):
    """Eq. 8."""
    num = jnp.linalg.norm(y - y_prime)
    den = jnp.linalg.norm(y) + EPS
    return float(num / den)


def sensitivity_sweep(cfg, params, tokens, nm, is_moe=False,
                      modules=DENSE_MODULES):
    """e_q for every (layer, module) at sparsity ``nm``.

    Returns np.ndarray [n_layers, n_modules] of relative errors. The sparse
    forward is jit-compiled ONCE — the keep_dense flags are graph *inputs*,
    so the 7 x n_layers sweep reuses the compiled executable.
    """
    import jax

    base_aux = (moe_mod.moe_aux(cfg) if is_moe else default_aux(cfg))
    fwd = moe_mod.forward if is_moe else model_mod.forward
    y = jax.jit(lambda p, t: fwd(cfg, p, t))(params, tokens)

    @jax.jit
    def pruned_forward(p, t, aux):
        return fwd(cfg, p, t, variant="nm", nm=nm, aux=aux)

    errs = np.zeros((cfg.n_layers, len(modules)), dtype=np.float64)
    for li in range(cfg.n_layers):
        for mi, mod in enumerate(modules):
            aux = dict(base_aux)
            keep = np.ones((cfg.n_layers, len(DENSE_MODULES)), np.float32)
            keep[li, MODULE_IDX[mod]] = 0.0  # prune exactly this one
            aux["keep_dense"] = jnp.asarray(keep)
            yp = pruned_forward(params, tokens, aux)
            errs[li, mi] = perturbation_error(y, yp)
    return errs


def module_mean_sensitivity(errs, modules=DENSE_MODULES):
    """Average over layers — the Appendix D / Fig. 6 series."""
    return {m: float(errs[:, i].mean()) for i, m in enumerate(modules)}


def select_skip_layers(errs, n_skip, modules=DENSE_MODULES):
    """Pick the `n_skip` layers where q_proj+gate_proj are most sensitive.

    Mirrors the paper's per-model skip lists (e.g. LLaMA3.1-8B skips
    q/gate in layers {19, 21, 28, 30, 31}).
    """
    qi = modules.index("q_proj")
    gi = modules.index("gate_proj")
    combined = errs[:, qi] + errs[:, gi]
    order = np.argsort(-combined)
    return sorted(int(i) for i in order[:n_skip])


def build_keep_dense(cfg, skip_layers, *, no_skip=False):
    """keep_dense aux tensor [L, n_modules] implementing the policy.

    ``no_skip=True`` is the Naive-top-k setting: prune every module
    everywhere (Appendix A: "sensitive layer skipping was not applied").
    """
    L = cfg.n_layers
    keep = np.ones((L, len(DENSE_MODULES)), dtype=np.float32)
    if no_skip:
        keep[:] = 0.0
        return jnp.asarray(keep)
    for mod in CANDIDATES:
        keep[:, MODULE_IDX[mod]] = 0.0
    # selective re-skip of q/gate in sensitive layers
    for li in skip_layers:
        keep[li, MODULE_IDX["q_proj"]] = 1.0
        keep[li, MODULE_IDX["gate_proj"]] = 1.0
    return jnp.asarray(keep)


def linear_flops_prefill(cfg, seq, is_moe=False):
    """Per-token matmul FLOPs (2*din*dout) of each linear module.

    For MoE, expert modules count activated experts only (top-k), matching
    how the paper counts A3B's "activated" compute.
    """
    d, q, kv = cfg.d_model, cfg.q_dim, cfg.kv_dim
    out = {
        "q_proj": 2 * d * q,
        "k_proj": 2 * d * kv,
        "v_proj": 2 * d * kv,
        "o_proj": 2 * q * d,
    }
    if is_moe:
        k, fe = cfg.top_k_experts, cfg.d_ff_expert
        out["gate_proj"] = 2 * d * fe * k
        out["up_proj"] = 2 * d * fe * k
        out["down_proj"] = 2 * fe * d * k
    else:
        f = cfg.d_ff
        out["gate_proj"] = 2 * d * f
        out["up_proj"] = 2 * d * f
        out["down_proj"] = 2 * f * d
    return out


def coverage(cfg, keep_dense, is_moe=False):
    """Fraction of linear-projection FLOPs that run through the N:M path —
    the paper's ">55% of linear computations accelerated" metric."""
    fl = linear_flops_prefill(cfg, 1, is_moe)
    keep = np.asarray(keep_dense)
    total = 0.0
    pruned = 0.0
    for li in range(cfg.n_layers):
        for mod, f in fl.items():
            total += f
            if keep[li, MODULE_IDX[mod]] == 0.0:
                pruned += f
    return pruned / total


def export_report(path, cfg_name, nm, errs, skip_layers, cov,
                  modules=DENSE_MODULES):
    """JSON report consumed by the rust fig6/coverage harnesses."""
    report = {
        "model": cfg_name,
        "nm": list(nm),
        "modules": list(modules),
        "per_layer": errs.tolist(),
        "module_mean": module_mean_sensitivity(errs, modules),
        "skip_layers": skip_layers,
        "coverage": cov,
    }
    with open(path, "w") as f:
        json.dump(report, f, indent=1)
    return report
