"""Weight-sparsity baselines (paper Appendix A, Table 1).

The paper compares naive top-k *activation* sparsity against the
representative training-free *weight* N:M pruners and finds activation
sparsity dominates. We implement all of them:

  * ``magnitude``   |W| within each M-group (classic)
  * ``wanda``       S_ij = |W_ij| * ||X_:,j||_2 (Sun et al. 2023)
  * ``sparsegpt``   OBS-based: Hessian H = X^T X + lambda*I, per-column
                    pruning by w^2 / [H^-1]_jj with error propagation into
                    the remaining weights (Frantar & Alistarh 2023)
  * ``prunerzero``  gradient-aware symbolic metric |W| * G^2 (Dong et al.
                    2024's evolved metric family; gradients from the LM
                    loss on calibration batches)

Convention: model weights are [d_in, d_out] (x @ W). Hardware weight N:M
groups run along the *reduction* axis (d_in), i.e. axis 0, independently
for every output column.

Because weight sparsity only changes the weights, these baselines reuse
the *dense* AOT artifact — aot.py just emits extra weight files, and the
rust Appendix-A harness swaps them in.
"""

import numpy as np
import jax
import jax.numpy as jnp

from ..configs import DENSE_MODULES
from .quant import WMAP


def _nm_mask_axis0(score, n, m):
    """Exact N:M keep mask with groups along axis 0. score [d_in, d_out]."""
    din, dout = score.shape
    assert din % m == 0
    g = score.reshape(din // m, m, dout)
    order = jnp.argsort(-g, axis=1)
    rank = jnp.argsort(order, axis=1)
    return (rank < n).astype(score.dtype).reshape(din, dout)


def magnitude_prune(w, n, m):
    return w * _nm_mask_axis0(jnp.abs(w), n, m)


def wanda_prune(w, x_norm, n, m):
    """x_norm [d_in] = ||X_:,j||_2 over the calibration set."""
    score = jnp.abs(w) * x_norm[:, None]
    return w * _nm_mask_axis0(score, n, m)


def prunerzero_prune(w, g, n, m):
    """Gradient-aware: score = |W| * G^2."""
    score = jnp.abs(w) * (g * g)
    return w * _nm_mask_axis0(score, n, m)


def sparsegpt_prune(w, hessian, n, m, percdamp=0.01):
    """OBS pruning with error propagation (SparseGPT, column-sequential).

    w [d_in, d_out]; hessian [d_in, d_in] = X^T X over calibration.
    Walks input channels left->right in M-sized groups; within each group
    selects the N channels to KEEP per output column by the OBS saliency
    w^2 / [H^-1]_jj, zeroes the rest, and distributes each zeroed weight's
    reconstruction error onto the not-yet-processed channels via the
    inverse-Hessian row (the classic OBS update).
    """
    w = np.array(w, dtype=np.float64)
    h = np.array(hessian, dtype=np.float64)
    din, dout = w.shape
    assert din % m == 0

    dead = np.diag(h) == 0
    h[dead, dead] = 1.0
    w[dead, :] = 0.0
    damp = percdamp * np.mean(np.diag(h))
    h[np.diag_indices(din)] += damp

    # upper-Cholesky trick from the SparseGPT reference implementation:
    # Hinv's relevant rows come from inv via Cholesky for stability.
    hinv = np.linalg.inv(h)
    # symmetrize for numeric hygiene
    hinv = (hinv + hinv.T) / 2.0

    for g0 in range(0, din, m):
        # saliency of each channel in the group, per output column
        cols = np.arange(g0, g0 + m)
        diag = np.maximum(np.diag(hinv)[cols], 1e-12)  # [m]
        sal = (w[cols, :] ** 2) / diag[:, None]  # [m, dout]
        # rank within group: keep top-n saliency per output column
        order = np.argsort(-sal, axis=0, kind="stable")
        rank = np.argsort(order, axis=0, kind="stable")
        prune_mask = rank >= n  # [m, dout] True = prune
        for off in range(m):
            j = g0 + off
            pj = prune_mask[off]  # [dout]
            if not pj.any():
                continue
            err = np.where(pj, w[j, :] / max(hinv[j, j], 1e-12), 0.0)
            # propagate into *remaining* (not yet processed) channels
            w[j + 1:, :] -= np.outer(hinv[j, j + 1:], err)
            w[j, pj] = 0.0
    return jnp.asarray(w.astype(np.float32))


# ---------------------------------------------------------------------------
# Whole-model drivers
# ---------------------------------------------------------------------------

def collect_weight_calibration(cfg, params, batches, loss_fn):
    """Per-module input-channel L2 norms, Hessians and gradients from
    calibration batches (shared by wanda / sparsegpt / prunerzero)."""
    from .quant import collect_activation_stats

    # activation L2 norms + Hessians need raw inputs; reuse the
    # layer-by-layer capture from quant.py but accumulate X^T X.
    from ..kernels import ref
    from ..model import rmsnorm, attention_block, Projector

    norms = {mod: [np.zeros(0)] * cfg.n_layers for mod in DENSE_MODULES}
    hess = {mod: [None] * cfg.n_layers for mod in DENSE_MODULES}

    def upd(module, layer, x):
        x2 = np.asarray(x, dtype=np.float64).reshape(-1, x.shape[-1])
        nrm = np.sqrt((x2 ** 2).sum(axis=0))
        if norms[module][layer].size == 0:
            norms[module][layer] = nrm ** 2
            hess[module][layer] = x2.T @ x2
        else:
            norms[module][layer] += nrm ** 2
            hess[module][layer] += x2.T @ x2

    for tokens in batches:
        b, s = tokens.shape
        pos = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
        x = params["embed"][tokens]
        for layer in range(cfg.n_layers):
            proj = Projector(cfg, "dense", False, layer=layer)
            h = rmsnorm(x, params["ln_attn"][layer], cfg.rmsnorm_eps)
            for mod in ("q_proj", "k_proj", "v_proj"):
                upd(mod, layer, h)
            a, _ = attention_block(cfg, proj, params, layer, h, pos)
            q = ref.rope((h @ params["wq"][layer]).reshape(
                b, s, cfg.n_q_heads, cfg.head_dim), pos, cfg.rope_theta)
            k = ref.rope((h @ params["wk"][layer]).reshape(
                b, s, cfg.n_kv_heads, cfg.head_dim), pos, cfg.rope_theta)
            v = (h @ params["wv"][layer]).reshape(
                b, s, cfg.n_kv_heads, cfg.head_dim)
            o_in = ref.causal_attention(q, k, v).reshape(b, s, cfg.q_dim)
            upd("o_proj", layer, o_in)
            x = x + a
            h = rmsnorm(x, params["ln_mlp"][layer], cfg.rmsnorm_eps)
            upd("gate_proj", layer, h)
            upd("up_proj", layer, h)
            g = h @ params["wg"][layer]
            u = h @ params["wu"][layer]
            hh = jax.nn.silu(g) * u
            upd("down_proj", layer, hh)
            x = x + hh @ params["wd"][layer]

    for mod in DENSE_MODULES:
        for layer in range(cfg.n_layers):
            norms[mod][layer] = np.sqrt(norms[mod][layer])

    # gradients for prunerzero
    grad_fn = jax.grad(lambda p, t: loss_fn(p, t))
    grads = None
    for tokens in batches:
        g = grad_fn(params, tokens)
        if grads is None:
            grads = {k: np.asarray(v, dtype=np.float64)
                     for k, v in g.items()}
        else:
            for k2, v in g.items():
                grads[k2] += np.asarray(v, dtype=np.float64)
    return dict(norms=norms, hess=hess, grads=grads)


def prune_model_weights(cfg, params, calib, method, n, m):
    """Return a new params dict with every linear projection N:M
    weight-pruned by ``method``."""
    p = dict(params)
    for module in DENSE_MODULES:
        wname = WMAP[module]
        pruned = []
        for layer in range(cfg.n_layers):
            w = p[wname][layer]
            if method == "magnitude":
                pw = magnitude_prune(w, n, m)
            elif method == "wanda":
                pw = wanda_prune(w, jnp.asarray(
                    calib["norms"][module][layer], jnp.float32), n, m)
            elif method == "sparsegpt":
                pw = sparsegpt_prune(w, calib["hess"][module][layer], n, m)
            elif method == "prunerzero":
                g = jnp.asarray(calib["grads"][wname][layer], jnp.float32)
                pw = prunerzero_prune(w, g, n, m)
            else:
                raise ValueError(method)
            pruned.append(pw)
        p[wname] = jnp.stack(pruned)
    return p
