"""Weight-aware activation scoring (paper §Methodology).

The activation element X_ij is scored by S_ij = |X_ij| * f(W_:,j), where
f summarizes the importance of input-channel j of the downstream weight
matrix. Two variants:

  * ``wanda_scales``  — Eq. 2: raw column L2 norms, min-normalized so the
    smallest channel weight is exactly 1 (guards against underflow in
    low-precision inference).
  * ``robust_norm_scales`` — Eq. 3-5 (Robust-Norm Scoring): clip W to its
    [0.5, 99.5] percentile range, standardize by global mean/variance, then
    take min-normalized column L2 norms of the standardized weights. The
    standardization spreads concentrated, low-variance weight distributions
    so boundary-critical channels separate.

The scales are *precomputed offline* and shipped as auxiliary weights; the
online kernel just multiplies |x| by them (kernels/nm_prune.py).

Convention: our weight matrices are stored [d_in, d_out] (x @ W), so the
paper's "column" W_:,j (all weights consuming input channel j) is our
*row* W[j, :].
"""

import jax.numpy as jnp


def _min_normalize(norms, eps=1e-12):
    return norms / (jnp.min(norms) + eps)


def wanda_scales(w):
    """Eq. 2 channel statistic. w [d_in, d_out] -> scales [d_in]."""
    norms = jnp.linalg.norm(w, axis=1)
    return _min_normalize(norms)


def robust_norm_scales(w, q_lo=0.005, q_hi=0.995):
    """Robust-Norm Scoring (Eq. 3-5). w [d_in, d_out] -> scales [d_in].

    1. Outlier removal: clip weights outside the [q_lo, q_hi] quantiles
       (clipping rather than discarding keeps the tensor rectangular; the
       extreme <1% of values stop dominating either way).
    2. Standardize with the clipped tensor's global mean/variance.
    3. Min-normalized per-input-channel L2 norms of the standardized
       weights.
    """
    lo = jnp.quantile(w, q_lo)
    hi = jnp.quantile(w, q_hi)
    wc = jnp.clip(w, lo, hi)
    mu = jnp.mean(wc)
    var = jnp.var(wc) + 1e-12
    wn = (wc - mu) / jnp.sqrt(var)
    norms = jnp.linalg.norm(wn, axis=1)
    return _min_normalize(norms)


# weight-name mapping used when building the aux scale tensors
_MODULE_WEIGHTS = {
    "q_proj": "wq", "k_proj": "wk", "v_proj": "wv", "o_proj": "wo",
    "gate_proj": "wg", "up_proj": "wu", "down_proj": "wd",
}


def build_aux_scales(cfg, params, method="robust"):
    """Per-(layer, module) channel scales for the whole model.

    method: "ones" (naive top-k), "wanda" (Eq. 2), "robust" (Eq. 3-5).
    Returns a dict shaped like model.default_aux()'s scale tensors.
    """
    from ..model import AUX_SCALE_NAMES

    fn = {"wanda": wanda_scales, "robust": robust_norm_scales}.get(method)
    out = {}
    for module, wname in _MODULE_WEIGHTS.items():
        aux_name = AUX_SCALE_NAMES[module]
        per_layer = []
        for layer in range(cfg.n_layers):
            w = params[wname][layer]
            per_layer.append(jnp.ones((w.shape[0],), jnp.float32)
                             if fn is None else fn(w))
        out[aux_name] = jnp.stack(per_layer)
    return out
