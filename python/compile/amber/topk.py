"""Naive N:M top-k activation masks — the paper's activation baseline.

Thin wrappers over the reference kernels with scale == 1 (pure magnitude).
Kept as its own module because the baseline appears in every table.
"""

import jax.numpy as jnp

from ..kernels import ref


def naive_mask(x, n, m):
    """Magnitude-only exact N:M keep mask."""
    return ref.nm_mask(jnp.abs(x), n, m)


def naive_prune(x, n, m):
    return x * naive_mask(x, n, m)


def density(mask, n, m):
    """Fraction of kept elements — must be exactly n/m for a valid mask."""
    return float(jnp.mean(mask))


def is_valid_nm(mask, n, m) -> bool:
    """Check the structural constraint: <= n nonzeros per m-group."""
    d = mask.shape[-1]
    g = mask.reshape(*mask.shape[:-1], d // m, m)
    return bool(jnp.all(jnp.sum(g, axis=-1) <= n))
