"""SmoothQuant scaling and the Outstanding-sparse inversion (paper Eq. 9).

SmoothQuant migrates activation outliers into the weights with a
per-channel scale

    s_j = max|X_:,j|^alpha / max|W_j,:|^(1-alpha)          (Eq. 9)

applied as  X' = X / s,  W' = diag(s) @ W  (output-preserving).

Outstanding-sparse observes that Amber Pruner selects *better* when the
activation range is expanded (structured sparsity patterns become visible),
so it applies the INVERTED factor s_hat = 1/s with a small alpha (0.10):
activations are stretched, weights shrink correspondingly, and the N:M
top-k picks survivors on the stretched distribution before quantization.

Like Robust-Norm scales, the smoothing is folded offline: X/s never happens
at runtime — s is absorbed into the preceding RMSNorm gain (for q/k/v/gate/
up) or into the preceding projection's weight columns (for o/down).
"""

import jax.numpy as jnp
import numpy as np


def smoothquant_scale(x_absmax, w_absmax, alpha=0.5, eps=1e-8):
    """Eq. 9. x_absmax, w_absmax [d_in] -> s [d_in]."""
    s = (x_absmax + eps) ** alpha / (w_absmax + eps) ** (1.0 - alpha)
    # guard degenerate channels (never-activated calibration channels)
    return jnp.maximum(s, eps)


def outstanding_scale(x_absmax, w_absmax, alpha=0.10, eps=1e-8):
    """Outstanding-sparse: s_hat = 1/s with small alpha — *expands* the
    activation range instead of compressing it."""
    return 1.0 / smoothquant_scale(x_absmax, w_absmax, alpha, eps)


def apply_smoothing(x, w, s):
    """Reference semantics (tests): (x/s) @ (s*w) == x @ w."""
    return x / s[None, :], w * s[:, None]


def absmax_stats(xs):
    """Per-channel max|x| over a calibration batch list."""
    m = None
    for x in xs:
        cur = jnp.max(jnp.abs(x.reshape(-1, x.shape[-1])), axis=0)
        m = cur if m is None else jnp.maximum(m, cur)
    return m


def fold_into_params(params, layer, module, s):
    """Fold activation scaling 1/s into the producer of this module's input.

    Producers:
      q/k/v   <- ln_attn gain        gate/up <- ln_mlp gain
      down    <- wu output columns
    o_proj is NOT smoothed: its input is the attention output, whose
    producer (v) sits behind the softmax-weighted average and, under GQA,
    a head-group broadcast — released SmoothQuant likewise restricts
    smoothing to LayerNorm-foldable inputs. For `down`, the input
    h = silu(g) * u is linear in u, so scaling wu's output columns by 1/s
    is exact.

    Consumer weights are multiplied by s row-wise. Returns updated params
    (functional).
    """
    p = dict(params)
    s = jnp.asarray(s)
    inv = 1.0 / s
    if module in ("q_proj", "k_proj", "v_proj"):
        p["ln_attn"] = p["ln_attn"].at[layer].mul(inv)
        for wn in ("wq", "wk", "wv"):
            p[wn] = p[wn].at[layer].mul(s[:, None])
    elif module in ("gate_proj", "up_proj"):
        p["ln_mlp"] = p["ln_mlp"].at[layer].mul(inv)
        for wn in ("wg", "wu"):
            p[wn] = p[wn].at[layer].mul(s[:, None])
    elif module == "down_proj":
        p["wu"] = p["wu"].at[layer].mul(inv[None, :])
        p["wd"] = p["wd"].at[layer].mul(s[:, None])
    else:
        raise ValueError(f"module {module} is not smoothable")
    return p


def smooth_model(cfg, params, act_stats, alpha=0.10, inverted=True,
                 modules=("q_proj", "gate_proj", "down_proj")):
    """Apply (inverted) smoothing to every foldable module group.

    ``act_stats[module][layer]`` = per-channel |x|max from calibration.
    q/k/v share one input (post-ln_attn) and must share one s — we use
    q_proj's stats (dominant FLOPs). gate/up share the post-ln_mlp input;
    we use gate's stats and fold once.
    """
    wmap = {"q_proj": "wq", "gate_proj": "wg", "down_proj": "wd"}
    p = params
    scale_fn = outstanding_scale if inverted else smoothquant_scale
    applied = {}
    for layer in range(cfg.n_layers):
        for module in modules:
            w = p[wmap[module]][layer]
            xmax = jnp.asarray(act_stats[module][layer])
            wmax = jnp.max(jnp.abs(w), axis=1)
            s = scale_fn(xmax, wmax, alpha)
            p = fold_into_params(p, layer, module, s)
            applied[(layer, module)] = np.asarray(s)
    return p, applied
