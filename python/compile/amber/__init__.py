"""Amber Pruner algorithms (offline / calibration side).

  * ``topk``            naive N:M magnitude masks (the paper's baseline)
  * ``scoring``         Wanda-like reversed scoring (Eq. 2) and
                        Robust-Norm Scoring (Eq. 3-5)
  * ``sensitivity``     relative perturbation error e_q (Eq. 8) and the
                        layer-skipping policy derived from it
  * ``smoothquant``     SmoothQuant scaling (Eq. 9) and the inverted
                        Outstanding-sparse variant (s_hat = 1/s, alpha=0.10)
  * ``quant``           W8A8 post-training quantization
  * ``weight_sparsity`` the weight-pruning baselines of Appendix A
                        (magnitude, Wanda, SparseGPT, Pruner-Zero-style)

All of this runs offline at `make artifacts` time; its outputs ship as
auxiliary weights next to the model parameters (< 0.05 % extra size, as the
paper reports).
"""
