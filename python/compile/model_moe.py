"""Layer-2 JAX model: Mixture-of-Experts variant (Qwen3-30B-A3B analogue).

Same attention backbone as model.py; the MLP is a top-2-of-N-expert MoE.
Per the paper, Robust-Norm Scoring is *not applicable* to MoE models
(tokens are routed dynamically, so a per-channel weight statistic of "the"
expert does not exist) — expert projections therefore always use naive
magnitude scores (scale == 1), while the layer-skip flags still apply.
Attention projections behave exactly as in the dense model.

Implementation note: every token is pushed through every expert and the
results are combined with the router's (renormalized) top-2 weights. At
these sizes that is cheaper than gather/scatter dispatch and — crucially —
keeps the lowered HLO free of dynamic shapes, which the AOT path requires.
The *served* FLOPs accounting in rust uses the activated-expert count, as
the paper does for A3B.
"""

import jax
import jax.numpy as jnp

from .configs import ModelConfig, DENSE_MODULES
from .kernels import ref
from .kernels import nm_spmm as k_spmm
from .model import (MODULE_IDX, Projector, attention_block, rmsnorm,
                    default_aux)


def init_params(cfg: ModelConfig, key) -> dict:
    import dataclasses
    from .model import init_params as dense_init_params
    # reuse attention/embedding init from a d_ff=1 dense config, then
    # replace the MLP weights with per-expert stacks + router.
    base = dense_init_params(dataclasses.replace(cfg, d_ff=1), key)
    d, fe, ne, L = cfg.d_model, cfg.d_ff_expert, cfg.n_experts, cfg.n_layers
    keys = jax.random.split(jax.random.fold_in(key, 99), 4)

    def dense_init(k, shape, fan_in):
        return jax.random.normal(k, shape, jnp.float32) / jnp.sqrt(fan_in)

    for name in ("wg", "wu", "wd"):
        base.pop(name)
    base["router"] = dense_init(keys[0], (L, d, ne), d)
    base["we_g"] = dense_init(keys[1], (L, ne, d, fe), d)
    base["we_u"] = dense_init(keys[2], (L, ne, d, fe), d)
    base["we_d"] = dense_init(keys[3], (L, ne, fe, d), fe)
    return base


def moe_aux(cfg: ModelConfig) -> dict:
    """Aux tensors for the MoE model: same keep_dense flags; expert scales
    exist but are pinned to ones (Robust-Norm N/A under dynamic routing)."""
    aux = default_aux(cfg)
    L = cfg.n_layers
    aux["scale_g"] = jnp.ones((L, cfg.d_model), jnp.float32)
    aux["scale_u"] = jnp.ones((L, cfg.d_model), jnp.float32)
    aux["scale_d"] = jnp.ones((L, cfg.d_ff_expert), jnp.float32)
    return aux


def _expert_proj(name, x2, w, nm, aux, layer, use_pallas):
    """Per-expert linear with optional N:M pruning (naive scores only)."""
    if nm is None:
        return (k_spmm.matmul(x2, w) if use_pallas else ref.matmul(x2, w))
    n, m = nm
    keep = aux["keep_dense"][layer, MODULE_IDX[name]]
    scale = jnp.ones((x2.shape[-1],), jnp.float32)
    fn = k_spmm.nm_prune_matmul if use_pallas else ref.nm_prune_matmul
    return fn(x2, w, scale, n, m, keep)


def moe_block(cfg, params, layer, x, nm, aux, use_pallas):
    """Top-k expert MLP. x [B, S, D] -> [B, S, D].

    Router top-k is computed with k successive argmax passes rather than
    ``jax.lax.top_k``: the latter lowers to a `topk(..., largest=true)`
    HLO instruction that xla_extension 0.5.1's text parser rejects, and
    the AOT interchange format is HLO text (see aot.py).
    """
    b, s, d = x.shape
    x2 = x.reshape(b * s, d)
    logits = jnp.dot(x2, params["router"][layer])  # [T, E]
    # iterative top-k: argmax, mask, repeat
    remaining = logits
    sel_onehots = []
    sel_logits = []
    for _ in range(cfg.top_k_experts):
        idx = jnp.argmax(remaining, axis=-1)
        oh = jax.nn.one_hot(idx, cfg.n_experts, dtype=logits.dtype)
        sel_onehots.append(oh)
        sel_logits.append(jnp.sum(logits * oh, axis=-1))
        remaining = jnp.where(oh > 0, -jnp.inf, remaining)
    top_vals = jnp.stack(sel_logits, axis=-1)  # [T, k]
    top_w = jax.nn.softmax(top_vals, axis=-1)  # renormalized over the top-k
    # dense-dispatch: every expert computes, router weights combine.
    gate_w = sum(top_w[:, i:i + 1] * sel_onehots[i]
                 for i in range(cfg.top_k_experts))  # [T, E]
    out = jnp.zeros_like(x2)
    for e in range(cfg.n_experts):
        g = _expert_proj("gate_proj", x2, params["we_g"][layer, e], nm, aux,
                         layer, use_pallas)
        u = _expert_proj("up_proj", x2, params["we_u"][layer, e], nm, aux,
                         layer, use_pallas)
        h = jax.nn.silu(g) * u
        y = _expert_proj("down_proj", h, params["we_d"][layer, e], nm, aux,
                         layer, use_pallas)
        out = out + gate_w[:, e:e + 1] * y
    return out.reshape(b, s, d)


def forward(cfg: ModelConfig, params: dict, tokens, *, variant="dense",
            nm=None, aux=None, use_pallas=False, return_kv=False, pos=None):
    """MoE prefill forward. Variants: "dense" or "nm" (fp only — the paper's
    MoE W8A8 hybrid uses per-token dynamic quantization, which we note in
    DESIGN.md but do not lower; Outstanding-sparse MoE rows reuse the fp
    graph with the quantization delta folded into the eval harness)."""
    b, s = tokens.shape
    if pos is None:
        pos = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    if aux is None:
        aux = moe_aux(cfg)
    x = params["embed"][tokens]
    proj_variant = "dense" if variant == "dense" else "nm"
    kvs = []
    for layer in range(cfg.n_layers):
        proj = Projector(cfg, proj_variant, use_pallas,
                         nm=nm, aux=aux, layer=layer)
        h = rmsnorm(x, params["ln_attn"][layer], cfg.rmsnorm_eps)
        a, kv = attention_block(cfg, proj, params, layer, h, pos,
                                use_pallas=use_pallas)
        x = x + a
        h = rmsnorm(x, params["ln_mlp"][layer], cfg.rmsnorm_eps)
        x = x + moe_block(cfg, params, layer, h,
                          nm if variant != "dense" else None, aux,
                          use_pallas)
        kvs.append(kv)
    x = rmsnorm(x, params["ln_final"], cfg.rmsnorm_eps)
    logits = jnp.dot(x, params["unembed"])
    if return_kv:
        ks = jnp.stack([kv[0] for kv in kvs])
        vs = jnp.stack([kv[1] for kv in kvs])
        return logits, ks, vs
    return logits


def decode_step(cfg: ModelConfig, params: dict, token, pos, k_cache,
                v_cache, kv_len, *, use_pallas=False):
    """Dense single-token decode for the MoE model."""
    b = token.shape[0]
    tokens = token[:, None]
    pos2 = pos[:, None]
    x = params["embed"][tokens]
    aux = moe_aux(cfg)
    new_ks, new_vs = [], []
    for layer in range(cfg.n_layers):
        proj = Projector(cfg, "dense", False, layer=layer)
        h = rmsnorm(x, params["ln_attn"][layer], cfg.rmsnorm_eps)
        a, (ck, cv) = attention_block(
            cfg, proj, params, layer, h, pos2,
            kv_cache=(k_cache[layer], v_cache[layer]), kv_len=kv_len)
        x = x + a
        h = rmsnorm(x, params["ln_mlp"][layer], cfg.rmsnorm_eps)
        x = x + moe_block(cfg, params, layer, h, None, aux, False)
        new_ks.append(ck)
        new_vs.append(cv)
    x = rmsnorm(x, params["ln_final"], cfg.rmsnorm_eps)
    logits = jnp.dot(x[:, 0], params["unembed"])
    return logits, jnp.stack(new_ks), jnp.stack(new_vs)


def loss_fn(cfg: ModelConfig, params: dict, tokens):
    logits = forward(cfg, params, tokens)
    targets = tokens[:, 1:]
    lp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    ll = jnp.take_along_axis(lp, targets[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)
