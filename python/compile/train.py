"""Tiny-model trainer (build path).

Trains the substitute models on the structured synthetic corpus so that
the activation statistics Amber Pruner exploits (near-zero mass, channel
outliers, per-channel weight-norm spread) are *emergent*, not faked.

Pure-jnp model path (no Pallas — that's the AOT path), Adam + cosine decay
with linear warmup, gradient clipping. Checkpoints are cached under
``artifacts/ckpt/<name>.npz``; `make artifacts` skips training when the
checkpoint exists and the config hash matches.

Run manually:  cd python && python -m compile.train [model ...]
"""

import functools
import hashlib
import json
import os
import time

import numpy as np
import jax
import jax.numpy as jnp

from . import corpus
from .configs import MODELS, ModelConfig, TrainConfig
from . import model as model_mod
from . import model_moe as moe_mod

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def cfg_hash(cfg: ModelConfig, tc: TrainConfig) -> str:
    blob = json.dumps([cfg.__dict__, tc.__dict__], sort_keys=True,
                      default=str)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def adam_init(params):
    z = jax.tree_util.tree_map(jnp.zeros_like, params)
    return dict(m=z, v=jax.tree_util.tree_map(jnp.zeros_like, params),
                step=jnp.zeros((), jnp.int32))


def lr_schedule(tc: TrainConfig, step):
    warm = jnp.minimum(step / max(tc.warmup, 1), 1.0)
    prog = jnp.clip((step - tc.warmup) / max(tc.steps - tc.warmup, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return tc.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(x * x)
                        for x in jax.tree_util.tree_leaves(tree)))


def make_train_step(cfg: ModelConfig, tc: TrainConfig, loss_fn):
    @jax.jit
    def step_fn(params, opt, tokens):
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, tokens))(params)
        gn = global_norm(grads)
        clip = jnp.minimum(1.0, tc.grad_clip / (gn + 1e-9))
        grads = jax.tree_util.tree_map(lambda g: g * clip, grads)
        step = opt["step"] + 1
        lr = lr_schedule(tc, step)
        b1, b2, eps = 0.9, 0.95, 1e-9

        def upd(m, g):
            return b1 * m + (1 - b1) * g

        def updv(v, g):
            return b2 * v + (1 - b2) * g * g

        m = jax.tree_util.tree_map(upd, opt["m"], grads)
        v = jax.tree_util.tree_map(updv, opt["v"], grads)
        mhat = jax.tree_util.tree_map(
            lambda x: x / (1 - b1 ** step.astype(jnp.float32)), m)
        vhat = jax.tree_util.tree_map(
            lambda x: x / (1 - b2 ** step.astype(jnp.float32)), v)
        new_params = jax.tree_util.tree_map(
            lambda p, mh, vh: p - lr * (mh / (jnp.sqrt(vh) + eps)
                                        + tc.weight_decay * p),
            params, mhat, vhat)
        return new_params, dict(m=m, v=v, step=step), loss, gn
    return step_fn


def train_model(name: str, verbose=True):
    cfg, tc = MODELS[name]
    is_moe = cfg.is_moe
    mod = moe_mod if is_moe else model_mod
    key = jax.random.PRNGKey(tc.seed)
    params = mod.init_params(cfg, key)
    opt = adam_init(params)
    step_fn = make_train_step(cfg, tc, mod.loss_fn)
    stream = corpus.training_stream(tc.seed, tc.skills, tc.batch_size,
                                    tc.seq_len)
    t0 = time.time()
    losses = []
    for i in range(tc.steps):
        tokens = jnp.asarray(next(stream))
        params, opt, loss, gn = step_fn(params, opt, tokens)
        if i % tc.log_every == 0 or i == tc.steps - 1:
            losses.append((i, float(loss)))
            if verbose:
                dt = time.time() - t0
                print(f"[{name}] step {i:5d} loss {float(loss):.4f} "
                      f"gnorm {float(gn):.2f} ({dt:.0f}s)", flush=True)
    # long-context phase (fresh jit: different shapes)
    if tc.long_steps > 0:
        long_stream = corpus.training_stream(
            tc.seed + 1_000_003, corpus.LONG_SKILLS, tc.long_batch,
            tc.long_seq)
        long_step_fn = make_train_step(cfg, tc, mod.loss_fn)
        for i in range(tc.long_steps):
            tokens = jnp.asarray(next(long_stream))
            params, opt, loss, gn = long_step_fn(params, opt, tokens)
            if i % tc.log_every == 0 or i == tc.long_steps - 1:
                losses.append((tc.steps + i, float(loss)))
                if verbose:
                    dt = time.time() - t0
                    print(f"[{name}] long {i:5d} loss {float(loss):.4f} "
                          f"({dt:.0f}s)", flush=True)
    return params, losses


def save_checkpoint(name, params, losses, h):
    os.makedirs(os.path.join(ARTIFACTS, "ckpt"), exist_ok=True)
    path = os.path.join(ARTIFACTS, "ckpt", f"{name}.npz")
    flat = {k: np.asarray(v) for k, v in params.items()}
    np.savez(path, __hash__=np.frombuffer(
        h.encode(), dtype=np.uint8), **flat)
    with open(os.path.join(ARTIFACTS, "ckpt", f"{name}.loss.json"), "w") as f:
        json.dump(losses, f)
    return path


def load_checkpoint(name):
    path = os.path.join(ARTIFACTS, "ckpt", f"{name}.npz")
    if not os.path.exists(path):
        return None, None
    z = np.load(path)
    h = bytes(z["__hash__"]).decode()
    params = {k: jnp.asarray(z[k]) for k in z.files if k != "__hash__"}
    return params, h


def get_or_train(name: str, verbose=True):
    """Cached-train entrypoint used by aot.py."""
    cfg, tc = MODELS[name]
    h = cfg_hash(cfg, tc)
    params, got = load_checkpoint(name)
    if params is not None and got == h:
        if verbose:
            print(f"[{name}] using cached checkpoint")
        return params
    params, losses = train_model(name, verbose)
    save_checkpoint(name, params, losses, h)
    return params


def main():
    import sys
    names = sys.argv[1:] or list(MODELS)
    for name in names:
        get_or_train(name)


if __name__ == "__main__":
    main()
