"""Ablation studies for the design choices DESIGN.md calls out.

Run:  cd python && python -m compile.ablation [--out ../artifacts]

Emits artifacts/stats/ablation.json with three sweeps (printed by
`amber repro ablation`):

  A1  scoring method: naive |x| vs Wanda-like (Eq. 2) vs Robust-Norm
      (Eq. 3-5) — relative output error ||Wx - Wx'|| / ||Wx|| per ratio,
      measured on real calibration activations of tiny-lm-a.
  A2  Robust-Norm clipping percentile (the 0.5/99.5 choice): sweep the
      clip quantile and measure the same output error at 2:4.
  A3  Outstanding-sparse alpha (the 0.10 choice): sweep alpha in the
      *inverted* scaling and measure (a) activation-range expansion and
      (b) N:M pruning output error on the smoothed tensors.
"""

import argparse
import json
import os

import numpy as np
import jax.numpy as jnp

from . import corpus, train
from .amber import scoring, smoothquant
from .configs import MODELS
from .kernels import ref


def calibration_activations(cfg, params, n_batches=2):
    """Real post-ln inputs of gate_proj at every layer."""
    import jax
    from .model import Projector, attention_block, rmsnorm

    rng = np.random.Generator(np.random.PCG64(777))
    out = []
    for _ in range(n_batches):
        tokens = jnp.asarray(corpus.pack_batch(
            rng, corpus.WORLD, ("grammar_a", "facts_a", "arith"), 8, 48))
        b, s = tokens.shape
        pos = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
        x = params["embed"][tokens]
        acts = []
        for li in range(cfg.n_layers):
            proj = Projector(cfg, "dense", False, layer=li)
            h = rmsnorm(x, params["ln_attn"][li], cfg.rmsnorm_eps)
            a, _ = attention_block(cfg, proj, params, li, h, pos)
            x = x + a
            h2 = rmsnorm(x, params["ln_mlp"][li], cfg.rmsnorm_eps)
            acts.append(h2.reshape(-1, cfg.d_model))
            g = h2 @ params["wg"][li]
            u = h2 @ params["wu"][li]
            x = x + (jax.nn.silu(g) * u) @ params["wd"][li]
        out.append(acts)
    # concat over batches, per layer
    return [jnp.concatenate([b[li] for b in out])
            for li in range(cfg.n_layers)]


def output_error(x, w, scale, n, m):
    y = x @ w
    xp = ref.nm_prune(x, scale, n, m)
    return float(jnp.linalg.norm(xp @ w - y) / (jnp.linalg.norm(y) + 1e-9))


def sweep_scoring(cfg, params, acts):
    res = {}
    for (n, m) in [(2, 4), (4, 8), (8, 16)]:
        rows = {}
        for method in ("naive", "wanda", "robust"):
            errs = []
            for li in range(cfg.n_layers):
                w = params["wg"][li]
                if method == "naive":
                    s = jnp.ones((cfg.d_model,), jnp.float32)
                elif method == "wanda":
                    s = scoring.wanda_scales(w)
                else:
                    s = scoring.robust_norm_scales(w)
                errs.append(output_error(acts[li], w, s, n, m))
            rows[method] = float(np.mean(errs))
        res[f"{n}:{m}"] = rows
    return res


def sweep_percentile(cfg, params, acts):
    res = {}
    for q in (0.0, 0.001, 0.005, 0.02, 0.05):
        errs = []
        for li in range(cfg.n_layers):
            w = params["wg"][li]
            s = scoring.robust_norm_scales(w, q_lo=q, q_hi=1.0 - q)
            errs.append(output_error(acts[li], w, s, 2, 4))
        res[f"{q}"] = float(np.mean(errs))
    return res


def sweep_alpha(cfg, params, acts):
    res = {}
    for alpha in (0.05, 0.10, 0.25, 0.5, 0.75):
        exps, errs = [], []
        for li in range(cfg.n_layers):
            w = params["wg"][li]
            x = acts[li]
            xmax = jnp.max(jnp.abs(x), axis=0)
            wmax = jnp.max(jnp.abs(w), axis=1)
            s_hat = smoothquant.outstanding_scale(xmax, wmax, alpha)
            xs = x / s_hat[None, :]
            ws = w * s_hat[:, None]
            exps.append(float(jnp.max(jnp.abs(xs)) / jnp.max(jnp.abs(x))))
            s = scoring.robust_norm_scales(ws)
            errs.append(output_error(xs, ws, s, 2, 4))
        res[f"{alpha}"] = dict(range_expansion=float(np.mean(exps)),
                               output_error=float(np.mean(errs)))
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "..", "artifacts"))
    args = ap.parse_args()
    cfg, _ = MODELS["tiny-lm-a"]
    params = train.get_or_train("tiny-lm-a", verbose=False)
    acts = calibration_activations(cfg, params)
    report = dict(
        model="tiny-lm-a",
        scoring=sweep_scoring(cfg, params, acts),
        robust_percentile=sweep_percentile(cfg, params, acts),
        outstanding_alpha=sweep_alpha(cfg, params, acts),
    )
    os.makedirs(os.path.join(args.out, "stats"), exist_ok=True)
    path = os.path.join(args.out, "stats", "ablation.json")
    with open(path, "w") as f:
        json.dump(report, f, indent=1)
    print(f"wrote {path}")
    for k, v in report["scoring"].items():
        print(k, v)


if __name__ == "__main__":
    main()
