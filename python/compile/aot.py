"""AOT lowering: JAX/Pallas graphs -> HLO text artifacts + weights files.

This is the single build-time entrypoint (`make artifacts`). It:

  1. trains (or loads cached) tiny models;
  2. runs the offline Amber Pruner pipeline: Robust-Norm scales (Eq. 3-5),
     sensitivity sweep (Eq. 8) -> skip sets, SmoothQuant/Outstanding-sparse
     folding (Eq. 9, inverted, alpha=0.10) and W8A8 PTQ;
  3. lowers every (model x variant x ratio x shape) graph to HLO **text**
     (jax >= 0.5 emits protos with 64-bit ids that xla_extension 0.5.1
     rejects; the text parser reassigns ids — see aot recipe);
  4. emits weights (.atw), aux-setting files, eval datasets, distribution
     stats (Fig 2/3/4, Appendix C) and manifest.json for the rust runtime.

Everything is cached: artifacts whose config hash matches are skipped.

Usage:  cd python && python -m compile.aot [--out ../artifacts] [--quick]
"""

import argparse
import json
import os
import time

import numpy as np
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import corpus, evalgen, params_io
from . import model as model_mod
from . import model_moe as moe_mod
from .amber import quant as quant_mod
from .amber import scoring, sensitivity, smoothquant
from .amber import weight_sparsity
from .configs import MODELS, RATIOS, SHAPES, SKIP_COUNTS, DENSE_MODULES

SETTINGS = ("naive", "ls", "all")  # naive top-k / +layer-skip / +robust


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def spec_of(tree):
    return jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(np.shape(a), np.asarray(a).dtype),
        tree)


# ---------------------------------------------------------------------------
# graph builders (bundle, *runtime_inputs) -> outputs tuple
# ---------------------------------------------------------------------------

def build_prefill_fn(cfg, variant, nm, is_moe, static_quantized=None):
    def fn(bundle, tokens):
        params = bundle["params"]
        aux = bundle.get("aux")
        qparams = None
        if variant in ("sq", "sq_nm"):
            qparams = dict(wq=bundle["qwq"], w_scale=bundle["qws"],
                           x_scale=bundle["qxs"],
                           quantized=static_quantized)
        if is_moe:
            logits, ks, vs = moe_mod.forward(
                cfg, params, tokens, variant=variant, nm=nm, aux=aux,
                use_pallas=True, return_kv=True)
        else:
            logits, ks, vs = model_mod.forward(
                cfg, params, tokens, variant=variant, nm=nm, aux=aux,
                qparams=qparams, use_pallas=True, return_kv=True)
        return (logits, ks, vs)
    return fn


def build_decode_fn(cfg, variant, is_moe, static_quantized=None):
    def fn(bundle, token, pos, k_cache, v_cache, kv_len):
        params = bundle["params"]
        if is_moe:
            return moe_mod.decode_step(cfg, params, token, pos, k_cache,
                                       v_cache, kv_len)
        qparams = None
        if variant == "sq":
            qparams = dict(wq=bundle["qwq"], w_scale=bundle["qws"],
                           x_scale=bundle["qxs"],
                           quantized=static_quantized)
        return model_mod.decode_step(cfg, params, token, pos, k_cache,
                                     v_cache, kv_len, variant=variant,
                                     qparams=qparams)
    return fn


# ---------------------------------------------------------------------------
# offline Amber pipeline per model
# ---------------------------------------------------------------------------

def calibration_batches(n=4, batch=8, seq=48, seed=4242):
    rng = np.random.Generator(np.random.PCG64(seed))
    return [jnp.asarray(corpus.pack_batch(
        rng, corpus.WORLD,
        ("grammar_a", "facts_a", "arith", "boolean", "kv_recall"),
        batch, seq)) for _ in range(n)]


def build_settings(cfg, params, nm, is_moe, n_skip, calib_tokens):
    """Aux tensors for each Table-1 setting + the sensitivity report."""
    errs = sensitivity.sensitivity_sweep(cfg, params, calib_tokens, nm,
                                         is_moe=is_moe)
    skip_layers = sensitivity.select_skip_layers(errs, n_skip)
    keep_policy = sensitivity.build_keep_dense(cfg, skip_layers)
    keep_naive = sensitivity.build_keep_dense(cfg, [], no_skip=True)
    base_aux = moe_mod.moe_aux(cfg) if is_moe else model_mod.default_aux(cfg)

    def with_keep(aux, keep):
        a = dict(aux)
        a["keep_dense"] = keep
        return a

    settings = {
        "naive": with_keep(base_aux, keep_naive),
        "ls": with_keep(base_aux, keep_policy),
    }
    if not is_moe:  # Robust-Norm Scoring is N/A for MoE (paper)
        robust = dict(base_aux)
        robust.update(scoring.build_aux_scales(cfg, params, "robust"))
        settings["all"] = with_keep(robust, keep_policy)
    return settings, errs, skip_layers


# ---------------------------------------------------------------------------
# emission helpers
# ---------------------------------------------------------------------------

class Emitter:
    def __init__(self, outdir, quick=False):
        self.outdir = outdir
        self.quick = quick
        self.manifest = {"artifacts": {}, "models": {}, "settings": {}}
        # merge with an existing manifest so `--models X` incremental runs
        # don't drop the other models' entries
        prev = os.path.join(outdir, "manifest.json")
        if os.path.exists(prev):
            try:
                with open(prev) as f:
                    old = json.load(f)
                for k in ("artifacts", "models", "settings"):
                    self.manifest[k].update(old.get(k, {}))
            except (json.JSONDecodeError, OSError):
                pass
        os.makedirs(outdir, exist_ok=True)
        os.makedirs(os.path.join(outdir, "hlo"), exist_ok=True)
        os.makedirs(os.path.join(outdir, "weights"), exist_ok=True)
        os.makedirs(os.path.join(outdir, "eval"), exist_ok=True)
        os.makedirs(os.path.join(outdir, "stats"), exist_ok=True)

    def lower_artifact(self, name, fn, bundle, runtime_specs, outputs_doc,
                       static_doc):
        """Lower fn(bundle, *runtime) and write hlo + manifest entry."""
        t0 = time.time()
        hlo_path = os.path.join(self.outdir, "hlo", f"{name}.hlo.txt")
        # keep_unused: the weights file ships every bundle tensor, so the
        # executable must keep the full parameter list even when a skip
        # policy leaves some (e.g. down_proj quant tensors) unused.
        lowered = jax.jit(fn, keep_unused=True).lower(
            spec_of(bundle), *runtime_specs)
        text = to_hlo_text(lowered)
        with open(hlo_path, "w") as f:
            f.write(text)
        flat = params_io.flatten_for_artifact(bundle)
        self.manifest["artifacts"][name] = dict(
            hlo=f"hlo/{name}.hlo.txt",
            params=[n for n, _ in flat],
            runtime_inputs=[dict(shape=list(s.shape), dtype=str(s.dtype))
                            for s in runtime_specs],
            outputs=outputs_doc,
            static=static_doc,
        )
        print(f"  lowered {name} ({len(text)/1e6:.1f} MB, "
              f"{time.time()-t0:.1f}s)", flush=True)

    def write_bundle(self, fname, bundle):
        flat = params_io.flatten_for_artifact(bundle)
        params_io.write_weights(
            os.path.join(self.outdir, "weights", fname), flat)
        return [n for n, _ in flat]


def emit_model(em: Emitter, name: str):
    from . import train as train_mod

    cfg, tc = MODELS[name]
    is_moe = cfg.is_moe
    print(f"[{name}] pipeline start", flush=True)
    params = train_mod.get_or_train(name)
    calib = calibration_batches()
    calib_tokens = calib[0]

    S, B = SHAPES.prefill_seq, SHAPES.prefill_batch
    LS, LB = SHAPES.long_seq, SHAPES.long_batch
    C, DB = SHAPES.decode_cache, SHAPES.decode_batch
    ratios = RATIOS if not em.quick else [RATIOS[0]]

    # ---- sensitivity + per-setting aux (use the middle ratio 4:8 for the
    # sweep, as sensitivity ordering is ratio-stable) ----
    settings, errs, skip_layers = build_settings(
        cfg, params, (4, 8), is_moe, SKIP_COUNTS[name], calib_tokens)
    cov = sensitivity.coverage(cfg, settings["ls"]["keep_dense"], is_moe)
    sensitivity.export_report(
        os.path.join(em.outdir, "stats", f"sensitivity_{name}.json"),
        name, (4, 8), errs, skip_layers, cov)
    print(f"  skip_layers={skip_layers} coverage={cov:.3f}", flush=True)

    # ---- weights + aux files ----
    em.write_bundle(f"{name}.atw", dict(params=params))
    for sname, aux in settings.items():
        em.write_bundle(f"{name}.aux_{sname}.atw", dict(aux=aux))
    # dense aux (keep everything) so the nm executable can also serve dense
    dense_aux = dict(settings["ls"])
    dense_aux["keep_dense"] = jnp.ones_like(settings["ls"]["keep_dense"])
    em.write_bundle(f"{name}.aux_dense.atw", dict(aux=dense_aux))
    em.manifest["settings"][name] = dict(
        settings=list(settings) + ["dense"],
        skip_layers=skip_layers, coverage=cov,
        sensitivity=f"stats/sensitivity_{name}.json")

    # ---- fp artifacts ----
    tok_spec = jax.ShapeDtypeStruct((B, S), np.int32)
    ltok_spec = jax.ShapeDtypeStruct((LB, LS), np.int32)
    aux0 = settings["ls"]
    kv_doc = ["logits", "k_cache", "v_cache"]

    em.lower_artifact(
        f"{name}.prefill{S}.dense", build_prefill_fn(cfg, "dense", None,
                                                     is_moe),
        dict(params=params), [tok_spec], kv_doc,
        dict(kind="prefill", variant="dense", batch=B, seq=S))
    em.lower_artifact(
        f"{name}.prefill{LS}.dense", build_prefill_fn(cfg, "dense", None,
                                                      is_moe),
        dict(params=params), [ltok_spec], kv_doc,
        dict(kind="prefill", variant="dense", batch=LB, seq=LS))
    for (n, m) in ratios:
        em.lower_artifact(
            f"{name}.prefill{S}.nm{n}_{m}",
            build_prefill_fn(cfg, "nm", (n, m), is_moe),
            dict(params=params, aux=aux0), [tok_spec], kv_doc,
            dict(kind="prefill", variant="nm", n=n, m=m, batch=B, seq=S))
        em.lower_artifact(
            f"{name}.prefill{LS}.nm{n}_{m}",
            build_prefill_fn(cfg, "nm", (n, m), is_moe),
            dict(params=params, aux=aux0), [ltok_spec], kv_doc,
            dict(kind="prefill", variant="nm", n=n, m=m, batch=LB, seq=LS))

    dec_specs = [
        jax.ShapeDtypeStruct((DB,), np.int32),
        jax.ShapeDtypeStruct((DB,), np.int32),
        jax.ShapeDtypeStruct((cfg.n_layers, DB, C, cfg.n_kv_heads,
                              cfg.head_dim), np.float32),
        jax.ShapeDtypeStruct((cfg.n_layers, DB, C, cfg.n_kv_heads,
                              cfg.head_dim), np.float32),
        jax.ShapeDtypeStruct((DB,), np.int32),
    ]
    em.lower_artifact(
        f"{name}.decode.dense", build_decode_fn(cfg, "dense", is_moe),
        dict(params=params), dec_specs, ["logits", "k_cache", "v_cache"],
        dict(kind="decode", variant="dense", batch=DB, cache=C))

    # ---- Outstanding-sparse (W8A8) pipeline: dense models only ----
    if not is_moe and not em.quick:
        stats = quant_mod.collect_activation_stats(cfg, params, calib,
                                                   None)
        act_stats = {m: [stats[m][li]["absmax"]
                         for li in range(cfg.n_layers)]
                     for m in DENSE_MODULES}
        sq_params, applied = smoothquant.smooth_model(
            cfg, params, act_stats, alpha=0.10, inverted=True)
        # recalibrate on the smoothed model, then quantize
        stats_sq = quant_mod.collect_activation_stats(cfg, sq_params,
                                                      calib, None)
        qp = quant_mod.build_qparams(cfg, sq_params, stats_sq, name)
        static_q = {m: qp["quantized"][m] for m in DENSE_MODULES}
        q_bundle_tensors = dict(
            qwq={m: qp["wq"][m] for m in DENSE_MODULES},
            qws={m: qp["w_scale"][m] for m in DENSE_MODULES},
            qxs={m: jnp.asarray(qp["x_scale"][m]) for m in DENSE_MODULES},
        )
        # robust scales recomputed on the smoothed weights
        sq_settings, sq_errs, sq_skip = build_settings(
            cfg, sq_params, (4, 8), is_moe, SKIP_COUNTS[name], calib_tokens)
        em.write_bundle(f"{name}.sq.atw",
                        dict(params=sq_params, **q_bundle_tensors))
        for sname, aux in sq_settings.items():
            em.write_bundle(f"{name}.sq.aux_{sname}.atw", dict(aux=aux))

        # distribution stats for Fig 3/4 (pre/post adjustment)
        export_sq_stats(em, name, cfg, params, sq_params, calib_tokens)

        sq_bundle = dict(params=sq_params, **q_bundle_tensors)
        em.lower_artifact(
            f"{name}.prefill{S}.sq", build_prefill_fn(
                cfg, "sq", None, is_moe, static_q),
            sq_bundle, [tok_spec], kv_doc,
            dict(kind="prefill", variant="sq", batch=B, seq=S))
        em.lower_artifact(
            f"{name}.prefill{LS}.sq", build_prefill_fn(
                cfg, "sq", None, is_moe, static_q),
            sq_bundle, [ltok_spec], kv_doc,
            dict(kind="prefill", variant="sq", batch=LB, seq=LS))
        sq_nm_bundle = dict(params=sq_params, aux=sq_settings["ls"],
                            **q_bundle_tensors)
        for (n, m) in ratios:
            em.lower_artifact(
                f"{name}.prefill{S}.sq_nm{n}_{m}",
                build_prefill_fn(cfg, "sq_nm", (n, m), is_moe, static_q),
                sq_nm_bundle, [tok_spec], kv_doc,
                dict(kind="prefill", variant="sq_nm", n=n, m=m,
                     batch=B, seq=S))
            em.lower_artifact(
                f"{name}.prefill{LS}.sq_nm{n}_{m}",
                build_prefill_fn(cfg, "sq_nm", (n, m), is_moe, static_q),
                sq_nm_bundle, [ltok_spec], kv_doc,
                dict(kind="prefill", variant="sq_nm", n=n, m=m,
                     batch=LB, seq=LS))
        em.lower_artifact(
            f"{name}.decode.sq", build_decode_fn(cfg, "sq", is_moe,
                                                 static_q),
            sq_bundle, dec_specs, ["logits", "k_cache", "v_cache"],
            dict(kind="decode", variant="sq", batch=DB, cache=C))

    # ---- weight-sparsity baseline weights (Appendix A) ----
    if name == "tiny-lm-a" and not em.quick:
        wcal = weight_sparsity.collect_weight_calibration(
            cfg, params, calib,
            lambda p, t: model_mod.loss_fn(cfg, p, t))
        for method in ("magnitude", "wanda", "sparsegpt", "prunerzero"):
            for (n, m) in ((2, 4), (4, 8)):
                wp = weight_sparsity.prune_model_weights(
                    cfg, params, wcal, method, n, m)
                em.write_bundle(f"{name}.wsp_{method}_{n}_{m}.atw",
                                dict(params=wp))
        em.manifest["models"].setdefault(name, {})["weight_sparsity"] = [
            f"{name}.wsp_{method}_{n}_{m}.atw"
            for method in ("magnitude", "wanda", "sparsegpt", "prunerzero")
            for (n, m) in ((2, 4), (4, 8))]

    # activation/weight distribution stats for Fig 2 + Appendix C
    export_distribution_stats(em, name, cfg, params, calib_tokens, is_moe)

    md = em.manifest["models"].setdefault(name, {})
    md.update(dict(
        config={k: getattr(cfg, k) for k in (
            "vocab_size", "d_model", "n_layers", "n_q_heads", "n_kv_heads",
            "head_dim", "d_ff", "n_experts", "top_k_experts",
            "d_ff_expert")},
        weights=f"weights/{name}.atw",
        is_moe=is_moe,
    ))


def export_distribution_stats(em, name, cfg, params, tokens, is_moe):
    """Fig 2 (activation vs weight near-zero mass) + Appendix C heatstats."""
    from .model import rmsnorm

    layer = cfg.n_layers // 2
    x = params["embed"][tokens]
    # run to the chosen layer with the reference path
    mod = moe_mod if is_moe else model_mod
    # capture gate_proj input at `layer` by a manual partial forward
    from .amber.quant import collect_activation_stats
    stats = {}
    h = None
    xs = {}
    bx = x
    pos = jnp.broadcast_to(jnp.arange(tokens.shape[1])[None, :],
                           tokens.shape)
    from .model import Projector, attention_block
    for li in range(layer + 1):
        proj = Projector(cfg, "dense", False, layer=li)
        hh = rmsnorm(bx, params["ln_attn"][li], cfg.rmsnorm_eps)
        if is_moe:
            a, _ = attention_block(cfg, proj, params, li, hh, pos)
            bx = bx + a
            hh2 = rmsnorm(bx, params["ln_mlp"][li], cfg.rmsnorm_eps)
            if li == layer:
                xs["gate_proj"] = hh2
                xs["q_proj"] = hh
            bx = bx + moe_mod.moe_block(cfg, params, li, hh2, None,
                                        moe_mod.moe_aux(cfg), False)
        else:
            a, _ = attention_block(cfg, proj, params, li, hh, pos)
            bx = bx + a
            hh2 = rmsnorm(bx, params["ln_mlp"][li], cfg.rmsnorm_eps)
            if li == layer:
                xs["gate_proj"] = hh2
                xs["q_proj"] = hh
            g = hh2 @ params["wg"][li]
            u = hh2 @ params["wu"][li]
            hmid = jax.nn.silu(g) * u
            if li == layer:
                xs["down_proj"] = hmid
                o_in_dummy = None
            bx = bx + hmid @ params["wd"][li]

    def tensor_stats(t):
        t = np.asarray(t).reshape(-1)
        amax = float(np.abs(t).max()) + 1e-12
        hist, edges = np.histogram(np.abs(t) / amax, bins=20,
                                   range=(0, 1))
        return dict(
            near_zero_frac=float(np.mean(np.abs(t) < 0.05 * amax)),
            absmax=amax,
            hist=hist.tolist(),
        )

    w_gate = (params["we_g"][layer, 0] if is_moe else params["wg"][layer])
    out = dict(
        model=name, layer=layer,
        activation_gate=tensor_stats(xs["gate_proj"]),
        weight_gate=tensor_stats(w_gate),
        activation_q=tensor_stats(xs["q_proj"]),
        modules={},
    )
    if not is_moe:
        out["activation_down"] = tensor_stats(xs["down_proj"])
    with open(os.path.join(em.outdir, "stats", f"dist_{name}.json"),
              "w") as f:
        json.dump(out, f, indent=1)


def export_sq_stats(em, name, cfg, params, sq_params, tokens):
    """Fig 3/4: activation/weight ranges pre/post Outstanding-sparse."""
    from .model import rmsnorm
    layer = cfg.n_layers // 2

    def gate_input(p):
        x = p["embed"][tokens]
        pos = jnp.broadcast_to(jnp.arange(tokens.shape[1])[None, :],
                               tokens.shape)
        from .model import Projector, attention_block
        for li in range(layer + 1):
            proj = Projector(cfg, "dense", False, layer=li)
            h = rmsnorm(x, p["ln_attn"][li], cfg.rmsnorm_eps)
            a, _ = attention_block(cfg, proj, p, li, h, pos)
            x = x + a
            h2 = rmsnorm(x, p["ln_mlp"][li], cfg.rmsnorm_eps)
            if li == layer:
                return h2
            g = h2 @ p["wg"][li]
            u = h2 @ p["wu"][li]
            x = x + (jax.nn.silu(g) * u) @ p["wd"][li]

    def chan_absmax(t):
        return np.asarray(jnp.max(jnp.abs(t.reshape(-1, t.shape[-1])),
                                  axis=0)).tolist()

    pre_x = gate_input(params)
    post_x = gate_input(sq_params)
    out = dict(
        model=name, layer=layer, alpha=0.10,
        pre=dict(act_absmax=chan_absmax(pre_x),
                 w_absmax=np.abs(np.asarray(
                     params["wg"][layer])).max(axis=1).tolist()),
        post=dict(act_absmax=chan_absmax(post_x),
                  w_absmax=np.abs(np.asarray(
                      sq_params["wg"][layer])).max(axis=1).tolist()),
    )
    with open(os.path.join(em.outdir, "stats", f"sq_dist_{name}.json"),
              "w") as f:
        json.dump(out, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--quick", action="store_true",
                    help="single ratio, fp only, tiny-lm-a only (CI smoke)")
    ap.add_argument("--models", nargs="*", default=None)
    args = ap.parse_args()

    em = Emitter(os.path.abspath(args.out), quick=args.quick)
    names = args.models or (["tiny-lm-a"] if args.quick else list(MODELS))
    for name in names:
        emit_model(em, name)
    evalgen.emit_all(os.path.join(em.outdir, "eval"),
                     n_samples=32 if args.quick else evalgen.N_SAMPLES)
    em.manifest["shapes"] = SHAPES.__dict__
    params_io.write_manifest(os.path.join(em.outdir, "manifest.json"),
                             em.manifest)
    print("manifest written", flush=True)


if __name__ == "__main__":
    main()
