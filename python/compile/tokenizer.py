"""Structured synthetic vocabulary (384 tokens).

The tiny models are trained on a synthetic token language rich enough to
support analogues of the paper's downstream tasks (DESIGN.md §2). The
vocabulary is partitioned into functional regions:

    0..9     control: PAD BOS EOS SEP QRY ANS TRUE FALSE YES NO
    10..19   digits 0-9
    20..31   operators / markers
    32..47   relation tokens R0..R15 (facts)
    48..79   entity tokens  E0..E31 (facts)
    80..207  word subspace A (English-analogue, 128 words)
    208..335 word subspace B (Chinese-analogue, 128 words)
    336..383 key tokens K0..K47 (long-context KV recall)
"""

VOCAB_SIZE = 384

PAD, BOS, EOS, SEP, QRY, ANS, TRUE, FALSE, YES, NO = range(10)

DIGIT0 = 10          # digits are DIGIT0 + d


def digit(d: int) -> int:
    assert 0 <= d <= 9
    return DIGIT0 + d


PLUS, MINUS, TIMES, EQ, LT, GT, IS, COMMA, SEL1, SEL2, SORT, THEN = range(20, 32)

REL0 = 32
N_RELS = 16


def rel(r: int) -> int:
    assert 0 <= r < N_RELS
    return REL0 + r


ENT0 = 48
N_ENTS = 32


def ent(e: int) -> int:
    assert 0 <= e < N_ENTS
    return ENT0 + e


WORD_A0 = 80
N_WORDS_A = 128


def word_a(w: int) -> int:
    assert 0 <= w < N_WORDS_A
    return WORD_A0 + w


WORD_B0 = 208
N_WORDS_B = 128


def word_b(w: int) -> int:
    assert 0 <= w < N_WORDS_B
    return WORD_B0 + w


KEY0 = 336
N_KEYS = 48


def key(k: int) -> int:
    assert 0 <= k < N_KEYS
    return KEY0 + k


_NAMES = {
    PAD: "<pad>", BOS: "<bos>", EOS: "<eos>", SEP: "<sep>", QRY: "<qry>",
    ANS: "<ans>", TRUE: "<true>", FALSE: "<false>", YES: "<yes>", NO: "<no>",
    PLUS: "+", MINUS: "-", TIMES: "*", EQ: "=", LT: "<", GT: ">",
    IS: "is", COMMA: ",", SEL1: "<sel1>", SEL2: "<sel2>", SORT: "<sort>",
    THEN: "<then>",
}


def token_name(t: int) -> str:
    """Human-readable token name (debugging / example transcripts)."""
    if t in _NAMES:
        return _NAMES[t]
    if DIGIT0 <= t < DIGIT0 + 10:
        return str(t - DIGIT0)
    if REL0 <= t < REL0 + N_RELS:
        return f"r{t - REL0}"
    if ENT0 <= t < ENT0 + N_ENTS:
        return f"E{t - ENT0}"
    if WORD_A0 <= t < WORD_A0 + N_WORDS_A:
        return f"a{t - WORD_A0}"
    if WORD_B0 <= t < WORD_B0 + N_WORDS_B:
        return f"b{t - WORD_B0}"
    if KEY0 <= t < KEY0 + N_KEYS:
        return f"k{t - KEY0}"
    return f"<{t}>"


def detok(tokens) -> str:
    return " ".join(token_name(int(t)) for t in tokens)
