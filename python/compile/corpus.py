"""Synthetic training corpus + task world.

A deterministic "world" (fact tables, grammar transition matrices) is
shared between the training corpus and the downstream-task generators in
``evalgen.py`` so that the tasks actually measure what the model learned.

Skills (each maps onto one of the paper's benchmark analogues):

    grammar_a / grammar_b   sparse first-order Markov grammar over word
                            subspaces A / B (general language statistics)
    facts_a                 one-hop relational facts  E_i r E_j  (MMLU)
    facts_b                 one-hop facts over relations R8..R15 (CEVAL)
    facts_hop2              two-hop composition  E_i r1 <then> r2 E_k (OBQA)
    arith                   single-step digit arithmetic mod 10
    chain                   chained 3-operand arithmetic with worked
                            intermediate step (GSM8K analogue, CoT style)
    copy                    delimited copy of a word span
    induction               periodic pattern continuation (ARC-E/ARC-C)
    boolean                 digit comparison -> TRUE/FALSE (BoolQ)
    entail                  premise/hypothesis consistency -> YES/NO (RTE)
    select                  positional selection <sel1>/<sel2> (Winogrande)
    sort                    3-digit sorting (PIQA physical-ordering analogue)
    kv_recall               long-context key/value recall (LongBench)
"""

import numpy as np

from . import tokenizer as tok

WORLD_SEED = 7_777_777


class World:
    """Deterministic relational / grammatical world shared by train + eval."""

    def __init__(self, seed: int = WORLD_SEED):
        rng = np.random.Generator(np.random.PCG64(seed))
        # one-hop fact tables: for each relation, a random permutation-ish
        # mapping entity -> entity (random with replacement, fixed).
        self.fact = rng.integers(0, tok.N_ENTS, size=(tok.N_RELS, tok.N_ENTS))
        # grammar transition: each word allows 4 successors
        self.gram_a = rng.integers(0, tok.N_WORDS_A, size=(tok.N_WORDS_A, 4))
        self.gram_b = rng.integers(0, tok.N_WORDS_B, size=(tok.N_WORDS_B, 4))

    def hop2(self, e: int, r1: int, r2: int) -> int:
        return int(self.fact[r2, self.fact[r1, e]])


WORLD = World()


# ---------------------------------------------------------------------------
# Skill sentence generators. Each returns a list[int] token sentence
# (no BOS/EOS; the packer adds separators).
# ---------------------------------------------------------------------------

def gen_grammar_a(rng, world):
    n = int(rng.integers(6, 14))
    w = int(rng.integers(0, tok.N_WORDS_A))
    out = [tok.word_a(w)]
    for _ in range(n - 1):
        w = int(world.gram_a[w, rng.integers(0, 4)])
        out.append(tok.word_a(w))
    return out


def gen_grammar_b(rng, world):
    n = int(rng.integers(6, 14))
    w = int(rng.integers(0, tok.N_WORDS_B))
    out = [tok.word_b(w)]
    for _ in range(n - 1):
        w = int(world.gram_b[w, rng.integers(0, 4)])
        out.append(tok.word_b(w))
    return out


def _fact_sentence(rng, world, rel_lo, rel_hi):
    r = int(rng.integers(rel_lo, rel_hi))
    e = int(rng.integers(0, tok.N_ENTS))
    t = int(world.fact[r, e])
    if rng.random() < 0.5:
        # declarative
        return [tok.ent(e), tok.rel(r), tok.ent(t)]
    # query form (same one the eval tasks use)
    return [tok.QRY, tok.ent(e), tok.rel(r), tok.ANS, tok.ent(t)]


def gen_facts_a(rng, world):
    return _fact_sentence(rng, world, 0, 8)


def gen_facts_b(rng, world):
    return _fact_sentence(rng, world, 8, 16)


def gen_facts_hop2(rng, world):
    r1 = int(rng.integers(0, 8))
    r2 = int(rng.integers(0, 8))
    e = int(rng.integers(0, tok.N_ENTS))
    t = world.hop2(e, r1, r2)
    if rng.random() < 0.4:
        return [tok.ent(e), tok.rel(r1), tok.THEN, tok.rel(r2), tok.ent(t)]
    return [tok.QRY, tok.ent(e), tok.rel(r1), tok.THEN, tok.rel(r2),
            tok.ANS, tok.ent(t)]


_OPS = [(tok.PLUS, lambda a, b: (a + b) % 10),
        (tok.MINUS, lambda a, b: (a - b) % 10),
        (tok.TIMES, lambda a, b: (a * b) % 10)]


def gen_arith(rng, world):
    a, b = int(rng.integers(0, 10)), int(rng.integers(0, 10))
    op_t, op_f = _OPS[int(rng.integers(0, 3))]
    return [tok.digit(a), op_t, tok.digit(b), tok.EQ, tok.digit(op_f(a, b))]


def chain_example(rng):
    """QRY a op1 b op2 c ANS t f — evaluated left-to-right mod 10,
    t = a op1 b (worked intermediate), f = t op2 c (final answer)."""
    a, b, c = (int(rng.integers(0, 10)) for _ in range(3))
    i1, i2 = int(rng.integers(0, 3)), int(rng.integers(0, 3))
    (t1, f1), (t2, f2) = _OPS[i1], _OPS[i2]
    t = f1(a, b)
    f = f2(t, c)
    toks = [tok.QRY, tok.digit(a), t1, tok.digit(b), t2, tok.digit(c),
            tok.ANS, tok.digit(t), tok.digit(f)]
    return toks, t, f


def gen_chain(rng, world):
    toks, _, _ = chain_example(rng)
    return toks


def gen_copy(rng, world):
    n = int(rng.integers(3, 7))
    span = [tok.word_a(int(rng.integers(0, tok.N_WORDS_A))) for _ in range(n)]
    return [tok.SEP] + span + [tok.SEP] + span


def gen_induction(rng, world):
    period = int(rng.integers(2, 5))
    motif = [tok.word_a(int(rng.integers(0, tok.N_WORDS_A)))
             for _ in range(period)]
    reps = int(rng.integers(3, 5))
    return motif * reps


def gen_boolean(rng, world):
    a, b = int(rng.integers(0, 10)), int(rng.integers(0, 10))
    use_lt = rng.random() < 0.5
    cmp_t = tok.LT if use_lt else tok.GT
    truth = (a < b) if use_lt else (a > b)
    return [tok.digit(a), cmp_t, tok.digit(b), tok.QRY,
            tok.TRUE if truth else tok.FALSE]


def gen_entail(rng, world):
    a, b = int(rng.integers(0, 10)), int(rng.integers(0, 10))
    while b == a:
        b = int(rng.integers(0, 10))
    lo, hi = min(a, b), max(a, b)
    # premise: lo < hi  (or hi > lo)
    if rng.random() < 0.5:
        prem = [tok.digit(lo), tok.LT, tok.digit(hi)]
    else:
        prem = [tok.digit(hi), tok.GT, tok.digit(lo)]
    # hypothesis: either consistent or contradictory restatement
    consistent = rng.random() < 0.5
    if consistent:
        hyp = [tok.digit(hi), tok.GT, tok.digit(lo)] if rng.random() < 0.5 \
            else [tok.digit(lo), tok.LT, tok.digit(hi)]
    else:
        hyp = [tok.digit(lo), tok.GT, tok.digit(hi)] if rng.random() < 0.5 \
            else [tok.digit(hi), tok.LT, tok.digit(lo)]
    return prem + [tok.SEP] + hyp + [tok.QRY, tok.YES if consistent else tok.NO]


def gen_select(rng, world):
    ea, eb = int(rng.integers(0, tok.N_ENTS)), int(rng.integers(0, tok.N_ENTS))
    first = rng.random() < 0.5
    sel = tok.SEL1 if first else tok.SEL2
    answer = ea if first else eb
    return [tok.ent(ea), tok.COMMA, tok.ent(eb), sel, tok.ANS, tok.ent(answer)]


def gen_sort(rng, world):
    d = sorted(int(rng.integers(0, 10)) for _ in range(3))
    shuf = list(d)
    rng.shuffle(shuf)
    return ([tok.digit(x) for x in shuf] + [tok.SORT]
            + [tok.digit(x) for x in d])


def gen_kv_recall(rng, world, n_pairs=None):
    n = int(rng.integers(4, 10)) if n_pairs is None else n_pairs
    keys = rng.choice(tok.N_KEYS, size=n, replace=False)
    vals = rng.integers(0, 10, size=n)
    out = []
    for k, v in zip(keys, vals):
        out += [tok.key(int(k)), tok.digit(int(v))]
    q = int(rng.integers(0, n))
    out += [tok.QRY, tok.key(int(keys[q])), tok.ANS, tok.digit(int(vals[q]))]
    return out


def gen_kv_recall_long(rng, world):
    """Long-context variant (the LongBench-analogue's distribution)."""
    return gen_kv_recall(rng, world, n_pairs=int(rng.integers(20, 45)))


def gen_induction_long(rng, world):
    """Motif repetition spanning a long window."""
    period = int(rng.integers(3, 6))
    motif = [tok.word_a(int(rng.integers(0, tok.N_WORDS_A)))
             for _ in range(period)]
    reps = int(rng.integers(20, 36))
    return motif * reps


SKILLS = {
    "grammar_a": gen_grammar_a,
    "grammar_b": gen_grammar_b,
    "facts_a": gen_facts_a,
    "facts_b": gen_facts_b,
    "facts_hop2": gen_facts_hop2,
    "arith": gen_arith,
    "chain": gen_chain,
    "copy": gen_copy,
    "induction": gen_induction,
    "boolean": gen_boolean,
    "entail": gen_entail,
    "select": gen_select,
    "sort": gen_sort,
    "kv_recall": gen_kv_recall,
    "kv_recall_long": gen_kv_recall_long,
    "induction_long": gen_induction_long,
}

# mixture for the long-context fine-tuning phase (positions the prefill256
# artifacts serve must be in-distribution)
LONG_SKILLS = ("kv_recall_long", "induction_long", "copy", "grammar_a",
               "chain")

# relative sampling weight per skill in the training mixture
SKILL_WEIGHTS = {
    "grammar_a": 1.0, "grammar_b": 1.0, "facts_a": 2.5, "facts_b": 2.5,
    "facts_hop2": 2.0, "arith": 2.0, "chain": 3.0, "copy": 1.0,
    "induction": 1.0, "boolean": 1.5, "entail": 1.5, "select": 1.5,
    "sort": 1.5, "kv_recall": 2.0, "kv_recall_long": 2.0,
    "induction_long": 1.0,
}


def pack_batch(rng, world, skills, batch_size, seq_len):
    """Pack skill sentences into (batch, seq_len) int32 next-token batches.

    Sentences are separated by EOS; each row starts with BOS. Loss is taken
    on every position (standard packed LM training).
    """
    names = list(skills)
    w = np.array([SKILL_WEIGHTS[n] for n in names], dtype=np.float64)
    w /= w.sum()
    rows = np.zeros((batch_size, seq_len), dtype=np.int32)
    for i in range(batch_size):
        buf = [tok.BOS]
        while len(buf) < seq_len:
            name = names[int(rng.choice(len(names), p=w))]
            buf += SKILLS[name](rng, world) + [tok.EOS]
        rows[i] = np.array(buf[:seq_len], dtype=np.int32)
    return rows


def training_stream(seed, skills, batch_size, seq_len):
    """Infinite deterministic generator of packed batches."""
    rng = np.random.Generator(np.random.PCG64(seed))
    world = WORLD
    while True:
        yield pack_batch(rng, world, skills, batch_size, seq_len)
