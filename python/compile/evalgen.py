"""Downstream-task dataset generation (the paper's benchmark analogues).

Every paper benchmark maps to a synthetic task over the trained models'
token world (DESIGN.md §2). Tasks are emitted as ``.aev`` binaries that the
rust eval harness replays through the AOT executables — the task *logic*
(choice construction, scoring spans) all lives here; rust only runs rows
and sums log-probabilities.

Multiple-choice scoring follows lm-eval-harness: each (context, choice)
pair becomes one padded row; the score of a choice is the sum of token
log-probs of the choice span given the context; the predicted choice is the
argmax; accuracy is mean(pred == gold).

Mapping (paper benchmark -> generator):
    ARC-Easy       induction_easy     ARC-Challenge  induction_hard
    BoolQ          boolean            MMLU           facts one-hop (A rels)
    CEVAL          facts one-hop (B rels, tiny-lm-b only)
    OBQA           facts two-hop      PIQA           sort (2-choice)
    RTE            entailment         Winogrande     positional select
    GSM8K (5-shot) chained arithmetic generation w/ worked step
    LongBench      long-context KV recall + long induction (avg of 2)
"""

import os

import numpy as np

from . import tokenizer as tok
from .corpus import WORLD, chain_example

EVAL_SEED = 987_654_321
N_SAMPLES = 200          # per task (tables); tests use fewer via arg
SEQ = 64
LONG_SEQ = 256


def _rng(task_id):
    return np.random.Generator(np.random.PCG64(EVAL_SEED + task_id))


def _mc_rows(samples):
    """samples: list of (ctx list[int], choices list[list[int]], gold int)
    -> rows for write_eval_mc."""
    rows = []
    for sid, (ctx, choices, gold) in enumerate(samples):
        for cid, ch in enumerate(choices):
            toks = list(ctx) + list(ch)
            rows.append(dict(tokens=toks, sample=sid, choice=cid,
                             score_start=len(ctx), score_len=len(ch),
                             gold=gold))
    return rows


# ---------------------------------------------------------------------------
# multiple-choice generators
# ---------------------------------------------------------------------------

def gen_induction(rng, n, period_lo, period_hi, reps):
    out = []
    for _ in range(n):
        period = int(rng.integers(period_lo, period_hi + 1))
        motif = [tok.word_a(int(rng.integers(0, tok.N_WORDS_A)))
                 for _ in range(period)]
        ctx = [tok.BOS] + motif * reps + motif[:-1]
        gold_tok = motif[-1]
        distractors = []
        while len(distractors) < 3:
            w = tok.word_a(int(rng.integers(0, tok.N_WORDS_A)))
            if w != gold_tok and w not in distractors and w not in motif:
                distractors.append(w)
        choices = [[gold_tok]] + [[d] for d in distractors]
        order = rng.permutation(4)
        gold = int(np.where(order == 0)[0][0])
        out.append((ctx, [choices[i] for i in order], gold))
    return out


def task_arc_easy(rng, n):
    return gen_induction(rng, n, 2, 2, 3)


def task_arc_challenge(rng, n):
    return gen_induction(rng, n, 3, 4, 2)


def task_boolq(rng, n):
    out = []
    for _ in range(n):
        a, b = int(rng.integers(0, 10)), int(rng.integers(0, 10))
        use_lt = rng.random() < 0.5
        cmp_t = tok.LT if use_lt else tok.GT
        truth = (a < b) if use_lt else (a > b)
        ctx = [tok.BOS, tok.digit(a), cmp_t, tok.digit(b), tok.QRY]
        choices = [[tok.TRUE], [tok.FALSE]]
        out.append((ctx, choices, 0 if truth else 1))
    return out


def _facts_mc(rng, n, rel_lo, rel_hi):
    out = []
    for _ in range(n):
        r = int(rng.integers(rel_lo, rel_hi))
        e = int(rng.integers(0, tok.N_ENTS))
        gold_e = int(WORLD.fact[r, e])
        ctx = [tok.BOS, tok.QRY, tok.ent(e), tok.rel(r), tok.ANS]
        ents = {gold_e}
        while len(ents) < 4:
            ents.add(int(rng.integers(0, tok.N_ENTS)))
        ents = list(ents)
        rng.shuffle(ents)
        gold = ents.index(gold_e)
        out.append((ctx, [[tok.ent(x)] for x in ents], gold))
    return out


def task_mmlu(rng, n):
    return _facts_mc(rng, n, 0, 8)


def task_ceval(rng, n):
    return _facts_mc(rng, n, 8, 16)


def task_obqa(rng, n):
    out = []
    for _ in range(n):
        r1, r2 = int(rng.integers(0, 8)), int(rng.integers(0, 8))
        e = int(rng.integers(0, tok.N_ENTS))
        gold_e = WORLD.hop2(e, r1, r2)
        ctx = [tok.BOS, tok.QRY, tok.ent(e), tok.rel(r1), tok.THEN,
               tok.rel(r2), tok.ANS]
        ents = {gold_e}
        while len(ents) < 4:
            ents.add(int(rng.integers(0, tok.N_ENTS)))
        ents = list(ents)
        rng.shuffle(ents)
        gold = ents.index(gold_e)
        out.append((ctx, [[tok.ent(x)] for x in ents], gold))
    return out


def task_piqa(rng, n):
    out = []
    for _ in range(n):
        d = [int(rng.integers(0, 10)) for _ in range(3)]
        while len(set(d)) < 2:  # need a distinguishable wrong ordering
            d[0] = int(rng.integers(0, 10))
        srt = sorted(d)
        shuf = list(srt)
        while shuf == srt:
            rng.shuffle(shuf)
        ctx = [tok.BOS] + [tok.digit(x) for x in d] + [tok.SORT]
        choices = [[tok.digit(x) for x in srt],
                   [tok.digit(x) for x in shuf]]
        if rng.random() < 0.5:
            choices = choices[::-1]
            gold = 1
        else:
            gold = 0
        out.append((ctx, choices, gold))
    return out


def task_rte(rng, n):
    out = []
    for _ in range(n):
        a, b = int(rng.integers(0, 10)), int(rng.integers(0, 10))
        while b == a:
            b = int(rng.integers(0, 10))
        lo, hi = min(a, b), max(a, b)
        prem = ([tok.digit(lo), tok.LT, tok.digit(hi)]
                if rng.random() < 0.5
                else [tok.digit(hi), tok.GT, tok.digit(lo)])
        consistent = rng.random() < 0.5
        if consistent:
            hyp = ([tok.digit(hi), tok.GT, tok.digit(lo)]
                   if rng.random() < 0.5
                   else [tok.digit(lo), tok.LT, tok.digit(hi)])
        else:
            hyp = ([tok.digit(lo), tok.GT, tok.digit(hi)]
                   if rng.random() < 0.5
                   else [tok.digit(hi), tok.LT, tok.digit(lo)])
        ctx = [tok.BOS] + prem + [tok.SEP] + hyp + [tok.QRY]
        out.append((ctx, [[tok.YES], [tok.NO]], 0 if consistent else 1))
    return out


def task_winogrande(rng, n):
    out = []
    for _ in range(n):
        ea = int(rng.integers(0, tok.N_ENTS))
        eb = int(rng.integers(0, tok.N_ENTS))
        while eb == ea:
            eb = int(rng.integers(0, tok.N_ENTS))
        first = rng.random() < 0.5
        sel = tok.SEL1 if first else tok.SEL2
        ctx = [tok.BOS, tok.ent(ea), tok.COMMA, tok.ent(eb), sel, tok.ANS]
        out.append((ctx, [[tok.ent(ea)], [tok.ent(eb)]], 0 if first else 1))
    return out


# ---------------------------------------------------------------------------
# generation tasks
# ---------------------------------------------------------------------------

def task_gsm8k(rng, n, shots=5):
    """5-shot chained arithmetic; gold = (intermediate, final) digits."""
    rows = []
    for sid in range(n):
        prompt = [tok.BOS]
        for _ in range(shots):
            ex, _, _ = chain_example(rng)
            prompt += ex + [tok.EOS]
        q, t, f = chain_example(rng)
        prompt += q[:-2]  # strip the worked answer, keep "... ANS"
        rows.append(dict(tokens=prompt, sample=sid,
                         gold=[tok.digit(t), tok.digit(f)], max_gen=4))
    return rows


def task_longbench_kv(rng, n, n_pairs=40):
    """Needle-style KV recall over a long context (TriviaQA analogue)."""
    rows = []
    for sid in range(n):
        keys = rng.choice(tok.N_KEYS, size=n_pairs, replace=False)
        vals = rng.integers(0, 10, size=n_pairs)
        ctx = [tok.BOS]
        for k, v in zip(keys, vals):
            ctx += [tok.key(int(k)), tok.digit(int(v))]
        q = int(rng.integers(0, n_pairs))
        ctx += [tok.QRY, tok.key(int(keys[q])), tok.ANS]
        rows.append(dict(tokens=ctx, sample=sid,
                         gold=[tok.digit(int(vals[q]))], max_gen=2))
    return rows


def task_longbench_induction(rng, n):
    """Long repeated-motif continuation filling most of the 256 window."""
    rows = []
    for sid in range(n):
        period = int(rng.integers(3, 6))
        motif = [tok.word_a(int(rng.integers(0, tok.N_WORDS_A)))
                 for _ in range(period)]
        reps = (LONG_SEQ - 24) // period
        ctx = [tok.BOS] + motif * reps + motif[:-1]
        rows.append(dict(tokens=ctx, sample=sid, gold=[motif[-1]],
                         max_gen=2))
    return rows


# ---------------------------------------------------------------------------
# emission
# ---------------------------------------------------------------------------

MC_TASKS = {
    # task_name: (generator, n_choices, paper benchmark)
    "arc_challenge": (task_arc_challenge, 4, "AC"),
    "arc_easy": (task_arc_easy, 4, "AE"),
    "boolq": (task_boolq, 2, "BQ"),
    "mmlu": (task_mmlu, 4, "MMLU"),
    "ceval": (task_ceval, 4, "CEVAL"),
    "obqa": (task_obqa, 4, "OBQA"),
    "piqa": (task_piqa, 2, "PIQA"),
    "rte": (task_rte, 2, "RTE"),
    "winogrande": (task_winogrande, 2, "WG"),
}

GEN_TASKS = {
    "gsm8k": (task_gsm8k, SEQ, "GSM8K"),
    "longbench_kv": (task_longbench_kv, LONG_SEQ, "LB-KV"),
    "longbench_ind": (task_longbench_induction, LONG_SEQ, "LB-IND"),
}


def emit_all(outdir, n_samples=N_SAMPLES):
    from . import params_io

    os.makedirs(outdir, exist_ok=True)
    index = {"mc": {}, "gen": {}, "n_samples": n_samples}
    for tid, (name, (fn, n_choices, bench)) in enumerate(MC_TASKS.items()):
        rng = _rng(tid)
        samples = fn(rng, n_samples)
        rows = _mc_rows(samples)
        path = os.path.join(outdir, f"{name}.aev")
        params_io.write_eval_mc(path, SEQ, n_choices, rows,
                                dict(n_samples=n_samples))
        index["mc"][name] = dict(file=f"{name}.aev", choices=n_choices,
                                 bench=bench, seq=SEQ)
    for tid, (name, (fn, seq, bench)) in enumerate(GEN_TASKS.items()):
        rng = _rng(1000 + tid)
        rows = fn(rng, n_samples if seq == SEQ else max(n_samples // 4, 16))
        path = os.path.join(outdir, f"{name}.aev")
        params_io.write_eval_gen(path, seq, rows, dict(n_samples=len(rows)))
        index["gen"][name] = dict(file=f"{name}.aev", bench=bench, seq=seq)
    params_io.write_manifest(os.path.join(outdir, "index.json"), index)
    return index
