"""Model / training / artifact configuration for the reproduction.

The paper evaluates LLaMA3.1-8B-Instruct, Qwen2-7B-Instruct and the MoE
Qwen3-30B-A3B on 8x Ascend 910B. This environment is a single CPU core, so
we substitute three *architecturally faithful* tiny models trained from
scratch on a structured synthetic corpus (see DESIGN.md §2):

  * ``tiny-lm-a``  — LLaMA3.1-8B analogue  (dense, GQA, RoPE, SwiGLU)
  * ``tiny-lm-b``  — Qwen2-7B analogue     (dense, different width/seed,
                       trained on the extra "B-subspace" fact corpus so it
                       has a CEVAL-analogue column)
  * ``tiny-moe``   — Qwen3-30B-A3B analogue (top-2-of-4-expert MoE MLP)

Module topology matches the paper exactly: q/k/v/o projections in
attention (GQA so k/v are cheap, which drives the skip policy) and
gate/up/down in the MLP.
"""

from dataclasses import dataclass, field, asdict
from typing import Optional


@dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab_size: int = 384
    d_model: int = 128
    n_layers: int = 4
    n_q_heads: int = 4
    n_kv_heads: int = 2
    head_dim: int = 32
    d_ff: int = 352
    rope_theta: float = 10000.0
    rmsnorm_eps: float = 1e-5
    # MoE (ignored when n_experts == 0)
    n_experts: int = 0
    top_k_experts: int = 2
    d_ff_expert: int = 0

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def q_dim(self) -> int:
        return self.n_q_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def n_params(self) -> int:
        """Exact parameter count (embeddings untied)."""
        d = self.d_model
        emb = 2 * self.vocab_size * d
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        if self.is_moe:
            mlp = self.n_experts * 3 * d * self.d_ff_expert + d * self.n_experts
        else:
            mlp = 3 * d * self.d_ff
        norms = 2 * d * self.n_layers + d
        return emb + self.n_layers * (attn + mlp) + norms


@dataclass(frozen=True)
class TrainConfig:
    seed: int = 0
    steps: int = 2200
    batch_size: int = 16
    seq_len: int = 48
    lr: float = 2e-3
    warmup: int = 80
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    log_every: int = 50
    # long-context fine-tuning phase: teaches positions the prefill256
    # artifacts serve (LongBench analogues) — seq_len alone would leave
    # RoPE positions > 48 out of distribution.
    long_steps: int = 300
    long_batch: int = 4
    long_seq: int = 192
    # which corpus skills this model is trained on (see corpus.SKILLS)
    skills: tuple = (
        "grammar_a", "facts_a", "facts_hop2", "arith", "chain",
        "copy", "induction", "boolean", "entail", "select",
        "sort", "kv_recall",
    )


# ---------------------------------------------------------------------------
# Presets. Sized for a single-CPU-core environment; topology mirrors the
# paper's models (GQA with few kv heads, SwiGLU MLP, RMSNorm, RoPE).
# ---------------------------------------------------------------------------

# GQA ratio 4 (LLaMA3.1-8B uses 32q/8kv) and ffn ratio 4 match the
# paper's models' FLOPs *shares*, so the same skip policy lands the same
# ">55% of linear computation accelerated" coverage (see DESIGN.md).
# Sizes are bounded by the single-CPU-core training budget.
TINY_LM_A = ModelConfig(
    name="tiny-lm-a", d_model=96, n_layers=6, n_q_heads=3, n_kv_heads=1,
    head_dim=32, d_ff=384,
)

TINY_LM_B = ModelConfig(
    name="tiny-lm-b", d_model=112, n_layers=6, n_q_heads=4, n_kv_heads=1,
    head_dim=28, d_ff=448,
)

TINY_MOE = ModelConfig(
    name="tiny-moe", d_model=96, n_layers=4, n_q_heads=3, n_kv_heads=1,
    head_dim=32, d_ff=0, n_experts=4, top_k_experts=2, d_ff_expert=160,
)

TRAIN_A = TrainConfig(seed=1)
TRAIN_B = TrainConfig(
    seed=2,
    skills=(
        "grammar_a", "grammar_b", "facts_a", "facts_b", "facts_hop2",
        "arith", "chain", "copy", "induction", "boolean", "entail",
        "select", "sort", "kv_recall",
    ),
)
TRAIN_MOE = TrainConfig(seed=3, steps=1200)

MODELS = {
    "tiny-lm-a": (TINY_LM_A, TRAIN_A),
    "tiny-lm-b": (TINY_LM_B, TRAIN_B),
    "tiny-moe": (TINY_MOE, TRAIN_MOE),
}

# Number of layers where q_proj/gate_proj are skipped (paper skips 5/32,
# 5/28 and 3/48 layers; at our depth that rounds to 1, 1 and 0). Chosen so
# coverage of linear FLOPs lands >55% like the paper's setups.
SKIP_COUNTS = {"tiny-lm-a": 1, "tiny-lm-b": 1, "tiny-moe": 0}

# The paper's three evaluated models map onto ours:
PAPER_MODEL_MAP = {
    "LLaMA3.1-8B": "tiny-lm-a",
    "Qwen2-7B": "tiny-lm-b",
    "Qwen3-30B-A3B": "tiny-moe",
}


@dataclass(frozen=True)
class ArtifactShapes:
    """Static shapes baked into the AOT-lowered executables."""
    prefill_batch: int = 8
    prefill_seq: int = 64
    long_batch: int = 2
    long_seq: int = 256
    decode_batch: int = 8
    decode_cache: int = 320  # long_seq + generation headroom


SHAPES = ArtifactShapes()

# Sparsity ratios evaluated in the paper (N, M).
RATIOS = [(2, 4), (4, 8), (8, 16)]

# Linear-projection module names, in paper order.
DENSE_MODULES = ("q_proj", "k_proj", "v_proj", "o_proj",
                 "gate_proj", "up_proj", "down_proj")
MOE_MODULES = ("q_proj", "k_proj", "v_proj", "o_proj",
               "gate_proj", "up_proj", "down_proj")  # expert mlps share names


def config_dict(cfg: ModelConfig) -> dict:
    return asdict(cfg)
