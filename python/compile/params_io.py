"""Binary interchange formats between the python build path and the rust
runtime. Custom formats (serde/npz are unavailable on the rust side):

weights file  (``*.atw`` — "Amber Tensor Weights"):
    magic  b"ATWB"            u32-LE version (=1)
    n_tensors u32
    per tensor:
        name_len u16, name utf-8
        dtype u8  (0=f32, 1=i32, 2=i8, 3=u8)
        ndim u8, dims i64 x ndim
        byte_len u64, raw little-endian data

The tensor ORDER in the file is the flattened-argument order of the lowered
executable: rust loads the file sequentially into PJRT literals and appends
the runtime inputs (tokens, positions, ...) after them. ``manifest.json``
records, per artifact, the tensor names, the runtime-input specs and the
output specs so the rust side can sanity-check shapes without ever parsing
HLO.

eval dataset file (``*.aev``):
    magic b"AEVD"  version u32 (=1)
    kind u8 (0 = multiple-choice, 1 = generation)
    seq_len u32, n_rows u32, n_samples u32, n_choices u32 (0 for gen)
    rows: n_rows x seq_len  i32 tokens (PAD-padded right)
    per row (MC):   sample_id u32, choice_id u16, score_start u16,
                    score_len u16, gold u16
    per row (gen):  sample_id u32, prompt_len u16, gold_len u16,
                    gold tokens i32 x 8 (zero-padded), max_gen u16
"""

import json
import struct

import numpy as np

DTYPE_CODES = {"float32": 0, "int32": 1, "int8": 2, "uint8": 3}


def write_weights(path, tensors):
    """tensors: list of (name, np.ndarray). Order == executable arg order."""
    with open(path, "wb") as f:
        f.write(b"ATWB")
        f.write(struct.pack("<II", 1, len(tensors)))
        for name, arr in tensors:
            arr = np.ascontiguousarray(arr)
            code = DTYPE_CODES[arr.dtype.name]
            nb = name.encode()
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", code, arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<q", d))
            raw = arr.tobytes()
            f.write(struct.pack("<Q", len(raw)))
            f.write(raw)


def read_weights(path):
    """Round-trip reader (tests + python-side verification)."""
    out = []
    inv = {v: k for k, v in DTYPE_CODES.items()}
    with open(path, "rb") as f:
        assert f.read(4) == b"ATWB"
        _, n = struct.unpack("<II", f.read(8))
        for _ in range(n):
            (nl,) = struct.unpack("<H", f.read(2))
            name = f.read(nl).decode()
            code, ndim = struct.unpack("<BB", f.read(2))
            dims = [struct.unpack("<q", f.read(8))[0] for _ in range(ndim)]
            (nb,) = struct.unpack("<Q", f.read(8))
            arr = np.frombuffer(f.read(nb), dtype=inv[code]).reshape(dims)
            out.append((name, arr))
    return out


def flatten_for_artifact(tree):
    """Deterministic (name, array) flattening of a params/aux dict.

    Sorted by key at each dict level — matching jax's pytree flattening
    order for dicts, so the lowered executable's parameter order equals the
    weights-file order by construction.
    """
    flat = []

    def rec(prefix, node):
        if isinstance(node, dict):
            for k in sorted(node):
                rec(f"{prefix}.{k}" if prefix else k, node[k])
        else:
            flat.append((prefix, np.asarray(node)))

    rec("", tree)
    return flat


# ---------------------------------------------------------------------------
# eval datasets
# ---------------------------------------------------------------------------

def write_eval_mc(path, seq_len, n_choices, rows, meta):
    """rows: list of dicts(tokens list[int], sample u32, choice u16,
    score_start, score_len, gold)."""
    with open(path, "wb") as f:
        f.write(b"AEVD")
        f.write(struct.pack("<IBIIII", 1, 0, seq_len, len(rows),
                            meta["n_samples"], n_choices))
        for r in rows:
            t = np.full(seq_len, 0, dtype=np.int32)
            t[:len(r["tokens"])] = r["tokens"]
            f.write(t.tobytes())
        for r in rows:
            f.write(struct.pack("<IHHHH", r["sample"], r["choice"],
                                r["score_start"], r["score_len"], r["gold"]))


def write_eval_gen(path, seq_len, rows, meta):
    with open(path, "wb") as f:
        f.write(b"AEVD")
        f.write(struct.pack("<IBIIII", 1, 1, seq_len, len(rows),
                            meta["n_samples"], 0))
        for r in rows:
            t = np.full(seq_len, 0, dtype=np.int32)
            t[:len(r["tokens"])] = r["tokens"]
            f.write(t.tobytes())
        for r in rows:
            gold = np.zeros(8, dtype=np.int32)
            gold[:len(r["gold"])] = r["gold"]
            f.write(struct.pack("<IHH", r["sample"], len(r["tokens"]),
                                len(r["gold"])))
            f.write(gold.tobytes())
            f.write(struct.pack("<H", r["max_gen"]))


def read_eval(path):
    """Python-side reader for tests."""
    with open(path, "rb") as f:
        assert f.read(4) == b"AEVD"
        ver, kind, seq_len, n_rows, n_samples, n_choices = struct.unpack(
            "<IBIIII", f.read(21))
        rows = np.frombuffer(f.read(4 * seq_len * n_rows),
                             dtype=np.int32).reshape(n_rows, seq_len)
        metas = []
        for _ in range(n_rows):
            if kind == 0:
                metas.append(struct.unpack("<IHHHH", f.read(12)))
            else:
                sid, plen, glen = struct.unpack("<IHH", f.read(8))
                gold = np.frombuffer(f.read(32), dtype=np.int32)[:glen]
                (mg,) = struct.unpack("<H", f.read(2))
                metas.append((sid, plen, tuple(gold.tolist()), mg))
    return dict(kind=kind, seq_len=seq_len, n_samples=n_samples,
                n_choices=n_choices, rows=rows, metas=metas)


def write_manifest(path, manifest: dict):
    with open(path, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
