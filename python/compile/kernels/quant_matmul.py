"""Pallas kernels for Outstanding-sparse: W8A8 (SmoothQuant) projections,
dense and fused with N:M activation pruning.

Semantics (must match ref.w8a8_matmul exactly):
  * activations: symmetric per-tensor int8 with a *static* calibrated scale
    (SmoothQuant-style, calibration in amber/quant.py)
  * weights:     symmetric per-output-channel int8 (precomputed offline,
    shipped as i8 tensors in weights.bin)
  * accumulation in int32, dequant to f32 with x_scale * w_scale[j]

For Outstanding-sparse the N:M pruning happens on the *smoothed float*
activations (where the inverted ŝ = 1/s scaling has expanded the range and
exposed the sparsity pattern — paper Fig. 3), and the surviving values are
then quantized. Zeroed slots quantize to exact int8 zeros, so the pruned
tile is still a valid hardware N:M operand.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .nm_prune import kernel_nm_mask, pick_token_tile, TOKEN_TILE
from .nm_spmm import _pick_out_tile


def _quantize(x, x_scale):
    return jnp.clip(jnp.round(x / x_scale), -127, 127).astype(jnp.int8)


def _w8a8_kernel(x_ref, wq_ref, wscale_ref, xscale_ref, o_ref):
    xq = _quantize(x_ref[...], xscale_ref[0]).astype(jnp.int32)
    acc = jnp.dot(xq, wq_ref[...].astype(jnp.int32),
                  preferred_element_type=jnp.int32)
    o_ref[...] = acc.astype(jnp.float32) * (xscale_ref[0]
                                            * wscale_ref[...][None, :])


def w8a8_matmul(x, wq, w_scale, x_scale):
    """Quantized projection: x [T,Din] f32, wq [Din,Dout] i8,
    w_scale [Dout] f32, x_scale scalar f32."""
    t, din = x.shape
    dout = wq.shape[1]
    tt = pick_token_tile(t)
    xs = jnp.broadcast_to(x_scale, (1,)).astype(jnp.float32)
    ot = _pick_out_tile(dout)
    return pl.pallas_call(
        _w8a8_kernel,
        grid=(t // tt, dout // ot),
        in_specs=[
            pl.BlockSpec((tt, din), lambda i, j: (i, 0)),
            pl.BlockSpec((din, ot), lambda i, j: (0, j)),
            pl.BlockSpec((ot,), lambda i, j: (j,)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((tt, ot), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((t, dout), jnp.float32),
        interpret=True,
    )(x, wq, w_scale, xs)


def _w8a8_nm_kernel(x_ref, wq_ref, wscale_ref, xscale_ref, scale_ref,
                    keep_ref, o_ref, *, n, m):
    x = x_ref[...]
    score = jnp.abs(x) * scale_ref[...][None, :]
    mask = kernel_nm_mask(score, n, m)
    mask = jnp.maximum(mask, keep_ref[0])
    xq = _quantize(x * mask, xscale_ref[0]).astype(jnp.int32)
    acc = jnp.dot(xq, wq_ref[...].astype(jnp.int32),
                  preferred_element_type=jnp.int32)
    o_ref[...] = acc.astype(jnp.float32) * (xscale_ref[0]
                                            * wscale_ref[...][None, :])


@functools.partial(jax.named_call, name="amber_w8a8_nm_prune_matmul")
def w8a8_nm_prune_matmul(x, wq, w_scale, x_scale, scale, n, m,
                         keep_dense=None):
    """Outstanding-sparse fused hot path: N:M-prune the smoothed float
    activations, quantize the survivors, int8 projection."""
    t, din = x.shape
    dout = wq.shape[1]
    tt = pick_token_tile(t)
    assert din % m == 0 and t % tt == 0
    if keep_dense is None:
        keep_dense = jnp.zeros((), jnp.float32)
    keep = jnp.broadcast_to(keep_dense, (1,)).astype(jnp.float32)
    xs = jnp.broadcast_to(x_scale, (1,)).astype(jnp.float32)
    ot = _pick_out_tile(dout)
    kernel = functools.partial(_w8a8_nm_kernel, n=n, m=m)
    return pl.pallas_call(
        kernel,
        grid=(t // tt, dout // ot),
        in_specs=[
            pl.BlockSpec((tt, din), lambda i, j: (i, 0)),
            pl.BlockSpec((din, ot), lambda i, j: (0, j)),
            pl.BlockSpec((ot,), lambda i, j: (j,)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((din,), lambda i, j: (0,)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((tt, ot), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((t, dout), jnp.float32),
        interpret=True,
    )(x, wq, w_scale, xs, scale, keep)
