"""Pallas kernel: causal GQA prefill attention.

One grid step per (batch, q-head): the (S, Dh) query block and its grouped
(S, Dh) key/value blocks are VMEM-resident (S<=256, Dh<=64 -> < 200 KiB),
softmax is computed in f32 with the standard max-subtraction. This is the
non-linear hot spot between the paper's sparsified projections; it is kept
dense (the paper sparsifies only the *linear* layers' inputs).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .nm_prune import PROFILE


def _attn_kernel_full(q_ref, k_ref, v_ref, o_ref, *, scale, group):
    """CPU-profile body: all (batch, head) pairs in one invocation —
    interpret mode serializes grid steps, so a 24-step grid cost ~10x the
    math at tiny sizes (§Perf L1)."""
    q = q_ref[...]
    k = k_ref[...]
    v = v_ref[...]
    b, s, hq, dh = q.shape
    kk = jnp.repeat(k, group, axis=2)
    vv = jnp.repeat(v, group, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, kk,
                        preferred_element_type=jnp.float32) * scale
    ii = jax.lax.broadcasted_iota(jnp.int32, (s, s), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (s, s), 1)
    logits = jnp.where((jj <= ii)[None, None], logits, -1e30)
    mx = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - mx)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o_ref[...] = jnp.einsum("bhqk,bkhd->bqhd", p, vv,
                            preferred_element_type=jnp.float32)


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, scale):
    # blocks: q [1, S, 1, Dh], k/v [1, S, 1, Dh]
    q = q_ref[0, :, 0, :]
    k = k_ref[0, :, 0, :]
    v = v_ref[0, :, 0, :]
    s = q.shape[0]
    logits = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    ii = jax.lax.broadcasted_iota(jnp.int32, (s, s), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (s, s), 1)
    logits = jnp.where(jj <= ii, logits, -1e30)
    mx = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - mx)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o_ref[0, :, 0, :] = jnp.dot(p, v, preferred_element_type=jnp.float32)


def causal_attention(q, k, v):
    """q [B,S,Hq,Dh], k/v [B,S,Hkv,Dh] -> [B,S,Hq,Dh], causal, GQA."""
    b, s, hq, dh = q.shape
    hkv = k.shape[2]
    group = hq // hkv
    scale = 1.0 / float(dh) ** 0.5
    if PROFILE != "tpu":
        kernel = functools.partial(_attn_kernel_full, scale=scale,
                                   group=group)
        return pl.pallas_call(
            kernel,
            grid=(1,),
            in_specs=[
                pl.BlockSpec((b, s, hq, dh), lambda i: (0, 0, 0, 0)),
                pl.BlockSpec((b, s, hkv, dh), lambda i: (0, 0, 0, 0)),
                pl.BlockSpec((b, s, hkv, dh), lambda i: (0, 0, 0, 0)),
            ],
            out_specs=pl.BlockSpec((b, s, hq, dh), lambda i: (0, 0, 0, 0)),
            out_shape=jax.ShapeDtypeStruct((b, s, hq, dh), jnp.float32),
            interpret=True,
        )(q, k, v)
    kernel = functools.partial(_attn_kernel, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(b, hq),
        in_specs=[
            pl.BlockSpec((1, s, 1, dh), lambda i, h: (i, 0, h, 0)),
            pl.BlockSpec((1, s, 1, dh), lambda i, h: (i, 0, h // group, 0)),
            pl.BlockSpec((1, s, 1, dh), lambda i, h: (i, 0, h // group, 0)),
        ],
        out_specs=pl.BlockSpec((1, s, 1, dh), lambda i, h: (i, 0, h, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, hq, dh), jnp.float32),
        interpret=True,
    )(q, k, v)
