"""Pallas kernels: N:M sparse-dense matmul and the fused prune+project
prefill hot path.

This is the projection the paper accelerates: a N:M-pruned activation tile
against a dense weight matrix (SpMM). On sparse-matmul hardware the pruned
tile is consumed in compressed (values, indices) form at N/M of the dense
FLOPs; on the MXU we express the same schedule as token-tile × out-tile
blocks with the full reduction axis resident in VMEM, and the mask applied
on the VPU immediately before the MXU dot. The N/M compute reduction is
demonstrated natively by `rust/src/sparsity/spmm.rs` on the CPU analogue.

Tile sizes: (TOKEN_TILE x D) activations, (D x OUT_TILE) weights, f32
accumulation — VMEM footprint per step = TOKEN_TILE*D + D*OUT_TILE floats
(~ 96 KiB at D=512, OUT_TILE=128), comfortably under a real core's ~16 MiB.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .nm_prune import kernel_nm_mask, pick_token_tile, PROFILE, TOKEN_TILE

OUT_TILE = 128


def _pick_out_tile(d_out):
    if PROFILE != "tpu":
        return d_out  # cpu/interpret: single block (see nm_prune.PROFILE)
    for t in (OUT_TILE, 64, 32, 16, 8, 4, 2, 1):
        if d_out % t == 0:
            return t
    return 1


def _matmul_kernel(x_ref, w_ref, o_ref):
    o_ref[...] = jnp.dot(x_ref[...], w_ref[...],
                         preferred_element_type=jnp.float32)


def matmul(x, w):
    """Dense blocked projection: x [T, Din] @ w [Din, Dout]."""
    t, din = x.shape
    dout = w.shape[1]
    tt = pick_token_tile(t)
    ot = _pick_out_tile(dout)
    return pl.pallas_call(
        _matmul_kernel,
        grid=(t // tt, dout // ot),
        in_specs=[
            pl.BlockSpec((tt, din), lambda i, j: (i, 0)),
            pl.BlockSpec((din, ot), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((tt, ot), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((t, dout), jnp.float32),
        interpret=True,
    )(x, w)


def _fused_kernel(x_ref, w_ref, scale_ref, keep_ref, o_ref, *, n, m):
    """Prune the activation tile in VMEM, then one MXU dot."""
    x = x_ref[...]
    score = jnp.abs(x) * scale_ref[...][None, :]
    mask = kernel_nm_mask(score, n, m)
    mask = jnp.maximum(mask, keep_ref[0])
    xp = x * mask
    o_ref[...] = jnp.dot(xp, w_ref[...], preferred_element_type=jnp.float32)


@functools.partial(jax.named_call, name="amber_nm_prune_matmul")
def nm_prune_matmul(x, w, scale, n, m, keep_dense=None):
    """Fused Amber-Pruner projection: N:M-prune x [T, Din] (score =
    |x| * scale) then project with w [Din, Dout]."""
    t, din = x.shape
    dout = w.shape[1]
    tt = pick_token_tile(t)
    assert din % m == 0 and t % tt == 0
    if keep_dense is None:
        keep_dense = jnp.zeros((), jnp.float32)
    keep = jnp.broadcast_to(keep_dense, (1,)).astype(x.dtype)
    ot = _pick_out_tile(dout)
    kernel = functools.partial(_fused_kernel, n=n, m=m)
    return pl.pallas_call(
        kernel,
        grid=(t // tt, dout // ot),
        in_specs=[
            pl.BlockSpec((tt, din), lambda i, j: (i, 0)),
            pl.BlockSpec((din, ot), lambda i, j: (0, j)),
            pl.BlockSpec((din,), lambda i, j: (0,)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((tt, ot), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((t, dout), jnp.float32),
        interpret=True,
    )(x, w, scale, keep)
