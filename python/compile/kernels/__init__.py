"""Layer-1 Pallas kernels (interpret=True) + pure-jnp oracles.

Every kernel here is the compute hot-spot of the paper's method:

  * ``nm_prune``        scored N:M top-k activation pruning (Amber Pruner
                        Eq. 2/5 applied online, with the precomputed
                        channel scale as an auxiliary weight)
  * ``nm_prune_matmul`` the fused prefill hot path: prune + projection
  * ``nm_spmm``         N:M-sparse x dense matmul over pruned activations
  * ``quant_matmul``    W8A8 (SmoothQuant) int8 matmul for Outstanding-sparse
  * ``attention``       causal GQA prefill attention

Kernels MUST be lowered with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls (see DESIGN.md §5 for the TPU mapping).
``ref.py`` holds the pure-jnp oracles pytest checks them against.
"""
