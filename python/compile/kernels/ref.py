"""Pure-jnp oracles for every Layer-1 kernel.

These are the correctness ground truth: the Pallas kernels in this package
must match them exactly (same masking tie-breaks, same accumulation dtype),
and the training-path model uses them directly (fast native XLA) while the
AOT artifacts use the Pallas versions.
"""

import jax
import jax.numpy as jnp


def nm_mask(score, n, m):
    """Exact-N:M keep mask from a score tensor.

    ``score`` [..., D] with D % m == 0. Within every group of ``m``
    consecutive channels keep the ``n`` highest-scoring elements. Ties are
    broken toward the lower channel index (stable argsort), which keeps the
    mask exactly N:M — a requirement of the hardware SpMM format the paper
    targets (a ">= kth value" mask can exceed N on ties).
    """
    d = score.shape[-1]
    assert d % m == 0, f"last dim {d} not divisible by M={m}"
    g = score.reshape(*score.shape[:-1], d // m, m)
    # rank within group: 0 = largest. argsort of -score is stable, so equal
    # scores rank lower-index-first.
    order = jnp.argsort(-g, axis=-1)
    rank = jnp.argsort(order, axis=-1)
    mask = (rank < n).astype(score.dtype)
    return mask.reshape(score.shape)


def nm_prune(x, scale, n, m, keep_dense=None):
    """Scored N:M activation pruning (Amber Pruner).

    score = |x| * scale  (Eq. 2 / Eq. 5 — the channel statistic of W is
    precomputed offline into ``scale``; naive top-k is scale == 1).
    ``keep_dense`` is a 0/1 scalar (float) that bypasses pruning when 1 —
    this is how the layer-skipping policy reaches the AOT graph as *data*
    rather than as a separate compiled artifact.
    """
    score = jnp.abs(x) * scale
    mask = nm_mask(score, n, m)
    if keep_dense is not None:
        mask = jnp.maximum(mask, keep_dense)
    return x * mask


def matmul(x, w):
    """Dense reference projection, f32 accumulation."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


def nm_prune_matmul(x, w, scale, n, m, keep_dense=None):
    """Fused reference: prune activations then project."""
    return matmul(nm_prune(x, scale, n, m, keep_dense), w)


def nm_compress(xp, n, m):
    """Compress an N:M-pruned tensor to (values, indices).

    xp [..., D] with at most n nonzeros per m-group (as produced by
    ``nm_prune``). Returns values [..., D//m, n] and int32 indices
    [..., D//m, n] (channel offset within the group). This is the memory
    layout a sparse tensor core / SpMM unit consumes, and the layout the
    rust-native SpMM benchmark uses.
    """
    d = xp.shape[-1]
    g = xp.reshape(*xp.shape[:-1], d // m, m)
    nz = (g != 0).astype(jnp.int32)
    # order channels: nonzeros first (stable), take first n
    order = jnp.argsort(-nz, axis=-1, stable=True)
    idx = order[..., :n]
    vals = jnp.take_along_axis(g, idx, axis=-1)
    return vals, idx.astype(jnp.int32)


def nm_decompress(vals, idx, m):
    """Inverse of ``nm_compress`` (zero-filled)."""
    shp = vals.shape[:-1]
    out = jnp.zeros(shp + (m,), vals.dtype)
    out = jnp.put_along_axis(out, idx.astype(jnp.int32), vals, axis=-1,
                             inplace=False)
    return out.reshape(*vals.shape[:-2], vals.shape[-2] * m)


def quantize_tensor(x, x_scale):
    """Per-tensor symmetric int8 quantization with a static scale."""
    q = jnp.clip(jnp.round(x / x_scale), -127, 127)
    return q.astype(jnp.int8)


def w8a8_matmul(x, wq, w_scale, x_scale):
    """W8A8 reference: static per-tensor activation quant, per-channel
    weight quant, int32 accumulation, float dequant."""
    xq = quantize_tensor(x, x_scale).astype(jnp.int32)
    acc = jnp.dot(xq, wq.astype(jnp.int32),
                  preferred_element_type=jnp.int32)
    return acc.astype(jnp.float32) * (x_scale * w_scale)[None, :]


def w8a8_nm_prune_matmul(x, wq, w_scale, x_scale, scale, n, m,
                         keep_dense=None):
    """Outstanding-sparse fused hot path: smooth-scaled activations are
    pruned N:M first, then quantized and projected in int8."""
    xp = nm_prune(x, scale, n, m, keep_dense)
    return w8a8_matmul(xp, wq, w_scale, x_scale)


def rope(x, pos, theta=10000.0):
    """Rotary position embedding. x [..., S, H, Dh], pos [..., S]."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = pos[..., :, None].astype(jnp.float32) * freqs  # [..., S, half]
    cos = jnp.cos(ang)[..., :, None, :]  # broadcast over heads
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin,
                            x1 * sin + x2 * cos], axis=-1)


def causal_attention(q, k, v, *, pos_q=None, pos_k=None, kv_len=None):
    """Causal GQA attention reference.

    q [B,Sq,Hq,Dh], k/v [B,Sk,Hkv,Dh]; Hq % Hkv == 0 (grouped queries).
    ``pos_q``/``pos_k`` [B,Sq]/[B,Sk] are absolute positions used for the
    causal mask (needed for decode, where Sq=1 mid-cache); defaults to
    arange. ``kv_len`` [B] optionally masks out cache slots >= length.
    """
    b, sq, hq, dh = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    group = hq // hkv
    if pos_q is None:
        pos_q = jnp.broadcast_to(jnp.arange(sq)[None, :], (b, sq))
    if pos_k is None:
        pos_k = jnp.broadcast_to(jnp.arange(sk)[None, :], (b, sk))
    kk = jnp.repeat(k, group, axis=2)  # [B,Sk,Hq,Dh]
    vv = jnp.repeat(v, group, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, kk) / jnp.sqrt(
        jnp.array(dh, jnp.float32))
    mask = pos_k[:, None, None, :] <= pos_q[:, None, :, None]  # [B,1,Sq,Sk]
    if kv_len is not None:
        mask = mask & (jnp.arange(sk)[None, None, None, :]
                       < kv_len[:, None, None, None])
    logits = jnp.where(mask, logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vv)
    return out
