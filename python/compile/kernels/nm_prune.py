"""Pallas kernel: scored N:M top-k activation pruning (Amber Pruner core).

Hardware adaptation (DESIGN.md §5): the paper targets an N:M SpMM unit
(Ascend/Ampere). On a TPU-style target there is no sparse MXU mode, so the
kernel is structured for VMEM instead: activations stream HBM→VMEM in
token-tile × full-feature blocks (the feature axis must be resident so each
M-group is local to the tile), the score/rank/mask runs on the VPU, and the
masked tile feeds the MXU matmul of the fused variant (``nm_spmm``).

``interpret=True`` everywhere — the CPU PJRT plugin cannot run Mosaic
custom-calls; correctness is exact vs ``ref.nm_prune`` either way.
"""

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Token-tile height. 16 divides every (batch*seq) the artifacts use and
# keeps the VMEM footprint of a block at 16*D*4B (D<=512 -> 32 KiB).
TOKEN_TILE = 16

# Tiling profile (§Perf L1): "tpu" uses the VMEM-sized tiles documented in
# DESIGN.md §5; "cpu" (default for this interpret-mode substrate) uses one
# full-extent block per pallas_call — interpret mode serializes grid steps
# through an HLO while-loop, and at tiny-model sizes the loop overhead
# dominated end-to-end latency by ~8x (EXPERIMENTS.md §Perf, iteration 1).
PROFILE = os.environ.get("AMBER_TILE_PROFILE", "cpu")


def pick_token_tile(t: int) -> int:
    """Largest legal token tile for the active profile."""
    if PROFILE == "tpu":
        assert t % TOKEN_TILE == 0
        return TOKEN_TILE
    return t  # cpu/interpret: single block


def kernel_nm_mask(score, n, m):
    """Exact-N:M keep mask inside a kernel body.

    Rank via O(m^2) pairwise comparisons instead of argsort: XLA's CPU
    sort is comparator-driven and dominated the sparse-prefill latency
    (§Perf L1 iteration 2, ~3x end-to-end). rank_i = #{j : s_j > s_i or
    (s_j == s_i and j < i)} reproduces the *stable* descending-argsort
    position exactly, so the mask is bit-identical to the oracle's.
    m <= 16 keeps the broadcast at m^2 = 256 lanes per group — VPU-friendly
    on real hardware too.
    """
    t, d = score.shape
    g = score.reshape(t, d // m, m)
    a = g[..., :, None]  # s_i
    b = g[..., None, :]  # s_j
    jj = jax.lax.broadcasted_iota(jnp.int32, (m, m), 1)
    ii = jax.lax.broadcasted_iota(jnp.int32, (m, m), 0)
    beats = (b > a) | ((b == a) & (jj < ii))
    rank = jnp.sum(beats.astype(jnp.int32), axis=-1)  # [t, d//m, m]
    return (rank < n).astype(score.dtype).reshape(t, d)


def _prune_kernel(x_ref, scale_ref, keep_ref, o_ref, *, n, m):
    """One (TOKEN_TILE, D) block: score, rank per M-group, mask."""
    x = x_ref[...]
    score = jnp.abs(x) * scale_ref[...][None, :]
    mask = kernel_nm_mask(score, n, m)
    # layer-skip flag arrives as data: keep==1 bypasses pruning.
    keep = keep_ref[0]
    mask = jnp.maximum(mask, keep)
    o_ref[...] = x * mask


@functools.partial(jax.named_call, name="amber_nm_prune")
def nm_prune(x, scale, n, m, keep_dense=None):
    """Prune ``x`` [T, D] to N:M along D. ``scale`` [D] is the offline
    channel statistic (ones = naive top-k). ``keep_dense`` scalar f32."""
    t, d = x.shape
    assert d % m == 0
    tt = pick_token_tile(t)
    assert t % tt == 0, f"token dim {t} % {tt} != 0"
    if keep_dense is None:
        keep_dense = jnp.zeros((), jnp.float32)
    keep = jnp.broadcast_to(keep_dense, (1,)).astype(x.dtype)
    kernel = functools.partial(_prune_kernel, n=n, m=m)
    return pl.pallas_call(
        kernel,
        grid=(t // tt,),
        in_specs=[
            pl.BlockSpec((tt, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tt, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d), x.dtype),
        interpret=True,
    )(x, scale, keep)
