"""Layer-2 JAX model: LLaMA-architecture transformer (GQA + RoPE + SwiGLU
+ RMSNorm) with the Amber-Pruner sparse prefill path.

Three graph variants, selected statically at lowering time:

  * ``variant="dense"``   — plain fp32 projections (the Bfloat16 baseline;
                            we run fp32 since the CPU path has no bf16 MXU)
  * ``variant="nm"``      — every linear projection goes through the fused
                            Layer-1 ``nm_prune_matmul`` kernel; whether a
                            given (layer, module) actually prunes is *data*
                            (``keep_dense`` flags + channel score scales
                            shipped as auxiliary weights), so naive top-k /
                            Amber-P(l.s.) / Amber-P(all) share one artifact
  * ``variant="sq"`` / ``"sq_nm"`` — W8A8 SmoothQuant projections
                            (Outstanding-sparse when fused with N:M)

``use_pallas=False`` swaps every kernel for its pure-jnp oracle — that is
the training path (fast native XLA) and the pytest equivalence target.

Parameters are dicts of stacked per-layer tensors (scan-friendly ordering,
stable flattening order == weights.bin order, see params_io.py).
"""

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .configs import ModelConfig, DENSE_MODULES
from .kernels import ref
from .kernels import nm_prune as k_prune  # noqa: F401 (re-export for tests)
from .kernels import nm_spmm as k_spmm
from .kernels import quant_matmul as k_quant
from .kernels import attention as k_attn

# module index order used by aux tensors (skip flags / score scales)
MODULE_IDX = {m: i for i, m in enumerate(DENSE_MODULES)}


# ---------------------------------------------------------------------------
# Parameter initialization
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key) -> dict:
    """Random init. Stacked [L, ...] tensors, scan/artifact friendly."""
    k_emb, k_out, *k_layers = jax.random.split(key, 2 + cfg.n_layers)
    d, q, kv, f = cfg.d_model, cfg.q_dim, cfg.kv_dim, cfg.d_ff

    def dense_init(key, shape, fan_in):
        return (jax.random.normal(key, shape, jnp.float32)
                * (1.0 / jnp.sqrt(fan_in)))

    def layer(key):
        ks = jax.random.split(key, 7)
        return dict(
            wq=dense_init(ks[0], (d, q), d),
            wk=dense_init(ks[1], (d, kv), d),
            wv=dense_init(ks[2], (d, kv), d),
            wo=dense_init(ks[3], (q, d), q),
            wg=dense_init(ks[4], (d, f), d),
            wu=dense_init(ks[5], (d, f), d),
            wd=dense_init(ks[6], (f, d), f),
        )

    layers = [layer(k) for k in k_layers]
    stacked = {name: jnp.stack([l[name] for l in layers])
               for name in layers[0]}
    return dict(
        embed=jax.random.normal(k_emb, (cfg.vocab_size, d)) * 0.02,
        unembed=dense_init(k_out, (d, cfg.vocab_size), d),
        ln_attn=jnp.ones((cfg.n_layers, d)),
        ln_mlp=jnp.ones((cfg.n_layers, d)),
        ln_final=jnp.ones((d,)),
        **stacked,
    )


def default_aux(cfg: ModelConfig) -> dict:
    """Auxiliary sparsity weights: per-(layer, module) keep-dense flags and
    per-channel score scales. Defaults = prune nothing, naive scores."""
    L = cfg.n_layers
    return dict(
        keep_dense=jnp.ones((L, len(DENSE_MODULES)), jnp.float32),
        scale_q=jnp.ones((L, cfg.d_model), jnp.float32),
        scale_k=jnp.ones((L, cfg.d_model), jnp.float32),
        scale_v=jnp.ones((L, cfg.d_model), jnp.float32),
        scale_o=jnp.ones((L, cfg.q_dim), jnp.float32),
        scale_g=jnp.ones((L, cfg.d_model), jnp.float32),
        scale_u=jnp.ones((L, cfg.d_model), jnp.float32),
        scale_d=jnp.ones((L, cfg.d_ff), jnp.float32),
    )


AUX_SCALE_NAMES = {
    "q_proj": "scale_q", "k_proj": "scale_k", "v_proj": "scale_v",
    "o_proj": "scale_o", "gate_proj": "scale_g", "up_proj": "scale_u",
    "down_proj": "scale_d",
}


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------

def rmsnorm(x, g, eps):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * g


class Projector:
    """Dispatches a named linear projection to the right kernel variant.

    Flattens [B, S, Din] to [B*S, Din] for the token-tiled kernels.
    """

    def __init__(self, cfg, variant, use_pallas, nm=None, aux=None,
                 qparams=None, layer=None):
        self.cfg, self.variant, self.use_pallas = cfg, variant, use_pallas
        self.nm, self.aux, self.qparams, self.layer = nm, aux, qparams, layer

    def __call__(self, name, x, w):
        b, s, din = x.shape
        x2 = x.reshape(b * s, din)
        mi = MODULE_IDX[name]
        if self.variant == "dense":
            y = (k_spmm.matmul(x2, w) if self.use_pallas
                 else ref.matmul(x2, w))
        elif self.variant == "nm":
            n, m = self.nm
            keep = self.aux["keep_dense"][self.layer, mi]
            scale = self.aux[AUX_SCALE_NAMES[name]][self.layer]
            fn = (k_spmm.nm_prune_matmul if self.use_pallas
                  else ref.nm_prune_matmul)
            y = fn(x2, w, scale, n, m, keep)
        elif self.variant in ("sq", "sq_nm"):
            qp = self.qparams
            wq = qp["wq"][name][self.layer]
            w_scale = qp["w_scale"][name][self.layer]
            x_scale = qp["x_scale"][name][self.layer]
            quantized = bool(qp["quantized"][name][self.layer])
            if not quantized:
                # quantization skip policy (paper §Outstanding-sparse):
                # fall back to the fp weights for this module.
                if self.variant == "sq_nm":
                    n, m = self.nm
                    keep = self.aux["keep_dense"][self.layer, mi]
                    scale = self.aux[AUX_SCALE_NAMES[name]][self.layer]
                    fn = (k_spmm.nm_prune_matmul if self.use_pallas
                          else ref.nm_prune_matmul)
                    return fn(x2, w, scale, n, m, keep).reshape(b, s, -1)
                y = (k_spmm.matmul(x2, w) if self.use_pallas
                     else ref.matmul(x2, w))
                return y.reshape(b, s, -1)
            if self.variant == "sq":
                fn = (k_quant.w8a8_matmul if self.use_pallas
                      else ref.w8a8_matmul)
                y = fn(x2, wq, w_scale, x_scale)
            else:
                n, m = self.nm
                keep = self.aux["keep_dense"][self.layer, mi]
                scale = self.aux[AUX_SCALE_NAMES[name]][self.layer]
                fn = (k_quant.w8a8_nm_prune_matmul if self.use_pallas
                      else ref.w8a8_nm_prune_matmul)
                y = fn(x2, wq, w_scale, x_scale, scale, n, m, keep)
        else:
            raise ValueError(self.variant)
        return y.reshape(b, s, -1)


def attention_block(cfg, proj, params, layer, x, pos, kv_cache=None,
                    kv_len=None, use_pallas=False):
    """Self-attention with RoPE + GQA. Returns (out, (k, v)) where k/v are
    this block's key/value tensors (post-RoPE k) for the cache."""
    b, s, d = x.shape
    q = proj("q_proj", x, params["wq"][layer])
    k = proj("k_proj", x, params["wk"][layer])
    v = proj("v_proj", x, params["wv"][layer])
    q = q.reshape(b, s, cfg.n_q_heads, cfg.head_dim)
    k = k.reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    q = ref.rope(q, pos, cfg.rope_theta)
    k = ref.rope(k, pos, cfg.rope_theta)
    if kv_cache is None:
        # prefill: attend within the (causal) block
        if use_pallas:
            o = k_attn.causal_attention(q, k, v)
        else:
            o = ref.causal_attention(q, k, v)
        new_kv = (k, v)
    else:
        # decode: append to cache at position pos, attend over cache
        ck, cv = kv_cache  # [B, C, Hkv, Dh]
        c = ck.shape[1]
        onehot = jax.nn.one_hot(pos[:, 0], c, dtype=ck.dtype)  # [B, C]
        ck = ck + onehot[:, :, None, None] * k
        cv = cv + onehot[:, :, None, None] * v
        pos_k = jnp.broadcast_to(jnp.arange(c)[None, :], (b, c))
        o = ref.causal_attention(q, ck, cv, pos_q=pos, pos_k=pos_k,
                                 kv_len=kv_len)
        new_kv = (ck, cv)
    o = o.reshape(b, s, cfg.q_dim)
    out = proj("o_proj", o, params["wo"][layer])
    return out, new_kv


def mlp_block(proj, params, layer, x):
    g = proj("gate_proj", x, params["wg"][layer])
    u = proj("up_proj", x, params["wu"][layer])
    h = jax.nn.silu(g) * u
    return proj("down_proj", h, params["wd"][layer])


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def forward(cfg: ModelConfig, params: dict, tokens, *, variant="dense",
            nm=None, aux=None, qparams=None, use_pallas=False,
            return_kv=False, pos=None):
    """Prefill forward: tokens [B, S] int32 -> logits [B, S, V].

    With ``return_kv`` also returns stacked KV ([L, B, S, Hkv, Dh] x2) for
    handing off to the decode executable (the paper's pipeline: sparse
    prefill feeds a dense decode through the KV cache).
    """
    b, s = tokens.shape
    if pos is None:
        pos = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    x = params["embed"][tokens]
    kvs = []
    for layer in range(cfg.n_layers):
        proj = Projector(cfg, variant, use_pallas, nm=nm, aux=aux,
                         qparams=qparams, layer=layer)
        h = rmsnorm(x, params["ln_attn"][layer], cfg.rmsnorm_eps)
        a, kv = attention_block(cfg, proj, params, layer, h, pos,
                                use_pallas=use_pallas)
        x = x + a
        h = rmsnorm(x, params["ln_mlp"][layer], cfg.rmsnorm_eps)
        x = x + mlp_block(proj, params, layer, h)
        kvs.append(kv)
    x = rmsnorm(x, params["ln_final"], cfg.rmsnorm_eps)
    logits = jnp.dot(x, params["unembed"])
    if return_kv:
        ks = jnp.stack([kv[0] for kv in kvs])  # [L, B, S, Hkv, Dh]
        vs = jnp.stack([kv[1] for kv in kvs])
        return logits, ks, vs
    return logits


def decode_step(cfg: ModelConfig, params: dict, token, pos, k_cache,
                v_cache, kv_len, *, variant="dense", qparams=None,
                use_pallas=False):
    """Single-token decode: token [B] int32, pos [B] int32,
    k/v_cache [L, B, C, Hkv, Dh], kv_len [B] (valid cache length incl. this
    token). Returns (logits [B, V], k_cache', v_cache').

    Decode is always *dense* (the paper confines N:M sparsity to prefill —
    decode is memory-bound and batch-1 GEMV gains nothing from N:M compute
    sparsity on this substrate).
    """
    b = token.shape[0]
    tokens = token[:, None]
    pos2 = pos[:, None]
    x = params["embed"][tokens]
    new_ks, new_vs = [], []
    for layer in range(cfg.n_layers):
        proj = Projector(cfg, variant, use_pallas, qparams=qparams,
                         layer=layer)
        h = rmsnorm(x, params["ln_attn"][layer], cfg.rmsnorm_eps)
        a, (ck, cv) = attention_block(
            cfg, proj, params, layer, h, pos2,
            kv_cache=(k_cache[layer], v_cache[layer]), kv_len=kv_len,
            use_pallas=False)
        x = x + a
        h = rmsnorm(x, params["ln_mlp"][layer], cfg.rmsnorm_eps)
        x = x + mlp_block(proj, params, layer, h)
        new_ks.append(ck)
        new_vs.append(cv)
    x = rmsnorm(x, params["ln_final"], cfg.rmsnorm_eps)
    logits = jnp.dot(x[:, 0], params["unembed"])
    return logits, jnp.stack(new_ks), jnp.stack(new_vs)


def loss_fn(cfg: ModelConfig, params: dict, tokens):
    """Packed next-token cross-entropy (training path, ref kernels)."""
    logits = forward(cfg, params, tokens)  # [B, S, V]
    targets = tokens[:, 1:]
    lp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    ll = jnp.take_along_axis(lp, targets[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)
