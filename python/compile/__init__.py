"""Build-time python package for the Amber Pruner reproduction.

Everything in here runs ONCE at `make artifacts` time:

  * Layer-1 Pallas kernels (``kernels/``) — the N:M pruning / SpMM /
    quantized-matmul compute hot-spots, checked against pure-jnp oracles.
  * Layer-2 JAX model (``model.py`` / ``model_moe.py``) — LLaMA-like and
    MoE transformers whose prefill path calls the Layer-1 kernels.
  * The Amber Pruner algorithms (``amber/``) — scoring, sensitivity
    analysis, SmoothQuant / Outstanding-sparse, W8A8 PTQ and the weight
    sparsity baselines.
  * ``train.py`` — trains the tiny models on a structured synthetic corpus
    so activation statistics are real, not faked.
  * ``aot.py`` — lowers every model variant to HLO *text* and emits the
    weights / manifest / eval datasets consumed by the rust runtime.

Python is never imported on the rust request path.
"""
