//! Prefix-cache fork-parity suite (ISSUE 6): serving a request whose
//! leading tokens come from cached KV must be **bitwise identical** to
//! cold-prefilling the whole prompt — at the runtime level
//! (`prefill_packed_prefixed` vs `prefill_packed` at every split point,
//! across every sparsity config and W8A8), through the trait's default
//! recompute-and-slice path, and end to end through the serving engine
//! (warm responses == cold responses, hit metrics moving, eviction under
//! block pressure never corrupting results).

use std::collections::HashMap;
use std::sync::atomic::Ordering;
use std::sync::mpsc::channel;
use std::sync::Arc;

use amber_pruner::coordinator::batcher::routing;
use amber_pruner::coordinator::request::{Request, SparsityConfig};
use amber_pruner::coordinator::scheduler::{
    Engine as ServeEngine, EngineConfig,
};
use amber_pruner::metrics::EngineMetrics;
use amber_pruner::runtime::{
    DecodeOut, Engine, Manifest, ModelSpec, NativeEngine, PrefillOut,
    PrefixedPrompt,
};
use amber_pruner::util::rng::Rng;
use anyhow::Result;

const MODEL: &str = "tiny-lm-a";
// tiny-lm geometry (ModelSpec::tiny)
const L: usize = 2;
const KVD: usize = 16;

fn prompt(rng: &mut Rng, len: usize) -> Vec<i32> {
    (0..len).map(|_| 1 + rng.below(300) as i32).collect()
}

/// Rows `lo..hi` of a `[L, total, KVD]` packed cache, per layer.
fn slice_rows(c: &[f32], total: usize, lo: usize, hi: usize) -> Vec<f32> {
    let mut out = Vec::with_capacity(L * (hi - lo) * KVD);
    for l in 0..L {
        let at = (l * total + lo) * KVD;
        out.extend_from_slice(&c[at..at + (hi - lo) * KVD]);
    }
    out
}

fn warm_req(prompt: &[i32], cold_k: &[f32], cold_v: &[f32], off: usize)
            -> PrefixedPrompt {
    let total = prompt.len();
    PrefixedPrompt {
        tokens: prompt.to_vec(),
        cached_len: off,
        prefix_k: slice_rows(cold_k, total, 0, off),
        prefix_v: slice_rows(cold_v, total, 0, off),
    }
}

/// The headline contract at the runtime layer: for every sparsity config
/// (incl. W8A8) and every split point, prefilling only the suffix over
/// cached prefix K/V reproduces the cold run's suffix logits and K/V
/// bitwise.
#[test]
fn forked_prefix_prefill_is_bitwise_cold_at_every_split() {
    let mut rng = Rng::new(41);
    let p = prompt(&mut rng, 24);
    let total = p.len();
    for cfg_s in ["dense", "2:4:ls", "4:8:naive", "8:16:all", "2:4:ls+sq"]
    {
        let cfg = SparsityConfig::parse(cfg_s).unwrap();
        let (art, _, files) = routing(MODEL, 64, &cfg);
        let refs: Vec<&str> = files.iter().map(|f| f.as_str()).collect();
        let mut e = NativeEngine::synthetic(vec![ModelSpec::tiny(MODEL)]);
        let bind = e.bind(&art, &refs).unwrap();
        let cold = e
            .prefill_packed(&art, &bind, std::slice::from_ref(&p))
            .unwrap();
        assert_eq!(cold.lens, vec![total]);
        for off in 1..total {
            let req = warm_req(&p, &cold.k_cache, &cold.v_cache, off);
            let warm = e
                .prefill_packed_prefixed(
                    &art,
                    &bind,
                    std::slice::from_ref(&req),
                )
                .unwrap();
            assert_eq!(warm.lens, vec![total - off], "{cfg_s} split {off}");
            assert_eq!(
                warm.logits[..],
                cold.logits[off * cold.vocab..],
                "{cfg_s}: suffix logits diverged at split {off}"
            );
            assert_eq!(
                warm.k_cache,
                slice_rows(&cold.k_cache, total, off, total),
                "{cfg_s}: suffix K diverged at split {off}"
            );
            assert_eq!(
                warm.v_cache,
                slice_rows(&cold.v_cache, total, off, total),
                "{cfg_s}: suffix V diverged at split {off}"
            );
            assert_eq!(warm.padded_tokens, 0, "native path computes \
                       exactly the suffix rows");
        }
    }
}

/// Mixed batches: warm requests (at different splits) packed together
/// with cold ones are all independent — each row matches its own
/// single-request cold reference.
#[test]
fn mixed_warm_and_cold_requests_pack_independently() {
    let mut rng = Rng::new(43);
    let prompts: Vec<Vec<i32>> = [17usize, 24, 9]
        .iter()
        .map(|&l| prompt(&mut rng, l))
        .collect();
    let cfg = SparsityConfig::parse("2:4:ls").unwrap();
    let (art, _, files) = routing(MODEL, 64, &cfg);
    let refs: Vec<&str> = files.iter().map(|f| f.as_str()).collect();
    let mut e = NativeEngine::synthetic(vec![ModelSpec::tiny(MODEL)]);
    let bind = e.bind(&art, &refs).unwrap();
    let colds: Vec<_> = prompts
        .iter()
        .map(|p| {
            e.prefill_packed(&art, &bind, std::slice::from_ref(p))
                .unwrap()
        })
        .collect();
    // request 0 warm at split 5, request 1 cold, request 2 warm at 8
    let reqs = vec![
        warm_req(&prompts[0], &colds[0].k_cache, &colds[0].v_cache, 5),
        PrefixedPrompt {
            tokens: prompts[1].clone(),
            cached_len: 0,
            prefix_k: Vec::new(),
            prefix_v: Vec::new(),
        },
        warm_req(&prompts[2], &colds[2].k_cache, &colds[2].v_cache, 8),
    ];
    let out = e.prefill_packed_prefixed(&art, &bind, &reqs).unwrap();
    assert_eq!(out.lens, vec![17 - 5, 24, 9 - 8]);
    let mut at = 0usize;
    for (i, (cold, off)) in colds.iter().zip([5usize, 0, 8]).enumerate() {
        let rows = prompts[i].len() - off;
        assert_eq!(
            out.logits[at * out.vocab..(at + rows) * out.vocab],
            cold.logits[off * cold.vocab..],
            "request {i} logits"
        );
        at += rows;
    }
}

/// Wraps the native engine but hides its packed/prefixed overrides, so
/// calls fall through to the trait defaults (pad-and-gather packed
/// prefill, recompute-and-slice prefixed prefill — the static-shape
/// PJRT route). The defaults must agree bitwise with the native
/// overrides; only the padded/recomputed accounting differs.
struct DefaultPrefixed(NativeEngine);

impl Engine for DefaultPrefixed {
    fn platform(&self) -> String {
        self.0.platform()
    }
    fn manifest(&self) -> &Manifest {
        self.0.manifest()
    }
    fn load_artifact(&mut self, name: &str) -> Result<f64> {
        self.0.load_artifact(name)
    }
    fn bind(&mut self, artifact: &str, files: &[&str]) -> Result<String> {
        self.0.bind(artifact, files)
    }
    fn prefill(
        &mut self,
        artifact: &str,
        binding: &str,
        tokens: &[i32],
    ) -> Result<PrefillOut> {
        self.0.prefill(artifact, binding, tokens)
    }
    fn decode(
        &mut self,
        artifact: &str,
        binding: &str,
        token: &[i32],
        pos: &[i32],
        k_cache: &[f32],
        v_cache: &[f32],
        kv_len: &[i32],
    ) -> Result<DecodeOut> {
        self.0
            .decode(artifact, binding, token, pos, k_cache, v_cache, kv_len)
    }
}

#[test]
fn default_prefixed_path_matches_native_override() {
    let mut rng = Rng::new(47);
    let p = prompt(&mut rng, 21);
    let total = p.len();
    for cfg_s in ["dense", "2:4:ls+sq"] {
        let cfg = SparsityConfig::parse(cfg_s).unwrap();
        let (art, _, files) = routing(MODEL, 64, &cfg);
        let refs: Vec<&str> = files.iter().map(|f| f.as_str()).collect();
        let mut native =
            NativeEngine::synthetic(vec![ModelSpec::tiny(MODEL)]);
        let nb = native.bind(&art, &refs).unwrap();
        let cold = native
            .prefill_packed(&art, &nb, std::slice::from_ref(&p))
            .unwrap();
        let mut dflt = DefaultPrefixed(NativeEngine::synthetic(vec![
            ModelSpec::tiny(MODEL),
        ]));
        let db = dflt.bind(&art, &refs).unwrap();
        for off in [1usize, 7, 8, 16, total - 1] {
            let req = warm_req(&p, &cold.k_cache, &cold.v_cache, off);
            let a = native
                .prefill_packed_prefixed(
                    &art,
                    &nb,
                    std::slice::from_ref(&req),
                )
                .unwrap();
            let b = dflt
                .prefill_packed_prefixed(
                    &art,
                    &db,
                    std::slice::from_ref(&req),
                )
                .unwrap();
            assert_eq!(a.lens, b.lens, "{cfg_s} split {off}");
            assert_eq!(a.logits, b.logits, "{cfg_s} split {off} logits");
            assert_eq!(a.k_cache, b.k_cache, "{cfg_s} split {off} K");
            assert_eq!(a.v_cache, b.v_cache, "{cfg_s} split {off} V");
            // the default recomputes the cached rows and says so
            assert!(
                b.padded_tokens >= off,
                "{cfg_s} split {off}: default path must report its \
                 {off} recomputed prefix rows, got {}",
                b.padded_tokens
            );
        }
    }
}

fn mk_req(id: u64, shared: &[i32], suffix_seed: u64, cfg: &str) -> Request {
    let mut r = Rng::new(suffix_seed);
    let mut p = shared.to_vec();
    p.extend((0..9).map(|_| 1 + r.below(300) as i32));
    Request {
        id,
        prompt: p,
        max_new_tokens: 4,
        config: SparsityConfig::parse(cfg).unwrap(),
        deadline_ticks: 0,
    }
}

/// Serve a two-wave shared-prefix workload: wave 1 seeds the cache,
/// wave 2 (same 32-token prefix, divergent suffixes) reuses it. Returns
/// the response token map and the metrics.
fn serve_two_waves(
    prefix_cache: bool,
) -> (HashMap<u64, Vec<i32>>, Arc<EngineMetrics>) {
    let metrics = Arc::new(EngineMetrics::new());
    let mut cfg = EngineConfig::new(MODEL);
    cfg.pool_threads = 1;
    cfg.prefix_cache = prefix_cache;
    let mut engine = ServeEngine::new(
        Box::new(NativeEngine::tiny()),
        cfg,
        Arc::clone(&metrics),
    )
    .unwrap();
    let (reply_tx, reply_rx) = channel();
    let mut rng = Rng::new(29);
    let shared = prompt(&mut rng, 32); // 2 full DEFAULT_BLOCK blocks
    engine.submit(mk_req(0, &shared, 100, "2:4:ls"), reply_tx.clone());
    while engine.step().unwrap() {}
    for id in 1..4u64 {
        engine.submit(
            mk_req(id, &shared, 100 + id, "2:4:ls"),
            reply_tx.clone(),
        );
    }
    while engine.step().unwrap() {}
    drop(reply_tx);
    engine.kv_invariants().unwrap();
    (reply_rx.try_iter().map(|r| (r.id, r.tokens)).collect(), metrics)
}

/// End to end through the scheduler: warm (forked-prefix) serving
/// produces bitwise-identical tokens to a prefix-cache-disabled engine,
/// while the hit metrics move exactly as the block math predicts.
#[test]
fn warm_serving_matches_cold_bitwise_and_reports_hits() {
    let (cold, mc) = serve_two_waves(false);
    let (warm, mw) = serve_two_waves(true);
    assert_eq!(cold.len(), 4, "every request completes");
    assert_eq!(warm, cold, "forked-prefix tokens must match cold");
    assert_eq!(mc.prefix_hit_blocks.load(Ordering::Relaxed), 0);
    assert_eq!(mc.prefix_hit_tokens.load(Ordering::Relaxed), 0);
    // 3 warm requests × the 32-token (2-block) shared prefix
    assert_eq!(mw.prefix_hit_blocks.load(Ordering::Relaxed), 6);
    assert_eq!(mw.prefix_hit_tokens.load(Ordering::Relaxed), 96);
    assert!(mw.prefix_cache_nodes.load(Ordering::Relaxed) > 0);
    assert_eq!(mw.prefix_evictions.load(Ordering::Relaxed), 0);
}

/// Divergence at every block offset: requests sharing `off` tokens with
/// the cached donor must each match their own cold run — the partial
/// boundary block is copy-on-written, never corrupted, at every offset
/// including the block-aligned and the fully-shared cases.
#[test]
fn divergence_at_every_offset_matches_cold() {
    let mut rng = Rng::new(53);
    let donor = prompt(&mut rng, 33); // 2 full blocks + 1
    let serve = |prefix_cache: bool,
                 probes: &[Vec<i32>]|
     -> HashMap<u64, Vec<i32>> {
        let metrics = Arc::new(EngineMetrics::new());
        let mut cfg = EngineConfig::new(MODEL);
        cfg.pool_threads = 1;
        cfg.prefix_cache = prefix_cache;
        let mut engine = ServeEngine::new(
            Box::new(NativeEngine::tiny()),
            cfg,
            Arc::clone(&metrics),
        )
        .unwrap();
        let (reply_tx, reply_rx) = channel();
        engine.submit(
            Request {
                id: 0,
                prompt: donor.clone(),
                max_new_tokens: 2,
                config: SparsityConfig::parse("dense").unwrap(),
                deadline_ticks: 0,
            },
            reply_tx.clone(),
        );
        while engine.step().unwrap() {}
        for (i, p) in probes.iter().enumerate() {
            engine.submit(
                Request {
                    id: 1 + i as u64,
                    prompt: p.clone(),
                    max_new_tokens: 2,
                    config: SparsityConfig::parse("dense").unwrap(),
                    deadline_ticks: 0,
                },
                reply_tx.clone(),
            );
            while engine.step().unwrap() {}
        }
        drop(reply_tx);
        engine.kv_invariants().unwrap();
        reply_rx.try_iter().map(|r| (r.id, r.tokens)).collect()
    };
    // probe i shares exactly i leading tokens with the donor, then
    // diverges; probe 33 is the donor verbatim (fully cached prompt —
    // admission must CoW the boundary block to recompute the last row)
    let mut probes: Vec<Vec<i32>> = Vec::new();
    for off in 0..=donor.len() {
        let mut p = donor[..off].to_vec();
        if off < donor.len() {
            p.push(donor[off] % 300 + 1); // diverge here
            p.extend_from_slice(&donor[off + 1..]);
        }
        probes.push(p);
    }
    let cold = serve(false, &probes);
    let warm = serve(true, &probes);
    assert_eq!(cold.len(), probes.len() + 1);
    assert_eq!(warm, cold, "divergence sweep must be bitwise cold");
}

/// Block pressure: a stream of distinct long prompts overflows what the
/// cache may retain; nodes are evicted (metric moves), admissions never
/// starve, and re-requesting the first prompt still completes with the
/// same tokens it got the first time.
#[test]
fn eviction_under_pressure_then_readmit_stays_correct() {
    let metrics = Arc::new(EngineMetrics::new());
    let mut cfg = EngineConfig::new(MODEL);
    cfg.pool_threads = 1;
    let mut engine = ServeEngine::new(
        Box::new(NativeEngine::tiny()),
        cfg,
        Arc::clone(&metrics),
    )
    .unwrap();
    let (reply_tx, reply_rx) = channel();
    let mut rng = Rng::new(57);
    let prompts: Vec<Vec<i32>> =
        (0..20).map(|_| prompt(&mut rng, 60)).collect();
    for (i, p) in prompts.iter().enumerate() {
        engine.submit(
            Request {
                id: i as u64,
                prompt: p.clone(),
                max_new_tokens: 8,
                config: SparsityConfig::parse("dense").unwrap(),
                deadline_ticks: 0,
            },
            reply_tx.clone(),
        );
        while engine.step().unwrap() {}
    }
    assert!(
        metrics.prefix_evictions.load(Ordering::Relaxed) > 0,
        "20 distinct 60-token prompts must overflow the 48-block pool"
    );
    // readmit the very first prompt; its nodes may or may not have
    // survived eviction — either way the tokens must be what request 0
    // got
    engine.submit(
        Request {
            id: 1000,
            prompt: prompts[0].clone(),
            max_new_tokens: 8,
            config: SparsityConfig::parse("dense").unwrap(),
            deadline_ticks: 0,
        },
        reply_tx.clone(),
    );
    while engine.step().unwrap() {}
    drop(reply_tx);
    engine.kv_invariants().unwrap();
    let all: HashMap<u64, Vec<i32>> =
        reply_rx.try_iter().map(|r| (r.id, r.tokens)).collect();
    assert_eq!(all.len(), 21, "every request completes under pressure");
    assert_eq!(all[&1000], all[&0], "readmitted prompt must reproduce");
}
