//! Integration tests over the native execution engine (no artifacts
//! directory needed — the synthetic inventory serves them) + property
//! tests on coordinator invariants that need no runtime at all.

use std::sync::mpsc::channel;
use std::sync::Arc;

use amber_pruner::coordinator::batcher::{routing, ConfigKey, PrefillQueues};
use amber_pruner::coordinator::kv::KvPages;
use amber_pruner::coordinator::request::{Request, SparsityConfig, Tracked};
use amber_pruner::coordinator::scheduler::{Engine, EngineConfig};
use amber_pruner::metrics::EngineMetrics;
use amber_pruner::runtime::{Engine as ExecEngine, NativeEngine};
use amber_pruner::sparsity::mask;
use amber_pruner::sparsity::policy::Setting;
use amber_pruner::testutil::prop::{prop_check, Gen};
use amber_pruner::util::rng::Rng;

// -------------------------------------------------------- native engine

#[test]
fn synthetic_inventory_prefills() {
    let mut rt = NativeEngine::tiny();
    let art = "tiny-lm-a.prefill64.dense";
    assert!(rt.manifest().artifacts.contains_key(art));
    let binding = rt.bind(art, &["tiny-lm-a.atw"]).unwrap();
    let meta = rt.manifest().artifact(art).unwrap().clone();
    let tokens: Vec<i32> =
        (0..meta.batch * meta.seq).map(|i| 1 + (i as i32 % 300)).collect();
    let out = rt.prefill(art, &binding, &tokens).unwrap();
    assert_eq!(out.vocab, 384);
    assert!(out.logits.iter().all(|v| v.is_finite()));
}

#[test]
fn sparse_artifact_with_dense_aux_matches_dense_artifact() {
    // keep_dense == 1 everywhere must reproduce the dense graph exactly
    // (the contract that lets one nm executable serve dense requests).
    let mut rt = NativeEngine::tiny();
    let nm_art = "tiny-lm-a.prefill64.nm2_4";
    let b_dense = rt
        .bind("tiny-lm-a.prefill64.dense", &["tiny-lm-a.atw"])
        .unwrap();
    let b_nm = rt
        .bind(nm_art, &["tiny-lm-a.atw", "tiny-lm-a.aux_dense.atw"])
        .unwrap();
    let meta = rt.manifest().artifact(nm_art).unwrap().clone();
    let tokens: Vec<i32> =
        (0..meta.batch * meta.seq).map(|i| 1 + (i as i32 % 300)).collect();
    let a = rt
        .prefill("tiny-lm-a.prefill64.dense", &b_dense, &tokens)
        .unwrap();
    let c = rt.prefill(nm_art, &b_nm, &tokens).unwrap();
    let max_diff = a
        .logits
        .iter()
        .zip(c.logits.iter())
        .map(|(x, y)| (x - y).abs())
        .fold(0f32, f32::max);
    assert!(max_diff < 2e-3, "dense-aux nm differs from dense: {max_diff}");
}

#[test]
fn engine_serves_mixed_sparsity_requests() {
    let rt = Box::new(NativeEngine::tiny());
    let metrics = Arc::new(EngineMetrics::new());
    let mut engine = Engine::new(
        rt,
        EngineConfig::new("tiny-lm-a"),
        Arc::clone(&metrics),
    )
    .unwrap();
    let (tx, rx) = channel();
    let (reply_tx, reply_rx) = channel();
    let configs = [
        SparsityConfig::dense(),
        SparsityConfig {
            setting: Setting::LayerSkip,
            nm: Some((2, 4)),
            quantized: false,
        },
        SparsityConfig {
            setting: Setting::Naive,
            nm: Some((2, 4)),
            quantized: false,
        },
    ];
    let mut rng = Rng::new(3);
    for id in 0..12u64 {
        let len = 8 + rng.usize_below(24);
        let prompt: Vec<i32> =
            (0..len).map(|_| 1 + rng.below(300) as i32).collect();
        tx.send(amber_pruner::coordinator::scheduler::EngineMsg::Submit(
            Request {
                id,
                prompt,
                max_new_tokens: 3,
                config: configs[(id % 3) as usize],
                deadline_ticks: 0,
            },
            reply_tx.clone(),
        ))
        .unwrap();
    }
    drop(tx);
    drop(reply_tx);
    engine.run(rx).unwrap();
    let responses: Vec<_> = reply_rx.try_iter().collect();
    assert_eq!(responses.len(), 12);
    for r in &responses {
        assert!(!r.tokens.is_empty());
        assert!(r.tokens.len() <= 3);
        assert!(r.ttft_secs >= 0.0 && r.e2e_secs >= r.ttft_secs);
    }
    engine.kv_invariants().unwrap();
    // sparse requests actually went through the pruned path, validly
    let audit = engine.audit().expect("native engine audits");
    assert!(audit.pruned_matmuls > 0);
    assert_eq!(audit.nm_violations, 0);
}

// ------------------------------------------- property tests (no runtime)

#[test]
fn prop_nm_mask_is_exact_and_scored() {
    prop_check("nm-mask-exact", 200, |rng, size| {
        let m = *Gen::choice(rng, &[4usize, 8, 16]);
        let n = m / 2;
        let groups = 1 + size % 8;
        let d = groups * m;
        let x = Gen::f32_vec(rng, d, 2.0);
        let scale: Vec<f32> =
            (0..d).map(|_| rng.f64() as f32 * 3.0 + 0.1).collect();
        let pruned = mask::nm_prune(&x, &scale, n, m);
        if !mask::validate_nm(&pruned, n, m) {
            return Err(format!("invalid N:M for n={n} m={m}"));
        }
        // kept values are exactly the original values
        for (a, b) in x.iter().zip(pruned.iter()) {
            if *b != 0.0 && a != b {
                return Err("pruning altered a kept value".into());
            }
        }
        // exactly n survivors per group when x has no zeros
        if x.iter().all(|v| *v != 0.0) {
            for g in pruned.chunks_exact(m) {
                let nz = g.iter().filter(|v| **v != 0.0).count();
                if nz != n {
                    return Err(format!("group has {nz} != {n} nonzeros"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_kv_pages_never_leak_or_alias() {
    prop_check("kv-pages", 120, |rng, size| {
        let block = *Gen::choice(rng, &[2usize, 4, 8]);
        let n_blocks = 4 + size % 12;
        let mut kv = KvPages::new(2, n_blocks, block, 1, 4, 16);
        // packed prefill cache [L=2, total=16, kvd=4]
        let pre = vec![1.0f32; 2 * 16 * 4];
        let mut active: Vec<u64> = Vec::new();
        let mut next_id = 0u64;
        for _ in 0..size * 4 {
            let vl = 1 + rng.usize_below(8);
            let reserve = (vl + rng.usize_below(8)).min(16);
            if rng.bool(0.6) && kv.can_admit(reserve) {
                kv.admit_packed(next_id, &pre, &pre, 0, 16, vl, reserve)
                    .map_err(|e| e.to_string())?;
                active.push(next_id);
                next_id += 1;
            } else if !active.is_empty() {
                let i = rng.usize_below(active.len());
                let id = active.swap_remove(i);
                kv.release(id).map_err(|e| e.to_string())?;
            }
            kv.check_invariants().map_err(|e| e.to_string())?;
            let owned: usize = active
                .iter()
                .map(|id| kv.table(*id).map(|t| t.len()).unwrap_or(0))
                .sum();
            if kv.free_blocks() != n_blocks - owned {
                return Err("free-block accounting drifted".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_batcher_conserves_and_groups_requests() {
    prop_check("batcher", 100, |rng, size| {
        let mut q = PrefillQueues::new(4, 0.0);
        let n = size * 3 + 1;
        let configs = [
            SparsityConfig::dense(),
            SparsityConfig::amber(2, 4),
            SparsityConfig::outstanding(8, 16),
        ];
        let mut pushed = std::collections::HashMap::new();
        for id in 0..n as u64 {
            let cfg = configs[rng.usize_below(3)];
            let (p, _, _) = routing("m", 64, &cfg);
            *pushed.entry(p.clone()).or_insert(0usize) += 1;
            let (tx, _rx) = channel();
            q.push(
                ConfigKey(p),
                Tracked {
                    req: Request {
                        id,
                        prompt: vec![1],
                        max_new_tokens: 1,
                        config: cfg,
                        deadline_ticks: 0,
                    },
                    arrived: std::time::Instant::now(),
                    first_token_at: None,
                    generated: vec![],
                    reply: tx,
                    retries: 0,
                    deadline_at: None,
                },
            );
        }
        let mut drained = std::collections::HashMap::new();
        let now = std::time::Instant::now();
        while let Some((key, batch)) = q.next_batch(8, true, now) {
            if batch.is_empty() || batch.len() > 4 {
                return Err(format!("bad batch size {}", batch.len()));
            }
            // all requests in a batch route to the same artifact
            for t in &batch {
                let (p, _, _) = routing("m", 64, &t.req.config);
                if p != key.0 {
                    return Err("mixed-config batch".into());
                }
            }
            *drained.entry(key.0).or_insert(0usize) += batch.len();
        }
        if pushed != drained {
            return Err(format!("lost requests: {pushed:?} vs {drained:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_sparsity_config_label_roundtrip() {
    prop_check("config-roundtrip", 100, |rng, _| {
        let cfg = SparsityConfig {
            setting: *Gen::choice(
                rng,
                &[Setting::Naive, Setting::LayerSkip, Setting::All],
            ),
            nm: if rng.bool(0.2) {
                None
            } else {
                Some(*Gen::choice(rng, &[(2, 4), (4, 8), (8, 16)]))
            },
            quantized: rng.bool(0.5),
        };
        let label = cfg.label();
        let parsed = SparsityConfig::parse(&label)
            .ok_or(format!("unparseable label {label}"))?;
        // nm + quantized must survive; setting collapses for dense
        if parsed.nm != cfg.nm || parsed.quantized != cfg.quantized {
            return Err(format!("roundtrip mismatch: {label}"));
        }
        if cfg.nm.is_some() && parsed.setting != cfg.setting {
            return Err(format!("setting mismatch: {label}"));
        }
        Ok(())
    });
}
