//! Golden-parity suite for the batched parallel prefill pipeline
//! (ISSUE 2): the block-compressed `NmCompressedBatch` SpMM and the
//! token-packed prefill path must be *bit-identical* to the pre-refactor
//! per-row / per-request execution, across every N:M ratio and thread
//! pool width.

mod common;

use std::sync::Arc;

use amber_pruner::exec::ThreadPool;
use amber_pruner::runtime::{
    DecodeOut, Engine, Manifest, ModelSpec, NativeEngine, PrefillOut,
};
use amber_pruner::sparsity::spmm::{NmCompressed, NmCompressedBatch};
use amber_pruner::util::rng::Rng;
use anyhow::Result;
use common::{prompt, sequential_logits};

const RATIOS: [(usize, usize); 3] = [(2, 4), (4, 8), (8, 16)];

fn rand_mat(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal() as f32).collect()
}

// ---------------------------------------------------- kernel-level parity

#[test]
fn batched_spmm_bit_identical_to_per_row_across_ratios_and_pools() {
    let mut rng = Rng::new(42);
    for &(n, m) in &RATIOS {
        for &t in &[1usize, 7, 32, 65] {
            let (din, dout) = (2 * m * 2, 24); // divisible by every m
            let x = rand_mat(&mut rng, t * din);
            let w = rand_mat(&mut rng, din * dout);
            let scale: Vec<f32> =
                (0..din).map(|_| rng.f64() as f32 + 0.1).collect();
            for sc in [&[][..], &scale[..]] {
                let per_row = NmCompressed::compress(&x, t, din, sc, n, m);
                let golden = per_row.matmul(&w, dout);
                for &block_rows in &[1usize, 8, 32] {
                    let batch = NmCompressedBatch::compress(
                        &x, t, din, sc, n, m, block_rows,
                    );
                    // identical compressed content
                    assert_eq!(
                        batch.decompress(),
                        per_row.decompress(),
                        "{n}:{m} t={t} block={block_rows}"
                    );
                    // serial tiled matmul
                    assert_eq!(
                        batch.matmul(&w, dout),
                        golden,
                        "{n}:{m} t={t} block={block_rows} serial"
                    );
                    // pool-parallel tiled matmul, widths 1/2/4
                    let wa = Arc::new(w.clone());
                    for width in [1usize, 2, 4] {
                        let pool = ThreadPool::new(width);
                        assert_eq!(
                            batch.matmul_parallel(&wa, dout, &pool),
                            golden,
                            "{n}:{m} t={t} block={block_rows} pool={width}"
                        );
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------- engine-level parity

fn engine(threads: usize) -> NativeEngine {
    NativeEngine::synthetic(vec![ModelSpec::tiny("tiny-lm-a")])
        .with_parallelism(threads)
}

#[test]
fn packed_multi_request_prefill_matches_sequential_prefill() {
    let mut rng = Rng::new(7);
    let lens = [5usize, 64, 17, 33, 1];
    let prompts: Vec<Vec<i32>> =
        lens.iter().map(|&l| prompt(&mut rng, l)).collect();
    for variant in ["dense", "nm2_4", "nm4_8", "nm8_16"] {
        let art = format!("tiny-lm-a.prefill64.{variant}");
        let files: Vec<&str> = if variant == "dense" {
            vec!["tiny-lm-a.atw"]
        } else {
            vec!["tiny-lm-a.atw", "tiny-lm-a.aux_ls.atw"]
        };
        for threads in [1usize, 2, 4] {
            let mut e = engine(threads);
            let bind = e.bind(&art, &files).unwrap();
            let golden =
                sequential_logits(&mut e, &art, &bind, 8, 64, &prompts);
            let packed = e.prefill_packed(&art, &bind, &prompts).unwrap();
            assert_eq!(packed.lens, lens.to_vec());
            let v = packed.vocab;
            for (i, g) in golden.iter().enumerate() {
                let start = packed.row_start(i);
                let got =
                    &packed.logits[start * v..(start + lens[i]) * v];
                assert_eq!(
                    got, &g[..],
                    "{art} threads={threads} request {i} diverged"
                );
            }
        }
    }
}

#[test]
fn packed_sq_prefill_close_to_f32_reference() {
    // W8A8 quantizes activations with PER-TOKEN scales, so a request's
    // quantized logits depend only on its own rows — never on its
    // batchmates. sq packing parity is therefore an EQUALITY pin:
    // packed sq must be bitwise identical to the sequential sq
    // reference (one request at a time through the padded artifact).
    // The quantization-drift bound against the f32 reference that the
    // unit suite (`quantized_path_close_to_f32`) enforces for padded sq
    // is kept as a sanity net — a wrong activation scale on the packed
    // path blows straight through it.
    let mut rng = Rng::new(31);
    let lens = [9usize, 33, 64];
    let prompts: Vec<Vec<i32>> =
        lens.iter().map(|&l| prompt(&mut rng, l)).collect();
    let mut e = engine(1);
    // f32 reference: sequential dense prefill (bitwise == packed f32,
    // proven by the fp parity test above)
    let fp_art = "tiny-lm-a.prefill64.dense";
    let fp_bind = e.bind(fp_art, &["tiny-lm-a.atw"]).unwrap();
    let golden = sequential_logits(&mut e, fp_art, &fp_bind, 8, 64, &prompts);
    let sq_art = "tiny-lm-a.prefill64.sq";
    let sq_bind = e.bind(sq_art, &["tiny-lm-a.sq.atw"]).unwrap();
    let golden_sq =
        sequential_logits(&mut e, sq_art, &sq_bind, 8, 64, &prompts);
    let packed = e.prefill_packed(sq_art, &sq_bind, &prompts).unwrap();
    let v = packed.vocab;
    for (i, g) in golden.iter().enumerate() {
        let start = packed.row_start(i);
        let got = &packed.logits[start * v..(start + lens[i]) * v];
        // the equality pin: per-token scales make packing bitwise
        assert_eq!(
            got,
            &golden_sq[i][..],
            "sq request {i}: packed != sequential (per-token scales \
             must make sq packing bitwise)"
        );
        let max_abs = g.iter().fold(0.0f32, |a, &b| a.max(b.abs()));
        let diff = got
            .iter()
            .zip(g.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(
            diff < max_abs.max(1.0) * 0.5,
            "sq request {i} drifted too far from f32: {diff} vs absmax \
             {max_abs}"
        );
    }
}

#[test]
fn packed_prefill_identical_across_pool_widths() {
    let mut rng = Rng::new(19);
    let prompts: Vec<Vec<i32>> =
        [40usize, 64, 3, 64, 25].iter().map(|&l| prompt(&mut rng, l)).collect();
    let art = "tiny-lm-a.prefill64.nm2_4";
    let files = ["tiny-lm-a.atw", "tiny-lm-a.aux_all.atw"];
    let run = |threads: usize| {
        let mut e = engine(threads);
        let bind = e.bind(art, &files).unwrap();
        let out = e.prefill_packed(art, &bind, &prompts).unwrap();
        (out.logits, out.k_cache, out.v_cache)
    };
    let serial = run(1);
    for threads in [2usize, 4] {
        assert_eq!(run(threads), serial, "pool width {threads}");
    }
}

// ------------------------------------- default trait impl vs native path

/// Wraps the native engine but hides its `prefill_packed` override, so
/// calls fall through to the trait's default pad-chunk-and-gather
/// implementation.
struct DefaultPacked(NativeEngine);

impl Engine for DefaultPacked {
    fn platform(&self) -> String {
        self.0.platform()
    }
    fn manifest(&self) -> &Manifest {
        self.0.manifest()
    }
    fn load_artifact(&mut self, name: &str) -> Result<f64> {
        self.0.load_artifact(name)
    }
    fn bind(&mut self, artifact: &str, files: &[&str]) -> Result<String> {
        self.0.bind(artifact, files)
    }
    fn prefill(
        &mut self,
        artifact: &str,
        binding: &str,
        tokens: &[i32],
    ) -> Result<PrefillOut> {
        self.0.prefill(artifact, binding, tokens)
    }
    fn decode(
        &mut self,
        artifact: &str,
        binding: &str,
        token: &[i32],
        pos: &[i32],
        k_cache: &[f32],
        v_cache: &[f32],
        kv_len: &[i32],
    ) -> Result<DecodeOut> {
        self.0
            .decode(artifact, binding, token, pos, k_cache, v_cache, kv_len)
    }
}

#[test]
fn default_packed_impl_matches_native_packed_pipeline() {
    // 11 requests > the static batch of 8: the default impl must chunk
    // into two padded prefills and still gather the same rows the
    // native single-pass packed pipeline produces
    let mut rng = Rng::new(23);
    let prompts: Vec<Vec<i32>> = (0..11)
        .map(|i| prompt(&mut rng, 3 + (i * 7) % 60))
        .collect();
    let art = "tiny-lm-a.prefill64.nm4_8";
    let files = ["tiny-lm-a.atw", "tiny-lm-a.aux_ls.atw"];
    let mut native = engine(1);
    let nb = native.bind(art, &files).unwrap();
    let want = native.prefill_packed(art, &nb, &prompts).unwrap();
    let mut fallback = DefaultPacked(engine(1));
    let fb = fallback.bind(art, &files).unwrap();
    let got = fallback.prefill_packed(art, &fb, &prompts).unwrap();
    assert_eq!(got.lens, want.lens);
    assert_eq!(got.vocab, want.vocab);
    assert_eq!(got.logits, want.logits);
    assert_eq!(got.k_cache, want.k_cache);
    assert_eq!(got.v_cache, want.v_cache);
    // the native pipeline computes no PAD rows; the default path pads
    // two 8x64 chunks and reports exactly that cost
    assert_eq!(want.padded_tokens, 0);
    let total: usize = want.lens.iter().sum();
    assert_eq!(got.padded_tokens, 2 * 8 * 64 - total);
}
