//! Chaos suite (ISSUE 9): drive the serving engine through >= 100
//! seeded fault schedules — injected prefill/decode failures, delays,
//! KV-allocation failures and dropped replies — layered over random
//! workload mixes, tiny block pools, chunked prefill, prefix caching
//! and tick deadlines, and check the fault-tolerance contract: every
//! request gets exactly one response (minus replies deliberately
//! dropped by injection), no KV blocks leak, the loop never livelocks,
//! and every *successful* response is token-identical to an
//! undisturbed fault-free reference — retries recompute from scratch,
//! so a survived fault is invisible to the client. Deterministic
//! companions pin the no-op guarantee of a plan that never fires, the
//! exactly-one-drop accounting of a `ReplySend` injection, and the
//! panic-to-`Fatal` conversion at the `Engine::run` unwind boundary.

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::channel;
use std::sync::Arc;

use amber_pruner::coordinator::error::ErrorKind;
use amber_pruner::coordinator::fault::{
    FaultKind, FaultPlan, FaultSite, ALL_SITES,
};
use amber_pruner::coordinator::request::{Request, SparsityConfig};
use amber_pruner::coordinator::scheduler::{
    Engine, EngineConfig, EngineMsg,
};
use amber_pruner::metrics::EngineMetrics;
use amber_pruner::runtime::NativeEngine;
use amber_pruner::testutil::prop::{prop_check, Gen};
use amber_pruner::util::rng::Rng;

const MODEL: &str = "tiny-lm-a";

fn prompt(rng: &mut Rng, len: usize) -> Vec<i32> {
    (0..len).map(|_| 1 + rng.below(300) as i32).collect()
}

fn mk_engine(
    cfg: EngineConfig,
    metrics: &Arc<EngineMetrics>,
) -> Engine {
    Engine::new(
        Box::new(NativeEngine::tiny()),
        cfg,
        Arc::clone(metrics),
    )
    .unwrap()
}

/// Fault-free, deadline-free reference: one-shot prefill, ample pool,
/// no prefix cache. Successful responses under any fault schedule must
/// match this bitwise.
fn serve_reference(reqs: &[Request]) -> HashMap<u64, Vec<i32>> {
    let metrics = Arc::new(EngineMetrics::new());
    let mut cfg = EngineConfig::new(MODEL);
    cfg.pool_threads = 1;
    cfg.max_wait_secs = 0.0;
    cfg.chunk_tokens = usize::MAX;
    cfg.prefix_cache = false;
    let mut engine = mk_engine(cfg, &metrics);
    let (reply_tx, reply_rx) = channel();
    for r in reqs {
        engine.submit(r.clone(), reply_tx.clone());
    }
    while engine.step().unwrap() {}
    drop(reply_tx);
    engine.kv_invariants().unwrap();
    reply_rx.try_iter().map(|r| (r.id, r.tokens)).collect()
}

/// The headline chaos property: >= 100 seeded fault schedules over
/// randomized workloads, pools, chunk sizes and deadlines. Checks
/// exactly-one-response accounting, token parity of successful
/// responses against the fault-free reference, no block leaks, no
/// over-allocation and no livelock; the suite as a whole must actually
/// fire faults, retry transients and cancel deadlines (non-vacuity).
#[test]
fn seeded_fault_schedules_never_leak_lose_or_livelock() {
    let total_fired = AtomicU64::new(0);
    let total_retries = AtomicU64::new(0);
    let total_timeouts = AtomicU64::new(0);
    prop_check("chaos", 110, |rng, size| {
        let n = 3 + size / 4; // 3..=10 requests
        let mut reqs: Vec<Request> = Vec::new();
        for id in 0..n {
            let len = 1 + rng.usize_below(48);
            reqs.push(Request {
                id: id as u64,
                prompt: prompt(rng, len),
                max_new_tokens: 1 + rng.usize_below(5),
                config: SparsityConfig::parse(*Gen::choice(
                    rng,
                    &["dense", "2:4:ls"],
                ))
                .unwrap(),
                // ~30% run under a tight tick deadline (1..=6); the
                // rest are patient
                deadline_ticks: if rng.bool(0.3) {
                    1 + rng.below(6)
                } else {
                    0
                },
            });
        }
        // the reference run strips deadlines: it pins what the tokens
        // *would* be, and only error-free chaos responses compare
        let patient: Vec<Request> = reqs
            .iter()
            .map(|r| Request {
                deadline_ticks: 0,
                ..r.clone()
            })
            .collect();
        let golden = serve_reference(&patient);
        if golden.len() != n {
            return Err(format!(
                "reference run lost requests: {} of {n}",
                golden.len()
            ));
        }

        let metrics = Arc::new(EngineMetrics::new());
        let mut cfg = EngineConfig::new(MODEL);
        cfg.pool_threads = 1;
        cfg.max_wait_secs = 0.0;
        cfg.kv_pool_blocks = 6 + rng.usize_below(7);
        cfg.chunk_tokens =
            *Gen::choice(rng, &[16usize, 32, usize::MAX]);
        cfg.prefix_cache = rng.bool(0.5);
        cfg.fault_plan = FaultPlan::seeded(
            rng.below(u64::MAX),
            1 + rng.usize_below(6),
            1 + rng.below(25),
        );
        let mut engine = mk_engine(cfg, &metrics);
        let (reply_tx, reply_rx) = channel();

        let mut next = reqs.iter();
        let mut submitted = 0usize;
        while submitted < n {
            if rng.bool(0.6) {
                engine
                    .submit(next.next().unwrap().clone(), reply_tx.clone());
                submitted += 1;
            } else {
                engine.step().map_err(|e| format!("step: {e}"))?;
                engine
                    .kv_invariants()
                    .map_err(|e| format!("kv invariants mid-run: {e}"))?;
            }
        }
        // drain; retry backoff legitimately idles for stretches, so
        // the livelock guard allows bounded no-work runs
        let mut spins = 0usize;
        loop {
            let worked =
                engine.step().map_err(|e| format!("step: {e}"))?;
            engine
                .kv_invariants()
                .map_err(|e| format!("kv invariants mid-drain: {e}"))?;
            let pending = engine.queued_requests()
                + engine.flight_requests()
                + engine.active_requests()
                + engine.parked_requests();
            if pending == 0 {
                break;
            }
            spins = if worked { 0 } else { spins + 1 };
            if spins > 2_000 {
                return Err(format!(
                    "livelock: {pending} requests pending"
                ));
            }
        }
        drop(reply_tx);

        let responses: Vec<_> = reply_rx.try_iter().collect();
        let dropped = engine.faults().fired_reply();
        if responses.len() as u64 != n as u64 - dropped {
            return Err(format!(
                "{} responses for {n} requests ({dropped} replies \
                 dropped by injection)",
                responses.len()
            ));
        }
        let mut seen: HashSet<u64> = HashSet::new();
        for r in &responses {
            if !seen.insert(r.id) {
                return Err(format!("request {} answered twice", r.id));
            }
            if r.error.is_none()
                && golden.get(&r.id) != Some(&r.tokens)
            {
                return Err(format!(
                    "request {}: successful response diverged from \
                     the fault-free reference",
                    r.id
                ));
            }
        }
        engine
            .kv_invariants()
            .map_err(|e| format!("kv invariants: {e}"))?;
        engine.clear_prefix_cache();
        let (free, total) = engine.kv_blocks();
        if free != total {
            return Err(format!(
                "block leak: {free} free of {total} after drain"
            ));
        }
        let peak = metrics.kv_blocks_peak.load(Ordering::Relaxed);
        if peak > total as u64 {
            return Err(format!(
                "allocation exceeded capacity: peak {peak} of {total}"
            ));
        }
        total_fired
            .fetch_add(engine.faults().fired(), Ordering::Relaxed);
        total_retries.fetch_add(
            metrics.retries.load(Ordering::Relaxed),
            Ordering::Relaxed,
        );
        total_timeouts.fetch_add(
            metrics.timeouts.load(Ordering::Relaxed),
            Ordering::Relaxed,
        );
        Ok(())
    });
    // the suite must exercise the paths it claims to cover
    assert!(
        total_fired.load(Ordering::Relaxed) > 0,
        "no fault ever fired — schedules never hit a live site"
    );
    assert!(
        total_retries.load(Ordering::Relaxed) > 0,
        "no transient failure was ever retried"
    );
    assert!(
        total_timeouts.load(Ordering::Relaxed) > 0,
        "no deadline was ever cancelled"
    );
}

/// A plan whose injections never come due (far-future ticks at every
/// site) must be a perfect no-op: responses bitwise identical to the
/// fault-free reference, nothing fired, nothing counted.
#[test]
fn unfired_fault_plan_is_bitwise_invisible() {
    let mut rng = Rng::new(101);
    let reqs: Vec<Request> = (0..6u64)
        .map(|id| Request {
            id,
            prompt: prompt(&mut rng, 10 + id as usize * 7),
            max_new_tokens: 4,
            config: SparsityConfig::parse(if id % 2 == 0 {
                "dense"
            } else {
                "2:4:ls"
            })
            .unwrap(),
            deadline_ticks: 0,
        })
        .collect();
    let golden = serve_reference(&reqs);

    let metrics = Arc::new(EngineMetrics::new());
    let mut cfg = EngineConfig::new(MODEL);
    cfg.pool_threads = 1;
    cfg.max_wait_secs = 0.0;
    cfg.chunk_tokens = usize::MAX;
    cfg.prefix_cache = false;
    let mut plan = FaultPlan::none();
    for site in ALL_SITES {
        plan = plan.with(1_000_000, site, FaultKind::Fail);
    }
    cfg.fault_plan = plan;
    let mut engine = mk_engine(cfg, &metrics);
    let (reply_tx, reply_rx) = channel();
    for r in &reqs {
        engine.submit(r.clone(), reply_tx.clone());
    }
    while engine.step().unwrap() {}
    drop(reply_tx);

    let got: HashMap<u64, Vec<i32>> =
        reply_rx.try_iter().map(|r| (r.id, r.tokens)).collect();
    assert_eq!(got, golden, "an unfired plan must be invisible");
    assert_eq!(engine.faults().fired(), 0);
    assert_eq!(engine.faults().pending(), ALL_SITES.len());
    assert_eq!(metrics.faults_injected.load(Ordering::Relaxed), 0);
}

/// A `ReplySend` injection drops exactly one response: the struck
/// request still runs to completion and releases its blocks, the
/// other request's reply arrives, and the plan's reply-drop counter
/// matches the accounting chaos runs rely on.
#[test]
fn injected_reply_drop_loses_exactly_one_response() {
    let mut rng = Rng::new(103);
    // r0 completes at tick 1 (one-shot prefill + its single token),
    // which is exactly when the ReplySend injection is armed
    let r0 = Request {
        id: 0,
        prompt: prompt(&mut rng, 8),
        max_new_tokens: 1,
        config: SparsityConfig::parse("dense").unwrap(),
        deadline_ticks: 0,
    };
    let r1 = Request {
        id: 1,
        prompt: prompt(&mut rng, 8),
        max_new_tokens: 2,
        config: SparsityConfig::parse("dense").unwrap(),
        deadline_ticks: 0,
    };

    let metrics = Arc::new(EngineMetrics::new());
    let mut cfg = EngineConfig::new(MODEL);
    cfg.pool_threads = 1;
    cfg.max_wait_secs = 0.0;
    cfg.chunk_tokens = usize::MAX;
    cfg.prefix_cache = false;
    cfg.fault_plan = FaultPlan::none().with(
        1,
        FaultSite::ReplySend,
        FaultKind::Fail,
    );
    let mut engine = mk_engine(cfg, &metrics);
    let (reply_tx, reply_rx) = channel();
    engine.submit(r0, reply_tx.clone());
    engine.submit(r1, reply_tx.clone());
    while engine.step().unwrap() {}
    drop(reply_tx);

    assert_eq!(engine.faults().fired_reply(), 1);
    assert_eq!(metrics.faults_injected.load(Ordering::Relaxed), 1);
    assert_eq!(
        metrics.requests_completed.load(Ordering::Relaxed),
        2,
        "the struck request still completes server-side"
    );
    let got: Vec<_> = reply_rx.try_iter().collect();
    assert_eq!(got.len(), 1, "exactly one response must be dropped");
    assert_eq!(got[0].id, 1);
    assert!(got[0].error.is_none());
    let (free, total) = engine.kv_blocks();
    assert_eq!(free, total, "the struck request leaked blocks");
}

/// A `Panic` injection unwinds into `Engine::run`'s catch boundary:
/// the in-flight requests answer `Fatal`, the KV store passes its
/// self-check and is left empty, and the same engine serves a fresh
/// run normally afterwards.
#[test]
fn injected_panic_converts_to_fatal_and_loop_survives() {
    let mut rng = Rng::new(107);
    let r0 = Request {
        id: 0,
        prompt: prompt(&mut rng, 20),
        max_new_tokens: 8,
        config: SparsityConfig::parse("dense").unwrap(),
        deadline_ticks: 0,
    };
    let r1 = Request {
        id: 1,
        prompt: prompt(&mut rng, 20),
        max_new_tokens: 8,
        config: SparsityConfig::parse("dense").unwrap(),
        deadline_ticks: 0,
    };
    let after = Request {
        id: 2,
        prompt: prompt(&mut rng, 20),
        max_new_tokens: 4,
        config: SparsityConfig::parse("dense").unwrap(),
        deadline_ticks: 0,
    };
    let golden = serve_reference(std::slice::from_ref(&after));

    let metrics = Arc::new(EngineMetrics::new());
    let mut cfg = EngineConfig::new(MODEL);
    cfg.pool_threads = 1;
    cfg.max_wait_secs = 0.0;
    cfg.chunk_tokens = usize::MAX;
    cfg.prefix_cache = false;
    // both requests are decoding by tick 2, when the panic fires
    cfg.fault_plan = FaultPlan::none().with(
        2,
        FaultSite::DecodeStep,
        FaultKind::Panic,
    );
    let mut engine = mk_engine(cfg, &metrics);
    let (tx, rx) = channel();
    let (reply_tx, reply_rx) = channel();
    tx.send(EngineMsg::Submit(r0, reply_tx.clone())).unwrap();
    tx.send(EngineMsg::Submit(r1, reply_tx.clone())).unwrap();
    drop(tx);
    engine.run(rx).unwrap();

    assert_eq!(metrics.faults_injected.load(Ordering::Relaxed), 1);
    let got: Vec<_> = reply_rx.try_iter().collect();
    assert_eq!(got.len(), 2, "both in-flight requests must answer");
    for r in &got {
        let err =
            r.error.as_ref().expect("panicked step must answer Fatal");
        assert_eq!(err.kind, ErrorKind::Fatal);
        assert!(
            err.reason.contains("panicked"),
            "unexpected reason: {}",
            err.reason
        );
    }
    engine.kv_invariants().unwrap();
    let (free, total) = engine.kv_blocks();
    assert_eq!(free, total, "panic recovery leaked blocks");

    // the same engine serves a fresh run normally afterwards
    let (tx2, rx2) = channel();
    tx2.send(EngineMsg::Submit(after, reply_tx.clone())).unwrap();
    drop(tx2);
    drop(reply_tx);
    engine.run(rx2).unwrap();
    let got: Vec<_> = reply_rx.try_iter().collect();
    assert_eq!(got.len(), 1, "the fresh request must answer");
    assert!(got[0].error.is_none());
    assert_eq!(got[0].tokens, golden[&2], "post-panic run diverged");
}
