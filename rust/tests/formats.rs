//! Format-level tests that need no PJRT: manifest parsing, eval-set
//! aggregation logic, weights-file round trip against bytes written in
//! the same layout python emits.

use std::io::Write;

use amber_pruner::runtime::Manifest;
use amber_pruner::tensor::io::{read_eval, read_weights};
use amber_pruner::tensor::math::{span_logprob, token_logprob};

fn tmpdir(name: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("amber-test-{name}-{}",
                                              std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn manifest_parses_minimal() {
    let dir = tmpdir("manifest");
    let manifest = r#"{
      "artifacts": {
        "m.prefill64.dense": {
          "hlo": "hlo/m.prefill64.dense.hlo.txt",
          "params": ["params.embed", "params.wq"],
          "runtime_inputs": [{"shape": [8, 64], "dtype": "int32"}],
          "outputs": ["logits", "k", "v"],
          "static": {"kind": "prefill", "variant": "dense",
                      "batch": 8, "seq": 64}
        },
        "m.decode.dense": {
          "hlo": "hlo/m.decode.dense.hlo.txt",
          "params": ["params.embed"],
          "runtime_inputs": [
            {"shape": [8], "dtype": "int32"},
            {"shape": [8], "dtype": "int32"},
            {"shape": [2, 8, 32, 1, 4], "dtype": "float32"},
            {"shape": [2, 8, 32, 1, 4], "dtype": "float32"},
            {"shape": [8], "dtype": "int32"}
          ],
          "outputs": ["logits", "k", "v"],
          "static": {"kind": "decode", "variant": "dense",
                      "batch": 8, "cache": 32}
        }
      },
      "models": {
        "m": {"weights": "weights/m.atw", "is_moe": false,
               "config": {"n_layers": 2, "vocab_size": 64}}
      },
      "settings": {"m": {"settings": ["naive", "ls"]}}
    }"#;
    std::fs::write(dir.join("manifest.json"), manifest).unwrap();
    let m = Manifest::load(&dir).unwrap();
    let a = m.artifact("m.prefill64.dense").unwrap();
    assert_eq!(a.batch, 8);
    assert_eq!(a.seq, 64);
    assert_eq!(a.params.len(), 2);
    assert_eq!(a.runtime_inputs[0].0, vec![8, 64]);
    let d = m.artifact("m.decode.dense").unwrap();
    assert_eq!(d.cache, 32);
    assert_eq!(d.runtime_inputs[2].0, vec![2, 8, 32, 1, 4]);
    assert!(m.models.get("m").unwrap().config["n_layers"] == 2);
    assert_eq!(m.settings["m"], vec!["naive", "ls"]);
    assert!(m.artifact("nope").is_err());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn weights_file_layout_matches_python_writer() {
    // bytes laid out exactly as params_io.write_weights does
    let dir = tmpdir("weights");
    let path = dir.join("x.atw");
    let mut f = std::fs::File::create(&path).unwrap();
    f.write_all(b"ATWB").unwrap();
    f.write_all(&1u32.to_le_bytes()).unwrap(); // version
    f.write_all(&2u32.to_le_bytes()).unwrap(); // n_tensors
    // tensor 1: "a" f32 [2, 2]
    f.write_all(&1u16.to_le_bytes()).unwrap();
    f.write_all(b"a").unwrap();
    f.write_all(&[0u8, 2u8]).unwrap(); // dtype f32, ndim 2
    f.write_all(&2i64.to_le_bytes()).unwrap();
    f.write_all(&2i64.to_le_bytes()).unwrap();
    f.write_all(&16u64.to_le_bytes()).unwrap();
    for v in [1.0f32, 2.0, 3.0, 4.0] {
        f.write_all(&v.to_le_bytes()).unwrap();
    }
    // tensor 2: "b.c" i8 [3]
    f.write_all(&3u16.to_le_bytes()).unwrap();
    f.write_all(b"b.c").unwrap();
    f.write_all(&[2u8, 1u8]).unwrap();
    f.write_all(&3i64.to_le_bytes()).unwrap();
    f.write_all(&3u64.to_le_bytes()).unwrap();
    f.write_all(&[5u8, 250u8, 7u8]).unwrap(); // -6 as u8=250
    drop(f);
    let ts = read_weights(&path).unwrap();
    assert_eq!(ts.len(), 2);
    assert_eq!(ts[0].name, "a");
    assert_eq!(ts[0].as_f32().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
    assert_eq!(ts[1].name, "b.c");
    assert_eq!(ts[1].dims, vec![3]);
    assert_eq!(ts[1].data, vec![5u8, 250, 7]);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn weights_file_rejects_corruption() {
    let dir = tmpdir("weights-bad");
    let path = dir.join("bad.atw");
    std::fs::write(&path, b"NOPE").unwrap();
    assert!(read_weights(&path).is_err());
    // truncated header
    std::fs::write(&path, b"ATWB\x01\x00\x00\x00").unwrap();
    assert!(read_weights(&path).is_err());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn eval_set_bytes_roundtrip() {
    // MC set written in the python layout: 2 samples x 2 choices, seq 8
    let dir = tmpdir("eval");
    let path = dir.join("t.aev");
    let mut f = std::fs::File::create(&path).unwrap();
    f.write_all(b"AEVD").unwrap();
    f.write_all(&1u32.to_le_bytes()).unwrap();
    f.write_all(&[0u8]).unwrap(); // kind MC
    f.write_all(&8u32.to_le_bytes()).unwrap(); // seq
    f.write_all(&4u32.to_le_bytes()).unwrap(); // rows
    f.write_all(&2u32.to_le_bytes()).unwrap(); // samples
    f.write_all(&2u32.to_le_bytes()).unwrap(); // choices
    for row in 0..4i32 {
        for pos in 0..8i32 {
            f.write_all(&(row * 10 + pos).to_le_bytes()).unwrap();
        }
    }
    for (sample, choice, gold) in
        [(0u32, 0u16, 1u16), (0, 1, 1), (1, 0, 0), (1, 1, 0)]
    {
        f.write_all(&sample.to_le_bytes()).unwrap();
        f.write_all(&choice.to_le_bytes()).unwrap();
        f.write_all(&3u16.to_le_bytes()).unwrap(); // score_start
        f.write_all(&2u16.to_le_bytes()).unwrap(); // score_len
        f.write_all(&gold.to_le_bytes()).unwrap();
    }
    drop(f);
    let set = read_eval(&path).unwrap();
    assert_eq!(set.seq_len, 8);
    assert_eq!(set.n_samples, 2);
    assert_eq!(set.n_choices, 2);
    assert_eq!(set.n_rows(), 4);
    assert_eq!(set.row_tokens(2)[0], 20);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn logprob_scoring_selects_higher_likelihood_span() {
    // vocab 4, seq 4. Build logits where span [2..4) = tokens [1, 2]
    // is very likely and an alternative [3, 3] is unlikely.
    let vocab = 4;
    let mut logits = vec![0f32; 4 * vocab];
    logits[1 * vocab + 1] = 8.0; // pos1 predicts token1 (at pos2)
    logits[2 * vocab + 2] = 8.0; // pos2 predicts token2 (at pos3)
    let good = span_logprob(&logits, vocab, 2, &[1, 2]);
    let bad = span_logprob(&logits, vocab, 2, &[3, 3]);
    assert!(good > bad + 5.0);
    // token_logprob normalizes
    let p: f64 = (0..vocab)
        .map(|t| token_logprob(&logits[0..vocab], t).exp())
        .sum();
    assert!((p - 1.0).abs() < 1e-9);
}
