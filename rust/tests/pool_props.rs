//! Model-based property suite for the block allocator (ISSUE 6): drive
//! [`BlockPool`] through randomized interleavings of
//! allocate/extend/fork/fork_prefix/cow/release and check every step
//! against a naive reference model that re-derives refcounts, free
//! counts and table aliasing from first principles. No leaks, no double
//! frees, no refcount drift, and `frag_stats` always consistent — under
//! at least 120 randomized cases (`prop_check` shrinks failures).

use std::collections::HashMap;

use amber_pruner::coordinator::paged::BlockPool;
use amber_pruner::testutil::prop::prop_check;
use amber_pruner::util::rng::Rng;

/// Naive reference: just the tables. Refcounts and free counts are
/// re-derived by counting, never tracked incrementally — the point is
/// to disagree with the pool if its incremental accounting drifts.
#[derive(Default)]
struct RefModel {
    tables: HashMap<u64, Vec<u32>>,
}

impl RefModel {
    fn refcount(&self, block: u32) -> usize {
        self.tables
            .values()
            .map(|t| t.iter().filter(|&&b| b == block).count())
            .sum()
    }

    fn used_blocks(&self, n_blocks: usize) -> usize {
        (0..n_blocks as u32)
            .filter(|&b| self.refcount(b) > 0)
            .count()
    }

    fn free_blocks(&self, n_blocks: usize) -> usize {
        n_blocks - self.used_blocks(n_blocks)
    }
}

/// Cross-check every observable of the pool against the model.
fn check_against_model(
    pool: &BlockPool,
    model: &RefModel,
    n_blocks: usize,
) -> Result<(), String> {
    pool.check_invariants()
        .map_err(|e| format!("pool invariants: {e}"))?;
    if pool.free_blocks() != model.free_blocks(n_blocks) {
        return Err(format!(
            "free drift: pool {} vs model {}",
            pool.free_blocks(),
            model.free_blocks(n_blocks)
        ));
    }
    let mut ids: Vec<u64> = model.tables.keys().copied().collect();
    ids.sort_unstable();
    if pool.sequences() != ids {
        return Err(format!(
            "sequence drift: pool {:?} vs model {ids:?}",
            pool.sequences()
        ));
    }
    for (&seq, table) in &model.tables {
        let got = pool
            .table(seq)
            .ok_or_else(|| format!("seq {seq} lost its table"))?;
        if got != table.as_slice() {
            return Err(format!(
                "table drift for seq {seq}: pool {got:?} vs model {table:?}"
            ));
        }
        for &b in table {
            if b as usize >= n_blocks {
                return Err(format!("seq {seq} holds out-of-range block {b}"));
            }
        }
    }
    for b in 0..n_blocks as u32 {
        let rc = pool.refcount_of(b).ok_or("refcount_of out of range")?;
        if rc as usize != model.refcount(b) {
            return Err(format!(
                "refcount drift on block {b}: pool {rc} vs model {}",
                model.refcount(b)
            ));
        }
    }
    let fs = pool.frag_stats();
    if fs.free_blocks != pool.free_blocks() || fs.n_blocks != n_blocks {
        return Err("frag_stats counts disagree with the pool".into());
    }
    if fs.longest_free_run > fs.free_blocks {
        return Err("longest free run exceeds free count".into());
    }
    let f = fs.fragmentation();
    if !(0.0..=1.0).contains(&f) {
        return Err(format!("fragmentation {f} out of [0,1]"));
    }
    Ok(())
}

#[test]
fn block_pool_matches_reference_model_under_random_interleavings() {
    prop_check("block-pool-model", 120, |rng, size| {
        let block_size = 1 + rng.usize_below(8);
        let n_blocks = 4 + rng.usize_below(4 + size * 2);
        let mut pool = BlockPool::new(n_blocks, block_size);
        let mut model = RefModel::default();
        let mut next_seq = 0u64;
        let steps = 10 + size * 8;
        for step in 0..steps {
            let live: Vec<u64> = {
                let mut v: Vec<u64> =
                    model.tables.keys().copied().collect();
                v.sort_unstable();
                v
            };
            match rng.below(12) {
                // allocate a fresh sequence (sometimes a duplicate id)
                0..=3 => {
                    let dup = !live.is_empty() && rng.bool(0.1);
                    let seq = if dup {
                        live[rng.usize_below(live.len())]
                    } else {
                        next_seq += 1;
                        next_seq
                    };
                    let tokens = 1 + rng.usize_below(4 * block_size);
                    let need = tokens.div_ceil(block_size).max(1);
                    let fits = need <= model.free_blocks(n_blocks);
                    let res = pool.allocate(seq, tokens);
                    if dup || !fits {
                        if res.is_ok() {
                            return Err(format!(
                                "step {step}: allocate(dup={dup}, \
                                 fits={fits}) must fail"
                            ));
                        }
                    } else {
                        let table = res
                            .map_err(|e| {
                                format!("step {step}: allocate: {e}")
                            })?
                            .to_vec();
                        if table.len() != need {
                            return Err(format!(
                                "step {step}: got {} blocks, need {need}",
                                table.len()
                            ));
                        }
                        model.tables.insert(seq, table);
                    }
                }
                // release (sometimes a sequence that was never allocated)
                4..=6 => {
                    let bogus = live.is_empty() || rng.bool(0.15);
                    let seq = if bogus {
                        u64::MAX - rng.below(5)
                    } else {
                        live[rng.usize_below(live.len())]
                    };
                    let known = model.tables.contains_key(&seq);
                    match pool.release(seq) {
                        Ok(()) if !known => {
                            return Err(format!(
                                "step {step}: release of unknown {seq} \
                                 must fail"
                            ));
                        }
                        Err(e) if known => {
                            return Err(format!(
                                "step {step}: release of live {seq} \
                                 failed: {e}"
                            ));
                        }
                        _ => {
                            model.tables.remove(&seq);
                        }
                    }
                }
                // fork a prefix (chains of forks included, since any
                // live sequence — including a prior child — can parent)
                7..=8 => {
                    if live.is_empty() {
                        continue;
                    }
                    let parent = live[rng.usize_below(live.len())];
                    let plen = model.tables[&parent].len();
                    // n in 0..=plen+1 probes both error bounds
                    let n = rng.usize_below(plen + 2);
                    next_seq += 1;
                    let child = next_seq;
                    let ok = n >= 1 && n <= plen;
                    match pool.fork_prefix(parent, child, n) {
                        Ok(()) if !ok => {
                            return Err(format!(
                                "step {step}: fork_prefix n={n} of \
                                 {plen} must fail"
                            ));
                        }
                        Err(e) if ok => {
                            return Err(format!(
                                "step {step}: fork_prefix failed: {e}"
                            ));
                        }
                        Ok(()) => {
                            let t = model.tables[&parent][..n].to_vec();
                            model.tables.insert(child, t);
                        }
                        Err(_) => {}
                    }
                }
                // full-table fork
                9 => {
                    if live.is_empty() {
                        continue;
                    }
                    let parent = live[rng.usize_below(live.len())];
                    next_seq += 1;
                    let child = next_seq;
                    pool.fork(parent, child).map_err(|e| {
                        format!("step {step}: fork: {e}")
                    })?;
                    let t = model.tables[&parent].clone();
                    model.tables.insert(child, t);
                }
                // copy-on-write a random table slot
                10 => {
                    if live.is_empty() {
                        continue;
                    }
                    let seq = live[rng.usize_below(live.len())];
                    let tlen = model.tables[&seq].len();
                    let idx = rng.usize_below(tlen + 1); // may be oob
                    if idx >= tlen {
                        if pool.cow(seq, idx).is_ok() {
                            return Err(format!(
                                "step {step}: cow oob index must fail"
                            ));
                        }
                        continue;
                    }
                    let old = model.tables[&seq][idx];
                    let shared = model.refcount(old) > 1;
                    let free = model.free_blocks(n_blocks);
                    match pool.cow(seq, idx) {
                        Ok(None) => {
                            if shared {
                                return Err(format!(
                                    "step {step}: cow of shared block \
                                     {old} was a no-op"
                                ));
                            }
                        }
                        Ok(Some((o, n))) => {
                            if !shared || o != old || n == old {
                                return Err(format!(
                                    "step {step}: bad cow \
                                     ({o},{n}) old={old} shared={shared}"
                                ));
                            }
                            if model.refcount(n) != 0 {
                                return Err(format!(
                                    "step {step}: cow target {n} was \
                                     not free"
                                ));
                            }
                            model.tables.get_mut(&seq).unwrap()[idx] = n;
                        }
                        Err(e) => {
                            if !(shared && free == 0) {
                                return Err(format!(
                                    "step {step}: cow errored \
                                     (shared={shared}, free={free}): {e}"
                                ));
                            }
                        }
                    }
                }
                // extend a live sequence
                _ => {
                    if live.is_empty() {
                        continue;
                    }
                    let seq = live[rng.usize_below(live.len())];
                    let have = model.tables[&seq].len();
                    let tokens = 1 + rng.usize_below(6 * block_size);
                    let need = tokens.div_ceil(block_size).max(1);
                    let extra = need.saturating_sub(have);
                    let fits = extra <= model.free_blocks(n_blocks);
                    match pool.extend(seq, tokens) {
                        Ok(added) => {
                            if !fits {
                                return Err(format!(
                                    "step {step}: extend past free \
                                     must fail"
                                ));
                            }
                            if added.len() != extra {
                                return Err(format!(
                                    "step {step}: extend added {} \
                                     blocks, expected {extra}",
                                    added.len()
                                ));
                            }
                            let t = model.tables.get_mut(&seq).unwrap();
                            t.extend_from_slice(&added);
                        }
                        Err(e) => {
                            if fits {
                                return Err(format!(
                                    "step {step}: extend failed: {e}"
                                ));
                            }
                        }
                    }
                }
            }
            check_against_model(&pool, &model, n_blocks)
                .map_err(|d| format!("after step {step}: {d}"))?;
        }
        // drain everything: the pool must come back whole, with no
        // leaked and no double-freed block
        let mut ids: Vec<u64> = model.tables.keys().copied().collect();
        ids.sort_unstable();
        for seq in ids {
            pool.release(seq)
                .map_err(|e| format!("drain release {seq}: {e}"))?;
            model.tables.remove(&seq);
            check_against_model(&pool, &model, n_blocks)
                .map_err(|d| format!("during drain: {d}"))?;
        }
        if pool.free_blocks() != n_blocks {
            return Err(format!(
                "leak: {} of {n_blocks} blocks free after full drain",
                pool.free_blocks()
            ));
        }
        Ok(())
    });
}
