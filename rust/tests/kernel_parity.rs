//! Kernel-parity property suite (ISSUE 4 + ISSUE 5): the
//! register-tiled SpMM / dense / W8A8 microkernels in
//! `kernels::{nm,dense,int8}` — row-major **and panel-packed** — must
//! be **bitwise identical** to the retained naive loops in
//! `kernels::reference` — across every N:M ratio, shapes where `dout`
//! is not a multiple of the tile, tile/panel widths (specialized and
//! runtime-width), row-block heights and pool widths — and the
//! per-token W8A8 activation scales must make packed sq prefill
//! bitwise equal to the sequential reference. The `packed_*` tests are
//! the ISSUE 5 gate: the panel layout is a pure layout transform, and
//! bind-time cached quantization must be bitwise identical to fresh
//! quantization.

mod common;

use std::sync::Arc;

use amber_pruner::exec::ThreadPool;
use amber_pruner::kernels::pack::PackedPanels;
use amber_pruner::kernels::simd::{Dispatch, Level};
use amber_pruner::kernels::{reference, DEFAULT_DOUT_TILE, MAX_DOUT_TILE};
use amber_pruner::quant;
use amber_pruner::runtime::{Engine, ModelSpec, NativeEngine};
use amber_pruner::sparsity::spmm::{
    dense_matmul, dense_matmul_packed, dense_matmul_packed_dispatch,
    dense_matmul_packed_parallel, dense_matmul_packed_parallel_dispatch,
    dense_matmul_parallel, dense_matmul_with_tile, NmCompressed,
    NmCompressedBatch,
};
use amber_pruner::util::rng::Rng;
use common::{prompt, sequential_logits};

const RATIOS: [(usize, usize); 3] = [(2, 4), (4, 8), (8, 16)];
/// Tile widths under test: the specialized const paths (4/8/16/32), the
/// runtime-width path (1/3/5/64), and an over-clamp value.
const TILES: [usize; 9] = [1, 3, 4, 5, 8, 16, 32, 64, 4096];

fn rand_mat(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal() as f32).collect()
}

// ------------------------------------------------------------ N:M SpMM

#[test]
fn tiled_nm_spmm_bitwise_equals_reference() {
    let mut rng = Rng::new(101);
    for &(n, m) in &RATIOS {
        let din = 2 * m * 3; // divisible by every m
        let per_row = din / m * n;
        // dout values deliberately NOT multiples of the default tile
        // (and of most swept tiles): ragged tails on every width
        for &(t, dout) in &[(1usize, 5usize), (7, 13), (33, 37), (4, 8)] {
            let x = rand_mat(&mut rng, t * din);
            let w = rand_mat(&mut rng, din * dout);
            let scale: Vec<f32> =
                (0..din).map(|_| rng.f64() as f32 + 0.1).collect();
            for sc in [&[][..], &scale[..]] {
                let c = NmCompressed::compress(&x, t, din, sc, n, m);
                let golden = reference::spmm_nm(
                    &c.values, &c.index, t, per_row, &w, dout,
                );
                assert_eq!(
                    c.matmul(&w, dout),
                    golden,
                    "{n}:{m} t={t} dout={dout} default tile"
                );
                for &tile in &TILES {
                    assert_eq!(
                        c.matmul_with_tile(&w, dout, tile),
                        golden,
                        "{n}:{m} t={t} dout={dout} tile={tile}"
                    );
                }
            }
        }
    }
}

#[test]
fn batched_tiled_nm_spmm_bitwise_across_blocks_and_pools() {
    let mut rng = Rng::new(103);
    for &(n, m) in &RATIOS {
        let din = 2 * m * 2;
        let per_row = din / m * n;
        let (t, dout) = (33usize, 21usize); // dout ragged for tile 8
        let x = rand_mat(&mut rng, t * din);
        let w = rand_mat(&mut rng, din * dout);
        let c = NmCompressed::compress(&x, t, din, &[], n, m);
        let golden =
            reference::spmm_nm(&c.values, &c.index, t, per_row, &w, dout);
        let wa = Arc::new(w.clone());
        for &block_rows in &[1usize, 7, 32] {
            let batch = NmCompressedBatch::compress(
                &x, t, din, &[], n, m, block_rows,
            );
            for &tile in &[3usize, DEFAULT_DOUT_TILE, 16] {
                assert_eq!(
                    batch.matmul_with_tile(&w, dout, tile),
                    golden,
                    "{n}:{m} block={block_rows} tile={tile} serial"
                );
                for &width in &[1usize, 4] {
                    let pool = ThreadPool::new(width);
                    assert_eq!(
                        batch.matmul_parallel_with_tile(
                            &wa, dout, &pool, tile
                        ),
                        golden,
                        "{n}:{m} block={block_rows} tile={tile} \
                         pool={width}"
                    );
                }
            }
        }
    }
}

// --------------------------------------------------------------- dense

#[test]
fn tiled_dense_bitwise_equals_reference() {
    let mut rng = Rng::new(107);
    for &(t, din, dout) in
        &[(1usize, 8usize, 5usize), (7, 24, 13), (33, 16, 37), (5, 32, 64)]
    {
        let x = rand_mat(&mut rng, t * din);
        let w = rand_mat(&mut rng, din * dout);
        let golden = reference::dense(&x, t, din, &w, dout);
        assert_eq!(dense_matmul(&x, t, din, &w, dout), golden);
        for &tile in &TILES {
            assert_eq!(
                dense_matmul_with_tile(&x, t, din, &w, dout, tile),
                golden,
                "t={t} din={din} dout={dout} tile={tile}"
            );
        }
    }
}

#[test]
fn zero_copy_dense_parallel_bitwise_across_pools() {
    let mut rng = Rng::new(109);
    let (t, din, dout) = (45usize, 16usize, 19usize);
    let x = Arc::new(rand_mat(&mut rng, t * din));
    let w = Arc::new(rand_mat(&mut rng, din * dout));
    let golden = reference::dense(&x, t, din, &w, dout);
    for &block_rows in &[1usize, 7, 32] {
        for &width in &[1usize, 4] {
            let pool = ThreadPool::new(width);
            for &tile in &[1usize, DEFAULT_DOUT_TILE, 32] {
                assert_eq!(
                    dense_matmul_parallel(
                        &x, t, din, &w, dout, &pool, block_rows, tile
                    ),
                    golden,
                    "block={block_rows} pool={width} tile={tile}"
                );
            }
        }
    }
}

// ---------------------------------------------------------------- W8A8

#[test]
fn tiled_w8a8_bitwise_equals_reference() {
    let mut rng = Rng::new(113);
    for &(t, din, dout) in &[(1usize, 16usize, 5usize), (9, 32, 29)] {
        let x = rand_mat(&mut rng, t * din);
        let w = rand_mat(&mut rng, din * dout);
        let (wq, ws) = quant::quantize_weight(&w, din, dout);
        // per-tensor
        let xs = 0.037f32;
        let xq = quant::quantize(&x, xs);
        let golden = reference::w8a8(&xq, t, din, &wq, dout, xs, &ws);
        assert_eq!(
            quant::w8a8_matmul(&xq, t, din, &wq, dout, xs, &ws),
            golden,
            "per-tensor t={t} dout={dout}"
        );
        // per-token
        let (xq_pt, xs_pt) = quant::quantize_per_token(&x, t, din);
        let golden_pt = reference::w8a8_per_token(
            &xq_pt, t, din, &wq, dout, &xs_pt, &ws,
        );
        assert_eq!(
            quant::w8a8_matmul_per_token(
                &xq_pt, t, din, &wq, dout, &xs_pt, &ws
            ),
            golden_pt,
            "per-token t={t} dout={dout}"
        );
        // tile sweep through the kernel entry point
        for &tile in &TILES {
            let mut out = vec![0.0f32; t * dout];
            amber_pruner::kernels::int8::w8a8_tiled_per_token(
                &xq_pt, t, din, &wq, dout, tile, &xs_pt, &ws, &mut out,
            );
            assert_eq!(out, golden_pt, "per-token tile={tile}");
        }
    }
}

// ------------------------------------------------- engine-level parity

#[test]
fn per_token_scales_make_sq_packing_bitwise() {
    // the satellite equality pin: with per-token activation scales a
    // token's quantized logits depend only on its own rows, so the
    // packed sq prefill must reproduce the sequential sq prefill
    // bit-for-bit — for every pool width
    let mut rng = Rng::new(127);
    let lens = [5usize, 64, 17, 1];
    let prompts: Vec<Vec<i32>> =
        lens.iter().map(|&l| prompt(&mut rng, l)).collect();
    let art = "tiny-lm-a.prefill64.sq";
    for &threads in &[1usize, 4] {
        let spec = ModelSpec::tiny("tiny-lm-a");
        let mut e =
            NativeEngine::synthetic(vec![spec]).with_parallelism(threads);
        let bind = e.bind(art, &["tiny-lm-a.sq.atw"]).unwrap();
        let golden = sequential_logits(&mut e, art, &bind, 8, 64, &prompts);
        let packed = e.prefill_packed(art, &bind, &prompts).unwrap();
        let v = packed.vocab;
        for (i, g) in golden.iter().enumerate() {
            let start = packed.row_start(i);
            let got = &packed.logits[start * v..(start + lens[i]) * v];
            assert_eq!(
                got,
                &g[..],
                "sq request {i} (threads={threads}): packed != \
                 sequential"
            );
        }
    }
}

// ------------------------------------------ panel-packed (ISSUE 5)

/// Panel widths under test: the specialized const paths (4/8/16/32),
/// the runtime-width path (1/3/5/64), and an over-clamp value.
const PANELS: [usize; 8] = [1, 3, 4, 8, 16, 32, 64, 4096];

#[test]
fn packed_kernels_bitwise_equal_reference_across_matrix() {
    // the full ISSUE 5 parity matrix: ratios x ragged douts x panel
    // widths x block_rows x pools, all three kernel families, against
    // the retained reference loops — packing is a pure layout transform
    let mut rng = Rng::new(211);
    let pools: Vec<ThreadPool> =
        [1usize, 4].iter().map(|&w| ThreadPool::new(w)).collect();
    for &(n, m) in &RATIOS {
        let din = 2 * m * 3; // divisible by every m
        let per_row = din / m * n;
        for &dout in &[5usize, 13, 21, 29, 37] {
            let t = 9usize;
            let x = rand_mat(&mut rng, t * din);
            let xa = Arc::new(x.clone());
            let w = rand_mat(&mut rng, din * dout);
            let c = NmCompressed::compress(&x, t, din, &[], n, m);
            let nm_golden = reference::spmm_nm(
                &c.values, &c.index, t, per_row, &w, dout,
            );
            let dense_golden = reference::dense(&x, t, din, &w, dout);
            let (wq, ws) = quant::quantize_weight(&w, din, dout);
            let (xq, xs) = quant::quantize_per_token(&x, t, din);
            let int8_golden = reference::w8a8_per_token(
                &xq, t, din, &wq, dout, &xs, &ws,
            );
            let pt_scale = 0.037f32;
            let xq_pt = quant::quantize(&x, pt_scale);
            let int8_pt_golden = reference::w8a8(
                &xq_pt, t, din, &wq, dout, pt_scale, &ws,
            );
            for &pw in &PANELS {
                let ctx = format!("{n}:{m} dout={dout} panel={pw}");
                let packed =
                    Arc::new(PackedPanels::pack(&w, din, dout, pw));
                // N:M per-row + dense serial
                assert_eq!(
                    c.matmul_packed(&packed),
                    nm_golden,
                    "{ctx} nm per-row"
                );
                assert_eq!(
                    dense_matmul_packed(&x, t, din, &packed),
                    dense_golden,
                    "{ctx} dense serial"
                );
                // int8: quantize-once-and-pack, per-token scales
                let (pq, ps) =
                    quant::quantize_weight_packed(&w, din, dout, pw);
                assert_eq!(ps, ws, "{ctx} int8 scales");
                assert_eq!(
                    quant::w8a8_matmul_packed_per_token(
                        &xq, t, din, &pq, &xs, &ps
                    ),
                    int8_golden,
                    "{ctx} int8 per-token"
                );
                // int8 per-tensor = per-token with a broadcast scale
                let bcast = vec![pt_scale; t];
                assert_eq!(
                    quant::w8a8_matmul_packed_per_token(
                        &xq_pt, t, din, &pq, &bcast, &ps
                    ),
                    int8_pt_golden,
                    "{ctx} int8 per-tensor"
                );
                // blocked + pooled
                for &block_rows in &[1usize, 7, 32] {
                    let batch = NmCompressedBatch::compress(
                        &x, t, din, &[], n, m, block_rows,
                    );
                    assert_eq!(
                        batch.matmul_packed(&packed),
                        nm_golden,
                        "{ctx} block={block_rows} serial"
                    );
                    for pool in &pools {
                        assert_eq!(
                            batch.matmul_packed_parallel(&packed, pool),
                            nm_golden,
                            "{ctx} block={block_rows} pool={}",
                            pool.size()
                        );
                        assert_eq!(
                            dense_matmul_packed_parallel(
                                &xa, t, din, &packed, pool, block_rows
                            ),
                            dense_golden,
                            "{ctx} dense block={block_rows} pool={}",
                            pool.size()
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn packed_per_module_tile_table_is_bit_transparent_through_engine() {
    // the planned per-module table mixes widths on the tiny geometry
    // (kv_dim 16 -> 8, d_model/q_dim/d_ff -> 16, vocab 384 -> 32); a
    // full prefill under it must be bitwise identical to every uniform
    // override — tile width is pure perf, per module or global
    let mut rng = Rng::new(223);
    let prompts: Vec<Vec<i32>> =
        [40usize, 64, 3].iter().map(|&l| prompt(&mut rng, l)).collect();
    let art = "tiny-lm-a.prefill64.nm2_4";
    let files = ["tiny-lm-a.atw", "tiny-lm-a.aux_all.atw"];
    let run = |tile: Option<usize>| {
        // force scalar dispatch: this test pins the scalar-planned
        // per-module widths, and auto-dispatch on a wide-SIMD CPU
        // legitimately widens them to whole registers (covered by the
        // simd_ tests below)
        let mut e =
            NativeEngine::synthetic(vec![ModelSpec::tiny("tiny-lm-a")])
                .with_dispatch_level(Level::Scalar);
        if let Some(t) = tile {
            e = e.with_dout_tile(t);
        }
        let bind = e.bind(art, &files).unwrap();
        let plan = e.plan_for(art, &bind).unwrap();
        let out = e.prefill_packed(art, &bind, &prompts).unwrap();
        (plan, out.logits, out.k_cache, out.v_cache)
    };
    let (plan, logits, kc, vc) = run(None);
    // prove the default really is a mixed table
    assert_eq!(plan.tiles.tile_for("k_proj"), 8);
    assert_eq!(plan.tiles.tile_for("q_proj"), 16);
    assert_eq!(plan.tiles.tile_for("lm_head"), 32);
    for tile in [1usize, 5, DEFAULT_DOUT_TILE, MAX_DOUT_TILE] {
        let (uplan, ul, uk, uv) = run(Some(tile));
        assert_eq!(uplan.tiles.tile_for("k_proj"), tile.min(64));
        assert_eq!((ul, uk, uv), (logits.clone(), kc.clone(), vc.clone()),
            "uniform tile {tile}");
    }
}

#[test]
fn packed_bind_rebind_cached_quant_bitwise_equals_fresh() {
    // the engine-level ISSUE 5 pin: a bind/re-bind cycle whose W8A8
    // weights come from the prep cache must be bitwise identical to a
    // fresh engine that quantizes at first bind — and quantization must
    // run at most once per weight Arc no matter how many binds
    let mut rng = Rng::new(227);
    let prompts: Vec<Vec<i32>> =
        [17usize, 64, 5].iter().map(|&l| prompt(&mut rng, l)).collect();
    let art = "tiny-lm-a.prefill64.sq";
    let spec = || ModelSpec::tiny("tiny-lm-a");
    // engine A: dense bind first (packs f32 only), then sq (adds the
    // cached quantization), then an sq re-bind (pure hits)
    let mut a = NativeEngine::synthetic(vec![spec()]);
    a.bind("tiny-lm-a.prefill64.dense", &["tiny-lm-a.atw"]).unwrap();
    assert_eq!(a.prep_report().weights_quantized, 0);
    let b1 = a.bind(art, &["tiny-lm-a.sq.atw"]).unwrap();
    let quants = a.prep_report().weights_quantized;
    assert!(quants > 0, "sq bind must prepare quantized weights");
    let out1 = a.prefill_packed(art, &b1, &prompts).unwrap();
    let b2 = a.bind(art, &["tiny-lm-a.sq.atw"]).unwrap();
    let out2 = a.prefill_packed(art, &b2, &prompts).unwrap();
    assert_eq!(
        a.prep_report().weights_quantized,
        quants,
        "re-bind must reuse the cached quantization"
    );
    // engine B: fresh quantization at its first (and only) sq bind
    let mut b = NativeEngine::synthetic(vec![spec()]);
    let bb = b.bind(art, &["tiny-lm-a.sq.atw"]).unwrap();
    let out3 = b.prefill_packed(art, &bb, &prompts).unwrap();
    assert_eq!(out1.logits, out2.logits, "re-bind changed sq logits");
    assert_eq!(out1.logits, out3.logits, "cached != fresh quantization");
    assert_eq!(out1.k_cache, out3.k_cache);
    assert_eq!(out1.v_cache, out3.v_cache);
}

// ---------------------------------------------- SIMD dispatch (ISSUE 7)

#[test]
fn simd_every_dispatch_level_bitwise_equals_tiled_across_matrix() {
    // the ISSUE 7 kernel gate: every dispatch level this build/CPU
    // offers — scalar always; AVX2/AVX-512/NEON when the `simd`
    // feature and the ISA are present — must be bitwise identical to
    // the tiled packed kernels (themselves pinned against the naive
    // reference above) across ratios x ragged douts x panel widths x
    // block_rows x pools, all three kernel families. With default
    // features the sweep degenerates to scalar-vs-scalar and stays
    // green.
    let mut rng = Rng::new(229);
    let levels = Dispatch::available_levels();
    assert!(levels.contains(&Level::Scalar), "scalar always available");
    let pools: Vec<ThreadPool> =
        [1usize, 4].iter().map(|&w| ThreadPool::new(w)).collect();
    for &(n, m) in &RATIOS {
        let din = 2 * m * 3; // divisible by every m
        for &dout in &[5usize, 13, 21, 37] {
            let t = 9usize;
            let x = rand_mat(&mut rng, t * din);
            let xa = Arc::new(x.clone());
            let w = rand_mat(&mut rng, din * dout);
            let (xq, xs) = quant::quantize_per_token(&x, t, din);
            let xqa = Arc::new(xq.clone());
            let xsa = Arc::new(xs.clone());
            for &pw in &PANELS {
                let packed =
                    Arc::new(PackedPanels::pack(&w, din, dout, pw));
                let (pq, ps) =
                    quant::quantize_weight_packed(&w, din, dout, pw);
                let (pq, ps) = (Arc::new(pq), Arc::new(ps));
                let batch = NmCompressedBatch::compress(
                    &x, t, din, &[], n, m, 7,
                );
                let nm_golden = batch.matmul_packed(&packed);
                let dense_golden = dense_matmul_packed(&x, t, din, &packed);
                let int8_golden = quant::w8a8_matmul_packed_per_token(
                    &xq, t, din, &pq, &xs, &ps,
                );
                for &level in &levels {
                    let disp = Dispatch::force(level).unwrap();
                    let ctx = format!(
                        "{n}:{m} dout={dout} panel={pw} level={level:?}"
                    );
                    assert_eq!(
                        batch.matmul_packed_dispatch(&packed, disp),
                        nm_golden,
                        "{ctx} nm serial"
                    );
                    assert_eq!(
                        dense_matmul_packed_dispatch(
                            &x, t, din, &packed, disp
                        ),
                        dense_golden,
                        "{ctx} dense serial"
                    );
                    assert_eq!(
                        quant::w8a8_matmul_packed_per_token_dispatch(
                            &xq, t, din, &pq, &xs, &ps, disp
                        ),
                        int8_golden,
                        "{ctx} int8 serial"
                    );
                    for pool in &pools {
                        for &block_rows in &[1usize, 32] {
                            let pctx = format!(
                                "{ctx} pool={} block={block_rows}",
                                pool.size()
                            );
                            assert_eq!(
                                batch.matmul_packed_parallel_dispatch(
                                    &packed, pool, disp
                                ),
                                nm_golden,
                                "{pctx} nm"
                            );
                            assert_eq!(
                                dense_matmul_packed_parallel_dispatch(
                                    &xa, t, din, &packed, pool,
                                    block_rows, disp,
                                ),
                                dense_golden,
                                "{pctx} dense"
                            );
                            assert_eq!(
                                quant::w8a8_matmul_packed_per_token_parallel_dispatch(
                                    &xqa, t, din, &pq, &xsa, &ps, pool,
                                    block_rows, disp,
                                ),
                                int8_golden,
                                "{pctx} int8"
                            );
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn simd_auto_dispatch_bind_serves_tokens_identical_to_forced_scalar() {
    // the ISSUE 7 engine gate: an auto-dispatch bind (whatever level
    // this CPU resolves, including the lane-widened tile planning that
    // comes with it) must serve token-identical output to a
    // forced-scalar bind — and so must every individually forced
    // level. SIMD is pure perf all the way through packing, N:M
    // prefill, and per-token W8A8.
    let mut rng = Rng::new(233);
    let prompts: Vec<Vec<i32>> =
        [5usize, 64, 17, 1].iter().map(|&l| prompt(&mut rng, l)).collect();
    let cases: [(&str, &[&str]); 2] = [
        ("tiny-lm-a.prefill64.sq", &["tiny-lm-a.sq.atw"]),
        (
            "tiny-lm-a.prefill64.nm2_4",
            &["tiny-lm-a.atw", "tiny-lm-a.aux_all.atw"],
        ),
    ];
    for (art, files) in cases {
        let run = |force: Option<Level>| {
            let mut e =
                NativeEngine::synthetic(vec![ModelSpec::tiny("tiny-lm-a")])
                    .with_parallelism(4);
            if let Some(level) = force {
                e = e.with_dispatch_level(level);
            }
            let bind = e.bind(art, files).unwrap();
            let level = e.dispatch_level();
            let out = e.prefill_packed(art, &bind, &prompts).unwrap();
            (level, out.logits, out.k_cache, out.v_cache)
        };
        let (_, gl, gk, gv) = run(Some(Level::Scalar));
        let (auto_level, al, ak, av) = run(None);
        assert_eq!(
            (al, ak, av),
            (gl.clone(), gk.clone(), gv.clone()),
            "{art}: auto dispatch ({auto_level:?}) != forced scalar"
        );
        for level in Dispatch::available_levels() {
            let (_, fl, fk, fv) = run(Some(level));
            assert_eq!(
                (fl, fk, fv),
                (gl.clone(), gk.clone(), gv.clone()),
                "{art}: forced {level:?} != forced scalar"
            );
        }
    }
}

#[test]
fn dout_tile_knob_is_bit_transparent_through_engine() {
    // the tile width is a pure perf knob: the full engine prefill must
    // produce identical bits for every width, including the runtime
    // fallback (5) and the clamp ceiling
    let mut rng = Rng::new(131);
    let prompts: Vec<Vec<i32>> =
        [40usize, 64, 3].iter().map(|&l| prompt(&mut rng, l)).collect();
    let art = "tiny-lm-a.prefill64.nm2_4";
    let files = ["tiny-lm-a.atw", "tiny-lm-a.aux_all.atw"];
    let run = |tile: usize| {
        let mut e =
            NativeEngine::synthetic(vec![ModelSpec::tiny("tiny-lm-a")])
                .with_dout_tile(tile);
        let bind = e.bind(art, &files).unwrap();
        let out = e.prefill_packed(art, &bind, &prompts).unwrap();
        (out.logits, out.k_cache, out.v_cache)
    };
    let golden = run(DEFAULT_DOUT_TILE);
    for tile in [1usize, 5, 16, MAX_DOUT_TILE] {
        assert_eq!(run(tile), golden, "dout_tile {tile}");
    }
}
