//! Chunk-parity suite (ISSUE 8): chunked prefill must be **bitwise
//! identical** to one-shot prefill. A chunk is a prefixed prefill whose
//! cached prefix is the request's own earlier chunks, so given the PR 6
//! fork-parity guarantee (suffix rows over a bitwise-equal cached
//! prefix equal the cold rows), induction over chunks pins the whole
//! chunked run to the cold one. This suite checks that induction at
//! the runtime layer (every chunk's logits and K/V against the cold
//! slices), then end to end through the serving engine (chunked token
//! streams == one-shot token streams across chunk sizes, sparsity
//! configs, prefix-cache settings and a heavy-tail mixed workload).

use std::collections::HashMap;
use std::sync::atomic::Ordering;
use std::sync::mpsc::channel;
use std::sync::Arc;

use amber_pruner::coordinator::batcher::routing;
use amber_pruner::coordinator::request::{Request, SparsityConfig};
use amber_pruner::coordinator::scheduler::{
    Engine as ServeEngine, EngineConfig,
};
use amber_pruner::metrics::EngineMetrics;
use amber_pruner::runtime::{
    Engine, ModelSpec, NativeEngine, PrefixedPrompt,
};
use amber_pruner::server::workload::{generate, WorkloadSpec};
use amber_pruner::util::rng::Rng;

const MODEL: &str = "tiny-lm-a";
// tiny-lm geometry (ModelSpec::tiny)
const L: usize = 2;
const KVD: usize = 16;

/// Every ratio x {fp, sq} plus dense — the full config surface.
const CONFIGS: [&str; 8] = [
    "dense",
    "dense+sq",
    "2:4:ls",
    "2:4:ls+sq",
    "4:8:naive",
    "4:8:naive+sq",
    "8:16:all",
    "8:16:all+sq",
];

fn prompt(rng: &mut Rng, len: usize) -> Vec<i32> {
    (0..len).map(|_| 1 + rng.below(300) as i32).collect()
}

/// Rows `lo..hi` of a `[L, total, KVD]` packed cache, per layer.
fn slice_rows(c: &[f32], total: usize, lo: usize, hi: usize) -> Vec<f32> {
    let mut out = Vec::with_capacity(L * (hi - lo) * KVD);
    for l in 0..L {
        let at = (l * total + lo) * KVD;
        out.extend_from_slice(&c[at..at + (hi - lo) * KVD]);
    }
    out
}

/// The induction step at the runtime layer: replay a prompt chunk by
/// chunk, each chunk a prefixed prefill over the cold run's leading
/// rows (exactly what the scheduler gathers from the request's own
/// KV), and require every chunk's logits and K/V to equal the cold
/// slices bitwise. Prompt length 60 is a multiple of neither chunk
/// size, so the final partial chunk is covered too.
#[test]
fn chunked_prefill_is_bitwise_one_shot_at_every_chunk() {
    let mut rng = Rng::new(61);
    let p = prompt(&mut rng, 60);
    let total = p.len();
    for cfg_s in CONFIGS {
        let cfg = SparsityConfig::parse(cfg_s).unwrap();
        let (art, _, files) = routing(MODEL, 64, &cfg);
        let refs: Vec<&str> = files.iter().map(|f| f.as_str()).collect();
        let mut e = NativeEngine::synthetic(vec![ModelSpec::tiny(MODEL)]);
        let bind = e.bind(&art, &refs).unwrap();
        let cold = e
            .prefill_packed(&art, &bind, std::slice::from_ref(&p))
            .unwrap();
        assert_eq!(cold.lens, vec![total]);
        for chunk in [16usize, 48] {
            let mut done = 0usize;
            while done < total {
                let len = chunk.min(total - done);
                let req = PrefixedPrompt {
                    tokens: p[..done + len].to_vec(),
                    cached_len: done,
                    prefix_k: slice_rows(&cold.k_cache, total, 0, done),
                    prefix_v: slice_rows(&cold.v_cache, total, 0, done),
                };
                let out = e
                    .prefill_packed_prefixed(
                        &art,
                        &bind,
                        std::slice::from_ref(&req),
                    )
                    .unwrap();
                assert_eq!(
                    out.lens,
                    vec![len],
                    "{cfg_s} chunk {chunk} at {done}"
                );
                assert_eq!(
                    out.logits[..],
                    cold.logits
                        [done * cold.vocab..(done + len) * cold.vocab],
                    "{cfg_s} chunk {chunk}: logits diverged at {done}"
                );
                assert_eq!(
                    out.k_cache,
                    slice_rows(&cold.k_cache, total, done, done + len),
                    "{cfg_s} chunk {chunk}: K diverged at {done}"
                );
                assert_eq!(
                    out.v_cache,
                    slice_rows(&cold.v_cache, total, done, done + len),
                    "{cfg_s} chunk {chunk}: V diverged at {done}"
                );
                done += len;
            }
        }
    }
}

/// Serve `reqs` on a fresh engine with the given scheduling knobs and
/// return the response token map plus the metrics.
fn serve(
    chunk_tokens: usize,
    prefix_cache: bool,
    reqs: &[Request],
) -> (HashMap<u64, Vec<i32>>, Arc<EngineMetrics>) {
    let metrics = Arc::new(EngineMetrics::new());
    let mut cfg = EngineConfig::new(MODEL);
    cfg.pool_threads = 1;
    cfg.chunk_tokens = chunk_tokens;
    cfg.prefix_cache = prefix_cache;
    let mut engine = ServeEngine::new(
        Box::new(NativeEngine::tiny()),
        cfg,
        Arc::clone(&metrics),
    )
    .unwrap();
    let (reply_tx, reply_rx) = channel();
    for r in reqs {
        engine.submit(r.clone(), reply_tx.clone());
    }
    while engine.step().unwrap() {}
    drop(reply_tx);
    assert_eq!(engine.queued_requests(), 0, "requests left queued");
    assert_eq!(engine.flight_requests(), 0, "requests left in flight");
    engine.clear_prefix_cache();
    engine.kv_invariants().unwrap();
    let (free, total) = engine.kv_blocks();
    assert_eq!(free, total, "blocks leaked after drain");
    (reply_rx.try_iter().map(|r| (r.id, r.tokens)).collect(), metrics)
}

/// End to end per config: the served token streams are identical at
/// every chunk size ({1 block, 3 blocks, one-shot}) and prefix-cache
/// setting. Prompt lengths include multiples of neither chunk size
/// (45, 17), an exact multiple (64 = the seq cap) and a one-chunk
/// short (8).
#[test]
fn served_tokens_identical_across_chunk_sizes_and_prefix_cache() {
    let mut rng = Rng::new(67);
    let lens = [45usize, 17, 60, 33, 64, 8];
    for cfg_s in CONFIGS {
        let config = SparsityConfig::parse(cfg_s).unwrap();
        let reqs: Vec<Request> = lens
            .iter()
            .enumerate()
            .map(|(i, &len)| Request {
                id: i as u64,
                prompt: prompt(&mut rng, len),
                max_new_tokens: 4,
                config,
                deadline_ticks: 0,
            })
            .collect();
        let (golden, mg) = serve(usize::MAX, false, &reqs);
        assert_eq!(golden.len(), reqs.len(), "{cfg_s}: requests lost");
        // one-shot = one chunk per request
        assert_eq!(
            mg.prefill_chunks.load(Ordering::Relaxed),
            reqs.len() as u64,
            "{cfg_s}: one-shot must count one chunk per request"
        );
        for chunk in [16usize, 48, usize::MAX] {
            for prefix in [false, true] {
                if chunk == usize::MAX && !prefix {
                    continue; // the golden run itself
                }
                let (got, m) = serve(chunk, prefix, &reqs);
                assert_eq!(
                    got, golden,
                    "{cfg_s}: tokens diverged at chunk={chunk} \
                     prefix={prefix}"
                );
                if chunk == 16 {
                    // 45->3, 17->2, 60->4, 33->3, 64->4, 8->1 chunks
                    assert!(
                        m.prefill_chunks.load(Ordering::Relaxed)
                            > reqs.len() as u64,
                        "{cfg_s}: long prompts must actually chunk"
                    );
                }
            }
        }
    }
}

/// The mixed-workload e2e gate: a heavy-tail workload over a mixed
/// sparsity/quantization population serves token-identically on a
/// chunked engine and a one-shot engine, with and without the prefix
/// cache.
#[test]
fn heavy_tail_mixed_workload_serves_identically_chunked() {
    let mut spec = WorkloadSpec::heavy_tail(24);
    spec.mix = vec![
        (SparsityConfig::parse("dense").unwrap(), 1.0),
        (SparsityConfig::parse("2:4:ls").unwrap(), 1.0),
        (SparsityConfig::parse("8:16:all+sq").unwrap(), 1.0),
    ];
    let reqs: Vec<Request> =
        generate(&spec).into_iter().map(|t| t.req).collect();
    let (golden, _) = serve(usize::MAX, false, &reqs);
    assert_eq!(golden.len(), 24, "every request must complete");
    for (chunk, prefix) in
        [(16usize, false), (16, true), (32, true), (usize::MAX, true)]
    {
        let (got, m) = serve(chunk, prefix, &reqs);
        assert_eq!(
            got, golden,
            "heavy-tail tokens diverged at chunk={chunk} prefix={prefix}"
        );
        if chunk == 16 {
            assert!(
                m.prefill_chunks.load(Ordering::Relaxed) > 24,
                "the heavy tail must produce multi-chunk prefills"
            );
        }
    }
}
