//! End-to-end coordinator test through the native engine (ISSUE 1
//! satellite): enqueue mixed-ratio requests, drive the real scheduler
//! loop, and assert completion order, coverage accounting and the N:M
//! validity of every pruned activation.

use std::sync::mpsc::channel;
use std::sync::Arc;

use amber_pruner::coordinator::scheduler::{
    DegradePolicy, Engine, EngineConfig, EngineMsg,
};
use amber_pruner::coordinator::request::{Request, SparsityConfig};
use amber_pruner::metrics::EngineMetrics;
use amber_pruner::runtime::NativeEngine;
use amber_pruner::server::workload::{generate, WorkloadSpec};
use amber_pruner::util::rng::Rng;

fn prompt(rng: &mut Rng, len: usize) -> Vec<i32> {
    (0..len).map(|_| 1 + rng.below(300) as i32).collect()
}

#[test]
fn mixed_ratio_workload_completes_with_valid_sparsity() {
    let metrics = Arc::new(EngineMetrics::new());
    let mut engine = Engine::new(
        Box::new(NativeEngine::tiny()),
        EngineConfig::new("tiny-lm-a"),
        Arc::clone(&metrics),
    )
    .unwrap();

    // every ratio x {fp, sq} plus dense — one bucket per config
    let configs: Vec<SparsityConfig> = [
        "dense", "2:4:ls", "4:8:naive", "8:16:all", "2:4:ls+sq", "dense+sq",
    ]
    .iter()
    .map(|s| SparsityConfig::parse(s).unwrap())
    .collect();

    let (tx, rx) = channel();
    let (reply_tx, reply_rx) = channel();
    let mut rng = Rng::new(11);
    let n = 18u64;
    for id in 0..n {
        let len = 6 + rng.usize_below(32);
        tx.send(EngineMsg::Submit(
            Request {
                id,
                prompt: prompt(&mut rng, len),
                max_new_tokens: 4,
                config: configs[(id as usize) % configs.len()],
                deadline_ticks: 0,
            },
            reply_tx.clone(),
        ))
        .unwrap();
    }
    drop(tx);
    drop(reply_tx);
    engine.run(rx).unwrap();

    let responses: Vec<_> = reply_rx.try_iter().collect();
    assert_eq!(responses.len(), n as usize, "every request must complete");
    let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..n).collect::<Vec<_>>());
    for r in &responses {
        assert!(!r.tokens.is_empty() && r.tokens.len() <= 4);
        assert!(r.e2e_secs >= r.ttft_secs && r.ttft_secs >= 0.0);
    }

    // KV slots + block pool drained cleanly
    engine.kv_invariants().unwrap();

    // coverage accounting: sparse configs really took the pruned path,
    // and every pruned activation satisfied exact N:M
    let audit = engine.audit().expect("native engine must audit");
    assert!(audit.pruned_matmuls > 0, "no pruned matmuls recorded");
    assert!(audit.dense_matmuls > 0, "dense path must also run");
    assert!(audit.nm_checks > 0, "validation must be on");
    assert_eq!(audit.nm_violations, 0, "N:M contract violated");
    assert_eq!(audit.pruned_fallbacks, 0, "unexpected dense fallback");
    assert!(
        audit.flops_saved_frac() > 0.0,
        "sparse prefill saved no FLOPs"
    );

    use std::sync::atomic::Ordering;
    assert_eq!(
        metrics.requests_completed.load(Ordering::Relaxed),
        n
    );
    assert!(metrics.prefill_batches.load(Ordering::Relaxed) >= 6);
}

#[test]
fn single_config_batch_completes_in_submission_order() {
    // one bucket, one prefill batch, equal generation budgets: the
    // decode loop iterates slots in sorted-id order, so completions are
    // reported in submission order.
    let metrics = Arc::new(EngineMetrics::new());
    let mut engine = Engine::new(
        Box::new(NativeEngine::tiny()),
        EngineConfig::new("tiny-lm-a"),
        Arc::clone(&metrics),
    )
    .unwrap();
    let (tx, rx) = channel();
    let (reply_tx, reply_rx) = channel();
    let mut rng = Rng::new(5);
    for id in 0..8u64 {
        tx.send(EngineMsg::Submit(
            Request {
                id,
                prompt: prompt(&mut rng, 12),
                max_new_tokens: 2,
                config: SparsityConfig::parse("8:16:ls").unwrap(),
                deadline_ticks: 0,
            },
            reply_tx.clone(),
        ))
        .unwrap();
    }
    drop(tx);
    drop(reply_tx);
    engine.run(rx).unwrap();
    let responses: Vec<_> = reply_rx.try_iter().collect();
    assert_eq!(responses.len(), 8);
    // deterministic completion order: sequences that finished at prefill
    // admission (immediate EOS -> 1 token) are reported first in id
    // order, then the decode-step completions in id order.
    let order: Vec<u64> = responses.iter().map(|r| r.id).collect();
    let mut expected: Vec<u64> = responses
        .iter()
        .filter(|r| r.tokens.len() == 1)
        .map(|r| r.id)
        .collect();
    expected.sort_unstable();
    let mut decode_done: Vec<u64> = responses
        .iter()
        .filter(|r| r.tokens.len() > 1)
        .map(|r| r.id)
        .collect();
    decode_done.sort_unstable();
    expected.extend(decode_done);
    assert_eq!(order, expected);
    engine.kv_invariants().unwrap();
    let audit = engine.audit().unwrap();
    assert_eq!(audit.nm_violations, 0);
    assert!(audit.pruned_matmuls > 0);
}

#[test]
fn shared_prefix_tenants_hit_the_prefix_cache() {
    // the canonical multi-tenant prefix-cache workload (ISSUE 6): 9
    // requests across 3 tenants, each tenant sharing a 32-token
    // (2-block) prompt prefix. Wave 1 serves one request per tenant
    // cold and seeds the cache; wave 2's six requests each fork the
    // cached prefix instead of re-prefilling it.
    use std::sync::atomic::Ordering;
    let metrics = Arc::new(EngineMetrics::new());
    let mut cfg = EngineConfig::new("tiny-lm-a");
    cfg.pool_threads = 1;
    let mut engine = Engine::new(
        Box::new(NativeEngine::tiny()),
        cfg,
        Arc::clone(&metrics),
    )
    .unwrap();
    let reqs = generate(&WorkloadSpec::shared_prefix(9, 3, 32));
    assert_eq!(reqs.len(), 9);
    let (reply_tx, reply_rx) = channel();
    let mut it = reqs.into_iter();
    // wave 1: one request per tenant, all cold
    for t in it.by_ref().take(3) {
        engine.submit(t.req, reply_tx.clone());
    }
    while engine.step().unwrap() {}
    assert_eq!(
        metrics.prefix_hit_blocks.load(Ordering::Relaxed),
        0,
        "first request of each tenant must prefill cold"
    );
    // wave 2: two more per tenant — each reuses the 32-token prefix
    for t in it {
        engine.submit(t.req, reply_tx.clone());
    }
    while engine.step().unwrap() {}
    drop(reply_tx);
    assert_eq!(
        metrics.prefix_hit_blocks.load(Ordering::Relaxed),
        12,
        "6 warm requests x 2 shared blocks each"
    );
    assert_eq!(
        metrics.prefix_hit_tokens.load(Ordering::Relaxed),
        6 * 32,
        "every warm request skips the full 32-token prefix"
    );
    assert!(metrics.prefix_cache_nodes.load(Ordering::Relaxed) > 0);
    engine.kv_invariants().unwrap();
    let responses: Vec<_> = reply_rx.try_iter().collect();
    assert_eq!(responses.len(), 9, "every request must complete");
    for r in &responses {
        assert!(!r.tokens.is_empty() && r.tokens.len() <= 8);
    }
}

#[test]
fn prefix_cache_survives_run_restart() {
    // ROADMAP follow-up (ISSUE 8 bugfix): the prefix cache used to be
    // cleared when `run` returned, so a warm restart — a second `run`
    // on the same engine — re-prefilled prefixes it had already
    // cached. Two runs over the same tenants must now show run 2
    // getting pure cache hits from run 1's registrations.
    use std::sync::atomic::Ordering;
    let metrics = Arc::new(EngineMetrics::new());
    let mut cfg = EngineConfig::new("tiny-lm-a");
    cfg.pool_threads = 1;
    let mut engine = Engine::new(
        Box::new(NativeEngine::tiny()),
        cfg,
        Arc::clone(&metrics),
    )
    .unwrap();
    let reqs = generate(&WorkloadSpec::shared_prefix(6, 3, 32));
    let (reply_tx, reply_rx) = channel();
    // run 1: one request per tenant, all cold
    let (tx, rx) = channel();
    for t in reqs.iter().take(3) {
        tx.send(EngineMsg::Submit(t.req.clone(), reply_tx.clone()))
            .unwrap();
    }
    drop(tx);
    engine.run(rx).unwrap();
    assert_eq!(
        metrics.prefix_hit_blocks.load(Ordering::Relaxed),
        0,
        "run 1 is cold"
    );
    assert!(
        metrics.prefix_cache_nodes.load(Ordering::Relaxed) > 0,
        "run 1 must leave the cache warm for the next run"
    );
    // run 2 (warm restart): same tenants — every request forks the
    // 32-token (2-block) prefix cached by run 1
    let (tx, rx) = channel();
    for t in reqs.iter().skip(3) {
        tx.send(EngineMsg::Submit(t.req.clone(), reply_tx.clone()))
            .unwrap();
    }
    drop(tx);
    engine.run(rx).unwrap();
    drop(reply_tx);
    assert_eq!(
        metrics.prefix_hit_blocks.load(Ordering::Relaxed),
        6,
        "3 warm-restart requests x 2 shared blocks each"
    );
    assert_eq!(
        metrics.prefix_hit_tokens.load(Ordering::Relaxed),
        3 * 32,
        "every run-2 request skips its full 32-token prefix"
    );
    let responses: Vec<_> = reply_rx.try_iter().collect();
    assert_eq!(responses.len(), 6, "both runs complete their requests");
    engine.clear_prefix_cache();
    engine.kv_invariants().unwrap();
    let (free, total) = engine.kv_blocks();
    assert_eq!(free, total, "blocks leaked across the restart");
}

#[test]
fn long_prompt_no_longer_head_of_line_blocks_shorts() {
    // ISSUE 8: under one-shot prefill a long prompt monopolizes the
    // iteration it is admitted into, so short requests behind it wait
    // out its entire prefill (head-of-line blocking). Chunked prefill
    // splits it across iterations and co-schedules the shorts. Both
    // engines serve the same heavy-tail-derived workload with a
    // 64-token iteration budget; completion order and per-response
    // TTFT flip between them.
    let spec = WorkloadSpec::heavy_tail(8);
    let mut prompts: Vec<Vec<i32>> =
        generate(&spec).into_iter().map(|t| t.req.prompt).collect();
    prompts.sort_by_key(|p| p.len());
    // the heavy-tail head, stretched to the 64-token seq cap; the 3
    // shortest tail requests, clamped to one 16-token chunk so each
    // completes in its first iteration
    let mut long = prompts.pop().unwrap();
    while long.len() < 64 {
        long.push(long[long.len() % 8]);
    }
    let shorts: Vec<Vec<i32>> = prompts
        .into_iter()
        .take(3)
        .map(|mut p| {
            p.truncate(16);
            p
        })
        .collect();
    let serve = |chunk_tokens: usize| -> Vec<(u64, f64)> {
        let metrics = Arc::new(EngineMetrics::new());
        let mut cfg = EngineConfig::new("tiny-lm-a");
        cfg.pool_threads = 1;
        cfg.max_wait_secs = 0.0;
        cfg.prefix_cache = false;
        cfg.chunk_tokens = chunk_tokens;
        cfg.iteration_budget = 64;
        let mut engine = Engine::new(
            Box::new(NativeEngine::tiny()),
            cfg,
            Arc::clone(&metrics),
        )
        .unwrap();
        let (reply_tx, reply_rx) = channel();
        let mk = |id: u64, prompt: Vec<i32>| Request {
            id,
            prompt,
            max_new_tokens: 1,
            config: SparsityConfig::parse("dense").unwrap(),
            deadline_ticks: 0,
        };
        engine.submit(mk(0, long.clone()), reply_tx.clone());
        for (i, s) in shorts.iter().enumerate() {
            engine.submit(mk(1 + i as u64, s.clone()), reply_tx.clone());
        }
        while engine.step().unwrap() {}
        drop(reply_tx);
        engine.kv_invariants().unwrap();
        // completion order with each response's TTFT
        reply_rx.try_iter().map(|r| (r.id, r.ttft_secs)).collect()
    };
    // one-shot: the 64-token head fills the whole iteration budget, so
    // it runs alone first and every short waits out its prefill
    let one_shot = serve(usize::MAX);
    assert_eq!(one_shot.len(), 4, "every request completes");
    assert_eq!(
        one_shot[0].0, 0,
        "one-shot: the long prompt completes first (HOL blocking)"
    );
    // chunked: the long prompt's first 16-token chunk shares iteration
    // 1 with all three shorts, which complete immediately; the long
    // prompt finishes three iterations later
    let chunked = serve(16);
    assert_eq!(chunked.len(), 4, "every request completes");
    assert_eq!(
        chunked[3].0, 0,
        "chunked: the long prompt must complete last"
    );
    let long_ttft = chunked[3].1;
    for (id, ttft) in &chunked[..3] {
        assert!(
            *ttft < long_ttft,
            "short {id} must reach its first token before the long \
             prompt ({ttft} vs {long_ttft})"
        );
    }
}

#[test]
fn burst_overload_sheds_degrades_and_cancels_deadlines() {
    // ISSUE 9 e2e: a 40-request burst (the bursty_deadlines workload)
    // hits admission at once, half the requests on a 3-tick deadline.
    // The overload watermarks first tighten dense requests to 4:8,
    // then shed outright; the deadline sweeps cancel what cannot be
    // served in time. Every request still gets exactly one response,
    // the error taxonomy accounts for all of them, and the block pool
    // drains clean.
    use std::sync::atomic::Ordering;
    let spec = WorkloadSpec::bursty_deadlines(40, 8, 3);
    let reqs: Vec<Request> =
        generate(&spec).into_iter().map(|t| t.req).collect();
    assert!(
        reqs.iter().any(|r| r.deadline_ticks == 3)
            && reqs.iter().any(|r| r.deadline_ticks == 0),
        "the workload must mix deadlines"
    );
    let metrics = Arc::new(EngineMetrics::new());
    let mut cfg = EngineConfig::new("tiny-lm-a");
    cfg.pool_threads = 1;
    cfg.max_wait_secs = 0.0;
    cfg.prefix_cache = false;
    // ~1200 prompt tokens arrive at once: past 200 queued tokens the
    // admission path degrades dense to 4:8, past 600 it sheds
    cfg.degrade_policy = Some(DegradePolicy {
        degrade_at: 200,
        shed_at: 600,
    });
    let mut engine = Engine::new(
        Box::new(NativeEngine::tiny()),
        cfg,
        Arc::clone(&metrics),
    )
    .unwrap();
    let (reply_tx, reply_rx) = channel();
    for r in reqs {
        engine.submit(r, reply_tx.clone());
    }
    let mut spins = 0usize;
    loop {
        let worked = engine.step().unwrap();
        let pending = engine.queued_requests()
            + engine.flight_requests()
            + engine.active_requests()
            + engine.parked_requests();
        if pending == 0 {
            break;
        }
        spins = if worked { 0 } else { spins + 1 };
        assert!(spins <= 1_000, "drain stalled: {pending} pending");
    }
    drop(reply_tx);

    let sheds = metrics.sheds.load(Ordering::Relaxed);
    let degraded = metrics.degraded.load(Ordering::Relaxed);
    let timeouts = metrics.timeouts.load(Ordering::Relaxed);
    assert!(sheds > 0, "the burst must overflow the shed watermark");
    assert!(degraded > 0, "the burst must cross the degrade watermark");
    assert!(timeouts > 0, "tight deadlines must cancel under overload");

    let responses: Vec<_> = reply_rx.try_iter().collect();
    assert_eq!(responses.len(), 40, "exactly one response per request");
    let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    assert_eq!(
        ids,
        (0..40).collect::<Vec<u64>>(),
        "no request lost or duplicated"
    );
    let served = responses.iter().filter(|r| r.error.is_none()).count();
    assert!(served > 0, "the engine must still serve under overload");
    for r in responses.iter().filter(|r| r.error.is_none()) {
        assert!(!r.tokens.is_empty(), "served response without tokens");
    }
    // no faults are injected here, so every request either completed,
    // was shed at admission or was cancelled by its deadline
    assert_eq!(
        served as u64 + sheds + timeouts,
        40,
        "the error taxonomy must account for every request"
    );
    engine.kv_invariants().unwrap();
    let (free, total) = engine.kv_blocks();
    assert_eq!(free, total, "blocks leaked under overload");
}

#[test]
fn replica_kill_mid_burst_keeps_the_error_taxonomy_exact() {
    // ISSUE 10 e2e: the overload burst above, served by a 2-replica
    // pool with one replica killed while the burst is in flight. The
    // victim's work fails over to the survivor; every request still
    // gets exactly one response, and the response-level taxonomy is
    // exact: served + shed + deadline-cancelled == n (a crash adds no
    // fourth category — failover re-dispatch absorbs it).
    use std::sync::atomic::Ordering;
    use std::time::{Duration, Instant};

    use amber_pruner::coordinator::error::ErrorKind;
    use amber_pruner::coordinator::replica::{
        EngineFactory, PoolConfig, ReplicaPool,
    };

    let spec = WorkloadSpec::bursty_deadlines(40, 8, 3);
    let reqs: Vec<Request> =
        generate(&spec).into_iter().map(|t| t.req).collect();
    let metrics = Arc::new(EngineMetrics::new());
    let m = Arc::clone(&metrics);
    let factory: EngineFactory = Arc::new(move |_i| {
        let mut cfg = EngineConfig::new("tiny-lm-a");
        cfg.pool_threads = 1;
        cfg.max_wait_secs = 0.0;
        cfg.prefix_cache = false;
        // per-replica watermarks at half the single-engine test's
        // levels: the burst splits across two engines
        cfg.degrade_policy = Some(DegradePolicy {
            degrade_at: 100,
            shed_at: 300,
        });
        Engine::new(Box::new(NativeEngine::tiny()), cfg, Arc::clone(&m))
    });
    let mut pcfg = PoolConfig::new(2);
    pcfg.heartbeat_timeout = Duration::ZERO;
    pcfg.poll = Duration::from_millis(1);
    let mut pool =
        ReplicaPool::start(factory, Arc::clone(&metrics), pcfg).unwrap();
    let handle = pool.handle();
    let (reply_tx, reply_rx) = channel();
    for r in &reqs {
        handle.submit(r.clone(), reply_tx.clone()).unwrap();
    }
    // pick whichever replica holds the most of the burst and kill it
    // mid-flight (the stall pins its queue while the crash lands)
    let deadline = Instant::now() + Duration::from_secs(10);
    let victim = loop {
        let snap = handle.snapshot().unwrap();
        let busiest =
            snap.iter().max_by_key(|s| s.outstanding).unwrap();
        if busiest.outstanding > 0 {
            break busiest.index;
        }
        assert!(
            Instant::now() < deadline,
            "the burst never reached a replica"
        );
        std::thread::sleep(Duration::from_micros(500));
    };
    handle.stall(victim, 50);
    handle.kill(victim);
    drop(reply_tx);

    let responses: Vec<_> = (0..40)
        .map(|k| {
            reply_rx
                .recv_timeout(Duration::from_secs(60))
                .unwrap_or_else(|_| {
                    panic!("response {k} of 40 never arrived")
                })
        })
        .collect();
    let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    assert_eq!(
        ids,
        (0..40).collect::<Vec<u64>>(),
        "no request lost or duplicated across the kill"
    );
    let served =
        responses.iter().filter(|r| r.error.is_none()).count();
    let sheds = responses
        .iter()
        .filter(|r| {
            r.error.as_ref().is_some_and(|e| {
                e.kind == ErrorKind::Rejected
                    && e.reason.starts_with("overloaded")
            })
        })
        .count();
    let timeouts = responses
        .iter()
        .filter(|r| {
            r.error.as_ref().is_some_and(|e| {
                e.kind == ErrorKind::Rejected
                    && e.reason.starts_with("deadline")
            })
        })
        .count();
    assert!(served > 0, "the pool must still serve through the kill");
    assert!(sheds > 0, "the burst must overflow the shed watermark");
    assert!(timeouts > 0, "tight deadlines must cancel under overload");
    assert_eq!(
        served + sheds + timeouts,
        40,
        "the error taxonomy must account for every request \
         (a replica crash must not add a fourth category)"
    );
    assert!(
        metrics.replica_redispatches.load(Ordering::Relaxed) > 0,
        "the kill must land while the burst is in flight"
    );
    assert!(
        metrics.replica_restarts.load(Ordering::Relaxed) > 0,
        "the supervisor must restart the killed replica"
    );
    pool.shutdown().unwrap();
}
