//! End-to-end coordinator test through the native engine (ISSUE 1
//! satellite): enqueue mixed-ratio requests, drive the real scheduler
//! loop, and assert completion order, coverage accounting and the N:M
//! validity of every pruned activation.

use std::sync::mpsc::channel;
use std::sync::Arc;

use amber_pruner::coordinator::scheduler::{
    Engine, EngineConfig, EngineMsg,
};
use amber_pruner::coordinator::request::{Request, SparsityConfig};
use amber_pruner::metrics::EngineMetrics;
use amber_pruner::runtime::NativeEngine;
use amber_pruner::server::workload::{generate, WorkloadSpec};
use amber_pruner::util::rng::Rng;

fn prompt(rng: &mut Rng, len: usize) -> Vec<i32> {
    (0..len).map(|_| 1 + rng.below(300) as i32).collect()
}

#[test]
fn mixed_ratio_workload_completes_with_valid_sparsity() {
    let metrics = Arc::new(EngineMetrics::new());
    let mut engine = Engine::new(
        Box::new(NativeEngine::tiny()),
        EngineConfig::new("tiny-lm-a"),
        Arc::clone(&metrics),
    )
    .unwrap();

    // every ratio x {fp, sq} plus dense — one bucket per config
    let configs: Vec<SparsityConfig> = [
        "dense", "2:4:ls", "4:8:naive", "8:16:all", "2:4:ls+sq", "dense+sq",
    ]
    .iter()
    .map(|s| SparsityConfig::parse(s).unwrap())
    .collect();

    let (tx, rx) = channel();
    let (reply_tx, reply_rx) = channel();
    let mut rng = Rng::new(11);
    let n = 18u64;
    for id in 0..n {
        let len = 6 + rng.usize_below(32);
        tx.send(EngineMsg::Submit(
            Request {
                id,
                prompt: prompt(&mut rng, len),
                max_new_tokens: 4,
                config: configs[(id as usize) % configs.len()],
            },
            reply_tx.clone(),
        ))
        .unwrap();
    }
    drop(tx);
    drop(reply_tx);
    engine.run(rx).unwrap();

    let responses: Vec<_> = reply_rx.try_iter().collect();
    assert_eq!(responses.len(), n as usize, "every request must complete");
    let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..n).collect::<Vec<_>>());
    for r in &responses {
        assert!(!r.tokens.is_empty() && r.tokens.len() <= 4);
        assert!(r.e2e_secs >= r.ttft_secs && r.ttft_secs >= 0.0);
    }

    // KV slots + block pool drained cleanly
    engine.kv_invariants().unwrap();

    // coverage accounting: sparse configs really took the pruned path,
    // and every pruned activation satisfied exact N:M
    let audit = engine.audit().expect("native engine must audit");
    assert!(audit.pruned_matmuls > 0, "no pruned matmuls recorded");
    assert!(audit.dense_matmuls > 0, "dense path must also run");
    assert!(audit.nm_checks > 0, "validation must be on");
    assert_eq!(audit.nm_violations, 0, "N:M contract violated");
    assert_eq!(audit.pruned_fallbacks, 0, "unexpected dense fallback");
    assert!(
        audit.flops_saved_frac() > 0.0,
        "sparse prefill saved no FLOPs"
    );

    use std::sync::atomic::Ordering;
    assert_eq!(
        metrics.requests_completed.load(Ordering::Relaxed),
        n
    );
    assert!(metrics.prefill_batches.load(Ordering::Relaxed) >= 6);
}

#[test]
fn single_config_batch_completes_in_submission_order() {
    // one bucket, one prefill batch, equal generation budgets: the
    // decode loop iterates slots in sorted-id order, so completions are
    // reported in submission order.
    let metrics = Arc::new(EngineMetrics::new());
    let mut engine = Engine::new(
        Box::new(NativeEngine::tiny()),
        EngineConfig::new("tiny-lm-a"),
        Arc::clone(&metrics),
    )
    .unwrap();
    let (tx, rx) = channel();
    let (reply_tx, reply_rx) = channel();
    let mut rng = Rng::new(5);
    for id in 0..8u64 {
        tx.send(EngineMsg::Submit(
            Request {
                id,
                prompt: prompt(&mut rng, 12),
                max_new_tokens: 2,
                config: SparsityConfig::parse("8:16:ls").unwrap(),
            },
            reply_tx.clone(),
        ))
        .unwrap();
    }
    drop(tx);
    drop(reply_tx);
    engine.run(rx).unwrap();
    let responses: Vec<_> = reply_rx.try_iter().collect();
    assert_eq!(responses.len(), 8);
    // deterministic completion order: sequences that finished at prefill
    // admission (immediate EOS -> 1 token) are reported first in id
    // order, then the decode-step completions in id order.
    let order: Vec<u64> = responses.iter().map(|r| r.id).collect();
    let mut expected: Vec<u64> = responses
        .iter()
        .filter(|r| r.tokens.len() == 1)
        .map(|r| r.id)
        .collect();
    expected.sort_unstable();
    let mut decode_done: Vec<u64> = responses
        .iter()
        .filter(|r| r.tokens.len() > 1)
        .map(|r| r.id)
        .collect();
    decode_done.sort_unstable();
    expected.extend(decode_done);
    assert_eq!(order, expected);
    engine.kv_invariants().unwrap();
    let audit = engine.audit().unwrap();
    assert_eq!(audit.nm_violations, 0);
    assert!(audit.pruned_matmuls > 0);
}

#[test]
fn shared_prefix_tenants_hit_the_prefix_cache() {
    // the canonical multi-tenant prefix-cache workload (ISSUE 6): 9
    // requests across 3 tenants, each tenant sharing a 32-token
    // (2-block) prompt prefix. Wave 1 serves one request per tenant
    // cold and seeds the cache; wave 2's six requests each fork the
    // cached prefix instead of re-prefilling it. Driven by manual
    // `step()` (run() clears the cache on exit).
    use std::sync::atomic::Ordering;
    let metrics = Arc::new(EngineMetrics::new());
    let mut cfg = EngineConfig::new("tiny-lm-a");
    cfg.pool_threads = 1;
    let mut engine = Engine::new(
        Box::new(NativeEngine::tiny()),
        cfg,
        Arc::clone(&metrics),
    )
    .unwrap();
    let reqs = generate(&WorkloadSpec::shared_prefix(9, 3, 32));
    assert_eq!(reqs.len(), 9);
    let (reply_tx, reply_rx) = channel();
    let mut it = reqs.into_iter();
    // wave 1: one request per tenant, all cold
    for t in it.by_ref().take(3) {
        engine.submit(t.req, reply_tx.clone());
    }
    while engine.step().unwrap() {}
    assert_eq!(
        metrics.prefix_hit_blocks.load(Ordering::Relaxed),
        0,
        "first request of each tenant must prefill cold"
    );
    // wave 2: two more per tenant — each reuses the 32-token prefix
    for t in it {
        engine.submit(t.req, reply_tx.clone());
    }
    while engine.step().unwrap() {}
    drop(reply_tx);
    assert_eq!(
        metrics.prefix_hit_blocks.load(Ordering::Relaxed),
        12,
        "6 warm requests x 2 shared blocks each"
    );
    assert_eq!(
        metrics.prefix_hit_tokens.load(Ordering::Relaxed),
        6 * 32,
        "every warm request skips the full 32-token prefix"
    );
    assert!(metrics.prefix_cache_nodes.load(Ordering::Relaxed) > 0);
    engine.kv_invariants().unwrap();
    let responses: Vec<_> = reply_rx.try_iter().collect();
    assert_eq!(responses.len(), 9, "every request must complete");
    for r in &responses {
        assert!(!r.tokens.is_empty() && r.tokens.len() <= 8);
    }
}
