//! Paged-vs-slot golden-parity suite (ISSUE 3): block-paged KV must be
//! a pure storage-layout change. Packed prefill + decode through
//! [`KvPages`] block tables produce **bitwise-identical** logits to the
//! pre-existing contiguous-slot path (`Engine::decode` over
//! `[L, B, C, H, D]` caches) across block sizes {8, 16, DEFAULT_BLOCK},
//! through both the native block-addressed kernel and the default
//! gather/scatter `decode_paged`; and a prompt longer than any
//! contiguous free run still admits (scattered table) and decodes
//! identically.

use std::collections::HashMap;
use std::sync::mpsc::channel;
use std::sync::Arc;

use amber_pruner::coordinator::kv::KvPages;
use amber_pruner::coordinator::paged::{BlockPool, DEFAULT_BLOCK};
use amber_pruner::coordinator::request::{Request, SparsityConfig};
use amber_pruner::coordinator::scheduler::{
    Engine as ServeEngine, EngineConfig, EngineMsg, PAD,
};
use amber_pruner::metrics::EngineMetrics;
use amber_pruner::runtime::{
    DecodeOut, Engine, Manifest, ModelSpec, NativeEngine, PrefillOut,
};
use amber_pruner::tensor::math::argmax;
use amber_pruner::util::rng::Rng;
use anyhow::Result;

const MODEL: &str = "tiny-lm-a";
// tiny-lm geometry (ModelSpec::tiny)
const L: usize = 2;
const KVD: usize = 16;
const DEC_B: usize = 8;
const CACHE: usize = 96;

fn prompt(rng: &mut Rng, len: usize) -> Vec<i32> {
    (0..len).map(|_| 1 + rng.below(300) as i32).collect()
}

/// Wraps the native engine but hides its `decode_paged` override, so
/// calls fall through to the trait's default gather/scatter
/// implementation (what a static-shape PJRT backend would execute).
struct DefaultPaged(NativeEngine);

impl Engine for DefaultPaged {
    fn platform(&self) -> String {
        self.0.platform()
    }
    fn manifest(&self) -> &Manifest {
        self.0.manifest()
    }
    fn load_artifact(&mut self, name: &str) -> Result<f64> {
        self.0.load_artifact(name)
    }
    fn bind(&mut self, artifact: &str, files: &[&str]) -> Result<String> {
        self.0.bind(artifact, files)
    }
    fn prefill(
        &mut self,
        artifact: &str,
        binding: &str,
        tokens: &[i32],
    ) -> Result<PrefillOut> {
        self.0.prefill(artifact, binding, tokens)
    }
    fn decode(
        &mut self,
        artifact: &str,
        binding: &str,
        token: &[i32],
        pos: &[i32],
        k_cache: &[f32],
        v_cache: &[f32],
        kv_len: &[i32],
    ) -> Result<DecodeOut> {
        self.0
            .decode(artifact, binding, token, pos, k_cache, v_cache, kv_len)
    }
}

/// The pre-existing slot path: scatter each request's packed prefill KV
/// rows into a contiguous `[L, B, C, kvd]` cache (slot = request index),
/// then drive `Engine::decode` for `steps` steps, absorbing the returned
/// caches — exactly what the pre-paging scheduler did. Returns the
/// per-step logits rows of every sequence.
fn slot_reference(
    e: &mut NativeEngine,
    dec_bind: &str,
    packed_k: &[f32],
    packed_v: &[f32],
    lens: &[usize],
    first_tokens: &[i32],
    steps: usize,
) -> Vec<Vec<Vec<f32>>> {
    let total: usize = lens.iter().sum();
    let mut kc = vec![0.0f32; L * DEC_B * CACHE * KVD];
    let mut vc = vec![0.0f32; L * DEC_B * CACHE * KVD];
    for (slot, &len) in lens.iter().enumerate() {
        let start: usize = lens[..slot].iter().sum();
        for l in 0..L {
            let src = (l * total + start) * KVD;
            let dst = ((l * DEC_B + slot) * CACHE) * KVD;
            kc[dst..dst + len * KVD]
                .copy_from_slice(&packed_k[src..src + len * KVD]);
            vc[dst..dst + len * KVD]
                .copy_from_slice(&packed_v[src..src + len * KVD]);
        }
    }
    let dec = format!("{MODEL}.decode.dense");
    let mut last: Vec<i32> = first_tokens.to_vec();
    let mut pos_len: Vec<usize> = lens.to_vec();
    let mut out_steps = vec![Vec::new(); lens.len()];
    for _ in 0..steps {
        let mut token = vec![PAD; DEC_B];
        let mut pos = vec![0i32; DEC_B];
        let mut kv_len = vec![1i32; DEC_B];
        for slot in 0..lens.len() {
            token[slot] = last[slot];
            pos[slot] = pos_len[slot] as i32;
            kv_len[slot] = (pos_len[slot] + 1) as i32;
        }
        let out = e
            .decode(&dec, dec_bind, &token, &pos, &kc, &vc, &kv_len)
            .unwrap();
        kc = out.k_cache;
        vc = out.v_cache;
        for slot in 0..lens.len() {
            let row =
                out.logits[slot * out.vocab..(slot + 1) * out.vocab].to_vec();
            last[slot] = argmax(&row) as i32;
            pos_len[slot] += 1;
            out_steps[slot].push(row);
        }
    }
    out_steps
}

/// Drive the same decode through a [`KvPages`] store with the given
/// block size (native override or default gather per `use_default`).
#[allow(clippy::too_many_arguments)]
fn paged_run(
    e: &mut dyn Engine,
    dec_bind: &str,
    block: usize,
    packed_k: &[f32],
    packed_v: &[f32],
    lens: &[usize],
    first_tokens: &[i32],
    steps: usize,
) -> Vec<Vec<Vec<f32>>> {
    let n_blocks = DEC_B * CACHE / block;
    let mut kv = KvPages::new(L, n_blocks, block, 1, KVD, CACHE);
    let total: usize = lens.iter().sum();
    for (i, &len) in lens.iter().enumerate() {
        let start: usize = lens[..i].iter().sum();
        kv.admit_packed(
            i as u64, packed_k, packed_v, start, total, len,
            len + steps,
        )
        .unwrap();
    }
    let dec = format!("{MODEL}.decode.dense");
    let mut last: Vec<i32> = first_tokens.to_vec();
    let mut out_steps = vec![Vec::new(); lens.len()];
    let mut rows: Vec<Option<u64>> = vec![None; DEC_B];
    for (i, r) in rows.iter_mut().enumerate().take(lens.len()) {
        *r = Some(i as u64);
    }
    for _ in 0..steps {
        let mut token = vec![PAD; DEC_B];
        let mut pos = vec![0i32; DEC_B];
        let mut kv_len = vec![1i32; DEC_B];
        for (i, _) in lens.iter().enumerate() {
            let len = kv.seq_len(i as u64).unwrap();
            kv.ensure_capacity(i as u64, len + 1).unwrap();
            token[i] = last[i];
            pos[i] = len as i32;
            kv_len[i] = (len + 1) as i32;
        }
        let mut view = kv.view(&rows);
        let out = e
            .decode_paged(&dec, dec_bind, &token, &pos, &mut view, &kv_len)
            .unwrap();
        for (i, _) in lens.iter().enumerate() {
            kv.advance(i as u64).unwrap();
            let row =
                out.logits[i * out.vocab..(i + 1) * out.vocab].to_vec();
            last[i] = argmax(&row) as i32;
            out_steps[i].push(row);
        }
    }
    kv.check_invariants().unwrap();
    out_steps
}

#[test]
fn paged_decode_bitwise_matches_slot_decode_across_block_sizes() {
    let mut rng = Rng::new(77);
    let lens = [37usize, 64, 5];
    let prompts: Vec<Vec<i32>> =
        lens.iter().map(|&l| prompt(&mut rng, l)).collect();
    let steps = 6usize;

    let mut e = NativeEngine::synthetic(vec![ModelSpec::tiny(MODEL)]);
    let art = format!("{MODEL}.prefill64.nm2_4");
    let bind = e
        .bind(&art, &[&format!("{MODEL}.atw"),
                      &format!("{MODEL}.aux_ls.atw")])
        .unwrap();
    let dec = format!("{MODEL}.decode.dense");
    let dec_bind = e.bind(&dec, &[&format!("{MODEL}.atw")]).unwrap();
    let pre = e.prefill_packed(&art, &bind, &prompts).unwrap();
    assert_eq!(pre.lens, lens.to_vec());
    let firsts: Vec<i32> = lens
        .iter()
        .enumerate()
        .map(|(i, &len)| {
            let start = pre.row_start(i);
            argmax(
                &pre.logits
                    [(start + len - 1) * pre.vocab..(start + len) * pre.vocab],
            ) as i32
        })
        .collect();

    let golden = slot_reference(
        &mut e, &dec_bind, &pre.k_cache, &pre.v_cache, &lens, &firsts,
        steps,
    );

    for block in [8usize, 16, DEFAULT_BLOCK] {
        // native block-addressed decode
        let got = paged_run(
            &mut e, &dec_bind, block, &pre.k_cache, &pre.v_cache, &lens,
            &firsts, steps,
        );
        assert_eq!(got, golden, "native paged decode, block {block}");
        // default gather/scatter decode_paged (the PJRT-shaped path)
        let mut fb =
            DefaultPaged(NativeEngine::synthetic(vec![ModelSpec::tiny(
                MODEL,
            )]));
        let fb_dec_bind =
            fb.bind(&dec, &[&format!("{MODEL}.atw")]).unwrap();
        let got_default = paged_run(
            &mut fb, &fb_dec_bind, block, &pre.k_cache, &pre.v_cache,
            &lens, &firsts, steps,
        );
        assert_eq!(
            got_default, golden,
            "default gather decode_paged, block {block}"
        );
    }
}

#[test]
fn fragmented_pool_admits_long_prompt_non_contiguously() {
    // fill a small pool, free alternating sequences so no free run is
    // longer than 2 blocks, then admit a prompt needing 6 blocks: it
    // must land scattered and decode bitwise-identically to the
    // contiguous slot path.
    let block = 8usize;
    let n_blocks = DEC_B * CACHE / block; // 96 blocks
    let mut kv = KvPages::new(L, n_blocks, block, 1, KVD, CACHE);
    let filler = vec![0.25f32; L * 16 * KVD];
    for seq in 0..n_blocks as u64 / 2 {
        kv.admit_packed(seq, &filler, &filler, 0, 16, 16, 16).unwrap();
    }
    assert_eq!(kv.free_blocks(), 0);
    for seq in (0..n_blocks as u64 / 2).step_by(2) {
        kv.release(seq).unwrap();
    }
    let fs = kv.frag_stats();
    assert!(fs.free_blocks >= 6);
    assert!(
        fs.longest_free_run <= 2,
        "free list must be fragmented, got run {}",
        fs.longest_free_run
    );
    assert!(fs.fragmentation() > 0.0);

    // a 44-token prompt (6 blocks > any free run) through real prefill
    let mut rng = Rng::new(91);
    let long = prompt(&mut rng, 44);
    let mut e = NativeEngine::synthetic(vec![ModelSpec::tiny(MODEL)]);
    let art = format!("{MODEL}.prefill64.dense");
    let bind = e.bind(&art, &[&format!("{MODEL}.atw")]).unwrap();
    let dec = format!("{MODEL}.decode.dense");
    let dec_bind = e.bind(&dec, &[&format!("{MODEL}.atw")]).unwrap();
    let pre = e
        .prefill_packed(&art, &bind, std::slice::from_ref(&long))
        .unwrap();
    let steps = 4usize;
    let seq = 1000u64;
    kv.admit_packed(seq, &pre.k_cache, &pre.v_cache, 0, 44, 44,
                    44 + steps)
        .unwrap();
    let table = kv.table(seq).unwrap().to_vec();
    assert!(table.len() >= 6);
    assert!(
        table.windows(2).any(|w| w[1] != w[0] + 1),
        "table should span non-adjacent physical blocks: {table:?}"
    );

    // decode the fragmented sequence vs the contiguous slot reference
    let first = argmax(&pre.logits[43 * pre.vocab..44 * pre.vocab]) as i32;
    let golden = slot_reference(
        &mut e, &dec_bind, &pre.k_cache, &pre.v_cache, &[44], &[first],
        steps,
    );
    let mut last = first;
    let mut rows: Vec<Option<u64>> = vec![None; DEC_B];
    rows[0] = Some(seq);
    for golden_row in &golden[0] {
        let len = kv.seq_len(seq).unwrap();
        kv.ensure_capacity(seq, len + 1).unwrap();
        let mut token = vec![PAD; DEC_B];
        let mut pos = vec![0i32; DEC_B];
        let mut kv_len = vec![1i32; DEC_B];
        token[0] = last;
        pos[0] = len as i32;
        kv_len[0] = (len + 1) as i32;
        let mut view = kv.view(&rows);
        let out = e
            .decode_paged(&dec, &dec_bind, &token, &pos, &mut view,
                          &kv_len)
            .unwrap();
        kv.advance(seq).unwrap();
        let row = &out.logits[..out.vocab];
        assert_eq!(row, &golden_row[..], "fragmented decode diverged");
        last = argmax(row) as i32;
    }
    kv.check_invariants().unwrap();
}

/// The whole serving stack, end to end: identical workloads produce
/// identical token sequences at every KV block size — including W8A8,
/// whose per-token activation scales make quantized outputs independent
/// of batch composition (the per-tensor scale used to force this test
/// to fp configs only).
#[test]
fn end_to_end_serving_identical_across_block_sizes() {
    let run = |kv_block: usize| -> HashMap<u64, Vec<i32>> {
        let metrics = Arc::new(EngineMetrics::new());
        let mut cfg = EngineConfig::new(MODEL);
        cfg.kv_block = kv_block;
        cfg.pool_threads = 1;
        let mut engine = ServeEngine::new(
            Box::new(NativeEngine::tiny()),
            cfg,
            Arc::clone(&metrics),
        )
        .unwrap();
        let configs: Vec<SparsityConfig> =
            ["dense", "2:4:ls", "4:8:naive", "8:16:all", "2:4:ls+sq"]
                .iter()
                .map(|s| SparsityConfig::parse(s).unwrap())
                .collect();
        let (tx, rx) = channel();
        let (reply_tx, reply_rx) = channel();
        let mut rng = Rng::new(13);
        for id in 0..20u64 {
            let len = 4 + rng.usize_below(60);
            tx.send(EngineMsg::Submit(
                Request {
                    id,
                    prompt: prompt(&mut rng, len),
                    max_new_tokens: 3 + (id % 3) as usize,
                    config: configs[(id as usize) % configs.len()],
                    deadline_ticks: 0,
                },
                reply_tx.clone(),
            ))
            .unwrap();
        }
        drop(tx);
        drop(reply_tx);
        engine.run(rx).unwrap();
        engine.kv_invariants().unwrap();
        reply_rx.try_iter().map(|r| (r.id, r.tokens)).collect()
    };
    let golden = run(DEFAULT_BLOCK);
    assert_eq!(golden.len(), 20, "every request must complete");
    for block in [8usize, 16] {
        assert_eq!(run(block), golden, "kv_block {block}");
    }
}

/// A generation budget the cache cannot hold truncates at the
/// per-sequence cap (decode cache length) instead of erroring the
/// engine: the reservation clamps and `run_decode` force-completes the
/// sequence when its KV fills up.
#[test]
fn generation_budget_beyond_cache_truncates_instead_of_erroring() {
    let metrics = Arc::new(EngineMetrics::new());
    let mut engine = ServeEngine::new(
        Box::new(NativeEngine::tiny()),
        EngineConfig::new(MODEL),
        Arc::clone(&metrics),
    )
    .unwrap();
    let (tx, rx) = channel();
    let (reply_tx, reply_rx) = channel();
    let mut rng = Rng::new(3);
    tx.send(EngineMsg::Submit(
        Request {
            id: 0,
            prompt: prompt(&mut rng, 60),
            max_new_tokens: 500, // far beyond the 96-token cache
            config: SparsityConfig::parse("dense").unwrap(),
            deadline_ticks: 0,
        },
        reply_tx.clone(),
    ))
    .unwrap();
    drop(tx);
    drop(reply_tx);
    engine.run(rx).unwrap();
    let rs: Vec<_> = reply_rx.try_iter().collect();
    assert_eq!(rs.len(), 1, "request must complete, not error");
    // 60 prompt tokens leave CACHE - 60 decode appends; plus the first
    // token (sampled at prefill, appended by the first decode step)
    assert!(!rs[0].tokens.is_empty());
    assert!(
        rs[0].tokens.len() <= CACHE - 60 + 1,
        "generated {} tokens past the cache cap",
        rs[0].tokens.len()
    );
    engine.kv_invariants().unwrap();
}

#[test]
fn block_pool_allocation_is_scatter_tolerant_at_scale() {
    // allocator-level mirror of the fragmentation test: churn a pool
    // and confirm a max-size table is always grantable whenever the
    // free-block count says so, regardless of free-list shape
    let mut pool = BlockPool::new(64, DEFAULT_BLOCK);
    let mut rng = Rng::new(5);
    let mut live: Vec<u64> = Vec::new();
    let mut next = 0u64;
    for _ in 0..400 {
        if rng.bool(0.55) {
            let tokens = 1 + rng.usize_below(6 * DEFAULT_BLOCK);
            if pool.can_admit(tokens) {
                pool.allocate(next, tokens).unwrap();
                live.push(next);
                next += 1;
            }
        } else if !live.is_empty() {
            let i = rng.usize_below(live.len());
            pool.release(live.swap_remove(i)).unwrap();
        }
        pool.check_invariants().unwrap();
        let fs = pool.frag_stats();
        assert_eq!(fs.free_blocks, pool.free_blocks());
        // whenever enough blocks are free anywhere, admission holds
        assert_eq!(
            pool.can_admit(4 * DEFAULT_BLOCK),
            pool.free_blocks() >= 4
        );
    }
}
