//! Property tests for the N:M kernel contract (ISSUE 1 satellite):
//! the laws every pruning path must satisfy, checked over >= 100 random
//! cases per invariant across the 2:4 / 4:8 / 8:16 ratios with random
//! t / din / dout / scale draws.
//!
//! 1. `nm_mask_scored` keeps exactly n channels per m-group;
//! 2. `decompress(compress(x)) == nm_prune(x)` (bit-exact);
//! 3. `NmCompressed::matmul == dense_matmul` on the pruned input
//!    within 1e-4;
//! 4. `validate_nm` holds after every prune path.

use amber_pruner::sparsity::mask::{nm_mask_scored, nm_prune, validate_nm};
use amber_pruner::sparsity::spmm::{dense_matmul, NmCompressed};
use amber_pruner::testutil::prop::{prop_check, Gen};
use amber_pruner::util::rng::Rng;

const RATIOS: [(usize, usize); 3] = [(2, 4), (4, 8), (8, 16)];

/// Random (t, din, scale, x) tuple for one ratio; din is a multiple of m.
fn gen_case(
    rng: &mut Rng,
    size: usize,
    m: usize,
) -> (usize, usize, Vec<f32>, Vec<f32>) {
    let t = Gen::usize(rng, 1, 1 + size % 8);
    let groups = Gen::usize(rng, 1, 1 + size % 6);
    let din = groups * m;
    let x = Gen::f32_vec(rng, t * din, 2.0);
    // scale: empty (naive magnitude) half the time, else random positive
    let scale: Vec<f32> = if rng.bool(0.5) {
        Vec::new()
    } else {
        (0..din).map(|_| rng.f32() * 3.0 + 0.05).collect()
    };
    (t, din, scale, x)
}

#[test]
fn prop_mask_keeps_exactly_n_per_group() {
    prop_check("mask-exactly-n-per-group", 150, |rng, size| {
        let &(n, m) = Gen::choice(rng, &RATIOS);
        let (t, din, scale, x) = gen_case(rng, size, m);
        for r in 0..t {
            let row = &x[r * din..(r + 1) * din];
            let mask = nm_mask_scored(row, &scale, n, m);
            for (g, chunk) in mask.chunks_exact(m).enumerate() {
                let kept = chunk.iter().filter(|k| **k).count();
                if kept != n {
                    return Err(format!(
                        "row {r} group {g}: kept {kept} != n {n} \
                         (ratio {n}:{m})"
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_decompress_compress_equals_prune() {
    prop_check("decompress-compress-eq-prune", 150, |rng, size| {
        let &(n, m) = Gen::choice(rng, &RATIOS);
        let (t, din, scale, x) = gen_case(rng, size, m);
        let c = NmCompressed::compress(&x, t, din, &scale, n, m);
        let round = c.decompress();
        for r in 0..t {
            let want = nm_prune(&x[r * din..(r + 1) * din], &scale, n, m);
            let got = &round[r * din..(r + 1) * din];
            if got != &want[..] {
                return Err(format!(
                    "row {r} roundtrip mismatch at ratio {n}:{m}"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_compressed_matmul_equals_dense_on_pruned() {
    prop_check("spmm-eq-dense-on-pruned", 120, |rng, size| {
        let &(n, m) = Gen::choice(rng, &RATIOS);
        let (t, din, scale, x) = gen_case(rng, size, m);
        let dout = Gen::usize(rng, 1, 4 + size);
        let w = Gen::f32_vec(rng, din * dout, 1.0);
        let c = NmCompressed::compress(&x, t, din, &scale, n, m);
        let y_sparse = c.matmul(&w, dout);
        let y_dense = dense_matmul(&c.decompress(), t, din, &w, dout);
        for (i, (a, b)) in y_sparse.iter().zip(y_dense.iter()).enumerate()
        {
            if (a - b).abs() >= 1e-4 {
                return Err(format!(
                    "elem {i}: sparse {a} vs dense {b} at ratio {n}:{m} \
                     (t={t} din={din} dout={dout})"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_validate_nm_holds_after_every_prune_path() {
    prop_check("validate-nm-after-prune", 150, |rng, size| {
        let &(n, m) = Gen::choice(rng, &RATIOS);
        let (t, din, scale, x) = gen_case(rng, size, m);
        // path 1: nm_prune
        for r in 0..t {
            let pruned = nm_prune(&x[r * din..(r + 1) * din], &scale, n, m);
            if !validate_nm(&pruned, n, m) {
                return Err(format!("nm_prune row {r} violates {n}:{m}"));
            }
        }
        // path 2: compress -> decompress
        let c = NmCompressed::compress(&x, t, din, &scale, n, m);
        for (r, row) in c.decompress().chunks_exact(din).enumerate() {
            if !validate_nm(row, n, m) {
                return Err(format!("compress row {r} violates {n}:{m}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_scale_reweights_but_preserves_structure() {
    // scored selection changes WHICH channels survive, never HOW MANY
    prop_check("scale-preserves-structure", 100, |rng, size| {
        let &(n, m) = Gen::choice(rng, &RATIOS);
        let (_, din, _, _) = gen_case(rng, size, m);
        let x = Gen::f32_vec(rng, din, 1.0);
        let scale: Vec<f32> =
            (0..din).map(|_| rng.f32() * 10.0 + 0.01).collect();
        let naive = nm_prune(&x, &[], n, m);
        let scored = nm_prune(&x, &scale, n, m);
        if !validate_nm(&naive, n, m) || !validate_nm(&scored, n, m) {
            return Err(format!("structure broken at {n}:{m}"));
        }
        // every kept value must be an original value
        for (a, b) in x.iter().zip(scored.iter()) {
            if *b != 0.0 && a != b {
                return Err("scored pruning altered a kept value".into());
            }
        }
        Ok(())
    });
}
