//! Scheduler property suite (ISSUE 8): drive the real continuous-
//! batching engine through randomized submit/step interleavings under
//! adversarially small block pools, random chunk sizes and prefix-cache
//! settings, and check the admission/preemption invariants — no block
//! leaks, allocation never exceeds pool capacity, and every admitted
//! request completes with tokens identical to an undisturbed one-shot
//! reference run (so preempt-and-resume is invisible to the client).
//! Deterministic companions pin the preemption path itself and the
//! no-decode-starvation guarantee while prefill chunks are pending.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::channel;
use std::sync::Arc;

use amber_pruner::coordinator::request::{Request, SparsityConfig};
use amber_pruner::coordinator::scheduler::{Engine, EngineConfig};
use amber_pruner::metrics::EngineMetrics;
use amber_pruner::runtime::NativeEngine;
use amber_pruner::testutil::prop::{prop_check, Gen};
use amber_pruner::util::rng::Rng;

const MODEL: &str = "tiny-lm-a";

fn prompt(rng: &mut Rng, len: usize) -> Vec<i32> {
    (0..len).map(|_| 1 + rng.below(300) as i32).collect()
}

fn mk_engine(
    cfg: EngineConfig,
    metrics: &Arc<EngineMetrics>,
) -> Engine {
    Engine::new(
        Box::new(NativeEngine::tiny()),
        cfg,
        Arc::clone(metrics),
    )
    .unwrap()
}

/// Undisturbed reference: one-shot prefill, ample pool, no prefix
/// cache. Tokens from any scheduling of the same requests must match
/// this bitwise (batch-, chunk- and prefix-parity compose).
fn serve_reference(reqs: &[Request]) -> HashMap<u64, Vec<i32>> {
    let metrics = Arc::new(EngineMetrics::new());
    let mut cfg = EngineConfig::new(MODEL);
    cfg.pool_threads = 1;
    cfg.max_wait_secs = 0.0;
    cfg.chunk_tokens = usize::MAX;
    cfg.prefix_cache = false;
    let mut engine = mk_engine(cfg, &metrics);
    let (reply_tx, reply_rx) = channel();
    for r in reqs {
        engine.submit(r.clone(), reply_tx.clone());
    }
    while engine.step().unwrap() {}
    drop(reply_tx);
    engine.kv_invariants().unwrap();
    reply_rx.try_iter().map(|r| (r.id, r.tokens)).collect()
}

/// The headline property: >= 100 randomized interleavings of submit
/// and step against engines with tiny pools (forcing the preemption
/// path), random chunk sizes and prefix-cache settings. Every request
/// completes token-identically to the reference, no block leaks, the
/// peak gauge never exceeds capacity.
#[test]
fn randomized_interleavings_preserve_tokens_and_blocks() {
    let total_preempt = AtomicU64::new(0);
    let total_chunked = AtomicU64::new(0);
    prop_check("sched-model", 110, |rng, size| {
        let n = 3 + size / 3; // 3..=13 requests
        let mut reqs: Vec<Request> = Vec::new();
        for id in 0..n {
            let len = 1 + rng.usize_below(64);
            reqs.push(Request {
                id: id as u64,
                prompt: prompt(rng, len),
                max_new_tokens: 1 + rng.usize_below(6),
                config: SparsityConfig::parse(*Gen::choice(
                    rng,
                    &["dense", "2:4:ls"],
                ))
                .unwrap(),
            });
        }
        let golden = serve_reference(&reqs);
        if golden.len() != n {
            return Err(format!(
                "reference run lost requests: {} of {n}",
                golden.len()
            ));
        }

        let metrics = Arc::new(EngineMetrics::new());
        let mut cfg = EngineConfig::new(MODEL);
        cfg.pool_threads = 1;
        cfg.max_wait_secs = 0.0;
        // 6..=14 blocks (96..=224 tokens): enough for any single
        // request, far too small for the population — admission must
        // wait, reclaim and preempt, never leak or over-allocate
        cfg.kv_pool_blocks = 6 + rng.usize_below(9);
        cfg.chunk_tokens =
            *Gen::choice(rng, &[16usize, 32, usize::MAX]);
        cfg.prefix_cache = rng.bool(0.5);
        let chunked = cfg.chunk_tokens != usize::MAX;
        let mut engine = mk_engine(cfg, &metrics);
        let (reply_tx, reply_rx) = channel();

        // random interleaving of submissions and iterations
        let mut next = reqs.iter();
        let mut submitted = 0usize;
        while submitted < n {
            if rng.bool(0.6) {
                engine
                    .submit(next.next().unwrap().clone(), reply_tx.clone());
                submitted += 1;
            } else {
                engine.step().map_err(|e| format!("step: {e}"))?;
            }
        }
        // drain, with a convergence guard so a livelocked scheduler
        // fails the property instead of hanging the suite
        let mut spins = 0usize;
        loop {
            let worked =
                engine.step().map_err(|e| format!("step: {e}"))?;
            let pending = engine.queued_requests()
                + engine.flight_requests()
                + engine.active_requests();
            if pending == 0 {
                break;
            }
            spins = if worked { 0 } else { spins + 1 };
            if spins > 10_000 {
                return Err(format!(
                    "drain stalled with {pending} requests pending"
                ));
            }
        }
        drop(reply_tx);

        let got: HashMap<u64, Vec<i32>> =
            reply_rx.try_iter().map(|r| (r.id, r.tokens)).collect();
        if got.len() != n {
            return Err(format!(
                "completed {} of {n} requests",
                got.len()
            ));
        }
        if got != golden {
            let bad: Vec<u64> = golden
                .iter()
                .filter(|(id, toks)| got.get(id) != Some(toks))
                .map(|(id, _)| *id)
                .collect();
            return Err(format!(
                "tokens diverged from the one-shot reference for \
                 requests {bad:?}"
            ));
        }
        engine
            .kv_invariants()
            .map_err(|e| format!("kv invariants: {e}"))?;
        engine.clear_prefix_cache();
        let (free, total) = engine.kv_blocks();
        if free != total {
            return Err(format!(
                "block leak: {free} free of {total} after drain"
            ));
        }
        let peak = metrics.kv_blocks_peak.load(Ordering::Relaxed);
        if peak > total as u64 {
            return Err(format!(
                "allocation exceeded capacity: peak {peak} of {total}"
            ));
        }
        total_preempt.fetch_add(
            metrics.preemptions.load(Ordering::Relaxed),
            Ordering::Relaxed,
        );
        if chunked {
            total_chunked.fetch_add(1, Ordering::Relaxed);
        }
        Ok(())
    });
    // the suite must actually exercise the adversarial paths it claims
    // to cover, not pass vacuously
    assert!(
        total_preempt.load(Ordering::Relaxed) > 0,
        "no case ever preempted — pools not small enough"
    );
    assert!(
        total_chunked.load(Ordering::Relaxed) > 0,
        "no case ever ran chunked"
    );
}

/// Deterministic preemption pin: two long-generation requests on a
/// 4-block pool. The younger is preempted when the elder's decode
/// needs its blocks, is re-admitted after the elder completes, and
/// finishes with exactly the tokens of an undisturbed solo run.
#[test]
fn preempted_request_resumes_token_identically() {
    let mut rng = Rng::new(71);
    let a = Request {
        id: 0,
        prompt: prompt(&mut rng, 30),
        max_new_tokens: 20,
        config: SparsityConfig::parse("dense").unwrap(),
    };
    let b = Request {
        id: 1,
        prompt: prompt(&mut rng, 30),
        max_new_tokens: 20,
        config: SparsityConfig::parse("dense").unwrap(),
    };
    let solo_a = serve_reference(std::slice::from_ref(&a));
    let solo_b = serve_reference(std::slice::from_ref(&b));

    let metrics = Arc::new(EngineMetrics::new());
    let mut cfg = EngineConfig::new(MODEL);
    cfg.pool_threads = 1;
    cfg.max_wait_secs = 0.0;
    cfg.chunk_tokens = usize::MAX;
    cfg.prefix_cache = false;
    // 64 tokens: each request needs 2 blocks for its prompt and grows
    // to 4 by the end of generation — they cannot both finish resident
    cfg.kv_pool_blocks = 4;
    let mut engine = mk_engine(cfg, &metrics);
    let (reply_tx, reply_rx) = channel();
    engine.submit(a, reply_tx.clone());
    assert!(engine.step().unwrap(), "elder must prefill");
    engine.submit(b, reply_tx.clone());
    while engine.step().unwrap() {}
    drop(reply_tx);

    assert!(
        metrics.preemptions.load(Ordering::Relaxed) >= 1,
        "the younger request must have been preempted"
    );
    let got: HashMap<u64, Vec<i32>> =
        reply_rx.try_iter().map(|r| (r.id, r.tokens)).collect();
    assert_eq!(got.len(), 2, "both requests must complete");
    assert_eq!(got[&0], solo_a[&0], "elder diverged");
    assert_eq!(
        got[&1], solo_b[&1],
        "preempted-and-resumed request must be token-identical"
    );
    engine.kv_invariants().unwrap();
    let (free, total) = engine.kv_blocks();
    assert_eq!(free, total, "blocks leaked across preemption");
}

/// Deterministic no-starvation pin: while a 64-token prompt works
/// through its prefill chunks, the already-active sequence takes a
/// decode step on **every** iteration — chunked prefill never
/// monopolizes the loop.
#[test]
fn decode_advances_every_iteration_while_chunks_are_pending() {
    let mut rng = Rng::new(73);
    let metrics = Arc::new(EngineMetrics::new());
    let mut cfg = EngineConfig::new(MODEL);
    cfg.pool_threads = 1;
    cfg.max_wait_secs = 0.0;
    cfg.chunk_tokens = 16;
    cfg.prefix_cache = false;
    let mut engine = mk_engine(cfg, &metrics);
    let (reply_tx, reply_rx) = channel();
    engine.submit(
        Request {
            id: 0,
            prompt: prompt(&mut rng, 8),
            max_new_tokens: 30,
            config: SparsityConfig::parse("dense").unwrap(),
        },
        reply_tx.clone(),
    );
    assert!(engine.step().unwrap());
    assert_eq!(engine.active_requests(), 1, "short must be decoding");
    engine.submit(
        Request {
            id: 1,
            prompt: prompt(&mut rng, 64),
            max_new_tokens: 1,
            config: SparsityConfig::parse("dense").unwrap(),
        },
        reply_tx.clone(),
    );
    // 64 tokens at 16-token chunks: four iterations of chunked
    // prefill, each of which must also decode the active sequence
    for i in 0..4 {
        let db0 = metrics.decode_batches.load(Ordering::Relaxed);
        let ch0 = metrics.prefill_chunks.load(Ordering::Relaxed);
        assert!(engine.step().unwrap(), "iteration {i} idle");
        assert_eq!(
            metrics.decode_batches.load(Ordering::Relaxed),
            db0 + 1,
            "decode starved at iteration {i}"
        );
        assert_eq!(
            metrics.prefill_chunks.load(Ordering::Relaxed),
            ch0 + 1,
            "chunk did not run at iteration {i}"
        );
    }
    assert_eq!(
        engine.flight_requests(),
        0,
        "long prompt must finish prefill in 4 chunks"
    );
    while engine.step().unwrap() {}
    drop(reply_tx);
    let got: Vec<_> = reply_rx.try_iter().collect();
    assert_eq!(got.len(), 2, "both requests must complete");
    engine.kv_invariants().unwrap();
}
