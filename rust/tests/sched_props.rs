//! Scheduler property suite (ISSUE 8): drive the real continuous-
//! batching engine through randomized submit/step interleavings under
//! adversarially small block pools, random chunk sizes and prefix-cache
//! settings, and check the admission/preemption invariants — no block
//! leaks, allocation never exceeds pool capacity, and every admitted
//! request completes with tokens identical to an undisturbed one-shot
//! reference run (so preempt-and-resume is invisible to the client).
//! Deterministic companions pin the preemption path itself, the
//! no-decode-starvation guarantee while prefill chunks are pending,
//! and (ISSUE 9) the fault-tolerance paths: deadline cancellation,
//! transient retry with backoff, retry exhaustion, overload
//! degrade/shed and dropped-receiver survival.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::channel;
use std::sync::Arc;

use amber_pruner::coordinator::error::ErrorKind;
use amber_pruner::coordinator::fault::{
    FaultKind, FaultPlan, FaultSite,
};
use amber_pruner::coordinator::request::{Request, SparsityConfig};
use amber_pruner::coordinator::scheduler::{
    DegradePolicy, Engine, EngineConfig,
};
use amber_pruner::metrics::EngineMetrics;
use amber_pruner::runtime::NativeEngine;
use amber_pruner::testutil::prop::{prop_check, Gen};
use amber_pruner::util::rng::Rng;

const MODEL: &str = "tiny-lm-a";

fn prompt(rng: &mut Rng, len: usize) -> Vec<i32> {
    (0..len).map(|_| 1 + rng.below(300) as i32).collect()
}

fn mk_engine(
    cfg: EngineConfig,
    metrics: &Arc<EngineMetrics>,
) -> Engine {
    Engine::new(
        Box::new(NativeEngine::tiny()),
        cfg,
        Arc::clone(metrics),
    )
    .unwrap()
}

/// Undisturbed reference: one-shot prefill, ample pool, no prefix
/// cache. Tokens from any scheduling of the same requests must match
/// this bitwise (batch-, chunk- and prefix-parity compose).
fn serve_reference(reqs: &[Request]) -> HashMap<u64, Vec<i32>> {
    let metrics = Arc::new(EngineMetrics::new());
    let mut cfg = EngineConfig::new(MODEL);
    cfg.pool_threads = 1;
    cfg.max_wait_secs = 0.0;
    cfg.chunk_tokens = usize::MAX;
    cfg.prefix_cache = false;
    let mut engine = mk_engine(cfg, &metrics);
    let (reply_tx, reply_rx) = channel();
    for r in reqs {
        engine.submit(r.clone(), reply_tx.clone());
    }
    while engine.step().unwrap() {}
    drop(reply_tx);
    engine.kv_invariants().unwrap();
    reply_rx.try_iter().map(|r| (r.id, r.tokens)).collect()
}

/// Step until nothing is queued, in flight, active or parked,
/// checking KV invariants at every tick. Unlike `while step()`, this
/// keeps ticking through retry-backoff windows where an iteration
/// legitimately does no work; a livelocked engine fails fast instead.
fn drain(engine: &mut Engine) {
    let mut spins = 0usize;
    loop {
        let worked = engine.step().unwrap();
        engine.kv_invariants().unwrap();
        let pending = engine.queued_requests()
            + engine.flight_requests()
            + engine.active_requests()
            + engine.parked_requests();
        if pending == 0 {
            break;
        }
        spins = if worked { 0 } else { spins + 1 };
        assert!(spins <= 1_000, "drain stalled: {pending} pending");
    }
}

/// The headline property: >= 100 randomized interleavings of submit
/// and step against engines with tiny pools (forcing the preemption
/// path), random chunk sizes and prefix-cache settings. Every request
/// completes token-identically to the reference, no block leaks, the
/// peak gauge never exceeds capacity.
#[test]
fn randomized_interleavings_preserve_tokens_and_blocks() {
    let total_preempt = AtomicU64::new(0);
    let total_chunked = AtomicU64::new(0);
    prop_check("sched-model", 110, |rng, size| {
        let n = 3 + size / 3; // 3..=13 requests
        let mut reqs: Vec<Request> = Vec::new();
        for id in 0..n {
            let len = 1 + rng.usize_below(64);
            reqs.push(Request {
                id: id as u64,
                prompt: prompt(rng, len),
                max_new_tokens: 1 + rng.usize_below(6),
                config: SparsityConfig::parse(*Gen::choice(
                    rng,
                    &["dense", "2:4:ls"],
                ))
                .unwrap(),
                deadline_ticks: 0,
            });
        }
        let golden = serve_reference(&reqs);
        if golden.len() != n {
            return Err(format!(
                "reference run lost requests: {} of {n}",
                golden.len()
            ));
        }

        let metrics = Arc::new(EngineMetrics::new());
        let mut cfg = EngineConfig::new(MODEL);
        cfg.pool_threads = 1;
        cfg.max_wait_secs = 0.0;
        // 6..=14 blocks (96..=224 tokens): enough for any single
        // request, far too small for the population — admission must
        // wait, reclaim and preempt, never leak or over-allocate
        cfg.kv_pool_blocks = 6 + rng.usize_below(9);
        cfg.chunk_tokens =
            *Gen::choice(rng, &[16usize, 32, usize::MAX]);
        cfg.prefix_cache = rng.bool(0.5);
        let chunked = cfg.chunk_tokens != usize::MAX;
        let mut engine = mk_engine(cfg, &metrics);
        let (reply_tx, reply_rx) = channel();

        // random interleaving of submissions and iterations
        let mut next = reqs.iter();
        let mut submitted = 0usize;
        while submitted < n {
            if rng.bool(0.6) {
                engine
                    .submit(next.next().unwrap().clone(), reply_tx.clone());
                submitted += 1;
            } else {
                engine.step().map_err(|e| format!("step: {e}"))?;
                engine
                    .kv_invariants()
                    .map_err(|e| format!("kv invariants mid-run: {e}"))?;
            }
        }
        // drain, with a convergence guard so a livelocked scheduler
        // fails the property instead of hanging the suite
        let mut spins = 0usize;
        loop {
            let worked =
                engine.step().map_err(|e| format!("step: {e}"))?;
            engine
                .kv_invariants()
                .map_err(|e| format!("kv invariants mid-drain: {e}"))?;
            let pending = engine.queued_requests()
                + engine.flight_requests()
                + engine.active_requests()
                + engine.parked_requests();
            if pending == 0 {
                break;
            }
            spins = if worked { 0 } else { spins + 1 };
            if spins > 10_000 {
                return Err(format!(
                    "drain stalled with {pending} requests pending"
                ));
            }
        }
        drop(reply_tx);

        let got: HashMap<u64, Vec<i32>> =
            reply_rx.try_iter().map(|r| (r.id, r.tokens)).collect();
        if got.len() != n {
            return Err(format!(
                "completed {} of {n} requests",
                got.len()
            ));
        }
        if got != golden {
            let bad: Vec<u64> = golden
                .iter()
                .filter(|(id, toks)| got.get(id) != Some(toks))
                .map(|(id, _)| *id)
                .collect();
            return Err(format!(
                "tokens diverged from the one-shot reference for \
                 requests {bad:?}"
            ));
        }
        engine
            .kv_invariants()
            .map_err(|e| format!("kv invariants: {e}"))?;
        engine.clear_prefix_cache();
        let (free, total) = engine.kv_blocks();
        if free != total {
            return Err(format!(
                "block leak: {free} free of {total} after drain"
            ));
        }
        let peak = metrics.kv_blocks_peak.load(Ordering::Relaxed);
        if peak > total as u64 {
            return Err(format!(
                "allocation exceeded capacity: peak {peak} of {total}"
            ));
        }
        total_preempt.fetch_add(
            metrics.preemptions.load(Ordering::Relaxed),
            Ordering::Relaxed,
        );
        if chunked {
            total_chunked.fetch_add(1, Ordering::Relaxed);
        }
        Ok(())
    });
    // the suite must actually exercise the adversarial paths it claims
    // to cover, not pass vacuously
    assert!(
        total_preempt.load(Ordering::Relaxed) > 0,
        "no case ever preempted — pools not small enough"
    );
    assert!(
        total_chunked.load(Ordering::Relaxed) > 0,
        "no case ever ran chunked"
    );
}

/// Deterministic preemption pin: two long-generation requests on a
/// 4-block pool. The younger is preempted when the elder's decode
/// needs its blocks, is re-admitted after the elder completes, and
/// finishes with exactly the tokens of an undisturbed solo run.
#[test]
fn preempted_request_resumes_token_identically() {
    let mut rng = Rng::new(71);
    let a = Request {
        id: 0,
        prompt: prompt(&mut rng, 30),
        max_new_tokens: 20,
        config: SparsityConfig::parse("dense").unwrap(),
        deadline_ticks: 0,
    };
    let b = Request {
        id: 1,
        prompt: prompt(&mut rng, 30),
        max_new_tokens: 20,
        config: SparsityConfig::parse("dense").unwrap(),
        deadline_ticks: 0,
    };
    let solo_a = serve_reference(std::slice::from_ref(&a));
    let solo_b = serve_reference(std::slice::from_ref(&b));

    let metrics = Arc::new(EngineMetrics::new());
    let mut cfg = EngineConfig::new(MODEL);
    cfg.pool_threads = 1;
    cfg.max_wait_secs = 0.0;
    cfg.chunk_tokens = usize::MAX;
    cfg.prefix_cache = false;
    // 64 tokens: each request needs 2 blocks for its prompt and grows
    // to 4 by the end of generation — they cannot both finish resident
    cfg.kv_pool_blocks = 4;
    let mut engine = mk_engine(cfg, &metrics);
    let (reply_tx, reply_rx) = channel();
    engine.submit(a, reply_tx.clone());
    assert!(engine.step().unwrap(), "elder must prefill");
    engine.submit(b, reply_tx.clone());
    while engine.step().unwrap() {}
    drop(reply_tx);

    assert!(
        metrics.preemptions.load(Ordering::Relaxed) >= 1,
        "the younger request must have been preempted"
    );
    let got: HashMap<u64, Vec<i32>> =
        reply_rx.try_iter().map(|r| (r.id, r.tokens)).collect();
    assert_eq!(got.len(), 2, "both requests must complete");
    assert_eq!(got[&0], solo_a[&0], "elder diverged");
    assert_eq!(
        got[&1], solo_b[&1],
        "preempted-and-resumed request must be token-identical"
    );
    engine.kv_invariants().unwrap();
    let (free, total) = engine.kv_blocks();
    assert_eq!(free, total, "blocks leaked across preemption");
}

/// Deterministic no-starvation pin: while a 64-token prompt works
/// through its prefill chunks, the already-active sequence takes a
/// decode step on **every** iteration — chunked prefill never
/// monopolizes the loop.
#[test]
fn decode_advances_every_iteration_while_chunks_are_pending() {
    let mut rng = Rng::new(73);
    let metrics = Arc::new(EngineMetrics::new());
    let mut cfg = EngineConfig::new(MODEL);
    cfg.pool_threads = 1;
    cfg.max_wait_secs = 0.0;
    cfg.chunk_tokens = 16;
    cfg.prefix_cache = false;
    let mut engine = mk_engine(cfg, &metrics);
    let (reply_tx, reply_rx) = channel();
    engine.submit(
        Request {
            id: 0,
            prompt: prompt(&mut rng, 8),
            max_new_tokens: 30,
            config: SparsityConfig::parse("dense").unwrap(),
            deadline_ticks: 0,
        },
        reply_tx.clone(),
    );
    assert!(engine.step().unwrap());
    assert_eq!(engine.active_requests(), 1, "short must be decoding");
    engine.submit(
        Request {
            id: 1,
            prompt: prompt(&mut rng, 64),
            max_new_tokens: 1,
            config: SparsityConfig::parse("dense").unwrap(),
            deadline_ticks: 0,
        },
        reply_tx.clone(),
    );
    // 64 tokens at 16-token chunks: four iterations of chunked
    // prefill, each of which must also decode the active sequence
    for i in 0..4 {
        let db0 = metrics.decode_batches.load(Ordering::Relaxed);
        let ch0 = metrics.prefill_chunks.load(Ordering::Relaxed);
        assert!(engine.step().unwrap(), "iteration {i} idle");
        assert_eq!(
            metrics.decode_batches.load(Ordering::Relaxed),
            db0 + 1,
            "decode starved at iteration {i}"
        );
        assert_eq!(
            metrics.prefill_chunks.load(Ordering::Relaxed),
            ch0 + 1,
            "chunk did not run at iteration {i}"
        );
    }
    assert_eq!(
        engine.flight_requests(),
        0,
        "long prompt must finish prefill in 4 chunks"
    );
    while engine.step().unwrap() {}
    drop(reply_tx);
    let got: Vec<_> = reply_rx.try_iter().collect();
    assert_eq!(got.len(), 2, "both requests must complete");
    engine.kv_invariants().unwrap();
}

/// Deterministic queued-deadline pin (ISSUE 9): a request that cannot
/// be admitted before its tick budget runs out is cancelled from the
/// queue with a `Rejected` response and an empty token stream, while
/// the resident request finishes token-identically to its solo run.
#[test]
fn queued_request_past_its_deadline_is_rejected() {
    let mut rng = Rng::new(81);
    let a = Request {
        id: 0,
        prompt: prompt(&mut rng, 30),
        max_new_tokens: 20,
        config: SparsityConfig::parse("dense").unwrap(),
        deadline_ticks: 0,
    };
    let b = Request {
        id: 1,
        prompt: prompt(&mut rng, 33),
        max_new_tokens: 4,
        config: SparsityConfig::parse("dense").unwrap(),
        deadline_ticks: 2,
    };
    let solo_a = serve_reference(std::slice::from_ref(&a));

    let metrics = Arc::new(EngineMetrics::new());
    let mut cfg = EngineConfig::new(MODEL);
    cfg.pool_threads = 1;
    cfg.max_wait_secs = 0.0;
    cfg.chunk_tokens = usize::MAX;
    cfg.prefix_cache = false;
    // 64 tokens: A's prompt takes 2 of the 4 blocks and its
    // generation grows to all 4, so B's 3-block one-shot prompt can
    // never be admitted while A is resident (admission waits, it
    // never preempts) — B must expire in the queue
    cfg.kv_pool_blocks = 4;
    let mut engine = mk_engine(cfg, &metrics);
    let (reply_tx, reply_rx) = channel();
    engine.submit(a, reply_tx.clone());
    assert!(engine.step().unwrap(), "A must prefill");
    engine.submit(b, reply_tx.clone());
    drain(&mut engine);
    drop(reply_tx);

    assert_eq!(
        metrics.timeouts.load(Ordering::Relaxed),
        1,
        "exactly one deadline cancellation"
    );
    let got: HashMap<u64, _> =
        reply_rx.try_iter().map(|r| (r.id, r)).collect();
    assert_eq!(got.len(), 2, "exactly one response per request");
    let err = got[&1].error.as_ref().expect("B must carry an error");
    assert_eq!(err.kind, ErrorKind::Rejected);
    assert!(
        err.reason.contains("queued"),
        "unexpected reason: {}",
        err.reason
    );
    assert!(got[&1].tokens.is_empty(), "B never generated a token");
    assert_eq!(got[&0].tokens, solo_a[&0], "A diverged");
    let (free, total) = engine.kv_blocks();
    assert_eq!(free, total, "blocks leaked across the cancellation");
}

/// Deterministic transient-retry pin (ISSUE 9): an injected prefill
/// failure releases the request's KV and parks it for a backed-off
/// retry, and the retried run is token-identical to an undisturbed
/// one — the fault is invisible to the client.
#[test]
fn injected_prefill_failure_retries_token_identically() {
    let mut rng = Rng::new(83);
    let req = Request {
        id: 0,
        prompt: prompt(&mut rng, 24),
        max_new_tokens: 6,
        config: SparsityConfig::parse("dense").unwrap(),
        deadline_ticks: 0,
    };
    let golden = serve_reference(std::slice::from_ref(&req));

    let metrics = Arc::new(EngineMetrics::new());
    let mut cfg = EngineConfig::new(MODEL);
    cfg.pool_threads = 1;
    cfg.max_wait_secs = 0.0;
    cfg.chunk_tokens = usize::MAX;
    cfg.prefix_cache = false;
    cfg.fault_plan = FaultPlan::none().with(
        1,
        FaultSite::PrefillChunk,
        FaultKind::Fail,
    );
    let mut engine = mk_engine(cfg, &metrics);
    let (reply_tx, reply_rx) = channel();
    engine.submit(req, reply_tx.clone());
    drain(&mut engine);
    drop(reply_tx);

    assert_eq!(metrics.faults_injected.load(Ordering::Relaxed), 1);
    assert_eq!(metrics.retries.load(Ordering::Relaxed), 1);
    assert_eq!(engine.faults().pending(), 0, "the fault must fire");
    assert_eq!(engine.parked_requests(), 0);
    let got: Vec<_> = reply_rx.try_iter().collect();
    assert_eq!(got.len(), 1, "exactly one response");
    assert!(got[0].error.is_none(), "the retry must succeed");
    assert_eq!(got[0].tokens, golden[&0], "retried run diverged");
    let (free, total) = engine.kv_blocks();
    assert_eq!(free, total, "blocks leaked across the retry");
}

/// Retry-exhaustion pin (ISSUE 9): with `max_retries = 1`, a second
/// injected failure escalates to a `Fatal` "giving up" response — and
/// the engine keeps serving fresh requests afterwards.
#[test]
fn exhausted_retries_escalate_to_fatal_and_engine_survives() {
    let mut rng = Rng::new(87);
    let doomed = Request {
        id: 0,
        prompt: prompt(&mut rng, 16),
        max_new_tokens: 4,
        config: SparsityConfig::parse("dense").unwrap(),
        deadline_ticks: 0,
    };
    let healthy = Request {
        id: 1,
        prompt: prompt(&mut rng, 16),
        max_new_tokens: 4,
        config: SparsityConfig::parse("dense").unwrap(),
        deadline_ticks: 0,
    };
    let golden = serve_reference(std::slice::from_ref(&healthy));

    let metrics = Arc::new(EngineMetrics::new());
    let mut cfg = EngineConfig::new(MODEL);
    cfg.pool_threads = 1;
    cfg.max_wait_secs = 0.0;
    cfg.chunk_tokens = usize::MAX;
    cfg.prefix_cache = false;
    cfg.max_retries = 1;
    cfg.retry_backoff_ticks = 1;
    // fails at tick 1, and again at tick 2 when the backed-off retry
    // wakes — exhausting the single-retry budget
    cfg.fault_plan = FaultPlan::none()
        .with(1, FaultSite::PrefillChunk, FaultKind::Fail)
        .with(2, FaultSite::PrefillChunk, FaultKind::Fail);
    let mut engine = mk_engine(cfg, &metrics);
    let (reply_tx, reply_rx) = channel();
    engine.submit(doomed, reply_tx.clone());
    drain(&mut engine);

    let r0 = reply_rx.try_iter().next().expect("doomed must answer");
    let err = r0.error.as_ref().expect("must be a terminal error");
    assert_eq!(err.kind, ErrorKind::Fatal);
    assert!(
        err.reason.contains("giving up"),
        "unexpected reason: {}",
        err.reason
    );
    assert_eq!(metrics.faults_injected.load(Ordering::Relaxed), 2);
    assert_eq!(
        metrics.retries.load(Ordering::Relaxed),
        1,
        "only the first failure is a retry; the second is fatal"
    );

    // the loop keeps serving: a fresh request completes normally
    engine.submit(healthy, reply_tx.clone());
    drain(&mut engine);
    drop(reply_tx);
    let got: Vec<_> = reply_rx.try_iter().collect();
    assert_eq!(got.len(), 1, "the healthy request must answer");
    assert!(got[0].error.is_none());
    assert_eq!(got[0].tokens, golden[&1], "healthy run diverged");
    let (free, total) = engine.kv_blocks();
    assert_eq!(free, total, "blocks leaked across the fatal path");
}

/// Overload-admission pin (ISSUE 9): past `degrade_at` queued prompt
/// tokens a dense request tightens to 4:8 (shedding compute, still
/// served); past `shed_at` it is shed outright with an immediate
/// `Rejected` response, before any engine iteration runs.
#[test]
fn admission_degrades_then_sheds_under_backlog() {
    let mut rng = Rng::new(89);
    let mut mk = |id: u64, len: usize| Request {
        id,
        prompt: prompt(&mut rng, len),
        max_new_tokens: 3,
        config: SparsityConfig::parse("dense").unwrap(),
        deadline_ticks: 0,
    };
    let a = mk(0, 30);
    let b = mk(1, 30);
    let c = mk(2, 10);

    let metrics = Arc::new(EngineMetrics::new());
    let mut cfg = EngineConfig::new(MODEL);
    cfg.pool_threads = 1;
    cfg.max_wait_secs = 0.0;
    cfg.chunk_tokens = usize::MAX;
    cfg.prefix_cache = false;
    cfg.degrade_policy = Some(DegradePolicy {
        degrade_at: 20,
        shed_at: 60,
    });
    let mut engine = mk_engine(cfg, &metrics);
    let (reply_tx, reply_rx) = channel();
    engine.submit(a, reply_tx.clone()); // backlog 0: admitted dense
    engine.submit(b, reply_tx.clone()); // backlog 30 >= 20: degraded
    engine.submit(c, reply_tx.clone()); // backlog 60 >= 60: shed

    assert_eq!(metrics.degraded.load(Ordering::Relaxed), 1);
    assert_eq!(metrics.sheds.load(Ordering::Relaxed), 1);
    assert_eq!(
        metrics.requests_admitted.load(Ordering::Relaxed),
        2,
        "the shed request is never admitted"
    );
    // the shed response is immediate, before any engine iteration
    let rc = reply_rx.try_iter().next().expect("shed answers at once");
    assert_eq!(rc.id, 2);
    let err = rc.error.as_ref().expect("shed must carry an error");
    assert_eq!(err.kind, ErrorKind::Rejected);
    assert!(
        err.reason.contains("overloaded"),
        "unexpected reason: {}",
        err.reason
    );

    drain(&mut engine);
    drop(reply_tx);
    let got: HashMap<u64, _> =
        reply_rx.try_iter().map(|r| (r.id, r)).collect();
    assert_eq!(got.len(), 2, "A and B must still be served");
    assert!(got[&0].error.is_none() && got[&1].error.is_none());
    assert!(!got[&0].tokens.is_empty() && !got[&1].tokens.is_empty());
    // the degraded request routes to the 4:8 bucket, so the two
    // survivors can no longer share one prefill batch
    assert!(
        metrics.prefill_batches.load(Ordering::Relaxed) >= 2,
        "degraded request must run in its own config bucket"
    );
    engine.kv_invariants().unwrap();
}

/// Dropped-receiver regression (ISSUE 9 satellite): a client that
/// vanishes before its response is sent must not panic or wedge the
/// loop — the send failure is swallowed, the request still completes
/// and later clients are served normally.
#[test]
fn dropped_reply_receiver_does_not_kill_the_loop() {
    let mut rng = Rng::new(97);
    let orphan = Request {
        id: 0,
        prompt: prompt(&mut rng, 12),
        max_new_tokens: 3,
        config: SparsityConfig::parse("dense").unwrap(),
        deadline_ticks: 0,
    };
    let live = Request {
        id: 1,
        prompt: prompt(&mut rng, 12),
        max_new_tokens: 3,
        config: SparsityConfig::parse("dense").unwrap(),
        deadline_ticks: 0,
    };

    let metrics = Arc::new(EngineMetrics::new());
    let mut cfg = EngineConfig::new(MODEL);
    cfg.pool_threads = 1;
    cfg.max_wait_secs = 0.0;
    cfg.chunk_tokens = usize::MAX;
    cfg.prefix_cache = false;
    let mut engine = mk_engine(cfg, &metrics);
    let (orphan_tx, orphan_rx) = channel();
    engine.submit(orphan, orphan_tx);
    drop(orphan_rx); // the client vanishes before its answer
    drain(&mut engine);
    assert_eq!(
        metrics.requests_completed.load(Ordering::Relaxed),
        1,
        "the orphaned request still runs to completion"
    );

    let (reply_tx, reply_rx) = channel();
    engine.submit(live, reply_tx);
    drain(&mut engine);
    let got: Vec<_> = reply_rx.try_iter().collect();
    assert_eq!(got.len(), 1, "later clients are served normally");
    assert!(got[0].error.is_none());
    let (free, total) = engine.kv_blocks();
    assert_eq!(free, total, "blocks leaked past the dropped client");
}
