//! Helpers shared by the parity suites (`batch_parity`,
//! `kernel_parity`): random prompts and the per-request sequential
//! prefill reference — one copy, so the padded-row-0 reference pattern
//! cannot drift between suites.

use amber_pruner::runtime::{Engine, NativeEngine};
use amber_pruner::util::rng::Rng;

/// PAD token id used by the padded reference batches.
pub const PAD: i32 = 0;

/// A random prompt of `len` tokens in the synthetic vocab.
pub fn prompt(rng: &mut Rng, len: usize) -> Vec<i32> {
    (0..len).map(|_| 1 + rng.below(300) as i32).collect()
}

/// Per-request sequential reference: each prompt alone in row 0 of the
/// static padded `[b, s]` artifact — the pre-refactor serving pattern.
/// Returns each request's first `len` logit rows.
pub fn sequential_logits(
    e: &mut NativeEngine,
    art: &str,
    bind: &str,
    b: usize,
    s: usize,
    prompts: &[Vec<i32>],
) -> Vec<Vec<f32>> {
    prompts
        .iter()
        .map(|p| {
            let len = p.len().min(s).max(1);
            let mut tokens = vec![PAD; b * s];
            tokens[..p.len().min(s)].copy_from_slice(&p[..p.len().min(s)]);
            let out = e.prefill(art, bind, &tokens).unwrap();
            out.logits[..len * out.vocab].to_vec()
        })
        .collect()
}
