//! Replica-pool suite (ISSUE 10): drive a supervised multi-replica
//! pool through >= 100 seeded schedules of submissions interleaved
//! with replica kills, graceful drains and restarts, and check the
//! failover contract: every accepted request gets exactly one
//! response, every error-free response is token-identical to a
//! single-replica fault-free reference (failover recomputes from
//! scratch, so a crash is invisible to the client), and the router's
//! outstanding counters settle to zero once everything is answered.
//! Deterministic companions pin kill-mid-prefill and kill-mid-decode
//! failover, drain-loses-nothing (plus restart-after-drain), heartbeat
//! fencing of a stalled replica, and the engine-level drain hand-back
//! protocol.

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::{Duration, Instant};

use amber_pruner::coordinator::replica::{
    EngineFactory, PoolConfig, PoolHandle, ReplicaPool, ReplicaStat,
};
use amber_pruner::coordinator::request::{Request, SparsityConfig};
use amber_pruner::coordinator::router::{Health, Policy};
use amber_pruner::coordinator::scheduler::{
    Engine, EngineConfig, EngineMsg,
};
use amber_pruner::metrics::EngineMetrics;
use amber_pruner::runtime::NativeEngine;
use amber_pruner::server::workload::{replica_schedule, ReplicaAction};
use amber_pruner::testutil::prop::{prop_check, Gen};
use amber_pruner::util::rng::Rng;

const MODEL: &str = "tiny-lm-a";

fn prompt(rng: &mut Rng, len: usize) -> Vec<i32> {
    (0..len).map(|_| 1 + rng.below(300) as i32).collect()
}

fn req(id: u64, prompt: Vec<i32>, max_new: usize) -> Request {
    Request {
        id,
        prompt,
        max_new_tokens: max_new,
        config: SparsityConfig::dense(),
        deadline_ticks: 0,
    }
}

/// Pool factory: every replica (and every restart) binds a fresh tiny
/// native engine with the given chunk size.
fn factory(
    metrics: &Arc<EngineMetrics>,
    chunk_tokens: usize,
) -> EngineFactory {
    let m = Arc::clone(metrics);
    Arc::new(move |_i| {
        let mut cfg = EngineConfig::new(MODEL);
        cfg.pool_threads = 1;
        cfg.max_wait_secs = 0.0;
        cfg.chunk_tokens = chunk_tokens;
        cfg.prefix_cache = false;
        Engine::new(Box::new(NativeEngine::tiny()), cfg, Arc::clone(&m))
    })
}

/// Single-replica, fault-free reference: what the tokens must be.
fn serve_reference(reqs: &[Request]) -> HashMap<u64, Vec<i32>> {
    let metrics = Arc::new(EngineMetrics::new());
    let mut cfg = EngineConfig::new(MODEL);
    cfg.pool_threads = 1;
    cfg.max_wait_secs = 0.0;
    cfg.chunk_tokens = usize::MAX;
    cfg.prefix_cache = false;
    let mut engine = Engine::new(
        Box::new(NativeEngine::tiny()),
        cfg,
        Arc::clone(&metrics),
    )
    .unwrap();
    let (reply_tx, reply_rx) = channel();
    for r in reqs {
        engine.submit(r.clone(), reply_tx.clone());
    }
    while engine.step().unwrap() {}
    drop(reply_tx);
    reply_rx.try_iter().map(|r| (r.id, r.tokens)).collect()
}

/// Poll [`PoolHandle::snapshot`] until `pred` holds (or time out).
fn wait_for<F: Fn(&[ReplicaStat]) -> bool>(
    handle: &PoolHandle,
    pred: F,
    timeout: Duration,
    what: &str,
) -> Result<Vec<ReplicaStat>, String> {
    let start = Instant::now();
    loop {
        let snap = handle
            .snapshot()
            .map_err(|e| format!("snapshot: {e}"))?;
        if pred(&snap) {
            return Ok(snap);
        }
        if start.elapsed() > timeout {
            return Err(format!("timed out waiting for {what}: {snap:?}"));
        }
        std::thread::sleep(Duration::from_micros(500));
    }
}

/// The headline property: >= 100 seeded schedules of submissions
/// interleaved with kills/drains/restarts over 2–3 replicas under a
/// random routing policy. Exactly one response per request, unique
/// ids, error-free responses token-identical to the single-replica
/// reference, and no outstanding-counter drift once the dust settles.
/// The suite as a whole must actually restart and drain replicas
/// (non-vacuity).
#[test]
fn seeded_replica_schedules_answer_exactly_once_and_match() {
    let total_restarts = AtomicU64::new(0);
    let total_drains = AtomicU64::new(0);
    let total_redispatched = AtomicU64::new(0);
    prop_check("replica", 110, |rng, size| {
        let replicas = 2 + rng.usize_below(2); // 2..=3
        let n = 4 + size / 4; // 4..=11 requests
        let mut reqs: Vec<Request> = Vec::new();
        for id in 0..n {
            let len = 8 + rng.usize_below(41); // 8..=48
            reqs.push(req(
                id as u64,
                prompt(rng, len),
                1 + rng.usize_below(4),
            ));
        }
        let golden = serve_reference(&reqs);
        if golden.len() != n {
            return Err(format!(
                "reference run lost requests: {} of {n}",
                golden.len()
            ));
        }

        let metrics = Arc::new(EngineMetrics::new());
        let mut pcfg = PoolConfig::new(replicas);
        pcfg.policy = *Gen::choice(
            rng,
            &[
                Policy::RoundRobin,
                Policy::LeastOutstanding,
                Policy::PrefixAffinity { block: 16, spill_at: 2 },
            ],
        );
        // thread-death supervision only: a loaded CI box must not
        // fence a merely-slow replica mid-property
        pcfg.heartbeat_timeout = Duration::ZERO;
        pcfg.poll = Duration::from_millis(1);
        let mut pool = ReplicaPool::start(
            factory(&metrics, *Gen::choice(rng, &[8usize, usize::MAX])),
            Arc::clone(&metrics),
            pcfg,
        )
        .map_err(|e| format!("pool start: {e}"))?;
        let handle = pool.handle();

        let mut chaos = replica_schedule(
            rng.below(u64::MAX),
            replicas,
            1 + rng.usize_below(5),
            0, // position-interleaved below; fire times unused
        )
        .into_iter();
        let (reply_tx, reply_rx) = channel();
        for r in &reqs {
            handle
                .submit(r.clone(), reply_tx.clone())
                .map_err(|e| format!("submit: {e}"))?;
            if rng.bool(0.35) {
                if let Some(e) = chaos.next() {
                    match e.action {
                        ReplicaAction::Kill => handle.kill(e.replica),
                        ReplicaAction::Drain => handle.drain(e.replica),
                        ReplicaAction::Restart => {
                            handle.restart(e.replica)
                        }
                    }
                }
            }
        }
        drop(reply_tx);

        let mut responses = Vec::with_capacity(n);
        for k in 0..n {
            match reply_rx.recv_timeout(Duration::from_secs(60)) {
                Ok(r) => responses.push(r),
                Err(_) => {
                    return Err(format!(
                        "response {k} of {n} never arrived"
                    ))
                }
            }
        }
        let mut seen: HashSet<u64> = HashSet::new();
        for r in &responses {
            if !seen.insert(r.id) {
                return Err(format!("request {} answered twice", r.id));
            }
            if r.error.is_none()
                && golden.get(&r.id) != Some(&r.tokens)
            {
                return Err(format!(
                    "request {}: error-free response diverged from \
                     the single-replica reference",
                    r.id
                ));
            }
        }
        // late zombie replies are dropped by the ledger fence, so the
        // client channel stays exactly-once even if we wait
        if let Ok(extra) =
            reply_rx.recv_timeout(Duration::from_millis(20))
        {
            return Err(format!(
                "request {} answered twice (late duplicate)",
                extra.id
            ));
        }
        // every dispatch must have been balanced by exactly one
        // completion/failover/rebind: no counter drift anywhere
        wait_for(
            &handle,
            |snap| snap.iter().all(|s| s.outstanding == 0),
            Duration::from_secs(10),
            "outstanding counters to settle at zero",
        )?;
        pool.shutdown().map_err(|e| format!("shutdown: {e}"))?;
        total_restarts.fetch_add(
            metrics.replica_restarts.load(Ordering::Relaxed),
            Ordering::Relaxed,
        );
        total_drains.fetch_add(
            metrics.replica_drains.load(Ordering::Relaxed),
            Ordering::Relaxed,
        );
        total_redispatched.fetch_add(
            metrics.replica_redispatches.load(Ordering::Relaxed)
                + metrics.replica_handbacks.load(Ordering::Relaxed),
            Ordering::Relaxed,
        );
        Ok(())
    });
    // the suite must exercise the paths it claims to cover
    assert!(
        total_restarts.load(Ordering::Relaxed) > 0,
        "no replica was ever restarted — kills never landed"
    );
    assert!(
        total_drains.load(Ordering::Relaxed) > 0,
        "no replica was ever drained"
    );
    assert!(
        total_redispatched.load(Ordering::Relaxed) > 0,
        "no request was ever re-dispatched or handed back"
    );
}

/// Kill a replica mid-prefill (long prompts, small chunks): its
/// in-flight requests fail over and recompute, every response is
/// error-free and token-identical to the single-replica reference.
#[test]
fn kill_mid_prefill_fails_over_token_identically() {
    let mut rng = Rng::new(0x10_aa);
    let reqs: Vec<Request> = (0..8u64)
        .map(|id| req(id, prompt(&mut rng, 60), 4))
        .collect();
    let golden = serve_reference(&reqs);

    let metrics = Arc::new(EngineMetrics::new());
    let mut pcfg = PoolConfig::new(2);
    pcfg.heartbeat_timeout = Duration::ZERO;
    pcfg.poll = Duration::from_millis(1);
    // 60-token prompts in 4-token chunks: 15 prefill ticks per
    // request, so the kill below lands mid-prefill
    let mut pool = ReplicaPool::start(
        factory(&metrics, 4),
        Arc::clone(&metrics),
        pcfg,
    )
    .unwrap();
    let handle = pool.handle();
    let (reply_tx, reply_rx) = channel();
    for r in &reqs {
        handle.submit(r.clone(), reply_tx.clone()).unwrap();
    }
    let snap = wait_for(
        &handle,
        |s| s.iter().any(|r| r.outstanding >= 2),
        Duration::from_secs(10),
        "a replica with work in flight",
    )
    .unwrap();
    let victim = snap
        .iter()
        .max_by_key(|s| s.outstanding)
        .unwrap()
        .index;
    // a short stall pins the victim's queue while the crash message
    // lands behind it, so the kill provably strikes work in flight
    handle.stall(victim, 50);
    handle.kill(victim);
    drop(reply_tx);

    let responses: Vec<_> = (0..reqs.len())
        .map(|_| {
            reply_rx
                .recv_timeout(Duration::from_secs(60))
                .expect("response lost across failover")
        })
        .collect();
    let ids: HashSet<u64> = responses.iter().map(|r| r.id).collect();
    assert_eq!(ids.len(), reqs.len(), "duplicate or missing ids");
    for r in &responses {
        assert!(
            r.error.is_none(),
            "request {} failed across failover: {:?}",
            r.id,
            r.error
        );
        assert_eq!(
            golden[&r.id], r.tokens,
            "request {}: failover replay diverged",
            r.id
        );
    }
    assert!(
        metrics.replica_redispatches.load(Ordering::Relaxed) > 0,
        "the kill never re-dispatched anything"
    );
    assert!(
        metrics.replica_restarts.load(Ordering::Relaxed) > 0,
        "the killed replica was never restarted"
    );
    pool.shutdown().unwrap();
}

/// Kill a replica mid-decode (short prompts, long generation): same
/// contract as the mid-prefill kill.
#[test]
fn kill_mid_decode_fails_over_token_identically() {
    let mut rng = Rng::new(0x10_bb);
    let reqs: Vec<Request> = (0..8u64)
        .map(|id| req(id, prompt(&mut rng, 4), 24))
        .collect();
    let golden = serve_reference(&reqs);

    let metrics = Arc::new(EngineMetrics::new());
    let mut pcfg = PoolConfig::new(2);
    pcfg.heartbeat_timeout = Duration::ZERO;
    pcfg.poll = Duration::from_millis(1);
    // one-shot prefill, 24 decode ticks per request: the kill lands
    // mid-decode
    let mut pool = ReplicaPool::start(
        factory(&metrics, usize::MAX),
        Arc::clone(&metrics),
        pcfg,
    )
    .unwrap();
    let handle = pool.handle();
    let (reply_tx, reply_rx) = channel();
    for r in &reqs {
        handle.submit(r.clone(), reply_tx.clone()).unwrap();
    }
    let snap = wait_for(
        &handle,
        |s| s.iter().any(|r| r.outstanding >= 2),
        Duration::from_secs(10),
        "a replica with work in flight",
    )
    .unwrap();
    let victim = snap
        .iter()
        .max_by_key(|s| s.outstanding)
        .unwrap()
        .index;
    // stall-then-kill: the crash message queues behind a short sleep,
    // so it provably strikes while decode work is outstanding
    handle.stall(victim, 50);
    handle.kill(victim);
    drop(reply_tx);

    for _ in 0..reqs.len() {
        let r = reply_rx
            .recv_timeout(Duration::from_secs(60))
            .expect("response lost across failover");
        assert!(r.error.is_none(), "request {} failed", r.id);
        assert_eq!(
            golden[&r.id], r.tokens,
            "request {}: failover replay diverged",
            r.id
        );
    }
    assert!(
        metrics.replica_redispatches.load(Ordering::Relaxed) > 0,
        "the kill never re-dispatched anything"
    );
    pool.shutdown().unwrap();
}

/// Graceful drain loses nothing: every request submitted before the
/// drain is answered error-free and token-identical, the drained slot
/// ends `Down`, and a restart brings it back for new work.
#[test]
fn graceful_drain_loses_nothing_and_restart_revives() {
    let mut rng = Rng::new(0x10_cc);
    let reqs: Vec<Request> = (0..10u64)
        .map(|id| req(id, prompt(&mut rng, 32), 4))
        .collect();
    let after = req(99, prompt(&mut rng, 12), 2);
    let mut all = reqs.clone();
    all.push(after.clone());
    let golden = serve_reference(&all);

    let metrics = Arc::new(EngineMetrics::new());
    let mut pcfg = PoolConfig::new(2);
    pcfg.heartbeat_timeout = Duration::ZERO;
    pcfg.poll = Duration::from_millis(1);
    let mut pool = ReplicaPool::start(
        factory(&metrics, 8),
        Arc::clone(&metrics),
        pcfg,
    )
    .unwrap();
    let handle = pool.handle();
    let (reply_tx, reply_rx) = channel();
    for r in &reqs {
        handle.submit(r.clone(), reply_tx.clone()).unwrap();
    }
    let snap = wait_for(
        &handle,
        |s| s.iter().any(|r| r.outstanding > 0),
        Duration::from_secs(10),
        "a replica with work in flight",
    )
    .unwrap();
    let victim = snap
        .iter()
        .max_by_key(|s| s.outstanding)
        .unwrap()
        .index;
    handle.drain(victim);

    for _ in 0..reqs.len() {
        let r = reply_rx
            .recv_timeout(Duration::from_secs(60))
            .expect("drain lost a response");
        assert!(r.error.is_none(), "request {} failed", r.id);
        assert_eq!(
            golden[&r.id], r.tokens,
            "request {}: response diverged across the drain",
            r.id
        );
    }
    assert_eq!(metrics.replica_drains.load(Ordering::Relaxed), 1);
    let snap = wait_for(
        &handle,
        |s| s[victim].health == Health::Down,
        Duration::from_secs(10),
        "the drained slot to finish",
    )
    .unwrap();
    assert_eq!(snap[victim].outstanding, 0, "drain leaked a counter");

    // a drained slot is revivable: restart, wait for its heartbeat
    // promotion, and serve fresh work
    handle.restart(victim);
    wait_for(
        &handle,
        |s| s[victim].health == Health::Up,
        Duration::from_secs(10),
        "the restarted slot to come up",
    )
    .unwrap();
    handle.submit(after.clone(), reply_tx.clone()).unwrap();
    drop(reply_tx);
    let r = reply_rx
        .recv_timeout(Duration::from_secs(60))
        .expect("post-restart request lost");
    assert_eq!(r.id, 99);
    assert!(r.error.is_none());
    assert_eq!(golden[&99], r.tokens);
    pool.shutdown().unwrap();
}

/// A stalled serve loop stops heartbeating: the supervisor fences the
/// zombie, re-dispatches its work and binds a replacement — clients
/// still get exactly one, token-identical response each.
#[test]
fn stalled_replica_is_fenced_and_replaced() {
    let mut rng = Rng::new(0x10_dd);
    let reqs: Vec<Request> = (0..6u64)
        .map(|id| req(id, prompt(&mut rng, 8), 12))
        .collect();
    let golden = serve_reference(&reqs);

    let metrics = Arc::new(EngineMetrics::new());
    let mut pcfg = PoolConfig::new(2);
    pcfg.heartbeat_timeout = Duration::from_millis(250);
    pcfg.poll = Duration::from_millis(1);
    let mut pool = ReplicaPool::start(
        factory(&metrics, usize::MAX),
        Arc::clone(&metrics),
        pcfg,
    )
    .unwrap();
    let handle = pool.handle();
    // both replicas must be heartbeating before the stall, so the
    // fence provably fires on a *stalled* beat, not a missing one
    wait_for(
        &handle,
        |s| s.iter().all(|r| r.health == Health::Up),
        Duration::from_secs(10),
        "both replicas up",
    )
    .unwrap();
    let (reply_tx, reply_rx) = channel();
    for r in &reqs {
        handle.submit(r.clone(), reply_tx.clone()).unwrap();
    }
    handle.stall(0, 1_500);
    drop(reply_tx);

    for _ in 0..reqs.len() {
        let r = reply_rx
            .recv_timeout(Duration::from_secs(60))
            .expect("response lost across the fence");
        assert!(r.error.is_none(), "request {} failed", r.id);
        assert_eq!(
            golden[&r.id], r.tokens,
            "request {}: fenced failover diverged",
            r.id
        );
    }
    // the fence must actually have fired and bound a fresh generation
    wait_for(
        &handle,
        |s| s[0].generation >= 1,
        Duration::from_secs(10),
        "the stalled slot to be rebound",
    )
    .unwrap();
    assert!(
        metrics.replica_restarts.load(Ordering::Relaxed) > 0,
        "the heartbeat fence never replaced the zombie"
    );
    pool.shutdown().unwrap();
}

/// Engine-level drain protocol: queued work is handed back un-replied
/// (retry counts preserved), the hand-back metric counts each one,
/// and the serve loop exits cleanly.
#[test]
fn engine_drain_hands_back_queued_work_unreplied() {
    let mut rng = Rng::new(0x10_ee);
    let metrics = Arc::new(EngineMetrics::new());
    let mut cfg = EngineConfig::new(MODEL);
    cfg.pool_threads = 1;
    cfg.max_wait_secs = 0.0;
    cfg.chunk_tokens = usize::MAX;
    cfg.prefix_cache = false;
    let mut engine = Engine::new(
        Box::new(NativeEngine::tiny()),
        cfg,
        Arc::clone(&metrics),
    )
    .unwrap();

    let (tx, rx) = channel();
    let (reply_tx, reply_rx) = channel();
    let (back_tx, back_rx) = channel();
    for id in 0..6u64 {
        tx.send(EngineMsg::Submit(
            req(id, prompt(&mut rng, 12), 2),
            reply_tx.clone(),
        ))
        .unwrap();
    }
    // the drain arrives in the same message batch, before any step:
    // everything is still queued, so everything hands back un-replied
    tx.send(EngineMsg::Drain(back_tx)).unwrap();
    drop(tx);
    drop(reply_tx);
    engine.run(rx).unwrap();

    let backs: Vec<_> = back_rx.try_iter().collect();
    assert_eq!(backs.len(), 6, "all queued work must hand back");
    let ids: HashSet<u64> = backs.iter().map(|h| h.req.id).collect();
    assert_eq!(ids, (0..6).collect::<HashSet<u64>>());
    for h in &backs {
        assert_eq!(h.retries, 0, "retry budget must be preserved");
    }
    assert_eq!(
        reply_rx.try_iter().count(),
        0,
        "handed-back work must not be answered by the drained engine"
    );
    assert_eq!(
        metrics.replica_handbacks.load(Ordering::Relaxed),
        6
    );

    // the same engine object serves normally again after the drain
    let (tx2, rx2) = channel();
    let (reply_tx2, reply_rx2) = channel();
    tx2.send(EngineMsg::Submit(
        req(7, prompt(&mut rng, 12), 2),
        reply_tx2.clone(),
    ))
    .unwrap();
    drop(tx2);
    drop(reply_tx2);
    engine.run(rx2).unwrap();
    let r = reply_rx2.recv_timeout(Duration::from_secs(10)).unwrap();
    assert_eq!(r.id, 7);
    assert!(r.error.is_none(), "post-drain engine must serve again");
}
