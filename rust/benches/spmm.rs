//! PERF bench: reference (naive axpy / dot) vs register-tiled kernels
//! across the three compute classes — dense f32, N:M compressed SpMM,
//! and W8A8 int8 — at prefill-like token counts.
//!
//! This is the CPU stand-in for the paper's SpMM hardware: the
//! compressed kernel touches n/m of the weight rows, so wall-clock
//! should scale toward n/m of dense at matmul-bound sizes, **provided
//! the kernel is tile-aware** — the point of the `kernels` layer. Each
//! series is emitted to `BENCH_spmm.json` (written next to the package
//! manifest when run via `cargo bench --bench spmm`) with executed
//! GFLOP/s, and the sparse:dense crossover point per ratio (smallest
//! token count where the tiled N:M kernel beats the tiled dense
//! kernel) is recorded — the honest version of the paper's
//! acceleration claim (EXPERIMENTS.md §Perf).
//!
//! Compression / quantization happen outside the timed region (a fused
//! prefill amortizes them); the `compress` series reports their cost
//! separately.
//!
//! Since the bind-time preparation layer (ISSUE 5) each family also has
//! a `packed` series: the same kernels over a tile-panel weight layout
//! ([`amber_pruner::kernels::pack`]) built once up front, the way the
//! native engine prepares weights at bind. Packing (and, for int8,
//! quantize-once) cost is measured separately and each packed row
//! carries `prep_secs` + `breakeven_calls` — how many kernel calls the
//! one-time preparation needs to pay for itself against the unpacked
//! per-call path.
//!
//! Since the explicit-SIMD layer (ISSUE 7) the packed series runs once
//! per dispatch level the host CPU offers (just `scalar` on default
//! features; AVX2/AVX-512/NEON under `--features simd`), each row
//! tagged with its `dispatch` level, and the sparse:dense crossover is
//! recomputed per level (`crossover_by_dispatch`) — vectorizing both
//! sides of the comparison moves the break-even honestly.

use std::collections::BTreeMap;

use amber_pruner::bench::{bench, black_box};
use amber_pruner::kernels::pack::PackedPanels;
use amber_pruner::kernels::simd::Dispatch;
use amber_pruner::kernels::{dense, int8, nm, reference, DEFAULT_DOUT_TILE};
use amber_pruner::quant;
use amber_pruner::sparsity::plan::planned_tile;
use amber_pruner::sparsity::spmm::NmCompressed;
use amber_pruner::util::json::Json;
use amber_pruner::util::rng::Rng;

const DIN: usize = 384;
const DOUT: usize = 384;
const TOKENS: [usize; 3] = [64, 256, 1024];
const RATIOS: [(usize, usize); 3] = [(2, 4), (4, 8), (8, 16)];
const WARMUP: usize = 1;
const ITERS: usize = 5;

fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal() as f32).collect()
}

struct Row {
    kernel: &'static str,
    imp: &'static str,
    ratio: Option<(usize, usize)>,
    tokens: usize,
    median_secs: f64,
    executed_flops: u64,
    /// one-time preparation seconds behind this series (packed rows)
    prep_secs: Option<f64>,
    /// calls for the one-time prep to break even vs the unpacked
    /// per-call path (None: not a packed row, or never breaks even)
    breakeven_calls: Option<f64>,
    /// panel width of the packed layout (packed rows)
    panel_w: Option<usize>,
    /// SIMD dispatch level the series ran at ("scalar" unless the
    /// `simd` feature resolved a vector level for a packed row)
    dispatch: &'static str,
}

impl Row {
    fn gflops(&self) -> f64 {
        self.executed_flops as f64 / self.median_secs.max(1e-12) / 1e9
    }
    fn json(&self, tiled_dense_median: Option<f64>) -> Json {
        let mut o = BTreeMap::new();
        o.insert("kernel".into(), Json::Str(self.kernel.into()));
        o.insert("impl".into(), Json::Str(self.imp.into()));
        o.insert(
            "prep_secs".into(),
            self.prep_secs.map(Json::Num).unwrap_or(Json::Null),
        );
        o.insert(
            "breakeven_calls".into(),
            self.breakeven_calls.map(Json::Num).unwrap_or(Json::Null),
        );
        o.insert(
            "panel_w".into(),
            self.panel_w
                .map(|w| Json::Num(w as f64))
                .unwrap_or(Json::Null),
        );
        o.insert("dispatch".into(), Json::Str(self.dispatch.into()));
        o.insert(
            "ratio".into(),
            match self.ratio {
                Some((n, m)) => Json::Str(format!("{n}:{m}")),
                None => Json::Null,
            },
        );
        o.insert("tokens".into(), Json::Num(self.tokens as f64));
        o.insert("din".into(), Json::Num(DIN as f64));
        o.insert("dout".into(), Json::Num(DOUT as f64));
        o.insert("median_secs".into(), Json::Num(self.median_secs));
        o.insert("gflops".into(), Json::Num(self.gflops()));
        // dense-equivalent throughput: what this wall-clock delivers in
        // dense-matmul terms (the serving-relevant number)
        let dense_flops = 2.0 * (self.tokens * DIN * DOUT) as f64;
        o.insert(
            "dense_equiv_gflops".into(),
            Json::Num(dense_flops / self.median_secs.max(1e-12) / 1e9),
        );
        o.insert(
            "speedup_vs_tiled_dense".into(),
            match tiled_dense_median {
                Some(d) => Json::Num(d / self.median_secs.max(1e-12)),
                None => Json::Null,
            },
        );
        Json::Obj(o)
    }
}

fn main() {
    let mut rng = Rng::new(42);
    let w = rand_vec(&mut rng, DIN * DOUT);
    let (wq, ws) = quant::quantize_weight(&w, DIN, DOUT);
    let mut rows: Vec<Row> = Vec::new();
    // tiled-dense medians per token count, the speedup/crossover base
    let mut dense_tiled_med: BTreeMap<usize, f64> = BTreeMap::new();
    // every SIMD dispatch level this build/CPU offers (just scalar on
    // default features): the packed series runs once per level, and
    // the sparse:dense crossover is recomputed per level
    let levels = Dispatch::available_levels();
    let mut packed_dense_med: BTreeMap<(&'static str, usize), f64> =
        BTreeMap::new();
    let mut packed_nm_med: BTreeMap<
        (&'static str, usize, usize, usize),
        f64,
    > = BTreeMap::new();

    // ---- one-time preparation (what NativeEngine::bind amortizes):
    // panel packing at the planned width, and quantize-once + pack for
    // the int8 path; per-call quantize_weight is the cost the old W8A8
    // hot path paid on every projection
    let panel_w = planned_tile(DOUT);
    let r = bench("prep.pack_f32", WARMUP, ITERS, None, || {
        black_box(PackedPanels::pack(&w, DIN, DOUT, panel_w));
    });
    let pack_secs = r.median_secs;
    let packed = PackedPanels::pack(&w, DIN, DOUT, panel_w);
    let r = bench("prep.quantize_weight", WARMUP, ITERS, None, || {
        black_box(quant::quantize_weight(&w, DIN, DOUT));
    });
    let quant_secs = r.median_secs;
    let r = bench("prep.quant_plus_pack_int8", WARMUP, ITERS, None, || {
        black_box(quant::quantize_weight_packed(&w, DIN, DOUT, panel_w));
    });
    let qpack_secs = r.median_secs;
    let (wq_packed, ws_packed) =
        quant::quantize_weight_packed(&w, DIN, DOUT, panel_w);

    // one-time prep -> per-call saving -> calls to break even
    let breakeven = |prep: f64, saving: f64| {
        (saving > 0.0).then_some(prep / saving)
    };

    println!("== spmm kernel core: reference vs tiled ({DIN}x{DOUT}) ==");
    for &t in &TOKENS {
        let x = rand_vec(&mut rng, t * DIN);
        let dense_flops = 2 * (t * DIN * DOUT) as u64;

        // ---- dense f32
        let r = bench(
            &format!("dense.reference      t={t}"),
            WARMUP,
            ITERS,
            Some(dense_flops),
            || {
                black_box(reference::dense(&x, t, DIN, &w, DOUT));
            },
        );
        rows.push(Row {
            kernel: "dense",
            imp: "reference",
            ratio: None,
            tokens: t,
            median_secs: r.median_secs,
            executed_flops: dense_flops,
            prep_secs: None,
            breakeven_calls: None,
            panel_w: None,
            dispatch: "scalar",
        });
        let mut out = vec![0.0f32; t * DOUT];
        let r = bench(
            &format!("dense.tiled          t={t}"),
            WARMUP,
            ITERS,
            Some(dense_flops),
            || {
                dense::dense_tiled(
                    &x,
                    t,
                    DIN,
                    &w,
                    DOUT,
                    DEFAULT_DOUT_TILE,
                    &mut out,
                );
                black_box(&out);
            },
        );
        dense_tiled_med.insert(t, r.median_secs);
        rows.push(Row {
            kernel: "dense",
            imp: "tiled",
            ratio: None,
            tokens: t,
            median_secs: r.median_secs,
            executed_flops: dense_flops,
            prep_secs: None,
            breakeven_calls: None,
            panel_w: None,
            dispatch: "scalar",
        });
        for &level in &levels {
            let disp = Dispatch::force(level).unwrap();
            let r = bench(
                &format!("dense.packed.{:<6} t={t}", level.name()),
                WARMUP,
                ITERS,
                Some(dense_flops),
                || {
                    (disp.dense)(&x, t, DIN, &packed, &mut out);
                    black_box(&out);
                },
            );
            packed_dense_med.insert((level.name(), t), r.median_secs);
            rows.push(Row {
                kernel: "dense",
                imp: "packed",
                ratio: None,
                tokens: t,
                median_secs: r.median_secs,
                executed_flops: dense_flops,
                prep_secs: Some(pack_secs),
                breakeven_calls: breakeven(
                    pack_secs,
                    dense_tiled_med[&t] - r.median_secs,
                ),
                panel_w: Some(panel_w),
                dispatch: level.name(),
            });
        }

        // ---- N:M compressed SpMM, every ratio
        for &(n, m) in &RATIOS {
            let c = NmCompressed::compress(&x, t, DIN, &[], n, m);
            let per_row = DIN / m * n;
            let sparse_flops = dense_flops * n as u64 / m as u64;
            let r = bench(
                &format!("nm{n}_{m}.reference    t={t}"),
                WARMUP,
                ITERS,
                Some(sparse_flops),
                || {
                    black_box(reference::spmm_nm(
                        &c.values, &c.index, t, per_row, &w, DOUT,
                    ));
                },
            );
            rows.push(Row {
                kernel: "nm",
                imp: "reference",
                ratio: Some((n, m)),
                tokens: t,
                median_secs: r.median_secs,
                executed_flops: sparse_flops,
                prep_secs: None,
                breakeven_calls: None,
                panel_w: None,
                dispatch: "scalar",
            });
            let mut out = vec![0.0f32; t * DOUT];
            let r = bench(
                &format!("nm{n}_{m}.tiled        t={t}"),
                WARMUP,
                ITERS,
                Some(sparse_flops),
                || {
                    nm::spmm_nm_tiled(
                        &c.values,
                        &c.index,
                        t,
                        per_row,
                        &w,
                        DOUT,
                        DEFAULT_DOUT_TILE,
                        &mut out,
                    );
                    black_box(&out);
                },
            );
            println!(
                "    -> vs tiled dense: {:.2}x (ideal {:.2}x)",
                dense_tiled_med[&t] / r.median_secs,
                m as f64 / n as f64
            );
            let nm_tiled_med = r.median_secs;
            rows.push(Row {
                kernel: "nm",
                imp: "tiled",
                ratio: Some((n, m)),
                tokens: t,
                median_secs: r.median_secs,
                executed_flops: sparse_flops,
                prep_secs: None,
                breakeven_calls: None,
                panel_w: None,
                dispatch: "scalar",
            });
            for &level in &levels {
                let disp = Dispatch::force(level).unwrap();
                let r = bench(
                    &format!(
                        "nm{n}_{m}.packed.{:<6} t={t}",
                        level.name()
                    ),
                    WARMUP,
                    ITERS,
                    Some(sparse_flops),
                    || {
                        (disp.spmm)(
                            &c.values, &c.index, t, per_row, &packed,
                            &mut out,
                        );
                        black_box(&out);
                    },
                );
                packed_nm_med
                    .insert((level.name(), n, m, t), r.median_secs);
                rows.push(Row {
                    kernel: "nm",
                    imp: "packed",
                    ratio: Some((n, m)),
                    tokens: t,
                    median_secs: r.median_secs,
                    executed_flops: sparse_flops,
                    prep_secs: Some(pack_secs),
                    breakeven_calls: breakeven(
                        pack_secs,
                        nm_tiled_med - r.median_secs,
                    ),
                    panel_w: Some(panel_w),
                    dispatch: level.name(),
                });
            }
        }

        // ---- W8A8 int8 (per-token activation scales, as served)
        let (xq, xs) = quant::quantize_per_token(&x, t, DIN);
        let r = bench(
            &format!("w8a8.reference       t={t}"),
            WARMUP,
            ITERS,
            Some(dense_flops),
            || {
                black_box(reference::w8a8_per_token(
                    &xq, t, DIN, &wq, DOUT, &xs, &ws,
                ));
            },
        );
        rows.push(Row {
            kernel: "w8a8",
            imp: "reference",
            ratio: None,
            tokens: t,
            median_secs: r.median_secs,
            executed_flops: dense_flops,
            prep_secs: None,
            breakeven_calls: None,
            panel_w: None,
            dispatch: "scalar",
        });
        let mut out = vec![0.0f32; t * DOUT];
        let r = bench(
            &format!("w8a8.tiled           t={t}"),
            WARMUP,
            ITERS,
            Some(dense_flops),
            || {
                int8::w8a8_tiled_per_token(
                    &xq,
                    t,
                    DIN,
                    &wq,
                    DOUT,
                    DEFAULT_DOUT_TILE,
                    &xs,
                    &ws,
                    &mut out,
                );
                black_box(&out);
            },
        );
        let w8a8_tiled_med = r.median_secs;
        rows.push(Row {
            kernel: "w8a8",
            imp: "tiled",
            ratio: None,
            tokens: t,
            median_secs: r.median_secs,
            executed_flops: dense_flops,
            prep_secs: None,
            breakeven_calls: None,
            panel_w: None,
            dispatch: "scalar",
        });
        for &level in &levels {
            let disp = Dispatch::force(level).unwrap();
            let r = bench(
                &format!("w8a8.packed.{:<6} t={t}", level.name()),
                WARMUP,
                ITERS,
                Some(dense_flops),
                || {
                    (disp.w8a8)(
                        &xq, t, DIN, &wq_packed, &xs, &ws_packed,
                        &mut out,
                    );
                    black_box(&out);
                },
            );
            rows.push(Row {
                kernel: "w8a8",
                imp: "packed",
                ratio: None,
                tokens: t,
                median_secs: r.median_secs,
                executed_flops: dense_flops,
                prep_secs: Some(qpack_secs),
                // the pre-prep W8A8 hot path re-quantized the weight
                // on every call: the per-call saving includes that
                // avoided quantization on top of the kernel delta
                breakeven_calls: breakeven(
                    qpack_secs,
                    quant_secs + w8a8_tiled_med - r.median_secs,
                ),
                panel_w: Some(panel_w),
                dispatch: level.name(),
            });
        }

        // compression overhead itself (prefill would fuse this)
        bench(
            &format!("compress 2:4         t={t}"),
            WARMUP,
            ITERS,
            Some((t * DIN) as u64),
            || {
                black_box(NmCompressed::compress(&x, t, DIN, &[], 2, 4));
            },
        );
    }

    // ---- crossover: smallest token count where tiled N:M beats tiled
    // dense, per ratio (None = never on these shapes)
    let mut crossover = BTreeMap::new();
    for &(n, m) in &RATIOS {
        let cross = TOKENS.iter().copied().find(|&t| {
            rows.iter().any(|r| {
                r.kernel == "nm"
                    && r.imp == "tiled"
                    && r.ratio == Some((n, m))
                    && r.tokens == t
                    && r.median_secs < dense_tiled_med[&t]
            })
        });
        println!(
            "crossover {n}:{m}: {}",
            cross
                .map(|t| format!("tokens >= {t}"))
                .unwrap_or_else(|| "not reached".into())
        );
        crossover.insert(
            format!("{n}:{m}"),
            match cross {
                Some(t) => Json::Num(t as f64),
                None => Json::Null,
            },
        );
    }

    // ---- per-dispatch crossover: same question for the packed
    // kernels at every available SIMD level (packed N:M vs packed
    // dense at the same level — vectorizing both sides moves the
    // break-even, and the acceptance bar is that it never moves above
    // the tiled baseline)
    let mut crossover_by_dispatch = BTreeMap::new();
    for &level in &levels {
        let mut per = BTreeMap::new();
        for &(n, m) in &RATIOS {
            let cross = TOKENS.iter().copied().find(|&t| {
                packed_nm_med[&(level.name(), n, m, t)]
                    < packed_dense_med[&(level.name(), t)]
            });
            println!(
                "crossover[{}] {n}:{m}: {}",
                level.name(),
                cross
                    .map(|t| format!("tokens >= {t}"))
                    .unwrap_or_else(|| "not reached".into())
            );
            per.insert(
                format!("{n}:{m}"),
                match cross {
                    Some(t) => Json::Num(t as f64),
                    None => Json::Null,
                },
            );
        }
        crossover_by_dispatch
            .insert(level.name().to_string(), Json::Obj(per));
    }

    let results: Vec<Json> = rows
        .iter()
        .map(|r| r.json(dense_tiled_med.get(&r.tokens).copied()))
        .collect();
    let mut root = BTreeMap::new();
    root.insert("bench".into(), Json::Str("spmm_kernel_core".into()));
    root.insert("din".into(), Json::Num(DIN as f64));
    root.insert("dout".into(), Json::Num(DOUT as f64));
    root.insert(
        "dout_tile".into(),
        Json::Num(DEFAULT_DOUT_TILE as f64),
    );
    // one-time preparation costs behind the packed series
    let mut prep = BTreeMap::new();
    prep.insert("panel_w".into(), Json::Num(panel_w as f64));
    prep.insert("pack_f32_secs".into(), Json::Num(pack_secs));
    prep.insert("quantize_weight_secs".into(), Json::Num(quant_secs));
    prep.insert("quant_plus_pack_secs".into(), Json::Num(qpack_secs));
    root.insert("prep".into(), Json::Obj(prep));
    root.insert("crossover".into(), Json::Obj(crossover));
    root.insert(
        "crossover_by_dispatch".into(),
        Json::Obj(crossover_by_dispatch),
    );
    root.insert("results".into(), Json::Arr(results));
    let path = "BENCH_spmm.json";
    match std::fs::write(path, Json::Obj(root).to_string()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => println!("could not write {path}: {e}"),
    }
}
