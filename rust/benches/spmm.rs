//! PERF bench: native N:M compressed SpMM vs dense matmul.
//!
//! This is the CPU stand-in for the paper's SpMM hardware: the compressed
//! kernel touches n/m of the weight rows, so wall-clock should scale
//! toward n/m of dense at matmul-bound sizes. Regenerates the mechanism
//! behind the paper's acceleration claims (EXPERIMENTS.md §Perf).

use amber_pruner::bench::{bench, black_box};
use amber_pruner::quant;
use amber_pruner::sparsity::spmm::{
    dense_matmul, dense_matmul_skip_zeros, NmCompressed,
};
use amber_pruner::util::rng::Rng;

fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal() as f32).collect()
}

fn main() {
    println!("== spmm: dense vs N:M compressed (f32) ==");
    let mut rng = Rng::new(42);
    // prefill-like projection sizes: T tokens x (din -> dout)
    for &(t, din, dout) in &[(256usize, 384usize, 384usize),
                             (512, 384, 1536),
                             (512, 1536, 384)] {
        let x = rand_vec(&mut rng, t * din);
        let w = rand_vec(&mut rng, din * dout);
        // fairness: the baseline is a TRUE dense matmul — no zero
        // skipping — so pruned inputs cannot make it silently sparse
        let name = format!("dense       {t}x{din}x{dout}");
        let dense = bench(&name, 2, 8, Some((t * din * dout) as u64), || {
            black_box(dense_matmul(&x, t, din, &w, dout));
        });
        for &(n, m) in &[(2usize, 4usize), (4, 8), (8, 16)] {
            let c = NmCompressed::compress(&x, t, din, &[], n, m);
            let label = format!("sparse {n}:{m}  {t}x{din}x{dout}");
            let sp = bench(&label, 2, 8, Some((t * din * dout) as u64), || {
                black_box(c.matmul(&w, dout));
            });
            println!(
                "    -> speedup {:.2}x (ideal {:.2}x)",
                dense.median_secs / sp.median_secs,
                m as f64 / n as f64
            );
        }
        // third series: what a branchy scalar kernel gets from the same
        // pruned input without the compressed format
        let pruned = NmCompressed::compress(&x, t, din, &[], 2, 4)
            .decompress();
        let bname = format!("branch 2:4  {t}x{din}x{dout}");
        bench(&bname, 2, 8, Some((t * din * dout) as u64), || {
            black_box(dense_matmul_skip_zeros(&pruned, t, din, &w, dout));
        });
        // compression overhead itself (prefill would fuse this)
        let cname = format!("compress 2:4 {t}x{din}");
        bench(&cname, 2, 8, Some((t * din) as u64), || {
            black_box(NmCompressed::compress(&x, t, din, &[], 2, 4));
        });
    }

    println!("\n== spmm int8 (Outstanding-sparse compute path) ==");
    let (t, din, dout) = (256usize, 384usize, 384usize);
    let x = rand_vec(&mut rng, t * din);
    let w = rand_vec(&mut rng, din * dout);
    let (wq, ws) = quant::quantize_weight(&w, din, dout);
    let xq = quant::quantize(&x, 0.05);
    bench("w8a8 dense  256x384x384", 2, 8,
          Some((t * din * dout) as u64), || {
        black_box(quant::w8a8_matmul(&xq, t, din, &wq, dout, 0.05, &ws));
    });
}
