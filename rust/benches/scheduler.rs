//! Coordinator micro-benchmarks (no PJRT): prefill-queue packing, KV slot
//! admit/release, router dispatch. These are the L3 hot-loop costs that
//! must stay negligible next to the model execution (§Perf L3 target).

use std::sync::mpsc::channel;
use std::time::Instant;

use amber_pruner::bench::{bench, black_box};
use amber_pruner::coordinator::batcher::{routing, ConfigKey, PrefillQueues};
use amber_pruner::coordinator::kv::KvPages;
use amber_pruner::coordinator::request::{Request, SparsityConfig, Tracked};
use amber_pruner::util::rng::Rng;

fn tracked(id: u64, cfg: SparsityConfig) -> Tracked {
    let (tx, rx) = channel();
    std::mem::forget(rx);
    Tracked {
        req: Request { id, prompt: vec![1; 32], max_new_tokens: 8,
                       config: cfg, deadline_ticks: 0 },
        arrived: Instant::now(),
        first_token_at: None,
        generated: vec![],
        reply: tx,
        retries: 0,
        deadline_at: None,
    }
}

fn main() {
    println!("== coordinator micro-benches ==");
    let configs = [
        SparsityConfig::dense(),
        SparsityConfig::amber(2, 4),
        SparsityConfig::amber(8, 16),
        SparsityConfig::outstanding(4, 8),
    ];

    bench("queue push+pack (1024 reqs, 4 configs)", 3, 20, Some(1024),
          || {
        let mut q = PrefillQueues::new(8, 0.001);
        let mut rng = Rng::new(1);
        for i in 0..1024u64 {
            let cfg = configs[rng.usize_below(4)];
            let (p, _, _) = routing("tiny-lm-a", 64, &cfg);
            q.push(ConfigKey(p), tracked(i, cfg));
        }
        let now = Instant::now();
        let mut total = 0;
        while let Some((_, b)) = q.next_batch(8, true, now) {
            total += b.len();
        }
        assert_eq!(total, 1024);
        black_box(total);
    });

    // paged KV admit/release churn at serving-like geometry: 8 seqs of
    // a 64-token prefill staged block-by-block, worst-case reservation
    let (l, seqs, c, h, d) = (6usize, 8usize, 320usize, 1usize, 32usize);
    let pre = vec![0.5f32; l * 8 * 64 * h * d];
    bench("kv admit+release (paged, 8 seqs, 64-token prefill)", 3, 50,
          Some(8), || {
        let mut kv = KvPages::new(l, seqs * c / 16, 16, h, d, c);
        for i in 0..seqs {
            kv.admit(i as u64, &pre, &pre, i, 8, 64, 48, 64).unwrap();
        }
        for i in 0..seqs {
            kv.release(i as u64).unwrap();
        }
        black_box(kv.free_blocks());
    });

    bench("routing resolution x1000", 3, 50, Some(1000), || {
        let mut acc = 0usize;
        for i in 0..1000u64 {
            let cfg = configs[(i % 4) as usize];
            let (p, d, w) = routing("tiny-lm-a", 64, &cfg);
            acc += p.len() + d.len() + w.len();
        }
        black_box(acc);
    });
}
