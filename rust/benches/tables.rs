//! `cargo bench --bench tables` — regenerates the paper's accuracy tables
//! and figures end-to-end through the rust runtime with a reduced sample
//! budget (fast smoke of the full repro path; `amber repro <t>` runs the
//! full budget). One bench entry per paper artifact, per DESIGN.md §4.

use amber_pruner::repro::{self, ReproCtx};

fn main() {
    let dir = std::path::Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("tables: artifacts/ missing — run `make artifacts`");
        return;
    }
    let ctx = ReproCtx { artifacts: dir, limit: 12, model: None };
    // table3's decode loops are the slow path — bench it on one model;
    // `amber repro table3` runs the full grid.
    let ctx_one = ReproCtx {
        artifacts: dir,
        limit: 8,
        model: Some("tiny-lm-a".to_string()),
    };
    for target in [
        "coverage",
        "tpu-model",
        "ablation",
        "fig2",
        "fig34",
        "fig6",
        "appc",
        "table1",
        "table2",
        "table3",
        "app-table1",
    ] {
        let c = if target == "table3" { &ctx_one } else { &ctx };
        let t0 = std::time::Instant::now();
        match repro::run(target, c) {
            Ok(()) => println!(
                "[tables] {target} regenerated in {:.1}s",
                t0.elapsed().as_secs_f64()
            ),
            Err(e) => println!("[tables] {target} SKIPPED: {e:#}"),
        }
    }
}
