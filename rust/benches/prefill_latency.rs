//! End-to-end prefill latency through the execution engine: dense vs each
//! N:M ratio (fp and W8A8). On the native CPU backend the sparse
//! artifacts really do less matmul work (compressed SpMM), so the ratios
//! report the paper's compute scaling directly; the coverage/ideal-speedup
//! model and the native spmm bench report the isolated mechanism
//! (§Perf L2/L3).
//!
//! Runs out of the box: without an `artifacts/` manifest the native
//! engine serves its synthetic inventory.

use amber_pruner::bench::bench;
use amber_pruner::runtime::{engine_for, Engine as _};

fn main() {
    let dir = std::path::Path::new("artifacts");
    let mut rt = match engine_for(dir) {
        Ok(rt) => rt,
        Err(e) => {
            println!("prefill_latency: engine unavailable: {e}");
            return;
        }
    };
    let model = "tiny-lm-a";
    let weights = format!("{model}.atw");
    let prefill_art = format!("{model}.prefill64.dense");
    let Some(meta) = rt.manifest().artifacts.get(&prefill_art).cloned()
    else {
        println!("prefill_latency: {prefill_art} not in manifest");
        return;
    };
    let (b, s) = (meta.batch, meta.seq);
    let tokens: Vec<i32> =
        (0..b * s).map(|i| 1 + (i % 300) as i32).collect();

    let mut variants: Vec<(String, Vec<String>)> =
        vec![(prefill_art.clone(), vec![weights.clone()])];
    for (n, m) in [(2, 4), (4, 8), (8, 16)] {
        let art = format!("{model}.prefill64.nm{n}_{m}");
        if rt.manifest().artifacts.contains_key(&art) {
            variants.push((
                art,
                vec![weights.clone(), format!("{model}.aux_ls.atw")],
            ));
        }
    }
    let sq = format!("{model}.prefill64.sq");
    if rt.manifest().artifacts.contains_key(&sq) {
        variants.push((sq, vec![format!("{model}.sq.atw")]));
    }

    println!("== prefill latency (batch {b} x seq {s}) ==");
    let mut dense_med = 0.0;
    for (art, files) in variants {
        let refs: Vec<&str> = files.iter().map(|s| s.as_str()).collect();
        let binding = match rt.bind(&art, &refs) {
            Ok(bd) => bd,
            Err(e) => {
                println!("skip {art}: {e}");
                continue;
            }
        };
        let r = bench(&art, 2, 10, Some((b * s) as u64), || {
            rt.prefill(&art, &binding, &tokens).expect("prefill");
        });
        if art.ends_with("dense") {
            dense_med = r.median_secs;
        } else if dense_med > 0.0 {
            println!(
                "    -> vs dense: {:.2}x",
                dense_med / r.median_secs
            );
        }
    }

    // decode step latency (the TPOT floor)
    let dec = format!("{model}.decode.dense");
    if rt.manifest().artifacts.contains_key(&dec) {
        let binding = rt.bind(&dec, &[&weights]).expect("bind decode");
        let dmeta = rt.manifest().artifact(&dec).unwrap().clone();
        let db = dmeta.batch;
        let dims = dmeta.runtime_inputs[2].0.clone();
        let n: usize = dims.iter().product();
        let kc = vec![0f32; n];
        let vc = vec![0f32; n];
        let token = vec![5i32; db];
        let pos = vec![3i32; db];
        let kv_len = vec![4i32; db];
        bench(&dec, 2, 10, Some(db as u64), || {
            rt.decode(&dec, &binding, &token, &pos, &kc, &vc, &kv_len)
                .expect("decode");
        });
    }
}
