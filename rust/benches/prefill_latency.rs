//! End-to-end prefill latency through the AOT executables: dense vs each
//! N:M ratio (fp and W8A8). On the CPU interpret substrate the sparse
//! graphs pay an argsort overhead instead of gaining SpMM speedup — the
//! *compute reduction* is reported by the coverage/ideal-speedup model and
//! the native spmm bench; this bench pins down the absolute artifact
//! latencies the coordinator schedules around (§Perf L2/L3).
//!
//! Skips gracefully when artifacts/ have not been built.

use amber_pruner::bench::bench;
use amber_pruner::runtime::ModelRuntime;

fn main() {
    let dir = std::path::Path::new("artifacts");
    let Ok(mut rt) = ModelRuntime::new(dir) else {
        println!("prefill_latency: artifacts/ missing — run `make artifacts`");
        return;
    };
    let model = "tiny-lm-a";
    let weights = format!("{model}.atw");
    let tokens: Vec<i32> = (0..8 * 64).map(|i| 1 + (i % 300) as i32).collect();

    let mut variants: Vec<(String, Vec<String>)> = vec![
        (format!("{model}.prefill64.dense"), vec![weights.clone()]),
    ];
    for (n, m) in [(2, 4), (4, 8), (8, 16)] {
        let art = format!("{model}.prefill64.nm{n}_{m}");
        if rt.manifest.artifacts.contains_key(&art) {
            variants.push((
                art,
                vec![weights.clone(), format!("{model}.aux_ls.atw")],
            ));
        }
    }
    let sq = format!("{model}.prefill64.sq");
    if rt.manifest.artifacts.contains_key(&sq) {
        variants.push((sq, vec![format!("{model}.sq.atw")]));
    }

    println!("== prefill latency (batch 8 x seq 64) ==");
    let mut dense_med = 0.0;
    for (art, files) in variants {
        let refs: Vec<&str> = files.iter().map(|s| s.as_str()).collect();
        let binding = match rt.bind(&art, &refs) {
            Ok(b) => b,
            Err(e) => {
                println!("skip {art}: {e}");
                continue;
            }
        };
        let r = bench(&art, 2, 10, Some(8 * 64), || {
            rt.prefill(&art, &binding, &tokens).expect("prefill");
        });
        if art.ends_with("dense") {
            dense_med = r.median_secs;
        } else if dense_med > 0.0 {
            println!(
                "    -> vs dense: {:.2}x (interpret-substrate overhead; \
                 see spmm bench for the SpMM mechanism)",
                dense_med / r.median_secs
            );
        }
    }

    // decode step latency (the TPOT floor)
    let dec = format!("{model}.decode.dense");
    if rt.manifest.artifacts.contains_key(&dec) {
        let binding = rt.bind(&dec, &[&weights]).expect("bind decode");
        let meta = rt.manifest.artifact(&dec).unwrap().clone();
        let b = meta.batch;
        let dims = rt.manifest.artifact(&dec).unwrap().runtime_inputs[2]
            .0
            .clone();
        let n: usize = dims.iter().product();
        let zeros = vec![0f32; n];
        let k = amber_pruner::tensor::HostTensor::f32(
            "k",
            dims.iter().map(|&d| d as i64).collect(),
            &zeros,
        )
        .to_literal()
        .unwrap();
        let v = amber_pruner::tensor::HostTensor::f32(
            "v",
            dims.iter().map(|&d| d as i64).collect(),
            &zeros,
        )
        .to_literal()
        .unwrap();
        let token = vec![5i32; b];
        let pos = vec![3i32; b];
        let kv_len = vec![4i32; b];
        bench(&dec, 2, 10, Some(b as u64), || {
            rt.decode(&dec, &binding, &token, &pos, &k, &v, &kv_len)
                .expect("decode");
        });
    }
}
