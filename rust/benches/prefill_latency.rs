//! End-to-end prefill latency through the execution engine: dense vs each
//! N:M ratio (fp and W8A8). On the native CPU backend the sparse
//! artifacts really do less matmul work (compressed SpMM), so the ratios
//! report the paper's compute scaling directly; the coverage/ideal-speedup
//! model and the native spmm bench report the isolated mechanism
//! (§Perf L2/L3).
//!
//! The batched section drives the token-packed pipeline across
//! tokens x {dense, 2:4, 4:8, 8:16} x pool width and emits
//! machine-readable results to `BENCH_prefill.json` (written next to the
//! package manifest when run via `cargo bench --bench prefill_latency`) —
//! the perf baseline future PRs regress against. Each row carries a
//! `chunk_tokens` column (0 = one-shot); the chunked row set replays
//! the same token population the way the continuous-batching scheduler
//! does under `chunk_tokens` (ISSUE 8), pricing the chunking overhead
//! against the one-shot rows. Every projection here
//! executes through the register-tiled kernel core (`kernels::*` via
//! the engine's per-module `SparsityPlan::tiles` table), so these
//! numbers reflect
//! the tiled kernels, not the retained reference loops (those are
//! benched head-to-head in `cargo bench --bench spmm`).
//!
//! Runs out of the box: without an `artifacts/` manifest the native
//! engine serves its synthetic inventory.
//!
//! Latencies here are **steady-state**: every variant is bound (and
//! its weights panel-packed / quantize-cached) before the timed loop,
//! so the numbers measure the post-bind hot path the way serving runs
//! it. The one-time preparation cost is reported separately — per
//! variant as `prep_secs` (the bind wall time, dominated by weight
//! preparation on a fresh engine) and per pool sweep as the engine's
//! cumulative `prep_stats` snapshot in `BENCH_prefill.json`.

use std::collections::BTreeMap;
use std::time::Instant;

use amber_pruner::bench::bench;
use amber_pruner::runtime::{engine_for, Engine as _, PrefixedPrompt};
use amber_pruner::util::json::Json;

const MODEL: &str = "tiny-lm-a";

fn artifact_section(rt: &mut Box<dyn amber_pruner::runtime::Engine>) {
    let weights = format!("{MODEL}.atw");
    let prefill_art = format!("{MODEL}.prefill64.dense");
    let Some(meta) = rt.manifest().artifacts.get(&prefill_art).cloned()
    else {
        println!("prefill_latency: {prefill_art} not in manifest");
        return;
    };
    let (b, s) = (meta.batch, meta.seq);
    let tokens: Vec<i32> =
        (0..b * s).map(|i| 1 + (i % 300) as i32).collect();

    let mut variants: Vec<(String, Vec<String>)> =
        vec![(prefill_art.clone(), vec![weights.clone()])];
    for (n, m) in [(2, 4), (4, 8), (8, 16)] {
        let art = format!("{MODEL}.prefill64.nm{n}_{m}");
        if rt.manifest().artifacts.contains_key(&art) {
            variants.push((
                art,
                vec![weights.clone(), format!("{MODEL}.aux_ls.atw")],
            ));
        }
    }
    let sq = format!("{MODEL}.prefill64.sq");
    if rt.manifest().artifacts.contains_key(&sq) {
        variants.push((sq, vec![format!("{MODEL}.sq.atw")]));
    }

    println!("== prefill latency (batch {b} x seq {s}) ==");
    let mut dense_med = 0.0;
    for (art, files) in variants {
        let refs: Vec<&str> = files.iter().map(|s| s.as_str()).collect();
        let binding = match rt.bind(&art, &refs) {
            Ok(bd) => bd,
            Err(e) => {
                println!("skip {art}: {e}");
                continue;
            }
        };
        let r = bench(&art, 2, 10, Some((b * s) as u64), || {
            rt.prefill(&art, &binding, &tokens).expect("prefill");
        });
        if art.ends_with("dense") {
            dense_med = r.median_secs;
        } else if dense_med > 0.0 {
            println!(
                "    -> vs dense: {:.2}x",
                dense_med / r.median_secs
            );
        }
    }

    // decode step latency (the TPOT floor)
    let dec = format!("{MODEL}.decode.dense");
    if rt.manifest().artifacts.contains_key(&dec) {
        let binding = rt.bind(&dec, &[&weights]).expect("bind decode");
        let dmeta = rt.manifest().artifact(&dec).unwrap().clone();
        let db = dmeta.batch;
        let dims = dmeta.runtime_inputs[2].0.clone();
        let n: usize = dims.iter().product();
        let kc = vec![0f32; n];
        let vc = vec![0f32; n];
        let token = vec![5i32; db];
        let pos = vec![3i32; db];
        let kv_len = vec![4i32; db];
        bench(&dec, 2, 10, Some(db as u64), || {
            rt.decode(&dec, &binding, &token, &pos, &kc, &vc, &kv_len)
                .expect("decode");
        });
    }
}

fn num(v: f64) -> Json {
    Json::Num(v)
}

/// Batched token-packed prefill: tokens x variant x pool width, emitted
/// to BENCH_prefill.json.
fn batched_section() {
    let dir = std::path::Path::new("artifacts");
    let seq = 64usize;
    let weights = format!("{MODEL}.atw");
    let mut results: Vec<Json> = Vec::new();
    let mut prep_snapshots: Vec<Json> = Vec::new();
    println!("== batched packed prefill (seq {seq} per request) ==");
    for &pool in &[1usize, 4] {
        let mut rt = match engine_for(dir) {
            Ok(rt) => rt,
            Err(e) => {
                println!("batched: engine unavailable: {e}");
                return;
            }
        };
        rt.set_parallelism(pool);
        let mut dense_med: BTreeMap<usize, f64> = BTreeMap::new();
        for variant in ["dense", "nm2_4", "nm4_8", "nm8_16"] {
            let art = format!("{MODEL}.prefill{seq}.{variant}");
            if !rt.manifest().artifacts.contains_key(&art) {
                println!("skip {art}: not in manifest");
                continue;
            }
            let files: Vec<String> = if variant == "dense" {
                vec![weights.clone()]
            } else {
                vec![weights.clone(), format!("{MODEL}.aux_ls.atw")]
            };
            let refs: Vec<&str> =
                files.iter().map(|s| s.as_str()).collect();
            // one-time cost (weight prep happens here, not in the
            // timed loop below): bind wall time on this engine
            let t0 = Instant::now();
            let binding = rt.bind(&art, &refs).expect("bind");
            let prep_secs = t0.elapsed().as_secs_f64();
            println!(
                "bind {art}: {:.3}ms one-time prep",
                prep_secs * 1e3
            );
            for &tokens in &[64usize, 256, 1024] {
                let n_req = tokens / seq;
                let prompts: Vec<Vec<i32>> = (0..n_req)
                    .map(|r| {
                        (0..seq)
                            .map(|i| 1 + ((r * seq + i) % 300) as i32)
                            .collect()
                    })
                    .collect();
                let name =
                    format!("packed.{variant}.t{tokens}.pool{pool}");
                let r = bench(&name, 2, 10, Some(tokens as u64), || {
                    rt.prefill_packed(&art, &binding, &prompts)
                        .expect("packed prefill");
                });
                let speedup = if variant == "dense" {
                    dense_med.insert(tokens, r.median_secs);
                    1.0
                } else {
                    dense_med
                        .get(&tokens)
                        .map(|d| d / r.median_secs)
                        .unwrap_or(0.0)
                };
                if variant != "dense" && speedup > 0.0 {
                    println!("    -> vs dense: {speedup:.2}x");
                }
                let mut o = BTreeMap::new();
                o.insert("variant".into(), Json::Str(variant.into()));
                o.insert("tokens".into(), num(tokens as f64));
                o.insert("pool".into(), num(pool as f64));
                o.insert("requests".into(), num(n_req as f64));
                o.insert("chunk_tokens".into(), num(0.0));
                o.insert("median_secs".into(), num(r.median_secs));
                o.insert("mean_secs".into(), num(r.mean_secs));
                o.insert("p95_secs".into(), num(r.p95_secs));
                o.insert(
                    "toks_per_sec".into(),
                    num(r.throughput.unwrap_or(0.0)),
                );
                o.insert("speedup_vs_dense".into(), num(speedup));
                o.insert("prep_secs".into(), num(prep_secs));
                results.push(Json::Obj(o));
            }
        }
        // chunked prefill rows (ISSUE 8): replay the same 1024 tokens
        // the way the scheduler serves them under `chunk_tokens` —
        // every request's i-th chunk batched into one prefixed prefill
        // over the request's own earlier chunks. The prefix K/V is a
        // cold prefill of the leading tokens, staged OUTSIDE the timed
        // loop (the serving engine gathers it from the paged KV store),
        // so the rows price exactly the chunking overhead: re-attention
        // over the cached prefix plus the extra dispatches.
        for variant in ["dense", "nm2_4"] {
            let art = format!("{MODEL}.prefill{seq}.{variant}");
            if !rt.manifest().artifacts.contains_key(&art) {
                continue;
            }
            let files: Vec<String> = if variant == "dense" {
                vec![weights.clone()]
            } else {
                vec![weights.clone(), format!("{MODEL}.aux_ls.atw")]
            };
            let refs: Vec<&str> =
                files.iter().map(|s| s.as_str()).collect();
            let binding = rt.bind(&art, &refs).expect("bind");
            let tokens = 1024usize;
            let n_req = tokens / seq;
            let prompts: Vec<Vec<i32>> = (0..n_req)
                .map(|r| {
                    (0..seq)
                        .map(|i| 1 + ((r * seq + i) % 300) as i32)
                        .collect()
                })
                .collect();
            for &chunk in &[16usize, 32] {
                let mut batches: Vec<Vec<PrefixedPrompt>> = Vec::new();
                let mut done = 0usize;
                while done < seq {
                    let len = chunk.min(seq - done);
                    let mut batch = Vec::with_capacity(n_req);
                    for p in &prompts {
                        let (pk, pv) = if done == 0 {
                            (Vec::new(), Vec::new())
                        } else {
                            let prefix = p[..done].to_vec();
                            let out = rt
                                .prefill_packed(
                                    &art,
                                    &binding,
                                    std::slice::from_ref(&prefix),
                                )
                                .expect("prefix prefill");
                            (out.k_cache, out.v_cache)
                        };
                        batch.push(PrefixedPrompt {
                            tokens: p[..done + len].to_vec(),
                            cached_len: done,
                            prefix_k: pk,
                            prefix_v: pv,
                        });
                    }
                    batches.push(batch);
                    done += len;
                }
                let name = format!(
                    "chunked.{variant}.t{tokens}.pool{pool}.c{chunk}"
                );
                let r = bench(&name, 2, 10, Some(tokens as u64), || {
                    for batch in &batches {
                        rt.prefill_packed_prefixed(&art, &binding, batch)
                            .expect("chunked prefill");
                    }
                });
                let speedup = dense_med
                    .get(&tokens)
                    .map(|d| d / r.median_secs)
                    .unwrap_or(0.0);
                if speedup > 0.0 {
                    println!("    -> vs one-shot dense: {speedup:.2}x");
                }
                let mut o = BTreeMap::new();
                o.insert("variant".into(), Json::Str(variant.into()));
                o.insert("tokens".into(), num(tokens as f64));
                o.insert("pool".into(), num(pool as f64));
                o.insert("requests".into(), num(n_req as f64));
                o.insert("chunk_tokens".into(), num(chunk as f64));
                o.insert("median_secs".into(), num(r.median_secs));
                o.insert("mean_secs".into(), num(r.mean_secs));
                o.insert("p95_secs".into(), num(r.p95_secs));
                o.insert(
                    "toks_per_sec".into(),
                    num(r.throughput.unwrap_or(0.0)),
                );
                o.insert("speedup_vs_dense".into(), num(speedup));
                results.push(Json::Obj(o));
            }
        }
        // cumulative weight-preparation accounting for this pool's
        // engine: one bind's worth of misses, the rest cache hits
        if let Some(ps) = rt.prep_stats() {
            let mut o = BTreeMap::new();
            o.insert("pool".into(), num(pool as f64));
            o.insert(
                "weights_packed".into(),
                num(ps.weights_packed as f64),
            );
            o.insert(
                "weights_quantized".into(),
                num(ps.weights_quantized as f64),
            );
            o.insert("cache_hits".into(), num(ps.cache_hits as f64));
            o.insert(
                "bytes_packed".into(),
                num(ps.bytes_packed as f64),
            );
            o.insert("prep_secs".into(), num(ps.prep_secs));
            prep_snapshots.push(Json::Obj(o));
        }
    }
    let mut root = BTreeMap::new();
    root.insert("bench".into(), Json::Str("batched_prefill".into()));
    root.insert("model".into(), Json::Str(MODEL.into()));
    root.insert("seq_per_request".into(), num(seq as f64));
    root.insert("prep_stats".into(), Json::Arr(prep_snapshots));
    root.insert("results".into(), Json::Arr(results));
    let path = "BENCH_prefill.json";
    match std::fs::write(path, Json::Obj(root).to_string()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => println!("could not write {path}: {e}"),
    }
}

fn main() {
    let dir = std::path::Path::new("artifacts");
    let mut rt = match engine_for(dir) {
        Ok(rt) => rt,
        Err(e) => {
            println!("prefill_latency: engine unavailable: {e}");
            return;
        }
    };
    artifact_section(&mut rt);
    batched_section();
}
