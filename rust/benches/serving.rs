//! Multi-replica serving latency: TTFT / TPOT percentiles for the same
//! request burst served by replica pools of width 1, 2 and 4, plus a
//! failover row where one of two replicas is killed mid-burst (ISSUE
//! 10). The pool runs the full supervised path — router dispatch,
//! per-replica engines on their own threads, fan-in, and (in the
//! failover row) crash detection plus re-dispatch — so the rows price
//! the coordination overhead and the failover recovery cost, not just
//! the kernels. Emits machine-readable results to `BENCH_serving.json`
//! (written next to the package manifest when run via
//! `cargo bench --bench serving`).
//!
//! Runs out of the box on the synthetic tiny model; no artifacts or
//! PJRT required.

use std::collections::BTreeMap;
use std::sync::atomic::Ordering;
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::{Duration, Instant};

use amber_pruner::coordinator::replica::{
    EngineFactory, PoolConfig, ReplicaPool,
};
use amber_pruner::coordinator::request::{
    Request, Response, SparsityConfig,
};
use amber_pruner::coordinator::scheduler::{Engine, EngineConfig};
use amber_pruner::metrics::stats::Histogram;
use amber_pruner::metrics::EngineMetrics;
use amber_pruner::runtime::NativeEngine;
use amber_pruner::util::json::Json;
use amber_pruner::util::rng::Rng;

const MODEL: &str = "tiny-lm-a";
const REQUESTS: usize = 48;
const PROMPT_LEN: usize = 48;
const MAX_NEW: usize = 8;

fn factory(metrics: &Arc<EngineMetrics>) -> EngineFactory {
    let m = Arc::clone(metrics);
    Arc::new(move |_i| {
        let mut cfg = EngineConfig::new(MODEL);
        cfg.pool_threads = 1;
        cfg.max_wait_secs = 0.0;
        cfg.prefix_cache = false;
        Engine::new(Box::new(NativeEngine::tiny()), cfg, Arc::clone(&m))
    })
}

fn burst() -> Vec<Request> {
    let mut rng = Rng::new(0xbe_5e_7a);
    (0..REQUESTS as u64)
        .map(|id| Request {
            id,
            prompt: (0..PROMPT_LEN)
                .map(|_| 1 + rng.below(300) as i32)
                .collect(),
            max_new_tokens: MAX_NEW,
            config: SparsityConfig::dense(),
            deadline_ticks: 0,
        })
        .collect()
}

struct Row {
    label: String,
    replicas: usize,
    failover: bool,
    wall_secs: f64,
    ttft_p50: f64,
    ttft_p99: f64,
    tpot_p50: f64,
    tpot_p99: f64,
    redispatches: u64,
    restarts: u64,
}

/// Serve the fixed burst on a fresh pool of `replicas` engines; with
/// `failover` the busiest of two replicas is stalled briefly and killed
/// once work is observed outstanding, so the row includes detection,
/// restart and re-dispatch recovery in its tail.
fn run_pool(label: &str, replicas: usize, failover: bool) -> Row {
    let metrics = Arc::new(EngineMetrics::new());
    let mut cfg = PoolConfig::new(replicas);
    cfg.poll = Duration::from_millis(1);
    // benches share loaded CI machines: never let a slow tick read as a
    // hung replica (thread-death detection still covers the kill row)
    cfg.heartbeat_timeout = Duration::ZERO;
    let mut pool =
        ReplicaPool::start(factory(&metrics), Arc::clone(&metrics), cfg)
            .expect("pool start");
    let handle = pool.handle();

    let reqs = burst();
    let (tx, rx) = channel::<Response>();
    let t0 = Instant::now();
    for r in &reqs {
        handle.submit(r.clone(), tx.clone()).expect("submit");
    }
    if failover {
        // wait until someone actually owns work, then strike the
        // busiest replica while a stall pins its queue
        let deadline = Instant::now() + Duration::from_secs(10);
        let victim = loop {
            let snap = handle.snapshot().expect("snapshot");
            if let Some(s) = snap
                .iter()
                .filter(|s| s.outstanding > 0)
                .max_by_key(|s| s.outstanding)
            {
                break s.index;
            }
            assert!(Instant::now() < deadline, "no replica took work");
            std::thread::sleep(Duration::from_micros(200));
        };
        handle.stall(victim, 20);
        handle.kill(victim);
    }

    let mut ttft = Histogram::new();
    let mut tpot = Histogram::new();
    for _ in 0..reqs.len() {
        let r = rx
            .recv_timeout(Duration::from_secs(120))
            .expect("response");
        assert!(r.error.is_none(), "bench burst must not error");
        ttft.observe(r.ttft_secs);
        if r.tokens.len() > 1 {
            tpot.observe(
                (r.e2e_secs - r.ttft_secs) / (r.tokens.len() - 1) as f64,
            );
        }
    }
    let wall_secs = t0.elapsed().as_secs_f64();
    pool.shutdown().expect("pool shutdown");

    let (ts, ds) = (ttft.summary(), tpot.summary());
    let row = Row {
        label: label.to_string(),
        replicas,
        failover,
        wall_secs,
        ttft_p50: ts.p50,
        ttft_p99: ts.p99,
        tpot_p50: ds.p50,
        tpot_p99: ds.p99,
        redispatches: metrics
            .replica_redispatches
            .load(Ordering::Relaxed),
        restarts: metrics.replica_restarts.load(Ordering::Relaxed),
    };
    println!(
        "bench {:<24} wall {:>8.3}s  ttft p50 {:>8.3}ms p99 {:>8.3}ms  \
         tpot p50 {:>8.3}ms p99 {:>8.3}ms  redispatch {}  restarts {}",
        row.label,
        row.wall_secs,
        row.ttft_p50 * 1e3,
        row.ttft_p99 * 1e3,
        row.tpot_p50 * 1e3,
        row.tpot_p99 * 1e3,
        row.redispatches,
        row.restarts,
    );
    row
}

fn num(v: f64) -> Json {
    Json::Num(v)
}

fn main() {
    println!(
        "== multi-replica serving ({REQUESTS} reqs, prompt {PROMPT_LEN}, \
         {MAX_NEW} new tokens) =="
    );
    let mut rows: Vec<Row> = Vec::new();
    for &replicas in &[1usize, 2, 4] {
        rows.push(run_pool(
            &format!("replicas{replicas}"),
            replicas,
            false,
        ));
    }
    rows.push(run_pool("replicas2.failover", 2, true));

    let baseline = rows[0].wall_secs;
    let results: Vec<Json> = rows
        .iter()
        .map(|r| {
            if r.replicas > 1 && !r.failover {
                println!(
                    "    -> {} vs 1 replica: {:.2}x wall",
                    r.label,
                    baseline / r.wall_secs.max(1e-12)
                );
            }
            let mut o = BTreeMap::new();
            o.insert("label".into(), Json::Str(r.label.clone()));
            o.insert("replicas".into(), num(r.replicas as f64));
            o.insert("failover".into(), Json::Bool(r.failover));
            o.insert("requests".into(), num(REQUESTS as f64));
            o.insert("wall_secs".into(), num(r.wall_secs));
            o.insert("ttft_p50_secs".into(), num(r.ttft_p50));
            o.insert("ttft_p99_secs".into(), num(r.ttft_p99));
            o.insert("tpot_p50_secs".into(), num(r.tpot_p50));
            o.insert("tpot_p99_secs".into(), num(r.tpot_p99));
            o.insert(
                "redispatches".into(),
                num(r.redispatches as f64),
            );
            o.insert("restarts".into(), num(r.restarts as f64));
            Json::Obj(o)
        })
        .collect();

    let mut root = BTreeMap::new();
    root.insert("bench".into(), Json::Str("replica_serving".into()));
    root.insert("model".into(), Json::Str(MODEL.into()));
    root.insert("requests".into(), num(REQUESTS as f64));
    root.insert("prompt_len".into(), num(PROMPT_LEN as f64));
    root.insert("max_new_tokens".into(), num(MAX_NEW as f64));
    root.insert("results".into(), Json::Arr(results));
    let path = "BENCH_serving.json";
    match std::fs::write(path, Json::Obj(root).to_string()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => println!("could not write {path}: {e}"),
    }
}
