//! Continuous-batching queues: per-config FIFO prefill queues with a
//! prefill-prioritized packing policy (the paper accelerates *prefill*, so
//! the scheduler favors draining prompt work; decode advances whenever no
//! prefill batch is ready, mirroring vLLM's iteration-level scheduling).

use std::collections::{BTreeMap, VecDeque};
use std::time::Instant;

use super::request::{SparsityConfig, Tracked};

/// Queue key: requests in one bucket share prefill artifact + binding.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct ConfigKey(pub String);

pub struct PrefillQueues {
    queues: BTreeMap<ConfigKey, VecDeque<Tracked>>,
    pub max_batch: usize,
    /// flush a partial batch when its head has waited this long
    pub max_wait_secs: f64,
}

impl PrefillQueues {
    pub fn new(max_batch: usize, max_wait_secs: f64) -> Self {
        PrefillQueues {
            queues: BTreeMap::new(),
            max_batch,
            max_wait_secs,
        }
    }

    pub fn push(&mut self, key: ConfigKey, t: Tracked) {
        self.queues.entry(key).or_default().push_back(t);
    }

    pub fn waiting(&self) -> usize {
        self.queues.values().map(|q| q.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.waiting() == 0
    }

    /// Pick the bucket to prefill next: a full batch if any bucket has
    /// one; otherwise the bucket with the oldest head *if* it exceeded
    /// max_wait or the engine is otherwise idle (`idle == true`).
    /// Returns up to `free_slots.min(max_batch)` requests.
    pub fn next_batch(
        &mut self,
        free_slots: usize,
        idle: bool,
        now: Instant,
    ) -> Option<(ConfigKey, Vec<Tracked>)> {
        let cap = self.max_batch.min(free_slots);
        if cap == 0 {
            return None;
        }
        // full batch available?
        let full = self
            .queues
            .iter()
            .filter(|(_, q)| q.len() >= cap)
            .map(|(k, _)| k.clone())
            .next();
        let key = match full {
            Some(k) => Some(k),
            None => {
                // oldest head across buckets
                let oldest = self
                    .queues
                    .iter()
                    .filter(|(_, q)| !q.is_empty())
                    .min_by_key(|(_, q)| q.front().unwrap().arrived);
                match oldest {
                    Some((k, q)) => {
                        let age = now
                            .duration_since(q.front().unwrap().arrived)
                            .as_secs_f64();
                        if idle || age >= self.max_wait_secs {
                            Some(k.clone())
                        } else {
                            None
                        }
                    }
                    None => None,
                }
            }
        }?;
        let q = self.queues.get_mut(&key).unwrap();
        let n = q.len().min(cap);
        let batch: Vec<Tracked> = q.drain(..n).collect();
        if q.is_empty() {
            self.queues.remove(&key);
        }
        Some((key, batch))
    }
}

/// Map a request's sparsity config to (prefill artifact, decode artifact,
/// weight files) under the artifact naming convention.
pub fn routing(
    model: &str,
    seq: usize,
    cfg: &SparsityConfig,
) -> (String, String, Vec<String>) {
    let sq = cfg.quantized;
    let weights = if sq {
        format!("{model}.sq.atw")
    } else {
        format!("{model}.atw")
    };
    match cfg.nm {
        None => {
            let variant = if sq { "sq" } else { "dense" };
            (
                format!("{model}.prefill{seq}.{variant}"),
                format!("{model}.decode.{}", if sq { "sq" } else { "dense" }),
                vec![weights],
            )
        }
        Some((n, m)) => {
            let variant = if sq { "sq_nm" } else { "nm" };
            let aux = cfg.setting.aux_file(model, sq);
            (
                format!("{model}.prefill{seq}.{variant}{n}_{m}"),
                format!("{model}.decode.{}", if sq { "sq" } else { "dense" }),
                vec![weights, aux],
            )
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparsity::policy::Setting;
    use std::sync::mpsc::channel;

    fn tracked(id: u64) -> Tracked {
        let (tx, _rx) = channel();
        Tracked {
            req: super::super::request::Request {
                id,
                prompt: vec![1, 2],
                max_new_tokens: 4,
                config: SparsityConfig::dense(),
            },
            arrived: Instant::now(),
            first_token_at: None,
            generated: vec![],
            reply: tx,
        }
    }

    #[test]
    fn full_batch_preferred() {
        let mut q = PrefillQueues::new(2, 10.0);
        q.push(ConfigKey("a".into()), tracked(1));
        q.push(ConfigKey("b".into()), tracked(2));
        q.push(ConfigKey("b".into()), tracked(3));
        let (k, batch) =
            q.next_batch(8, false, Instant::now()).expect("batch");
        assert_eq!(k.0, "b");
        assert_eq!(batch.len(), 2);
        // "a" has a lone request; not flushed while busy & young
        assert!(q.next_batch(8, false, Instant::now()).is_none());
        // ... but flushed when idle
        let (k2, b2) = q.next_batch(8, true, Instant::now()).unwrap();
        assert_eq!(k2.0, "a");
        assert_eq!(b2.len(), 1);
        assert!(q.is_empty());
    }

    #[test]
    fn respects_free_slots() {
        let mut q = PrefillQueues::new(8, 0.0);
        for i in 0..5 {
            q.push(ConfigKey("a".into()), tracked(i));
        }
        let (_, batch) = q.next_batch(3, true, Instant::now()).unwrap();
        assert_eq!(batch.len(), 3);
        assert_eq!(q.waiting(), 2);
        assert!(q.next_batch(0, true, Instant::now()).is_none());
    }

    #[test]
    fn routing_names() {
        let c = SparsityConfig {
            setting: Setting::LayerSkip,
            nm: Some((8, 16)),
            quantized: false,
        };
        let (p, d, w) = routing("tiny-lm-a", 64, &c);
        assert_eq!(p, "tiny-lm-a.prefill64.nm8_16");
        assert_eq!(d, "tiny-lm-a.decode.dense");
        assert_eq!(w, vec!["tiny-lm-a.atw", "tiny-lm-a.aux_ls.atw"]);
        let (p2, d2, w2) = routing("tiny-lm-a", 64, &SparsityConfig {
            setting: Setting::Naive,
            nm: Some((2, 4)),
            quantized: true,
        });
        assert_eq!(p2, "tiny-lm-a.prefill64.sq_nm2_4");
        assert_eq!(d2, "tiny-lm-a.decode.sq");
        assert_eq!(w2, vec!["tiny-lm-a.sq.atw",
                            "tiny-lm-a.sq.aux_naive.atw"]);
    }
}
