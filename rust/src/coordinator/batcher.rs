//! Continuous-batching queues: per-config FIFO prefill queues with a
//! prefill-prioritized packing policy (the paper accelerates *prefill*, so
//! the scheduler favors draining prompt work; decode advances whenever no
//! prefill batch is ready, mirroring vLLM's iteration-level scheduling).

use std::collections::{BTreeMap, VecDeque};
use std::time::Instant;

use super::request::{SparsityConfig, Tracked};

/// Queue key: requests in one bucket share prefill artifact + binding.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct ConfigKey(pub String);

/// Per-config FIFO prefill queues + the packing policy (module docs).
pub struct PrefillQueues {
    queues: BTreeMap<ConfigKey, VecDeque<Tracked>>,
    /// the prefill artifact's static batch — the "full bucket" threshold
    pub max_batch: usize,
    /// flush a partial batch when its head has waited this long
    pub max_wait_secs: f64,
}

impl PrefillQueues {
    /// Queues with a `max_batch` full-bucket threshold and `max_wait_secs`
    /// flush policy.
    pub fn new(max_batch: usize, max_wait_secs: f64) -> Self {
        PrefillQueues {
            queues: BTreeMap::new(),
            max_batch,
            max_wait_secs,
        }
    }

    /// Enqueue a tracked request into its config bucket.
    pub fn push(&mut self, key: ConfigKey, t: Tracked) {
        self.queues.entry(key).or_default().push_back(t);
    }

    /// Re-enqueue a preempted request at the *front* of its bucket: it
    /// already waited its turn (and lost staged work to the eviction),
    /// so on re-admission it must not queue behind younger arrivals.
    pub fn push_front(&mut self, key: ConfigKey, t: Tracked) {
        self.queues.entry(key).or_default().push_front(t);
    }

    /// Requests waiting across all buckets.
    pub fn waiting(&self) -> usize {
        self.queues.values().map(|q| q.len()).sum()
    }

    /// Whether every bucket is drained.
    pub fn is_empty(&self) -> bool {
        self.waiting() == 0
    }

    /// Queued prompt-token backlog across all buckets — the signal the
    /// overload watermarks ([`super::scheduler::DegradePolicy`])
    /// compare against at admission.
    pub fn queued_tokens(&self) -> usize {
        self.queues
            .values()
            .flat_map(|q| q.iter())
            .map(|t| t.req.prompt.len())
            .sum()
    }

    /// Empty every bucket, returning all queued requests oldest
    /// arrival first (ties by id). The graceful-drain hand-back path:
    /// the scheduler sends these back to the replica pool un-replied
    /// so survivors can recompute them.
    pub fn drain_all(&mut self) -> Vec<Tracked> {
        let mut out: Vec<Tracked> = Vec::new();
        for q in self.queues.values_mut() {
            out.extend(q.drain(..));
        }
        self.queues.clear();
        out.sort_by_key(|t| (t.arrived, t.req.id));
        out
    }

    /// Remove and return every queued request whose deadline has
    /// passed (`deadline_at < tick` — a request keeps the whole tick
    /// it expires on, so `deadline_ticks = 1` gets one scheduling
    /// opportunity). The scheduler sweeps this at the top of every
    /// iteration and answers each with a `Rejected` response.
    pub fn take_expired(&mut self, tick: u64) -> Vec<Tracked> {
        let mut out = Vec::new();
        self.queues.retain(|_, q| {
            let mut i = 0;
            while i < q.len() {
                if q[i].deadline_at.is_some_and(|d| d < tick) {
                    if let Some(t) = q.remove(i) {
                        out.push(t);
                    }
                } else {
                    i += 1;
                }
            }
            !q.is_empty()
        });
        out
    }

    /// The shared bucket-selection policy: a "full" bucket if any
    /// (per the caller's capacity rule), otherwise the bucket with the
    /// oldest head *if* it exceeded max_wait or the engine is otherwise
    /// idle (`idle == true`).
    fn select_bucket<F: Fn(&VecDeque<Tracked>) -> bool>(
        &self,
        is_full: F,
        idle: bool,
        now: Instant,
    ) -> Option<ConfigKey> {
        let full = self
            .queues
            .iter()
            .filter(|(_, q)| !q.is_empty() && is_full(q))
            .map(|(k, _)| k.clone())
            .next();
        if full.is_some() {
            return full;
        }
        let (k, q) = self
            .queues
            .iter()
            .filter(|(_, q)| !q.is_empty())
            .min_by_key(|(_, q)| q.front().unwrap().arrived)?;
        let age = now
            .duration_since(q.front().unwrap().arrived)
            .as_secs_f64();
        if idle || age >= self.max_wait_secs {
            Some(k.clone())
        } else {
            None
        }
    }

    /// Drain the first `n` requests of `key`'s bucket, dropping the
    /// bucket when emptied.
    fn drain_bucket(&mut self, key: ConfigKey, n: usize)
                    -> (ConfigKey, Vec<Tracked>) {
        let q = self.queues.get_mut(&key).unwrap();
        let batch: Vec<Tracked> = q.drain(..n.min(q.len())).collect();
        if q.is_empty() {
            self.queues.remove(&key);
        }
        (key, batch)
    }

    /// Pick the bucket to prefill next (see `select_bucket`).
    /// Returns up to `free_slots.min(max_batch)` requests.
    pub fn next_batch(
        &mut self,
        free_slots: usize,
        idle: bool,
        now: Instant,
    ) -> Option<(ConfigKey, Vec<Tracked>)> {
        let cap = self.max_batch.min(free_slots);
        if cap == 0 {
            return None;
        }
        let key = self.select_bucket(|q| q.len() >= cap, idle, now)?;
        Some(self.drain_bucket(key, cap))
    }

    /// Worst-case block demand across every bucket's *head* request
    /// (prompt clamped to `seq`, reservation clamped per
    /// [`BlockBudget::demand`]). The scheduler compares this against
    /// the free-block count to decide how hard to evict prefix-cache
    /// nodes: as long as `free >= max_head_demand`, no queue head is
    /// starved by cached blocks. `None` when every bucket is empty.
    pub fn max_head_demand(
        &self,
        budget: &BlockBudget,
        seq: usize,
    ) -> Option<usize> {
        self.queues
            .values()
            .filter_map(|q| q.front())
            .map(|t| {
                let tk = t.req.prompt.len().min(seq).max(1);
                budget.demand(tk, t.req.max_new_tokens)
            })
            .max()
    }

    /// Token-packed, block-budgeted variant of
    /// [`PrefillQueues::next_batch`]: the bucket is chosen by the same
    /// policy (`select_bucket`), but the batch is cut by two
    /// budgets instead of a fixed request count —
    ///
    /// * a **token** budget: each request contributes
    ///   `min(prompt_len, seq).max(1)` packed tokens, so short prompts
    ///   can pack more than `max_batch` requests into the same kernel
    ///   budget and long prompts fewer;
    /// * a **block** budget ([`BlockBudget`]): each request reserves
    ///   `ceil((tokens + max_new_tokens) / block_size)` KV blocks, which
    ///   may live *anywhere* in the pool. When the free-block budget
    ///   cuts a bucket, the admitted prefix runs now and the remainder
    ///   continues in a later batch once decode frees blocks —
    ///   partial-prefill continuation, not head-of-line blocking.
    ///
    /// A bucket counts as "full" once it can fill the token budget,
    /// `max_batch` requests, or the free-block budget (a block-cut
    /// bucket flushes immediately: waiting cannot help until blocks
    /// free up). Demand is the cap-clamped reservation admission will
    /// actually take ([`BlockBudget::demand`]), so an admissible head
    /// always fits the pool eventually (free recovers to total as
    /// decode drains). A genuinely unservable request (prompt beyond
    /// the per-sequence cap) is rejected per-request at admission, not
    /// here; a defensive branch additionally surfaces a head whose
    /// demand exceeds a (hand-built) pool smaller than the cap, so no
    /// budget shape can deadlock the queue.
    pub fn next_packed_batch(
        &mut self,
        budget: BlockBudget,
        seq: usize,
        max_tokens: usize,
        idle: bool,
        now: Instant,
    ) -> Option<(ConfigKey, Vec<Tracked>)> {
        if budget.free_blocks == 0 || max_tokens == 0 {
            return None;
        }
        let full_at = self.max_batch.max(1);
        // (requests to take, packed tokens, cut by the block budget?)
        let packable = |q: &VecDeque<Tracked>| -> (usize, usize, bool) {
            let mut toks = 0usize;
            let mut blocks = 0usize;
            let mut n = 0usize;
            let mut cut = false;
            for t in q.iter() {
                let tk = t.req.prompt.len().min(seq).max(1);
                let bl = budget.demand(tk, t.req.max_new_tokens);
                if n == 0 {
                    if bl > budget.free_blocks {
                        // head doesn't fit the free blocks: wait for
                        // decode to release some. The > total branch is
                        // purely defensive — unreachable for a
                        // scheduler-built budget (demand clamps to the
                        // cap and the pool is sized to hold the cap),
                        // but a hand-built budget smaller than the cap
                        // would otherwise wait forever, so surface the
                        // head alone and let admission reject it.
                        if bl <= budget.total_blocks {
                            cut = true;
                            break;
                        }
                        return (1, tk, true);
                    }
                } else if toks + tk > max_tokens {
                    break;
                } else if blocks + bl > budget.free_blocks {
                    cut = true;
                    break;
                }
                toks += tk;
                blocks += bl;
                n += 1;
                if toks >= max_tokens {
                    break;
                }
            }
            (n, toks, cut)
        };
        let key = self.select_bucket(
            |q| {
                let (n, toks, cut) = packable(q);
                n >= full_at || toks >= max_tokens || (cut && n > 0)
            },
            idle,
            now,
        )?;
        let (n, _, _) = packable(&self.queues[&key]);
        if n == 0 {
            return None; // head waits for blocks to free up
        }
        Some(self.drain_bucket(key, n))
    }

    /// Chunk-aware [`PrefillQueues::max_head_demand`]: the block demand
    /// of every bucket head's *first chunk* — exactly what
    /// [`PrefillQueues::next_chunk_batch`] admission will charge
    /// ([`BlockBudget::chunk_demand`]), not the one-shot worst case.
    pub fn max_head_chunk_demand(
        &self,
        budget: &BlockBudget,
        seq: usize,
        chunk_tokens: usize,
    ) -> Option<usize> {
        self.queues
            .values()
            .filter_map(|q| q.front())
            .map(|t| {
                let tk = t.req.prompt.len().min(seq).max(1);
                budget.chunk_demand(tk, chunk_tokens)
            })
            .max()
    }

    /// Chunk-aware admission for the continuous-batching loop: the same
    /// bucket policy and budget shape as
    /// [`PrefillQueues::next_packed_batch`], but each request is costed
    /// by its **first chunk** — `min(prompt, chunk_tokens)` packed
    /// tokens and the matching [`BlockBudget::chunk_demand`] blocks —
    /// because chunked admission stages only chunk 1. Later chunks and
    /// decode grow the block table on demand, with preemption (not an
    /// up-front worst-case reservation) covering pool pressure. With
    /// `chunk_tokens = usize::MAX` this costs the whole clamped prompt,
    /// recovering one-shot admission minus the `+ max_new` reservation.
    pub fn next_chunk_batch(
        &mut self,
        budget: BlockBudget,
        seq: usize,
        chunk_tokens: usize,
        max_tokens: usize,
        idle: bool,
        now: Instant,
    ) -> Option<(ConfigKey, Vec<Tracked>)> {
        if budget.free_blocks == 0 || max_tokens == 0 {
            return None;
        }
        let full_at = self.max_batch.max(1);
        // (requests to take, packed tokens, cut by the block budget?)
        let packable = |q: &VecDeque<Tracked>| -> (usize, usize, bool) {
            let mut toks = 0usize;
            let mut blocks = 0usize;
            let mut n = 0usize;
            let mut cut = false;
            for t in q.iter() {
                let tk = t.req.prompt.len().min(seq).max(1);
                let ck = tk.min(chunk_tokens);
                let bl = budget.chunk_demand(tk, chunk_tokens);
                if n == 0 {
                    if bl > budget.free_blocks {
                        // same wait-vs-surface policy as
                        // `next_packed_batch` (see its docs)
                        if bl <= budget.total_blocks {
                            cut = true;
                            break;
                        }
                        return (1, ck, true);
                    }
                } else if toks + ck > max_tokens {
                    break;
                } else if blocks + bl > budget.free_blocks {
                    cut = true;
                    break;
                }
                toks += ck;
                blocks += bl;
                n += 1;
                if toks >= max_tokens {
                    break;
                }
            }
            (n, toks, cut)
        };
        let key = self.select_bucket(
            |q| {
                let (n, toks, cut) = packable(q);
                n >= full_at || toks >= max_tokens || (cut && n > 0)
            },
            idle,
            now,
        )?;
        let (n, _, _) = packable(&self.queues[&key]);
        if n == 0 {
            return None; // head waits for blocks to free up
        }
        Some(self.drain_bucket(key, n))
    }
}

/// Free-KV-block budget the packed batcher admits against (built by the
/// scheduler from the [`super::kv::KvPages`] pool each iteration).
#[derive(Debug, Clone, Copy)]
pub struct BlockBudget {
    /// blocks currently free (anywhere in the pool)
    pub free_blocks: usize,
    /// pool capacity — a request needing more than this can never run
    pub total_blocks: usize,
    /// tokens per block
    pub block_size: usize,
    /// per-sequence token cap (admission clamps reservations here, so
    /// the batcher must account the same clamped demand)
    pub max_seq_tokens: usize,
}

impl BlockBudget {
    /// Blocks needed to hold `tokens` tokens.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_size.max(1)).max(1)
    }

    /// Blocks a request with `prompt_tokens` + `max_new` generation
    /// budget will actually reserve: the worst case, clamped to the
    /// per-sequence cap — exactly what admission reserves, so batcher
    /// accounting and `KvPages::admit_packed` can never disagree.
    pub fn demand(&self, prompt_tokens: usize, max_new: usize) -> usize {
        self.blocks_for(
            (prompt_tokens + max_new).min(self.max_seq_tokens),
        )
    }

    /// Blocks the *first chunk* of a request stages under chunked,
    /// on-demand admission: `min(prompt, chunk)` tokens, cap-clamped.
    /// No `+ max_new` term — later chunks and decode extend the block
    /// table on demand and preemption covers pool pressure, so this is
    /// what admission actually allocates, not a worst case.
    pub fn chunk_demand(
        &self,
        prompt_tokens: usize,
        chunk_tokens: usize,
    ) -> usize {
        self.blocks_for(
            prompt_tokens.min(chunk_tokens).min(self.max_seq_tokens),
        )
    }
}

/// Map a request's sparsity config to (prefill artifact, decode artifact,
/// weight files) under the artifact naming convention.
pub fn routing(
    model: &str,
    seq: usize,
    cfg: &SparsityConfig,
) -> (String, String, Vec<String>) {
    let sq = cfg.quantized;
    let weights = if sq {
        format!("{model}.sq.atw")
    } else {
        format!("{model}.atw")
    };
    match cfg.nm {
        None => {
            let variant = if sq { "sq" } else { "dense" };
            (
                format!("{model}.prefill{seq}.{variant}"),
                format!("{model}.decode.{}", if sq { "sq" } else { "dense" }),
                vec![weights],
            )
        }
        Some((n, m)) => {
            let variant = if sq { "sq_nm" } else { "nm" };
            let aux = cfg.setting.aux_file(model, sq);
            (
                format!("{model}.prefill{seq}.{variant}{n}_{m}"),
                format!("{model}.decode.{}", if sq { "sq" } else { "dense" }),
                vec![weights, aux],
            )
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparsity::policy::Setting;
    use std::sync::mpsc::channel;

    fn tracked_len(id: u64, prompt_len: usize) -> Tracked {
        let (tx, _rx) = channel();
        Tracked {
            req: super::super::request::Request {
                id,
                prompt: vec![1; prompt_len.max(1)],
                max_new_tokens: 4,
                config: SparsityConfig::dense(),
                deadline_ticks: 0,
            },
            arrived: Instant::now(),
            first_token_at: None,
            generated: vec![],
            reply: tx,
            retries: 0,
            deadline_at: None,
        }
    }

    fn tracked(id: u64) -> Tracked {
        tracked_len(id, 2)
    }

    #[test]
    fn take_expired_sweeps_only_past_deadlines() {
        let mut q = PrefillQueues::new(4, 10.0);
        let mut live = tracked(1);
        live.deadline_at = Some(10);
        let mut edge = tracked(2); // expires on tick 5, kept through it
        edge.deadline_at = Some(5);
        let mut dead = tracked(3);
        dead.deadline_at = Some(4);
        q.push(ConfigKey("a".into()), live);
        q.push(ConfigKey("a".into()), edge);
        q.push(ConfigKey("b".into()), dead);
        let expired = q.take_expired(5);
        assert_eq!(
            expired.iter().map(|t| t.req.id).collect::<Vec<_>>(),
            vec![3]
        );
        assert_eq!(q.waiting(), 2);
        // the rest expire once the tick passes their deadlines, but a
        // request without one is never swept
        q.push(ConfigKey("a".into()), tracked(4));
        assert_eq!(q.take_expired(1_000_000).len(), 2);
        assert_eq!(q.waiting(), 1);
    }

    #[test]
    fn queued_tokens_sums_prompt_backlog() {
        let mut q = PrefillQueues::new(4, 10.0);
        assert_eq!(q.queued_tokens(), 0);
        q.push(ConfigKey("a".into()), tracked_len(1, 10));
        q.push(ConfigKey("b".into()), tracked_len(2, 7));
        assert_eq!(q.queued_tokens(), 17);
    }

    #[test]
    fn full_batch_preferred() {
        let mut q = PrefillQueues::new(2, 10.0);
        q.push(ConfigKey("a".into()), tracked(1));
        q.push(ConfigKey("b".into()), tracked(2));
        q.push(ConfigKey("b".into()), tracked(3));
        let (k, batch) =
            q.next_batch(8, false, Instant::now()).expect("batch");
        assert_eq!(k.0, "b");
        assert_eq!(batch.len(), 2);
        // "a" has a lone request; not flushed while busy & young
        assert!(q.next_batch(8, false, Instant::now()).is_none());
        // ... but flushed when idle
        let (k2, b2) = q.next_batch(8, true, Instant::now()).unwrap();
        assert_eq!(k2.0, "a");
        assert_eq!(b2.len(), 1);
        assert!(q.is_empty());
    }

    #[test]
    fn respects_free_slots() {
        let mut q = PrefillQueues::new(8, 0.0);
        for i in 0..5 {
            q.push(ConfigKey("a".into()), tracked(i));
        }
        let (_, batch) = q.next_batch(3, true, Instant::now()).unwrap();
        assert_eq!(batch.len(), 3);
        assert_eq!(q.waiting(), 2);
        assert!(q.next_batch(0, true, Instant::now()).is_none());
    }

    fn budget(free: usize, total: usize, bs: usize) -> BlockBudget {
        BlockBudget {
            free_blocks: free,
            total_blocks: total,
            block_size: bs,
            // a cap high enough that no test demand clamps
            max_seq_tokens: 1 << 20,
        }
    }

    #[test]
    fn packed_batch_packs_short_prompts_beyond_max_batch() {
        // max_batch 2, but five 2-token prompts (1 KV block each) fit
        // the 64-token budget and the 8 free blocks: all five pack
        let mut q = PrefillQueues::new(2, 10.0);
        for i in 0..5 {
            q.push(ConfigKey("a".into()), tracked_len(i, 2));
        }
        let (_, batch) = q
            .next_packed_batch(budget(8, 8, 16), 64, 64, true,
                               Instant::now())
            .expect("batch");
        assert_eq!(batch.len(), 5);
        assert!(q.is_empty());
    }

    #[test]
    fn packed_batch_cuts_on_token_budget() {
        // 40-token prompts against a 64-token budget: one per batch
        // (the first request is always taken)
        let mut q = PrefillQueues::new(8, 10.0);
        for i in 0..3 {
            q.push(ConfigKey("a".into()), tracked_len(i, 40));
        }
        let now = Instant::now();
        let bb = budget(16, 16, 16);
        let (_, b1) = q.next_packed_batch(bb, 64, 64, true, now).unwrap();
        assert_eq!(b1.len(), 1);
        assert_eq!(b1[0].req.id, 0);
        let (_, b2) = q.next_packed_batch(bb, 64, 64, true, now).unwrap();
        assert_eq!(b2.len(), 1);
        assert_eq!(b2[0].req.id, 1);
        assert_eq!(q.waiting(), 1);
        // prompt lengths clamp to seq: two 40-token prompts at seq 16
        // cost 16 each and pack together under the 64-token budget
        let mut q2 = PrefillQueues::new(8, 10.0);
        for i in 0..2 {
            q2.push(ConfigKey("a".into()), tracked_len(i, 40));
        }
        let (_, b3) = q2.next_packed_batch(bb, 16, 64, true, now).unwrap();
        assert_eq!(b3.len(), 2);
    }

    #[test]
    fn packed_batch_respects_block_budget_and_wait_policy() {
        // 2-token prompts + 4 generation tokens = 6 tokens = 1 block
        // at block_size 16
        let mut q = PrefillQueues::new(4, 10.0);
        for i in 0..6 {
            q.push(ConfigKey("a".into()), tracked_len(i, 2));
        }
        let now = Instant::now();
        // only 3 free blocks: the batch cuts there even with token
        // budget left, and flushes immediately (a block-cut batch is
        // "full" — waiting cannot help until decode frees blocks); the
        // remaining requests continue in a later batch
        let (_, b) = q
            .next_packed_batch(budget(3, 24, 16), 64, 256, false, now)
            .unwrap();
        assert_eq!(b.len(), 3);
        // remaining 3 < max_batch and under both budgets: not a full
        // bucket, so nothing is cut while busy & young...
        assert!(q
            .next_packed_batch(budget(24, 24, 16), 64, 256, false, now)
            .is_none());
        // ...but an idle engine flushes them all
        let (_, b2) = q
            .next_packed_batch(budget(24, 24, 16), 64, 256, true, now)
            .unwrap();
        assert_eq!(b2.len(), 3);
        // a lone young request is not flushed while busy...
        q.push(ConfigKey("a".into()), tracked_len(9, 2));
        assert!(q
            .next_packed_batch(budget(24, 24, 16), 64, 256, false, now)
            .is_none());
        // ...but is when idle
        assert!(q
            .next_packed_batch(budget(24, 24, 16), 64, 256, true, now)
            .is_some());
        assert!(q
            .next_packed_batch(budget(0, 24, 16), 64, 256, true, now)
            .is_none());
    }

    #[test]
    fn block_demand_clamps_to_the_per_seq_cap() {
        let bb = BlockBudget {
            free_blocks: 4,
            total_blocks: 4,
            block_size: 16,
            max_seq_tokens: 32,
        };
        // a 100-token worst case clamps to the 32-token cap -> 2 blocks
        assert_eq!(bb.demand(20, 80), 2);
        assert_eq!(bb.demand(4, 4), 1);
        // clamped demand always fits a pool sized to hold the cap, so
        // admission and batcher accounting cannot disagree
        assert!(bb.demand(64, 500) <= bb.total_blocks);
    }

    #[test]
    fn packed_batch_head_waits_for_blocks_or_is_surfaced_alone() {
        let now = Instant::now();
        // head needs 3 blocks (40 + 4 tokens at block 16); only 2 free
        // but the pool holds 8: wait for decode to release blocks
        let mut q = PrefillQueues::new(4, 10.0);
        q.push(ConfigKey("a".into()), tracked_len(1, 40));
        assert!(q
            .next_packed_batch(budget(2, 8, 16), 64, 256, true, now)
            .is_none());
        assert_eq!(q.waiting(), 1, "waiting head must stay queued");
        // head bigger than the whole pool: cut alone for admission to
        // resolve (clamped reservation or a loud error) rather than
        // deadlocking the queue behind it
        let (_, b) = q
            .next_packed_batch(budget(2, 2, 16), 64, 256, false, now)
            .expect("oversized head is surfaced");
        assert_eq!(b.len(), 1);
        assert!(q.is_empty());
    }

    #[test]
    fn max_head_demand_peeks_every_bucket() {
        let mut q = PrefillQueues::new(4, 10.0);
        let bb = budget(8, 8, 16);
        assert_eq!(q.max_head_demand(&bb, 64), None);
        // heads: 2+4 tokens -> 1 block vs 40+4 tokens -> 3 blocks;
        // the non-head 40-token request in "a" must not count
        q.push(ConfigKey("a".into()), tracked_len(1, 2));
        q.push(ConfigKey("a".into()), tracked_len(2, 40));
        q.push(ConfigKey("b".into()), tracked_len(3, 40));
        assert_eq!(q.max_head_demand(&bb, 64), Some(3));
        // prompt clamps to seq: 16+4 tokens -> 2 blocks
        assert_eq!(q.max_head_demand(&bb, 16), Some(2));
    }

    #[test]
    fn push_front_requeues_ahead_of_younger_arrivals() {
        let mut q = PrefillQueues::new(4, 10.0);
        q.push(ConfigKey("a".into()), tracked(1));
        q.push(ConfigKey("a".into()), tracked(2));
        // a preempted request jumps the line on re-admission
        q.push_front(ConfigKey("a".into()), tracked(9));
        let (_, b) = q.next_batch(8, true, Instant::now()).unwrap();
        assert_eq!(
            b.iter().map(|t| t.req.id).collect::<Vec<_>>(),
            vec![9, 1, 2]
        );
    }

    #[test]
    fn chunk_demand_charges_first_chunk_not_worst_case() {
        let bb = budget(8, 8, 16);
        // 40-token prompt, 16-token chunks: 1 block now, not
        // ceil((40 + max_new) / 16) up front
        assert_eq!(bb.chunk_demand(40, 16), 1);
        assert_eq!(bb.chunk_demand(40, 32), 2);
        // chunk = MAX recovers the whole clamped prompt (no + max_new)
        assert_eq!(bb.chunk_demand(40, usize::MAX), 3);
        let capped = BlockBudget { max_seq_tokens: 32, ..bb };
        assert_eq!(capped.chunk_demand(100, usize::MAX), 2);
    }

    #[test]
    fn chunk_batch_admits_by_first_chunk_cost() {
        // four 40-token prompts, 16-token chunks, 4 free blocks: each
        // head chunk costs 1 block and 16 tokens, so all four admit
        // where one-shot packing (3 blocks each) would cut at one
        let now = Instant::now();
        let mut q = PrefillQueues::new(8, 10.0);
        for i in 0..4 {
            q.push(ConfigKey("a".into()), tracked_len(i, 40));
        }
        let (_, b) = q
            .next_chunk_batch(budget(4, 16, 16), 64, 16, 256, true, now)
            .expect("batch");
        assert_eq!(b.len(), 4);
        // token budget still cuts: 16-token chunks against a 32-token
        // iteration budget admit two per call
        let mut q2 = PrefillQueues::new(8, 10.0);
        for i in 0..4 {
            q2.push(ConfigKey("a".into()), tracked_len(i, 40));
        }
        let (_, b2) = q2
            .next_chunk_batch(budget(16, 16, 16), 64, 16, 32, true, now)
            .unwrap();
        assert_eq!(b2.len(), 2);
        // a head whose first chunk exceeds the free blocks waits
        let mut q3 = PrefillQueues::new(8, 10.0);
        q3.push(ConfigKey("a".into()), tracked_len(1, 40));
        assert!(q3
            .next_chunk_batch(budget(1, 8, 16), 64, 32, 256, true, now)
            .is_none());
        assert_eq!(q3.waiting(), 1);
    }

    #[test]
    fn max_head_chunk_demand_is_chunk_clamped() {
        let mut q = PrefillQueues::new(4, 10.0);
        let bb = budget(8, 8, 16);
        assert_eq!(q.max_head_chunk_demand(&bb, 64, 16), None);
        q.push(ConfigKey("a".into()), tracked_len(1, 40));
        q.push(ConfigKey("b".into()), tracked_len(2, 2));
        // 40-token head: first chunk of 16 -> 1 block (one-shot
        // max_head_demand would say 3)
        assert_eq!(q.max_head_chunk_demand(&bb, 64, 16), Some(1));
        assert_eq!(q.max_head_chunk_demand(&bb, 64, 32), Some(2));
        assert_eq!(q.max_head_chunk_demand(&bb, 64, usize::MAX), Some(3));
    }

    #[test]
    fn routing_names() {
        let c = SparsityConfig {
            setting: Setting::LayerSkip,
            nm: Some((8, 16)),
            quantized: false,
        };
        let (p, d, w) = routing("tiny-lm-a", 64, &c);
        assert_eq!(p, "tiny-lm-a.prefill64.nm8_16");
        assert_eq!(d, "tiny-lm-a.decode.dense");
        assert_eq!(w, vec!["tiny-lm-a.atw", "tiny-lm-a.aux_ls.atw"]);
        let (p2, d2, w2) = routing("tiny-lm-a", 64, &SparsityConfig {
            setting: Setting::Naive,
            nm: Some((2, 4)),
            quantized: true,
        });
        assert_eq!(p2, "tiny-lm-a.prefill64.sq_nm2_4");
        assert_eq!(d2, "tiny-lm-a.decode.sq");
        assert_eq!(w2, vec!["tiny-lm-a.sq.atw",
                            "tiny-lm-a.sq.aux_naive.atw"]);
    }
}
