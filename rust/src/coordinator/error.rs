//! Error taxonomy for the serving loop (ISSUE 9).
//!
//! Every failure the engine can hand back to a client is classified
//! into one of three kinds, and the classification decides the
//! scheduler's reaction:
//!
//! | kind | meaning | scheduler reaction |
//! |------|---------|--------------------|
//! | `Transient` | retryable hiccup (failed batch, injected fault) | release KV, re-queue with tick-based backoff |
//! | `Fatal` | cannot complete (retries exhausted, engine panic) | error `Response`, release KV, keep serving |
//! | `Rejected` | refused by policy (deadline, shed, unservable) | error `Response` immediately |
//!
//! `Transient` never reaches a client directly — it is the *internal*
//! classification that drives the retry path; only when the retry
//! budget is exhausted does it escalate to `Fatal`. The taxonomy rides
//! on [`super::request::Response::error`], so the fault-free path
//! (`error == None`) is byte-identical to the pre-taxonomy protocol.

/// Failure classification (module docs for the full table).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorKind {
    /// Retryable: the engine re-queues the request with tick-based
    /// exponential backoff instead of failing it.
    Transient,
    /// Unrecoverable for this request: retries exhausted or the engine
    /// panicked while it was in flight. The loop keeps serving others.
    Fatal,
    /// Refused by policy: deadline exceeded, overload shed, or a
    /// request the pool can never hold.
    Rejected,
}

impl ErrorKind {
    /// Wire-protocol label (`"transient"` / `"fatal"` / `"rejected"`).
    pub fn label(&self) -> &'static str {
        match self {
            ErrorKind::Transient => "transient",
            ErrorKind::Fatal => "fatal",
            ErrorKind::Rejected => "rejected",
        }
    }
}

/// A classified per-request failure, carried on
/// [`super::request::Response::error`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RequestError {
    /// failure classification
    pub kind: ErrorKind,
    /// human-readable cause
    pub reason: String,
}

impl RequestError {
    /// An unrecoverable failure.
    pub fn fatal(reason: impl Into<String>) -> RequestError {
        RequestError { kind: ErrorKind::Fatal, reason: reason.into() }
    }

    /// A policy refusal (deadline, shed, unservable).
    pub fn rejected(reason: impl Into<String>) -> RequestError {
        RequestError { kind: ErrorKind::Rejected, reason: reason.into() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_are_stable_wire_strings() {
        assert_eq!(ErrorKind::Transient.label(), "transient");
        assert_eq!(ErrorKind::Fatal.label(), "fatal");
        assert_eq!(ErrorKind::Rejected.label(), "rejected");
    }

    #[test]
    fn constructors_classify() {
        assert_eq!(RequestError::fatal("x").kind, ErrorKind::Fatal);
        assert_eq!(
            RequestError::rejected("x").kind,
            ErrorKind::Rejected
        );
    }
}
