//! Block-granular KV accounting (paged-attention-style allocator).
//!
//! The decode executable's physical cache is slot-contiguous (static
//! shapes — see kv.rs), but admission control and capacity accounting run
//! at block granularity like vLLM's PagedAttention: a sequence owns
//! ceil(len / BLOCK) blocks from a global pool, blocks are ref-counted so
//! a shared prompt prefix can be accounted once (prefix caching), and the
//! scheduler admits a prefill batch only if its worst-case block demand
//! fits. This keeps the coordinator's admission logic identical to a
//! paged deployment even though the tiny-model substrate doesn't need
//! physical paging.

use anyhow::{bail, Result};
use std::collections::HashMap;

pub const DEFAULT_BLOCK: usize = 16;

#[derive(Debug, Clone)]
pub struct BlockPool {
    pub block_size: usize,
    pub n_blocks: usize,
    free: Vec<u32>,
    refcount: Vec<u16>,
    /// seq -> owned block ids (in order)
    owners: HashMap<u64, Vec<u32>>,
}

impl BlockPool {
    pub fn new(n_blocks: usize, block_size: usize) -> BlockPool {
        BlockPool {
            block_size,
            n_blocks,
            free: (0..n_blocks as u32).rev().collect(),
            refcount: vec![0; n_blocks],
            owners: HashMap::new(),
        }
    }

    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_size)
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    pub fn can_admit(&self, tokens: usize) -> bool {
        self.blocks_for(tokens) <= self.free.len()
    }

    /// Allocate blocks for a new sequence of `tokens` tokens.
    pub fn allocate(&mut self, seq: u64, tokens: usize) -> Result<&[u32]> {
        if self.owners.contains_key(&seq) {
            bail!("seq {seq} already has an allocation");
        }
        let need = self.blocks_for(tokens);
        if need > self.free.len() {
            bail!("pool exhausted: need {need}, free {}", self.free.len());
        }
        let mut blocks = Vec::with_capacity(need);
        for _ in 0..need {
            let b = self.free.pop().unwrap();
            self.refcount[b as usize] = 1;
            blocks.push(b);
        }
        self.owners.insert(seq, blocks);
        Ok(self.owners.get(&seq).unwrap())
    }

    /// Extend a sequence by `new_tokens` (decode growth); allocates new
    /// tail blocks as needed.
    pub fn grow(&mut self, seq: u64, old_tokens: usize, new_tokens: usize)
                -> Result<()> {
        let need_total = self.blocks_for(old_tokens + new_tokens);
        let have = self
            .owners
            .get(&seq)
            .map(|b| b.len())
            .ok_or_else(|| anyhow::anyhow!("seq {seq} not allocated"))?;
        let extra = need_total.saturating_sub(have);
        if extra > self.free.len() {
            bail!("pool exhausted growing seq {seq}");
        }
        for _ in 0..extra {
            let b = self.free.pop().unwrap();
            self.refcount[b as usize] = 1;
            self.owners.get_mut(&seq).unwrap().push(b);
        }
        Ok(())
    }

    /// Fork: new sequence shares the owner's blocks (prefix cache hit) —
    /// copy-on-write accounting via refcounts.
    pub fn fork(&mut self, parent: u64, child: u64) -> Result<()> {
        let blocks = self
            .owners
            .get(&parent)
            .cloned()
            .ok_or_else(|| anyhow::anyhow!("parent {parent} missing"))?;
        if self.owners.contains_key(&child) {
            bail!("child {child} already allocated");
        }
        for &b in &blocks {
            self.refcount[b as usize] += 1;
        }
        self.owners.insert(child, blocks);
        Ok(())
    }

    pub fn release(&mut self, seq: u64) {
        if let Some(blocks) = self.owners.remove(&seq) {
            for b in blocks {
                let rc = &mut self.refcount[b as usize];
                *rc -= 1;
                if *rc == 0 {
                    self.free.push(b);
                }
            }
        }
    }

    pub fn check_invariants(&self) -> Result<()> {
        let mut expected = vec![0u16; self.n_blocks];
        for blocks in self.owners.values() {
            for &b in blocks {
                expected[b as usize] += 1;
            }
        }
        if expected != self.refcount {
            bail!("refcount drift");
        }
        let frees = self.free.len();
        let used = self.refcount.iter().filter(|r| **r > 0).count();
        if frees + used != self.n_blocks {
            bail!("block leak: {frees} free + {used} used != {}",
                  self.n_blocks);
        }
        for &b in &self.free {
            if self.refcount[b as usize] != 0 {
                bail!("free block {b} has refcount");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_grow_release() {
        let mut p = BlockPool::new(8, 16);
        assert_eq!(p.blocks_for(1), 1);
        assert_eq!(p.blocks_for(16), 1);
        assert_eq!(p.blocks_for(17), 2);
        p.allocate(1, 40).unwrap(); // 3 blocks
        assert_eq!(p.free_blocks(), 5);
        p.grow(1, 40, 8).unwrap(); // 48 tokens -> 3 blocks, no extra
        assert_eq!(p.free_blocks(), 5);
        p.grow(1, 48, 1).unwrap(); // 49 -> 4 blocks
        assert_eq!(p.free_blocks(), 4);
        p.check_invariants().unwrap();
        p.release(1);
        assert_eq!(p.free_blocks(), 8);
        p.check_invariants().unwrap();
    }

    #[test]
    fn fork_shares_and_cow_releases() {
        let mut p = BlockPool::new(4, 16);
        p.allocate(1, 32).unwrap(); // 2 blocks
        p.fork(1, 2).unwrap();
        assert_eq!(p.free_blocks(), 2); // shared, not copied
        p.release(1);
        assert_eq!(p.free_blocks(), 2); // child still holds them
        p.check_invariants().unwrap();
        p.release(2);
        assert_eq!(p.free_blocks(), 4);
        p.check_invariants().unwrap();
    }

    #[test]
    fn exhaustion_and_admission() {
        let mut p = BlockPool::new(2, 16);
        assert!(p.can_admit(32));
        assert!(!p.can_admit(33));
        p.allocate(7, 32).unwrap();
        assert!(p.allocate(8, 1).is_err());
    }
}
