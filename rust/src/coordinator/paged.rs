//! Block-granular KV allocator (paged-attention-style).
//!
//! This is a *real* allocator, not an accounting stub: [`BlockPool`]
//! hands out physical block ids from a global free list and keeps a
//! per-sequence **block table** (the ordered list of physical blocks
//! holding that sequence's KV rows, like vLLM's PagedAttention). The
//! physical storage lives in [`super::kv::KvPages`], which stages
//! prefill KV into the allocated blocks and lets decode append into a
//! sequence's tail block; the scheduler admits a prefill batch by free
//! **block** count, so a long prompt never needs a contiguous run of
//! anything — its table can be scattered across the whole pool.
//!
//! Blocks are ref-counted so a shared prompt prefix can be accounted
//! once ([`BlockPool::fork_prefix`], copy-on-write accounting): the
//! prefix cache ([`super::prefix`]) forks a cached sequence's leading
//! blocks into a new request's table, and writers must copy a shared
//! block before mutating it ([`BlockPool::cow`]) — decode appends and
//! partial-block admission both go through that path.

use anyhow::{bail, Result};
use std::collections::HashMap;

/// Default tokens-per-block of the paged KV cache (vLLM's default).
pub const DEFAULT_BLOCK: usize = 16;

/// Snapshot of the free list's shape (see [`BlockPool::frag_stats`]).
///
/// Fragmentation is *observability only*: allocation never needs a
/// contiguous run, so a scattered free list affects nothing but cache
/// locality. The metric exists so serving dashboards can correlate
/// paging behavior with latency.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FragStats {
    /// Total blocks in the pool.
    pub n_blocks: usize,
    /// Currently free blocks.
    pub free_blocks: usize,
    /// Length of the longest run of physically consecutive free ids.
    pub longest_free_run: usize,
    /// Number of maximal consecutive free runs.
    pub free_runs: usize,
}

impl FragStats {
    /// `0.0` = all free space is one contiguous run; approaches `1.0`
    /// as the free list scatters into single-block islands. `0.0` when
    /// nothing is free.
    pub fn fragmentation(&self) -> f64 {
        if self.free_blocks == 0 {
            return 0.0;
        }
        1.0 - self.longest_free_run as f64 / self.free_blocks as f64
    }
}

/// Physical block allocator + per-sequence block tables (module docs).
#[derive(Debug, Clone)]
pub struct BlockPool {
    block_size: usize,
    n_blocks: usize,
    /// LIFO free list of physical ids (deterministic allocation order).
    free: Vec<u32>,
    refcount: Vec<u16>,
    /// seq -> block table: owned physical ids in token order.
    owners: HashMap<u64, Vec<u32>>,
}

impl BlockPool {
    /// A pool of `n_blocks` physical blocks of `block_size` tokens each.
    pub fn new(n_blocks: usize, block_size: usize) -> BlockPool {
        BlockPool {
            block_size: block_size.max(1),
            n_blocks,
            free: (0..n_blocks as u32).rev().collect(),
            refcount: vec![0; n_blocks],
            owners: HashMap::new(),
        }
    }

    /// Tokens per block.
    pub fn block_size(&self) -> usize {
        self.block_size
    }

    /// Total blocks in the pool.
    pub fn n_blocks(&self) -> usize {
        self.n_blocks
    }

    /// Blocks needed to hold `tokens` tokens.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_size)
    }

    /// Currently free blocks.
    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    /// Whether `tokens` more tokens could be allocated right now — from
    /// *anywhere* in the pool; contiguity is never required.
    pub fn can_admit(&self, tokens: usize) -> bool {
        self.blocks_for(tokens) <= self.free.len()
    }

    /// The sequence's block table (physical ids in token order), if
    /// allocated.
    pub fn table(&self, seq: u64) -> Option<&[u32]> {
        self.owners.get(&seq).map(|b| b.as_slice())
    }

    /// Allocate blocks for a new sequence of `tokens` tokens; returns
    /// the table.
    pub fn allocate(&mut self, seq: u64, tokens: usize) -> Result<&[u32]> {
        if self.owners.contains_key(&seq) {
            bail!("seq {seq} already has an allocation");
        }
        let need = self.blocks_for(tokens).max(1);
        if need > self.free.len() {
            bail!("pool exhausted: need {need}, free {}", self.free.len());
        }
        let mut blocks = Vec::with_capacity(need);
        for _ in 0..need {
            let b = self.free.pop().unwrap();
            self.refcount[b as usize] = 1;
            blocks.push(b);
        }
        self.owners.insert(seq, blocks);
        Ok(self.owners.get(&seq).unwrap())
    }

    /// Extend a sequence's table to cover `total_tokens` tokens (decode
    /// growth past a block boundary); returns the newly allocated tail
    /// block ids (empty when the table already covers the length).
    pub fn extend(&mut self, seq: u64, total_tokens: usize)
                  -> Result<Vec<u32>> {
        let need_total = self.blocks_for(total_tokens).max(1);
        let have = self
            .owners
            .get(&seq)
            .map(|b| b.len())
            .ok_or_else(|| anyhow::anyhow!("seq {seq} not allocated"))?;
        let extra = need_total.saturating_sub(have);
        if extra > self.free.len() {
            bail!(
                "pool exhausted growing seq {seq}: need {extra}, free {}",
                self.free.len()
            );
        }
        let mut added = Vec::with_capacity(extra);
        for _ in 0..extra {
            let b = self.free.pop().unwrap();
            self.refcount[b as usize] = 1;
            self.owners.get_mut(&seq).unwrap().push(b);
            added.push(b);
        }
        Ok(added)
    }

    /// Fork: new sequence shares the owner's **whole** table (the
    /// full-table special case of [`BlockPool::fork_prefix`]) —
    /// copy-on-write accounting via refcounts. Writers must copy a
    /// shared block before mutating it ([`BlockPool::cow`]).
    pub fn fork(&mut self, parent: u64, child: u64) -> Result<()> {
        let len = self
            .owners
            .get(&parent)
            .map(|b| b.len())
            .ok_or_else(|| anyhow::anyhow!("parent {parent} missing"))?;
        self.fork_prefix(parent, child, len)
    }

    /// Fork the first `n_blocks` of `parent`'s table into a new
    /// sequence `child` (prefix-cache hit): the child's table aliases
    /// the parent's leading blocks, each refcount bumped. Fork chains
    /// (fork of a fork) are fine — refcounts compose. Errors: missing
    /// parent, child already allocated, `n_blocks` zero or beyond the
    /// parent's table, or a refcount at `u16::MAX` (saturation would
    /// silently alias on release).
    pub fn fork_prefix(
        &mut self,
        parent: u64,
        child: u64,
        n_blocks: usize,
    ) -> Result<()> {
        let table = self
            .owners
            .get(&parent)
            .ok_or_else(|| anyhow::anyhow!("parent {parent} missing"))?;
        if self.owners.contains_key(&child) {
            bail!("child {child} already allocated");
        }
        if n_blocks == 0 {
            bail!("fork of zero blocks from parent {parent}");
        }
        if n_blocks > table.len() {
            bail!(
                "fork of {n_blocks} blocks from parent {parent} \
                 (table holds {})",
                table.len()
            );
        }
        let blocks: Vec<u32> = table[..n_blocks].to_vec();
        // check before mutating: saturation must not half-apply
        for &b in &blocks {
            if self.refcount[b as usize] == u16::MAX {
                bail!("refcount saturated on block {b}");
            }
        }
        for &b in &blocks {
            self.refcount[b as usize] += 1;
        }
        self.owners.insert(child, blocks);
        Ok(())
    }

    /// Copy-on-write a sequence's table entry `block_idx`: if the
    /// physical block is shared (refcount > 1), allocate a fresh block,
    /// point the table entry at it and return `Some((old, new))` so the
    /// caller can copy the payload; if the block is already exclusively
    /// owned, return `None` (nothing to do). Errors: unknown sequence,
    /// index beyond the table, or pool exhaustion.
    pub fn cow(
        &mut self,
        seq: u64,
        block_idx: usize,
    ) -> Result<Option<(u32, u32)>> {
        let table = self
            .owners
            .get(&seq)
            .ok_or_else(|| anyhow::anyhow!("cow of unallocated seq {seq}"))?;
        let Some(&old) = table.get(block_idx) else {
            bail!(
                "cow index {block_idx} beyond seq {seq}'s table ({})",
                table.len()
            );
        };
        if self.refcount[old as usize] <= 1 {
            return Ok(None); // exclusive: write in place
        }
        let Some(new) = self.free.pop() else {
            bail!("pool exhausted on copy-on-write of seq {seq}");
        };
        self.refcount[new as usize] = 1;
        self.refcount[old as usize] -= 1;
        self.owners.get_mut(&seq).unwrap()[block_idx] = new;
        Ok(Some((old, new)))
    }

    /// The refcount of a physical block id, if in range (test/metrics
    /// introspection).
    pub fn refcount_of(&self, block: u32) -> Option<u16> {
        self.refcount.get(block as usize).copied()
    }

    /// Sequence ids that currently own a block table, ascending.
    pub fn sequences(&self) -> Vec<u64> {
        let mut ids: Vec<u64> = self.owners.keys().copied().collect();
        ids.sort_unstable();
        ids
    }

    /// Return a sequence's blocks to the free list. Freeing a sequence
    /// that owns nothing, or freeing twice, is an error — silent
    /// double-frees are how block tables end up aliased.
    pub fn release(&mut self, seq: u64) -> Result<()> {
        let Some(blocks) = self.owners.remove(&seq) else {
            bail!("release of unallocated seq {seq} (double free?)");
        };
        for b in blocks {
            let rc = &mut self.refcount[b as usize];
            if *rc == 0 {
                bail!("double free of block {b} (refcount already 0)");
            }
            *rc -= 1;
            if *rc == 0 {
                self.free.push(b);
            }
        }
        Ok(())
    }

    /// Free-list shape for the fragmentation gauge (module docs).
    pub fn frag_stats(&self) -> FragStats {
        let mut ids: Vec<u32> = self.free.clone();
        ids.sort_unstable();
        let (mut longest, mut runs, mut cur) = (0usize, 0usize, 0usize);
        let mut prev: Option<u32> = None;
        for &b in &ids {
            match prev {
                Some(p) if b == p + 1 => cur += 1,
                _ => {
                    runs += 1;
                    cur = 1;
                }
            }
            longest = longest.max(cur);
            prev = Some(b);
        }
        FragStats {
            n_blocks: self.n_blocks,
            free_blocks: ids.len(),
            longest_free_run: longest,
            free_runs: runs,
        }
    }

    /// Internal-consistency checks used by the property/parity suites.
    pub fn check_invariants(&self) -> Result<()> {
        let mut expected = vec![0u16; self.n_blocks];
        for blocks in self.owners.values() {
            for &b in blocks {
                expected[b as usize] += 1;
            }
        }
        if expected != self.refcount {
            bail!("refcount drift");
        }
        let frees = self.free.len();
        let used = self.refcount.iter().filter(|r| **r > 0).count();
        if frees + used != self.n_blocks {
            bail!("block leak: {frees} free + {used} used != {}",
                  self.n_blocks);
        }
        for &b in &self.free {
            if self.refcount[b as usize] != 0 {
                bail!("free block {b} has refcount");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_extend_release() {
        let mut p = BlockPool::new(8, 16);
        assert_eq!(p.blocks_for(1), 1);
        assert_eq!(p.blocks_for(16), 1);
        assert_eq!(p.blocks_for(17), 2);
        let table = p.allocate(1, 40).unwrap().to_vec(); // 3 blocks
        assert_eq!(table.len(), 3);
        assert_eq!(p.free_blocks(), 5);
        assert!(p.extend(1, 48).unwrap().is_empty()); // still 3 blocks
        assert_eq!(p.free_blocks(), 5);
        let added = p.extend(1, 49).unwrap(); // 49 -> 4 blocks
        assert_eq!(added.len(), 1);
        assert_eq!(p.table(1).unwrap().len(), 4);
        assert_eq!(p.free_blocks(), 4);
        p.check_invariants().unwrap();
        p.release(1).unwrap();
        assert_eq!(p.free_blocks(), 8);
        p.check_invariants().unwrap();
    }

    #[test]
    fn allocation_needs_no_contiguous_run() {
        // free every other sequence so no two free ids are adjacent,
        // then allocate a table bigger than any contiguous free run
        let mut p = BlockPool::new(8, 4);
        for seq in 0..4u64 {
            p.allocate(seq, 8).unwrap(); // 2 blocks each
        }
        p.release(0).unwrap();
        p.release(2).unwrap();
        let fs = p.frag_stats();
        assert_eq!(fs.free_blocks, 4);
        assert!(fs.longest_free_run < 4, "free list must be fragmented");
        assert!(fs.fragmentation() > 0.0);
        // 4 blocks = 16 tokens, scattered: still admits
        assert!(p.can_admit(16));
        let t = p.allocate(9, 16).unwrap().to_vec();
        assert_eq!(t.len(), 4);
        p.check_invariants().unwrap();
    }

    #[test]
    fn fork_shares_and_cow_releases() {
        let mut p = BlockPool::new(4, 16);
        p.allocate(1, 32).unwrap(); // 2 blocks
        p.fork(1, 2).unwrap();
        assert_eq!(p.free_blocks(), 2); // shared, not copied
        p.release(1).unwrap();
        assert_eq!(p.free_blocks(), 2); // child still holds them
        p.check_invariants().unwrap();
        p.release(2).unwrap();
        assert_eq!(p.free_blocks(), 4);
        p.check_invariants().unwrap();
    }

    #[test]
    fn exhaustion_and_admission() {
        let mut p = BlockPool::new(2, 16);
        assert!(p.can_admit(32));
        assert!(!p.can_admit(33));
        p.allocate(7, 32).unwrap();
        assert!(p.allocate(8, 1).is_err());
    }

    #[test]
    fn release_of_unallocated_seq_is_an_error() {
        let mut p = BlockPool::new(2, 16);
        let err = p.release(5).unwrap_err();
        assert!(err.to_string().contains("unallocated"));
        p.check_invariants().unwrap();
    }

    #[test]
    fn double_free_is_an_error() {
        let mut p = BlockPool::new(2, 16);
        p.allocate(1, 16).unwrap();
        p.release(1).unwrap();
        assert!(p.release(1).is_err());
        assert_eq!(p.free_blocks(), 2);
        p.check_invariants().unwrap();
    }

    #[test]
    fn extend_of_unallocated_seq_is_an_error() {
        let mut p = BlockPool::new(2, 16);
        assert!(p.extend(3, 16).is_err());
    }

    #[test]
    fn fork_of_missing_parent_is_an_error() {
        let mut p = BlockPool::new(4, 16);
        assert!(p.fork(9, 10).unwrap_err().to_string().contains("missing"));
        assert!(p
            .fork_prefix(9, 10, 1)
            .unwrap_err()
            .to_string()
            .contains("missing"));
        p.check_invariants().unwrap();
    }

    #[test]
    fn fork_onto_existing_child_is_an_error() {
        let mut p = BlockPool::new(4, 16);
        p.allocate(1, 16).unwrap();
        p.allocate(2, 16).unwrap();
        assert!(p.fork(1, 2).unwrap_err().to_string().contains("already"));
        // nothing half-applied: refcounts unchanged
        assert_eq!(p.refcount_of(p.table(1).unwrap()[0]), Some(1));
        p.check_invariants().unwrap();
    }

    #[test]
    fn fork_prefix_bounds_are_enforced() {
        let mut p = BlockPool::new(4, 16);
        p.allocate(1, 32).unwrap(); // 2 blocks
        assert!(p.fork_prefix(1, 2, 0).is_err());
        assert!(p.fork_prefix(1, 2, 3).is_err());
        assert!(p.table(2).is_none(), "failed fork must not allocate");
        p.fork_prefix(1, 2, 1).unwrap();
        assert_eq!(p.table(2).unwrap(), &p.table(1).unwrap()[..1]);
        assert_eq!(p.refcount_of(p.table(1).unwrap()[0]), Some(2));
        assert_eq!(p.refcount_of(p.table(1).unwrap()[1]), Some(1));
        p.check_invariants().unwrap();
    }

    #[test]
    fn fork_of_fork_chains_compose_refcounts() {
        let mut p = BlockPool::new(4, 16);
        p.allocate(1, 32).unwrap(); // 2 blocks
        p.fork(1, 2).unwrap();
        p.fork_prefix(2, 3, 1).unwrap(); // fork of a fork
        let b0 = p.table(1).unwrap()[0];
        let b1 = p.table(1).unwrap()[1];
        assert_eq!(p.refcount_of(b0), Some(3));
        assert_eq!(p.refcount_of(b1), Some(2));
        p.check_invariants().unwrap();
        // releasing the original parent keeps shared blocks alive
        p.release(1).unwrap();
        assert_eq!(p.refcount_of(b0), Some(2));
        assert_eq!(p.refcount_of(b1), Some(1));
        assert_eq!(p.free_blocks(), 2);
        p.check_invariants().unwrap();
        p.release(2).unwrap();
        p.release(3).unwrap();
        assert_eq!(p.free_blocks(), 4);
        p.check_invariants().unwrap();
    }

    #[test]
    fn cow_on_exclusive_block_is_a_no_op() {
        let mut p = BlockPool::new(4, 16);
        p.allocate(1, 32).unwrap();
        let before = p.table(1).unwrap().to_vec();
        assert_eq!(p.cow(1, 1).unwrap(), None);
        assert_eq!(p.table(1).unwrap(), &before[..]);
        p.check_invariants().unwrap();
    }

    #[test]
    fn cow_on_shared_block_copies_exactly_one_entry() {
        let mut p = BlockPool::new(4, 16);
        p.allocate(1, 32).unwrap(); // 2 blocks
        p.fork(1, 2).unwrap();
        let parent = p.table(1).unwrap().to_vec();
        let (old, new) = p.cow(2, 1).unwrap().expect("shared -> must copy");
        assert_eq!(old, parent[1]);
        assert_ne!(new, old);
        assert_eq!(p.table(2).unwrap()[0], parent[0], "untouched entry");
        assert_eq!(p.table(2).unwrap()[1], new);
        assert_eq!(p.table(1).unwrap(), &parent[..], "parent unchanged");
        assert_eq!(p.refcount_of(old), Some(1));
        assert_eq!(p.refcount_of(new), Some(1));
        assert_eq!(p.refcount_of(parent[0]), Some(2));
        p.check_invariants().unwrap();
        // second write to the now-exclusive block: no further copy
        assert_eq!(p.cow(2, 1).unwrap(), None);
    }

    #[test]
    fn cow_errors_on_bad_seq_index_and_exhaustion() {
        let mut p = BlockPool::new(2, 16);
        assert!(p.cow(1, 0).unwrap_err().to_string().contains("unalloc"));
        p.allocate(1, 32).unwrap(); // both blocks
        assert!(p.cow(1, 2).unwrap_err().to_string().contains("beyond"));
        p.fork(1, 2).unwrap();
        // every block shared, zero free: the copy cannot be satisfied
        let err = p.cow(2, 0).unwrap_err();
        assert!(err.to_string().contains("exhausted"));
        p.check_invariants().unwrap();
    }

    #[test]
    fn sequences_lists_owners_in_order() {
        let mut p = BlockPool::new(4, 16);
        p.allocate(7, 16).unwrap();
        p.allocate(3, 16).unwrap();
        p.fork(7, 5).unwrap();
        assert_eq!(p.sequences(), vec![3, 5, 7]);
    }

    #[test]
    fn frag_stats_track_free_runs() {
        let mut p = BlockPool::new(6, 4);
        let fresh = p.frag_stats();
        assert_eq!(fresh.longest_free_run, 6);
        assert_eq!(fresh.free_runs, 1);
        assert_eq!(fresh.fragmentation(), 0.0);
        // LIFO free list: seqs own ids in order 0..6
        for seq in 0..6u64 {
            p.allocate(seq, 4).unwrap();
        }
        p.release(1).unwrap();
        p.release(3).unwrap();
        p.release(4).unwrap();
        let fs = p.frag_stats();
        assert_eq!(fs.free_blocks, 3);
        assert_eq!(fs.longest_free_run, 2); // {3,4}
        assert_eq!(fs.free_runs, 2); // {1}, {3,4}
        assert!(fs.fragmentation() > 0.0);
    }
}
