//! Health-aware request router: fronts one or more engine replicas.
//!
//! Policies: round-robin, least-outstanding, and prefix-affinity
//! (hash the block-aligned prompt prefix so prefix-cache siblings
//! land on the same replica, spilling to least-outstanding when the
//! affinity target is overloaded or unhealthy). Every policy skips
//! replicas that are not [`Health::Up`]; with zero routable replicas
//! selection returns a typed [`RouteError`] instead of panicking.
//!
//! On this single-core testbed a single replica is the normal
//! deployment; the router exists so the serving stack has the full
//! shape of the paper's target environment (8-NPU node = 8 replicas
//! behind one router). The [`super::replica::ReplicaPool`] supervisor
//! drives the health states; unit + property tests exercise the rest.

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::Sender;
use std::sync::Arc;

use anyhow::Result;

use super::request::{Request, Response};
use super::scheduler::EngineMsg;

/// Replica-selection policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// cycle through healthy replicas in order
    RoundRobin,
    /// pick the healthy replica with the fewest requests in flight
    LeastOutstanding,
    /// hash the block-aligned prompt prefix to a home replica, so
    /// requests sharing a cached prefix land on the same replica's
    /// prefix cache; spill to least-outstanding when the home replica
    /// is not `Up` or already has `spill_at` requests in flight
    PrefixAffinity {
        /// prefix tokens are hashed in blocks of this many tokens
        /// (use the KV block size so the hashed span is exactly the
        /// cacheable span); 0 hashes the whole prompt
        block: usize,
        /// spill to least-outstanding when the home replica has this
        /// many requests outstanding (0 = never spill on load)
        spill_at: u64,
    },
}

/// Replica health as seen by the router. Only `Up` replicas receive
/// new work; the supervisor walks replicas through the other states.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Health {
    /// serving; routable
    Up,
    /// graceful drain in progress: finishing in-flight work, not
    /// admitting — the router must not send it anything new
    Draining,
    /// dead (crashed, hung, or drained to completion); not routable
    Down,
    /// a fresh engine is binding after a restart; not routable until
    /// its first heartbeat
    Restarting,
}

impl Health {
    /// Short lowercase label for logs and reports.
    pub fn label(&self) -> &'static str {
        match self {
            Health::Up => "up",
            Health::Draining => "draining",
            Health::Down => "down",
            Health::Restarting => "restarting",
        }
    }
}

/// Typed selection failure: the caller decides whether to park the
/// request (replicas are restarting) or reject it (pool is empty /
/// everything is gone for good). Never a panic.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouteError {
    /// the router fronts zero replicas
    NoReplicas,
    /// every replica is unroutable (draining, down, or restarting)
    AllDown,
}

impl fmt::Display for RouteError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RouteError::NoReplicas => write!(f, "no replicas"),
            RouteError::AllDown => {
                write!(f, "no routable replica (all down or draining)")
            }
        }
    }
}

impl std::error::Error for RouteError {}

/// One engine replica behind the router.
pub struct Replica {
    /// the replica's message channel
    pub tx: Sender<EngineMsg>,
    /// requests dispatched but not yet completed
    pub outstanding: Arc<AtomicU64>,
    /// router-visible health; only `Up` receives new work
    pub health: Health,
}

impl Replica {
    /// A fresh `Up` replica behind `tx` with zero outstanding work.
    pub fn new(tx: Sender<EngineMsg>) -> Replica {
        Replica {
            tx,
            outstanding: Arc::new(AtomicU64::new(0)),
            health: Health::Up,
        }
    }
}

/// Fronts one or more engine replicas (module docs).
pub struct Router {
    replicas: Vec<Replica>,
    policy: Policy,
    rr_next: usize,
}

/// FNV-1a over the little-endian bytes of the token ids. Hand-rolled
/// so the affinity mapping is deterministic across runs and Rust
/// versions (`DefaultHasher` promises neither).
fn fnv1a(tokens: &[i32]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for t in tokens {
        for b in t.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
    }
    h
}

/// The affinity key of a prompt: FNV-1a over its block-aligned prefix
/// (the span the prefix cache can actually share). Prompts shorter
/// than one block hash whole, so short siblings still co-locate.
pub fn affinity_hash(prompt: &[i32], block: usize) -> u64 {
    let aligned = if block == 0 {
        prompt.len()
    } else {
        (prompt.len() / block) * block
    };
    let span = if aligned == 0 { prompt.len() } else { aligned };
    fnv1a(&prompt[..span])
}

impl Router {
    /// A router over `replicas` with the given policy.
    pub fn new(replicas: Vec<Replica>, policy: Policy) -> Router {
        Router { replicas, policy, rr_next: 0 }
    }

    /// Replica count (any health).
    pub fn n_replicas(&self) -> usize {
        self.replicas.len()
    }

    /// Replicas currently `Up`.
    pub fn n_up(&self) -> usize {
        self.replicas
            .iter()
            .filter(|r| r.health == Health::Up)
            .count()
    }

    /// A replica's health.
    pub fn health(&self, i: usize) -> Health {
        self.replicas[i].health
    }

    /// Set a replica's health (the supervisor's lifecycle hook).
    pub fn set_health(&mut self, i: usize, h: Health) {
        self.replicas[i].health = h;
    }

    /// A replica's outstanding-request count.
    pub fn outstanding(&self, i: usize) -> u64 {
        self.replicas[i].outstanding.load(Ordering::Relaxed)
    }

    /// A replica's message channel (for drain/chaos control messages).
    pub fn tx(&self, i: usize) -> &Sender<EngineMsg> {
        &self.replicas[i].tx
    }

    /// Swap in a restarted replica's fresh channel: outstanding resets
    /// to zero (the supervisor re-dispatched or failed everything the
    /// old incarnation held) and health moves to `Restarting` until
    /// its first heartbeat.
    pub fn rebind(&mut self, i: usize, tx: Sender<EngineMsg>) {
        let r = &mut self.replicas[i];
        r.tx = tx;
        r.outstanding.store(0, Ordering::Relaxed);
        r.health = Health::Restarting;
    }

    /// The `Up` replica with the fewest outstanding requests (ties to
    /// the lowest index), if any is `Up`.
    fn least_outstanding(&self) -> Option<usize> {
        self.replicas
            .iter()
            .enumerate()
            .filter(|(_, r)| r.health == Health::Up)
            .min_by_key(|(i, r)| {
                (r.outstanding.load(Ordering::Relaxed), *i)
            })
            .map(|(i, _)| i)
    }

    /// Pick a replica index for `req`. Only `Up` replicas are
    /// candidates; with none routable this is a typed [`RouteError`],
    /// never a panic.
    pub fn pick(&mut self, req: &Request) -> Result<usize, RouteError> {
        if self.replicas.is_empty() {
            return Err(RouteError::NoReplicas);
        }
        let n = self.replicas.len();
        match self.policy {
            Policy::RoundRobin => {
                for k in 0..n {
                    let i = (self.rr_next + k) % n;
                    if self.replicas[i].health == Health::Up {
                        self.rr_next = (i + 1) % n;
                        return Ok(i);
                    }
                }
                Err(RouteError::AllDown)
            }
            Policy::LeastOutstanding => {
                self.least_outstanding().ok_or(RouteError::AllDown)
            }
            Policy::PrefixAffinity { block, spill_at } => {
                // home replica from the stable hash over ALL slots, so
                // the mapping survives restarts of other replicas
                let home =
                    (affinity_hash(&req.prompt, block) % n as u64)
                        as usize;
                let r = &self.replicas[home];
                let loaded = spill_at > 0
                    && r.outstanding.load(Ordering::Relaxed) >= spill_at;
                if r.health == Health::Up && !loaded {
                    return Ok(home);
                }
                // spill: the home replica is unhealthy or overloaded
                self.least_outstanding().ok_or(RouteError::AllDown)
            }
        }
    }

    /// Route one request to a replica; returns the replica index. A
    /// failed send (replica channel closed — it died between the
    /// health check and the send) rolls the outstanding counter back
    /// and marks the replica `Down`, so one dead replica can never
    /// permanently bias `LeastOutstanding` toward itself.
    pub fn dispatch(
        &mut self,
        req: Request,
        reply: Sender<Response>,
    ) -> Result<usize> {
        let i = self.pick(&req)?;
        self.replicas[i].outstanding.fetch_add(1, Ordering::Relaxed);
        if self.replicas[i]
            .tx
            .send(EngineMsg::Submit(req, reply))
            .is_err()
        {
            // roll back the optimistic increment — the request never
            // reached the replica
            self.complete(i);
            self.replicas[i].health = Health::Down;
            return Err(anyhow::anyhow!("replica {i} channel closed"));
        }
        Ok(i)
    }

    /// Called by the completion fan-in when a response arrives (and by
    /// the dispatch rollback). Saturating: a stray double-complete
    /// must not wrap the gauge to u64::MAX and poison the policy.
    pub fn complete(&self, replica: usize) {
        let _ = self.replicas[replica].outstanding.fetch_update(
            Ordering::Relaxed,
            Ordering::Relaxed,
            |v| Some(v.saturating_sub(1)),
        );
    }

    /// Send shutdown to every replica.
    pub fn shutdown(&self) {
        for r in &self.replicas {
            let _ = r.tx.send(EngineMsg::Shutdown);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::SparsityConfig;
    use std::sync::mpsc::channel;

    fn mk_router(
        n: usize,
        policy: Policy,
    ) -> (Router, Vec<std::sync::mpsc::Receiver<EngineMsg>>) {
        let mut reps = Vec::new();
        let mut rxs = Vec::new();
        for _ in 0..n {
            let (tx, rx) = channel();
            reps.push(Replica::new(tx));
            rxs.push(rx);
        }
        (Router::new(reps, policy), rxs)
    }

    fn req(id: u64) -> Request {
        Request {
            id,
            prompt: vec![1],
            max_new_tokens: 1,
            config: SparsityConfig::dense(),
            deadline_ticks: 0,
        }
    }

    fn req_with_prompt(id: u64, prompt: Vec<i32>) -> Request {
        Request { prompt, ..req(id) }
    }

    #[test]
    fn round_robin_cycles() {
        let (mut r, rxs) = mk_router(3, Policy::RoundRobin);
        let (tx, _rx) = channel();
        let picks: Vec<usize> = (0..6)
            .map(|i| r.dispatch(req(i), tx.clone()).unwrap())
            .collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
        assert_eq!(rxs[0].try_iter().count(), 2);
    }

    #[test]
    fn round_robin_skips_unhealthy() {
        let (mut r, _rxs) = mk_router(3, Policy::RoundRobin);
        let (tx, _rx) = channel();
        r.set_health(1, Health::Down);
        let picks: Vec<usize> = (0..4)
            .map(|i| r.dispatch(req(i), tx.clone()).unwrap())
            .collect();
        assert_eq!(picks, vec![0, 2, 0, 2]);
    }

    #[test]
    fn least_outstanding_balances() {
        let (mut r, _rxs) = mk_router(2, Policy::LeastOutstanding);
        let (tx, _rx) = channel();
        r.dispatch(req(0), tx.clone()).unwrap(); // -> 0
        r.dispatch(req(1), tx.clone()).unwrap(); // -> 1
        r.complete(0);
        // replica 0 now has 0 outstanding, replica 1 has 1
        let i = r.dispatch(req(2), tx).unwrap();
        assert_eq!(i, 0);
    }

    #[test]
    fn all_down_is_a_typed_error_not_a_panic() {
        for policy in [
            Policy::RoundRobin,
            Policy::LeastOutstanding,
            Policy::PrefixAffinity { block: 16, spill_at: 0 },
        ] {
            let (mut r, _rxs) = mk_router(2, policy);
            r.set_health(0, Health::Down);
            r.set_health(1, Health::Draining);
            assert_eq!(r.pick(&req(0)), Err(RouteError::AllDown));
        }
        let (mut empty, _) = mk_router(0, Policy::LeastOutstanding);
        assert_eq!(empty.pick(&req(0)), Err(RouteError::NoReplicas));
    }

    #[test]
    fn failed_send_rolls_back_outstanding_and_downs_the_replica() {
        // regression: the counter leak used to bias LeastOutstanding
        // toward a dead replica forever (fetch_add before a failed
        // send, no decrement on the error path)
        let (mut r, mut rxs) = mk_router(2, Policy::LeastOutstanding);
        let (tx, _rx) = channel();
        drop(rxs.remove(0)); // replica 0's engine is gone
        let err = r.dispatch(req(0), tx.clone());
        assert!(err.is_err());
        assert_eq!(r.outstanding(0), 0, "no leak on the error path");
        assert_eq!(r.health(0), Health::Down);
        // and the survivor keeps serving
        let i = r.dispatch(req(1), tx).unwrap();
        assert_eq!(i, 1);
    }

    #[test]
    fn prefix_affinity_colocates_and_spills() {
        let policy = Policy::PrefixAffinity { block: 4, spill_at: 2 };
        let (mut r, _rxs) = mk_router(4, policy);
        let (tx, _rx) = channel();
        // identical block-aligned prefixes land on one replica even
        // when the tails differ
        let shared: Vec<i32> = (1..=8).collect();
        let mut a = shared.clone();
        a.extend([91, 92]);
        let mut b = shared.clone();
        b.extend([71]);
        let ia = r.dispatch(req_with_prompt(0, a.clone()), tx.clone());
        let ib = r.dispatch(req_with_prompt(1, b.clone()), tx.clone());
        let home = ia.unwrap();
        assert_eq!(home, ib.unwrap(), "siblings share a home replica");
        // the sub-block tail does not change the key...
        assert_eq!(
            affinity_hash(&a, 4),
            affinity_hash(&b, 4),
            "tail past the aligned prefix is ignored"
        );
        // ...but at spill_at outstanding the home overflows to the
        // least-outstanding survivor
        let ic = r
            .dispatch(req_with_prompt(2, shared.clone()), tx.clone())
            .unwrap();
        assert_ne!(ic, home, "overloaded home spills");
        // a downed home also spills instead of failing
        r.set_health(home, Health::Down);
        let id = r.dispatch(req_with_prompt(3, shared), tx).unwrap();
        assert_ne!(id, home);
    }

    #[test]
    fn rebind_resets_outstanding_and_requires_health_promotion() {
        let (mut r, _rxs) = mk_router(1, Policy::LeastOutstanding);
        let (tx, _rx) = channel();
        r.dispatch(req(0), tx.clone()).unwrap();
        assert_eq!(r.outstanding(0), 1);
        let (ntx, _nrx) = channel();
        r.rebind(0, ntx);
        assert_eq!(r.outstanding(0), 0);
        assert_eq!(r.health(0), Health::Restarting);
        // not routable until the supervisor promotes it
        assert_eq!(r.pick(&req(1)), Err(RouteError::AllDown));
        r.set_health(0, Health::Up);
        assert!(r.pick(&req(1)).is_ok());
    }
}
