//! Request router: fronts one or more engine replicas.
//!
//! Policies: round-robin, least-outstanding. On this single-core testbed a
//! single replica is the normal deployment; the router exists so the
//! serving stack has the full shape of the paper's target environment
//! (8-NPU node = 8 replicas behind one router) and is exercised by unit +
//! property tests.

use std::sync::mpsc::Sender;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{bail, Result};

use super::request::{Request, Response};
use super::scheduler::EngineMsg;

/// Replica-selection policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// cycle through replicas in order
    RoundRobin,
    /// pick the replica with the fewest requests in flight
    LeastOutstanding,
}

/// One engine replica behind the router.
pub struct Replica {
    /// the replica's message channel
    pub tx: Sender<EngineMsg>,
    /// requests dispatched but not yet completed
    pub outstanding: Arc<AtomicU64>,
}

/// Fronts one or more engine replicas (module docs).
pub struct Router {
    replicas: Vec<Replica>,
    policy: Policy,
    rr_next: usize,
}

impl Router {
    /// A router over `replicas` with the given policy.
    pub fn new(replicas: Vec<Replica>, policy: Policy) -> Router {
        Router { replicas, policy, rr_next: 0 }
    }

    /// Replica count.
    pub fn n_replicas(&self) -> usize {
        self.replicas.len()
    }

    /// Pick a replica index for the next request.
    pub fn pick(&mut self) -> Result<usize> {
        if self.replicas.is_empty() {
            bail!("no replicas");
        }
        Ok(match self.policy {
            Policy::RoundRobin => {
                let i = self.rr_next % self.replicas.len();
                self.rr_next = (self.rr_next + 1) % self.replicas.len();
                i
            }
            Policy::LeastOutstanding => self
                .replicas
                .iter()
                .enumerate()
                .min_by_key(|(_, r)| r.outstanding.load(Ordering::Relaxed))
                .map(|(i, _)| i)
                .unwrap(),
        })
    }

    /// Route one request to a replica; returns the replica index.
    pub fn dispatch(
        &mut self,
        req: Request,
        reply: Sender<Response>,
    ) -> Result<usize> {
        let i = self.pick()?;
        self.replicas[i]
            .outstanding
            .fetch_add(1, Ordering::Relaxed);
        self.replicas[i]
            .tx
            .send(EngineMsg::Submit(req, reply))
            .map_err(|_| anyhow::anyhow!("replica {i} channel closed"))?;
        Ok(i)
    }

    /// Called by the completion fan-in when a response arrives.
    pub fn complete(&self, replica: usize) {
        self.replicas[replica]
            .outstanding
            .fetch_sub(1, Ordering::Relaxed);
    }

    /// Send shutdown to every replica.
    pub fn shutdown(&self) {
        for r in &self.replicas {
            let _ = r.tx.send(EngineMsg::Shutdown);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::SparsityConfig;
    use std::sync::mpsc::channel;

    fn mk_router(n: usize, policy: Policy) -> (Router, Vec<std::sync::mpsc::Receiver<EngineMsg>>) {
        let mut reps = Vec::new();
        let mut rxs = Vec::new();
        for _ in 0..n {
            let (tx, rx) = channel();
            reps.push(Replica {
                tx,
                outstanding: Arc::new(AtomicU64::new(0)),
            });
            rxs.push(rx);
        }
        (Router::new(reps, policy), rxs)
    }

    fn req(id: u64) -> Request {
        Request {
            id,
            prompt: vec![1],
            max_new_tokens: 1,
            config: SparsityConfig::dense(),
            deadline_ticks: 0,
        }
    }

    #[test]
    fn round_robin_cycles() {
        let (mut r, rxs) = mk_router(3, Policy::RoundRobin);
        let (tx, _rx) = channel();
        let picks: Vec<usize> = (0..6)
            .map(|i| r.dispatch(req(i), tx.clone()).unwrap())
            .collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
        assert_eq!(rxs[0].try_iter().count(), 2);
    }

    #[test]
    fn least_outstanding_balances() {
        let (mut r, _rxs) = mk_router(2, Policy::LeastOutstanding);
        let (tx, _rx) = channel();
        r.dispatch(req(0), tx.clone()).unwrap(); // -> 0
        r.dispatch(req(1), tx.clone()).unwrap(); // -> 1
        r.complete(0);
        // replica 0 now has 0 outstanding, replica 1 has 1
        let i = r.dispatch(req(2), tx).unwrap();
        assert_eq!(i, 0);
    }
}
