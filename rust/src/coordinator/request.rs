//! Request / response types and the per-request sparsity configuration.

use std::sync::mpsc::Sender;
use std::time::Instant;

use super::error::RequestError;
use crate::sparsity::policy::Setting;

/// Per-request sparsity knob — the paper's method surfaced at the API.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SparsityConfig {
    /// skip-policy setting (naive / layer-skip / all)
    pub setting: Setting,
    /// N:M ratio; None for dense
    pub nm: Option<(usize, usize)>,
    /// W8A8 (Outstanding-sparse) path
    pub quantized: bool,
}

impl SparsityConfig {
    /// The dense fp baseline config.
    pub fn dense() -> Self {
        SparsityConfig { setting: Setting::Dense, nm: None, quantized: false }
    }

    /// Amber Pruner at N:M (fp, full policy with Robust-Norm scoring).
    pub fn amber(n: usize, m: usize) -> Self {
        SparsityConfig {
            setting: Setting::All,
            nm: Some((n, m)),
            quantized: false,
        }
    }

    /// Outstanding-sparse at N:M (W8A8 + layer skipping).
    pub fn outstanding(n: usize, m: usize) -> Self {
        SparsityConfig {
            setting: Setting::LayerSkip,
            nm: Some((n, m)),
            quantized: true,
        }
    }

    /// Parse "dense", "2:4", "8:16+sq", "4:8:naive" style strings (server
    /// protocol + CLI).
    pub fn parse(s: &str) -> Option<SparsityConfig> {
        let mut quantized = false;
        let mut core = s.trim();
        if let Some(stripped) = core.strip_suffix("+sq") {
            quantized = true;
            core = stripped;
        }
        if core == "dense" {
            return Some(SparsityConfig {
                setting: Setting::Dense,
                nm: None,
                quantized,
            });
        }
        let parts: Vec<&str> = core.split(':').collect();
        if parts.len() < 2 {
            return None;
        }
        let n = parts[0].parse().ok()?;
        let m = parts[1].parse().ok()?;
        let setting = match parts.get(2).copied() {
            None | Some("all") => Setting::All,
            Some("ls") => Setting::LayerSkip,
            Some("naive") => Setting::Naive,
            _ => return None,
        };
        Some(SparsityConfig { setting, nm: Some((n, m)), quantized })
    }

    /// One rung down the graceful-degradation ladder: a strictly more
    /// aggressive N:M ratio serving the same request with less prefill
    /// compute. The paper's method is training-free, so the ratio can
    /// tighten per request at admission time with no model change —
    /// overload control degrades before it sheds
    /// ([`super::scheduler::DegradePolicy`]).
    ///
    /// Ladder: dense → 4:8 → 2:4 (an `m > 8` config steps to 4:8
    /// first); 2:4 is the floor (`None`). The quantization flag is
    /// preserved; a dense request picks up the full Amber policy
    /// ([`Setting::All`]) with its first ratio.
    pub fn degraded(&self) -> Option<SparsityConfig> {
        let nm = match self.nm {
            None => (4, 8),
            Some((_, m)) if m > 8 => (4, 8),
            Some((_, m)) if m > 4 => (2, 4),
            Some(_) => return None, // already at the 2:4 floor
        };
        Some(SparsityConfig {
            setting: if self.setting == Setting::Dense {
                Setting::All
            } else {
                self.setting
            },
            nm: Some(nm),
            quantized: self.quantized,
        })
    }

    /// Canonical string form (inverse of [`SparsityConfig::parse`]).
    pub fn label(&self) -> String {
        let q = if self.quantized { "+sq" } else { "" };
        match self.nm {
            None => format!("dense{q}"),
            Some((n, m)) => format!(
                "{n}:{m}:{}{q}",
                match self.setting {
                    Setting::Naive => "naive",
                    Setting::LayerSkip => "ls",
                    _ => "all",
                }
            ),
        }
    }
}

/// One generation request.
#[derive(Debug, Clone)]
pub struct Request {
    /// caller-chosen request id (echoed in the response)
    pub id: u64,
    /// prompt token ids
    pub prompt: Vec<i32>,
    /// generation budget
    pub max_new_tokens: usize,
    /// the request's sparsity configuration
    pub config: SparsityConfig,
    /// complete-or-cancel deadline, measured in engine iterations
    /// (ticks) from submission — deterministic, never wall-clock. The
    /// engine cancels an expired request at its next scheduling point
    /// (queue sweep, chunk boundary, decode turn) with a `Rejected`
    /// error response carrying any tokens generated so far. 0 = no
    /// deadline (the default).
    pub deadline_ticks: u64,
}

/// The completed generation for one request.
#[derive(Debug, Clone)]
pub struct Response {
    /// the request's id
    pub id: u64,
    /// generated token ids (includes the terminating EOS, if any)
    pub tokens: Vec<i32>,
    /// time to first token, seconds
    pub ttft_secs: f64,
    /// end-to-end latency, seconds
    pub e2e_secs: f64,
    /// the prefill artifact that served the request (may be empty)
    pub prefill_artifact: String,
    /// how the request failed, if it did (`None` = success; `tokens`
    /// then holds whatever was generated before the failure)
    pub error: Option<RequestError>,
}

/// A not-yet-finished request handed back by a draining engine
/// ([`super::scheduler::EngineMsg::Drain`]): it has received **no**
/// response, so whoever drains the replica owns re-dispatching it.
/// The pipeline is deterministic and recomputes from scratch, so a
/// survivor replica serves it token-identically.
pub struct HandedBack {
    /// the request, untouched (generated tokens are discarded — replay
    /// recomputes from the prompt)
    pub req: Request,
    /// where its eventual response must go
    pub reply: Sender<Response>,
    /// transient-failure retries the request had already consumed on
    /// the draining replica, for supervisors that account retry budget
    /// across replicas
    pub retries: u32,
}

/// A request in flight inside the engine.
pub struct Tracked {
    /// the request itself
    pub req: Request,
    /// when it entered the engine
    pub arrived: Instant,
    /// when its first token was produced
    pub first_token_at: Option<Instant>,
    /// tokens generated so far
    pub generated: Vec<i32>,
    /// where the response goes on completion
    pub reply: Sender<Response>,
    /// transient-failure retries consumed so far (preemptions are not
    /// failures and do not count)
    pub retries: u32,
    /// absolute expiry tick (`submit tick + deadline_ticks`), resolved
    /// once at submission; `None` = no deadline
    pub deadline_at: Option<u64>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_labels_roundtrip() {
        for s in ["dense", "2:4:naive", "4:8:ls", "8:16:all", "8:16:ls+sq",
                  "dense+sq"] {
            let c = SparsityConfig::parse(s).unwrap();
            assert_eq!(c.label(), s.replace(":all", ":all"));
        }
        assert!(SparsityConfig::parse("3x7").is_none());
        assert!(SparsityConfig::parse("2:4:bogus").is_none());
    }

    #[test]
    fn degradation_ladder_tightens_to_the_2_4_floor() {
        let d0 = SparsityConfig::dense();
        let d1 = d0.degraded().unwrap();
        assert_eq!(d1.nm, Some((4, 8)));
        assert_eq!(d1.setting, Setting::All);
        let d2 = d1.degraded().unwrap();
        assert_eq!(d2.nm, Some((2, 4)));
        assert!(d2.degraded().is_none(), "2:4 is the floor");
        // 8:16 steps through 4:8, keeping setting and quantization
        let o = SparsityConfig::outstanding(8, 16);
        let o1 = o.degraded().unwrap();
        assert_eq!(o1.nm, Some((4, 8)));
        assert_eq!(o1.setting, Setting::LayerSkip);
        assert!(o1.quantized);
    }

    #[test]
    fn parse_shorthand() {
        let c = SparsityConfig::parse("2:4").unwrap();
        assert_eq!(c.nm, Some((2, 4)));
        assert_eq!(c.setting, Setting::All);
    }
}
