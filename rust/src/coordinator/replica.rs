//! Supervised replica pool: N engine serve loops behind one
//! health-aware router, with failover re-dispatch and graceful drain.
//!
//! ```text
//!                      ┌─ replica-0 thread ─ Engine::run ─┐
//!  clients ─► PoolMsg ─┤  replica-1 thread ─ Engine::run  ├─► fan-in
//!             (supervisor: ledger + Router + heartbeats)  │   (one
//!                      └─ replica-N thread ─ Engine::run ─┘  channel)
//! ```
//!
//! Each replica is an [`Engine`] built *inside* its own thread (the
//! execution backends are not `Send`, so a factory closure travels to
//! the thread and binds there) and wrapped in supervision: the
//! supervisor detects death three ways — the thread finishing (panic
//! escaping [`Engine::run`], an engine error, a disconnected channel)
//! and a heartbeat that stops advancing (a hung serve loop) — and
//! restarts the replica with a fresh engine bind.
//!
//! **Exactly-once responses.** The supervisor keeps a ledger of every
//! accepted request. All replica responses fan into one channel; the
//! first response for an id is forwarded to the client and retires the
//! ledger entry, any later copy (a fenced-off zombie finishing a
//! request that was already re-dispatched) is dropped. The pipeline is
//! deterministic and recomputes from scratch, so either copy carries
//! identical tokens — the replay guarantee PR 9 pinned for preemption
//! and retries extends across the replica boundary unchanged.
//!
//! **Failover.** When a replica dies, its ledger entries re-dispatch
//! to survivors (deterministic id order). Each crash-failover consumes
//! one pool-level attempt; past [`PoolConfig::max_redispatch`] the
//! request fails with a `Fatal` response instead of bouncing forever.
//! Graceful-drain hand-backs re-dispatch **without** consuming the
//! budget — a drain is an operator action, not a failure. Tick-based
//! deadlines (`deadline_ticks`) are relative budgets and re-resolve on
//! the survivor's tick clock.
//!
//! **Drain.** [`PoolHandle::drain`] walks a replica `Up → Draining →
//! Down`: the engine stops admitting, hands queued/parked work back
//! un-replied ([`HandedBack`]), finishes what is in flight, and exits.
//! Nothing is lost and nothing answers twice — the ledger fence holds
//! for drains exactly as for crashes.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::AtomicU64;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::error::RequestError;
use super::request::{HandedBack, Request, Response};
use super::router::{Health, Policy, Replica, Router};
use super::scheduler::{Engine, EngineMsg};
use crate::metrics::EngineMetrics;

/// Builds one replica's engine, called **inside** the replica thread
/// (execution backends are not `Send`). The argument is the replica
/// index, so factories can vary per-replica configuration.
pub type EngineFactory =
    Arc<dyn Fn(usize) -> Result<Engine> + Send + Sync>;

/// Replica-pool tuning knobs.
#[derive(Clone)]
pub struct PoolConfig {
    /// number of replicas to spawn
    pub replicas: usize,
    /// replica-selection policy for new and re-dispatched requests
    pub policy: Policy,
    /// declare a replica hung when its heartbeat has not advanced for
    /// this long, fence it off and restart it (`0` disables heartbeat
    /// supervision; thread-death detection always runs)
    pub heartbeat_timeout: Duration,
    /// supervisor poll period: the latency floor for dispatch,
    /// fan-out and death detection
    pub poll: Duration,
    /// crash-failover re-dispatches tolerated per request before it
    /// fails with a `Fatal` response (drain hand-backs are free)
    pub max_redispatch: u32,
    /// automatic restarts tolerated per replica slot before the
    /// supervisor leaves it `Down` for good
    pub max_restarts: u32,
}

impl PoolConfig {
    /// Defaults for `replicas` slots: least-outstanding routing, 1 s
    /// heartbeat timeout, 2 ms poll, 3 re-dispatches, 8 restarts.
    pub fn new(replicas: usize) -> PoolConfig {
        PoolConfig {
            replicas: replicas.max(1),
            policy: Policy::LeastOutstanding,
            heartbeat_timeout: Duration::from_secs(1),
            poll: Duration::from_millis(2),
            max_redispatch: 3,
            max_restarts: 8,
        }
    }
}

/// Control-plane messages understood by the pool supervisor.
enum PoolMsg {
    /// accept a request; the response goes to the sender exactly once
    Submit(Request, Sender<Response>),
    /// a replica response, forwarded off the fan-in channel
    Completed(Response),
    /// chaos: crash a replica (panic out of its serve loop)
    Kill(usize),
    /// chaos: stall a replica's serve loop for the given milliseconds
    Stall(usize, u64),
    /// gracefully drain a replica (`Up → Draining → Down`)
    Drain(usize),
    /// restart a `Down` replica with a fresh engine bind
    Restart(usize),
    /// snapshot per-replica stats
    Snapshot(Sender<Vec<ReplicaStat>>),
    /// graceful pool shutdown; the optional sender is acked once every
    /// ledger entry is answered and every replica thread has exited
    Shutdown(Option<Sender<()>>),
}

/// Point-in-time view of one replica slot ([`PoolHandle::snapshot`]).
#[derive(Debug, Clone)]
pub struct ReplicaStat {
    /// slot index
    pub index: usize,
    /// router-visible health
    pub health: Health,
    /// requests dispatched to this incarnation and not yet answered
    pub outstanding: u64,
    /// engine binds consumed by this slot (0 = the initial bind, each
    /// restart adds one)
    pub generation: u32,
    /// requests dispatched to this slot over the pool's lifetime
    pub dispatched: u64,
    /// latest heartbeat value (0 = the incarnation has not beaten yet)
    pub beats: u64,
}

/// How a replica thread ended.
enum ReplicaExit {
    /// `Engine::run` returned `Ok` (shutdown or drain completed)
    Clean,
    /// `Engine::run` returned an error (e.g. corrupt KV after a panic)
    Failed(String),
    /// a panic escaped `Engine::run` (crash injection or a real bug)
    Panicked(String),
    /// the factory could not build the engine
    BindFailed(String),
}

/// One supervised replica slot.
struct Slot {
    join: Option<JoinHandle<ReplicaExit>>,
    heartbeat: Arc<AtomicU64>,
    /// last heartbeat value observed by the supervisor
    last_beat: u64,
    /// when `last_beat` last changed
    last_beat_at: Instant,
    generation: u32,
    dispatched: u64,
}

/// One accepted request: the authoritative exactly-once record. The
/// entry is retired by the first response for its id; everything else
/// about the request (where it ran, how often it failed over) lives
/// here so replicas stay disposable.
struct Entry {
    /// slot currently working the request (`None` = awaiting
    /// re-dispatch)
    replica: Option<usize>,
    req: Request,
    /// the client's reply channel (replicas answer into the fan-in,
    /// never to clients directly)
    reply: Sender<Response>,
    /// crash-failover re-dispatches consumed
    attempts: u32,
}

/// Cloneable handle for submitting work and driving chaos/lifecycle
/// operations against a running [`ReplicaPool`].
#[derive(Clone)]
pub struct PoolHandle {
    ctl: Sender<PoolMsg>,
}

impl PoolHandle {
    /// Submit a request; its response arrives on `reply` exactly once.
    pub fn submit(
        &self,
        req: Request,
        reply: Sender<Response>,
    ) -> Result<()> {
        self.ctl
            .send(PoolMsg::Submit(req, reply))
            .map_err(|_| anyhow::anyhow!("replica pool is gone"))
    }

    /// Crash replica `i` (its in-flight work fails over to survivors
    /// and the supervisor restarts it).
    pub fn kill(&self, i: usize) {
        let _ = self.ctl.send(PoolMsg::Kill(i));
    }

    /// Stall replica `i`'s serve loop for `ms` milliseconds (heartbeat
    /// supervision fences and replaces it if the stall outlives the
    /// timeout).
    pub fn stall(&self, i: usize, ms: u64) {
        let _ = self.ctl.send(PoolMsg::Stall(i, ms));
    }

    /// Gracefully drain replica `i` (`Up → Draining → Down`); its
    /// queued work re-dispatches to survivors, in-flight work finishes
    /// in place.
    pub fn drain(&self, i: usize) {
        let _ = self.ctl.send(PoolMsg::Drain(i));
    }

    /// Restart a `Down` replica with a fresh engine bind.
    pub fn restart(&self, i: usize) {
        let _ = self.ctl.send(PoolMsg::Restart(i));
    }

    /// Per-replica health/outstanding/generation stats.
    pub fn snapshot(&self) -> Result<Vec<ReplicaStat>> {
        let (tx, rx) = channel();
        self.ctl
            .send(PoolMsg::Snapshot(tx))
            .map_err(|_| anyhow::anyhow!("replica pool is gone"))?;
        rx.recv().context("replica pool dropped the snapshot")
    }

    /// Begin a graceful pool shutdown without waiting for it (the
    /// drain-on-shutdown trigger for the TCP path; use
    /// [`ReplicaPool::shutdown`] to wait).
    pub fn begin_shutdown(&self) {
        let _ = self.ctl.send(PoolMsg::Shutdown(None));
    }
}

/// A running pool of supervised engine replicas (module docs).
pub struct ReplicaPool {
    handle: PoolHandle,
    supervisor: Option<JoinHandle<()>>,
}

impl ReplicaPool {
    /// Spawn `cfg.replicas` supervised replicas plus the supervisor
    /// thread. Engines are built lazily inside their threads via
    /// `factory`; replicas become routable at their first heartbeat.
    pub fn start(
        factory: EngineFactory,
        metrics: Arc<EngineMetrics>,
        cfg: PoolConfig,
    ) -> Result<ReplicaPool> {
        let (ctl_tx, ctl_rx) = channel::<PoolMsg>();
        let (fanin_tx, fanin_rx) = channel::<Response>();
        // forwarder: replica responses become control-plane messages,
        // so the supervisor blocks on exactly one channel
        let fwd_ctl = ctl_tx.clone();
        std::thread::Builder::new()
            .name("pool-fanin".into())
            .spawn(move || {
                for resp in fanin_rx {
                    if fwd_ctl.send(PoolMsg::Completed(resp)).is_err() {
                        break; // supervisor gone
                    }
                }
            })
            .context("spawn of the pool fan-in thread")?;
        let n = cfg.replicas;
        let sup = Supervisor::new(factory, metrics, cfg, fanin_tx);
        let supervisor = std::thread::Builder::new()
            .name("pool-supervisor".into())
            .spawn(move || sup.run(ctl_rx))
            .with_context(|| {
                format!("spawn of the supervisor for {n} replicas")
            })?;
        Ok(ReplicaPool {
            handle: PoolHandle { ctl: ctl_tx },
            supervisor: Some(supervisor),
        })
    }

    /// A cloneable submission/chaos handle.
    pub fn handle(&self) -> PoolHandle {
        self.handle.clone()
    }

    /// Block until the supervisor exits — i.e. until a shutdown
    /// initiated elsewhere (the TCP `shutdown` command via
    /// [`PoolHandle::begin_shutdown`]) completes. Does not itself
    /// start a shutdown.
    pub fn wait(&mut self) -> Result<()> {
        let Some(sup) = self.supervisor.take() else {
            return Ok(());
        };
        match sup.join() {
            Ok(()) => Ok(()),
            Err(_) => Err(anyhow::anyhow!(
                "replica-pool supervisor panicked"
            )),
        }
    }

    /// Graceful shutdown: every accepted request is answered (served,
    /// or failed with a typed error), every replica thread joins, then
    /// the supervisor exits. Idempotent; also runs on drop.
    pub fn shutdown(&mut self) -> Result<()> {
        let Some(sup) = self.supervisor.take() else {
            return Ok(());
        };
        let (ack_tx, ack_rx) = channel();
        let _ = self.handle.ctl.send(PoolMsg::Shutdown(Some(ack_tx)));
        // the ack only exists for callers that want to block; the join
        // below is the real synchronization
        let _ = ack_rx.recv_timeout(Duration::from_secs(60));
        match sup.join() {
            Ok(()) => Ok(()),
            Err(_) => Err(anyhow::anyhow!(
                "replica-pool supervisor panicked"
            )),
        }
    }
}

impl Drop for ReplicaPool {
    fn drop(&mut self) {
        let _ = self.shutdown();
    }
}

/// The supervisor's mutable state (runs on its own thread).
struct Supervisor {
    factory: EngineFactory,
    metrics: Arc<EngineMetrics>,
    cfg: PoolConfig,
    router: Router,
    slots: Vec<Slot>,
    ledger: HashMap<u64, Entry>,
    /// accepted requests awaiting (re-)dispatch, in failover order
    unassigned: VecDeque<u64>,
    /// replica responses fan into this (cloned per dispatch)
    fanin_tx: Sender<Response>,
    /// drain hand-backs arrive here
    handback_tx: Sender<HandedBack>,
    handback_rx: Receiver<HandedBack>,
    shutting_down: bool,
    shutdown_acks: Vec<Sender<()>>,
}

impl Supervisor {
    fn new(
        factory: EngineFactory,
        metrics: Arc<EngineMetrics>,
        cfg: PoolConfig,
        fanin_tx: Sender<Response>,
    ) -> Supervisor {
        let n = cfg.replicas;
        let (handback_tx, handback_rx) = channel();
        // placeholder channels; spawn_slot rebinds each immediately
        let replicas: Vec<Replica> = (0..n)
            .map(|_| {
                let (tx, _rx) = channel();
                Replica::new(tx)
            })
            .collect();
        let now = Instant::now();
        let slots: Vec<Slot> = (0..n)
            .map(|_| Slot {
                join: None,
                heartbeat: Arc::new(AtomicU64::new(0)),
                last_beat: 0,
                last_beat_at: now,
                generation: 0,
                dispatched: 0,
            })
            .collect();
        let mut sup = Supervisor {
            factory,
            metrics,
            router: Router::new(replicas, cfg.policy),
            cfg,
            slots,
            ledger: HashMap::new(),
            unassigned: VecDeque::new(),
            fanin_tx,
            handback_tx,
            handback_rx,
            shutting_down: false,
            shutdown_acks: Vec::new(),
        };
        for i in 0..n {
            sup.spawn_slot(i, true);
        }
        sup
    }

    /// The supervision loop: control messages, hand-backs, death and
    /// heartbeat checks, re-dispatch, gauges — every `cfg.poll`.
    fn run(mut self, ctl_rx: Receiver<PoolMsg>) {
        loop {
            match ctl_rx.recv_timeout(self.cfg.poll) {
                Ok(m) => {
                    self.handle(m);
                    loop {
                        match ctl_rx.try_recv() {
                            Ok(m) => self.handle(m),
                            Err(_) => break,
                        }
                    }
                }
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => {
                    // every handle is gone; nobody can ack, but drain
                    // what was accepted before exiting
                    self.begin_shutdown();
                }
            }
            loop {
                match self.handback_rx.try_recv() {
                    Ok(h) => self.requeue_handback(h),
                    Err(_) => break,
                }
            }
            self.supervise();
            self.flush_unassigned();
            self.publish();
            if self.shutting_down && self.done() {
                for ack in self.shutdown_acks.drain(..) {
                    let _ = ack.send(());
                }
                return;
            }
        }
    }

    fn handle(&mut self, msg: PoolMsg) {
        match msg {
            PoolMsg::Submit(req, reply) => self.accept(req, reply),
            PoolMsg::Completed(resp) => self.deliver(resp),
            PoolMsg::Kill(i) => {
                if i < self.slots.len() {
                    let _ = self.router.tx(i).send(EngineMsg::Crash);
                }
            }
            PoolMsg::Stall(i, ms) => {
                if i < self.slots.len() {
                    let _ =
                        self.router.tx(i).send(EngineMsg::Stall(ms));
                }
            }
            PoolMsg::Drain(i) => self.drain(i),
            PoolMsg::Restart(i) => {
                if i < self.slots.len()
                    && self.router.health(i) == Health::Down
                    && !self.shutting_down
                {
                    EngineMetrics::inc(
                        &self.metrics.replica_restarts,
                        1,
                    );
                    self.spawn_slot(i, false);
                }
            }
            PoolMsg::Snapshot(tx) => {
                let _ = tx.send(self.snapshot());
            }
            PoolMsg::Shutdown(ack) => {
                self.begin_shutdown();
                if let Some(a) = ack {
                    if self.done() {
                        let _ = a.send(());
                    } else {
                        self.shutdown_acks.push(a);
                    }
                }
            }
        }
    }

    /// Accept a request into the ledger (or refuse it with a typed
    /// response when the pool is shutting down / the id is taken).
    fn accept(&mut self, req: Request, reply: Sender<Response>) {
        let id = req.id;
        if self.shutting_down {
            refuse(&reply, id, "pool is shutting down");
            return;
        }
        if self.ledger.contains_key(&id) {
            refuse(&reply, id, "duplicate request id");
            return;
        }
        self.ledger.insert(
            id,
            Entry { replica: None, req, reply, attempts: 0 },
        );
        self.unassigned.push_back(id);
    }

    /// Forward the first response for an id to its client; drop any
    /// later copy (a fenced-off zombie answering a request that was
    /// already re-dispatched — token-identical either way).
    fn deliver(&mut self, resp: Response) {
        match self.ledger.remove(&resp.id) {
            Some(e) => {
                if let Some(i) = e.replica {
                    self.router.complete(i);
                }
                let _ = e.reply.send(resp);
            }
            None => {
                EngineMetrics::inc(
                    &self.metrics.replica_stale_replies,
                    1,
                );
            }
        }
    }

    /// Begin a graceful drain of replica `i`.
    fn drain(&mut self, i: usize) {
        if i >= self.slots.len()
            || self.router.health(i) != Health::Up
        {
            return;
        }
        EngineMetrics::inc(&self.metrics.replica_drains, 1);
        self.router.set_health(i, Health::Draining);
        if self
            .router
            .tx(i)
            .send(EngineMsg::Drain(self.handback_tx.clone()))
            .is_err()
        {
            // already dead; supervision will fail it over
            self.router.set_health(i, Health::Down);
        }
    }

    /// A drained request re-enters the dispatch queue without
    /// consuming failover budget. The engine's `HandedBack.reply` is
    /// the fan-in sender, not the client — the ledger entry owns the
    /// real reply channel, so an entry-less hand-back (the request was
    /// already answered) is simply dropped.
    fn requeue_handback(&mut self, h: HandedBack) {
        let id = h.req.id;
        if let Some(e) = self.ledger.get_mut(&id) {
            if let Some(i) = e.replica.take() {
                self.router.complete(i);
            }
            if !self.unassigned.contains(&id) {
                self.unassigned.push_back(id);
            }
        }
    }

    /// Spawn (or respawn) slot `i`: fresh channel, fresh heartbeat,
    /// engine factory runs inside the new thread. The slot is
    /// `Restarting` until its first heartbeat.
    fn spawn_slot(&mut self, i: usize, initial: bool) {
        let gen = if initial {
            self.slots[i].generation
        } else {
            self.slots[i].generation + 1
        };
        let (tx, rx) = channel::<EngineMsg>();
        let beat = Arc::new(AtomicU64::new(0));
        let factory = self.factory.clone();
        let thread_beat = beat.clone();
        let spawned = std::thread::Builder::new()
            .name(format!("replica-{i}.g{gen}"))
            .spawn(move || {
                let mut engine = match factory(i) {
                    Ok(e) => e,
                    Err(e) => {
                        return ReplicaExit::BindFailed(format!(
                            "{e:#}"
                        ))
                    }
                };
                engine.set_heartbeat(thread_beat);
                let ran = std::panic::catch_unwind(
                    std::panic::AssertUnwindSafe(move || {
                        engine.run(rx)
                    }),
                );
                match ran {
                    Ok(Ok(())) => ReplicaExit::Clean,
                    Ok(Err(e)) => {
                        ReplicaExit::Failed(format!("{e:#}"))
                    }
                    Err(p) => {
                        ReplicaExit::Panicked(panic_text(p.as_ref()))
                    }
                }
            });
        match spawned {
            Ok(join) => {
                self.router.rebind(i, tx);
                let s = &mut self.slots[i];
                s.join = Some(join);
                s.heartbeat = beat;
                s.last_beat = 0;
                s.last_beat_at = Instant::now();
                s.generation = gen;
            }
            Err(e) => {
                crate::warn_log!(
                    "replica {i}: thread spawn failed ({e}); slot down"
                );
                self.router.set_health(i, Health::Down);
            }
        }
    }

    /// Death and liveness checks for every slot.
    fn supervise(&mut self) {
        for i in 0..self.slots.len() {
            match self.router.health(i) {
                Health::Down => {}
                Health::Draining => {
                    if self.slot_finished(i) {
                        // deliberate exit; anything still on the books
                        // (a hand-back raced the exit) re-dispatches
                        // without penalty
                        self.reap(i);
                        self.failover(i, false);
                        self.router.set_health(i, Health::Down);
                    }
                }
                Health::Up | Health::Restarting => {
                    if self.slot_finished(i) {
                        self.on_death(i);
                    } else {
                        self.check_heartbeat(i);
                    }
                }
            }
        }
    }

    /// Has slot `i`'s current thread exited?
    fn slot_finished(&self, i: usize) -> bool {
        self.slots[i]
            .join
            .as_ref()
            .is_some_and(|j| j.is_finished())
    }

    /// Join a finished slot thread and log how it ended.
    fn reap(&mut self, i: usize) -> Option<ReplicaExit> {
        let join = self.slots[i].join.take()?;
        match join.join() {
            Ok(exit) => {
                let what = match &exit {
                    ReplicaExit::Clean => "exited cleanly".into(),
                    ReplicaExit::Failed(e) => format!("failed: {e}"),
                    ReplicaExit::Panicked(p) => {
                        format!("panicked: {p}")
                    }
                    ReplicaExit::BindFailed(e) => {
                        format!("engine bind failed: {e}")
                    }
                };
                crate::warn_log!("replica {i}: {what}");
                Some(exit)
            }
            Err(_) => {
                crate::warn_log!("replica {i}: thread died opaquely");
                Some(ReplicaExit::Panicked("opaque thread death".into()))
            }
        }
    }

    /// An `Up`/`Restarting` replica's thread died: fail its work over
    /// to survivors and restart the slot (until `max_restarts`).
    fn on_death(&mut self, i: usize) {
        let exit = self.reap(i);
        self.failover(i, true);
        self.router.set_health(i, Health::Down);
        let bind_failed =
            matches!(exit, Some(ReplicaExit::BindFailed(_)));
        if self.shutting_down {
            return; // never restart while draining the pool
        }
        if self.slots[i].generation + 1 > self.cfg.max_restarts {
            crate::warn_log!(
                "replica {i}: restart budget exhausted; slot down"
            );
            return;
        }
        if bind_failed && self.slots[i].generation >= 1 {
            // two consecutive bind failures: the factory is broken,
            // not the replica — stop burning threads on it
            crate::warn_log!(
                "replica {i}: engine bind failed twice; slot down"
            );
            return;
        }
        EngineMetrics::inc(&self.metrics.replica_restarts, 1);
        self.spawn_slot(i, false);
    }

    /// Promote a `Restarting` slot at its first heartbeat; fence and
    /// replace an `Up` slot whose heartbeat stalled past the timeout.
    fn check_heartbeat(&mut self, i: usize) {
        let beat = self.slots[i]
            .heartbeat
            .load(std::sync::atomic::Ordering::Relaxed);
        if beat != self.slots[i].last_beat {
            self.slots[i].last_beat = beat;
            self.slots[i].last_beat_at = Instant::now();
            if self.router.health(i) == Health::Restarting && beat > 0
            {
                self.router.set_health(i, Health::Up);
            }
            return;
        }
        let timeout = self.cfg.heartbeat_timeout;
        if timeout.is_zero()
            || self.router.health(i) != Health::Up
            || self.slots[i].last_beat_at.elapsed() <= timeout
        {
            return;
        }
        // hung: fence the incarnation off (drop its channel so a
        // late-waking zombie drains into disconnected senders and its
        // stale replies hit the ledger fence) and bind a replacement
        crate::warn_log!(
            "replica {i}: heartbeat stalled past {timeout:?}; \
             fencing and restarting"
        );
        self.slots[i].join = None; // detach the zombie thread
        self.failover(i, true);
        if !self.shutting_down
            && self.slots[i].generation + 1 <= self.cfg.max_restarts
        {
            EngineMetrics::inc(&self.metrics.replica_restarts, 1);
            self.spawn_slot(i, false);
        } else {
            self.router.set_health(i, Health::Down);
        }
    }

    /// Move every ledger entry assigned to slot `i` back to the
    /// dispatch queue (deterministic id order). `penalize` charges one
    /// failover attempt per request — crashes do, drains don't — and
    /// requests past the budget fail with a `Fatal` response here.
    fn failover(&mut self, i: usize, penalize: bool) {
        let mut ids: Vec<u64> = self
            .ledger
            .iter()
            .filter(|(_, e)| e.replica == Some(i))
            .map(|(id, _)| *id)
            .collect();
        if ids.is_empty() {
            return;
        }
        ids.sort_unstable();
        for id in ids {
            let Some(e) = self.ledger.get_mut(&id) else { continue };
            e.replica = None;
            // the entry no longer counts against the dead slot (a
            // rebind would also reset the counter, but a slot can go
            // `Down` for good without one)
            self.router.complete(i);
            if penalize {
                e.attempts += 1;
                if e.attempts > self.cfg.max_redispatch {
                    let n = e.attempts - 1;
                    if let Some(e) = self.ledger.remove(&id) {
                        fail(
                            &e.reply,
                            id,
                            format!(
                                "giving up after {n} replica \
                                 failovers"
                            ),
                        );
                    }
                    continue;
                }
                EngineMetrics::inc(
                    &self.metrics.replica_redispatches,
                    1,
                );
            }
            if !self.unassigned.contains(&id) {
                self.unassigned.push_back(id);
            }
        }
    }

    /// Dispatch every queued request to an `Up` replica. With nothing
    /// routable: wait if a replica is restarting, otherwise answer
    /// each request with a typed refusal so exactly-once still holds.
    fn flush_unassigned(&mut self) {
        while let Some(id) = self.unassigned.pop_front() {
            let Some(entry) = self.ledger.get(&id) else {
                continue; // already answered (stale queue slot)
            };
            if self.router.n_up() == 0 {
                let restarting = (0..self.slots.len()).any(|i| {
                    self.router.health(i) == Health::Restarting
                });
                if restarting && !self.shutting_down {
                    // a fresh bind is coming; hold the queue
                    self.unassigned.push_front(id);
                    return;
                }
                if let Some(e) = self.ledger.remove(&id) {
                    let why = if self.shutting_down {
                        "pool is shutting down"
                    } else {
                        "no replicas available"
                    };
                    refuse(&e.reply, id, why);
                }
                continue;
            }
            let req = entry.req.clone();
            match self.router.dispatch(req, self.fanin_tx.clone()) {
                Ok(i) => {
                    if let Some(e) = self.ledger.get_mut(&id) {
                        e.replica = Some(i);
                    }
                    self.slots[i].dispatched += 1;
                }
                Err(_) => {
                    // the picked replica died mid-send (dispatch
                    // already downed it); retry on the next pass
                    self.unassigned.push_front(id);
                    return;
                }
            }
        }
    }

    /// Start the graceful pool shutdown exactly once: refuse new
    /// work, flush what is queued, then ask every replica to finish
    /// and exit.
    fn begin_shutdown(&mut self) {
        if self.shutting_down {
            return;
        }
        self.shutting_down = true;
        self.flush_unassigned();
        self.router.shutdown();
    }

    /// Shutdown is complete when every accepted request has been
    /// answered and every replica thread has exited.
    fn done(&mut self) -> bool {
        if !self.ledger.is_empty() || !self.unassigned.is_empty() {
            return false;
        }
        for i in 0..self.slots.len() {
            if self.slots[i].join.is_some() {
                if !self.slot_finished(i) {
                    return false;
                }
                self.reap(i);
                self.router.set_health(i, Health::Down);
            }
        }
        true
    }

    fn snapshot(&self) -> Vec<ReplicaStat> {
        (0..self.slots.len())
            .map(|i| ReplicaStat {
                index: i,
                health: self.router.health(i),
                outstanding: self.router.outstanding(i),
                generation: self.slots[i].generation,
                dispatched: self.slots[i].dispatched,
                beats: self.slots[i]
                    .heartbeat
                    .load(std::sync::atomic::Ordering::Relaxed),
            })
            .collect()
    }

    fn publish(&self) {
        EngineMetrics::set(
            &self.metrics.replicas_total,
            self.slots.len() as u64,
        );
        EngineMetrics::set(
            &self.metrics.replicas_up,
            self.router.n_up() as u64,
        );
    }
}

/// Answer a request with a `Rejected` response (pool-level refusal).
fn refuse(reply: &Sender<Response>, id: u64, why: &str) {
    let _ = reply.send(Response {
        id,
        tokens: Vec::new(),
        ttft_secs: 0.0,
        e2e_secs: 0.0,
        prefill_artifact: String::new(),
        error: Some(RequestError::rejected(why)),
    });
}

/// Answer a request with a `Fatal` response (failover budget spent).
fn fail(reply: &Sender<Response>, id: u64, why: String) {
    let _ = reply.send(Response {
        id,
        tokens: Vec::new(),
        ttft_secs: 0.0,
        e2e_secs: 0.0,
        prefill_artifact: String::new(),
        error: Some(RequestError::fatal(why)),
    });
}

/// Best-effort text of a caught panic payload.
fn panic_text(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Where the TCP front door sends work: one engine's channel (the
/// single-replica deployment, byte-identical to the pre-pool path) or
/// a supervised pool.
#[derive(Clone)]
pub enum Gateway {
    /// a single engine behind a plain message channel
    Direct(Sender<EngineMsg>),
    /// a supervised replica pool
    Pool(PoolHandle),
}

impl Gateway {
    /// Submit a request; the response arrives on `reply` exactly once
    /// (or an error is returned and nothing was accepted).
    pub fn submit(
        &self,
        req: Request,
        reply: Sender<Response>,
    ) -> Result<()> {
        match self {
            Gateway::Direct(tx) => tx
                .send(EngineMsg::Submit(req, reply))
                .map_err(|_| anyhow::anyhow!("engine is gone")),
            Gateway::Pool(h) => h.submit(req, reply),
        }
    }

    /// Begin a graceful shutdown of whatever is behind the gateway:
    /// in-flight and queued work finishes, then the serve loop(s)
    /// exit.
    pub fn begin_shutdown(&self) {
        match self {
            Gateway::Direct(tx) => {
                let _ = tx.send(EngineMsg::Shutdown);
            }
            Gateway::Pool(h) => h.begin_shutdown(),
        }
    }
}
