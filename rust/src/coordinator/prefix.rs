//! Hash-chained prefix cache over block-aligned token prefixes.
//!
//! vLLM-style automatic prefix caching on the paged substrate: every
//! **full** block of a prompt is addressable by the chain hash of the
//! token ids up to that block boundary (a radix-trie keyed by hash
//! instead of pointers). Each cached node is an independent [`BlockPool`]
//! sequence holding the first `depth` blocks of the prompt that
//! registered it — created with [`KvPages::fork_prefix`], so the node is
//! pure refcount accounting and keeps its blocks alive after the
//! registering request completes and releases its own table.
//!
//! Admission flow (driven by the scheduler):
//! 1. [`PrefixCache::lookup`] walks the prompt's block boundaries
//!    deepest-first match, verifies the stored tokens (hashes can
//!    collide), **pins** the hit so eviction cannot race admission,
//!    and returns the node to fork from.
//! 2. The scheduler forks the node's leading blocks into the request's
//!    table, prefills only the uncached suffix, and stages it with
//!    [`KvPages::admit_packed_prefixed`] — copy-on-write handles the
//!    partially-valid boundary block.
//! 3. [`PrefixCache::register`] inserts nodes for the request's own
//!    full blocks (deduplicated by hash), then the scheduler unpins.
//!
//! Eviction is LRU with deepest-first tie-breaking: under block
//! pressure the scheduler calls [`PrefixCache::evict_one`], which
//! releases the least-recently-used unpinned node — preferring the
//! deepest such node, since leaf blocks are the least shared and
//! releasing them actually returns blocks to the free list.
//!
//! [`BlockPool`]: super::paged::BlockPool

use std::collections::HashMap;

use super::kv::KvPages;

/// First sequence id used for cache nodes — far above any realistic
/// client request id, so node tables and request tables share the
/// [`super::paged::BlockPool`] namespace without colliding.
pub const NODE_SEQ_BASE: u64 = 1 << 62;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

fn fnv1a(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Chain-hash step: fold one block's token ids into the parent hash.
fn chain_hash(parent: u64, chunk: &[i32]) -> u64 {
    let mut h = fnv1a(FNV_OFFSET, &parent.to_le_bytes());
    for &t in chunk {
        h = fnv1a(h, &t.to_le_bytes());
    }
    h
}

/// One cached block-aligned prefix (module docs).
#[derive(Debug)]
struct Node {
    /// The pool sequence holding this prefix's blocks.
    seq: u64,
    /// Prefix length in blocks.
    depth: usize,
    /// The exact token prefix — verified on lookup, hashes can collide.
    tokens: Vec<i32>,
    /// Logical-clock timestamp of the last hit/registration (LRU).
    last_use: u64,
    /// In-flight admissions forking from this node; pinned nodes are
    /// never evicted.
    pins: u32,
}

/// A successful [`PrefixCache::lookup`]: fork `cached_tokens` tokens
/// (= `depth_blocks` full blocks) from pool sequence `node_seq`. The
/// node is pinned until [`PrefixCache::unpin`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PrefixHit {
    /// Pool sequence id of the cached node to fork from.
    pub node_seq: u64,
    /// Shared prefix length in blocks.
    pub depth_blocks: usize,
    /// Shared prefix length in tokens (`depth_blocks * block_size`).
    pub cached_tokens: usize,
}

/// Hash-chained radix index over cached block-aligned prefixes
/// (module docs).
pub struct PrefixCache {
    block_size: usize,
    /// chain hash -> node
    nodes: HashMap<u64, Node>,
    /// node seq -> chain hash (for unpin/eviction bookkeeping)
    by_seq: HashMap<u64, u64>,
    next_seq: u64,
    clock: u64,
    evictions: u64,
}

impl PrefixCache {
    /// An empty cache over `block_size`-token blocks.
    pub fn new(block_size: usize) -> PrefixCache {
        PrefixCache {
            block_size: block_size.max(1),
            nodes: HashMap::new(),
            by_seq: HashMap::new(),
            next_seq: NODE_SEQ_BASE,
            clock: 0,
            evictions: 0,
        }
    }

    /// Cached nodes currently held.
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    /// Whether the cache holds no nodes.
    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Nodes evicted over the cache's lifetime.
    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    /// Deepest cached node matching a block-aligned prefix of `tokens`,
    /// if any. Refreshes the LRU stamp of every matched ancestor and
    /// **pins** the returned node — callers must
    /// [`PrefixCache::unpin`] once the fork has happened (or been
    /// abandoned).
    pub fn lookup(&mut self, tokens: &[i32]) -> Option<PrefixHit> {
        self.clock += 1;
        let bs = self.block_size;
        let mut h = 0u64;
        let mut best: Option<(u64, usize)> = None;
        for d in 1..=tokens.len() / bs {
            h = chain_hash(h, &tokens[(d - 1) * bs..d * bs]);
            let Some(node) = self.nodes.get_mut(&h) else {
                break;
            };
            if node.depth != d || node.tokens != tokens[..d * bs] {
                break; // hash collision: treat as a miss from here on
            }
            node.last_use = self.clock;
            best = Some((h, d));
        }
        let (h, d) = best?;
        let node = self.nodes.get_mut(&h).unwrap();
        node.pins += 1;
        Some(PrefixHit {
            node_seq: node.seq,
            depth_blocks: d,
            cached_tokens: d * bs,
        })
    }

    /// Drop the pin taken by [`PrefixCache::lookup`]. Unknown sequences
    /// are ignored (the node may have been evicted after an abandoned
    /// fork — pins only block eviction while nonzero).
    pub fn unpin(&mut self, node_seq: u64) {
        if let Some(h) = self.by_seq.get(&node_seq) {
            if let Some(node) = self.nodes.get_mut(h) {
                node.pins = node.pins.saturating_sub(1);
            }
        }
    }

    /// Register every full-block prefix of `tokens` from the admitted
    /// sequence `owner` (whose block table must cover them): each new
    /// depth forks `owner`'s leading blocks into a fresh node sequence.
    /// Existing nodes are refreshed, not duplicated. Returns the number
    /// of nodes created; fork failures stop registration and are
    /// reported by the caller's invariant checks rather than panicking.
    pub fn register(
        &mut self,
        owner: u64,
        tokens: &[i32],
        kv: &mut KvPages,
    ) -> usize {
        self.clock += 1;
        let bs = self.block_size;
        let mut h = 0u64;
        let mut created = 0usize;
        for d in 1..=tokens.len() / bs {
            h = chain_hash(h, &tokens[(d - 1) * bs..d * bs]);
            if let Some(node) = self.nodes.get_mut(&h) {
                if node.depth == d && node.tokens == tokens[..d * bs] {
                    node.last_use = self.clock;
                } // else: hash collision — keep the incumbent
                continue;
            }
            let seq = self.next_seq;
            if kv.fork_prefix(owner, seq, d).is_err() {
                break; // owner released or pool inconsistency: stop
            }
            self.next_seq += 1;
            self.nodes.insert(
                h,
                Node {
                    seq,
                    depth: d,
                    tokens: tokens[..d * bs].to_vec(),
                    last_use: self.clock,
                    pins: 0,
                },
            );
            self.by_seq.insert(seq, h);
            created += 1;
        }
        created
    }

    /// Evict the least-recently-used unpinned node (deepest first on
    /// ties — leaf blocks are the least shared, so releasing them is
    /// what actually frees memory). Returns the number of blocks
    /// returned to the free list, or `None` when every node is pinned
    /// or the cache is empty.
    pub fn evict_one(&mut self, kv: &mut KvPages) -> Option<usize> {
        let (&h, _) = self
            .nodes
            .iter()
            .filter(|(_, n)| n.pins == 0)
            .min_by_key(|(_, n)| (n.last_use, usize::MAX - n.depth))?;
        let node = self.nodes.remove(&h).unwrap();
        self.by_seq.remove(&node.seq);
        let before = kv.free_blocks();
        // release failure would mean the pool lost the node's table —
        // surfaced by kv.check_invariants() in the suites; the node is
        // forgotten either way so eviction cannot livelock
        let _ = kv.release(node.seq);
        self.evictions += 1;
        Some(kv.free_blocks() - before)
    }

    /// Release every node (serving-loop shutdown), returning tables to
    /// the pool so the final invariant sweep sees a drained allocator.
    pub fn clear(&mut self, kv: &mut KvPages) {
        for (_, node) in self.nodes.drain() {
            let _ = kv.release(node.seq);
        }
        self.by_seq.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kv(n_blocks: usize, bs: usize) -> KvPages {
        KvPages::new(1, n_blocks, bs, 1, 2, n_blocks * bs)
    }

    fn admit(kv: &mut KvPages, seq: u64, len: usize) {
        let pre = vec![0.25f32; len * 2];
        kv.admit_packed(seq, &pre, &pre, 0, len, len, len).unwrap();
    }

    #[test]
    fn register_then_lookup_hits_deepest_block() {
        let mut kv = kv(8, 4);
        let mut pc = PrefixCache::new(4);
        let prompt: Vec<i32> = (1..=10).collect(); // 2 full blocks + 2
        admit(&mut kv, 1, 10);
        assert_eq!(pc.register(1, &prompt, &mut kv), 2);
        assert_eq!(pc.len(), 2);
        kv.release(1).unwrap(); // nodes keep the blocks alive
        kv.check_invariants().unwrap();
        let hit = pc.lookup(&prompt).expect("full prefix cached");
        assert_eq!(hit.depth_blocks, 2);
        assert_eq!(hit.cached_tokens, 8);
        assert!(kv.table(hit.node_seq).is_some());
        // divergence after the first block hits only depth 1
        let mut div = prompt.clone();
        div[5] = 99;
        let shallow = pc.lookup(&div).unwrap();
        assert_eq!(shallow.depth_blocks, 1);
        // divergence in the first block misses entirely
        div[0] = 99;
        assert_eq!(pc.lookup(&div), None);
        // prompts shorter than one block can never hit
        assert_eq!(pc.lookup(&prompt[..3]), None);
        pc.unpin(hit.node_seq);
        pc.unpin(shallow.node_seq);
        pc.clear(&mut kv);
        assert_eq!(kv.free_blocks(), kv.n_blocks());
    }

    #[test]
    fn register_deduplicates_shared_prefixes() {
        let mut kv = kv(8, 4);
        let mut pc = PrefixCache::new(4);
        let a: Vec<i32> = (1..=8).collect();
        let mut b = a.clone();
        b[7] = 77; // shares exactly the first block
        admit(&mut kv, 1, 8);
        admit(&mut kv, 2, 8);
        assert_eq!(pc.register(1, &a, &mut kv), 2);
        assert_eq!(pc.register(2, &b, &mut kv), 1, "block 1 deduped");
        assert_eq!(pc.len(), 3);
        kv.check_invariants().unwrap();
        pc.clear(&mut kv);
        kv.release(1).unwrap();
        kv.release(2).unwrap();
        assert_eq!(kv.free_blocks(), kv.n_blocks());
    }

    #[test]
    fn eviction_is_lru_deepest_first_and_respects_pins() {
        let mut kv = kv(8, 4);
        let mut pc = PrefixCache::new(4);
        let prompt: Vec<i32> = (1..=8).collect();
        admit(&mut kv, 1, 8);
        pc.register(1, &prompt, &mut kv); // depths 1 and 2, same stamp
        kv.release(1).unwrap();
        // deepest-first on the LRU tie: the depth-2 leaf goes first
        let freed = pc.evict_one(&mut kv).unwrap();
        assert_eq!(freed, 1, "leaf block exclusively owned by depth 2");
        assert_eq!(pc.len(), 1);
        assert_eq!(pc.evictions(), 1);
        // pin the survivor: nothing evictable
        let hit = pc.lookup(&prompt).unwrap();
        assert_eq!(hit.depth_blocks, 1);
        assert_eq!(pc.evict_one(&mut kv), None);
        pc.unpin(hit.node_seq);
        assert_eq!(pc.evict_one(&mut kv), Some(1));
        assert_eq!(kv.free_blocks(), kv.n_blocks());
        kv.check_invariants().unwrap();
    }

    #[test]
    fn lookup_refreshes_lru_order() {
        let mut kv = kv(16, 4);
        let mut pc = PrefixCache::new(4);
        let a: Vec<i32> = (1..=4).collect();
        let b: Vec<i32> = (11..=14).collect();
        admit(&mut kv, 1, 4);
        admit(&mut kv, 2, 4);
        pc.register(1, &a, &mut kv);
        pc.register(2, &b, &mut kv); // b newer than a
        kv.release(1).unwrap();
        kv.release(2).unwrap();
        let hit = pc.lookup(&a).unwrap(); // a newest now
        pc.unpin(hit.node_seq);
        pc.evict_one(&mut kv).unwrap(); // evicts b
        assert!(pc.lookup(&b).is_none());
        assert!(pc.lookup(&a).is_some());
        kv.check_invariants().unwrap();
    }
}
