//! The engine loop: continuous batching (iteration-level scheduling)
//! over an execution backend.
//!
//! Each iteration runs **both** kinds of work inside one token budget:
//! pending prefill *chunks* (long prompts split into block-aligned
//! pieces) and the due decode batch. A chunk is just a prefixed prefill
//! whose "cached" prefix is the request's own earlier chunks — the
//! bitwise-pinned PR 6 segment path ([`crate::runtime::Engine::
//! prefill_packed_prefixed`]) — so chunked execution is bitwise
//! identical to one-shot prefill (the `chunk-parity` suite pins this),
//! and a long prompt no longer head-of-line-blocks the short requests
//! and decode steps behind it.
//!
//! Admission is by free **block** count ([`super::paged::BlockPool`])
//! and *on demand*: a request stages only what it has actually
//! computed (its first chunk at admission; later chunks and decode
//! tokens extend the block table as they land), never the old
//! `prompt + max_new_tokens` worst case. When the pool runs dry, the
//! scheduler reclaims blocks instead of blocking: prefix-cache nodes
//! evict first, then the *youngest* block-holding request is preempted
//! (KV released, prompt recomputed from scratch on re-admission at the
//! front of its queue). Preemption is age-ordered — only requests
//! strictly younger than the one that needs blocks are victims — so
//! the oldest request always progresses and admission cannot livelock.
//! Responses only go out at completion and the pipeline is
//! deterministic, so a preempted-and-resumed request is
//! token-identical to an undisturbed run (the scheduler property suite
//! checks this over randomized interleavings).
//!
//! When more sequences are active than the decode artifact's static
//! batch, decode steps the least-advanced sequences first (fair
//! round-robin by generated length, then id); a round-robin cursor
//! over flight configs does the same for prefill chunks.
//!
//! The loop is backend-neutral: it drives a `Box<dyn runtime::Engine>`,
//! so the same scheduler serves the native CPU backend (default) and
//! the PJRT backend (`pjrt` feature), which sees contiguous KV via the
//! default [`crate::runtime::Engine::decode_paged`] gather.

use std::collections::{BTreeMap, HashMap, HashSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::batcher::{routing, BlockBudget, ConfigKey, PrefillQueues};
use super::error::{ErrorKind, RequestError};
use super::fault::{FaultKind, FaultPlan, FaultSite};
use super::kv::KvPages;
use super::paged::DEFAULT_BLOCK;
use super::prefix::PrefixCache;
use super::request::{
    HandedBack, Request, Response, SparsityConfig, Tracked,
};
use crate::metrics::EngineMetrics;
use crate::runtime::{
    Engine as ExecEngine, PrefixedPrompt, SparsityAudit,
};
use crate::tensor::math::argmax;

/// End-of-sequence token id of the synthetic token world.
pub const EOS: i32 = 2;
/// Padding token id.
pub const PAD: i32 = 0;

/// Engine-loop configuration (model, serving shapes, scheduling knobs).
#[derive(Clone)]
pub struct EngineConfig {
    /// model name (manifest key)
    pub model: String,
    /// prefill artifact sequence length to serve
    pub prefill_seq: usize,
    /// flush a partial prefill batch after its head waited this long
    pub max_wait_secs: f64,
    /// stop after this many completed requests (0 = run until channel
    /// closes)
    pub run_until: usize,
    /// width of the execution backend's projection thread pool (the
    /// engine owns the pool; 1 = serial). Defaults to the host's
    /// available parallelism, capped at 8 — results are bit-identical
    /// at every width (see the batch-parity suite).
    pub pool_threads: usize,
    /// tokens per KV block ([`DEFAULT_BLOCK`] unless overridden).
    /// Results are bit-identical at every block size (see the
    /// paged-parity suite); the knob exists for memory-granularity
    /// tuning and tests.
    pub kv_block: usize,
    /// share full prompt-prefix KV blocks across requests through the
    /// radix [`PrefixCache`] (fork at admission, copy-on-write on
    /// divergence, LRU-evicted under block pressure). On by default:
    /// forked-prefix prefill is bit-identical to cold prefill (see the
    /// prefix-parity suite), so the knob only trades KV blocks for
    /// prefill compute.
    pub prefix_cache: bool,
    /// split prompts into prefill chunks of at most this many tokens
    /// (rounded up to whole KV blocks — chunks stage block-by-block).
    /// Each chunk runs as a prefixed prefill over the request's own
    /// earlier chunks and is co-scheduled with the due decode batch.
    /// `usize::MAX` disables chunking (one-shot prefill, the parity
    /// baseline); results are bit-identical at every chunk size (see
    /// the chunk-parity suite), so the knob only trades time-to-first-
    /// token of long prompts against interactivity of everyone else.
    pub chunk_tokens: usize,
    /// per-iteration token budget shared by prefill chunks and the due
    /// decode batch (0 = auto: the prefill artifact's static
    /// `batch x seq` token capacity)
    pub iteration_budget: usize,
    /// override the paged pool's block count (0 = derive from the
    /// decode artifact's `batch x cache` capacity). Deliberately small
    /// pools force the preemption path; the scheduler property suite
    /// uses this.
    pub kv_pool_blocks: usize,
    /// deterministic fault-injection schedule (chaos testing); the
    /// default empty plan is a guaranteed no-op — every check is one
    /// `Vec::is_empty`, and the fault-free parity suites pin that a
    /// no-op plan serves byte-identical tokens
    pub fault_plan: FaultPlan,
    /// opt-in overload control: degrade-then-shed watermarks over the
    /// queued prompt-token backlog (`None` = admit everything, the
    /// default)
    pub degrade_policy: Option<DegradePolicy>,
    /// transient failures tolerated per request before it escalates to
    /// a `Fatal` response
    pub max_retries: u32,
    /// base retry backoff in engine iterations (ticks); doubles per
    /// retry, capped at 64x the base. Deterministic, never wall-clock.
    pub retry_backoff_ticks: u64,
}

/// Overload-control watermarks over the queued prompt-token backlog,
/// checked at admission ([`Engine::submit`]). Past `degrade_at` a new
/// request's N:M config is tightened one rung
/// ([`SparsityConfig::degraded`]) — the paper's training-free ratio
/// flexibility as a shed-compute-before-shedding-requests lever; past
/// `shed_at` new requests are refused with a `Rejected` response.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DegradePolicy {
    /// backlog (queued prompt tokens) at which new requests degrade
    /// one N:M rung (0 disables degradation)
    pub degrade_at: usize,
    /// backlog at which new requests are shed outright (0 disables
    /// shedding)
    pub shed_at: usize,
}

impl EngineConfig {
    /// Defaults for `model`: seq 64, 5 ms max-wait, host parallelism,
    /// [`DEFAULT_BLOCK`]-token KV blocks, 2-block prefill chunks.
    pub fn new(model: &str) -> EngineConfig {
        EngineConfig {
            model: model.to_string(),
            prefill_seq: 64,
            max_wait_secs: 0.005,
            run_until: 0,
            pool_threads: default_pool_threads(),
            kv_block: DEFAULT_BLOCK,
            prefix_cache: true,
            chunk_tokens: 2 * DEFAULT_BLOCK,
            iteration_budget: 0,
            kv_pool_blocks: 0,
            fault_plan: FaultPlan::none(),
            degrade_policy: None,
            max_retries: 3,
            retry_backoff_ticks: 2,
        }
    }
}

fn default_pool_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(8)
}

/// Messages accepted by [`Engine::run`]'s channel.
pub enum EngineMsg {
    /// Enqueue a request; the response goes to the provided sender.
    Submit(Request, Sender<Response>),
    /// Finish remaining work (queued included), then exit the serve
    /// loop.
    Shutdown,
    /// Graceful drain: stop admitting, hand queued/parked work back
    /// through the sender un-replied, finish what is already in
    /// flight, then exit the serve loop. New submits arriving during
    /// the drain are handed back immediately instead of admitted.
    Drain(Sender<HandedBack>),
    /// Chaos hook: panic out of the serve loop, abandoning every
    /// in-flight request *without* a reply — the deterministic stand-in
    /// for a replica process dying. The panic escapes [`Engine::run`]
    /// (it is raised outside the per-step unwind boundary), so a
    /// supervisor observes a dead thread exactly as it would for a
    /// real crash.
    Crash,
    /// Chaos hook: block the serve loop for this many milliseconds
    /// without dying, so heartbeat supervision sees a stalled (not
    /// dead) replica.
    Stall(u64),
}

struct ActiveSeq {
    tracked: Tracked,
    last_token: i32,
    decode_artifact: String,
    decode_binding: String,
    last_token_at: Instant,
}

/// A request mid-chunked-prefill: admitted out of its queue, its KV
/// growing chunk by chunk until the whole (clamped) prompt is staged
/// and it graduates to decode.
struct ChunkFlight {
    tracked: Tracked,
    /// prefill bucket this request was admitted from (chunk batches
    /// group by it, preemption requeues under it)
    key: ConfigKey,
    /// prompt tokens actually served: `min(prompt_len, prefill_seq)`
    clamped_len: usize,
    /// KV rows staged so far (forked cache prefix + executed chunks);
    /// 0 = holds no blocks yet
    done: usize,
}

/// One chunk of the batch being executed this iteration (build-phase
/// bookkeeping; the matching [`PrefixedPrompt`] rides in a parallel
/// vector).
struct BuiltChunk {
    id: u64,
    /// tokens forked from the prefix cache (chunk 1 only, for metrics)
    cached: usize,
    /// KV rows valid before this chunk (cache prefix or earlier chunks)
    cached_now: usize,
    /// pinned donor node to unpin once the chunk is staged
    node: Option<u64>,
    /// chunk 1 (admit) vs continuation (extend)
    first: bool,
}

/// A transiently-failed request waiting out its tick-based retry
/// backoff before re-queuing at the front of its bucket.
struct Parked {
    /// tick at which it re-queues
    ready: u64,
    /// its prefill bucket
    key: ConfigKey,
    tracked: Tracked,
}

/// The serving engine: scheduler state over an execution backend.
pub struct Engine {
    /// engine-loop configuration
    pub cfg: EngineConfig,
    /// the execution backend being scheduled
    pub rt: Box<dyn ExecEngine>,
    /// shared serving metrics
    pub metrics: Arc<EngineMetrics>,
    queues: PrefillQueues,
    /// block-paged KV store (physical blocks + per-sequence tables)
    kv: KvPages,
    /// radix index over cached prompt prefixes; its nodes hold forked
    /// block tables in `kv` until evicted under block pressure
    prefix: PrefixCache,
    /// requests mid-chunked-prefill, in admission (arrival) order
    flight: Vec<ChunkFlight>,
    active: HashMap<u64, ActiveSeq>,
    /// round-robin cursor over decode-artifact groups (fp vs sq decode
    /// differ), so no group starves under sustained mixed-config load
    decode_rr: usize,
    /// round-robin cursor over the flight's config buckets, so no
    /// bucket's chunks starve while another drains a long prompt
    prefill_rr: usize,
    /// the decode artifact's static batch (iteration-budget accounting)
    decode_batch: usize,
    #[allow(dead_code)] // kept for config introspection / tests
    vocab: usize,
    completed: usize,
    /// deterministic iteration counter — the tick clock that drives
    /// deadlines, retry backoff and fault schedules
    tick: u64,
    /// the mutable copy of `cfg.fault_plan` being consumed
    faults: FaultPlan,
    /// transiently-failed requests waiting out their retry backoff
    parked: Vec<Parked>,
    /// set while a graceful drain is in progress: queued/parked work
    /// (and any new submit) is handed back here instead of served
    drain_to: Option<Sender<HandedBack>>,
    /// liveness beacon: [`Engine::run`] stores a fresh value here every
    /// loop iteration so a supervisor can detect a hung loop
    heartbeat: Option<Arc<AtomicU64>>,
}

impl Engine {
    /// Build the engine for `cfg.model`, sizing the paged KV store from
    /// the decode artifact's static shapes (`batch * cache` tokens of
    /// capacity, split into `cfg.kv_block`-token blocks) unless
    /// `cfg.kv_pool_blocks` overrides the block count.
    pub fn new(
        mut rt: Box<dyn ExecEngine>,
        cfg: EngineConfig,
        metrics: Arc<EngineMetrics>,
    ) -> Result<Engine> {
        // the engine owns one projection pool; its width comes from the
        // coordinator config and reaches every batched kernel
        rt.set_parallelism(cfg.pool_threads);
        // geometry from the manifest
        let model = rt
            .manifest()
            .models
            .get(&cfg.model)
            .with_context(|| format!("model {} in manifest", cfg.model))?
            .clone();
        let g = |k: &str| model.config.get(k).copied().unwrap_or(0);
        let dec = rt
            .manifest()
            .artifact(&format!("{}.decode.dense", cfg.model))?
            .clone();
        // prefill batch = the prefill artifact's static batch
        let prefill_batch = rt
            .manifest()
            .artifact(&format!(
                "{}.prefill{}.dense",
                cfg.model, cfg.prefill_seq
            ))
            .map(|a| a.batch)
            .unwrap_or(8)
            .max(1);
        let kv_block = cfg.kv_block.max(1);
        let n_blocks = if cfg.kv_pool_blocks > 0 {
            cfg.kv_pool_blocks
        } else {
            (dec.batch * dec.cache / kv_block).max(1)
        };
        // the per-sequence cap must never exceed what the pool can
        // physically hold (block flooring can shave tokens off the
        // nominal batch*cache capacity)
        let max_seq = dec.cache.min(n_blocks * kv_block);
        let kv = KvPages::new(
            g("n_layers"),
            n_blocks,
            kv_block,
            g("n_kv_heads"),
            g("head_dim"),
            max_seq,
        );
        EngineMetrics::set(&metrics.kv_blocks_total, n_blocks as u64);
        let vocab = g("vocab_size");
        Ok(Engine {
            queues: PrefillQueues::new(prefill_batch, cfg.max_wait_secs),
            prefix: PrefixCache::new(kv_block),
            faults: cfg.fault_plan.clone(),
            cfg,
            rt,
            metrics,
            kv,
            flight: Vec::new(),
            active: HashMap::new(),
            decode_rr: 0,
            prefill_rr: 0,
            decode_batch: dec.batch.max(1),
            vocab,
            completed: 0,
            tick: 0,
            parked: Vec::new(),
            drain_to: None,
            heartbeat: None,
        })
    }

    /// Install a liveness beacon: every [`Engine::run`] loop iteration
    /// stores a monotonically increasing value into `beat`. The loop
    /// beats even when idle (the idle path still polls and steps), so
    /// a beat that stops moving really means a stalled serve loop —
    /// the replica supervisor's missed-heartbeat signal.
    pub fn set_heartbeat(&mut self, beat: Arc<AtomicU64>) {
        self.heartbeat = Some(beat);
    }

    /// Enqueue a request into its config bucket, running admission
    /// control first: past `degrade_policy.shed_at` queued prompt
    /// tokens the request is shed with a `Rejected` response; past
    /// `degrade_at` its sparsity config tightens one rung
    /// ([`SparsityConfig::degraded`]), shedding compute before
    /// shedding requests. A `deadline_ticks` budget resolves to its
    /// absolute expiry tick here.
    pub fn submit(&mut self, mut req: Request, reply: Sender<Response>) {
        if let Some(pol) = self.cfg.degrade_policy {
            let backlog = self.queues.queued_tokens();
            if pol.shed_at > 0 && backlog >= pol.shed_at {
                EngineMetrics::inc(&self.metrics.sheds, 1);
                let t = Tracked {
                    req,
                    arrived: Instant::now(),
                    first_token_at: None,
                    generated: Vec::new(),
                    reply,
                    retries: 0,
                    deadline_at: None,
                };
                self.finish_with_error(
                    t,
                    ErrorKind::Rejected,
                    format!("overloaded: {backlog} queued prompt tokens"),
                );
                return;
            }
            if pol.degrade_at > 0 && backlog >= pol.degrade_at {
                if let Some(d) = req.config.degraded() {
                    EngineMetrics::inc(&self.metrics.degraded, 1);
                    crate::debug_log!(
                        "request {}: degraded {} -> {} at {backlog} \
                         queued tokens",
                        req.id,
                        req.config.label(),
                        d.label()
                    );
                    req.config = d;
                }
            }
        }
        let (prefill, _, _) =
            routing(&self.cfg.model, self.cfg.prefill_seq, &req.config);
        EngineMetrics::inc(&self.metrics.requests_admitted, 1);
        let deadline_at = if req.deadline_ticks > 0 {
            Some(self.tick + req.deadline_ticks)
        } else {
            None
        };
        self.queues.push(
            ConfigKey(prefill),
            Tracked {
                req,
                arrived: Instant::now(),
                first_token_at: None,
                generated: Vec::new(),
                reply,
                retries: 0,
                deadline_at,
            },
        );
    }

    /// Blocking serve loop over a message channel. The prefix cache
    /// deliberately survives loop exit: a later `run` on the same
    /// engine starts warm (see the warm-restart test); use
    /// [`Engine::clear_prefix_cache`] to drain it explicitly.
    ///
    /// [`EngineMsg::Drain`] turns the loop into a graceful drain:
    /// queued and parked requests are handed back un-replied through
    /// the drain sender (see [`HandedBack`]), in-flight work finishes
    /// and replies normally, and the loop exits once empty. Because
    /// the pipeline is deterministic, a handed-back request recomputed
    /// elsewhere is token-identical — drain loses nothing.
    ///
    /// This is also the fault boundary: a panicking or erroring
    /// [`Engine::step`] fails the in-flight requests with `Fatal`
    /// responses and keeps serving — after a panic, only once a
    /// [`Engine::kv_invariants`] self-check passes (a corrupt KV store
    /// aborts the loop with an error instead).
    pub fn run(&mut self, rx: Receiver<EngineMsg>) -> Result<()> {
        let mut open = true;
        loop {
            // liveness beacon: beats every iteration, idle or busy, so
            // a supervisor can tell "hung" from "quiet"
            if let Some(beat) = &self.heartbeat {
                beat.store(self.tick + 1, Ordering::Relaxed);
            }
            // drain incoming messages (non-blocking while work pending)
            let busy = !self.queues.is_empty()
                || !self.active.is_empty()
                || !self.flight.is_empty()
                || !self.parked.is_empty();
            loop {
                let msg = if busy {
                    match rx.try_recv() {
                        Ok(m) => Some(m),
                        Err(_) => None,
                    }
                } else if open {
                    match rx.recv_timeout(Duration::from_millis(50)) {
                        Ok(m) => Some(m),
                        Err(RecvTimeoutError::Timeout) => None,
                        Err(RecvTimeoutError::Disconnected) => {
                            open = false;
                            None
                        }
                    }
                } else {
                    None
                };
                match msg {
                    Some(EngineMsg::Submit(r, tx)) => {
                        if self.drain_to.is_some() {
                            self.hand_back_submit(r, tx);
                        } else {
                            self.submit(r, tx);
                        }
                    }
                    Some(EngineMsg::Shutdown) => open = false,
                    Some(EngineMsg::Drain(tx)) => {
                        self.drain_to = Some(tx);
                        open = false;
                    }
                    Some(EngineMsg::Crash) => {
                        // outside the per-step unwind boundary on
                        // purpose: the panic escapes `run`, the thread
                        // dies, and in-flight requests go unanswered —
                        // a faithful stand-in for a replica crash
                        panic!(
                            "injected replica crash at tick {}",
                            self.tick
                        );
                    }
                    Some(EngineMsg::Stall(ms)) => {
                        crate::warn_log!(
                            "injected stall: serve loop blocked {ms} ms"
                        );
                        std::thread::sleep(Duration::from_millis(ms));
                    }
                    None => break,
                }
            }
            // a drain keeps handing back anything that lands in the
            // queues after the initial sweep (preemptions, woken
            // retries) — in-flight work still finishes normally
            if self.drain_to.is_some() {
                self.hand_back_waiting();
            }
            if !open
                && self.queues.is_empty()
                && self.active.is_empty()
                && self.flight.is_empty()
                && self.parked.is_empty()
            {
                self.drain_to = None;
                return Ok(());
            }
            if self.cfg.run_until > 0
                && self.completed >= self.cfg.run_until
            {
                self.drain_to = None;
                return Ok(());
            }
            // the unwind boundary: one bad request or backend bug must
            // not take the serve loop (and every other client) down
            let stepped = std::panic::catch_unwind(
                std::panic::AssertUnwindSafe(|| self.step()),
            );
            match stepped {
                Ok(Ok(_)) => {}
                Ok(Err(e)) => {
                    crate::warn_log!(
                        "engine step failed: {e:#}; failing in-flight \
                         requests and continuing"
                    );
                    self.fail_in_flight(&format!(
                        "engine step failed: {e}"
                    ));
                }
                Err(payload) => {
                    let msg = panic_message(payload.as_ref());
                    if let Err(inv) = self.kv_invariants() {
                        // corrupt KV store: answer what we can, then
                        // refuse to keep serving on broken state
                        self.fail_in_flight(&format!(
                            "engine panicked: {msg}"
                        ));
                        bail!(
                            "engine panic ({msg}) left the KV store \
                             corrupt: {inv}"
                        );
                    }
                    crate::warn_log!(
                        "engine step panicked ({msg}); KV invariants \
                         hold — failing in-flight requests and \
                         continuing"
                    );
                    self.fail_in_flight(&format!(
                        "engine panicked: {msg}"
                    ));
                }
            }
        }
    }

    /// Fail every admitted request (flight + active) with a `Fatal`
    /// response, releasing KV best-effort. The backstop after a step
    /// error or caught panic: those requests' states are
    /// unrecoverable, but queued and future requests keep being
    /// served.
    fn fail_in_flight(&mut self, reason: &str) {
        let flight = std::mem::take(&mut self.flight);
        for f in flight {
            let id = f.tracked.req.id;
            if self.kv.table(id).is_some() {
                let _ = self.kv.release(id);
            }
            self.finish_with_error(
                f.tracked,
                ErrorKind::Fatal,
                reason.to_string(),
            );
        }
        let mut ids: Vec<u64> = self.active.keys().copied().collect();
        ids.sort_unstable();
        for id in ids {
            let Some(a) = self.active.remove(&id) else { continue };
            if self.kv.table(id).is_some() {
                let _ = self.kv.release(id);
            }
            self.finish_with_error(
                a.tracked,
                ErrorKind::Fatal,
                reason.to_string(),
            );
        }
        self.publish_paging();
    }

    /// Refuse a submit that arrived mid-drain: it goes straight back
    /// out through the drain sender, never into the queues.
    fn hand_back_submit(&mut self, req: Request, reply: Sender<Response>) {
        let id = req.id;
        let Some(tx) = self.drain_to.clone() else { return };
        EngineMetrics::inc(&self.metrics.replica_handbacks, 1);
        let hb = HandedBack { req, reply, retries: 0 };
        if let Err(back) = tx.send(hb) {
            // drain receiver gone: nobody can re-dispatch this request,
            // so answer it here rather than lose it
            let hb = back.0;
            crate::warn_log!(
                "request {id}: drain hand-back receiver dropped; \
                 failing the request"
            );
            let t = Tracked {
                req: hb.req,
                arrived: Instant::now(),
                first_token_at: None,
                generated: Vec::new(),
                reply: hb.reply,
                retries: hb.retries,
                deadline_at: None,
            };
            self.finish_with_error(
                t,
                ErrorKind::Fatal,
                "drain hand-back receiver dropped".into(),
            );
        }
    }

    /// The drain sweep: empty the prefill queues and the retry park,
    /// handing every waiting request back un-replied (oldest arrival
    /// first) through the drain sender. Runs every loop iteration
    /// while a drain is active, so work that re-enters the queues
    /// mid-drain (preemptions, woken retries) is handed back too.
    fn hand_back_waiting(&mut self) {
        let Some(tx) = self.drain_to.clone() else { return };
        let mut waiting = self.queues.drain_all();
        for p in std::mem::take(&mut self.parked) {
            waiting.push(p.tracked);
        }
        if waiting.is_empty() {
            return;
        }
        waiting.sort_by_key(|t| (t.arrived, t.req.id));
        for t in waiting {
            let id = t.req.id;
            EngineMetrics::inc(&self.metrics.replica_handbacks, 1);
            let arrived = t.arrived;
            let hb = HandedBack {
                req: t.req,
                reply: t.reply,
                retries: t.retries,
            };
            if let Err(back) = tx.send(hb) {
                let hb = back.0;
                crate::warn_log!(
                    "request {id}: drain hand-back receiver dropped; \
                     failing the request"
                );
                let t = Tracked {
                    req: hb.req,
                    arrived,
                    first_token_at: None,
                    generated: Vec::new(),
                    reply: hb.reply,
                    retries: hb.retries,
                    deadline_at: None,
                };
                self.finish_with_error(
                    t,
                    ErrorKind::Fatal,
                    "drain hand-back receiver dropped".into(),
                );
            }
        }
    }

    /// One scheduling iteration: run due prefill chunks *and* the due
    /// decode batch inside one token budget. Returns whether any work
    /// was done.
    ///
    /// Each call advances the engine's deterministic tick clock, which
    /// drives request deadlines, retry backoff and the fault schedule
    /// — iteration counts, never wall-clock time.
    pub fn step(&mut self) -> Result<bool> {
        self.tick += 1;
        self.expire_and_wake();
        let idle = self.active.is_empty() && self.flight.is_empty();
        let now = Instant::now();
        let chunk = self.effective_chunk();
        // iteration token budget: prefill chunks share the iteration
        // with the due decode batch, so the chunk share shrinks by the
        // decode rows about to run
        let budget = if self.cfg.iteration_budget > 0 {
            self.cfg.iteration_budget
        } else {
            self.queues.max_batch * self.cfg.prefill_seq
        };
        let decode_due = self.active.len().min(self.decode_batch);
        let chunk_budget = budget.saturating_sub(decode_due).max(1);
        let prefilled =
            self.run_prefill_chunks(chunk, chunk_budget, idle, now)?;
        // decode advances every iteration it has work — prefill chunks
        // no longer monopolize the loop
        let decoded = if self.active.is_empty() {
            false
        } else {
            self.run_decode()?
        };
        Ok(prefilled || decoded)
    }

    /// Top-of-iteration sweep: cancel queued and parked requests past
    /// their deadlines (`Rejected`, one response each) and move
    /// backed-off requests whose retry tick has come to the front of
    /// their queues, oldest arrival frontmost.
    fn expire_and_wake(&mut self) {
        let tick = self.tick;
        for t in self.queues.take_expired(tick) {
            EngineMetrics::inc(&self.metrics.timeouts, 1);
            self.finish_with_error(
                t,
                ErrorKind::Rejected,
                "deadline exceeded while queued".into(),
            );
        }
        if self.parked.is_empty() {
            return;
        }
        let parked = std::mem::take(&mut self.parked);
        let mut wake: Vec<Parked> = Vec::new();
        for p in parked {
            if p.tracked.deadline_at.is_some_and(|d| d < tick) {
                EngineMetrics::inc(&self.metrics.timeouts, 1);
                self.finish_with_error(
                    p.tracked,
                    ErrorKind::Rejected,
                    "deadline exceeded during retry backoff".into(),
                );
            } else if p.ready <= tick {
                wake.push(p);
            } else {
                self.parked.push(p);
            }
        }
        // push_front in reverse age order leaves the oldest frontmost
        wake.sort_by_key(|p| (p.tracked.arrived, p.tracked.req.id));
        for p in wake.into_iter().rev() {
            self.queues.push_front(p.key, p.tracked);
        }
    }

    /// Consult the fault plan at `site` for the current tick, counting
    /// and logging any injection that fires. A `Panic` injection
    /// panics right here, exercising the [`Engine::run`] unwind
    /// boundary.
    fn fire(&mut self, site: FaultSite) -> Option<FaultKind> {
        if self.faults.is_noop() {
            return None; // the fault-free fast path
        }
        let kind = self.faults.fire(self.tick, site)?;
        EngineMetrics::inc(&self.metrics.faults_injected, 1);
        crate::warn_log!(
            "injected fault at tick {}: {site:?} {kind:?}",
            self.tick
        );
        if kind == FaultKind::Panic {
            panic!("injected panic at tick {} ({site:?})", self.tick);
        }
        Some(kind)
    }

    /// Best-effort response delivery: a vanished client (dropped
    /// receiver) is logged and skipped — never a panic, never a dead
    /// serve loop. Consults the fault plan's `ReplySend` site first.
    fn send_reply(
        &mut self,
        id: u64,
        reply: &Sender<Response>,
        resp: Response,
    ) {
        if self.fire(FaultSite::ReplySend).is_some() {
            crate::warn_log!(
                "request {id}: reply dropped by injected fault"
            );
            return;
        }
        if reply.send(resp).is_err() {
            crate::warn_log!(
                "request {id}: client disconnected; response dropped"
            );
        }
    }

    /// Terminal error reply: record latency metrics, count the
    /// request completed (it will never be scheduled again) and send a
    /// best-effort `Response` carrying `kind`, `reason` and any tokens
    /// generated before the failure.
    fn finish_with_error(
        &mut self,
        t: Tracked,
        kind: ErrorKind,
        reason: String,
    ) {
        let now = Instant::now();
        let e2e = now.duration_since(t.arrived).as_secs_f64();
        self.metrics.observe_e2e(e2e);
        EngineMetrics::inc(&self.metrics.requests_completed, 1);
        self.completed += 1;
        let ttft = t
            .first_token_at
            .map(|f| f.duration_since(t.arrived).as_secs_f64())
            .unwrap_or(0.0);
        let id = t.req.id;
        crate::debug_log!(
            "request {id} failed ({}): {reason}",
            kind.label()
        );
        let resp = Response {
            id,
            tokens: t.generated,
            ttft_secs: ttft,
            e2e_secs: e2e,
            prefill_artifact: String::new(),
            error: Some(RequestError { kind, reason }),
        };
        self.send_reply(id, &t.reply, resp);
    }

    /// Transient-failure path: release the request's KV, clear its
    /// generated tokens and park it under tick-based exponential
    /// backoff (base `retry_backoff_ticks`, doubling per retry, capped
    /// at 64x) before it re-queues at the front of its bucket — the
    /// same deterministic recompute-from-scratch machinery as
    /// preemption, so a retried request is token-identical to an
    /// undisturbed run. After `max_retries` failures it escalates to
    /// `Fatal`.
    fn fail_transient(&mut self, id: u64, reason: &str) -> Result<()> {
        let mut t = if let Some(a) = self.active.remove(&id) {
            a.tracked
        } else if let Some(p) = self
            .flight
            .iter()
            .position(|f| f.tracked.req.id == id)
        {
            self.flight.remove(p).tracked
        } else {
            bail!("transient failure of unknown request {id}");
        };
        if self.kv.table(id).is_some() {
            self.kv.release(id)?;
        }
        self.publish_paging();
        t.generated.clear();
        t.retries += 1;
        if t.retries > self.cfg.max_retries {
            let n = t.retries - 1;
            self.finish_with_error(
                t,
                ErrorKind::Fatal,
                format!(
                    "giving up after {n} transient failures: {reason}"
                ),
            );
            return Ok(());
        }
        EngineMetrics::inc(&self.metrics.retries, 1);
        let base = self.cfg.retry_backoff_ticks.max(1);
        let backoff = base << (t.retries - 1).min(6);
        crate::debug_log!(
            "request {id}: transient failure ({reason}); retry {} in \
             {backoff} tick(s)",
            t.retries
        );
        let (prefill, _, _) = routing(
            &self.cfg.model,
            self.cfg.prefill_seq,
            &t.req.config,
        );
        self.parked.push(Parked {
            ready: self.tick + backoff,
            key: ConfigKey(prefill),
            tracked: t,
        });
        Ok(())
    }

    /// The serving chunk size: `cfg.chunk_tokens` rounded up to a
    /// whole number of KV blocks; `usize::MAX` = one-shot.
    fn effective_chunk(&self) -> usize {
        let c = self.cfg.chunk_tokens;
        if c == usize::MAX {
            return usize::MAX;
        }
        let bs = self.kv.block_size().max(1);
        c.max(1).div_ceil(bs) * bs
    }

    fn block_budget(&self) -> BlockBudget {
        BlockBudget {
            free_blocks: self.kv.free_blocks(),
            total_blocks: self.kv.n_blocks(),
            block_size: self.kv.block_size(),
            max_seq_tokens: self.kv.max_seq_tokens,
        }
    }

    /// Admit due requests into the flight and run one config bucket's
    /// next chunks as a single packed (possibly prefixed) prefill
    /// batch. Returns whether a batch executed.
    fn run_prefill_chunks(
        &mut self,
        chunk: usize,
        max_tokens: usize,
        idle: bool,
        now: Instant,
    ) -> Result<bool> {
        // fault hook: `Delay` stalls the whole prefill phase one tick;
        // `Fail` makes this tick's batch execution (if any) error into
        // the transient-retry path. Consulted only when prefill work
        // could actually run.
        let mut fail_exec = false;
        if !self.flight.is_empty() || !self.queues.is_empty() {
            match self.fire(FaultSite::PrefillChunk) {
                Some(FaultKind::Delay) => return Ok(false),
                Some(_) => fail_exec = true,
                None => {}
            }
        }
        let seq_cap = self.cfg.prefill_seq;
        let mut blocks = self.block_budget();
        // prefix-cache nodes hold KV blocks; under pressure they yield
        // to admissions. Evict (LRU, deepest-first on ties) until the
        // largest queue-head *first chunk* fits the free list — not
        // the one-shot worst case: later chunks grow on demand and
        // reclaim covers pressure.
        if let Some(need) = self
            .queues
            .max_head_chunk_demand(&blocks, seq_cap, chunk)
        {
            while self.kv.free_blocks() < need
                && self.prefix.evict_one(&mut self.kv).is_some()
            {}
            blocks.free_blocks = self.kv.free_blocks();
            self.publish_prefix();
        }
        // admission: move one due bucket into the flight, costed by
        // first chunks. Members run below in admission (arrival) order.
        if let Some((key, batch)) = self.queues.next_chunk_batch(
            blocks, seq_cap, chunk, max_tokens, idle, now,
        ) {
            for t in batch {
                let clamped_len = t.req.prompt.len().min(seq_cap);
                self.flight.push(ChunkFlight {
                    key: key.clone(),
                    clamped_len,
                    done: 0,
                    tracked: t,
                });
            }
        }
        if self.flight.is_empty() {
            return Ok(false);
        }
        // rotate over the distinct config buckets in flight so no
        // bucket's chunks starve behind another's long prompt
        let mut keys: Vec<ConfigKey> = Vec::new();
        for f in &self.flight {
            if !keys.contains(&f.key) {
                keys.push(f.key.clone());
            }
        }
        let key = keys[self.prefill_rr % keys.len()].clone();
        self.prefill_rr = self.prefill_rr.wrapping_add(1);
        let member_ids: Vec<u64> = self
            .flight
            .iter()
            .filter(|f| f.key == key)
            .map(|f| f.tracked.req.id)
            .collect();

        // Build phase — each member contributes its next chunk until
        // the token budget cuts. Chunk 1 does the prefix-cache lookup
        // and fork (the only chunk that can be cache-warm); every
        // chunk's prefix K/V is gathered from the request's own table,
        // so a continuation chunk attends over its earlier chunks
        // exactly as a warm request attends over a donor's blocks.
        let mut built: Vec<BuiltChunk> = Vec::new();
        let mut reqs: Vec<PrefixedPrompt> = Vec::new();
        let mut toks = 0usize;
        let mut cfg0: Option<SparsityConfig> = None;
        for id in member_ids {
            let Some(fpos) = self
                .flight
                .iter()
                .position(|f| f.tracked.req.id == id)
            else {
                continue; // preempted while reclaiming below
            };
            let (done0, clamped_len, arrived, deadline_at, config) = {
                let f = &self.flight[fpos];
                (
                    f.done,
                    f.clamped_len,
                    f.tracked.arrived,
                    f.tracked.deadline_at,
                    f.tracked.req.config,
                )
            };
            // chunk-boundary deadline check: an expired request stops
            // consuming prefill budget right here
            if deadline_at.is_some_and(|d| d < self.tick) {
                let f = self.flight.remove(fpos);
                if self.kv.table(id).is_some() {
                    let _ = self.kv.release(id);
                }
                self.publish_paging();
                EngineMetrics::inc(&self.metrics.timeouts, 1);
                self.finish_with_error(
                    f.tracked,
                    ErrorKind::Rejected,
                    "deadline exceeded during chunked prefill".into(),
                );
                continue;
            }
            let prompt = self.flight[fpos].tracked.req.prompt.clone();
            let target = clamped_len.max(1);
            // worst-case length before the (possibly warm) lookup —
            // budget-cut here so nothing needs undoing on a break
            if !built.is_empty()
                && toks + (target - done0).min(chunk) > max_tokens
            {
                break;
            }
            let mut node = None;
            let mut cached = 0usize;
            if done0 == 0 && self.cfg.prefix_cache && clamped_len > 0 {
                let clamped = &prompt[..clamped_len];
                if let Some(hit) = self.prefix.lookup(clamped) {
                    // at least one suffix token always recomputes: the
                    // last prompt row must be live to sample from
                    let c = hit.cached_tokens.min(clamped_len - 1);
                    if c > 0
                        && self
                            .kv
                            .fork_prefix(
                                hit.node_seq,
                                id,
                                self.kv.blocks_for(c),
                            )
                            .is_ok()
                    {
                        node = Some(hit.node_seq);
                        cached = c;
                    } else {
                        self.prefix.unpin(hit.node_seq);
                    }
                }
            }
            let cached_now = if done0 == 0 { cached } else { done0 };
            let len = (target - cached_now).min(chunk);
            // block demand of staging this chunk: table growth plus
            // one copy-on-write block when a warm prefix ends mid-block
            let bs = self.kv.block_size();
            let table_len =
                self.kv.table(id).map(|t| t.len()).unwrap_or(0);
            let mut need = (cached_now + len)
                .div_ceil(bs)
                .saturating_sub(table_len);
            if done0 == 0 && cached > 0 && cached % bs != 0 {
                need += 1;
            }
            if need > self.kv.free_blocks() {
                let undo = |eng: &mut Engine| {
                    if cached > 0 {
                        let _ = eng.kv.release(id);
                    }
                    if let Some(n) = node {
                        eng.prefix.unpin(n);
                    }
                };
                if need > self.kv.n_blocks() {
                    // cannot fit even an emptied pool: unservable
                    undo(self);
                    self.reject_flight(
                        id,
                        "chunk demand exceeds the block pool",
                    )?;
                    continue;
                }
                if !built.is_empty() {
                    // only the batch head preempts; later members wait
                    undo(self);
                    break;
                }
                let mut protect: HashSet<u64> = HashSet::new();
                protect.insert(id);
                if !self.reclaim_blocks(need, (arrived, id), &protect)? {
                    // every holder is as old or older: they complete
                    // and free blocks; retry next iteration
                    undo(self);
                    break;
                }
            }
            let (pk, pv) = if cached_now > 0 {
                self.kv.gather_seq(id, cached_now).with_context(|| {
                    format!("gather of seq {id}'s chunk prefix")
                })?
            } else {
                (Vec::new(), Vec::new())
            };
            let upto = (cached_now + len).min(clamped_len);
            reqs.push(PrefixedPrompt {
                tokens: prompt[..upto].to_vec(),
                cached_len: cached_now,
                prefix_k: pk,
                prefix_v: pv,
            });
            built.push(BuiltChunk {
                id,
                cached,
                cached_now,
                node,
                first: done0 == 0,
            });
            if cfg0.is_none() {
                cfg0 = Some(config);
            }
            toks += len;
        }
        if built.is_empty() {
            return Ok(false);
        }

        // Execute phase — bind and run the batch. Weight binding comes
        // from the first member's config (a bucket shares it by
        // construction). An all-cold batch takes the plain packed path:
        // byte-for-byte the route a chunking- and prefix-cache-disabled
        // engine takes.
        let artifact = key.0.clone();
        let Some(cfg0) = cfg0 else { return Ok(false) };
        let (_, decode_artifact, files) =
            routing(&self.cfg.model, seq_cap, &cfg0);
        let file_refs: Vec<&str> =
            files.iter().map(|f| f.as_str()).collect();
        let binding = self.rt.bind(&artifact, &file_refs)?;
        let dec_files = vec![file_refs[0]];
        let dec_binding = self.rt.bind(&decode_artifact, &dec_files)?;
        // binds above are where weight preparation (panel packing +
        // cached quantization) happens; refresh the prep gauges
        self.publish_prep();
        let any_warm = built.iter().any(|b| b.cached_now > 0);
        let ran = if fail_exec {
            Err(anyhow::anyhow!(
                "injected prefill failure at tick {}",
                self.tick
            ))
        } else if any_warm {
            self.rt.prefill_packed_prefixed(&artifact, &binding, &reqs)
        } else {
            let prompts: Vec<Vec<i32>> =
                reqs.into_iter().map(|r| r.tokens).collect();
            self.rt.prefill_packed(&artifact, &binding, &prompts)
        };
        let out = match ran {
            Ok(out) => out,
            Err(e) => {
                // a failed batch fails *transiently*: every member
                // releases its KV (forked prefixes included), unpins
                // its donor node and parks for a backed-off retry —
                // the loop keeps serving everyone else
                let msg = format!("prefill batch failed: {e}");
                for b in &built {
                    if let Some(n) = b.node {
                        self.prefix.unpin(n);
                    }
                }
                let ids: Vec<u64> =
                    built.iter().map(|b| b.id).collect();
                for id in ids {
                    self.fail_transient(id, &msg)?;
                }
                return Ok(true);
            }
        };
        let total = out.total_tokens();
        EngineMetrics::inc(&self.metrics.prefill_tokens, total as u64);
        // 0 on the native shape-flexible pipeline; the real padding
        // cost on backends using the pad-and-gather default (PJRT)
        EngineMetrics::inc(
            &self.metrics.padded_prefill_tokens,
            out.padded_tokens as u64,
        );
        EngineMetrics::inc(&self.metrics.prefill_batches, 1);
        EngineMetrics::inc(
            &self.metrics.prefill_chunks,
            built.len() as u64,
        );

        // Stage phase — land each chunk's KV, then either keep the
        // request in flight (more chunks to come) or graduate it to
        // decode with its first sampled token.
        let now = Instant::now();
        // the KvAlloc fault site: prefill staging consults it first
        // (decode capacity assurance gets it only on ticks where no
        // chunk stages); at most one member's allocation fails
        let mut kv_fault = self.fire(FaultSite::KvAlloc);
        let mut start = 0usize; // packed row offset of chunk i
        for (i, b) in built.iter().enumerate() {
            let len = out.lens[i];
            if kv_fault.take().is_some() {
                // injected allocation failure: this member's staging
                // fails before touching the store — transient retry,
                // everyone else in the batch stages normally
                if let Some(n) = b.node {
                    self.prefix.unpin(n);
                }
                self.fail_transient(
                    b.id,
                    "injected KV allocation failure",
                )?;
                start += len;
                continue;
            }
            let staged = if !b.first {
                self.kv.extend_packed(
                    b.id,
                    &out.k_cache,
                    &out.v_cache,
                    start,
                    total,
                    len,
                )
            } else if b.cached > 0 {
                self.kv.admit_packed_prefixed(
                    b.id,
                    &out.k_cache,
                    &out.v_cache,
                    start,
                    total,
                    b.cached,
                    len,
                    b.cached + len,
                )
            } else {
                // on-demand reservation: exactly the staged tokens —
                // decode and later chunks extend the table themselves
                self.kv.admit_packed(
                    b.id,
                    &out.k_cache,
                    &out.v_cache,
                    start,
                    total,
                    len,
                    len,
                )
            };
            start += len;
            if let Err(err) = staged {
                // unservable request (e.g. a prompt longer than the KV
                // cap on a misconfigured manifest): fail it ALONE,
                // never the whole serve loop
                if let Some(n) = b.node {
                    self.prefix.unpin(n);
                }
                self.reject_flight(b.id, &format!("{err}"))?;
                continue;
            }
            // reuse accounting only counts admissions actually served
            if b.cached > 0 {
                EngineMetrics::inc(
                    &self.metrics.prefix_hit_blocks,
                    self.kv.blocks_for(b.cached) as u64,
                );
                EngineMetrics::inc(
                    &self.metrics.prefix_hit_tokens,
                    b.cached as u64,
                );
            }
            if let Some(n) = b.node {
                self.prefix.unpin(n);
            }
            let Some(fpos) = self
                .flight
                .iter()
                .position(|f| f.tracked.req.id == b.id)
            else {
                crate::warn_log!(
                    "request {}: vanished from flight mid-stage",
                    b.id
                );
                continue;
            };
            let done_after = b.cached_now + len;
            self.flight[fpos].done = done_after;
            if done_after < self.flight[fpos].clamped_len.max(1) {
                continue; // more chunks to come
            }
            // final chunk: greedy first token from the last prompt row
            // (an empty prompt — rejected at the TCP layer, but defend
            // the engine too — occupies one PAD row and scores from it)
            let mut f = self.flight.remove(fpos);
            let row = &out.logits
                [(start - 1) * out.vocab..start * out.vocab];
            let first = argmax(row) as i32;
            // a preempted-and-resumed request keeps its original TTFT
            if f.tracked.first_token_at.is_none() {
                f.tracked.first_token_at = Some(now);
                self.metrics.observe_ttft(
                    now.duration_since(f.tracked.arrived).as_secs_f64(),
                );
            }
            f.tracked.generated.push(first);
            // publish this prompt's own full blocks back into the
            // cache before maybe_complete: an immediately-finished
            // request still seeds the cache for followers
            if self.cfg.prefix_cache {
                let clamped =
                    f.tracked.req.prompt[..f.clamped_len].to_vec();
                self.prefix.register(b.id, &clamped, &mut self.kv);
            }
            self.active.insert(
                b.id,
                ActiveSeq {
                    tracked: f.tracked,
                    last_token: first,
                    decode_artifact: decode_artifact.clone(),
                    decode_binding: dec_binding.clone(),
                    last_token_at: now,
                },
            );
            // immediately-finished sequences (max_new_tokens == 1/EOS)
            self.maybe_complete(b.id)?;
        }
        self.publish_paging();
        self.publish_frag();
        self.publish_prefix();
        Ok(true)
    }

    /// Fail one admitted request alone (unservable chunk — e.g. a
    /// demand exceeding the whole block pool) with a `Rejected`
    /// response; the serve loop and the rest of the batch continue.
    fn reject_flight(&mut self, id: u64, err: &str) -> Result<()> {
        crate::warn_log!("request {id} rejected by KV admission: {err}");
        let Some(p) = self
            .flight
            .iter()
            .position(|f| f.tracked.req.id == id)
        else {
            return Ok(());
        };
        let f = self.flight.remove(p);
        if self.kv.table(id).is_some() {
            let _ = self.kv.release(id);
        }
        self.finish_with_error(
            f.tracked,
            ErrorKind::Rejected,
            format!("KV admission rejected the request: {err}"),
        );
        self.publish_paging();
        Ok(())
    }

    /// Evict a request's KV blocks and send it back to the *front* of
    /// its prefill queue. Generated tokens are discarded and the
    /// prompt recomputes from chunk 1 on re-admission — the pipeline
    /// is deterministic and responses only go out at completion, so
    /// preemption is invisible to the client except as latency.
    fn preempt(&mut self, id: u64) -> Result<()> {
        let mut t = if let Some(a) = self.active.remove(&id) {
            a.tracked
        } else if let Some(p) = self
            .flight
            .iter()
            .position(|f| f.tracked.req.id == id)
        {
            self.flight.remove(p).tracked
        } else {
            bail!("preempt of unknown request {id}");
        };
        if self.kv.table(id).is_some() {
            self.kv.release(id)?;
        }
        t.generated.clear();
        EngineMetrics::inc(&self.metrics.preemptions, 1);
        let (prefill, _, _) =
            routing(&self.cfg.model, self.cfg.prefill_seq, &t.req.config);
        self.queues.push_front(ConfigKey(prefill), t);
        self.publish_paging();
        Ok(())
    }

    /// The youngest block-holding request strictly younger (by
    /// arrival, then id) than `than`, excluding `protect`. Age-ordered
    /// preemption: the oldest request can always grow, so the loop
    /// cannot livelock.
    fn preemption_victim(
        &self,
        than: (Instant, u64),
        protect: &HashSet<u64>,
    ) -> Option<u64> {
        let mut best: Option<(Instant, u64)> = None;
        {
            let mut consider = |arrived: Instant, id: u64| {
                if protect.contains(&id) {
                    return;
                }
                let p = (arrived, id);
                if p > than && best.is_none_or(|b| p > b) {
                    best = Some(p);
                }
            };
            for (id, a) in &self.active {
                consider(a.tracked.arrived, *id);
            }
            for f in &self.flight {
                if f.done > 0 {
                    consider(f.tracked.arrived, f.tracked.req.id);
                }
            }
        }
        best.map(|(_, id)| id)
    }

    /// Free blocks until at least `need` are available: prefix-cache
    /// nodes evict first (cached blocks are pure opportunism), then
    /// the youngest request younger than `than` is preempted. Returns
    /// `false` when neither source can help — every holder is
    /// `protect`ed or at least as old — leaving the caller to skip
    /// and retry once they complete.
    fn reclaim_blocks(
        &mut self,
        need: usize,
        than: (Instant, u64),
        protect: &HashSet<u64>,
    ) -> Result<bool> {
        let ok = loop {
            if self.kv.free_blocks() >= need {
                break true;
            }
            if self.prefix.evict_one(&mut self.kv).is_some() {
                continue;
            }
            match self.preemption_victim(than, protect) {
                Some(v) => self.preempt(v)?,
                None => break false,
            }
        };
        self.publish_prefix();
        Ok(ok)
    }

    fn run_decode(&mut self) -> Result<bool> {
        // decode-turn deadline sweep: an expired sequence answers now
        // with whatever it generated (partial tokens, `Rejected`) and
        // releases its blocks before this tick's batch forms
        let mut expired: Vec<u64> = self
            .active
            .iter()
            .filter(|(_, a)| {
                a.tracked.deadline_at.is_some_and(|d| d < self.tick)
            })
            .map(|(id, _)| *id)
            .collect();
        expired.sort_unstable();
        let any_expired = !expired.is_empty();
        for id in expired {
            let Some(a) = self.active.remove(&id) else { continue };
            if self.kv.table(id).is_some() {
                let _ = self.kv.release(id);
            }
            self.publish_paging();
            EngineMetrics::inc(&self.metrics.timeouts, 1);
            self.finish_with_error(
                a.tracked,
                ErrorKind::Rejected,
                "deadline exceeded during decode".into(),
            );
        }
        if self.active.is_empty() {
            return Ok(any_expired);
        }
        // fault hook: `Delay` stalls this tick's decode batch one
        // iteration; `Fail` errors the batch execution below into the
        // transient-retry path
        let mut fail_exec = false;
        match self.fire(FaultSite::DecodeStep) {
            Some(FaultKind::Delay) => return Ok(any_expired),
            Some(_) => fail_exec = true,
            None => {}
        }
        // group by decode artifact (fp vs sq); BTreeMap so group order
        // is deterministic (HashMap iteration varies run to run, and
        // W8A8 logits depend on batch composition), and a round-robin
        // cursor over the sorted groups so none starves when several
        // stay populated under sustained load
        let mut by_art: BTreeMap<(String, String), Vec<u64>> =
            BTreeMap::new();
        for (id, a) in &self.active {
            by_art
                .entry((
                    a.decode_artifact.clone(),
                    a.decode_binding.clone(),
                ))
                .or_default()
                .push(*id);
        }
        if by_art.is_empty() {
            return Ok(false);
        }
        let pick = self.decode_rr % by_art.len();
        self.decode_rr = self.decode_rr.wrapping_add(1);
        let Some(((artifact, binding), ids)) =
            by_art.into_iter().nth(pick)
        else {
            return Ok(false);
        };
        let meta = self.rt.manifest().artifact(&artifact)?.clone();
        let b = meta.batch;
        // a sequence whose KV hit the per-sequence cap cannot take
        // another token: finish it with what it has (the cap is the
        // decode cache — only reachable when a request's generation
        // budget exceeds what the cache can hold)
        let cap = self.kv.max_seq_tokens;
        let (step_ids, full_ids): (Vec<u64>, Vec<u64>) = ids
            .into_iter()
            .partition(|id| self.kv.seq_len(*id).unwrap_or(0) < cap);
        let forced = !full_ids.is_empty();
        for id in full_ids {
            self.complete(id)?;
        }
        let mut ids = step_ids;
        if ids.is_empty() {
            return Ok(forced);
        }
        // paged KV admits more concurrent sequences than the decode
        // artifact's static batch; step the least-advanced first so
        // nobody starves (deterministic: generated length, then id)
        if ids.len() > b {
            ids.sort_unstable_by_key(|id| {
                (self.active[id].tracked.generated.len(), *id)
            });
            ids.truncate(b);
        }
        // assure KV capacity oldest-first, reclaiming blocks (prefix
        // eviction, then preemption of strictly younger requests)
        // under pressure: age always progresses, and a preempted
        // victim simply drops out of this step
        ids.sort_unstable_by_key(|id| {
            (self.active[id].tracked.arrived, *id)
        });
        let mut assured: Vec<u64> = Vec::new();
        // the KvAlloc fault site (when prefill staging left it unfired
        // this tick): one sequence's capacity assurance fails
        let mut kv_fault = self.fire(FaultSite::KvAlloc);
        for id in ids {
            if !self.active.contains_key(&id) {
                continue; // preempted while reclaiming for an older one
            }
            if kv_fault.take().is_some() {
                self.fail_transient(
                    id,
                    "injected KV allocation failure",
                )?;
                continue;
            }
            let len = self
                .kv
                .seq_len(id)
                .with_context(|| format!("seq {id} missing from KV"))?;
            let bs = self.kv.block_size();
            let table_len =
                self.kv.table(id).map(|t| t.len()).unwrap_or(0);
            // append lands at position `len`: a fresh tail block when
            // `len` crosses a boundary, plus one copy-on-write block
            // when the target block is still shared (cached prefix)
            let mut need =
                (len + 1).div_ceil(bs).saturating_sub(table_len);
            if self.kv.is_shared(id, len) {
                need += 1;
            }
            if need > self.kv.free_blocks() {
                let mut protect: HashSet<u64> =
                    assured.iter().copied().collect();
                protect.insert(id);
                let than = (self.active[&id].tracked.arrived, id);
                if !self.reclaim_blocks(need, than, &protect)? {
                    // every holder is as old or older: skip this
                    // sequence for the iteration; it retries once
                    // they complete and free blocks
                    continue;
                }
            }
            self.kv.ensure_capacity(id, len + 1)?;
            self.kv.make_writable(id, len)?;
            assured.push(id);
        }
        if assured.is_empty() {
            return Ok(forced);
        }
        assured.sort_unstable(); // determinism of row assignment
        let ids = assured;
        let mut token = vec![PAD; b];
        let mut pos = vec![0i32; b];
        let mut kv_len = vec![1i32; b];
        let mut rows: Vec<Option<u64>> = vec![None; b];
        for (row, id) in ids.iter().enumerate() {
            let a = &self.active[id];
            let len = self.kv.seq_len(*id).unwrap_or(0);
            token[row] = a.last_token;
            pos[row] = len as i32;
            kv_len[row] = (len + 1) as i32;
            rows[row] = Some(*id);
        }
        let ran = if fail_exec {
            Err(anyhow::anyhow!(
                "injected decode failure at tick {}",
                self.tick
            ))
        } else {
            // split the borrows: the backend runs over the paged view
            let rt = &mut self.rt;
            let mut view = self.kv.view(&rows);
            rt.decode_paged(
                &artifact, &binding, &token, &pos, &mut view, &kv_len,
            )
        };
        let out = match ran {
            Ok(out) => out,
            Err(e) => {
                // transient batch failure: nothing advanced (KV valid
                // lengths only bump on success below), so every
                // stepped sequence releases and parks for a retry
                let msg = format!("decode batch failed: {e}");
                for id in ids {
                    self.fail_transient(id, &msg)?;
                }
                return Ok(true);
            }
        };
        EngineMetrics::inc(&self.metrics.decode_batches, 1);
        EngineMetrics::inc(&self.metrics.decode_tokens, ids.len() as u64);
        // the engine wrote each stepped sequence's K/V in place through
        // its block table; just bump the valid lengths
        for id in &ids {
            self.kv.advance(*id)?;
        }
        let now = Instant::now();
        for (row, id) in ids.iter().enumerate() {
            let Some(a) = self.active.get_mut(id) else { continue };
            let r = &out.logits[row * out.vocab..(row + 1) * out.vocab];
            let next = argmax(r) as i32;
            a.last_token = next;
            a.tracked.generated.push(next);
            let tpot = now.duration_since(a.last_token_at).as_secs_f64();
            a.last_token_at = now;
            self.metrics.observe_tpot(tpot);
            self.maybe_complete(*id)?;
        }
        Ok(true)
    }

    fn maybe_complete(&mut self, id: u64) -> Result<()> {
        let Some(a) = self.active.get(&id) else { return Ok(()) };
        let g = &a.tracked.generated;
        let done = g.len() >= a.tracked.req.max_new_tokens
            || g.last() == Some(&EOS);
        if !done {
            return Ok(());
        }
        self.complete(id)
    }

    /// Finish a sequence unconditionally: release its KV blocks, record
    /// metrics and send the (successful, `error: None`) response.
    fn complete(&mut self, id: u64) -> Result<()> {
        let Some(a) = self.active.remove(&id) else {
            return Ok(());
        };
        self.kv.release(id)?;
        self.publish_paging();
        let now = Instant::now();
        let e2e = now.duration_since(a.tracked.arrived).as_secs_f64();
        self.metrics.observe_e2e(e2e);
        EngineMetrics::inc(&self.metrics.requests_completed, 1);
        self.completed += 1;
        let ttft = a
            .tracked
            .first_token_at
            .map(|t| t.duration_since(a.tracked.arrived).as_secs_f64())
            .unwrap_or(0.0);
        let t = a.tracked;
        let resp = Response {
            id,
            tokens: t.generated,
            ttft_secs: ttft,
            e2e_secs: e2e,
            prefill_artifact: String::new(),
            error: None,
        };
        self.send_reply(id, &t.reply, resp);
        Ok(())
    }

    /// Push the O(1) paged-KV gauges (blocks in use, peak). Called on
    /// every admission/release.
    fn publish_paging(&self) {
        let used =
            (self.kv.n_blocks() - self.kv.free_blocks()) as u64;
        EngineMetrics::set(&self.metrics.kv_blocks_in_use, used);
        EngineMetrics::set_max(&self.metrics.kv_blocks_peak, used);
    }

    /// Refresh the fragmentation gauge. Costs a free-list sort, so it
    /// runs once per prefill batch rather than per completion.
    fn publish_frag(&self) {
        let fs = self.kv.frag_stats();
        EngineMetrics::set(
            &self.metrics.kv_frag_permille,
            (fs.fragmentation() * 1000.0).round() as u64,
        );
    }

    /// Publish the engine's cumulative weight-preparation accounting
    /// (bind-time panel packing + cached quantization) so prep
    /// amortization is visible in the serving report. Cheap snapshot;
    /// refreshed after each prefill batch's binds.
    fn publish_prep(&self) {
        let Some(ps) = self.rt.prep_stats() else { return };
        EngineMetrics::set(
            &self.metrics.weight_prep_us,
            (ps.prep_secs * 1e6).round() as u64,
        );
        EngineMetrics::set(
            &self.metrics.weight_bytes_packed,
            ps.bytes_packed,
        );
        EngineMetrics::set(
            &self.metrics.weight_bytes_resident,
            ps.bytes_resident,
        );
        EngineMetrics::set(&self.metrics.weight_prep_hits, ps.cache_hits);
        EngineMetrics::set(
            &self.metrics.weight_prep_misses,
            ps.prep_calls(),
        );
    }

    /// Push the prefix-cache gauges (resident nodes, lifetime
    /// evictions). Refreshed after each prefill batch and after
    /// pressure-driven eviction.
    fn publish_prefix(&self) {
        EngineMetrics::set(
            &self.metrics.prefix_cache_nodes,
            self.prefix.len() as u64,
        );
        EngineMetrics::set(
            &self.metrics.prefix_evictions,
            self.prefix.evictions(),
        );
    }

    /// Drop every prefix-cache node, returning their block tables to
    /// the pool. The cache deliberately persists across [`Engine::run`]
    /// invocations (warm restarts get hits); this is the explicit
    /// drain for tests, invariant sweeps and memory reclaim.
    pub fn clear_prefix_cache(&mut self) {
        self.prefix.clear(&mut self.kv);
        self.publish_paging();
        self.publish_prefix();
    }

    /// Sequences currently in the decode phase.
    pub fn active_requests(&self) -> usize {
        self.active.len()
    }

    /// Requests admitted but still mid-chunked-prefill.
    pub fn flight_requests(&self) -> usize {
        self.flight.len()
    }

    /// Requests still waiting in the prefill queues (includes
    /// preempted requests awaiting re-admission).
    pub fn queued_requests(&self) -> usize {
        self.queues.waiting()
    }

    /// Transiently-failed requests waiting out their retry backoff.
    pub fn parked_requests(&self) -> usize {
        self.parked.len()
    }

    /// Engine iterations stepped so far — the deterministic tick clock
    /// behind deadlines, retry backoff and fault schedules.
    pub fn ticks(&self) -> u64 {
        self.tick
    }

    /// The fault plan being consumed (fired / pending accounting for
    /// chaos tests).
    pub fn faults(&self) -> &FaultPlan {
        &self.faults
    }

    /// `(free, total)` blocks in the paged KV pool.
    pub fn kv_blocks(&self) -> (usize, usize) {
        (self.kv.free_blocks(), self.kv.n_blocks())
    }

    /// Check the paged KV store's invariants (block tables, refcounts,
    /// lengths); used by tests after a drained run.
    pub fn kv_invariants(&self) -> Result<()> {
        self.kv.check_invariants()
    }

    /// Sparsity accounting from the backend, if it tracks any.
    pub fn audit(&self) -> Option<SparsityAudit> {
        self.rt.audit()
    }
}

/// Best-effort text of a caught panic payload (`&str` and `String`
/// payloads cover `panic!` in practice; anything else is opaque).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}
