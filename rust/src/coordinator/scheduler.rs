//! The engine loop: iteration-level scheduling over an execution backend.
//!
//! Each iteration either (a) packs a same-config prefill batch, runs the
//! (possibly N:M-sparse) prefill artifact, samples first tokens and
//! admits the sequences into KV slots, or (b) advances every active slot
//! one dense decode step. Prefill is prioritized (the paper's setting:
//! prefill is the compute bottleneck being accelerated); a partial prefill
//! batch is flushed once its head request ages past `max_wait` or the
//! decode side is idle.
//!
//! The loop is backend-neutral: it drives a `Box<dyn runtime::Engine>`,
//! so the same scheduler serves the native CPU backend (default) and the
//! PJRT backend (`pjrt` feature).

use std::collections::HashMap;
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::batcher::{routing, ConfigKey, PrefillQueues};
use super::kv::KvSlots;
use super::paged::{BlockPool, DEFAULT_BLOCK};
use super::request::{Request, Response, Tracked};
use crate::metrics::EngineMetrics;
use crate::runtime::{Engine as ExecEngine, SparsityAudit};
use crate::tensor::math::argmax;

pub const EOS: i32 = 2;
pub const PAD: i32 = 0;

#[derive(Clone)]
pub struct EngineConfig {
    pub model: String,
    pub prefill_seq: usize,
    pub max_wait_secs: f64,
    /// stop after this many completed requests (0 = run until channel
    /// closes)
    pub run_until: usize,
    /// width of the execution backend's projection thread pool (the
    /// engine owns the pool; 1 = serial). Defaults to the host's
    /// available parallelism, capped at 8 — results are bit-identical
    /// at every width (see the batch-parity suite).
    pub pool_threads: usize,
}

impl EngineConfig {
    pub fn new(model: &str) -> EngineConfig {
        EngineConfig {
            model: model.to_string(),
            prefill_seq: 64,
            max_wait_secs: 0.005,
            run_until: 0,
            pool_threads: default_pool_threads(),
        }
    }
}

fn default_pool_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(8)
}

pub enum EngineMsg {
    Submit(Request, Sender<Response>),
    Shutdown,
}

struct ActiveSeq {
    tracked: Tracked,
    slot: usize,
    last_token: i32,
    decode_artifact: String,
    decode_binding: String,
    last_token_at: Instant,
}

pub struct Engine {
    pub cfg: EngineConfig,
    pub rt: Box<dyn ExecEngine>,
    pub metrics: Arc<EngineMetrics>,
    queues: PrefillQueues,
    kv: KvSlots,
    /// block-granular admission accounting (paged-attention style)
    pool: BlockPool,
    active: HashMap<u64, ActiveSeq>,
    /// decode artifact shared by all active seqs in a decode batch;
    /// batches are grouped per decode artifact (fp vs sq decode differ).
    #[allow(dead_code)] // kept for config introspection / tests
    vocab: usize,
    completed: usize,
}

impl Engine {
    pub fn new(
        mut rt: Box<dyn ExecEngine>,
        cfg: EngineConfig,
        metrics: Arc<EngineMetrics>,
    ) -> Result<Engine> {
        // the engine owns one projection pool; its width comes from the
        // coordinator config and reaches every batched kernel
        rt.set_parallelism(cfg.pool_threads);
        // geometry from the manifest
        let model = rt
            .manifest()
            .models
            .get(&cfg.model)
            .with_context(|| format!("model {} in manifest", cfg.model))?
            .clone();
        let g = |k: &str| model.config.get(k).copied().unwrap_or(0);
        let dec = rt
            .manifest()
            .artifact(&format!("{}.decode.dense", cfg.model))?
            .clone();
        // prefill batch = the prefill artifact's static batch
        let prefill_batch = rt
            .manifest()
            .artifact(&format!(
                "{}.prefill{}.dense",
                cfg.model, cfg.prefill_seq
            ))
            .map(|a| a.batch)
            .unwrap_or(8)
            .max(1);
        let kv = KvSlots::new(
            g("n_layers"),
            dec.batch,
            dec.cache,
            g("n_kv_heads"),
            g("head_dim"),
        );
        let pool = BlockPool::new(
            dec.batch * dec.cache / DEFAULT_BLOCK,
            DEFAULT_BLOCK,
        );
        let vocab = g("vocab_size");
        Ok(Engine {
            queues: PrefillQueues::new(prefill_batch, cfg.max_wait_secs),
            cfg,
            rt,
            metrics,
            kv,
            pool,
            active: HashMap::new(),
            vocab,
            completed: 0,
        })
    }

    pub fn submit(&mut self, req: Request, reply: Sender<Response>) {
        let (prefill, _, _) =
            routing(&self.cfg.model, self.cfg.prefill_seq, &req.config);
        EngineMetrics::inc(&self.metrics.requests_admitted, 1);
        self.queues.push(
            ConfigKey(prefill),
            Tracked {
                req,
                arrived: Instant::now(),
                first_token_at: None,
                generated: Vec::new(),
                reply: reply.clone(),
            },
        );
    }

    /// Blocking serve loop over a message channel.
    pub fn run(&mut self, rx: Receiver<EngineMsg>) -> Result<()> {
        let mut open = true;
        loop {
            // drain incoming messages (non-blocking while work pending)
            let busy = !self.queues.is_empty() || !self.active.is_empty();
            loop {
                let msg = if busy {
                    match rx.try_recv() {
                        Ok(m) => Some(m),
                        Err(_) => None,
                    }
                } else if open {
                    match rx.recv_timeout(Duration::from_millis(50)) {
                        Ok(m) => Some(m),
                        Err(RecvTimeoutError::Timeout) => None,
                        Err(RecvTimeoutError::Disconnected) => {
                            open = false;
                            None
                        }
                    }
                } else {
                    None
                };
                match msg {
                    Some(EngineMsg::Submit(r, tx)) => self.submit(r, tx),
                    Some(EngineMsg::Shutdown) => open = false,
                    None => break,
                }
            }
            if !open && self.queues.is_empty() && self.active.is_empty() {
                return Ok(());
            }
            if self.cfg.run_until > 0 && self.completed >= self.cfg.run_until
            {
                return Ok(());
            }
            self.step()?;
        }
    }

    /// One scheduling iteration. Returns whether any work was done.
    pub fn step(&mut self) -> Result<bool> {
        let idle = self.active.is_empty();
        let now = Instant::now();
        // token-packed batching: the budget is the prefill artifact's
        // static token capacity (batch x seq), but short prompts can
        // pack more requests than the static batch into it
        let budget = self.queues.max_batch * self.cfg.prefill_seq;
        if let Some((key, batch)) = self.queues.next_packed_batch(
            self.kv.free_slots(),
            self.cfg.prefill_seq,
            budget,
            idle,
            now,
        ) {
            self.run_prefill(&key, batch)?;
            return Ok(true);
        }
        if !self.active.is_empty() {
            self.run_decode()?;
            return Ok(true);
        }
        Ok(false)
    }

    fn run_prefill(
        &mut self,
        key: &ConfigKey,
        mut batch: Vec<Tracked>,
    ) -> Result<()> {
        let artifact = key.0.clone();
        // weights binding comes from the first request's config (all
        // requests in a bucket share it by construction)
        let cfg0 = batch[0].req.config;
        let (_, decode_artifact, files) =
            routing(&self.cfg.model, self.cfg.prefill_seq, &cfg0);
        let file_refs: Vec<&str> = files.iter().map(|f| f.as_str()).collect();
        let binding = self.rt.bind(&artifact, &file_refs)?;
        let dec_files = vec![file_refs[0]];
        let dec_binding = self.rt.bind(&decode_artifact, &dec_files)?;

        // token-packed submission: each request's prompt rides verbatim
        // (the engine clamps to the artifact seq); no PAD rows between
        // requests, so the batch reaches the kernel as one
        // [total_tokens, d] matrix
        let prompts: Vec<Vec<i32>> =
            batch.iter().map(|t| t.req.prompt.clone()).collect();
        let out = self.rt.prefill_packed(&artifact, &binding, &prompts)?;
        let total = out.total_tokens();
        EngineMetrics::inc(&self.metrics.prefill_tokens, total as u64);
        // 0 on the native shape-flexible pipeline; the real padding cost
        // on backends using the pad-and-gather default path (PJRT)
        EngineMetrics::inc(
            &self.metrics.padded_prefill_tokens,
            out.padded_tokens as u64,
        );
        EngineMetrics::inc(&self.metrics.prefill_batches, 1);
        let now = Instant::now();
        let mut start = 0usize; // packed row offset of request i
        for (i, mut t) in batch.drain(..).enumerate() {
            let len = out.lens[i];
            // greedy first token from the last prompt position (an empty
            // prompt — rejected at the TCP layer, but defend the engine
            // too — occupies one PAD row and scores from it)
            let row = &out.logits
                [(start + len - 1) * out.vocab..(start + len) * out.vocab];
            let first = argmax(row) as i32;
            t.first_token_at = Some(now);
            self.metrics
                .observe_ttft(now.duration_since(t.arrived).as_secs_f64());
            t.generated.push(first);
            let id = t.req.id;
            // block-granular admission accounting: reserve the sequence's
            // worst-case footprint (prompt + full generation budget)
            self.pool
                .allocate(id, len + t.req.max_new_tokens)
                .ok();
            let slot = self.kv.admit_packed(
                id,
                &out.k_cache,
                &out.v_cache,
                start,
                total,
                len,
            )?;
            start += len;
            self.active.insert(
                id,
                ActiveSeq {
                    tracked: t,
                    slot,
                    last_token: first,
                    decode_artifact: decode_artifact.clone(),
                    decode_binding: dec_binding.clone(),
                    last_token_at: now,
                },
            );
            // immediately-finished sequences (max_new_tokens == 1 or EOS)
            self.maybe_complete(id)?;
        }
        Ok(())
    }

    fn run_decode(&mut self) -> Result<()> {
        // group by decode artifact (fp vs sq)
        let mut by_art: HashMap<(String, String), Vec<u64>> = HashMap::new();
        for (id, a) in &self.active {
            by_art
                .entry((a.decode_artifact.clone(), a.decode_binding.clone()))
                .or_default()
                .push(*id);
        }
        let Some(((artifact, binding), mut ids)) = by_art.into_iter().next()
        else {
            return Ok(());
        };
        ids.sort(); // determinism
        let meta = self.rt.manifest().artifact(&artifact)?.clone();
        let b = meta.batch;
        ids.truncate(b);
        let mut token = vec![PAD; b];
        let mut pos = vec![0i32; b];
        let mut kv_len = vec![1i32; b];
        let mut stepped = Vec::new();
        for id in &ids {
            let a = &self.active[id];
            let slot = a.slot;
            // each active seq occupies its KV slot row; the decode batch
            // is indexed BY SLOT (cache layout)
            token[slot] = a.last_token;
            pos[slot] = self.kv.len[slot] as i32;
            kv_len[slot] = (self.kv.len[slot] + 1) as i32;
            stepped.push(slot);
        }
        // split the borrows: the engine runs over the KV host mirrors
        let rt = &mut self.rt;
        let out = rt.decode(
            &artifact, &binding, &token, &pos, &self.kv.k, &self.kv.v,
            &kv_len,
        )?;
        EngineMetrics::inc(&self.metrics.decode_batches, 1);
        EngineMetrics::inc(&self.metrics.decode_tokens, ids.len() as u64);
        self.kv
            .absorb_decode_output(out.k_cache, out.v_cache, &stepped);
        let now = Instant::now();
        for id in ids {
            let a = self.active.get_mut(&id).unwrap();
            let slot = a.slot;
            let row = &out.logits[slot * out.vocab..(slot + 1) * out.vocab];
            let next = argmax(row) as i32;
            a.last_token = next;
            a.tracked.generated.push(next);
            let tpot = now.duration_since(a.last_token_at).as_secs_f64();
            a.last_token_at = now;
            self.metrics.observe_tpot(tpot);
            self.maybe_complete(id)?;
        }
        Ok(())
    }

    fn maybe_complete(&mut self, id: u64) -> Result<()> {
        let done = {
            let a = &self.active[&id];
            let g = &a.tracked.generated;
            g.len() >= a.tracked.req.max_new_tokens
                || g.last() == Some(&EOS)
        };
        if !done {
            return Ok(());
        }
        let a = self.active.remove(&id).unwrap();
        self.kv.release(a.slot);
        self.pool.release(id);
        let now = Instant::now();
        let e2e = now.duration_since(a.tracked.arrived).as_secs_f64();
        self.metrics.observe_e2e(e2e);
        EngineMetrics::inc(&self.metrics.requests_completed, 1);
        self.completed += 1;
        let ttft = a
            .tracked
            .first_token_at
            .map(|t| t.duration_since(a.tracked.arrived).as_secs_f64())
            .unwrap_or(0.0);
        let _ = a.tracked.reply.send(Response {
            id,
            tokens: a.tracked.generated,
            ttft_secs: ttft,
            e2e_secs: e2e,
            prefill_artifact: String::new(),
        });
        Ok(())
    }

    pub fn kv_invariants(&self) -> Result<()> {
        self.kv.check_invariants()?;
        self.pool.check_invariants()
    }

    /// Sparsity accounting from the backend, if it tracks any.
    pub fn audit(&self) -> Option<SparsityAudit> {
        self.rt.audit()
    }
}
