//! The engine loop: iteration-level scheduling over an execution backend.
//!
//! Each iteration either (a) packs a same-config prefill batch, runs the
//! (possibly N:M-sparse) prefill artifact, samples first tokens and
//! admits the sequences into the block-paged KV store, or (b) advances a
//! decode batch one step. Prefill is prioritized (the paper's setting:
//! prefill is the compute bottleneck being accelerated); a partial
//! prefill batch is flushed once its head request ages past `max_wait`,
//! the decode side is idle, or the free-block budget cuts it (the rest
//! of the bucket continues in a later batch).
//!
//! Admission is by free **block** count ([`super::paged::BlockPool`]):
//! a request reserves `ceil((prompt + max_new_tokens) / block)` blocks,
//! which may live anywhere in the pool — long prompts never need a
//! contiguous KV slot, so concurrency is bounded by total KV memory,
//! not by `decode_batch` slots. When more sequences are active than the
//! decode artifact's static batch, decode steps the least-advanced
//! sequences first (fair round-robin by generated length, then id).
//!
//! The loop is backend-neutral: it drives a `Box<dyn runtime::Engine>`,
//! so the same scheduler serves the native CPU backend (default) and the
//! PJRT backend (`pjrt` feature), which sees contiguous KV via the
//! default [`crate::runtime::Engine::decode_paged`] gather.

use std::collections::{BTreeMap, HashMap};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::batcher::{routing, BlockBudget, ConfigKey, PrefillQueues};
use super::kv::KvPages;
use super::paged::DEFAULT_BLOCK;
use super::prefix::PrefixCache;
use super::request::{Request, Response, Tracked};
use crate::metrics::EngineMetrics;
use crate::runtime::{
    Engine as ExecEngine, PrefixedPrompt, SparsityAudit,
};
use crate::tensor::math::argmax;

/// End-of-sequence token id of the synthetic token world.
pub const EOS: i32 = 2;
/// Padding token id.
pub const PAD: i32 = 0;

/// Engine-loop configuration (model, serving shapes, scheduling knobs).
#[derive(Clone)]
pub struct EngineConfig {
    /// model name (manifest key)
    pub model: String,
    /// prefill artifact sequence length to serve
    pub prefill_seq: usize,
    /// flush a partial prefill batch after its head waited this long
    pub max_wait_secs: f64,
    /// stop after this many completed requests (0 = run until channel
    /// closes)
    pub run_until: usize,
    /// width of the execution backend's projection thread pool (the
    /// engine owns the pool; 1 = serial). Defaults to the host's
    /// available parallelism, capped at 8 — results are bit-identical
    /// at every width (see the batch-parity suite).
    pub pool_threads: usize,
    /// tokens per KV block ([`DEFAULT_BLOCK`] unless overridden).
    /// Results are bit-identical at every block size (see the
    /// paged-parity suite); the knob exists for memory-granularity
    /// tuning and tests.
    pub kv_block: usize,
    /// share full prompt-prefix KV blocks across requests through the
    /// radix [`PrefixCache`] (fork at admission, copy-on-write on
    /// divergence, LRU-evicted under block pressure). On by default:
    /// forked-prefix prefill is bit-identical to cold prefill (see the
    /// prefix-parity suite), so the knob only trades KV blocks for
    /// prefill compute.
    pub prefix_cache: bool,
}

impl EngineConfig {
    /// Defaults for `model`: seq 64, 5 ms max-wait, host parallelism,
    /// [`DEFAULT_BLOCK`]-token KV blocks.
    pub fn new(model: &str) -> EngineConfig {
        EngineConfig {
            model: model.to_string(),
            prefill_seq: 64,
            max_wait_secs: 0.005,
            run_until: 0,
            pool_threads: default_pool_threads(),
            kv_block: DEFAULT_BLOCK,
            prefix_cache: true,
        }
    }
}

fn default_pool_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(8)
}

/// Messages accepted by [`Engine::run`]'s channel.
pub enum EngineMsg {
    /// Enqueue a request; the response goes to the provided sender.
    Submit(Request, Sender<Response>),
    /// Drain remaining work, then exit the serve loop.
    Shutdown,
}

struct ActiveSeq {
    tracked: Tracked,
    last_token: i32,
    decode_artifact: String,
    decode_binding: String,
    last_token_at: Instant,
}

/// The serving engine: scheduler state over an execution backend.
pub struct Engine {
    /// engine-loop configuration
    pub cfg: EngineConfig,
    /// the execution backend being scheduled
    pub rt: Box<dyn ExecEngine>,
    /// shared serving metrics
    pub metrics: Arc<EngineMetrics>,
    queues: PrefillQueues,
    /// block-paged KV store (physical blocks + per-sequence tables)
    kv: KvPages,
    /// radix index over cached prompt prefixes; its nodes hold forked
    /// block tables in `kv` until evicted under block pressure
    prefix: PrefixCache,
    active: HashMap<u64, ActiveSeq>,
    /// round-robin cursor over decode-artifact groups (fp vs sq decode
    /// differ), so no group starves under sustained mixed-config load
    decode_rr: usize,
    #[allow(dead_code)] // kept for config introspection / tests
    vocab: usize,
    completed: usize,
}

impl Engine {
    /// Build the engine for `cfg.model`, sizing the paged KV store from
    /// the decode artifact's static shapes (`batch * cache` tokens of
    /// capacity, split into `cfg.kv_block`-token blocks).
    pub fn new(
        mut rt: Box<dyn ExecEngine>,
        cfg: EngineConfig,
        metrics: Arc<EngineMetrics>,
    ) -> Result<Engine> {
        // the engine owns one projection pool; its width comes from the
        // coordinator config and reaches every batched kernel
        rt.set_parallelism(cfg.pool_threads);
        // geometry from the manifest
        let model = rt
            .manifest()
            .models
            .get(&cfg.model)
            .with_context(|| format!("model {} in manifest", cfg.model))?
            .clone();
        let g = |k: &str| model.config.get(k).copied().unwrap_or(0);
        let dec = rt
            .manifest()
            .artifact(&format!("{}.decode.dense", cfg.model))?
            .clone();
        // prefill batch = the prefill artifact's static batch
        let prefill_batch = rt
            .manifest()
            .artifact(&format!(
                "{}.prefill{}.dense",
                cfg.model, cfg.prefill_seq
            ))
            .map(|a| a.batch)
            .unwrap_or(8)
            .max(1);
        let kv_block = cfg.kv_block.max(1);
        let n_blocks = (dec.batch * dec.cache / kv_block).max(1);
        // the per-sequence cap must never exceed what the pool can
        // physically hold (block flooring can shave tokens off the
        // nominal batch*cache capacity)
        let max_seq = dec.cache.min(n_blocks * kv_block);
        let kv = KvPages::new(
            g("n_layers"),
            n_blocks,
            kv_block,
            g("n_kv_heads"),
            g("head_dim"),
            max_seq,
        );
        EngineMetrics::set(&metrics.kv_blocks_total, n_blocks as u64);
        let vocab = g("vocab_size");
        Ok(Engine {
            queues: PrefillQueues::new(prefill_batch, cfg.max_wait_secs),
            prefix: PrefixCache::new(kv_block),
            cfg,
            rt,
            metrics,
            kv,
            active: HashMap::new(),
            decode_rr: 0,
            vocab,
            completed: 0,
        })
    }

    /// Enqueue a request into its config bucket.
    pub fn submit(&mut self, req: Request, reply: Sender<Response>) {
        let (prefill, _, _) =
            routing(&self.cfg.model, self.cfg.prefill_seq, &req.config);
        EngineMetrics::inc(&self.metrics.requests_admitted, 1);
        self.queues.push(
            ConfigKey(prefill),
            Tracked {
                req,
                arrived: Instant::now(),
                first_token_at: None,
                generated: Vec::new(),
                reply: reply.clone(),
            },
        );
    }

    /// Blocking serve loop over a message channel.
    pub fn run(&mut self, rx: Receiver<EngineMsg>) -> Result<()> {
        let mut open = true;
        loop {
            // drain incoming messages (non-blocking while work pending)
            let busy = !self.queues.is_empty() || !self.active.is_empty();
            loop {
                let msg = if busy {
                    match rx.try_recv() {
                        Ok(m) => Some(m),
                        Err(_) => None,
                    }
                } else if open {
                    match rx.recv_timeout(Duration::from_millis(50)) {
                        Ok(m) => Some(m),
                        Err(RecvTimeoutError::Timeout) => None,
                        Err(RecvTimeoutError::Disconnected) => {
                            open = false;
                            None
                        }
                    }
                } else {
                    None
                };
                match msg {
                    Some(EngineMsg::Submit(r, tx)) => self.submit(r, tx),
                    Some(EngineMsg::Shutdown) => open = false,
                    None => break,
                }
            }
            if !open && self.queues.is_empty() && self.active.is_empty() {
                self.shutdown_prefix();
                return Ok(());
            }
            if self.cfg.run_until > 0 && self.completed >= self.cfg.run_until
            {
                self.shutdown_prefix();
                return Ok(());
            }
            self.step()?;
        }
    }

    /// One scheduling iteration. Returns whether any work was done.
    pub fn step(&mut self) -> Result<bool> {
        let idle = self.active.is_empty();
        let now = Instant::now();
        // token-packed batching: the budget is the prefill artifact's
        // static token capacity (batch x seq), but short prompts can
        // pack more requests than the static batch into it. Admission
        // itself is by free-block count: each request's worst-case KV
        // footprint must fit somewhere in the pool.
        let budget = self.queues.max_batch * self.cfg.prefill_seq;
        let mut blocks = BlockBudget {
            free_blocks: self.kv.free_blocks(),
            total_blocks: self.kv.n_blocks(),
            block_size: self.kv.block_size(),
            max_seq_tokens: self.kv.max_seq_tokens,
        };
        // prefix-cache nodes hold KV blocks; under pressure they yield
        // to admissions. Evict (LRU, deepest-first on ties) until the
        // worst-case queue head fits the free list — cached blocks must
        // never starve, let alone deadlock, the prefill queues.
        if let Some(need) =
            self.queues.max_head_demand(&blocks, self.cfg.prefill_seq)
        {
            while self.kv.free_blocks() < need
                && self.prefix.evict_one(&mut self.kv).is_some()
            {}
            blocks.free_blocks = self.kv.free_blocks();
            self.publish_prefix();
        }
        if let Some((key, batch)) = self.queues.next_packed_batch(
            blocks,
            self.cfg.prefill_seq,
            budget,
            idle,
            now,
        ) {
            self.run_prefill(&key, batch)?;
            return Ok(true);
        }
        if !self.active.is_empty() {
            self.run_decode()?;
            return Ok(true);
        }
        Ok(false)
    }

    fn run_prefill(
        &mut self,
        key: &ConfigKey,
        mut batch: Vec<Tracked>,
    ) -> Result<()> {
        let artifact = key.0.clone();
        // weights binding comes from the first request's config (all
        // requests in a bucket share it by construction)
        let cfg0 = batch[0].req.config;
        let (_, decode_artifact, files) =
            routing(&self.cfg.model, self.cfg.prefill_seq, &cfg0);
        let file_refs: Vec<&str> = files.iter().map(|f| f.as_str()).collect();
        let binding = self.rt.bind(&artifact, &file_refs)?;
        let dec_files = vec![file_refs[0]];
        let dec_binding = self.rt.bind(&decode_artifact, &dec_files)?;
        // binds above are where weight preparation (panel packing +
        // cached quantization) happens; refresh the prep gauges
        self.publish_prep();

        // Phase A — prefix-cache lookup. For every request whose leading
        // full blocks are cached, fork the donor node's blocks into the
        // request's table NOW (refcount bump, no data movement) and
        // gather the donor's K/V rows so the backend can attend over
        // them; everything else rides cold. At least one suffix token is
        // always recomputed — the last prompt row must be live to sample
        // the first token from (a fully cached prompt copy-on-writes its
        // boundary block at admission instead).
        let seq_cap = self.cfg.prefill_seq;
        // per request: Some(donor node) + cached token count when warm
        let mut hits: Vec<Option<(u64, usize)>> =
            Vec::with_capacity(batch.len());
        let mut reqs: Vec<PrefixedPrompt> =
            Vec::with_capacity(batch.len());
        let mut any_warm = false;
        for t in &batch {
            let p = &t.req.prompt;
            let clamped = &p[..p.len().min(seq_cap)];
            let mut warm = None;
            if self.cfg.prefix_cache && !clamped.is_empty() {
                if let Some(hit) = self.prefix.lookup(clamped) {
                    let cached =
                        hit.cached_tokens.min(clamped.len() - 1);
                    if cached > 0
                        && self
                            .kv
                            .fork_prefix(
                                hit.node_seq,
                                t.req.id,
                                self.kv.blocks_for(cached),
                            )
                            .is_ok()
                    {
                        match self.kv.gather_seq(hit.node_seq, cached) {
                            Some((pk, pv)) => {
                                warm = Some((hit.node_seq, cached, pk, pv));
                            }
                            None => {
                                // unreachable for a live node; undo the
                                // fork and fall back to a cold prefill
                                let _ = self.kv.release(t.req.id);
                            }
                        }
                    }
                    if warm.is_none() {
                        self.prefix.unpin(hit.node_seq);
                    }
                }
            }
            match warm {
                Some((node, cached, pk, pv)) => {
                    any_warm = true;
                    hits.push(Some((node, cached)));
                    reqs.push(PrefixedPrompt {
                        tokens: p.clone(),
                        cached_len: cached,
                        prefix_k: pk,
                        prefix_v: pv,
                    });
                }
                None => {
                    hits.push(None);
                    reqs.push(PrefixedPrompt {
                        tokens: p.clone(),
                        cached_len: 0,
                        prefix_k: Vec::new(),
                        prefix_v: Vec::new(),
                    });
                }
            }
        }

        // Phase B — token-packed submission: each request's prompt (warm:
        // uncached suffix only) rides verbatim (the engine clamps to the
        // artifact seq); no PAD rows between requests, so the batch
        // reaches the kernel as one [total_tokens, d] matrix. An
        // all-cold batch takes the plain packed path — byte-for-byte the
        // route a prefix-cache-disabled engine takes.
        let out = if any_warm {
            self.rt.prefill_packed_prefixed(&artifact, &binding, &reqs)?
        } else {
            let prompts: Vec<Vec<i32>> =
                reqs.into_iter().map(|r| r.tokens).collect();
            self.rt.prefill_packed(&artifact, &binding, &prompts)?
        };
        let total = out.total_tokens();
        EngineMetrics::inc(&self.metrics.prefill_tokens, total as u64);
        // 0 on the native shape-flexible pipeline; the real padding cost
        // on backends using the pad-and-gather default path (PJRT)
        EngineMetrics::inc(
            &self.metrics.padded_prefill_tokens,
            out.padded_tokens as u64,
        );
        EngineMetrics::inc(&self.metrics.prefill_batches, 1);
        let now = Instant::now();
        let mut start = 0usize; // packed row offset of request i
        for (i, mut t) in batch.drain(..).enumerate() {
            // packed row count this request contributed: the full
            // (clamped) prompt when cold, the uncached suffix when warm
            let len = out.lens[i];
            let (node, cached) = match hits[i] {
                Some((n, c)) => (Some(n), c),
                None => (None, 0),
            };
            // greedy first token from the last prompt position (an empty
            // prompt — rejected at the TCP layer, but defend the engine
            // too — occupies one PAD row and scores from it); a warm
            // request's last prompt row is always computed (phase A
            // leaves >= 1 suffix token), so the same indexing holds
            let row = &out.logits
                [(start + len - 1) * out.vocab..(start + len) * out.vocab];
            let first = argmax(row) as i32;
            t.first_token_at = Some(now);
            self.metrics
                .observe_ttft(now.duration_since(t.arrived).as_secs_f64());
            t.generated.push(first);
            let id = t.req.id;
            // block-paged admission: stage this request's packed KV rows
            // block-by-block, reserving its worst-case footprint
            // (prompt + full generation budget) so decode growth cannot
            // fail mid-stream. Blocks may be scattered anywhere. The
            // reservation clamps to the per-sequence cap — a generation
            // budget the cache can't hold truncates at the cap
            // (run_decode force-completes) instead of erroring. Warm
            // requests extend the table forked in phase A, with the
            // boundary block copy-on-written if the cached prefix ends
            // mid-block.
            let reserve = (cached + len + t.req.max_new_tokens)
                .min(self.kv.max_seq_tokens);
            let admitted = if cached > 0 {
                self.kv.admit_packed_prefixed(
                    id,
                    &out.k_cache,
                    &out.v_cache,
                    start,
                    total,
                    cached,
                    len,
                    reserve,
                )
            } else {
                self.kv.admit_packed(
                    id,
                    &out.k_cache,
                    &out.v_cache,
                    start,
                    total,
                    len,
                    reserve,
                )
            };
            if let Err(err) = admitted {
                // unservable request (e.g. a prompt longer than the KV
                // cap on a misconfigured manifest): fail it ALONE with
                // its prefill-sampled token, never the whole serve loop
                crate::warn_log!(
                    "request {id} rejected by KV admission: {err}"
                );
                if cached > 0 {
                    // drop the forked table; the donor node keeps its
                    // own refcounts on the shared blocks
                    let _ = self.kv.release(id);
                }
                if let Some(n) = node {
                    self.prefix.unpin(n);
                }
                start += len;
                let e2e =
                    now.duration_since(t.arrived).as_secs_f64();
                self.metrics.observe_e2e(e2e);
                EngineMetrics::inc(&self.metrics.requests_completed, 1);
                self.completed += 1;
                let _ = t.reply.send(Response {
                    id,
                    tokens: t.generated,
                    ttft_secs: e2e,
                    e2e_secs: e2e,
                    prefill_artifact: String::new(),
                });
                continue;
            }
            start += len;
            // reuse accounting only counts admissions it actually served
            if cached > 0 {
                EngineMetrics::inc(
                    &self.metrics.prefix_hit_blocks,
                    self.kv.blocks_for(cached) as u64,
                );
                EngineMetrics::inc(
                    &self.metrics.prefix_hit_tokens,
                    cached as u64,
                );
            }
            // publish this prompt's own full blocks back into the cache
            // before maybe_complete: an immediately-finished request
            // still seeds the cache for followers
            if self.cfg.prefix_cache {
                let clamped_len = t.req.prompt.len().min(seq_cap);
                let clamped = t.req.prompt[..clamped_len].to_vec();
                self.prefix.register(id, &clamped, &mut self.kv);
            }
            if let Some(n) = node {
                self.prefix.unpin(n);
            }
            self.active.insert(
                id,
                ActiveSeq {
                    tracked: t,
                    last_token: first,
                    decode_artifact: decode_artifact.clone(),
                    decode_binding: dec_binding.clone(),
                    last_token_at: now,
                },
            );
            // immediately-finished sequences (max_new_tokens == 1 or EOS)
            self.maybe_complete(id)?;
        }
        self.publish_paging();
        self.publish_frag();
        self.publish_prefix();
        Ok(())
    }

    fn run_decode(&mut self) -> Result<()> {
        // group by decode artifact (fp vs sq); BTreeMap so group order
        // is deterministic (HashMap iteration varies run to run, and
        // W8A8 logits depend on batch composition), and a round-robin
        // cursor over the sorted groups so none starves when several
        // stay populated under sustained load
        let mut by_art: BTreeMap<(String, String), Vec<u64>> =
            BTreeMap::new();
        for (id, a) in &self.active {
            by_art
                .entry((a.decode_artifact.clone(), a.decode_binding.clone()))
                .or_default()
                .push(*id);
        }
        if by_art.is_empty() {
            return Ok(());
        }
        let pick = self.decode_rr % by_art.len();
        self.decode_rr = self.decode_rr.wrapping_add(1);
        let Some(((artifact, binding), ids)) = by_art.into_iter().nth(pick)
        else {
            return Ok(());
        };
        let meta = self.rt.manifest().artifact(&artifact)?.clone();
        let b = meta.batch;
        // a sequence whose KV hit the per-sequence cap cannot take
        // another token: finish it with what it has (the cap is the
        // decode cache — only reachable when a request's generation
        // budget exceeds what the cache can hold)
        let cap = self.kv.max_seq_tokens;
        let (step_ids, full_ids): (Vec<u64>, Vec<u64>) = ids
            .into_iter()
            .partition(|id| self.kv.seq_len(*id).unwrap_or(0) < cap);
        for id in full_ids {
            self.complete(id)?;
        }
        let mut ids = step_ids;
        if ids.is_empty() {
            return Ok(());
        }
        // paged KV admits more concurrent sequences than the decode
        // artifact's static batch; step the least-advanced first so
        // nobody starves (deterministic: generated length, then id)
        if ids.len() > b {
            ids.sort_unstable_by_key(|id| {
                (self.active[id].tracked.generated.len(), *id)
            });
            ids.truncate(b);
        }
        ids.sort_unstable(); // determinism of row assignment
        let mut token = vec![PAD; b];
        let mut pos = vec![0i32; b];
        let mut kv_len = vec![1i32; b];
        let mut rows: Vec<Option<u64>> = vec![None; b];
        for (row, id) in ids.iter().enumerate() {
            let a = &self.active[id];
            let len = self
                .kv
                .seq_len(*id)
                .with_context(|| format!("seq {id} missing from KV"))?;
            // append lands at position `len`: allocate the tail block if
            // `len` crosses a block boundary (a no-op while the
            // admission-time reservation covers it), then make sure the
            // target block is exclusively owned — the first append past
            // a shared cached prefix copy-on-writes it (a no-op on
            // unshared blocks)
            self.kv.ensure_capacity(*id, len + 1)?;
            self.kv.make_writable(*id, len)?;
            token[row] = a.last_token;
            pos[row] = len as i32;
            kv_len[row] = (len + 1) as i32;
            rows[row] = Some(*id);
        }
        // split the borrows: the backend runs over the paged KV view
        let rt = &mut self.rt;
        let mut view = self.kv.view(&rows);
        let out = rt.decode_paged(
            &artifact, &binding, &token, &pos, &mut view, &kv_len,
        )?;
        EngineMetrics::inc(&self.metrics.decode_batches, 1);
        EngineMetrics::inc(&self.metrics.decode_tokens, ids.len() as u64);
        // the engine wrote each stepped sequence's K/V in place through
        // its block table; just bump the valid lengths
        for id in &ids {
            self.kv.advance(*id)?;
        }
        let now = Instant::now();
        for (row, id) in ids.iter().enumerate() {
            let a = self.active.get_mut(id).unwrap();
            let r = &out.logits[row * out.vocab..(row + 1) * out.vocab];
            let next = argmax(r) as i32;
            a.last_token = next;
            a.tracked.generated.push(next);
            let tpot = now.duration_since(a.last_token_at).as_secs_f64();
            a.last_token_at = now;
            self.metrics.observe_tpot(tpot);
            self.maybe_complete(*id)?;
        }
        Ok(())
    }

    fn maybe_complete(&mut self, id: u64) -> Result<()> {
        let done = {
            let a = &self.active[&id];
            let g = &a.tracked.generated;
            g.len() >= a.tracked.req.max_new_tokens
                || g.last() == Some(&EOS)
        };
        if !done {
            return Ok(());
        }
        self.complete(id)
    }

    /// Finish a sequence unconditionally: release its KV blocks, record
    /// metrics and send the response.
    fn complete(&mut self, id: u64) -> Result<()> {
        let a = self.active.remove(&id).unwrap();
        self.kv.release(id)?;
        self.publish_paging();
        let now = Instant::now();
        let e2e = now.duration_since(a.tracked.arrived).as_secs_f64();
        self.metrics.observe_e2e(e2e);
        EngineMetrics::inc(&self.metrics.requests_completed, 1);
        self.completed += 1;
        let ttft = a
            .tracked
            .first_token_at
            .map(|t| t.duration_since(a.tracked.arrived).as_secs_f64())
            .unwrap_or(0.0);
        let _ = a.tracked.reply.send(Response {
            id,
            tokens: a.tracked.generated,
            ttft_secs: ttft,
            e2e_secs: e2e,
            prefill_artifact: String::new(),
        });
        Ok(())
    }

    /// Push the O(1) paged-KV gauges (blocks in use, peak). Called on
    /// every admission/release.
    fn publish_paging(&self) {
        let used =
            (self.kv.n_blocks() - self.kv.free_blocks()) as u64;
        EngineMetrics::set(&self.metrics.kv_blocks_in_use, used);
        EngineMetrics::set_max(&self.metrics.kv_blocks_peak, used);
    }

    /// Refresh the fragmentation gauge. Costs a free-list sort, so it
    /// runs once per prefill batch rather than per completion.
    fn publish_frag(&self) {
        let fs = self.kv.frag_stats();
        EngineMetrics::set(
            &self.metrics.kv_frag_permille,
            (fs.fragmentation() * 1000.0).round() as u64,
        );
    }

    /// Publish the engine's cumulative weight-preparation accounting
    /// (bind-time panel packing + cached quantization) so prep
    /// amortization is visible in the serving report. Cheap snapshot;
    /// refreshed after each prefill batch's binds.
    fn publish_prep(&self) {
        let Some(ps) = self.rt.prep_stats() else { return };
        EngineMetrics::set(
            &self.metrics.weight_prep_us,
            (ps.prep_secs * 1e6).round() as u64,
        );
        EngineMetrics::set(
            &self.metrics.weight_bytes_packed,
            ps.bytes_packed,
        );
        EngineMetrics::set(
            &self.metrics.weight_bytes_resident,
            ps.bytes_resident,
        );
        EngineMetrics::set(&self.metrics.weight_prep_hits, ps.cache_hits);
        EngineMetrics::set(
            &self.metrics.weight_prep_misses,
            ps.prep_calls(),
        );
    }

    /// Push the prefix-cache gauges (resident nodes, lifetime
    /// evictions). Refreshed after each prefill batch and after
    /// pressure-driven eviction.
    fn publish_prefix(&self) {
        EngineMetrics::set(
            &self.metrics.prefix_cache_nodes,
            self.prefix.len() as u64,
        );
        EngineMetrics::set(
            &self.metrics.prefix_evictions,
            self.prefix.evictions(),
        );
    }

    /// Drop every prefix-cache node on serve-loop exit, returning their
    /// block tables to the pool so the post-run invariant sweep (and a
    /// fresh serve loop) sees a fully drained allocator.
    fn shutdown_prefix(&mut self) {
        self.prefix.clear(&mut self.kv);
        self.publish_paging();
        self.publish_prefix();
    }

    /// Check the paged KV store's invariants (block tables, refcounts,
    /// lengths); used by tests after a drained run.
    pub fn kv_invariants(&self) -> Result<()> {
        self.kv.check_invariants()
    }

    /// Sparsity accounting from the backend, if it tracks any.
    pub fn audit(&self) -> Option<SparsityAudit> {
        self.rt.audit()
    }
}
