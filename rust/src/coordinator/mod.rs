//! Layer-3 serving coordinator (the paper's system integrated as a
//! first-class serving feature).
//!
//! vLLM-shaped pipeline, single engine thread, no tokio:
//!
//! ```text
//!  clients ──mpsc──► Router ──per-config queues──► Scheduler loop
//!                                                    │  prefill batch (N:M sparse, token-packed)
//!                                                    │  decode batch  (dense, block-paged KV)
//!                                                    ▼
//!                                     dyn runtime::Engine
//!                                     (NativeEngine by default;
//!                                      PJRT behind the `pjrt` feature)
//! ```
//!
//! Multi-replica deployments put a supervised [`replica::ReplicaPool`]
//! in front: N engine threads behind a health-aware [`router::Router`]
//! (prefix-affinity / least-outstanding / round-robin), with crash
//! failover re-dispatch, heartbeat fencing and graceful drain — see
//! the `replica` module docs.
//!
//! The KV cache is genuinely block-paged (`paged::BlockPool` allocator +
//! `kv::KvPages` physical store): admission is by free-**block** count,
//! so long prompts never need a contiguous slot and concurrency is
//! bounded by KV memory, not by decode-batch slots. See
//! `docs/ARCHITECTURE.md` for the full request lifecycle.
//!
//! The paper's contribution appears as the per-request **sparsity config**:
//! requests choose `dense | 2:4 | 4:8 | 8:16` x `naive | ls | all` x
//! `fp | w8a8`; the router buckets by config, the batcher packs same-config
//! prefills (sparse prefill shares one artifact per ratio — method and
//! skip-policy arrive as auxiliary weights), and decode is always dense,
//! exactly as the paper confines sparsity to prefill.

pub mod batcher;
pub mod error;
pub mod fault;
pub mod kv;
pub mod paged;
pub mod prefix;
pub mod replica;
pub mod request;
pub mod scheduler;
pub mod router;

pub use error::{ErrorKind, RequestError};
pub use fault::{FaultKind, FaultPlan, FaultSite};
pub use replica::{
    EngineFactory, Gateway, PoolConfig, PoolHandle, ReplicaPool,
    ReplicaStat,
};
pub use request::{HandedBack, Request, Response, SparsityConfig};
pub use router::{Health, Policy, RouteError};
pub use scheduler::{DegradePolicy, Engine, EngineConfig, EngineMsg};
