//! KV-cache slot manager.
//!
//! The decode executable owns a fixed [L, B_dec, C, H_kv, Dh] cache; this
//! module manages the B_dec slots: allocation, host staging (scattering a
//! prefill batch's [L, B_pre, S, ...] cache rows into slots), per-slot
//! lengths and release. The staging buffer is the host mirror the engine
//! uploads each decode step (see EXPERIMENTS.md §Perf for the measured
//! cost and the device-resident variant).

use anyhow::{bail, Result};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SlotState {
    Free,
    Active { seq_id: u64 },
}

pub struct KvSlots {
    pub n_layers: usize,
    pub n_slots: usize,
    pub cache_len: usize,
    pub kv_heads: usize,
    pub head_dim: usize,
    /// host mirrors [L, B, C, H, D]
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub state: Vec<SlotState>,
    /// valid prefix length per slot (== next write position)
    pub len: Vec<usize>,
}

impl KvSlots {
    pub fn new(
        n_layers: usize,
        n_slots: usize,
        cache_len: usize,
        kv_heads: usize,
        head_dim: usize,
    ) -> KvSlots {
        let sz = n_layers * n_slots * cache_len * kv_heads * head_dim;
        KvSlots {
            n_layers,
            n_slots,
            cache_len,
            kv_heads,
            head_dim,
            k: vec![0.0; sz],
            v: vec![0.0; sz],
            state: vec![SlotState::Free; n_slots],
            len: vec![0; n_slots],
        }
    }

    pub fn free_slots(&self) -> usize {
        self.state.iter().filter(|s| **s == SlotState::Free).count()
    }

    pub fn active_slots(&self) -> Vec<usize> {
        (0..self.n_slots)
            .filter(|&i| matches!(self.state[i], SlotState::Active { .. }))
            .collect()
    }

    pub fn seq_at(&self, slot: usize) -> Option<u64> {
        match self.state[slot] {
            SlotState::Active { seq_id } => Some(seq_id),
            SlotState::Free => None,
        }
    }

    fn slot_stride(&self) -> usize {
        self.cache_len * self.kv_heads * self.head_dim
    }

    fn layer_stride(&self) -> usize {
        self.n_slots * self.slot_stride()
    }

    /// Claim a free slot for sequence `seq_id`, scattering its prefill
    /// KV rows (row `src_row` of a [L, B_pre, S, H, D] prefill cache) into
    /// the slot and zeroing the tail.
    #[allow(clippy::too_many_arguments)]
    pub fn admit(
        &mut self,
        seq_id: u64,
        prefill_k: &[f32],
        prefill_v: &[f32],
        src_row: usize,
        pre_batch: usize,
        seq_len: usize,
        valid_len: usize,
    ) -> Result<usize> {
        let slot = match self.state.iter().position(|s| *s == SlotState::Free)
        {
            Some(s) => s,
            None => bail!("no free KV slots"),
        };
        if valid_len > self.cache_len {
            bail!("prefill length {valid_len} exceeds cache {}",
                  self.cache_len);
        }
        let row_sz = self.kv_heads * self.head_dim;
        let pre_layer_stride = pre_batch * seq_len * row_sz;
        let pre_row_stride = seq_len * row_sz;
        let slot_stride = self.slot_stride();
        for l in 0..self.n_layers {
            let dst_base = l * self.layer_stride() + slot * slot_stride;
            let src_base = l * pre_layer_stride + src_row * pre_row_stride;
            let n = valid_len * row_sz;
            self.k[dst_base..dst_base + n]
                .copy_from_slice(&prefill_k[src_base..src_base + n]);
            self.v[dst_base..dst_base + n]
                .copy_from_slice(&prefill_v[src_base..src_base + n]);
            // zero the tail: decode's one-hot write ADDS, so stale values
            // at positions >= valid_len would corrupt the cache.
            self.k[dst_base + n..dst_base + slot_stride].fill(0.0);
            self.v[dst_base + n..dst_base + slot_stride].fill(0.0);
        }
        self.state[slot] = SlotState::Active { seq_id };
        self.len[slot] = valid_len;
        Ok(slot)
    }

    /// Merge the decode output caches back into the host mirror and bump
    /// slot lengths — but ONLY for the slots that actually stepped. The
    /// engine writes a K/V row for *every* batch row (static shapes), so
    /// rows that belong to a different decode group this iteration, or to
    /// no sequence at all, carry garbage at their write position; copying
    /// the whole cache would corrupt them.
    pub fn absorb_decode_output(&mut self, k: Vec<f32>, v: Vec<f32>,
                                stepped: &[usize]) {
        debug_assert_eq!(k.len(), self.k.len());
        let slot_stride = self.slot_stride();
        for l in 0..self.n_layers {
            let lbase = l * self.layer_stride();
            for &slot in stepped {
                let a = lbase + slot * slot_stride;
                self.k[a..a + slot_stride]
                    .copy_from_slice(&k[a..a + slot_stride]);
                self.v[a..a + slot_stride]
                    .copy_from_slice(&v[a..a + slot_stride]);
            }
        }
        for &slot in stepped {
            self.len[slot] += 1;
        }
    }

    pub fn release(&mut self, slot: usize) {
        self.state[slot] = SlotState::Free;
        self.len[slot] = 0;
    }

    /// Invariant checks used by property tests.
    pub fn check_invariants(&self) -> Result<()> {
        let mut seen = std::collections::HashSet::new();
        for (i, s) in self.state.iter().enumerate() {
            if let SlotState::Active { seq_id } = s {
                if !seen.insert(*seq_id) {
                    bail!("seq {seq_id} owns two slots");
                }
                if self.len[i] == 0 {
                    bail!("active slot {i} has zero length");
                }
                if self.len[i] > self.cache_len {
                    bail!("slot {i} overflows cache");
                }
            } else if self.len[i] != 0 {
                bail!("free slot {i} has nonzero length");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk() -> KvSlots {
        KvSlots::new(2, 3, 8, 1, 4)
    }

    #[test]
    fn admit_scatter_release() {
        let mut kv = mk();
        // prefill cache [L=2, B=2, S=4, H=1, D=4]
        let pre: Vec<f32> = (0..2 * 2 * 4 * 4).map(|i| i as f32).collect();
        let slot =
            kv.admit(7, &pre, &pre, 1, 2, 4, 3).unwrap();
        assert_eq!(slot, 0);
        assert_eq!(kv.len[0], 3);
        // layer 0, slot 0, pos 0 == prefill row 1, pos 0
        let got = &kv.k[0..4];
        let want = &pre[1 * 4 * 4..1 * 4 * 4 + 4];
        assert_eq!(got, want);
        // tail zeroed
        assert!(kv.k[3 * 4..8 * 4].iter().all(|&x| x == 0.0));
        kv.check_invariants().unwrap();
        kv.release(slot);
        assert_eq!(kv.free_slots(), 3);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn exhaustion() {
        let mut kv = mk();
        let pre = vec![0.5; 2 * 1 * 4 * 4];
        for i in 0..3 {
            kv.admit(i, &pre, &pre, 0, 1, 4, 2).unwrap();
        }
        assert!(kv.admit(99, &pre, &pre, 0, 1, 4, 2).is_err());
    }

    #[test]
    fn rejects_overflow() {
        let mut kv = mk();
        let pre = vec![0.5; 2 * 1 * 16 * 4];
        assert!(kv.admit(1, &pre, &pre, 0, 1, 16, 16).is_err());
    }
}
