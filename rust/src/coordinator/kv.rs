//! Block-paged KV store: the physical memory behind the block tables.
//!
//! [`KvPages`] owns the host K/V arrays in the paged layout
//! `[L, n_blocks, block_size, H_kv, D_h]` plus a
//! [`super::paged::BlockPool`] that hands out physical block ids.
//! Admission stages a prefill batch's KV rows **block by block** through
//! each sequence's table (copy-on-admit), so a long prompt needs free
//! blocks *anywhere* in the pool — never a contiguous run; decode
//! appends each new token's K/V into the sequence's tail block through
//! a [`PagedKv`] view, allocating a fresh block only on a block
//! boundary. The engine uploads or addresses this mirror per backend:
//! the native engine walks the block tables directly, compiled static
//! backends get a contiguous gather from the default
//! [`crate::runtime::Engine::decode_paged`].

use anyhow::{bail, Result};
use std::collections::HashMap;

use super::paged::{BlockPool, FragStats};
use crate::runtime::PagedKv;

/// Block-paged KV store (module docs).
pub struct KvPages {
    /// transformer layers
    pub n_layers: usize,
    /// KV heads per layer
    pub kv_heads: usize,
    /// head dimension
    pub head_dim: usize,
    /// per-sequence token ceiling — the decode artifact's static cache
    /// length, which is what a compiled contiguous gather can address
    pub max_seq_tokens: usize,
    pool: BlockPool,
    /// keys, `[L, n_blocks, block_size, H_kv * D_h]`
    k: Vec<f32>,
    /// values, same layout
    v: Vec<f32>,
    /// valid token prefix per admitted sequence
    len: HashMap<u64, usize>,
}

impl KvPages {
    /// A store of `n_blocks` blocks of `block_size` token rows each,
    /// shared by all sequences; `max_seq_tokens` caps any one sequence.
    pub fn new(
        n_layers: usize,
        n_blocks: usize,
        block_size: usize,
        kv_heads: usize,
        head_dim: usize,
        max_seq_tokens: usize,
    ) -> KvPages {
        let sz = n_layers * n_blocks * block_size * kv_heads * head_dim;
        KvPages {
            n_layers,
            kv_heads,
            head_dim,
            max_seq_tokens,
            pool: BlockPool::new(n_blocks, block_size),
            k: vec![0.0; sz],
            v: vec![0.0; sz],
            len: HashMap::new(),
        }
    }

    /// `H_kv * D_h` floats per token row.
    pub fn kv_dim(&self) -> usize {
        self.kv_heads * self.head_dim
    }

    /// Tokens per block.
    pub fn block_size(&self) -> usize {
        self.pool.block_size()
    }

    /// Total physical blocks.
    pub fn n_blocks(&self) -> usize {
        self.pool.n_blocks()
    }

    /// Currently free blocks.
    pub fn free_blocks(&self) -> usize {
        self.pool.free_blocks()
    }

    /// Blocks needed for `tokens` tokens.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        self.pool.blocks_for(tokens)
    }

    /// Whether a sequence of `tokens` tokens could be admitted now.
    pub fn can_admit(&self, tokens: usize) -> bool {
        tokens <= self.max_seq_tokens && self.pool.can_admit(tokens)
    }

    /// Free-list fragmentation snapshot (observability).
    pub fn frag_stats(&self) -> FragStats {
        self.pool.frag_stats()
    }

    /// Admitted sequence ids, ascending.
    pub fn active(&self) -> Vec<u64> {
        let mut ids: Vec<u64> = self.len.keys().copied().collect();
        ids.sort_unstable();
        ids
    }

    /// Valid token prefix of an admitted sequence.
    pub fn seq_len(&self, seq: u64) -> Option<usize> {
        self.len.get(&seq).copied()
    }

    /// The sequence's block table (physical ids in token order).
    pub fn table(&self, seq: u64) -> Option<&[u32]> {
        self.pool.table(seq)
    }

    /// float offset of (layer, block, in-block row 0)
    fn block_base(&self, layer: usize, block: u32) -> usize {
        ((layer * self.n_blocks() + block as usize) * self.block_size())
            * self.kv_dim()
    }

    /// Zero the physical storage of `blocks` in every layer. Decode's
    /// paged append writes one row at a time, so stale data past a
    /// sequence's valid prefix must never be observable.
    fn zero_blocks(&mut self, blocks: &[u32]) {
        let span = self.block_size() * self.kv_dim();
        for l in 0..self.n_layers {
            for &b in blocks {
                let at = self.block_base(l, b);
                self.k[at..at + span].fill(0.0);
                self.v[at..at + span].fill(0.0);
            }
        }
    }

    /// Admit sequence `seq_id` from a token-packed prefill cache
    /// `[L, total_tokens, H, D]`: its K/V occupy rows
    /// `start .. start + valid_len` of every layer, and are staged
    /// block-by-block into a freshly allocated table covering
    /// `reserve_tokens` (≥ `valid_len`; the scheduler reserves
    /// `prompt + max_new_tokens` so decode growth can never fail).
    /// All allocated blocks are zeroed before staging.
    pub fn admit_packed(
        &mut self,
        seq_id: u64,
        packed_k: &[f32],
        packed_v: &[f32],
        start: usize,
        total_tokens: usize,
        valid_len: usize,
        reserve_tokens: usize,
    ) -> Result<()> {
        if valid_len == 0 {
            bail!("admit of empty sequence {seq_id}");
        }
        let reserve = reserve_tokens.max(valid_len);
        if reserve > self.max_seq_tokens {
            bail!(
                "sequence {seq_id} needs {reserve} tokens, cache holds {}",
                self.max_seq_tokens
            );
        }
        if start + valid_len > total_tokens {
            bail!(
                "packed rows {start}..{} exceed batch of {total_tokens}",
                start + valid_len
            );
        }
        let table: Vec<u32> = self.pool.allocate(seq_id, reserve)?.to_vec();
        self.zero_blocks(&table);
        let row_sz = self.kv_dim();
        let bs = self.block_size();
        for l in 0..self.n_layers {
            let mut done = 0usize;
            for &blk in &table {
                if done >= valid_len {
                    break;
                }
                let rows = bs.min(valid_len - done);
                let src = (l * total_tokens + start + done) * row_sz;
                let dst = self.block_base(l, blk);
                self.k[dst..dst + rows * row_sz]
                    .copy_from_slice(&packed_k[src..src + rows * row_sz]);
                self.v[dst..dst + rows * row_sz]
                    .copy_from_slice(&packed_v[src..src + rows * row_sz]);
                done += rows;
            }
        }
        self.len.insert(seq_id, valid_len);
        Ok(())
    }

    /// Admit from a right-padded `[L, B_pre, S, H, D]` prefill cache:
    /// row `src_row`'s first `valid_len` positions. The padded layout is
    /// the packed layout with `pre_batch * seq_len` total rows and this
    /// request's rows starting at `src_row * seq_len`, so this delegates
    /// to [`KvPages::admit_packed`].
    #[allow(clippy::too_many_arguments)]
    pub fn admit(
        &mut self,
        seq_id: u64,
        prefill_k: &[f32],
        prefill_v: &[f32],
        src_row: usize,
        pre_batch: usize,
        seq_len: usize,
        valid_len: usize,
        reserve_tokens: usize,
    ) -> Result<()> {
        self.admit_packed(
            seq_id,
            prefill_k,
            prefill_v,
            src_row * seq_len,
            pre_batch * seq_len,
            valid_len,
            reserve_tokens,
        )
    }

    /// Share the first `n_blocks` of `parent`'s table with a new
    /// sequence `child` (prefix-cache hit): pure refcount accounting in
    /// the [`BlockPool`] — no KV rows move. The child has a block table
    /// but no valid length until [`KvPages::admit_packed_prefixed`]
    /// stages its suffix, so prefix-cache *nodes* (which are never
    /// admitted) simply hold block tables that keep their blocks alive.
    pub fn fork_prefix(
        &mut self,
        parent: u64,
        child: u64,
        n_blocks: usize,
    ) -> Result<()> {
        self.pool.fork_prefix(parent, child, n_blocks)
    }

    /// Copy-on-write `seq`'s table entry `block_idx` if the physical
    /// block is shared: the pool swaps in a fresh block and this copies
    /// the old block's K/V payload into it across all layers, so the
    /// caller may then overwrite rows without disturbing other owners.
    /// Returns the `(old, new)` physical ids when a copy happened.
    pub fn cow_block(&mut self, seq: u64, block_idx: usize)
                     -> Result<Option<(u32, u32)>> {
        let Some((old, new)) = self.pool.cow(seq, block_idx)? else {
            return Ok(None);
        };
        let span = self.block_size() * self.kv_dim();
        for l in 0..self.n_layers {
            let src = self.block_base(l, old);
            let dst = self.block_base(l, new);
            self.k.copy_within(src..src + span, dst);
            self.v.copy_within(src..src + span, dst);
        }
        Ok(Some((old, new)))
    }

    /// Make the block holding token position `pos` exclusively owned
    /// before a write lands there (decode appends into a possibly
    /// shared tail block). No-op when the block is already exclusive.
    pub fn make_writable(&mut self, seq: u64, pos: usize) -> Result<()> {
        let idx = pos / self.block_size();
        self.cow_block(seq, idx).map(|_| ())
    }

    /// Whether the block holding token position `pos` of `seq` is
    /// shared with another owner, i.e. a write there will trigger a
    /// copy-on-write (and hence needs a spare block). False for
    /// unknown sequences or positions past the table.
    pub fn is_shared(&self, seq: u64, pos: usize) -> bool {
        let Some(table) = self.pool.table(seq) else {
            return false;
        };
        match table.get(pos / self.block_size()) {
            Some(&b) => self.pool.refcount_of(b).unwrap_or(0) > 1,
            None => false,
        }
    }

    /// Admit a sequence whose first `cached_len` KV rows already live in
    /// its block table (shared via [`KvPages::fork_prefix`]): stage only
    /// the `suffix_len` freshly computed rows — packed at rows
    /// `start .. start + suffix_len` of a `[L, total, H, D]` cache —
    /// at positions `cached_len ..` of the sequence, growing the table
    /// to `reserve_tokens`. If `cached_len` is not block-aligned the
    /// boundary block is shared *and* partially overwritten, so it is
    /// copy-on-written first and its stale tail rows zeroed.
    #[allow(clippy::too_many_arguments)]
    pub fn admit_packed_prefixed(
        &mut self,
        seq_id: u64,
        packed_k: &[f32],
        packed_v: &[f32],
        start: usize,
        total_tokens: usize,
        cached_len: usize,
        suffix_len: usize,
        reserve_tokens: usize,
    ) -> Result<()> {
        let bs = self.block_size();
        let row_sz = self.kv_dim();
        if cached_len == 0 || suffix_len == 0 {
            bail!(
                "prefixed admit of seq {seq_id} needs a nonempty cached \
                 prefix and suffix (got {cached_len}+{suffix_len})"
            );
        }
        if self.len.contains_key(&seq_id) {
            bail!("seq {seq_id} already admitted");
        }
        let have = self
            .pool
            .table(seq_id)
            .map(|t| t.len())
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "prefixed admit of seq {seq_id} without a forked table"
                )
            })?;
        if have * bs < cached_len {
            bail!(
                "seq {seq_id}'s forked table covers {} tokens, \
                 cached prefix claims {cached_len}",
                have * bs
            );
        }
        let valid_len = cached_len + suffix_len;
        let reserve = reserve_tokens.max(valid_len);
        if reserve > self.max_seq_tokens {
            bail!(
                "sequence {seq_id} needs {reserve} tokens, cache holds {}",
                self.max_seq_tokens
            );
        }
        if start + suffix_len > total_tokens {
            bail!(
                "packed rows {start}..{} exceed batch of {total_tokens}",
                start + suffix_len
            );
        }
        let added = self.pool.extend(seq_id, reserve)?;
        if !added.is_empty() {
            self.zero_blocks(&added);
        }
        // boundary block: shared with the cache node but about to take
        // suffix rows — copy it, then clear the donor's stale tail
        let off = cached_len % bs;
        if off != 0 {
            let bidx = cached_len / bs;
            self.cow_block(seq_id, bidx)?;
            let blk = self.pool.table(seq_id).unwrap()[bidx];
            for l in 0..self.n_layers {
                let at = self.block_base(l, blk) + off * row_sz;
                let end = self.block_base(l, blk) + bs * row_sz;
                self.k[at..end].fill(0.0);
                self.v[at..end].fill(0.0);
            }
        }
        let table: Vec<u32> = self.pool.table(seq_id).unwrap().to_vec();
        for l in 0..self.n_layers {
            for r in 0..suffix_len {
                let pos = cached_len + r;
                let blk = table[pos / bs];
                let src = (l * total_tokens + start + r) * row_sz;
                let dst = self.block_base(l, blk) + (pos % bs) * row_sz;
                self.k[dst..dst + row_sz]
                    .copy_from_slice(&packed_k[src..src + row_sz]);
                self.v[dst..dst + row_sz]
                    .copy_from_slice(&packed_v[src..src + row_sz]);
            }
        }
        self.len.insert(seq_id, valid_len);
        Ok(())
    }

    /// Stage a continuation chunk for an *already admitted* sequence:
    /// `suffix_len` freshly computed KV rows — packed at rows
    /// `start .. start + suffix_len` of a `[L, total, H, D]` cache —
    /// appended at the sequence's current valid length. The block table
    /// grows on demand ([`BlockPool::extend`]), so chunked prefill
    /// reserves nothing beyond what it has actually computed; the
    /// boundary block is made writable first (a no-op unless a cached
    /// prefix left it shared).
    pub fn extend_packed(
        &mut self,
        seq_id: u64,
        packed_k: &[f32],
        packed_v: &[f32],
        start: usize,
        total_tokens: usize,
        suffix_len: usize,
    ) -> Result<()> {
        let Some(&len) = self.len.get(&seq_id) else {
            bail!("continuation chunk for unadmitted seq {seq_id}");
        };
        if suffix_len == 0 {
            bail!("empty continuation chunk for seq {seq_id}");
        }
        let new_len = len + suffix_len;
        if new_len > self.max_seq_tokens {
            bail!(
                "sequence {seq_id} grew to {new_len} tokens, cache \
                 holds {}",
                self.max_seq_tokens
            );
        }
        if start + suffix_len > total_tokens {
            bail!(
                "packed rows {start}..{} exceed batch of {total_tokens}",
                start + suffix_len
            );
        }
        let added = self.pool.extend(seq_id, new_len)?;
        if !added.is_empty() {
            self.zero_blocks(&added);
        }
        self.make_writable(seq_id, len)?;
        let bs = self.block_size();
        let row_sz = self.kv_dim();
        let table: Vec<u32> = self.pool.table(seq_id).unwrap().to_vec();
        for l in 0..self.n_layers {
            for r in 0..suffix_len {
                let pos = len + r;
                let blk = table[pos / bs];
                let src = (l * total_tokens + start + r) * row_sz;
                let dst =
                    self.block_base(l, blk) + (pos % bs) * row_sz;
                self.k[dst..dst + row_sz]
                    .copy_from_slice(&packed_k[src..src + row_sz]);
                self.v[dst..dst + row_sz]
                    .copy_from_slice(&packed_v[src..src + row_sz]);
            }
        }
        self.len.insert(seq_id, new_len);
        Ok(())
    }

    /// Make sure `seq`'s table covers `tokens` tokens, allocating (and
    /// zeroing) tail blocks on a block boundary. A no-op while the
    /// admission-time reservation still covers the length.
    pub fn ensure_capacity(&mut self, seq: u64, tokens: usize)
                           -> Result<()> {
        if tokens > self.max_seq_tokens {
            bail!(
                "sequence {seq} grew to {tokens} tokens, cache holds {}",
                self.max_seq_tokens
            );
        }
        let added = self.pool.extend(seq, tokens)?;
        if !added.is_empty() {
            self.zero_blocks(&added);
        }
        Ok(())
    }

    /// Bump `seq`'s valid length after the engine appended one decoded
    /// token's K/V through the paged view.
    pub fn advance(&mut self, seq: u64) -> Result<()> {
        let Some(len) = self.len.get_mut(&seq) else {
            bail!("advance of unknown seq {seq}");
        };
        let cap = self
            .pool
            .table(seq)
            .map(|t| t.len() * self.pool.block_size())
            .unwrap_or(0);
        if *len + 1 > cap {
            bail!("seq {seq} advanced past its block table ({cap} tokens)");
        }
        *len += 1;
        Ok(())
    }

    /// Release a sequence's blocks back to the pool.
    pub fn release(&mut self, seq: u64) -> Result<()> {
        self.pool.release(seq)?;
        self.len.remove(&seq);
        Ok(())
    }

    /// A [`PagedKv`] view for one decode step: `rows[i]` names the
    /// sequence occupying decode-batch row `i` (`None` = static-shape
    /// filler row with an empty table). Tables are snapshotted into the
    /// view; the K/V storage is borrowed mutably.
    pub fn view(&mut self, rows: &[Option<u64>]) -> PagedKv<'_> {
        let tables: Vec<Vec<u32>> = rows
            .iter()
            .map(|r| match r {
                Some(id) => self
                    .pool
                    .table(*id)
                    .map(|t| t.to_vec())
                    .unwrap_or_default(),
                None => Vec::new(),
            })
            .collect();
        PagedKv {
            n_layers: self.n_layers,
            n_blocks: self.pool.n_blocks(),
            block_size: self.pool.block_size(),
            kv_dim: self.kv_heads * self.head_dim,
            tables,
            k: &mut self.k,
            v: &mut self.v,
        }
    }

    /// Contiguous `[L, rows, H*D]` gather of a sequence's first `rows`
    /// positions — the slot-style view, for parity tests and contiguous
    /// backends.
    pub fn gather_seq(&self, seq: u64, rows: usize)
                      -> Option<(Vec<f32>, Vec<f32>)> {
        let table = self.pool.table(seq)?;
        let kvd = self.kv_dim();
        let bs = self.block_size();
        if rows > table.len() * bs {
            return None;
        }
        let mut gk = vec![0.0f32; self.n_layers * rows * kvd];
        let mut gv = vec![0.0f32; self.n_layers * rows * kvd];
        for l in 0..self.n_layers {
            let mut at = 0usize;
            for &blk in table {
                if at >= rows {
                    break;
                }
                let n = bs.min(rows - at);
                let src = self.block_base(l, blk);
                let dst = (l * rows + at) * kvd;
                gk[dst..dst + n * kvd]
                    .copy_from_slice(&self.k[src..src + n * kvd]);
                gv[dst..dst + n * kvd]
                    .copy_from_slice(&self.v[src..src + n * kvd]);
                at += n;
            }
        }
        Some((gk, gv))
    }

    /// Invariant checks used by the property/parity suites.
    pub fn check_invariants(&self) -> Result<()> {
        self.pool.check_invariants()?;
        for (&seq, &len) in &self.len {
            let Some(table) = self.pool.table(seq) else {
                bail!("seq {seq} has a length but no block table");
            };
            if len == 0 {
                bail!("admitted seq {seq} has zero length");
            }
            if len > table.len() * self.pool.block_size() {
                bail!("seq {seq} length {len} overflows its table");
            }
            if len > self.max_seq_tokens {
                bail!("seq {seq} overflows the per-sequence cap");
            }
        }
        // every owned table belongs to an admitted sequence
        for seq in self.active() {
            if self.pool.table(seq).is_none() {
                bail!("seq {seq} admitted without blocks");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(block: usize) -> KvPages {
        // 2 layers, capacity 3 seqs x 8 tokens, H*D = 4
        KvPages::new(2, 24 / block, block, 1, 4, 8)
    }

    #[test]
    fn admit_stage_release() {
        let mut kv = mk(4);
        // prefill cache [L=2, B=2, S=4, H=1, D=4]
        let pre: Vec<f32> = (0..2 * 2 * 4 * 4).map(|i| i as f32).collect();
        kv.admit(7, &pre, &pre, 1, 2, 4, 3, 3).unwrap();
        assert_eq!(kv.seq_len(7), Some(3));
        // gather reproduces prefill row 1's first 3 positions per layer
        let (gk, _) = kv.gather_seq(7, 3).unwrap();
        for l in 0..2 {
            let src = (l * 2 + 1) * 4 * 4;
            assert_eq!(&gk[l * 3 * 4..(l * 3 + 3) * 4],
                       &pre[src..src + 3 * 4]);
        }
        kv.check_invariants().unwrap();
        kv.release(7).unwrap();
        assert_eq!(kv.free_blocks(), kv.n_blocks());
        kv.check_invariants().unwrap();
    }

    #[test]
    fn admit_packed_matches_padded_admit_across_block_sizes() {
        // the same rows staged through [L, B, S, H, D] and through the
        // packed [L, total, H, D] layout must land identically
        let (l, b, s, hd) = (2usize, 2usize, 4usize, 4usize);
        let pre: Vec<f32> =
            (0..l * b * s * hd).map(|i| i as f32).collect();
        let lens = [3usize, 4usize];
        let total: usize = lens.iter().sum();
        let mut packed = vec![0.0f32; l * total * hd];
        for li in 0..l {
            let mut row = 0usize;
            for (bi, &len) in lens.iter().enumerate() {
                let src = (li * b + bi) * s * hd;
                let dst = (li * total + row) * hd;
                packed[dst..dst + len * hd]
                    .copy_from_slice(&pre[src..src + len * hd]);
                row += len;
            }
        }
        for block in [2usize, 4, 8] {
            let mut kv_a = mk(block);
            let mut kv_b = mk(block);
            for (bi, &len) in lens.iter().enumerate() {
                kv_a.admit(bi as u64, &pre, &pre, bi, b, s, len, len)
                    .unwrap();
                let start: usize = lens[..bi].iter().sum();
                kv_b.admit_packed(
                    bi as u64, &packed, &packed, start, total, len, len,
                )
                .unwrap();
            }
            for (bi, &len) in lens.iter().enumerate() {
                assert_eq!(
                    kv_a.gather_seq(bi as u64, len),
                    kv_b.gather_seq(bi as u64, len),
                    "block {block} seq {bi}"
                );
            }
            kv_b.check_invariants().unwrap();
        }
    }

    #[test]
    fn reservation_spans_blocks_and_zeroes_them() {
        let mut kv = mk(4);
        let pre: Vec<f32> = (0..2 * 6 * 4).map(|_| 1.5f32).collect();
        // 3 valid tokens, reserve 7 -> 2 blocks; tail must be zero
        kv.admit_packed(1, &pre, &pre, 0, 6, 3, 7).unwrap();
        assert_eq!(kv.table(1).unwrap().len(), 2);
        let (gk, gv) = kv.gather_seq(1, 7).unwrap();
        for l in 0..2 {
            let base = l * 7 * 4;
            assert!(gk[base..base + 3 * 4].iter().all(|&x| x == 1.5));
            assert!(gk[base + 3 * 4..base + 7 * 4]
                .iter()
                .chain(gv[base + 3 * 4..base + 7 * 4].iter())
                .all(|&x| x == 0.0));
        }
    }

    #[test]
    fn admit_packed_rejects_out_of_range_rows() {
        let mut kv = mk(4);
        let packed = vec![0.5f32; 2 * 6 * 4];
        assert!(kv
            .admit_packed(1, &packed, &packed, 4, 6, 4, 4)
            .is_err());
    }

    #[test]
    fn exhaustion_and_per_seq_cap() {
        let mut kv = mk(8); // 3 blocks of 8
        let pre = vec![0.5; 2 * 8 * 4];
        for i in 0..3 {
            kv.admit_packed(i, &pre, &pre, 0, 8, 2, 8).unwrap();
        }
        assert!(kv.admit_packed(99, &pre, &pre, 0, 8, 2, 8).is_err());
        kv.release(0).unwrap();
        // per-sequence cap: 9 > max_seq_tokens 8
        assert!(kv.admit_packed(99, &pre, &pre, 0, 8, 2, 9).is_err());
        assert!(!kv.can_admit(9));
    }

    #[test]
    fn ensure_capacity_allocates_on_block_boundary() {
        let mut kv = mk(4);
        let pre = vec![0.5; 2 * 4 * 4];
        kv.admit_packed(1, &pre, &pre, 0, 4, 4, 4).unwrap(); // 1 block
        assert_eq!(kv.table(1).unwrap().len(), 1);
        kv.ensure_capacity(1, 4).unwrap(); // still 1 block
        assert_eq!(kv.table(1).unwrap().len(), 1);
        kv.ensure_capacity(1, 5).unwrap(); // boundary -> 2 blocks
        assert_eq!(kv.table(1).unwrap().len(), 2);
        kv.advance(1).unwrap();
        assert_eq!(kv.seq_len(1), Some(5));
        // growth past the per-seq cap is rejected
        assert!(kv.ensure_capacity(1, 9).is_err());
        kv.check_invariants().unwrap();
    }

    /// Packed single-seq cache `[L=2, total, H*D=4]` with row value
    /// `layer*1000 + row*10 + lane`.
    fn packed(total: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; 2 * total * 4];
        for l in 0..2 {
            for r in 0..total {
                for d in 0..4 {
                    out[(l * total + r) * 4 + d] =
                        (l * 1000 + r * 10 + d) as f32;
                }
            }
        }
        out
    }

    #[test]
    fn prefixed_admit_matches_cold_admit_aligned() {
        // donor holds 8 tokens (2 blocks of 4); a fork of its first
        // block plus a staged suffix must gather identically to a cold
        // admit of the same 7 rows
        let pre = packed(8);
        let mut kv = mk(4);
        kv.admit_packed(1, &pre, &pre, 0, 8, 8, 8).unwrap();
        kv.fork_prefix(1, 2, 1).unwrap();
        // suffix rows 4..7 of the same cache, cached_len = 4
        kv.admit_packed_prefixed(2, &pre, &pre, 4, 8, 4, 3, 7).unwrap();
        assert_eq!(kv.seq_len(2), Some(7));
        let mut cold = mk(4);
        cold.admit_packed(2, &pre, &pre, 0, 8, 7, 7).unwrap();
        assert_eq!(kv.gather_seq(2, 7), cold.gather_seq(2, 7));
        // shared leading block, fresh tail block
        assert_eq!(kv.table(2).unwrap()[0], kv.table(1).unwrap()[0]);
        assert_ne!(kv.table(2).unwrap()[1], kv.table(1).unwrap()[1]);
        kv.check_invariants().unwrap();
        kv.release(1).unwrap();
        kv.release(2).unwrap();
        assert_eq!(kv.free_blocks(), kv.n_blocks());
    }

    #[test]
    fn prefixed_admit_cows_unaligned_boundary_block() {
        // cached_len = 3 lands mid-block: the boundary block must be
        // copied before the suffix overwrites rows 3.., leaving the
        // donor's rows intact
        let pre = packed(8);
        let mut kv = mk(4);
        kv.admit_packed(1, &pre, &pre, 0, 8, 6, 6).unwrap();
        kv.fork_prefix(1, 2, 1).unwrap();
        let shared = kv.table(2).unwrap()[0];
        kv.admit_packed_prefixed(2, &pre, &pre, 3, 8, 3, 4, 7).unwrap();
        assert_ne!(kv.table(2).unwrap()[0], shared, "boundary not CoW'd");
        let mut cold = mk(4);
        cold.admit_packed(2, &pre, &pre, 0, 8, 7, 7).unwrap();
        assert_eq!(kv.gather_seq(2, 7), cold.gather_seq(2, 7));
        // donor unchanged
        let mut donor = mk(4);
        donor.admit_packed(1, &pre, &pre, 0, 8, 6, 6).unwrap();
        assert_eq!(kv.gather_seq(1, 6), donor.gather_seq(1, 6));
        kv.check_invariants().unwrap();
    }

    #[test]
    fn prefixed_admit_validates_preconditions() {
        let pre = packed(8);
        let mut kv = mk(4);
        kv.admit_packed(1, &pre, &pre, 0, 8, 8, 8).unwrap();
        // no forked table
        assert!(kv
            .admit_packed_prefixed(9, &pre, &pre, 4, 8, 4, 2, 6)
            .is_err());
        kv.fork_prefix(1, 2, 1).unwrap();
        // cached prefix beyond the forked table (1 block = 4 tokens)
        assert!(kv
            .admit_packed_prefixed(2, &pre, &pre, 4, 8, 5, 2, 7)
            .is_err());
        // empty suffix / empty prefix
        assert!(kv
            .admit_packed_prefixed(2, &pre, &pre, 4, 8, 4, 0, 6)
            .is_err());
        assert!(kv
            .admit_packed_prefixed(2, &pre, &pre, 4, 8, 0, 2, 6)
            .is_err());
        // reserve past the per-seq cap (mk: max_seq_tokens = 8)
        assert!(kv
            .admit_packed_prefixed(2, &pre, &pre, 4, 8, 4, 2, 9)
            .is_err());
        // packed rows out of range
        assert!(kv
            .admit_packed_prefixed(2, &pre, &pre, 7, 8, 4, 2, 6)
            .is_err());
        // the happy path still works after all those rejections
        kv.admit_packed_prefixed(2, &pre, &pre, 4, 8, 4, 2, 6).unwrap();
        // double admit
        assert!(kv
            .admit_packed_prefixed(2, &pre, &pre, 4, 8, 4, 2, 6)
            .is_err());
        kv.check_invariants().unwrap();
    }

    #[test]
    fn extend_packed_chunks_gather_like_one_cold_admit() {
        // staging 7 rows as 3 + 2 + 2 chunks (on-demand block growth)
        // must gather bitwise-identically to one cold 7-row admit
        let pre = packed(8);
        let mut kv = mk(4);
        kv.admit_packed(1, &pre, &pre, 0, 8, 3, 3).unwrap();
        assert_eq!(kv.table(1).unwrap().len(), 1); // nothing reserved
        kv.extend_packed(1, &pre, &pre, 3, 8, 2).unwrap();
        assert_eq!(kv.seq_len(1), Some(5));
        assert_eq!(kv.table(1).unwrap().len(), 2); // grew on demand
        kv.extend_packed(1, &pre, &pre, 5, 8, 2).unwrap();
        assert_eq!(kv.seq_len(1), Some(7));
        let mut cold = mk(4);
        cold.admit_packed(1, &pre, &pre, 0, 8, 7, 7).unwrap();
        assert_eq!(kv.gather_seq(1, 7), cold.gather_seq(1, 7));
        kv.check_invariants().unwrap();
        kv.release(1).unwrap();
        assert_eq!(kv.free_blocks(), kv.n_blocks());
    }

    #[test]
    fn extend_packed_after_prefixed_admit_cows_nothing_extra() {
        // chunk 2 of a warm request: the append boundary is past the
        // forked prefix, so no block may be copied and the donor stays
        // bitwise intact
        let pre = packed(8);
        let mut kv = mk(4);
        kv.admit_packed(1, &pre, &pre, 0, 8, 8, 8).unwrap();
        kv.fork_prefix(1, 2, 1).unwrap();
        kv.admit_packed_prefixed(2, &pre, &pre, 4, 8, 4, 2, 6).unwrap();
        let tail = kv.table(2).unwrap()[1];
        kv.extend_packed(2, &pre, &pre, 6, 8, 2).unwrap();
        assert_eq!(kv.table(2).unwrap()[1], tail, "tail block was CoW'd");
        let mut cold = mk(4);
        cold.admit_packed(2, &pre, &pre, 0, 8, 8, 8).unwrap();
        assert_eq!(kv.gather_seq(2, 8), cold.gather_seq(2, 8));
        assert_eq!(kv.gather_seq(1, 8), cold.gather_seq(2, 8));
        kv.check_invariants().unwrap();
    }

    #[test]
    fn extend_packed_validates_preconditions() {
        let pre = packed(8);
        let mut kv = mk(4);
        // unknown sequence
        assert!(kv.extend_packed(9, &pre, &pre, 0, 8, 2).is_err());
        kv.admit_packed(1, &pre, &pre, 0, 8, 4, 4).unwrap();
        // empty chunk
        assert!(kv.extend_packed(1, &pre, &pre, 4, 8, 0).is_err());
        // growth past the per-seq cap (mk: max_seq_tokens = 8)
        assert!(kv.extend_packed(1, &pre, &pre, 0, 8, 5).is_err());
        // packed rows out of range
        assert!(kv.extend_packed(1, &pre, &pre, 7, 8, 2).is_err());
        // the happy path still works after the rejections
        kv.extend_packed(1, &pre, &pre, 4, 8, 4).unwrap();
        assert_eq!(kv.seq_len(1), Some(8));
        kv.check_invariants().unwrap();
    }

    #[test]
    fn make_writable_cows_shared_append_target() {
        let pre = packed(8);
        let mut kv = mk(4);
        kv.admit_packed(1, &pre, &pre, 0, 8, 8, 8).unwrap();
        kv.fork_prefix(1, 2, 2).unwrap();
        let shared_tail = kv.table(2).unwrap()[1];
        kv.make_writable(2, 5).unwrap(); // pos 5 -> block index 1
        let owned_tail = kv.table(2).unwrap()[1];
        assert_ne!(owned_tail, shared_tail);
        assert_eq!(kv.table(1).unwrap()[1], shared_tail);
        // payload was copied: both gathers still agree
        assert_eq!(kv.gather_seq(2, 8), kv.gather_seq(1, 8));
        // exclusive now: second call is a no-op
        kv.make_writable(2, 5).unwrap();
        assert_eq!(kv.table(2).unwrap()[1], owned_tail);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn is_shared_tracks_fork_and_cow() {
        let pre = packed(8);
        let mut kv = mk(4);
        kv.admit_packed(1, &pre, &pre, 0, 8, 8, 8).unwrap();
        assert!(!kv.is_shared(1, 0));
        kv.fork_prefix(1, 2, 2).unwrap(); // share both donor blocks
        assert!(kv.is_shared(1, 0));
        assert!(kv.is_shared(2, 5));
        kv.make_writable(2, 5).unwrap();
        assert!(!kv.is_shared(2, 5));
        // block 0 of seq 2 is still the shared donor block
        assert!(kv.is_shared(2, 0));
        // unknown sequence / past-the-table positions are not shared
        assert!(!kv.is_shared(99, 0));
        assert!(!kv.is_shared(1, 1000));
        kv.check_invariants().unwrap();
    }

    #[test]
    fn advance_past_table_is_an_error() {
        let mut kv = mk(4);
        let pre = vec![0.5; 2 * 4 * 4];
        kv.admit_packed(1, &pre, &pre, 0, 4, 4, 4).unwrap();
        // table covers 4 tokens, len is 4: advancing without
        // ensure_capacity must fail loudly
        assert!(kv.advance(1).is_err());
    }
}
