//! KV-cache slot manager.
//!
//! The decode executable owns a fixed [L, B_dec, C, H_kv, Dh] cache; this
//! module manages the B_dec slots: allocation, host staging (scattering a
//! prefill batch's [L, B_pre, S, ...] cache rows into slots), per-slot
//! lengths and release. The staging buffer is the host mirror the engine
//! uploads each decode step (see EXPERIMENTS.md §Perf for the measured
//! cost and the device-resident variant).

use anyhow::{bail, Result};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SlotState {
    Free,
    Active { seq_id: u64 },
}

pub struct KvSlots {
    pub n_layers: usize,
    pub n_slots: usize,
    pub cache_len: usize,
    pub kv_heads: usize,
    pub head_dim: usize,
    /// host mirrors [L, B, C, H, D]
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    pub state: Vec<SlotState>,
    /// valid prefix length per slot (== next write position)
    pub len: Vec<usize>,
}

impl KvSlots {
    pub fn new(
        n_layers: usize,
        n_slots: usize,
        cache_len: usize,
        kv_heads: usize,
        head_dim: usize,
    ) -> KvSlots {
        let sz = n_layers * n_slots * cache_len * kv_heads * head_dim;
        KvSlots {
            n_layers,
            n_slots,
            cache_len,
            kv_heads,
            head_dim,
            k: vec![0.0; sz],
            v: vec![0.0; sz],
            state: vec![SlotState::Free; n_slots],
            len: vec![0; n_slots],
        }
    }

    pub fn free_slots(&self) -> usize {
        self.state.iter().filter(|s| **s == SlotState::Free).count()
    }

    pub fn active_slots(&self) -> Vec<usize> {
        (0..self.n_slots)
            .filter(|&i| matches!(self.state[i], SlotState::Active { .. }))
            .collect()
    }

    pub fn seq_at(&self, slot: usize) -> Option<u64> {
        match self.state[slot] {
            SlotState::Active { seq_id } => Some(seq_id),
            SlotState::Free => None,
        }
    }

    fn slot_stride(&self) -> usize {
        self.cache_len * self.kv_heads * self.head_dim
    }

    fn layer_stride(&self) -> usize {
        self.n_slots * self.slot_stride()
    }

    /// Claim a free slot for sequence `seq_id`, scattering its prefill
    /// KV rows (row `src_row` of a [L, B_pre, S, H, D] prefill cache) into
    /// the slot and zeroing the tail.
    ///
    /// The padded layout is the packed layout with `pre_batch * seq_len`
    /// total rows and this request's rows starting at `src_row * seq_len`,
    /// so this delegates to [`KvSlots::admit_packed`] — one copy of the
    /// slot-claim / tail-zero logic.
    #[allow(clippy::too_many_arguments)]
    pub fn admit(
        &mut self,
        seq_id: u64,
        prefill_k: &[f32],
        prefill_v: &[f32],
        src_row: usize,
        pre_batch: usize,
        seq_len: usize,
        valid_len: usize,
    ) -> Result<usize> {
        self.admit_packed(
            seq_id,
            prefill_k,
            prefill_v,
            src_row * seq_len,
            pre_batch * seq_len,
            valid_len,
        )
    }

    /// Claim a free slot from a token-packed prefill cache
    /// `[L, total_tokens, H, D]`: this sequence's K/V occupy rows
    /// `start .. start + valid_len` of every layer. The slot tail is
    /// zeroed: decode's one-hot write ADDS, so stale values at positions
    /// >= valid_len would corrupt the cache.
    pub fn admit_packed(
        &mut self,
        seq_id: u64,
        packed_k: &[f32],
        packed_v: &[f32],
        start: usize,
        total_tokens: usize,
        valid_len: usize,
    ) -> Result<usize> {
        let slot = match self.state.iter().position(|s| *s == SlotState::Free)
        {
            Some(s) => s,
            None => bail!("no free KV slots"),
        };
        if valid_len > self.cache_len {
            bail!("prefill length {valid_len} exceeds cache {}",
                  self.cache_len);
        }
        if start + valid_len > total_tokens {
            bail!(
                "packed rows {start}..{} exceed batch of {total_tokens}",
                start + valid_len
            );
        }
        let row_sz = self.kv_heads * self.head_dim;
        let slot_stride = self.slot_stride();
        for l in 0..self.n_layers {
            let dst_base = l * self.layer_stride() + slot * slot_stride;
            let src_base = (l * total_tokens + start) * row_sz;
            let n = valid_len * row_sz;
            self.k[dst_base..dst_base + n]
                .copy_from_slice(&packed_k[src_base..src_base + n]);
            self.v[dst_base..dst_base + n]
                .copy_from_slice(&packed_v[src_base..src_base + n]);
            // zero the tail (see the doc comment above)
            self.k[dst_base + n..dst_base + slot_stride].fill(0.0);
            self.v[dst_base + n..dst_base + slot_stride].fill(0.0);
        }
        self.state[slot] = SlotState::Active { seq_id };
        self.len[slot] = valid_len;
        Ok(slot)
    }

    /// Merge the decode output caches back into the host mirror and bump
    /// slot lengths — but ONLY for the slots that actually stepped. The
    /// engine writes a K/V row for *every* batch row (static shapes), so
    /// rows that belong to a different decode group this iteration, or to
    /// no sequence at all, carry garbage at their write position; copying
    /// the whole cache would corrupt them.
    pub fn absorb_decode_output(&mut self, k: Vec<f32>, v: Vec<f32>,
                                stepped: &[usize]) {
        debug_assert_eq!(k.len(), self.k.len());
        let slot_stride = self.slot_stride();
        for l in 0..self.n_layers {
            let lbase = l * self.layer_stride();
            for &slot in stepped {
                let a = lbase + slot * slot_stride;
                self.k[a..a + slot_stride]
                    .copy_from_slice(&k[a..a + slot_stride]);
                self.v[a..a + slot_stride]
                    .copy_from_slice(&v[a..a + slot_stride]);
            }
        }
        for &slot in stepped {
            self.len[slot] += 1;
        }
    }

    pub fn release(&mut self, slot: usize) {
        self.state[slot] = SlotState::Free;
        self.len[slot] = 0;
    }

    /// Invariant checks used by property tests.
    pub fn check_invariants(&self) -> Result<()> {
        let mut seen = std::collections::HashSet::new();
        for (i, s) in self.state.iter().enumerate() {
            if let SlotState::Active { seq_id } = s {
                if !seen.insert(*seq_id) {
                    bail!("seq {seq_id} owns two slots");
                }
                if self.len[i] == 0 {
                    bail!("active slot {i} has zero length");
                }
                if self.len[i] > self.cache_len {
                    bail!("slot {i} overflows cache");
                }
            } else if self.len[i] != 0 {
                bail!("free slot {i} has nonzero length");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk() -> KvSlots {
        KvSlots::new(2, 3, 8, 1, 4)
    }

    #[test]
    fn admit_scatter_release() {
        let mut kv = mk();
        // prefill cache [L=2, B=2, S=4, H=1, D=4]
        let pre: Vec<f32> = (0..2 * 2 * 4 * 4).map(|i| i as f32).collect();
        let slot =
            kv.admit(7, &pre, &pre, 1, 2, 4, 3).unwrap();
        assert_eq!(slot, 0);
        assert_eq!(kv.len[0], 3);
        // layer 0, slot 0, pos 0 == prefill row 1, pos 0
        let got = &kv.k[0..4];
        let want = &pre[1 * 4 * 4..1 * 4 * 4 + 4];
        assert_eq!(got, want);
        // tail zeroed
        assert!(kv.k[3 * 4..8 * 4].iter().all(|&x| x == 0.0));
        kv.check_invariants().unwrap();
        kv.release(slot);
        assert_eq!(kv.free_slots(), 3);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn admit_packed_matches_padded_admit() {
        // the same rows staged through [L, B, S, H, D] and through the
        // packed [L, total, H, D] layout must land identically
        let (l, b, s, hd) = (2usize, 2usize, 4usize, 4usize);
        let pre: Vec<f32> =
            (0..l * b * s * hd).map(|i| i as f32).collect();
        // packed layout: request 0 = 3 rows, request 1 = 4 rows
        let lens = [3usize, 4usize];
        let total: usize = lens.iter().sum();
        let mut packed = vec![0.0f32; l * total * hd];
        for li in 0..l {
            let mut row = 0usize;
            for (bi, &len) in lens.iter().enumerate() {
                let src = (li * b + bi) * s * hd;
                let dst = (li * total + row) * hd;
                packed[dst..dst + len * hd]
                    .copy_from_slice(&pre[src..src + len * hd]);
                row += len;
            }
        }
        let mut kv_a = mk();
        let mut kv_b = mk();
        for (bi, &len) in lens.iter().enumerate() {
            let sa = kv_a
                .admit(bi as u64, &pre, &pre, bi, b, s, len)
                .unwrap();
            let start: usize = lens[..bi].iter().sum();
            let sb = kv_b
                .admit_packed(
                    bi as u64, &packed, &packed, start, total, len,
                )
                .unwrap();
            assert_eq!(sa, sb);
        }
        assert_eq!(kv_a.k, kv_b.k);
        assert_eq!(kv_a.len, kv_b.len);
        kv_b.check_invariants().unwrap();
    }

    #[test]
    fn admit_packed_rejects_out_of_range_rows() {
        let mut kv = mk();
        let packed = vec![0.5f32; 2 * 6 * 4];
        assert!(kv.admit_packed(1, &packed, &packed, 4, 6, 4).is_err());
    }

    #[test]
    fn exhaustion() {
        let mut kv = mk();
        let pre = vec![0.5; 2 * 1 * 4 * 4];
        for i in 0..3 {
            kv.admit(i, &pre, &pre, 0, 1, 4, 2).unwrap();
        }
        assert!(kv.admit(99, &pre, &pre, 0, 1, 4, 2).is_err());
    }

    #[test]
    fn rejects_overflow() {
        let mut kv = mk();
        let pre = vec![0.5; 2 * 1 * 16 * 4];
        assert!(kv.admit(1, &pre, &pre, 0, 1, 16, 16).is_err());
    }
}
