//! Execution substrate: thread pool + helpers (tokio is unavailable
//! offline; the coordinator is an explicit threaded pipeline instead).

pub mod pool;

pub use pool::ThreadPool;
