//! Fixed-size thread pool over std::sync::mpsc.
//!
//! The serving coordinator uses explicit threads (client simulation, TCP
//! acceptor, engine loop); the pool covers fan-out work such as parallel
//! artifact compilation, workload generation and — since the batched
//! prefill pipeline — the row-tile fan-out of `NmCompressedBatch` /
//! `dense_matmul_parallel` (the native engine owns one pool and hands it
//! to every projection kernel). Pool jobs are `'static`, so fan-out
//! callers share buffers with workers via `Arc` rather than borrows;
//! since the register-tiled kernel core, activations and weights are
//! `Arc`-threaded end-to-end through the pipeline, so submitting a
//! row-tile job copies nothing.
//!
//! Panic safety: a panicking job is caught inside the worker (the worker
//! thread survives and keeps draining the queue), and [`ThreadPool::map`]
//! re-raises the failure on the *calling* thread after every item has
//! settled — loud, and never a deadlock.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size worker pool over one shared job queue (module docs).
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    /// A pool of `n.max(1)` worker threads.
    pub fn new(n: usize) -> ThreadPool {
        let n = n.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("pool-{i}"))
                    .spawn(move || loop {
                        // the lock guard is dropped before the job runs,
                        // so a panicking job can never poison the shared
                        // receiver; catching the panic keeps this worker
                        // alive for subsequent jobs.
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => {
                                let _ = catch_unwind(AssertUnwindSafe(job));
                            }
                            Err(_) => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers }
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Enqueue a fire-and-forget job on the pool.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("pool receiver gone");
    }

    /// Run `f` over all items, collecting results **in input order**
    /// (result `i` always corresponds to `items[i]`, however the pool
    /// interleaves execution — the guarantee the batched SpMM tiling
    /// relies on). An empty `items` returns an empty vec immediately.
    ///
    /// # Panics
    /// If any item's `f` panics, every remaining item still runs to
    /// completion and `map` then panics on the calling thread with the
    /// indices of the failed items. The pool itself stays usable.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        if n == 0 {
            return Vec::new();
        }
        let f = Arc::new(f);
        let (tx, rx) = mpsc::channel();
        for (i, item) in items.into_iter().enumerate() {
            let tx = tx.clone();
            let f = Arc::clone(&f);
            self.submit(move || {
                let r = catch_unwind(AssertUnwindSafe(|| f(item)));
                let _ = tx.send((i, r));
            });
        }
        drop(tx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        let mut failed: Vec<usize> = Vec::new();
        for (i, r) in rx {
            match r {
                Ok(v) => out[i] = Some(v),
                Err(_) => failed.push(i),
            }
        }
        if !failed.is_empty() {
            failed.sort_unstable();
            panic!(
                "ThreadPool::map: {} of {n} item(s) panicked \
                 (indices {failed:?})",
                failed.len()
            );
        }
        out.into_iter()
            .map(|r| r.expect("map result missing"))
            .collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        // the in-order-collection guarantee: result i belongs to item i
        // for every pool width, including width 1
        for width in [1, 3, 7] {
            let pool = ThreadPool::new(width);
            let out = pool.map((0..50).collect::<Vec<_>>(), |x| x * 2);
            assert_eq!(out, (0..50).map(|x| x * 2).collect::<Vec<_>>());
        }
    }

    #[test]
    fn map_empty_items_returns_empty() {
        let pool = ThreadPool::new(2);
        let out: Vec<i32> = pool.map(Vec::<i32>::new(), |x| x);
        assert!(out.is_empty());
        // and the pool is still alive afterwards
        assert_eq!(pool.map(vec![1, 2, 3], |x| x + 1), vec![2, 3, 4]);
    }

    #[test]
    fn panicking_map_item_fails_loudly_without_deadlock() {
        let pool = ThreadPool::new(2);
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.map(vec![0u32, 1, 2, 3], |x| {
                if x == 2 {
                    panic!("boom");
                }
                x * 10
            })
        }));
        let msg = match r {
            Ok(_) => panic!("map must propagate the item panic"),
            Err(e) => e
                .downcast_ref::<String>()
                .cloned()
                .unwrap_or_default(),
        };
        assert!(msg.contains("panicked"), "unhelpful message: {msg}");
        assert!(msg.contains("[2]"), "missing failed index: {msg}");
        // the pool survived: workers did not die, nothing deadlocks
        assert_eq!(pool.map(vec![5, 6], |x| x + 1), vec![6, 7]);
    }

    #[test]
    fn panicking_submitted_job_does_not_kill_workers() {
        let pool = ThreadPool::new(1); // single worker: it MUST survive
        pool.submit(|| panic!("raw job panic"));
        let counter = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&counter);
        pool.submit(move || {
            c.fetch_add(1, Ordering::SeqCst);
        });
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 1);
    }
}
