//! Int8 quantization helpers (rust mirror of `amber/quant.py`) — used for
//! verification of the W8A8 artifacts and by the native SpMM bench's int8
//! variant (Outstanding-sparse's compute path).
//!
//! The matmuls dispatch to the register-tiled int8 kernel in
//! [`crate::kernels::int8`] and are bitwise identical to the retained
//! reference loops in [`crate::kernels::reference`]. Activation scaling
//! comes in two flavors: per-tensor ([`quantize`] + [`w8a8_matmul`]) and
//! **per-token** ([`quantize_per_token`] + [`w8a8_matmul_per_token`]),
//! where each token row carries its own absmax scale — the serving path
//! uses per-token so a token's quantized logits never depend on its
//! batchmates (what makes packed sq prefill bitwise-reproducible).

use crate::exec::ThreadPool;
use crate::kernels::pack::PackedPanels;
use crate::kernels::simd::Dispatch;
use crate::kernels::{self, DEFAULT_DOUT_TILE};
use std::sync::Arc;

/// Symmetric per-tensor int8 quantization with a static scale.
pub fn quantize(x: &[f32], scale: f32) -> Vec<i8> {
    x.iter()
        .map(|&v| (v / scale).round().clamp(-127.0, 127.0) as i8)
        .collect()
}

/// Dequantize int8 values back to f32 with one scale.
pub fn dequantize(q: &[i8], scale: f32) -> Vec<f32> {
    q.iter().map(|&v| v as f32 * scale).collect()
}

/// Symmetric **per-token** int8 quantization of a `[t, din]` activation:
/// each token row gets its own absmax scale (`(absmax/127).max(1e-8)`,
/// the same formula the per-tensor serving path used for the whole
/// batch). Returns `(quantized rows, per-row scales)`.
pub fn quantize_per_token(
    x: &[f32],
    t: usize,
    din: usize,
) -> (Vec<i8>, Vec<f32>) {
    assert_eq!(x.len(), t * din, "quantize_per_token: x shape");
    let mut q = Vec::with_capacity(t * din);
    let mut scales = Vec::with_capacity(t);
    for row in x.chunks_exact(din) {
        let absmax = row.iter().fold(0.0f32, |a, &b| a.max(b.abs()));
        let s = (absmax / 127.0).max(1e-8);
        scales.push(s);
        q.extend(
            row.iter()
                .map(|&v| (v / s).round().clamp(-127.0, 127.0) as i8),
        );
    }
    (q, scales)
}

/// Per-output-channel weight quantization: w [din, dout] row-major ->
/// (wq, per-column scales). Both sweeps walk the storage row-major
/// (`chunks_exact(dout)` against a `dout`-wide running absmax /
/// per-column scale vector), so the weight matrix is streamed
/// sequentially instead of strided.
pub fn quantize_weight(w: &[f32], din: usize, dout: usize) -> (Vec<i8>, Vec<f32>) {
    assert_eq!(w.len(), din * dout, "quantize_weight: w shape");
    let mut absmax = vec![0.0f32; dout];
    for row in w.chunks_exact(dout) {
        for (a, &v) in absmax.iter_mut().zip(row.iter()) {
            *a = a.max(v.abs());
        }
    }
    let scales: Vec<f32> =
        absmax.iter().map(|&a| (a / 127.0).max(1e-8)).collect();
    let mut wq = Vec::with_capacity(din * dout);
    for row in w.chunks_exact(dout) {
        wq.extend(row.iter().zip(scales.iter()).map(|(&v, &s)| {
            (v / s).round().clamp(-127.0, 127.0) as i8
        }));
    }
    (wq, scales)
}

/// [`quantize_weight`] + tile-panel packing in one call: the bind-time
/// preparation the native engine caches per weight `Arc` — quantize the
/// `[din, dout]` weight once, pack the int8 bytes into panels of
/// `panel_w` columns, and return the per-column scales alongside.
/// Feed the result to
/// [`w8a8_matmul_packed_per_token`] /
/// [`crate::kernels::int8::w8a8_tiled_per_token_packed`].
pub fn quantize_weight_packed(
    w: &[f32],
    din: usize,
    dout: usize,
    panel_w: usize,
) -> (PackedPanels<i8>, Vec<f32>) {
    let (wq, scales) = quantize_weight(w, din, dout);
    (PackedPanels::pack(&wq, din, dout, panel_w), scales)
}

/// W8A8 matmul over a pre-quantized, panel-packed weight with
/// **per-token** activation scales — the zero-preparation hot path the
/// serving pipeline runs once weights are prepared at bind. Bitwise
/// identical to [`w8a8_matmul_per_token`] on the same quantized bytes.
pub fn w8a8_matmul_packed_per_token(
    xq: &[i8],
    t: usize,
    din: usize,
    wq: &PackedPanels<i8>,
    x_scales: &[f32],
    w_scales: &[f32],
) -> Vec<f32> {
    w8a8_matmul_packed_per_token_dispatch(
        xq,
        t,
        din,
        wq,
        x_scales,
        w_scales,
        Dispatch::scalar(),
    )
}

/// [`w8a8_matmul_packed_per_token`] through a resolved SIMD
/// [`Dispatch`] vtable — bitwise identical at every level.
pub fn w8a8_matmul_packed_per_token_dispatch(
    xq: &[i8],
    t: usize,
    din: usize,
    wq: &PackedPanels<i8>,
    x_scales: &[f32],
    w_scales: &[f32],
    disp: Dispatch,
) -> Vec<f32> {
    let mut out = vec![0.0f32; t * wq.dout];
    (disp.w8a8)(xq, t, din, wq, x_scales, w_scales, &mut out);
    out
}

/// Row-tiled parallel variant of [`w8a8_matmul_packed_per_token`]:
/// token rows are chunked into `block_rows`-high tiles fanned out over
/// `pool`, with the quantized activation, per-token scales, packed
/// weight and per-column scales all `Arc`-shared with the workers
/// (zero copies). Per-token scaling makes every row's arithmetic
/// independent of its batchmates, so each tile runs the identical
/// serial kernel on its own rows and the result is **bit-identical**
/// to the serial packed kernel for every tiling and pool width — the
/// same contract [`crate::sparsity::spmm::dense_matmul_packed_parallel`]
/// holds for f32.
#[allow(clippy::too_many_arguments)]
pub fn w8a8_matmul_packed_per_token_parallel_dispatch(
    xq: &Arc<Vec<i8>>,
    t: usize,
    din: usize,
    wq: &Arc<PackedPanels<i8>>,
    x_scales: &Arc<Vec<f32>>,
    w_scales: &Arc<Vec<f32>>,
    pool: &ThreadPool,
    block_rows: usize,
    disp: Dispatch,
) -> Vec<f32> {
    assert_eq!(xq.len(), t * din, "w8a8 parallel: activation shape");
    assert_eq!(x_scales.len(), t, "w8a8 parallel: per-token scales");
    let block_rows = block_rows.max(1);
    if pool.size() <= 1 || t <= block_rows {
        return w8a8_matmul_packed_per_token_dispatch(
            xq, t, din, wq, x_scales, w_scales, disp,
        );
    }
    let mut tiles_spec: Vec<(usize, usize)> = Vec::new();
    let mut row0 = 0;
    while row0 < t {
        let rows = block_rows.min(t - row0);
        tiles_spec.push((row0, rows));
        row0 += rows;
    }
    let xs = Arc::clone(xq);
    let ss = Arc::clone(x_scales);
    let w2 = Arc::clone(wq);
    let ws2 = Arc::clone(w_scales);
    let tiles = pool.map(tiles_spec, move |(row0, rows)| {
        w8a8_matmul_packed_per_token_dispatch(
            &xs[row0 * din..(row0 + rows) * din],
            rows,
            din,
            &w2,
            &ss[row0..row0 + rows],
            &ws2,
            disp,
        )
    });
    // map preserves tile order: assembly is a straight concatenation
    let mut out = Vec::with_capacity(t * wq.dout);
    for tile in tiles {
        out.extend_from_slice(&tile);
    }
    out
}

/// W8A8 matmul with int32 accumulation and a per-tensor activation
/// scale (reference semantics of the quant_matmul Pallas kernel) —
/// executed by the register-tiled int8 kernel.
pub fn w8a8_matmul(
    xq: &[i8],
    t: usize,
    din: usize,
    wq: &[i8],
    dout: usize,
    x_scale: f32,
    w_scales: &[f32],
) -> Vec<f32> {
    let mut out = vec![0.0f32; t * dout];
    kernels::int8::w8a8_tiled(
        xq,
        t,
        din,
        wq,
        dout,
        DEFAULT_DOUT_TILE,
        x_scale,
        w_scales,
        &mut out,
    );
    out
}

/// W8A8 matmul with int32 accumulation and **per-token** activation
/// scales fused at dequant — the serving path's int8 kernel (pair with
/// [`quantize_per_token`]).
pub fn w8a8_matmul_per_token(
    xq: &[i8],
    t: usize,
    din: usize,
    wq: &[i8],
    dout: usize,
    x_scales: &[f32],
    w_scales: &[f32],
) -> Vec<f32> {
    let mut out = vec![0.0f32; t * dout];
    kernels::int8::w8a8_tiled_per_token(
        xq,
        t,
        din,
        wq,
        dout,
        DEFAULT_DOUT_TILE,
        x_scales,
        w_scales,
        &mut out,
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn quant_roundtrip_error_bounded() {
        let mut rng = Rng::new(5);
        let x: Vec<f32> = (0..256).map(|_| rng.normal() as f32).collect();
        let absmax = x.iter().fold(0f32, |a, &b| a.max(b.abs()));
        let scale = absmax / 127.0;
        let q = quantize(&x, scale);
        let d = dequantize(&q, scale);
        for (a, b) in x.iter().zip(d.iter()) {
            assert!((a - b).abs() <= scale * 0.5 + 1e-6);
        }
    }

    #[test]
    fn w8a8_close_to_f32() {
        let mut rng = Rng::new(6);
        let (t, din, dout) = (4, 32, 8);
        let x: Vec<f32> = (0..t * din).map(|_| rng.normal() as f32).collect();
        let w: Vec<f32> =
            (0..din * dout).map(|_| rng.normal() as f32 * 0.1).collect();
        let (wq, ws) = quantize_weight(&w, din, dout);
        let xmax = x.iter().fold(0f32, |a, &b| a.max(b.abs()));
        let xs = (xmax / 127.0).max(1e-8);
        let xq = quantize(&x, xs);
        let yq = w8a8_matmul(&xq, t, din, &wq, dout, xs, &ws);
        // f32 reference
        for r in 0..t {
            for c in 0..dout {
                let mut acc = 0f32;
                for k in 0..din {
                    acc += x[r * din + k] * w[k * dout + c];
                }
                let err = (acc - yq[r * dout + c]).abs();
                assert!(err < 0.15, "err {err} at ({r},{c})");
            }
        }
    }

    #[test]
    fn per_token_at_least_as_tight_as_per_tensor() {
        // a batch with one large-magnitude row: per-tensor scaling
        // crushes the small rows' resolution, per-token preserves it
        let mut rng = Rng::new(8);
        let (t, din, dout) = (4usize, 32usize, 8usize);
        let mut x: Vec<f32> =
            (0..t * din).map(|_| rng.normal() as f32 * 0.05).collect();
        for v in x[..din].iter_mut() {
            *v *= 100.0; // row 0 dominates the batch absmax
        }
        let w: Vec<f32> =
            (0..din * dout).map(|_| rng.normal() as f32 * 0.1).collect();
        let (wq, ws) = quantize_weight(&w, din, dout);
        let (xq_pt, xs_pt) = quantize_per_token(&x, t, din);
        let y_pt =
            w8a8_matmul_per_token(&xq_pt, t, din, &wq, dout, &xs_pt, &ws);
        let xmax = x.iter().fold(0f32, |a, &b| a.max(b.abs()));
        let s = (xmax / 127.0).max(1e-8);
        let y_tensor =
            w8a8_matmul(&quantize(&x, s), t, din, &wq, dout, s, &ws);
        // f32 reference, rows 1.. (the small rows)
        let mut err_pt = 0.0f32;
        let mut err_tensor = 0.0f32;
        for r in 1..t {
            for c in 0..dout {
                let mut acc = 0f32;
                for k in 0..din {
                    acc += x[r * din + k] * w[k * dout + c];
                }
                err_pt = err_pt.max((acc - y_pt[r * dout + c]).abs());
                err_tensor =
                    err_tensor.max((acc - y_tensor[r * dout + c]).abs());
            }
        }
        assert!(
            err_pt < err_tensor,
            "per-token ({err_pt}) should beat per-tensor ({err_tensor}) \
             on the dominated rows"
        );
    }

    #[test]
    fn packed_quant_matches_row_major_bitwise() {
        // quantize-once-and-pack must reproduce the per-call path bit
        // for bit: same quantized bytes, same scales, same matmul
        let mut rng = Rng::new(10);
        let (t, din, dout) = (3usize, 32usize, 21usize);
        let x: Vec<f32> =
            (0..t * din).map(|_| rng.normal() as f32).collect();
        let w: Vec<f32> =
            (0..din * dout).map(|_| rng.normal() as f32 * 0.1).collect();
        let (wq, ws) = quantize_weight(&w, din, dout);
        let (xq, xs) = quantize_per_token(&x, t, din);
        let golden = w8a8_matmul_per_token(&xq, t, din, &wq, dout, &xs, &ws);
        for pw in [1usize, 8, 16, 64] {
            let (pq, ps) = quantize_weight_packed(&w, din, dout, pw);
            assert_eq!(ps, ws, "panel_w {pw}: scales");
            assert_eq!(pq.unpack(), wq, "panel_w {pw}: bytes");
            assert_eq!(
                w8a8_matmul_packed_per_token(&xq, t, din, &pq, &xs, &ps),
                golden,
                "panel_w {pw}: matmul"
            );
        }
    }

    #[test]
    fn packed_per_token_parallel_matches_serial_bitwise() {
        // the pooled int8 fan-out must reproduce the serial packed
        // kernel bit for bit at every row tiling and pool width
        let mut rng = Rng::new(12);
        let (t, din, dout) = (13usize, 32usize, 21usize);
        let x: Vec<f32> =
            (0..t * din).map(|_| rng.normal() as f32).collect();
        let w: Vec<f32> =
            (0..din * dout).map(|_| rng.normal() as f32 * 0.1).collect();
        let (pq, ps) = quantize_weight_packed(&w, din, dout, 8);
        let (xq, xs) = quantize_per_token(&x, t, din);
        let golden =
            w8a8_matmul_packed_per_token(&xq, t, din, &pq, &xs, &ps);
        let xq = Arc::new(xq);
        let xs = Arc::new(xs);
        let pq = Arc::new(pq);
        let ps = Arc::new(ps);
        for threads in [1usize, 2, 4] {
            let pool = ThreadPool::new(threads);
            for block_rows in [1usize, 3, 4, 32] {
                assert_eq!(
                    w8a8_matmul_packed_per_token_parallel_dispatch(
                        &xq,
                        t,
                        din,
                        &pq,
                        &xs,
                        &ps,
                        &pool,
                        block_rows,
                        Dispatch::scalar(),
                    ),
                    golden,
                    "threads {threads} block_rows {block_rows}"
                );
            }
        }
    }

    #[test]
    fn per_token_rows_independent_of_batchmates() {
        // quantizing a row alone or inside a batch yields the same
        // bytes and scale — the property that makes packed sq bitwise
        let mut rng = Rng::new(9);
        let (t, din) = (3usize, 16usize);
        let x: Vec<f32> =
            (0..t * din).map(|_| rng.normal() as f32).collect();
        let (q_all, s_all) = quantize_per_token(&x, t, din);
        for r in 0..t {
            let row = &x[r * din..(r + 1) * din];
            let (q_row, s_row) = quantize_per_token(row, 1, din);
            assert_eq!(&q_all[r * din..(r + 1) * din], &q_row[..]);
            assert_eq!(s_all[r], s_row[0]);
        }
    }
}
