//! Int8 quantization helpers (rust mirror of `amber/quant.py`) — used for
//! verification of the W8A8 artifacts and by the native SpMM bench's int8
//! variant (Outstanding-sparse's compute path).

/// Symmetric per-tensor int8 quantization with a static scale.
pub fn quantize(x: &[f32], scale: f32) -> Vec<i8> {
    x.iter()
        .map(|&v| (v / scale).round().clamp(-127.0, 127.0) as i8)
        .collect()
}

/// Dequantize int8 values back to f32 with one scale.
pub fn dequantize(q: &[i8], scale: f32) -> Vec<f32> {
    q.iter().map(|&v| v as f32 * scale).collect()
}

/// Per-output-channel weight quantization: w [din, dout] row-major ->
/// (wq, per-column scales).
pub fn quantize_weight(w: &[f32], din: usize, dout: usize) -> (Vec<i8>, Vec<f32>) {
    let mut absmax = vec![0f32; dout];
    for r in 0..din {
        for c in 0..dout {
            absmax[c] = absmax[c].max(w[r * dout + c].abs());
        }
    }
    let scales: Vec<f32> =
        absmax.iter().map(|&a| (a / 127.0).max(1e-8)).collect();
    let mut wq = vec![0i8; din * dout];
    for r in 0..din {
        for c in 0..dout {
            wq[r * dout + c] = (w[r * dout + c] / scales[c])
                .round()
                .clamp(-127.0, 127.0) as i8;
        }
    }
    (wq, scales)
}

/// W8A8 matmul with int32 accumulation (reference semantics of the
/// quant_matmul Pallas kernel).
pub fn w8a8_matmul(
    xq: &[i8],
    t: usize,
    din: usize,
    wq: &[i8],
    dout: usize,
    x_scale: f32,
    w_scales: &[f32],
) -> Vec<f32> {
    let mut out = vec![0f32; t * dout];
    for r in 0..t {
        for c in 0..dout {
            let mut acc: i32 = 0;
            for k in 0..din {
                acc += xq[r * din + k] as i32 * wq[k * dout + c] as i32;
            }
            out[r * dout + c] = acc as f32 * x_scale * w_scales[c];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn quant_roundtrip_error_bounded() {
        let mut rng = Rng::new(5);
        let x: Vec<f32> = (0..256).map(|_| rng.normal() as f32).collect();
        let absmax = x.iter().fold(0f32, |a, &b| a.max(b.abs()));
        let scale = absmax / 127.0;
        let q = quantize(&x, scale);
        let d = dequantize(&q, scale);
        for (a, b) in x.iter().zip(d.iter()) {
            assert!((a - b).abs() <= scale * 0.5 + 1e-6);
        }
    }

    #[test]
    fn w8a8_close_to_f32() {
        let mut rng = Rng::new(6);
        let (t, din, dout) = (4, 32, 8);
        let x: Vec<f32> = (0..t * din).map(|_| rng.normal() as f32).collect();
        let w: Vec<f32> =
            (0..din * dout).map(|_| rng.normal() as f32 * 0.1).collect();
        let (wq, ws) = quantize_weight(&w, din, dout);
        let xmax = x.iter().fold(0f32, |a, &b| a.max(b.abs()));
        let xs = (xmax / 127.0).max(1e-8);
        let xq = quantize(&x, xs);
        let yq = w8a8_matmul(&xq, t, din, &wq, dout, xs, &ws);
        // f32 reference
        for r in 0..t {
            for c in 0..dout {
                let mut acc = 0f32;
                for k in 0..din {
                    acc += x[r * din + k] * w[k * dout + c];
                }
                let err = (acc - yq[r * dout + c]).abs();
                assert!(err < 0.15, "err {err} at ({r},{c})");
            }
        }
    }
}
