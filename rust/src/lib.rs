//! Amber Pruner — rust serving coordinator (Layer 3).
//!
//! Reproduction of "Amber Pruner: Leveraging N:M Activation Sparsity for
//! Efficient Prefill in Large Language Models". The compute graphs (Layer 2
//! JAX model + Layer 1 Pallas kernels) are AOT-lowered to HLO text by
//! `python/compile/aot.py`; this crate loads them through the PJRT C API
//! (`xla` crate) and serves batched requests with per-request N:M sparsity
//! configs. Python is never on the request path.

pub mod util;
pub mod exec;
pub mod tensor;
pub mod metrics;
pub mod sparsity;
pub mod quant;
pub mod runtime;
pub mod coordinator;
pub mod server;
pub mod eval;
pub mod repro;
pub mod bench;
pub mod testutil;
