//! Amber Pruner — rust serving coordinator (Layer 3).
//!
//! Reproduction of "Amber Pruner: Leveraging N:M Activation Sparsity for
//! Efficient Prefill in Large Language Models". The serving stack — a
//! continuous-batching scheduler with per-request N:M sparsity configs,
//! KV slot management, TCP front-end, eval + repro harnesses — drives a
//! backend-neutral [`runtime::Engine`]:
//!
//! * the default [`runtime::NativeEngine`] executes prefill/decode
//!   entirely on CPU in pure Rust (`tensor::math`,
//!   `sparsity::spmm::NmCompressed`, `quant`) with no external
//!   dependencies, so `cargo build && cargo test` and the whole serving
//!   path work out of the box;
//! * the `pjrt` cargo feature adds `runtime::ModelRuntime`, which
//!   loads compute graphs AOT-lowered to HLO text by
//!   `python/compile/aot.py` through the PJRT C API (`xla` crate).
//!
//! Python is never on the request path in either backend.
//!
//! `docs/ARCHITECTURE.md` (repo root) maps the full request lifecycle
//! across these modules.
#![warn(missing_docs)]

pub mod util;
pub mod exec;
pub mod kernels;
pub mod tensor;
pub mod metrics;
pub mod sparsity;
pub mod quant;
pub mod runtime;
pub mod coordinator;
pub mod server;
pub mod eval;
pub mod repro;
pub mod bench;
pub mod testutil;
