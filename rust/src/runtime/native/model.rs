//! Model geometry ([`ModelSpec`]) and deterministically synthesized
//! weights ([`NativeModel`]) for the native CPU backend.
//!
//! Projection weight matrices are `[din, dout]` row-major (the `spmm`
//! convention) and `Arc`-shared so the batched projection pipeline can
//! fan row-tiles out over the engine thread pool without copying
//! weights per tile.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use super::super::artifact::{ArtifactMeta, Manifest, ModelInfo};
use crate::sparsity::coverage::Geometry;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// The N:M ratios every model's artifact inventory covers.
pub const RATIOS: [(usize, usize); 3] = [(2, 4), (4, 8), (8, 16)];

/// Geometry + serving shapes of one native model.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    /// model name (manifest key, weight seed)
    pub name: String,
    /// vocabulary size
    pub vocab: usize,
    /// model width
    pub d_model: usize,
    /// transformer layers
    pub n_layers: usize,
    /// query heads
    pub n_q_heads: usize,
    /// key/value heads (GQA when < n_q_heads)
    pub n_kv_heads: usize,
    /// per-head dimension
    pub head_dim: usize,
    /// MLP hidden width
    pub d_ff: usize,
    /// static prefill batch of the synthetic artifacts
    pub prefill_batch: usize,
    /// prefill sequence lengths served
    pub prefill_seqs: Vec<usize>,
    /// static decode batch
    pub decode_batch: usize,
    /// decode cache length (per-sequence KV token ceiling)
    pub cache_len: usize,
    /// layers where q/gate stay dense under the `ls` / `all` settings
    pub skip_layers: Vec<usize>,
    /// weight-synthesis seed
    pub seed: u64,
}

impl ModelSpec {
    /// Self-contained default: the tiny-lm geometry the repo's tests and
    /// token world (vocab 384) assume. All dims divide 16 so every
    /// supported N:M group size applies cleanly.
    pub fn tiny(name: &str) -> ModelSpec {
        ModelSpec {
            name: name.to_string(),
            vocab: 384,
            d_model: 32,
            n_layers: 2,
            n_q_heads: 2,
            n_kv_heads: 1,
            head_dim: 16,
            d_ff: 64,
            prefill_batch: 8,
            prefill_seqs: vec![64],
            decode_batch: 8,
            cache_len: 96,
            skip_layers: vec![1],
            seed: fnv1a(name.as_bytes()),
        }
    }

    /// Adopt geometry from a real manifest entry; anything missing keeps
    /// the tiny default. Dimensions are then sanitized so attention and
    /// pruning group math stay well-defined.
    pub fn from_manifest(
        info: &ModelInfo,
        manifest: &Manifest,
        dir: &Path,
    ) -> ModelSpec {
        let mut spec = ModelSpec::tiny(&info.name);
        let g = |k: &str| info.config.get(k).copied().unwrap_or(0);
        let adopt = |cur: &mut usize, v: usize| {
            if v > 0 {
                *cur = v;
            }
        };
        adopt(&mut spec.vocab, g("vocab_size"));
        adopt(&mut spec.d_model, g("d_model"));
        adopt(&mut spec.n_layers, g("n_layers"));
        adopt(&mut spec.n_q_heads, g("n_q_heads"));
        adopt(&mut spec.n_kv_heads, g("n_kv_heads"));
        adopt(&mut spec.head_dim, g("head_dim"));
        adopt(&mut spec.d_ff, g("d_ff"));
        // serving shapes from the artifact inventory
        let mut seqs: Vec<usize> = Vec::new();
        for a in manifest.artifacts.values() {
            if !a.name.starts_with(&format!("{}.", info.name)) {
                continue;
            }
            if a.kind == "prefill" {
                if !seqs.contains(&a.seq) && a.seq > 0 {
                    seqs.push(a.seq);
                }
                if a.batch > 0 {
                    spec.prefill_batch = a.batch;
                }
            } else if a.kind == "decode" {
                if a.batch > 0 {
                    spec.decode_batch = a.batch;
                }
                if a.cache > 0 {
                    spec.cache_len = a.cache;
                }
            }
        }
        if !seqs.is_empty() {
            seqs.sort_unstable();
            spec.prefill_seqs = seqs;
        }
        if let Some(skips) = stats_skip_layers(dir, &info.name) {
            spec.skip_layers = skips;
        } else {
            spec.skip_layers = vec![spec.n_layers.saturating_sub(1)];
        }
        spec.sanitize()
    }

    pub(super) fn sanitize(mut self) -> ModelSpec {
        if self.n_kv_heads == 0 || self.n_q_heads % self.n_kv_heads != 0 {
            self.n_kv_heads = self.n_q_heads.max(1);
            self.n_q_heads = self.n_kv_heads;
        }
        self.vocab = self.vocab.max(16);
        self.cache_len = self.cache_len.max(self.max_prefill_seq() + 16);
        self
    }

    /// Query projection width (`n_q_heads * head_dim`).
    pub fn q_dim(&self) -> usize {
        self.n_q_heads * self.head_dim
    }

    /// Key/value projection width (`n_kv_heads * head_dim`).
    pub fn kv_dim(&self) -> usize {
        self.n_kv_heads * self.head_dim
    }

    /// Longest served prefill sequence length.
    pub fn max_prefill_seq(&self) -> usize {
        self.prefill_seqs.iter().copied().max().unwrap_or(64)
    }

    /// The spec as a [`Geometry`] (what per-module tile planning and
    /// coverage accounting consume).
    pub fn geometry(&self) -> Geometry {
        Geometry {
            d_model: self.d_model,
            n_layers: self.n_layers,
            q_dim: self.q_dim(),
            kv_dim: self.kv_dim(),
            d_ff: self.d_ff,
            n_experts: 0,
            top_k: 0,
            d_ff_expert: 0,
        }
    }

    /// Synthesize the manifest entries (artifacts + model info +
    /// settings) this model serves.
    pub(super) fn manifest_entries(
        &self,
        artifacts: &mut BTreeMap<String, ArtifactMeta>,
        models: &mut BTreeMap<String, ModelInfo>,
        settings: &mut BTreeMap<String, Vec<String>>,
    ) {
        let prefill_meta = |name: &str,
                           variant: &str,
                           seq: usize,
                           nm: Option<(usize, usize)>| {
            ArtifactMeta {
                name: name.to_string(),
                hlo: String::new(),
                params: Vec::new(),
                runtime_inputs: vec![(
                    vec![self.prefill_batch, seq],
                    "int32".to_string(),
                )],
                outputs: vec!["logits".into(), "k".into(), "v".into()],
                kind: "prefill".to_string(),
                variant: variant.to_string(),
                batch: self.prefill_batch,
                seq,
                cache: 0,
                nm,
            }
        };
        for &seq in &self.prefill_seqs {
            for (variant, nm) in prefill_variants() {
                let name = match nm {
                    Some((n, m)) => format!(
                        "{}.prefill{seq}.{variant}{n}_{m}",
                        self.name
                    ),
                    None => format!("{}.prefill{seq}.{variant}", self.name),
                };
                artifacts
                    .insert(name.clone(), prefill_meta(&name, variant, seq, nm));
            }
        }
        let cache_shape = vec![
            self.n_layers,
            self.decode_batch,
            self.cache_len,
            self.n_kv_heads,
            self.head_dim,
        ];
        for variant in ["dense", "sq"] {
            let name = format!("{}.decode.{variant}", self.name);
            artifacts.insert(
                name.clone(),
                ArtifactMeta {
                    name: name.clone(),
                    hlo: String::new(),
                    params: Vec::new(),
                    runtime_inputs: vec![
                        (vec![self.decode_batch], "int32".to_string()),
                        (vec![self.decode_batch], "int32".to_string()),
                        (cache_shape.clone(), "float32".to_string()),
                        (cache_shape.clone(), "float32".to_string()),
                        (vec![self.decode_batch], "int32".to_string()),
                    ],
                    outputs: vec!["logits".into(), "k".into(), "v".into()],
                    kind: "decode".to_string(),
                    variant: variant.to_string(),
                    batch: self.decode_batch,
                    seq: 0,
                    cache: self.cache_len,
                    nm: None,
                },
            );
        }
        let mut config = BTreeMap::new();
        config.insert("vocab_size".to_string(), self.vocab);
        config.insert("d_model".to_string(), self.d_model);
        config.insert("n_layers".to_string(), self.n_layers);
        config.insert("n_q_heads".to_string(), self.n_q_heads);
        config.insert("n_kv_heads".to_string(), self.n_kv_heads);
        config.insert("head_dim".to_string(), self.head_dim);
        config.insert("d_ff".to_string(), self.d_ff);
        models.insert(
            self.name.clone(),
            ModelInfo {
                name: self.name.clone(),
                weights: format!("weights/{}.atw", self.name),
                is_moe: false,
                config,
            },
        );
        settings.insert(
            self.name.clone(),
            vec!["naive".into(), "ls".into(), "all".into()],
        );
    }
}

fn prefill_variants() -> Vec<(&'static str, Option<(usize, usize)>)> {
    let mut v: Vec<(&'static str, Option<(usize, usize)>)> =
        vec![("dense", None), ("sq", None)];
    for &(n, m) in &RATIOS {
        v.push(("nm", Some((n, m))));
        v.push(("sq_nm", Some((n, m))));
    }
    v
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

fn stats_skip_layers(dir: &Path, model: &str) -> Option<Vec<usize>> {
    let p = dir.join("stats").join(format!("sensitivity_{model}.json"));
    let text = std::fs::read_to_string(p).ok()?;
    let j = Json::parse(&text).ok()?;
    let arr = j.get("skip_layers")?.as_arr()?;
    Some(arr.iter().filter_map(|v| v.as_usize()).collect())
}

/// Process-wide weight-identity counter backing [`ModelWeight::id`].
static NEXT_WEIGHT_ID: AtomicU64 = AtomicU64::new(1);

/// One projection weight matrix with a stable identity.
///
/// The preparation cache used to key prepared panels by the weight's
/// `Arc` pointer, which forced the row-major original to stay alive for
/// the engine's whole lifetime — a ~2x duplication of every projection
/// weight once the panel-packed copy existed. A `ModelWeight` instead
/// carries a process-unique `id` (the cache key, valid even after the
/// data is gone) and makes the row-major bytes **releasable**: after
/// `bind` packs a weight, [`ModelWeight::release`] drops the original
/// and the panels become the only resident copy. A later preparation at
/// a different tile width reconstructs the row-major bytes losslessly
/// from any existing panel packing (`PackedPanels::unpack`).
pub(super) struct ModelWeight {
    id: u64,
    data: Option<Arc<Vec<f32>>>,
}

impl ModelWeight {
    /// Wrap a freshly synthesized/loaded `[din, dout]` matrix.
    pub(super) fn new(data: Vec<f32>) -> ModelWeight {
        ModelWeight {
            id: NEXT_WEIGHT_ID.fetch_add(1, Ordering::Relaxed),
            data: Some(Arc::new(data)),
        }
    }

    /// Process-unique identity — the preparation-cache key.
    pub(super) fn id(&self) -> u64 {
        self.id
    }

    /// The row-major original, while still resident.
    pub(super) fn data(&self) -> Option<&Arc<Vec<f32>>> {
        self.data.as_ref()
    }

    /// Drop the row-major original (the packed panels keep the bytes).
    pub(super) fn release(&mut self) {
        self.data = None;
    }

    /// Bytes of row-major f32 still resident in this weight.
    pub(super) fn resident_bytes(&self) -> u64 {
        self.data
            .as_ref()
            .map(|d| (d.len() * std::mem::size_of::<f32>()) as u64)
            .unwrap_or(0)
    }
}

/// One transformer layer's weights; `scale_*` are the per-input-channel
/// weight norms the `all` setting uses as Robust-Norm-style scores.
pub(super) struct LayerWeights {
    pub(super) attn_norm: Vec<f32>,
    pub(super) wq: ModelWeight,
    pub(super) wk: ModelWeight,
    pub(super) wv: ModelWeight,
    pub(super) wo: ModelWeight,
    pub(super) mlp_norm: Vec<f32>,
    pub(super) w_gate: ModelWeight,
    pub(super) w_up: ModelWeight,
    pub(super) w_down: ModelWeight,
    pub(super) scale_q: Vec<f32>,
    pub(super) scale_gate: Vec<f32>,
    pub(super) scale_down: Vec<f32>,
}

impl LayerWeights {
    /// The layer's eight projection weights (seven slots; the lm_head
    /// lives on the model).
    fn weights_mut(&mut self) -> [&mut ModelWeight; 7] {
        [
            &mut self.wq,
            &mut self.wk,
            &mut self.wv,
            &mut self.wo,
            &mut self.w_gate,
            &mut self.w_up,
            &mut self.w_down,
        ]
    }
}

/// A native model: spec + deterministically synthesized weights.
pub struct NativeModel {
    /// the model's geometry + serving shapes
    pub spec: ModelSpec,
    pub(super) embed: Vec<f32>,
    pub(super) layers: Vec<LayerWeights>,
    pub(super) final_norm: Vec<f32>,
    pub(super) lm_head: ModelWeight,
}

fn rand_mat(rng: &mut Rng, din: usize, dout: usize) -> Vec<f32> {
    let scale = 1.0 / (din.max(1) as f64).sqrt();
    (0..din * dout)
        .map(|_| (rng.normal() * scale) as f32)
        .collect()
}

/// Per-input-channel L2 norm of a `[din, dout]` weight matrix.
fn row_norms(w: &[f32], din: usize, dout: usize) -> Vec<f32> {
    (0..din)
        .map(|j| {
            w[j * dout..(j + 1) * dout]
                .iter()
                .map(|v| v * v)
                .sum::<f32>()
                .sqrt()
        })
        .collect()
}

impl NativeModel {
    /// Synthesize the model's weights deterministically from its spec.
    pub fn build(spec: ModelSpec) -> NativeModel {
        let mut rng = Rng::new(spec.seed);
        let (d, qd, kvd, f) =
            (spec.d_model, spec.q_dim(), spec.kv_dim(), spec.d_ff);
        let layers = (0..spec.n_layers)
            .map(|_| {
                let wq = rand_mat(&mut rng, d, qd);
                let w_gate = rand_mat(&mut rng, d, f);
                let w_down = rand_mat(&mut rng, f, d);
                LayerWeights {
                    attn_norm: vec![1.0; d],
                    wk: ModelWeight::new(rand_mat(&mut rng, d, kvd)),
                    wv: ModelWeight::new(rand_mat(&mut rng, d, kvd)),
                    wo: ModelWeight::new(rand_mat(&mut rng, qd, d)),
                    mlp_norm: vec![1.0; d],
                    w_up: ModelWeight::new(rand_mat(&mut rng, d, f)),
                    scale_q: row_norms(&wq, d, qd),
                    scale_gate: row_norms(&w_gate, d, f),
                    scale_down: row_norms(&w_down, f, d),
                    wq: ModelWeight::new(wq),
                    w_gate: ModelWeight::new(w_gate),
                    w_down: ModelWeight::new(w_down),
                }
            })
            .collect();
        NativeModel {
            embed: rand_mat(&mut rng, spec.vocab, spec.d_model),
            final_norm: vec![1.0; spec.d_model],
            lm_head: ModelWeight::new(rand_mat(
                &mut rng,
                spec.d_model,
                spec.vocab,
            )),
            layers,
            spec,
        }
    }

    /// Drop every projection weight's row-major original — called by
    /// `bind` right after preparation packs them, making the panel
    /// layout the only resident copy (the `embed` table and the norm /
    /// score vectors are not packed and stay).
    pub(super) fn release_weight_originals(&mut self) {
        for lw in &mut self.layers {
            for w in lw.weights_mut() {
                w.release();
            }
        }
        self.lm_head.release();
    }

    /// Row-major projection-weight bytes still resident (the
    /// `weight_bytes_resident` metric): per-weight f32 bytes for every
    /// not-yet-released original, zero once `bind` has released them.
    pub(super) fn weight_bytes_resident(&self) -> u64 {
        let mut total = self.lm_head.resident_bytes();
        for lw in &self.layers {
            total += lw.wq.resident_bytes()
                + lw.wk.resident_bytes()
                + lw.wv.resident_bytes()
                + lw.wo.resident_bytes()
                + lw.w_gate.resident_bytes()
                + lw.w_up.resident_bytes()
                + lw.w_down.resident_bytes();
        }
        total
    }

    pub(super) fn embed_tokens(&self, tokens: &[i32]) -> Vec<f32> {
        let d = self.spec.d_model;
        let mut x = vec![0.0f32; tokens.len() * d];
        for (i, &tok) in tokens.iter().enumerate() {
            let id = (tok.max(0) as usize).min(self.spec.vocab - 1);
            x[i * d..(i + 1) * d]
                .copy_from_slice(&self.embed[id * d..(id + 1) * d]);
        }
        x
    }
}
