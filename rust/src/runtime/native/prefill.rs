//! The batched prefill pipeline: one forward pass over a token-packed
//! segment batch.
//!
//! Both entry points funnel into [`NativeModel::prefill_segments`]:
//!
//! * the classic right-padded `[b, s]` prefill is segments of equal
//!   length `s` (bit-identical to the pre-refactor monolith);
//! * the token-packed multi-request prefill is arbitrary per-request
//!   segments with **no padding rows** — the coordinator's batches
//!   finally reach the kernel as one `[total_tokens, d]` matrix,
//!   compressed once and tiled over the engine thread pool.

use std::sync::Arc;

use crate::runtime::engine::SparsityAudit;

use super::layers::{
    causal_attention_segments_prefixed, rmsnorm, silu, ExecOpts,
    ProjKind, SegPrefix,
};
use super::model::NativeModel;
use super::prepared::PreparedModel;

/// One request's cached-prefix K/V for the prefixed prefill pipeline:
/// `len` leading tokens whose keys/values live in `k`/`v` as
/// `[L, len, H_kv*D_h]`. `len == 0` marks a cold request.
pub(super) struct PrefixKv<'a> {
    pub len: usize,
    pub k: &'a [f32],
    pub v: &'a [f32],
}

impl PrefixKv<'_> {
    /// An empty (cold) prefix.
    pub(super) fn none() -> PrefixKv<'static> {
        PrefixKv { len: 0, k: &[], v: &[] }
    }
}

impl NativeModel {
    /// Forward pass over packed segments: `tokens` is the concatenation
    /// of every request's prompt (`lens[i]` tokens each); request `i`
    /// owns rows `sum(lens[..i]) ..+ lens[i]` of every activation,
    /// attends only within its own segment, and its K/V land at the same
    /// rows of the `[L, total, H_kv*Dh]` caches. Every projection runs
    /// against the bind-time prepared (panel-packed, quant-cached)
    /// weights in `prepared`.
    pub(super) fn prefill_segments(
        &self,
        tokens: &[i32],
        lens: &[usize],
        prepared: &PreparedModel,
        opts: &ExecOpts<'_>,
        audit: &mut SparsityAudit,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let cold: Vec<PrefixKv<'_>> =
            lens.iter().map(|_| PrefixKv::none()).collect();
        self.prefill_segments_prefixed(
            tokens, lens, &cold, prepared, opts, audit,
        )
    }

    /// Prefix-aware packed prefill: segment `i` holds only the **suffix**
    /// tokens of its request (`lens[i]` of them), sitting at global
    /// positions `prefixes[i].len ..` of the sequence; attention reads
    /// the cached-prefix K/V from `prefixes[i]` and the fresh rows from
    /// this pass. Logits and the `[L, total, H_kv*Dh]` caches cover the
    /// suffix rows only. With empty prefixes this **is**
    /// [`NativeModel::prefill_segments`] — the cold path delegates here,
    /// so the two cannot drift. Every per-row stage (embed, rmsnorm,
    /// projections, N:M compression, W8A8 per-token scales, lm_head)
    /// is row-independent, and the model applies no positional encoding
    /// (causality alone breaks symmetry), so suffix rows computed here
    /// are bitwise equal to the same rows of a cold full-prompt prefill
    /// whenever the cached K/V is bitwise equal — the prefix-parity
    /// suite pins exactly that.
    pub(super) fn prefill_segments_prefixed(
        &self,
        tokens: &[i32],
        lens: &[usize],
        prefixes: &[PrefixKv<'_>],
        prepared: &PreparedModel,
        opts: &ExecOpts<'_>,
        audit: &mut SparsityAudit,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let sp = &self.spec;
        let (d, kvd) = (sp.d_model, sp.kv_dim());
        let t: usize = lens.iter().sum();
        debug_assert_eq!(tokens.len(), t, "tokens must match packed lens");
        debug_assert_eq!(lens.len(), prefixes.len());
        let mut segs = Vec::with_capacity(lens.len());
        let mut start = 0usize;
        for &len in lens {
            segs.push((start, len));
            start += len;
        }
        let mut x = self.embed_tokens(tokens);
        let mut k_cache = vec![0.0f32; sp.n_layers * t * kvd];
        let mut v_cache = vec![0.0f32; sp.n_layers * t * kvd];
        for (l, (lw, pl)) in self
            .layers
            .iter()
            .zip(prepared.layers.iter())
            .enumerate()
        {
            // activations are Arc'd once per step so the parallel dense
            // tiles share them with pool workers without copying
            let h = Arc::new(rmsnorm(&x, t, d, &lw.attn_norm));
            let q = lw
                .projection(ProjKind::Q, sp, pl)
                .run(&h, t, l, opts, audit);
            let k = lw
                .projection(ProjKind::K, sp, pl)
                .run(&h, t, l, opts, audit);
            let v = lw
                .projection(ProjKind::V, sp, pl)
                .run(&h, t, l, opts, audit);
            // stash this layer's K/V in [L, total, H_kv, D_h]
            let base = l * t * kvd;
            k_cache[base..base + t * kvd].copy_from_slice(&k);
            v_cache[base..base + t * kvd].copy_from_slice(&v);
            // this layer's slice of each request's cached-prefix K/V
            let seg_pre: Vec<SegPrefix<'_>> = prefixes
                .iter()
                .map(|pre| {
                    let span = pre.len * kvd;
                    SegPrefix {
                        len: pre.len,
                        k: &pre.k[l * span..(l + 1) * span],
                        v: &pre.v[l * span..(l + 1) * span],
                    }
                })
                .collect();
            let attn = Arc::new(causal_attention_segments_prefixed(
                &q, &k, &v, &segs, &seg_pre, sp,
            ));
            let o = lw
                .projection(ProjKind::O, sp, pl)
                .run(&attn, t, l, opts, audit);
            for (xi, oi) in x.iter_mut().zip(o.iter()) {
                *xi += oi;
            }
            let h2 = Arc::new(rmsnorm(&x, t, d, &lw.mlp_norm));
            let gate = lw
                .projection(ProjKind::Gate, sp, pl)
                .run(&h2, t, l, opts, audit);
            let up = lw
                .projection(ProjKind::Up, sp, pl)
                .run(&h2, t, l, opts, audit);
            let act: Arc<Vec<f32>> = Arc::new(
                gate.iter()
                    .zip(up.iter())
                    .map(|(&g, &u)| silu(g) * u)
                    .collect(),
            );
            let down = lw
                .projection(ProjKind::Down, sp, pl)
                .run(&act, t, l, opts, audit);
            for (xi, di) in x.iter_mut().zip(down.iter()) {
                *xi += di;
            }
        }
        let logits = self.logits(
            &x,
            t,
            prepared,
            opts.pool,
            opts.block_rows,
            opts.dispatch,
            audit,
        );
        (logits, k_cache, v_cache)
    }
}

#[cfg(test)]
mod tests {
    use crate::runtime::engine::Engine;
    use crate::runtime::native::testsupport::{small_spec, tokens_for};
    use crate::runtime::native::NativeEngine;

    #[test]
    fn prefill_shapes_and_finite() {
        let mut e = NativeEngine::synthetic(vec![small_spec()]);
        let art = "tiny-lm-a.prefill16.dense";
        let bind = e.bind(art, &["tiny-lm-a.atw"]).unwrap();
        let out = e.prefill(art, &bind, &tokens_for(2, 16)).unwrap();
        assert_eq!(out.vocab, 384);
        assert_eq!(out.logits.len(), 2 * 16 * 384);
        assert_eq!(out.k_cache.len(), 2 * 2 * 16 * 16); // L*B*S*kvd
        assert!(out.logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn sparse_prefill_audits_and_differs_from_dense() {
        let mut e = NativeEngine::synthetic(vec![small_spec()]);
        let toks = tokens_for(2, 16);
        let b_dense = e
            .bind("tiny-lm-a.prefill16.dense", &["tiny-lm-a.atw"])
            .unwrap();
        let dense = e
            .prefill("tiny-lm-a.prefill16.dense", &b_dense, &toks)
            .unwrap();
        e.reset_audit();
        let b_nm = e
            .bind(
                "tiny-lm-a.prefill16.nm2_4",
                &["tiny-lm-a.atw", "tiny-lm-a.aux_ls.atw"],
            )
            .unwrap();
        let sparse = e
            .prefill("tiny-lm-a.prefill16.nm2_4", &b_nm, &toks)
            .unwrap();
        let audit = Engine::audit(&e).unwrap();
        assert!(audit.pruned_matmuls > 0, "no pruned projections ran");
        assert_eq!(audit.nm_violations, 0, "N:M contract violated");
        assert_eq!(audit.pruned_fallbacks, 0, "unexpected dense fallback");
        // 2:4 over layer-0 q/gate/down saves ~8% of this model's total
        // linear FLOPs (layer 1 is skipped by the ls policy)
        assert!(audit.flops_saved_frac() > 0.05);
        // per-projection coverage: under ls with layer 1 skipped, down
        // is fully covered, q/gate half-covered, k/v/o/up/lm_head not
        let m = |name: &str| audit.module(name).unwrap();
        assert!((m("down_proj").coverage_frac() - 1.0).abs() < 1e-12);
        assert!((m("q_proj").coverage_frac() - 0.5).abs() < 1e-12);
        assert!((m("gate_proj").coverage_frac() - 0.5).abs() < 1e-12);
        for dense_mod in ["k_proj", "v_proj", "o_proj", "up_proj", "lm_head"]
        {
            assert_eq!(m(dense_mod).coverage_frac(), 0.0, "{dense_mod}");
            assert!(m(dense_mod).dense_flops > 0, "{dense_mod} never ran");
        }
        let diff = dense
            .logits
            .iter()
            .zip(sparse.logits.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(diff > 0.0, "2:4 pruning changed nothing");
        assert!(sparse.logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn quantized_path_close_to_f32() {
        let mut e = NativeEngine::synthetic(vec![small_spec()]);
        let toks = tokens_for(2, 16);
        let bf = e
            .bind("tiny-lm-a.prefill16.dense", &["tiny-lm-a.atw"])
            .unwrap();
        let fp = e
            .prefill("tiny-lm-a.prefill16.dense", &bf, &toks)
            .unwrap();
        let bq = e
            .bind("tiny-lm-a.prefill16.sq", &["tiny-lm-a.sq.atw"])
            .unwrap();
        let q = e.prefill("tiny-lm-a.prefill16.sq", &bq, &toks).unwrap();
        let max_abs =
            fp.logits.iter().fold(0.0f32, |a, &b| a.max(b.abs()));
        let diff = fp
            .logits
            .iter()
            .zip(q.logits.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(
            diff < max_abs.max(1.0) * 0.5,
            "w8a8 drifted too far: {diff} vs absmax {max_abs}"
        );
    }

    #[test]
    fn nm_artifact_with_dense_aux_matches_dense_artifact() {
        // keep_dense everywhere must reproduce the dense path exactly —
        // the contract that lets one nm artifact serve dense requests.
        let mut e = NativeEngine::synthetic(vec![small_spec()]);
        let toks = tokens_for(2, 16);
        let b_dense = e
            .bind("tiny-lm-a.prefill16.dense", &["tiny-lm-a.atw"])
            .unwrap();
        let b_nm = e
            .bind(
                "tiny-lm-a.prefill16.nm2_4",
                &["tiny-lm-a.atw", "tiny-lm-a.aux_dense.atw"],
            )
            .unwrap();
        let a = e
            .prefill("tiny-lm-a.prefill16.dense", &b_dense, &toks)
            .unwrap();
        let c = e
            .prefill("tiny-lm-a.prefill16.nm2_4", &b_nm, &toks)
            .unwrap();
        for (x, y) in a.logits.iter().zip(c.logits.iter()) {
            assert_eq!(x, y);
        }
    }

    #[test]
    fn parallelism_is_bit_transparent() {
        // same engine weights, pool on vs off: identical logits
        let toks = tokens_for(2, 16);
        let art = "tiny-lm-a.prefill16.nm4_8";
        let run = |threads: usize| {
            let mut e = NativeEngine::synthetic(vec![small_spec()])
                .with_parallelism(threads);
            let bind = e
                .bind(art, &["tiny-lm-a.atw", "tiny-lm-a.aux_ls.atw"])
                .unwrap();
            e.prefill(art, &bind, &toks).unwrap().logits
        };
        let serial = run(1);
        assert_eq!(serial, run(4));
    }

    #[test]
    fn packed_prefill_matches_padded_rows() {
        // native packed pipeline == padded pipeline, row for row
        let mut e = NativeEngine::synthetic(vec![small_spec()]);
        let art = "tiny-lm-a.prefill16.nm2_4";
        let bind = e
            .bind(art, &["tiny-lm-a.atw", "tiny-lm-a.aux_ls.atw"])
            .unwrap();
        let prompts: Vec<Vec<i32>> =
            vec![tokens_for(1, 7), tokens_for(1, 16)];
        // padded reference through the static [2, 16] artifact
        let mut padded = vec![0i32; 2 * 16];
        padded[..7].copy_from_slice(&prompts[0]);
        padded[16..32].copy_from_slice(&prompts[1]);
        let full = e.prefill(art, &bind, &padded).unwrap();
        let packed = e.prefill_packed(art, &bind, &prompts).unwrap();
        assert_eq!(packed.lens, vec![7, 16]);
        assert_eq!(packed.total_tokens(), 23);
        let v = packed.vocab;
        assert_eq!(v, full.vocab);
        // request 0 rows 0..7, request 1 rows 7..23
        assert_eq!(&packed.logits[..7 * v], &full.logits[..7 * v]);
        assert_eq!(
            &packed.logits[7 * v..23 * v],
            &full.logits[16 * v..32 * v]
        );
        // K/V gather parity: [L, total, kvd] vs [L, B, S, kvd]
        let kvd = 16;
        for l in 0..2usize {
            let p0 = l * 23 * kvd;
            let f0 = l * 2 * 16 * kvd;
            assert_eq!(
                &packed.k_cache[p0..p0 + 7 * kvd],
                &full.k_cache[f0..f0 + 7 * kvd]
            );
            assert_eq!(
                &packed.k_cache[p0 + 7 * kvd..p0 + 23 * kvd],
                &full.k_cache[f0 + 16 * kvd..f0 + 32 * kvd]
            );
            assert_eq!(
                &packed.v_cache[p0 + 7 * kvd..p0 + 23 * kvd],
                &full.v_cache[f0 + 16 * kvd..f0 + 32 * kvd]
            );
        }
    }
}
