//! One dense decode step over block-paged KV (the paper confines
//! sparsity to prefill; decode is always dense / W8A8).
//!
//! [`NativeModel::decode_paged`] is the single decode implementation:
//! every cache access goes through a [`PagedKv`] block table (logical
//! position `p` → physical block `table[p / block]`, in-block row
//! `p % block`), the new token's K/V is appended into the sequence's
//! tail block in place, and attention gathers per block. The contiguous
//! `[L, B, C, H, D]` path used by [`crate::runtime::Engine::decode`] is
//! the special case "one block of `C` rows per batch row" — the same
//! code, the same float-op order, so paged and slot-style execution are
//! bit-identical by construction (pinned by `tests/paged_parity.rs`).

use std::sync::Arc;

use crate::kernels::simd::Dispatch;
use crate::runtime::engine::{PagedKv, SparsityAudit};
use crate::sparsity::plan::SparsityPlan;

use super::layers::{rmsnorm, silu, softmax_inplace, ExecOpts, ProjKind};
use super::model::NativeModel;
use super::prepared::PreparedModel;

impl NativeModel {
    /// Advance every batch row one decode step against a block-paged KV
    /// view. Projections run through the same
    /// [`super::layers::Projection`] steps as prefill, under the
    /// all-dense plan, against the bind-time prepared weights — a
    /// steady-state decode step performs **zero** weight preparation
    /// (the engine pins this with a debug assertion on the prep
    /// counter). Rows with an empty block table are static-shape
    /// fillers: they compute (keeping the batch shape static, as the
    /// slot path always did) but own no storage — they attend to their
    /// own freshly computed K/V only and write nothing. W8A8 uses
    /// per-token activation scales, so filler rows cannot perturb real
    /// rows through a shared batch absmax.
    #[allow(clippy::too_many_arguments)]
    pub(super) fn decode_paged(
        &self,
        token: &[i32],
        pos: &[i32],
        kv: &mut PagedKv<'_>,
        kv_len: &[i32],
        prepared: &PreparedModel,
        quantized: bool,
        block_rows: usize,
        dispatch: Dispatch,
        audit: &mut SparsityAudit,
    ) -> Vec<f32> {
        let sp = &self.spec;
        let b = token.len();
        let (d, qd, kvd) = (sp.d_model, sp.q_dim(), sp.kv_dim());
        let dh = sp.head_dim;
        let group = sp.n_q_heads / sp.n_kv_heads;
        let dense_plan = SparsityPlan::dense(sp.n_layers)
            .with_tiles(prepared.tiles.clone());
        let opts = ExecOpts::new(
            &dense_plan,
            quantized,
            false,
            None,
            block_rows,
            dispatch,
        );
        let mut x = self.embed_tokens(token);
        for (l, (lw, pl)) in self
            .layers
            .iter()
            .zip(prepared.layers.iter())
            .enumerate()
        {
            let h = Arc::new(rmsnorm(&x, b, d, &lw.attn_norm));
            let q = lw
                .projection(ProjKind::Q, sp, pl)
                .run(&h, b, l, &opts, audit);
            let k = lw
                .projection(ProjKind::K, sp, pl)
                .run(&h, b, l, &opts, audit);
            let v = lw
                .projection(ProjKind::V, sp, pl)
                .run(&h, b, l, &opts, audit);
            let mut attn = vec![0.0f32; b * qd];
            for bi in 0..b {
                let krow_new = &k[bi * kvd..(bi + 1) * kvd];
                let vrow_new = &v[bi * kvd..(bi + 1) * kvd];
                let paged = !kv.tables[bi].is_empty();
                let span = if paged {
                    let cap = kv.capacity(&kv.tables[bi]);
                    let p = (pos[bi].max(0) as usize).min(cap - 1);
                    // append this step's K/V at the row's position
                    // through the block table (assign, not accumulate —
                    // admission zeroed the blocks)
                    let w = kv.pos_offset(l, &kv.tables[bi], p);
                    kv.k[w..w + kvd].copy_from_slice(krow_new);
                    kv.v[w..w + kvd].copy_from_slice(vrow_new);
                    (kv_len[bi].max(1) as usize).min(cap)
                } else {
                    // filler row: no storage; span clamps to its own
                    // just-computed K/V (bit-identical to the slot path,
                    // which read position 0 right after writing it)
                    1
                };
                // hoist the block-table address translation out of the
                // per-head loops: offs[j] = float offset of position j,
                // shared by the K and V reads across every query head
                // (the inner loops then run on plain adds, like the old
                // contiguous slot stride)
                let offs: Vec<usize> = if paged {
                    (0..span)
                        .map(|j| kv.pos_offset(l, &kv.tables[bi], j))
                        .collect()
                } else {
                    Vec::new()
                };
                for hq in 0..sp.n_q_heads {
                    let kvh = hq / group;
                    let qrow = &q[bi * qd + hq * dh..bi * qd + (hq + 1) * dh];
                    let mut scores = vec![0.0f32; span];
                    for (j, sc) in scores.iter_mut().enumerate() {
                        let krow: &[f32] = if paged {
                            let kr = offs[j] + kvh * dh;
                            &kv.k[kr..kr + dh]
                        } else {
                            &krow_new[kvh * dh..(kvh + 1) * dh]
                        };
                        let dot: f32 = qrow
                            .iter()
                            .zip(krow.iter())
                            .map(|(a, c)| a * c)
                            .sum();
                        *sc = dot / (dh as f32).sqrt();
                    }
                    softmax_inplace(&mut scores);
                    let orow = &mut attn
                        [bi * qd + hq * dh..bi * qd + (hq + 1) * dh];
                    for (j, &wgt) in scores.iter().enumerate() {
                        let vrow: &[f32] = if paged {
                            let vr = offs[j] + kvh * dh;
                            &kv.v[vr..vr + dh]
                        } else {
                            &vrow_new[kvh * dh..(kvh + 1) * dh]
                        };
                        for (oe, &ve) in orow.iter_mut().zip(vrow.iter()) {
                            *oe += wgt * ve;
                        }
                    }
                }
            }
            let attn = Arc::new(attn);
            let o = lw
                .projection(ProjKind::O, sp, pl)
                .run(&attn, b, l, &opts, audit);
            for (xi, oi) in x.iter_mut().zip(o.iter()) {
                *xi += oi;
            }
            let h2 = Arc::new(rmsnorm(&x, b, d, &lw.mlp_norm));
            let gate = lw
                .projection(ProjKind::Gate, sp, pl)
                .run(&h2, b, l, &opts, audit);
            let up = lw
                .projection(ProjKind::Up, sp, pl)
                .run(&h2, b, l, &opts, audit);
            let act: Arc<Vec<f32>> = Arc::new(
                gate.iter()
                    .zip(up.iter())
                    .map(|(&g, &u)| silu(g) * u)
                    .collect(),
            );
            let down = lw
                .projection(ProjKind::Down, sp, pl)
                .run(&act, b, l, &opts, audit);
            for (xi, di) in x.iter_mut().zip(down.iter()) {
                *xi += di;
            }
        }
        self.logits(&x, b, prepared, None, block_rows, dispatch, audit)
    }
}

#[cfg(test)]
mod tests {
    use crate::runtime::engine::Engine;
    use crate::runtime::native::testsupport::{small_spec, tokens_for};
    use crate::runtime::native::NativeEngine;

    #[test]
    fn decode_continues_from_prefill_cache() {
        let mut e = NativeEngine::synthetic(vec![small_spec()]);
        let art = "tiny-lm-a.prefill16.dense";
        let bind = e.bind(art, &["tiny-lm-a.atw"]).unwrap();
        let toks = tokens_for(2, 16);
        let out = e.prefill(art, &bind, &toks).unwrap();
        // scatter prefill row 0 into a fresh decode cache
        let spec = e.model("tiny-lm-a").unwrap().spec.clone();
        let (l, b, c, kvd) =
            (spec.n_layers, spec.decode_batch, spec.cache_len, spec.kv_dim());
        let plen = 5usize;
        let mut kc = vec![0.0f32; l * b * c * kvd];
        let mut vc = vec![0.0f32; l * b * c * kvd];
        for li in 0..l {
            let src = (li * 2 * 16) * kvd; // prefill [L, 2, 16, kvd]
            let dst = (li * b * c) * kvd;
            kc[dst..dst + plen * kvd]
                .copy_from_slice(&out.k_cache[src..src + plen * kvd]);
            vc[dst..dst + plen * kvd]
                .copy_from_slice(&out.v_cache[src..src + plen * kvd]);
        }
        let dec = "tiny-lm-a.decode.dense";
        let dbind = e.bind(dec, &["tiny-lm-a.atw"]).unwrap();
        let mut token = vec![0i32; b];
        token[0] = 7;
        let mut pos = vec![0i32; b];
        pos[0] = plen as i32;
        let mut kv_len = vec![1i32; b];
        kv_len[0] = (plen + 1) as i32;
        let d = e
            .decode(dec, &dbind, &token, &pos, &kc, &vc, &kv_len)
            .unwrap();
        assert_eq!(d.logits.len(), b * 384);
        assert!(d.logits.iter().all(|v| v.is_finite()));
        // the new K/V landed at position plen of slot 0
        let slot = plen * kvd;
        assert!(d.k_cache[slot..slot + kvd].iter().any(|&v| v != 0.0));
    }
}
