//! `NativeEngine` — pure-Rust CPU execution of the serving path.
//!
//! The default backend: no PJRT, no XLA, no network. It executes a small
//! decoder-only transformer (GQA attention + SwiGLU MLP, RMSNorm, no
//! positional encoding — causality alone breaks symmetry at this scale)
//! as a composable prefill pipeline:
//!
//! * `model`    — [`ModelSpec`] geometry + deterministically synthesized
//!                weights (`Arc`-shared for the tile fan-out)
//! * `prepared` — bind-time weight preparation: panel packing at the
//!                per-module planned tile width + cached W8A8
//!                quantization, keyed per weight `Arc` (no hot path
//!                packs or quantizes anything)
//! * `layers`   — the `Projection` step abstraction: policy
//!                resolution from a [`SparsityPlan`], panel-packed
//!                register-tiled dense / block-compressed N:M /
//!                per-token W8A8 kernels ([`crate::kernels`]),
//!                per-module audit
//! * `prefill`  — one forward pass over a token-packed segment batch
//!                (right-padded `[b, s]` prefill is the equal-segment
//!                special case)
//! * `decode`   — the dense decode step over block-paged KV
//!                ([`crate::runtime::PagedKv`] block tables; the
//!                contiguous slot cache is the one-block special case)
//!
//! Per-request N:M configs arrive exactly as they do on the PJRT path:
//! the artifact name carries the ratio (`...nm2_4`) and the bound aux
//! file carries the setting (`naive` / `ls` / `all` / `dense`); the
//! engine turns them into an explicit [`SparsityPlan`] before anything
//! touches a kernel. The engine owns one [`ThreadPool`]
//! ([`Engine::set_parallelism`], driven by the coordinator's
//! `EngineConfig`) that every projection's row tiles fan out over.
//!
//! Weights are synthesized deterministically (seeded by model name), so
//! the full coordinator stack — router, batcher, scheduler, KV slots,
//! TCP front-end — runs end-to-end out of the box: with a real
//! `artifacts/manifest.json` the engine adopts its model geometry and
//! artifact inventory; without one it serves a self-contained synthetic
//! inventory. Every pruned activation is checked against `validate_nm`
//! and accounted in a [`SparsityAudit`].

mod decode;
mod layers;
mod model;
mod prefill;
mod prepared;

pub use model::{ModelSpec, NativeModel, RATIOS};

use std::collections::{BTreeMap, HashMap};
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use super::artifact::Manifest;
use super::engine::{
    DecodeOut, Engine, PackedPrefillOut, PagedDecodeOut, PagedKv,
    PrefillOut, PrefixedPrompt, PrepStats, SparsityAudit,
};
use crate::exec::ThreadPool;
use crate::kernels::simd::{Dispatch, Level};
use crate::sparsity::plan::{SparsityPlan, TileTable};
use crate::sparsity::policy::Setting;
use crate::sparsity::spmm::DEFAULT_BLOCK_ROWS;
use crate::util::json::Json;

use layers::ExecOpts;
use prefill::PrefixKv;
use prepared::{PrepCache, PreparedModel};

/// The native CPU execution engine (see module docs).
pub struct NativeEngine {
    manifest: Manifest,
    models: BTreeMap<String, NativeModel>,
    /// "artifact::binding-key" -> the per-layer/per-projection plan,
    /// built once at [`Engine::bind`] time and reused by every prefill
    /// (the plan carries its [`Setting`])
    bindings: HashMap<String, Arc<SparsityPlan>>,
    audit: SparsityAudit,
    /// run `validate_nm` on every pruned activation (cheap; on by default)
    pub validate: bool,
    /// projection fan-out pool; `None` = serial execution
    pool: Option<Arc<ThreadPool>>,
    /// row-tile height for the batched kernels
    pub block_rows: usize,
    /// uniform `dout`-tile override for the register-tiled kernels;
    /// `None` (the default) plans a per-module [`TileTable`] from each
    /// model's geometry at [`Engine::bind`] time (pure perf — outputs
    /// are bitwise identical for every width)
    pub tile_override: Option<usize>,
    /// force the SIMD dispatch to a specific level at the next bind
    /// (`None` = auto-detect); resolution fails loudly when the level
    /// is unavailable on this build/CPU
    force_level: Option<Level>,
    /// the SIMD kernel vtable resolved at [`Engine::bind`] time and
    /// threaded through `ExecOpts` — hot paths never probe the CPU.
    /// Scalar until the first bind; every level is bitwise identical,
    /// so the value is pure perf
    dispatch: Dispatch,
    /// bind-time weight preparation cache: panel-packed f32 + cached
    /// W8A8 quantization per weight id
    prep: PrepCache,
    /// (model name, tile table) -> the prepared weights bindings built
    /// under that table execute against. Keyed by table so toggling
    /// [`NativeEngine::tile_override`] between binds can never desync a
    /// live binding's plan from the weights it resolves to — each
    /// binding looks up preparation through its own plan's tiles.
    prepared: HashMap<(String, TileTable), Arc<PreparedModel>>,
}

impl NativeEngine {
    /// Engine over an artifacts directory: adopts `manifest.json` when
    /// present, otherwise serves the self-contained synthetic inventory.
    pub fn from_dir(dir: &Path) -> Result<NativeEngine> {
        if dir.join("manifest.json").exists() {
            let manifest = Manifest::load(dir)?;
            let models = manifest
                .models
                .values()
                .map(|info| {
                    let spec = ModelSpec::from_manifest(info, &manifest, dir);
                    (info.name.clone(), NativeModel::build(spec))
                })
                .collect();
            Ok(NativeEngine::assemble(manifest, models))
        } else {
            Ok(NativeEngine::synthetic(vec![ModelSpec::tiny("tiny-lm-a")]))
        }
    }

    /// Fully self-contained engine from explicit model specs.
    pub fn synthetic(specs: Vec<ModelSpec>) -> NativeEngine {
        let specs: Vec<ModelSpec> =
            specs.into_iter().map(ModelSpec::sanitize).collect();
        let mut artifacts = BTreeMap::new();
        let mut models_info = BTreeMap::new();
        let mut settings = BTreeMap::new();
        for spec in &specs {
            spec.manifest_entries(
                &mut artifacts,
                &mut models_info,
                &mut settings,
            );
        }
        let manifest = Manifest {
            dir: std::path::PathBuf::new(),
            artifacts,
            models: models_info,
            settings,
            raw: Json::Obj(BTreeMap::new()),
        };
        let models = specs
            .into_iter()
            .map(|spec| (spec.name.clone(), NativeModel::build(spec)))
            .collect();
        NativeEngine::assemble(manifest, models)
    }

    /// The default synthetic single-model engine.
    pub fn tiny() -> NativeEngine {
        NativeEngine::synthetic(vec![ModelSpec::tiny("tiny-lm-a")])
    }

    fn assemble(
        manifest: Manifest,
        models: BTreeMap<String, NativeModel>,
    ) -> NativeEngine {
        NativeEngine {
            manifest,
            models,
            bindings: HashMap::new(),
            audit: SparsityAudit::default(),
            validate: true,
            pool: None,
            block_rows: DEFAULT_BLOCK_ROWS,
            tile_override: None,
            force_level: None,
            dispatch: Dispatch::scalar(),
            prep: PrepCache::default(),
            prepared: HashMap::new(),
        }
    }

    /// Builder-style [`Engine::set_parallelism`].
    pub fn with_parallelism(mut self, threads: usize) -> NativeEngine {
        self.set_parallelism(threads);
        self
    }

    /// Builder-style uniform kernel `dout`-tile override (applies to
    /// bindings created afterwards, and to every decode); without it
    /// each model gets a per-module [`TileTable`] planned from its
    /// geometry. Pure perf either way: the parity suite pins that every
    /// width yields bitwise-identical outputs.
    pub fn with_dout_tile(mut self, dout_tile: usize) -> NativeEngine {
        self.tile_override = Some(crate::kernels::clamp_tile(dout_tile));
        self
    }

    /// Builder-style SIMD dispatch-level override: the next `bind`
    /// resolves its kernel vtable at exactly `level` instead of
    /// auto-detecting, failing loudly if the level is unavailable on
    /// this build/CPU. The test/tuning knob behind the `simd_` parity
    /// family — every level is bitwise identical, so this is pure perf.
    pub fn with_dispatch_level(mut self, level: Level) -> NativeEngine {
        self.force_level = Some(level);
        self
    }

    /// The dispatch level the engine last resolved (Scalar before any
    /// bind, and always Scalar without the `simd` feature).
    pub fn dispatch_level(&self) -> Level {
        self.dispatch.level
    }

    /// The tile table bindings of `spec`'s model are packed with: the
    /// uniform override when set, otherwise the geometry-planned
    /// per-module table, widened so full panels are whole vector
    /// registers at the resolved dispatch level (`lanes` = 1 keeps the
    /// scalar plan).
    fn tile_table(&self, spec: &ModelSpec, lanes: usize) -> TileTable {
        match self.tile_override {
            Some(t) => TileTable::uniform(t),
            None => TileTable::plan_for_lanes(
                &spec.geometry(),
                spec.vocab,
                lanes,
            ),
        }
    }

    /// Cumulative weight-preparation accounting (packs, cached
    /// quantizations, hits, bytes, one-time seconds), plus the
    /// still-resident row-major weight bytes (zero at steady state:
    /// `bind` releases originals once they are packed).
    pub fn prep_report(&self) -> PrepStats {
        let mut s = self.prep.stats();
        s.bytes_resident = self
            .models
            .values()
            .map(|m| m.weight_bytes_resident())
            .sum();
        s
    }

    /// The prepared-weight handle a binding of `artifact`'s model
    /// executes against, resolved by the binding plan's own tile table
    /// (so every binding sees exactly the preparation its plan was
    /// built with).
    fn prepared_for(
        &self,
        artifact: &str,
        tiles: &TileTable,
    ) -> Result<Arc<PreparedModel>> {
        let model_name = model_name_of(artifact);
        self.prepared
            .get(&(model_name.to_string(), tiles.clone()))
            .cloned()
            .ok_or_else(|| {
                anyhow!(
                    "artifact {artifact}: weights not prepared — \
                     bind() must run first"
                )
            })
    }

    /// Zero the accumulated [`SparsityAudit`].
    pub fn reset_audit(&mut self) {
        self.audit = SparsityAudit::default();
    }

    /// The loaded model by name, if any.
    pub fn model(&self, name: &str) -> Option<&NativeModel> {
        self.models.get(name)
    }

    fn model_for_artifact(&self, artifact: &str) -> Result<&NativeModel> {
        let model_name = model_name_of(artifact);
        self.models.get(model_name).ok_or_else(|| {
            anyhow!("artifact {artifact}: model '{model_name}' not loaded")
        })
    }

    fn binding_plan(
        &self,
        artifact: &str,
        binding: &str,
    ) -> Result<&Arc<SparsityPlan>> {
        self.bindings
            .get(&binding_key(artifact, binding))
            .ok_or_else(|| {
                anyhow!("artifact {artifact}: binding '{binding}' missing")
            })
    }

    /// The explicit per-layer/per-projection plan an (artifact, binding)
    /// pair resolves to — exactly what the kernels execute (prebuilt at
    /// bind time).
    pub fn plan_for(
        &self,
        artifact: &str,
        binding: &str,
    ) -> Result<SparsityPlan> {
        Ok(self.binding_plan(artifact, binding)?.as_ref().clone())
    }

    /// Shared prefill execution: resolve the binding's prebuilt plan,
    /// run the segment pipeline under the engine's pool/audit, and
    /// return `(logits, k_cache, v_cache, vocab, exec_secs)`. Both
    /// [`Engine::prefill`] (equal segments) and [`Engine::prefill_packed`]
    /// funnel through here so the padded and packed paths cannot
    /// diverge.
    fn exec_prefill(
        &mut self,
        artifact: &str,
        quantized: bool,
        binding: &str,
        tokens: &[i32],
        lens: &[usize],
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, usize, f64)> {
        let plan = Arc::clone(self.binding_plan(artifact, binding)?);
        let prepared = self.prepared_for(artifact, &plan.tiles)?;
        let validate = self.validate;
        let block_rows = self.block_rows;
        let pool = self.pool.clone();
        let mut audit = self.audit;
        let model = self.model_for_artifact(artifact)?;
        let opts = ExecOpts::new(
            &plan,
            quantized,
            validate,
            pool.as_deref(),
            block_rows,
            self.dispatch,
        );
        let vocab = model.spec.vocab;
        let t0 = Instant::now();
        let (logits, k_cache, v_cache) = model.prefill_segments(
            tokens, lens, &prepared, &opts, &mut audit,
        );
        let exec_secs = t0.elapsed().as_secs_f64();
        self.audit = audit;
        Ok((logits, k_cache, v_cache, vocab, exec_secs))
    }

    /// Prefix-aware variant of [`NativeEngine::exec_prefill`]: segment
    /// `i` holds only its request's suffix tokens and `prefixes[i]`
    /// carries the cached-prefix K/V (`[L, len, H_kv*D_h]`). Cold
    /// prefill is the all-empty-prefix special case, so the two paths
    /// share one pipeline and cannot drift.
    fn exec_prefill_prefixed(
        &mut self,
        artifact: &str,
        quantized: bool,
        binding: &str,
        tokens: &[i32],
        lens: &[usize],
        prefixes: &[PrefixKv<'_>],
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, usize, f64)> {
        let plan = Arc::clone(self.binding_plan(artifact, binding)?);
        let prepared = self.prepared_for(artifact, &plan.tiles)?;
        let validate = self.validate;
        let block_rows = self.block_rows;
        let pool = self.pool.clone();
        let mut audit = self.audit;
        let model = self.model_for_artifact(artifact)?;
        let opts = ExecOpts::new(
            &plan,
            quantized,
            validate,
            pool.as_deref(),
            block_rows,
            self.dispatch,
        );
        let vocab = model.spec.vocab;
        let t0 = Instant::now();
        let (logits, k_cache, v_cache) = model.prefill_segments_prefixed(
            tokens, lens, prefixes, &prepared, &opts, &mut audit,
        );
        let exec_secs = t0.elapsed().as_secs_f64();
        self.audit = audit;
        Ok((logits, k_cache, v_cache, vocab, exec_secs))
    }
}

fn binding_key(artifact: &str, binding: &str) -> String {
    format!("{artifact}::{binding}")
}

/// The model that owns an artifact: the leading dot-separated segment
/// of its name (`tiny-lm-a.prefill64.nm2_4` → `tiny-lm-a`).
fn model_name_of(artifact: &str) -> &str {
    artifact.split('.').next().unwrap_or(artifact)
}

/// Resolve the setting encoded in a bound file list: the aux file name
/// carries it (`<model>[.sq].aux_<tag>.atw`). N:M artifacts bound with no
/// aux default to naive magnitude scoring; dense artifacts to dense.
fn setting_from_files(files: &[&str], is_nm: bool) -> Result<Setting> {
    for f in files {
        let Some(idx) = f.find(".aux_") else { continue };
        let tag = f[idx + ".aux_".len()..].trim_end_matches(".atw");
        return match tag {
            "dense" => Ok(Setting::Dense),
            "naive" => Ok(Setting::Naive),
            "ls" => Ok(Setting::LayerSkip),
            "all" => Ok(Setting::All),
            other => Err(anyhow!("unknown aux setting '{other}' in {f}")),
        };
    }
    Ok(if is_nm { Setting::Naive } else { Setting::Dense })
}

impl Engine for NativeEngine {
    fn platform(&self) -> String {
        "native-cpu".to_string()
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn load_artifact(&mut self, name: &str) -> Result<f64> {
        self.manifest.artifact(name)?;
        self.model_for_artifact(name)?;
        Ok(0.0)
    }

    fn bind(&mut self, artifact: &str, files: &[&str]) -> Result<String> {
        let meta = self.manifest.artifact(artifact)?;
        let nm = meta.nm;
        let want_quant = meta.variant.starts_with("sq");
        let setting = setting_from_files(files, nm.is_some())?;
        // resolve the SIMD kernel vtable ONCE, here — the hot paths
        // carry the resolved function pointers through ExecOpts and
        // never probe the CPU (auto() caches detection process-wide)
        self.dispatch = match self.force_level {
            Some(level) => Dispatch::force(level).ok_or_else(|| {
                anyhow!(
                    "dispatch level {level:?} unavailable on this \
                     build/CPU (simd feature off, wrong arch, or \
                     missing ISA)"
                )
            })?,
            None => Dispatch::auto(),
        };
        // field-precise model lookup: `prep` below needs `&mut self`
        // alongside this `&NativeModel`
        let model_name = model_name_of(artifact).to_string();
        let model = self.models.get(&model_name).ok_or_else(|| {
            anyhow!("artifact {artifact}: model '{model_name}' not loaded")
        })?;
        let tiles =
            self.tile_table(&model.spec, self.dispatch.level.lanes_f32());
        let key = files.join("+");
        let map_key = binding_key(artifact, &key);
        // the plan is built once per binding and reused by every
        // prefill; rebuilt if the tile table changed since (e.g. the
        // uniform override was toggled between binds), so the plan's
        // table always matches what the weights are packed with
        let plan_stale = self
            .bindings
            .get(&map_key)
            .is_some_and(|p| p.tiles != tiles);
        if plan_stale || !self.bindings.contains_key(&map_key) {
            let plan = Arc::new(
                SparsityPlan::build(
                    model.spec.n_layers,
                    &model.spec.skip_layers,
                    nm,
                    setting,
                )
                .with_tiles(tiles.clone()),
            );
            self.bindings.insert(map_key, plan);
        }
        // bind-time weight preparation: panel-pack every projection at
        // its planned tile width, and (for sq bindings) quantize + pack
        // the int8 side — all cached per weight Arc, so a re-bind is
        // pure cache hits and no hot path ever prepares anything
        let pm = self.prep.prepare_model(model, &tiles, want_quant);
        // packed-only weight memory: the panels (and cached quant
        // source) are now the only copies the engine needs, so drop
        // the row-major originals instead of holding every projection
        // twice. A later re-bind at a different tile width
        // reconstructs the row-major view losslessly from any packed
        // entry (`PackedPanels::unpack`), so this is pure memory, not
        // a behavior change.
        if let Some(m) = self.models.get_mut(&model_name) {
            m.release_weight_originals();
        }
        self.prepared.insert((model_name, tiles), Arc::new(pm));
        Ok(key)
    }

    fn prefill(
        &mut self,
        artifact: &str,
        binding: &str,
        tokens: &[i32],
    ) -> Result<PrefillOut> {
        let meta = self.manifest.artifact(artifact)?.clone();
        if meta.kind != "prefill" {
            bail!("artifact {artifact} is not a prefill artifact");
        }
        let (b, s) = (meta.batch, meta.seq);
        if tokens.len() != b * s {
            bail!(
                "prefill {artifact}: tokens len {} != {b}x{s}",
                tokens.len()
            );
        }
        let lens = vec![s; b]; // padded prefill = equal segments
        let (logits, k_cache, v_cache, vocab, exec_secs) = self
            .exec_prefill(
                artifact,
                meta.variant.starts_with("sq"),
                binding,
                tokens,
                &lens,
            )?;
        Ok(PrefillOut {
            logits,
            batch: b,
            seq: s,
            vocab,
            k_cache,
            v_cache,
            exec_secs,
        })
    }

    fn prefill_packed(
        &mut self,
        artifact: &str,
        binding: &str,
        prompts: &[Vec<i32>],
    ) -> Result<PackedPrefillOut> {
        let meta = self.manifest.artifact(artifact)?.clone();
        if meta.kind != "prefill" {
            bail!("artifact {artifact} is not a prefill artifact");
        }
        if prompts.is_empty() {
            bail!("prefill_packed {artifact}: empty batch");
        }
        let s = meta.seq;
        if s == 0 {
            bail!("prefill_packed {artifact}: degenerate seq 0");
        }
        // clamp to the artifact's seq; empty prompts occupy one PAD row
        // (mirrors the scheduler's defensive clamping and the default
        // trait implementation)
        let lens: Vec<usize> =
            prompts.iter().map(|p| p.len().min(s).max(1)).collect();
        let total: usize = lens.iter().sum();
        let mut tokens: Vec<i32> = Vec::with_capacity(total);
        for (p, &len) in prompts.iter().zip(&lens) {
            if p.is_empty() {
                tokens.push(0); // PAD
            } else {
                tokens.extend_from_slice(&p[..len]);
            }
        }
        let (logits, k_cache, v_cache, vocab, exec_secs) = self
            .exec_prefill(
                artifact,
                meta.variant.starts_with("sq"),
                binding,
                &tokens,
                &lens,
            )?;
        Ok(PackedPrefillOut {
            logits,
            lens,
            vocab,
            k_cache,
            v_cache,
            padded_tokens: 0, // shape-flexible: no PAD rows computed
            exec_secs,
        })
    }

    fn prefill_packed_prefixed(
        &mut self,
        artifact: &str,
        binding: &str,
        reqs: &[PrefixedPrompt],
    ) -> Result<PackedPrefillOut> {
        let meta = self.manifest.artifact(artifact)?.clone();
        if meta.kind != "prefill" {
            bail!("artifact {artifact} is not a prefill artifact");
        }
        if reqs.is_empty() {
            bail!("prefill_packed_prefixed {artifact}: empty batch");
        }
        let s = meta.seq;
        if s == 0 {
            bail!("prefill_packed_prefixed {artifact}: degenerate seq 0");
        }
        let (layers, kvd) = {
            let spec = &self.model_for_artifact(artifact)?.spec;
            (spec.n_layers, spec.kv_dim())
        };
        // clamp to the artifact's seq (the scheduler clamps before the
        // prefix lookup, so cached_len is always within the clamped
        // prompt); validate the prefix buffers before any kernel runs
        let mut lens = Vec::with_capacity(reqs.len());
        let mut tokens: Vec<i32> = Vec::new();
        for (i, r) in reqs.iter().enumerate() {
            let full = r.tokens.len().min(s).max(1);
            if r.cached_len >= full {
                bail!(
                    "prefill_packed_prefixed {artifact}: request {i} has \
                     cached_len {} but only {full} prompt rows — at least \
                     one suffix token must be computed",
                    r.cached_len
                );
            }
            let want = layers * r.cached_len * kvd;
            if r.prefix_k.len() != want || r.prefix_v.len() != want {
                bail!(
                    "prefill_packed_prefixed {artifact}: request {i} \
                     prefix K/V must be [L={layers}, {}, {kvd}] = {want} \
                     floats (got {}/{})",
                    r.cached_len,
                    r.prefix_k.len(),
                    r.prefix_v.len()
                );
            }
            lens.push(full - r.cached_len);
            if r.tokens.is_empty() {
                tokens.push(0); // PAD, mirroring prefill_packed
            } else {
                tokens.extend_from_slice(&r.tokens[r.cached_len..full]);
            }
        }
        let prefixes: Vec<PrefixKv<'_>> = reqs
            .iter()
            .map(|r| PrefixKv {
                len: r.cached_len,
                k: &r.prefix_k,
                v: &r.prefix_v,
            })
            .collect();
        let (logits, k_cache, v_cache, vocab, exec_secs) = self
            .exec_prefill_prefixed(
                artifact,
                meta.variant.starts_with("sq"),
                binding,
                &tokens,
                &lens,
                &prefixes,
            )?;
        Ok(PackedPrefillOut {
            logits,
            lens,
            vocab,
            k_cache,
            v_cache,
            padded_tokens: 0, // cached rows are genuinely skipped
            exec_secs,
        })
    }

    fn decode(
        &mut self,
        artifact: &str,
        binding: &str,
        token: &[i32],
        pos: &[i32],
        k_cache: &[f32],
        v_cache: &[f32],
        kv_len: &[i32],
    ) -> Result<DecodeOut> {
        let meta = self.manifest.artifact(artifact)?.clone();
        if meta.kind != "decode" {
            bail!("artifact {artifact} is not a decode artifact");
        }
        let tiles = self.binding_plan(artifact, binding)?.tiles.clone();
        let b = meta.batch;
        let cache = meta.cache;
        if b == 0 || cache == 0 {
            bail!("decode {artifact}: degenerate batch {b} / cache {cache}");
        }
        if token.len() != b || pos.len() != b || kv_len.len() != b {
            bail!("decode {artifact}: batch inputs must have len {b}");
        }
        let quantized = meta.variant.starts_with("sq");
        let model = self.model_for_artifact(artifact)?;
        let expect =
            model.spec.n_layers * b * cache * model.spec.kv_dim();
        if k_cache.len() != expect || v_cache.len() != expect {
            bail!(
                "decode {artifact}: cache len {} != expected {expect}",
                k_cache.len()
            );
        }
        let vocab = model.spec.vocab;
        let mut kc = k_cache.to_vec();
        let mut vc = v_cache.to_vec();
        // contiguous [L, B, C, H, D] is the paged layout's special case
        // "one block of C rows per batch row": run the one paged
        // implementation over a trivial view — identical offsets,
        // identical float-op order (see decode.rs module docs)
        let mut view = PagedKv {
            n_layers: model.spec.n_layers,
            n_blocks: b,
            block_size: cache,
            kv_dim: model.spec.kv_dim(),
            tables: (0..b).map(|i| vec![i as u32]).collect(),
            k: &mut kc,
            v: &mut vc,
        };
        let mut audit = self.audit;
        let block_rows = self.block_rows;
        let dispatch = self.dispatch;
        let prepared = self.prepared_for(artifact, &tiles)?;
        // steady-state contract: a decode step performs zero weight
        // preparation — everything was packed/quantized at bind
        #[cfg(debug_assertions)]
        let prep_calls_before = self.prep.stats().prep_calls();
        let t0 = Instant::now();
        let logits = model.decode_paged(
            token, pos, &mut view, kv_len, &prepared, quantized,
            block_rows, dispatch, &mut audit,
        );
        let exec_secs = t0.elapsed().as_secs_f64();
        #[cfg(debug_assertions)]
        debug_assert_eq!(
            self.prep.stats().prep_calls(),
            prep_calls_before,
            "decode must not prepare weights"
        );
        self.audit = audit;
        Ok(DecodeOut {
            logits,
            batch: b,
            vocab,
            k_cache: kc,
            v_cache: vc,
            exec_secs,
        })
    }

    fn decode_paged(
        &mut self,
        artifact: &str,
        binding: &str,
        token: &[i32],
        pos: &[i32],
        kv: &mut PagedKv<'_>,
        kv_len: &[i32],
    ) -> Result<PagedDecodeOut> {
        let meta = self.manifest.artifact(artifact)?.clone();
        if meta.kind != "decode" {
            bail!("artifact {artifact} is not a decode artifact");
        }
        let tiles = self.binding_plan(artifact, binding)?.tiles.clone();
        let b = meta.batch;
        if token.len() != b || pos.len() != b || kv_len.len() != b {
            bail!("decode {artifact}: batch inputs must have len {b}");
        }
        if kv.tables.len() != b {
            bail!(
                "decode {artifact}: {} row tables != batch {b}",
                kv.tables.len()
            );
        }
        // loud, not silent: a write position beyond a row's block table
        // means the caller forgot to allocate the tail block (the inner
        // kernel's clamp is for the contiguous wrap only)
        for (row, table) in kv.tables.iter().enumerate() {
            if table.is_empty() {
                continue;
            }
            let p = pos[row].max(0) as usize;
            if p >= kv.capacity(table) {
                bail!(
                    "decode {artifact}: row {row} writes at {p} beyond \
                     its table ({} tokens) — allocate the tail block \
                     first",
                    kv.capacity(table)
                );
            }
        }
        let quantized = meta.variant.starts_with("sq");
        let model = self.model_for_artifact(artifact)?;
        if kv.n_layers != model.spec.n_layers
            || kv.kv_dim != model.spec.kv_dim()
        {
            bail!(
                "decode {artifact}: paged view geometry {}x{} != model \
                 {}x{}",
                kv.n_layers,
                kv.kv_dim,
                model.spec.n_layers,
                model.spec.kv_dim()
            );
        }
        let expect =
            kv.n_layers * kv.n_blocks * kv.block_size * kv.kv_dim;
        if kv.k.len() != expect || kv.v.len() != expect {
            bail!(
                "decode {artifact}: paged store len {} != expected {expect}",
                kv.k.len()
            );
        }
        let vocab = model.spec.vocab;
        let mut audit = self.audit;
        let block_rows = self.block_rows;
        let dispatch = self.dispatch;
        let prepared = self.prepared_for(artifact, &tiles)?;
        // steady-state contract: a decode step performs zero weight
        // preparation — everything was packed/quantized at bind
        #[cfg(debug_assertions)]
        let prep_calls_before = self.prep.stats().prep_calls();
        let t0 = Instant::now();
        let logits = model.decode_paged(
            token, pos, kv, kv_len, &prepared, quantized, block_rows,
            dispatch, &mut audit,
        );
        let exec_secs = t0.elapsed().as_secs_f64();
        #[cfg(debug_assertions)]
        debug_assert_eq!(
            self.prep.stats().prep_calls(),
            prep_calls_before,
            "decode must not prepare weights"
        );
        self.audit = audit;
        Ok(PagedDecodeOut {
            logits,
            batch: b,
            vocab,
            exec_secs,
        })
    }

    fn set_parallelism(&mut self, threads: usize) {
        let threads = threads.max(1);
        if threads <= 1 {
            self.pool = None;
        } else if self.pool.as_ref().map(|p| p.size()) != Some(threads) {
            self.pool = Some(Arc::new(ThreadPool::new(threads)));
        }
    }

    fn audit(&self) -> Option<SparsityAudit> {
        Some(self.audit)
    }

    fn prep_stats(&self) -> Option<PrepStats> {
        // prep_report (not the raw cache stats) so the resident-bytes
        // gauge reflects whether the row-major originals were released
        Some(self.prep_report())
    }
}

#[cfg(test)]
pub(crate) mod testsupport {
    use super::ModelSpec;

    pub(crate) fn small_spec() -> ModelSpec {
        ModelSpec {
            prefill_batch: 2,
            prefill_seqs: vec![16],
            decode_batch: 2,
            cache_len: 24,
            ..ModelSpec::tiny("tiny-lm-a")
        }
    }

    pub(crate) fn tokens_for(b: usize, s: usize) -> Vec<i32> {
        (0..b * s).map(|i| 1 + (i as i32 % 300)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::testsupport::small_spec;
    use super::*;

    #[test]
    fn plan_for_resolves_binding_to_policy_table() {
        let mut e = NativeEngine::synthetic(vec![small_spec()]);
        let bind = e
            .bind(
                "tiny-lm-a.prefill16.nm2_4",
                &["tiny-lm-a.atw", "tiny-lm-a.aux_ls.atw"],
            )
            .unwrap();
        let plan = e.plan_for("tiny-lm-a.prefill16.nm2_4", &bind).unwrap();
        assert!(plan.policy(0, "down_proj").is_sparse());
        assert!(plan.policy(0, "q_proj").is_sparse());
        // layer 1 is the tiny spec's skip layer: q/gate dense, down sparse
        assert!(!plan.policy(1, "q_proj").is_sparse());
        assert!(plan.policy(1, "down_proj").is_sparse());
        assert!(!plan.policy(0, "o_proj").is_sparse());
    }

    #[test]
    fn bind_prepares_weights_once_and_rebind_hits_cache() {
        let mut e = NativeEngine::synthetic(vec![small_spec()]);
        let art = "tiny-lm-a.prefill16.sq";
        e.bind(art, &["tiny-lm-a.sq.atw"]).unwrap();
        let s1 = e.prep_report();
        // 7 weights x 2 layers + lm_head packed; the 14 layer weights
        // quantized (lm_head logits always run f32)
        assert_eq!(s1.weights_packed, 15);
        assert_eq!(s1.weights_quantized, 14);
        assert!(s1.bytes_packed > 0);
        // re-bind (and a dense bind of the same model): zero new
        // preparations — pure cache hits
        e.bind(art, &["tiny-lm-a.sq.atw"]).unwrap();
        e.bind("tiny-lm-a.prefill16.dense", &["tiny-lm-a.atw"])
            .unwrap();
        let s2 = e.prep_report();
        assert_eq!(s2.prep_calls(), s1.prep_calls());
        assert!(s2.cache_hits > s1.cache_hits);
    }

    #[test]
    fn bind_drops_row_major_weight_originals() {
        let mut e = NativeEngine::synthetic(vec![small_spec()]);
        let before = e.prep_report();
        // before any bind the row-major originals are the only copy
        assert!(before.bytes_resident > 0);
        assert_eq!(before.bytes_packed, 0);
        let art = "tiny-lm-a.prefill16.sq";
        let bind = e.bind(art, &["tiny-lm-a.sq.atw"]).unwrap();
        let after = e.prep_report();
        // the ~2x duplication is gone: packed panels hold every value,
        // the originals are released
        assert_eq!(after.bytes_resident, 0);
        assert!(after.bytes_packed >= before.bytes_resident);
        // a re-bind at a NEW tile width must re-prepare from the
        // packed panels (lossless unpack), not from the originals
        e.tile_override = Some(5);
        e.bind(art, &["tiny-lm-a.sq.atw"]).unwrap();
        let repacked = e.prep_report();
        assert!(repacked.weights_packed > after.weights_packed);
        assert_eq!(repacked.bytes_resident, 0);
        // and serving still works off packed-only memory
        e.tile_override = None;
        let tokens = super::testsupport::tokens_for(2, 16);
        let out = e.prefill(art, &bind, &tokens).unwrap();
        assert!(out.logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn unknown_binding_is_rejected() {
        let mut e = NativeEngine::tiny();
        let err = e
            .prefill("tiny-lm-a.prefill64.dense", "nope", &[0; 8 * 64])
            .unwrap_err();
        assert!(err.to_string().contains("binding"));
    }
}
