//! The projection-step layer of the native pipeline.
//!
//! A transformer block is executed as a sequence of [`Projection`] steps
//! (q/k/v/o, gate/up/down, lm_head) instead of inline matmul code: each
//! step resolves its [`ProjPolicy`] from the prefill's [`SparsityPlan`],
//! dispatches to the panel-packed register-tiled dense / block-compressed
//! N:M / W8A8 kernels (optionally fanned out over the engine
//! [`ThreadPool`]), validates pruned activations, and attributes FLOPs to
//! its module in the [`SparsityAudit`] — one place for the
//! policy/kernel/audit plumbing the old monolith re-derived at every call
//! site.
//!
//! Activations flow through the pipeline as `Arc<Vec<f32>>`, so the
//! parallel dense tiles share the buffer with pool workers without a
//! per-call copy (zero-copy end-to-end), and the W8A8 path quantizes
//! activations with **per-token** scales, so a token's quantized output
//! never depends on its batchmates.
//!
//! Weights arrive **prepared**: every step holds a
//! [`PreparedWeight`](super::prepared::PreparedWeight) built once at
//! bind time — panel-packed f32 at the module's planned tile width,
//! plus the cached `(wq, w_scales)` int8 panels for quantized bindings.
//! No projection run packs or quantizes anything; the hot path is pure
//! kernel execution.
//!
//! [`ProjPolicy`]: crate::sparsity::plan::ProjPolicy

use crate::exec::ThreadPool;
use crate::kernels::simd::Dispatch;
use crate::quant;
use crate::runtime::engine::SparsityAudit;
use crate::sparsity::mask::validate_nm;
use crate::sparsity::plan::SparsityPlan;
use crate::sparsity::spmm::{
    dense_matmul_packed_dispatch, dense_matmul_packed_parallel_dispatch,
    NmCompressedBatch,
};

use std::sync::Arc;

use super::model::{LayerWeights, ModelSpec, NativeModel};
use super::prepared::{PreparedLayer, PreparedModel, PreparedWeight};

/// Execution knobs shared by every projection of one forward pass.
pub(super) struct ExecOpts<'a> {
    pub plan: &'a SparsityPlan,
    /// W8A8 (Outstanding-sparse) reference path
    pub quantized: bool,
    /// run `validate_nm` on every pruned activation
    pub validate: bool,
    /// row-tile fan-out pool; `None` = serial (bit-identical either way)
    pub pool: Option<&'a ThreadPool>,
    /// row-tile height for the batched kernels
    pub block_rows: usize,
    /// the SIMD kernel vtable the binding resolved at bind time — every
    /// level is bitwise identical, so this is pure perf
    pub dispatch: Dispatch,
}

impl<'a> ExecOpts<'a> {
    pub(super) fn new(
        plan: &'a SparsityPlan,
        quantized: bool,
        validate: bool,
        pool: Option<&'a ThreadPool>,
        block_rows: usize,
        dispatch: Dispatch,
    ) -> ExecOpts<'a> {
        ExecOpts {
            plan,
            quantized,
            validate,
            pool,
            block_rows: block_rows.max(1),
            dispatch,
        }
    }
}

/// The seven per-layer projection slots (plus the lm_head, built ad hoc).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(super) enum ProjKind {
    Q,
    K,
    V,
    O,
    Gate,
    Up,
    Down,
}

/// One linear projection step: which policy module it resolves against,
/// its bind-time-prepared weight (panel-packed f32 + cached int8), and
/// the optional Robust-Norm channel scores.
pub(super) struct Projection<'m> {
    pub module: &'static str,
    pub prep: &'m PreparedWeight,
    pub din: usize,
    pub dout: usize,
    pub scale: Option<&'m [f32]>,
}

impl LayerWeights {
    /// The projection step for one slot of this layer, running against
    /// the layer's prepared weights.
    pub(super) fn projection<'m>(
        &'m self,
        kind: ProjKind,
        sp: &ModelSpec,
        pl: &'m PreparedLayer,
    ) -> Projection<'m> {
        let (d, qd, kvd, f) =
            (sp.d_model, sp.q_dim(), sp.kv_dim(), sp.d_ff);
        let prep = pl.get(kind);
        match kind {
            ProjKind::Q => Projection {
                module: "q_proj",
                prep,
                din: d,
                dout: qd,
                scale: Some(&self.scale_q),
            },
            ProjKind::K => Projection {
                module: "k_proj",
                prep,
                din: d,
                dout: kvd,
                scale: None,
            },
            ProjKind::V => Projection {
                module: "v_proj",
                prep,
                din: d,
                dout: kvd,
                scale: None,
            },
            ProjKind::O => Projection {
                module: "o_proj",
                prep,
                din: qd,
                dout: d,
                scale: None,
            },
            ProjKind::Gate => Projection {
                module: "gate_proj",
                prep,
                din: d,
                dout: f,
                scale: Some(&self.scale_gate),
            },
            ProjKind::Up => Projection {
                module: "up_proj",
                prep,
                din: d,
                dout: f,
                scale: None,
            },
            ProjKind::Down => Projection {
                module: "down_proj",
                prep,
                din: f,
                dout: d,
                scale: Some(&self.scale_down),
            },
        }
    }
}

impl<'m> Projection<'m> {
    /// Execute this step over `[t, din]` activations under the plan's
    /// policy for (`layer`, module). Pruned activations are validated
    /// against the exact-N:M contract and accounted per module. The
    /// activation arrives `Arc`'d so the parallel dense tiles can share
    /// it with pool workers without copying (zero-copy end-to-end); the
    /// weight side is the bind-time panel-packed preparation — no
    /// packing or quantization happens here.
    pub(super) fn run(
        &self,
        x: &Arc<Vec<f32>>,
        t: usize,
        layer: usize,
        opts: &ExecOpts<'_>,
        audit: &mut SparsityAudit,
    ) -> Vec<f32> {
        debug_assert_eq!(self.prep.din, self.din, "prepared weight din");
        debug_assert_eq!(self.prep.dout, self.dout, "prepared weight dout");
        // the plan's tile table and the pack-time stamp must agree —
        // the packed data's width is what the kernel executes
        debug_assert_eq!(
            self.prep.tile,
            opts.plan.tiles.tile_for(self.module),
            "{}: prepared tile != planned tile",
            self.module
        );
        let policy = opts.plan.policy(layer, self.module);
        match policy.nm {
            Some((n, m)) if self.din % m == 0 => {
                let scale: &[f32] = if policy.scored {
                    self.scale.unwrap_or(&[])
                } else {
                    &[]
                };
                let c = NmCompressedBatch::compress(
                    x,
                    t,
                    self.din,
                    scale,
                    n,
                    m,
                    opts.block_rows,
                );
                let st = c.stats(self.dout);
                audit.record_pruned(
                    self.module,
                    st.dense_flops,
                    st.sparse_flops,
                );
                // decompress at most once, shared by validation and the
                // int8 reference path
                let pruned_dense = if opts.validate || opts.quantized {
                    Some(c.decompress())
                } else {
                    None
                };
                if let Some(pd) = &pruned_dense {
                    if opts.validate {
                        audit.nm_checks += 1;
                        for row in pd.chunks_exact(self.din) {
                            if !validate_nm(row, n, m) {
                                audit.nm_violations += 1;
                            }
                        }
                    }
                }
                if opts.quantized {
                    // NOTE: the int8 reference executes dense-shaped work
                    // over the pruned input; the audit still records n/m
                    // sparse FLOPs — the SpMM-hardware cost model (see
                    // SparsityAudit docs)
                    self.w8a8_per_token(
                        pruned_dense.as_deref().unwrap(),
                        t,
                        opts,
                    )
                } else {
                    match opts.pool {
                        Some(pool) => c.matmul_packed_parallel_dispatch(
                            &self.prep.packed,
                            pool,
                            opts.dispatch,
                        ),
                        None => c.matmul_packed_dispatch(
                            &self.prep.packed,
                            opts.dispatch,
                        ),
                    }
                }
            }
            other => {
                if other.is_some() {
                    // pruning was requested but din is not a multiple of
                    // m: execute dense and record the fallback loudly
                    audit.pruned_fallbacks += 1;
                }
                audit.record_dense(
                    self.module,
                    2 * (t * self.din * self.dout) as u64,
                );
                if opts.quantized {
                    self.w8a8_per_token(x, t, opts)
                } else {
                    match opts.pool {
                        Some(pool) => dense_matmul_packed_parallel_dispatch(
                            x,
                            t,
                            self.din,
                            &self.prep.packed,
                            pool,
                            opts.block_rows,
                            opts.dispatch,
                        ),
                        None => dense_matmul_packed_dispatch(
                            x,
                            t,
                            self.din,
                            &self.prep.packed,
                            opts.dispatch,
                        ),
                    }
                }
            }
        }
    }

    /// W8A8 path: **per-token** activation scales, per-channel weight
    /// scales, panel-packed register-tiled int8 kernel. The weight side
    /// (`wq`, `w_scales`) is the bind-time cached quantization — a
    /// quantized binding prepares it before any projection runs, so the
    /// hot path only quantizes the activation. With a pool in the opts
    /// the matmul fans out over row tiles like the dense/N:M paths
    /// (per-token scales make every row independent, so the fan-out is
    /// bit-identical to the serial kernel).
    fn w8a8_per_token(
        &self,
        x: &[f32],
        t: usize,
        opts: &ExecOpts<'_>,
    ) -> Vec<f32> {
        let q = self.prep.quant().unwrap_or_else(|| {
            panic!(
                "{}: quantized run without bind-time weight \
                 quantization — bind() must prepare sq bindings",
                self.module
            )
        });
        let (xq, xs) = quant::quantize_per_token(x, t, self.din);
        match opts.pool {
            Some(pool) => {
                let xq = Arc::new(xq);
                let xs = Arc::new(xs);
                quant::w8a8_matmul_packed_per_token_parallel_dispatch(
                    &xq,
                    t,
                    self.din,
                    &q.wq,
                    &xs,
                    &q.scales,
                    pool,
                    opts.block_rows,
                    opts.dispatch,
                )
            }
            None => quant::w8a8_matmul_packed_per_token_dispatch(
                &xq,
                t,
                self.din,
                &q.wq,
                &xs,
                &q.scales,
                opts.dispatch,
            ),
        }
    }
}

pub(super) fn rmsnorm(x: &[f32], t: usize, d: usize, w: &[f32]) -> Vec<f32> {
    let mut out = vec![0.0f32; t * d];
    for r in 0..t {
        let row = &x[r * d..(r + 1) * d];
        let ms = row.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let inv = 1.0 / (ms + 1e-5).sqrt();
        for j in 0..d {
            out[r * d + j] = row[j] * inv * w[j];
        }
    }
    out
}

pub(super) fn silu(v: f32) -> f32 {
    v / (1.0 + (-v).exp())
}

pub(super) fn softmax_inplace(scores: &mut [f32]) {
    let mx = scores.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let mut denom = 0.0f32;
    for s in scores.iter_mut() {
        *s = (*s - mx).exp();
        denom += *s;
    }
    let inv = 1.0 / denom.max(1e-30);
    for s in scores.iter_mut() {
        *s *= inv;
    }
}

/// One segment's cached-prefix K/V for prefix-aware attention: `len`
/// leading positions whose rows live in `k`/`v` (`[len, H_kv*D_h]`,
/// a single layer's slice). `len == 0` marks a cold segment.
pub(super) struct SegPrefix<'a> {
    pub len: usize,
    pub k: &'a [f32],
    pub v: &'a [f32],
}

impl SegPrefix<'_> {
    /// An empty (cold) prefix.
    pub(super) fn none() -> SegPrefix<'static> {
        SegPrefix { len: 0, k: &[], v: &[] }
    }
}

/// Causal GQA attention over token-packed segments: `segs` lists each
/// request's `(start_row, len)` in the packed `[total, *]` activation;
/// every token attends to its own segment's prefix only. A right-padded
/// `[b, s]` batch is the special case `segs = [(0,s), (s,s), ...]`, which
/// reproduces the pre-refactor per-batch-row attention bit-for-bit.
pub(super) fn causal_attention_segments(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    segs: &[(usize, usize)],
    sp: &ModelSpec,
) -> Vec<f32> {
    let cold: Vec<SegPrefix<'_>> =
        segs.iter().map(|_| SegPrefix::none()).collect();
    causal_attention_segments_prefixed(q, k, v, segs, &cold, sp)
}

/// Prefix-aware causal GQA attention: segment `i`'s queries sit at
/// **global** positions `prefixes[i].len ..`, attending first over the
/// cached-prefix K/V rows and then over the segment's own fresh rows.
/// With empty prefixes this is exactly [`causal_attention_segments`] —
/// one implementation, so the cold and warm paths cannot drift. The
/// float op sequence per query is identical to a cold run over the full
/// sequence (same ascending-`j` dots, same softmax over the same score
/// vector, same ascending-`j` V accumulation), which is what makes
/// forked-prefix prefill bitwise equal to cold prefill.
pub(super) fn causal_attention_segments_prefixed(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    segs: &[(usize, usize)],
    prefixes: &[SegPrefix<'_>],
    sp: &ModelSpec,
) -> Vec<f32> {
    let (qd, kvd, dh) = (sp.q_dim(), sp.kv_dim(), sp.head_dim);
    let group = sp.n_q_heads / sp.n_kv_heads;
    let inv_sqrt = 1.0 / (dh as f32).sqrt();
    let total = q.len() / qd;
    debug_assert_eq!(segs.len(), prefixes.len());
    let max_len = segs
        .iter()
        .zip(prefixes.iter())
        .map(|(&(_, l), pre)| l + pre.len)
        .max()
        .unwrap_or(0);
    let mut out = vec![0.0f32; total * qd];
    let mut scores = vec![0.0f32; max_len];
    for (&(start, len), pre) in segs.iter().zip(prefixes.iter()) {
        let off = pre.len;
        for p in 0..len {
            let qbase = (start + p) * qd;
            let span = off + p + 1;
            for hq in 0..sp.n_q_heads {
                let kvh = hq / group;
                let qrow = &q[qbase + hq * dh..qbase + (hq + 1) * dh];
                for (j, sc) in scores.iter_mut().take(span).enumerate() {
                    let krow = if j < off {
                        let kr = j * kvd + kvh * dh;
                        &pre.k[kr..kr + dh]
                    } else {
                        let kr = (start + j - off) * kvd + kvh * dh;
                        &k[kr..kr + dh]
                    };
                    let dot: f32 = qrow
                        .iter()
                        .zip(krow.iter())
                        .map(|(a, c)| a * c)
                        .sum();
                    *sc = dot * inv_sqrt;
                }
                softmax_inplace(&mut scores[..span]);
                let orow =
                    &mut out[qbase + hq * dh..qbase + (hq + 1) * dh];
                for (j, &wgt) in scores[..span].iter().enumerate() {
                    let vrow = if j < off {
                        let vr = j * kvd + kvh * dh;
                        &pre.v[vr..vr + dh]
                    } else {
                        let vr = (start + j - off) * kvd + kvh * dh;
                        &v[vr..vr + dh]
                    };
                    for (oe, &ve) in orow.iter_mut().zip(vrow.iter()) {
                        *oe += wgt * ve;
                    }
                }
            }
        }
    }
    out
}

impl NativeModel {
    /// Final norm + lm_head logits. The lm_head always runs dense f32
    /// (never quantized, never pruned, never validated) — the same
    /// special case as the pre-refactor `logits` helper — against the
    /// prepared (panel-packed) head weight.
    #[allow(clippy::too_many_arguments)]
    pub(super) fn logits(
        &self,
        x: &[f32],
        t: usize,
        prepared: &PreparedModel,
        pool: Option<&ThreadPool>,
        block_rows: usize,
        dispatch: Dispatch,
        audit: &mut SparsityAudit,
    ) -> Vec<f32> {
        let d = self.spec.d_model;
        let h = Arc::new(rmsnorm(x, t, d, &self.final_norm));
        let dense_plan =
            SparsityPlan::dense(0).with_tiles(prepared.tiles.clone());
        let opts = ExecOpts::new(
            &dense_plan,
            false,
            false,
            pool,
            block_rows,
            dispatch,
        );
        let head = Projection {
            module: "lm_head",
            prep: &prepared.lm_head,
            din: d,
            dout: self.spec.vocab,
            scale: None,
        };
        head.run(&h, t, 0, &opts, audit)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Splitting a segment at any offset into (cached prefix, fresh
    /// suffix) must reproduce the cold attention rows bitwise — the
    /// kernel-level core of the prefix-parity contract.
    #[test]
    fn prefixed_attention_matches_cold_at_every_split() {
        let sp = ModelSpec::tiny("attn-parity");
        let (qd, kvd) = (sp.q_dim(), sp.kv_dim());
        let len = 9usize;
        let mut rng = Rng::new(42);
        let fill = |rng: &mut Rng, n: usize| -> Vec<f32> {
            (0..n).map(|_| rng.below(2000) as f32 / 1000.0 - 1.0).collect()
        };
        let q = fill(&mut rng, len * qd);
        let k = fill(&mut rng, len * kvd);
        let v = fill(&mut rng, len * kvd);
        let cold = causal_attention_segments(&q, &k, &v, &[(0, len)], &sp);
        for off in 1..len {
            let pre = SegPrefix {
                len: off,
                k: &k[..off * kvd],
                v: &v[..off * kvd],
            };
            let warm = causal_attention_segments_prefixed(
                &q[off * qd..],
                &k[off * kvd..],
                &v[off * kvd..],
                &[(0, len - off)],
                &[pre],
                &sp,
            );
            assert_eq!(warm, cold[off * qd..], "split at {off} drifted");
        }
    }

    /// Two packed segments, one warm and one cold, in the same call:
    /// the cold segment must be unaffected by its neighbor's prefix.
    #[test]
    fn mixed_warm_cold_segments_are_independent() {
        let sp = ModelSpec::tiny("attn-mixed");
        let (qd, kvd) = (sp.q_dim(), sp.kv_dim());
        let (a_len, b_len, off) = (6usize, 5usize, 4usize);
        let mut rng = Rng::new(7);
        let fill = |rng: &mut Rng, n: usize| -> Vec<f32> {
            (0..n).map(|_| rng.below(2000) as f32 / 1000.0 - 1.0).collect()
        };
        // request A: full sequence a_len, suffix computed after `off`
        let qa = fill(&mut rng, a_len * qd);
        let ka = fill(&mut rng, a_len * kvd);
        let va = fill(&mut rng, a_len * kvd);
        // request B: cold
        let qb = fill(&mut rng, b_len * qd);
        let kb = fill(&mut rng, b_len * kvd);
        let vb = fill(&mut rng, b_len * kvd);
        let cold_a =
            causal_attention_segments(&qa, &ka, &va, &[(0, a_len)], &sp);
        let cold_b =
            causal_attention_segments(&qb, &kb, &vb, &[(0, b_len)], &sp);
        // packed: A's suffix rows then B's full rows
        let sfx = a_len - off;
        let mut q = qa[off * qd..].to_vec();
        q.extend_from_slice(&qb);
        let mut k = ka[off * kvd..].to_vec();
        k.extend_from_slice(&kb);
        let mut v = va[off * kvd..].to_vec();
        v.extend_from_slice(&vb);
        let out = causal_attention_segments_prefixed(
            &q,
            &k,
            &v,
            &[(0, sfx), (sfx, b_len)],
            &[
                SegPrefix {
                    len: off,
                    k: &ka[..off * kvd],
                    v: &va[..off * kvd],
                },
                SegPrefix::none(),
            ],
            &sp,
        );
        assert_eq!(out[..sfx * qd], cold_a[off * qd..]);
        assert_eq!(out[sfx * qd..], cold_b[..]);
    }
}
