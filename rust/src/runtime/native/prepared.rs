//! Bind-time weight preparation: panel packing + cached W8A8
//! quantization, keyed per weight identity.
//!
//! Every projection weight the hot path touches is prepared **once**,
//! at [`Engine::bind`] time, into a [`PreparedWeight`]:
//!
//! * the f32 matrix is packed into tile panels
//!   ([`crate::kernels::pack::PackedPanels`]) at the width the
//!   per-module [`TileTable`] plans for its output dimension, so the
//!   inner kernel loops stream weights unit-stride instead of striding
//!   by `dout`;
//! * for W8A8 (`sq*`) bindings the weight is additionally quantized
//!   (`quant::quantize_weight`, **the only call site under
//!   `runtime/native/`**) and its int8 bytes packed into the same
//!   panel layout — cached in a `OnceLock`, so quantization happens at
//!   most once per weight no matter how many bindings, prefills or
//!   decode steps share it.
//!
//! The [`PrepCache`] keys preparations by `(weight id, tile width)`
//! ([`ModelWeight::id`], a process-unique identity): re-binds, the
//! decode path, and the lm_head all resolve to the same
//! `Arc<PreparedWeight>` (a cache *hit*), so steady-state serving does
//! **zero** weight preparation — a contract the engine pins with a
//! debug assertion around every decode step, and reports through
//! [`PrepStats`] (`weight_prep_ms` / hit / miss counters in
//! `EngineMetrics`).
//!
//! Keying by id rather than pointer is what lets `bind` **release**
//! the row-major originals after packing ([`ModelWeight::release`])
//! without dangling the cache: the id stays valid with the data gone.
//! Packed weight memory is therefore not duplicated at steady state
//! (the `weight_bytes_resident` metric pins this at zero after bind) —
//! and when a released weight must be prepared again at a different
//! tile width, the cache reconstructs its row-major bytes losslessly
//! from any existing panel packing (`PackedPanels::unpack`), so the
//! new panels are bitwise identical to packing the original.
//!
//! [`Engine::bind`]: crate::runtime::Engine::bind

use std::collections::HashMap;
use std::sync::{Arc, OnceLock};
use std::time::Instant;

use crate::kernels::pack::PackedPanels;
use crate::quant;
use crate::runtime::engine::PrepStats;
use crate::sparsity::plan::TileTable;

use super::layers::ProjKind;
use super::model::{ModelWeight, NativeModel};

/// A quantized, panel-packed weight: the cached output of
/// `quantize_weight` + packing (per-column scales ride alongside).
/// Both members are `Arc`'d so the parallel W8A8 row tiles can share
/// them with pool workers without copying.
pub(super) struct QuantPanels {
    /// int8 weight bytes in tile-panel layout
    pub wq: Arc<PackedPanels<i8>>,
    /// per-output-column dequant scales
    pub scales: Arc<Vec<f32>>,
}

/// One projection weight, prepared for the hot path: panel-packed f32
/// (always) and panel-packed int8 + scales (once a quantized binding
/// asks for it). Shared by every binding/decode via `Arc`.
pub(super) struct PreparedWeight {
    /// contraction width
    pub din: usize,
    /// output columns
    pub dout: usize,
    /// panel / `dout`-tile width stamped at pack time (from the
    /// binding's [`TileTable`])
    pub tile: usize,
    /// f32 panels (`Arc` so pool workers share them zero-copy)
    pub packed: Arc<PackedPanels<f32>>,
    quant: OnceLock<QuantPanels>,
}

impl PreparedWeight {
    /// The cached quantized panels, if a quantized binding prepared
    /// them. Hot paths `expect` this: bind() prepares quantization for
    /// every `sq*` artifact before any projection runs.
    pub fn quant(&self) -> Option<&QuantPanels> {
        self.quant.get()
    }
}

/// One transformer layer's prepared projections.
pub(super) struct PreparedLayer {
    q: Arc<PreparedWeight>,
    k: Arc<PreparedWeight>,
    v: Arc<PreparedWeight>,
    o: Arc<PreparedWeight>,
    gate: Arc<PreparedWeight>,
    up: Arc<PreparedWeight>,
    down: Arc<PreparedWeight>,
}

impl PreparedLayer {
    /// The prepared weight for one projection slot.
    pub fn get(&self, kind: ProjKind) -> &PreparedWeight {
        match kind {
            ProjKind::Q => &self.q,
            ProjKind::K => &self.k,
            ProjKind::V => &self.v,
            ProjKind::O => &self.o,
            ProjKind::Gate => &self.gate,
            ProjKind::Up => &self.up,
            ProjKind::Down => &self.down,
        }
    }
}

/// A whole model's prepared weights under one tile table — what
/// prefill, decode and logits execute against.
pub(super) struct PreparedModel {
    /// per transformer layer
    pub layers: Vec<PreparedLayer>,
    /// the logits head (never quantized — logits always run f32)
    pub lm_head: Arc<PreparedWeight>,
    /// the tile table the weights were packed with
    pub tiles: TileTable,
}

/// The engine's preparation cache: `(weight id, tile width)` →
/// prepared weight, plus cumulative [`PrepStats`].
#[derive(Default)]
pub(super) struct PrepCache {
    weights: HashMap<(u64, usize), Arc<PreparedWeight>>,
    /// row-major quantization `(wq bytes, per-column scales)` per
    /// weight id — tile-independent, so preparing the same weight at
    /// another tile width re-packs the int8 panels but never
    /// re-quantizes
    quants: HashMap<u64, Arc<(Vec<i8>, Vec<f32>)>>,
    stats: PrepStats,
}

impl PrepCache {
    /// Snapshot of the cumulative preparation accounting.
    pub fn stats(&self) -> PrepStats {
        self.stats
    }

    /// The row-major f32 bytes of `w`: the resident original when it
    /// has not been released, otherwise a lossless reconstruction from
    /// any existing panel packing of the same weight (release happens
    /// only after a first packing exists, so one always does).
    fn row_major(&self, w: &ModelWeight) -> Arc<Vec<f32>> {
        if let Some(d) = w.data() {
            return Arc::clone(d);
        }
        let packed = self
            .weights
            .iter()
            .find(|((id, _), _)| *id == w.id())
            .map(|(_, p)| &p.packed)
            .unwrap_or_else(|| {
                panic!(
                    "weight {} released before any packing existed",
                    w.id()
                )
            });
        Arc::new(packed.unpack())
    }

    /// Get-or-pack one weight at `tile` width. A hit returns the
    /// shared handle; a miss packs (counted + timed).
    fn prepare(
        &mut self,
        w: &ModelWeight,
        din: usize,
        dout: usize,
        tile: usize,
    ) -> Arc<PreparedWeight> {
        let key = (w.id(), tile);
        if let Some(p) = self.weights.get(&key) {
            self.stats.cache_hits += 1;
            return Arc::clone(p);
        }
        let rm = self.row_major(w);
        let t0 = Instant::now();
        let packed = Arc::new(PackedPanels::pack(&rm, din, dout, tile));
        self.stats.prep_secs += t0.elapsed().as_secs_f64();
        self.stats.weights_packed += 1;
        self.stats.bytes_packed += packed.bytes() as u64;
        let p = Arc::new(PreparedWeight {
            din,
            dout,
            tile,
            packed,
            quant: OnceLock::new(),
        });
        self.weights.insert(key, Arc::clone(&p));
        p
    }

    /// Quantize + pack the int8 side of `p` if not already cached.
    /// Quantization itself runs **at most once per weight id** (the
    /// row-major bytes/scales are tile-independent and cached by id);
    /// a different tile width only re-packs those bytes into new
    /// panels. Works after release too: the f32 source is then
    /// reconstructed from `p`'s own panels, which is bitwise the
    /// original.
    fn ensure_quant(&mut self, w: &ModelWeight, p: &PreparedWeight) {
        if p.quant.get().is_some() {
            self.stats.cache_hits += 1;
            return;
        }
        let rm = match self.quants.get(&w.id()) {
            Some(q) => {
                self.stats.cache_hits += 1;
                Arc::clone(q)
            }
            None => {
                let src = self.row_major(w);
                let t0 = Instant::now();
                let (wq, scales) =
                    quant::quantize_weight(&src, p.din, p.dout);
                self.stats.prep_secs += t0.elapsed().as_secs_f64();
                self.stats.weights_quantized += 1;
                let q = Arc::new((wq, scales));
                self.quants.insert(w.id(), Arc::clone(&q));
                q
            }
        };
        let t0 = Instant::now();
        let wq = PackedPanels::pack(&rm.0, p.din, p.dout, p.tile);
        self.stats.prep_secs += t0.elapsed().as_secs_f64();
        self.stats.bytes_packed += wq.bytes() as u64;
        // a racing fill is impossible (the cache is behind &mut), but
        // set() is the non-panicking idempotent form regardless
        let _ = p.quant.set(QuantPanels {
            wq: Arc::new(wq),
            scales: Arc::new(rm.1.clone()),
        });
    }

    /// Prepare every projection of `model` under `tiles` (and, when
    /// `want_quant`, the cached W8A8 side of each layer weight — the
    /// lm_head stays f32-only: logits are never quantized). Cheap when
    /// already prepared: all lookups hit.
    pub fn prepare_model(
        &mut self,
        model: &NativeModel,
        tiles: &TileTable,
        want_quant: bool,
    ) -> PreparedModel {
        let sp = &model.spec;
        let (d, qd, kvd, f) =
            (sp.d_model, sp.q_dim(), sp.kv_dim(), sp.d_ff);
        let mut layers = Vec::with_capacity(model.layers.len());
        for lw in &model.layers {
            let slots: [(&ModelWeight, &str, usize, usize); 7] = [
                (&lw.wq, "q_proj", d, qd),
                (&lw.wk, "k_proj", d, kvd),
                (&lw.wv, "v_proj", d, kvd),
                (&lw.wo, "o_proj", qd, d),
                (&lw.w_gate, "gate_proj", d, f),
                (&lw.w_up, "up_proj", d, f),
                (&lw.w_down, "down_proj", f, d),
            ];
            let mut prepared: Vec<Arc<PreparedWeight>> =
                Vec::with_capacity(slots.len());
            for (w, module, din, dout) in slots {
                let p =
                    self.prepare(w, din, dout, tiles.tile_for(module));
                if want_quant {
                    self.ensure_quant(w, &p);
                }
                prepared.push(p);
            }
            let mut it = prepared.into_iter();
            layers.push(PreparedLayer {
                q: it.next().unwrap(),
                k: it.next().unwrap(),
                v: it.next().unwrap(),
                o: it.next().unwrap(),
                gate: it.next().unwrap(),
                up: it.next().unwrap(),
                down: it.next().unwrap(),
            });
        }
        let lm_head =
            self.prepare(&model.lm_head, d, sp.vocab, tiles.lm_head);
        PreparedModel {
            layers,
            lm_head,
            tiles: tiles.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::model::ModelSpec;
    use super::*;

    #[test]
    fn prepare_is_cached_per_weight_and_tile() {
        let model = NativeModel::build(ModelSpec::tiny("prep-test"));
        let tiles =
            TileTable::plan(&model.spec.geometry(), model.spec.vocab);
        let mut cache = PrepCache::default();
        let pm = cache.prepare_model(&model, &tiles, false);
        let s1 = cache.stats();
        // 7 weights per layer + lm_head, all misses, none quantized
        let expect = (7 * model.spec.n_layers + 1) as u64;
        assert_eq!(s1.weights_packed, expect);
        assert_eq!(s1.weights_quantized, 0);
        assert_eq!(s1.cache_hits, 0);
        assert!(s1.bytes_packed > 0);
        // tile stamps follow the table
        assert_eq!(
            pm.layers[0].get(ProjKind::K).tile,
            tiles.tile_for("k_proj")
        );
        assert_eq!(pm.lm_head.tile, tiles.lm_head);
        // re-prepare: pure hits, same handles
        let pm2 = cache.prepare_model(&model, &tiles, false);
        let s2 = cache.stats();
        assert_eq!(s2.weights_packed, expect);
        assert_eq!(s2.cache_hits, expect);
        assert!(Arc::ptr_eq(&pm.lm_head, &pm2.lm_head));
        // quantized re-prepare: quantizes the 7*L layer weights once,
        // never the lm_head; a further pass is all hits again
        let pm3 = cache.prepare_model(&model, &tiles, true);
        let s3 = cache.stats();
        assert_eq!(
            s3.weights_quantized,
            (7 * model.spec.n_layers) as u64
        );
        assert!(pm3.layers[0].get(ProjKind::Q).quant().is_some());
        assert!(pm3.lm_head.quant().is_none());
        let calls_before = cache.stats().prep_calls();
        cache.prepare_model(&model, &tiles, true);
        assert_eq!(cache.stats().prep_calls(), calls_before);
        // a different tile table re-packs (f32 + int8 panels) but
        // NEVER re-quantizes: the row-major bytes are cached per id
        let uni = TileTable::uniform(4);
        let pm4 = cache.prepare_model(&model, &uni, true);
        let s4 = cache.stats();
        assert_eq!(
            s4.weights_quantized,
            (7 * model.spec.n_layers) as u64,
            "re-tiling must not re-quantize"
        );
        assert_eq!(s4.weights_packed, 2 * expect);
        assert!(pm4.layers[0].get(ProjKind::Q).quant().is_some());
        assert_eq!(pm4.layers[0].get(ProjKind::Q).tile, 4);
    }

    #[test]
    fn packed_panels_roundtrip_through_prepared_weight() {
        let model = NativeModel::build(ModelSpec::tiny("prep-rt"));
        let mut cache = PrepCache::default();
        let lw = &model.layers[0];
        let (d, f) = (model.spec.d_model, model.spec.d_ff);
        let original: Vec<f32> = lw.w_gate.data().unwrap().to_vec();
        let p = cache.prepare(&lw.w_gate, d, f, 16);
        assert_eq!(p.packed.unpack(), original);
        cache.ensure_quant(&lw.w_gate, &p);
        let q = p.quant().unwrap();
        let (wq, ws) = quant::quantize_weight(&original, d, f);
        assert_eq!(q.wq.unpack(), wq);
        assert_eq!(*q.scales, ws);
    }

    #[test]
    fn released_weights_reprepare_bitwise_from_panels() {
        // pack dense-only, release the originals, then ask for a
        // quantized preparation at a NEW tile width: both the f32
        // panels and the int8 quantization must be reconstructed
        // bitwise from the surviving panel packing
        let mut model = NativeModel::build(ModelSpec::tiny("prep-rel"));
        let tiles =
            TileTable::plan(&model.spec.geometry(), model.spec.vocab);
        let mut cache = PrepCache::default();
        cache.prepare_model(&model, &tiles, false);
        // goldens from the resident originals
        let (d, f) = (model.spec.d_model, model.spec.d_ff);
        let w0: Vec<f32> =
            model.layers[0].w_gate.data().unwrap().to_vec();
        let (wq0, ws0) = quant::quantize_weight(&w0, d, f);
        assert!(model.weight_bytes_resident() > 0);
        model.release_weight_originals();
        assert_eq!(model.weight_bytes_resident(), 0);
        // re-tile + quantize with the data gone
        let uni = TileTable::uniform(4);
        let pm = cache.prepare_model(&model, &uni, true);
        let p = pm.layers[0].get(ProjKind::Gate);
        assert_eq!(p.tile, 4);
        assert_eq!(p.packed.unpack(), w0, "f32 repack drifted");
        let q = p.quant().unwrap();
        assert_eq!(q.wq.unpack(), wq0, "int8 quantization drifted");
        assert_eq!(*q.scales, ws0);
    }
}
