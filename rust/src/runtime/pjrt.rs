//! The PJRT execution engine (`pjrt` cargo feature).
//!
//! One `ModelRuntime` owns the CPU client, the compiled executables and
//! the bound weight literals. The coordinator reaches it through the
//! [`Engine`](super::engine::Engine) trait; everything below is generic
//! tuple plumbing over the `xla` crate.
//!
//! Perf note (§Perf in EXPERIMENTS.md): weights are uploaded to device
//! buffers ONCE per (artifact, weight-set) binding via
//! `buffer_from_host_literal`, and executions use `execute_b` so steady-
//! state calls only upload the small runtime inputs (tokens / KV cache).

use std::collections::HashMap;
use std::path::PathBuf;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};
use xla::{Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable};

use super::artifact::{ArtifactMeta, Manifest};
use super::engine::{DecodeOut, Engine, PrefillOut};
use crate::tensor::io::read_weights;
use crate::tensor::HostTensor;

/// A compiled artifact + the device-resident weight buffers for one or
/// more weight-set bindings (e.g. the same nm executable bound to the
/// "naive" / "ls" / "all" aux settings).
struct Compiled {
    exe: PjRtLoadedExecutable,
    meta: ArtifactMeta,
    /// binding key (weight files joined with '+') -> device buffers in
    /// executable argument order
    bindings: HashMap<String, Vec<PjRtBuffer>>,
}

/// The PJRT/XLA execution engine over AOT-compiled HLO artifacts.
pub struct ModelRuntime {
    client: PjRtClient,
    /// the artifact + model inventory being served
    pub manifest: Manifest,
    dir: PathBuf,
    compiled: HashMap<String, Compiled>,
    /// weight file -> tensor name -> host literal
    weight_files: HashMap<String, HashMap<String, Literal>>,
}

impl ModelRuntime {
    /// A runtime over `<artifacts_dir>/manifest.json` with a CPU PJRT
    /// client.
    pub fn new(artifacts_dir: &std::path::Path) -> Result<ModelRuntime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = PjRtClient::cpu()?;
        Ok(ModelRuntime {
            client,
            manifest,
            dir: artifacts_dir.to_path_buf(),
            compiled: HashMap::new(),
            weight_files: HashMap::new(),
        })
    }

    /// Load + compile an artifact (idempotent). Returns compile seconds.
    fn load_artifact_inner(&mut self, name: &str) -> Result<f64> {
        if self.compiled.contains_key(name) {
            return Ok(0.0);
        }
        let meta = self.manifest.artifact(name)?.clone();
        let hlo_path = self.dir.join(&meta.hlo);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path
                .to_str()
                .ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parse HLO {}", hlo_path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile artifact {name}"))?;
        let secs = t0.elapsed().as_secs_f64();
        self.compiled.insert(
            name.to_string(),
            Compiled { exe, meta, bindings: HashMap::new() },
        );
        Ok(secs)
    }

    fn ensure_weight_file(&mut self, file: &str) -> Result<()> {
        if self.weight_files.contains_key(file) {
            return Ok(());
        }
        let path = self.dir.join("weights").join(file);
        let tensors = read_weights(&path)?;
        let mut map = HashMap::new();
        for t in tensors {
            let lit = t.to_literal()?;
            map.insert(t.name.clone(), lit);
        }
        self.weight_files.insert(file.to_string(), map);
        Ok(())
    }

    /// Bind a set of weight files to an artifact: resolves every name in
    /// the artifact's flattened-parameter list against the union of the
    /// files and uploads the literals to device buffers once.
    fn bind_inner(&mut self, artifact: &str, files: &[&str]) -> Result<String> {
        let key = files.join("+");
        if self
            .compiled
            .get(artifact)
            .map(|c| c.bindings.contains_key(&key))
            .unwrap_or(false)
        {
            return Ok(key);
        }
        self.load_artifact_inner(artifact)?;
        for f in files {
            self.ensure_weight_file(f)?;
        }
        let meta = self.compiled[artifact].meta.clone();
        let mut buffers = Vec::with_capacity(meta.params.len());
        for pname in &meta.params {
            let mut found = None;
            for f in files {
                if let Some(lit) = self.weight_files[*f].get(pname) {
                    found = Some(lit);
                    break;
                }
            }
            let lit = found.ok_or_else(|| {
                anyhow!(
                    "artifact {artifact}: param '{pname}' not found in \
                     weight files {files:?}"
                )
            })?;
            let buf = self.client.buffer_from_host_literal(None, lit)?;
            buffers.push(buf);
        }
        self.compiled
            .get_mut(artifact)
            .unwrap()
            .bindings
            .insert(key.clone(), buffers);
        Ok(key)
    }

    /// Raw tuple execution: weights from `binding`, then `inputs`.
    fn execute(
        &self,
        artifact: &str,
        binding: &str,
        inputs: &[&Literal],
    ) -> Result<(Vec<Literal>, f64)> {
        let c = self
            .compiled
            .get(artifact)
            .ok_or_else(|| anyhow!("artifact {artifact} not loaded"))?;
        let weights = c
            .bindings
            .get(binding)
            .ok_or_else(|| anyhow!("binding {binding} missing"))?;
        if c.meta.runtime_inputs.len() != inputs.len() {
            bail!(
                "artifact {artifact}: expected {} runtime inputs, got {}",
                c.meta.runtime_inputs.len(),
                inputs.len()
            );
        }
        // upload runtime inputs, then run fully on device buffers.
        // Buffers can't be cheaply cloned; execute_b borrows, so we build
        // a reference vec over (weights..., uploaded inputs...).
        let t0 = Instant::now();
        let uploaded: Vec<PjRtBuffer> = inputs
            .iter()
            .map(|l| self.client.buffer_from_host_literal(None, l))
            .collect::<Result<_, _>>()?;
        let mut refs: Vec<&PjRtBuffer> =
            Vec::with_capacity(weights.len() + uploaded.len());
        refs.extend(weights.iter());
        refs.extend(uploaded.iter());
        let result = c.exe.execute_b(&refs)?;
        let tuple = result[0][0].to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        Ok((parts, t0.elapsed().as_secs_f64()))
    }

    /// `[L, B, C, H_kv, D_h]` shape of a decode artifact's cache input.
    fn cache_dims(meta: &ArtifactMeta) -> Result<Vec<i64>> {
        let dims = meta
            .runtime_inputs
            .get(2)
            .map(|(shape, _)| shape.clone())
            .ok_or_else(|| {
                anyhow!("artifact {}: no KV cache input", meta.name)
            })?;
        Ok(dims.into_iter().map(|d| d as i64).collect())
    }
}

impl Engine for ModelRuntime {
    fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn load_artifact(&mut self, name: &str) -> Result<f64> {
        self.load_artifact_inner(name)
    }

    fn bind(&mut self, artifact: &str, files: &[&str]) -> Result<String> {
        self.bind_inner(artifact, files)
    }

    fn prefill(
        &mut self,
        artifact: &str,
        binding: &str,
        tokens: &[i32],
    ) -> Result<PrefillOut> {
        let meta = self.manifest.artifact(artifact)?.clone();
        let (b, s) = (meta.batch, meta.seq);
        if tokens.len() != b * s {
            bail!(
                "prefill {artifact}: tokens len {} != {}x{}",
                tokens.len(),
                b,
                s
            );
        }
        let tok = HostTensor::i32("tokens", vec![b as i64, s as i64], tokens)
            .to_literal()?;
        let (parts, secs) = self.execute(artifact, binding, &[&tok])?;
        if parts.len() != 3 {
            bail!("prefill {artifact}: expected 3 outputs");
        }
        let mut it = parts.into_iter();
        let logits_lit = it.next().unwrap();
        let k = it.next().unwrap();
        let v = it.next().unwrap();
        let logits: Vec<f32> = logits_lit.to_vec()?;
        let vocab = logits.len() / (b * s);
        Ok(PrefillOut {
            logits,
            batch: b,
            seq: s,
            vocab,
            k_cache: k.to_vec()?,
            v_cache: v.to_vec()?,
            exec_secs: secs,
        })
    }

    fn decode(
        &mut self,
        artifact: &str,
        binding: &str,
        token: &[i32],
        pos: &[i32],
        k_cache: &[f32],
        v_cache: &[f32],
        kv_len: &[i32],
    ) -> Result<DecodeOut> {
        let meta = self.manifest.artifact(artifact)?.clone();
        let b = meta.batch;
        let dims = Self::cache_dims(&meta)?;
        let expect: i64 = dims.iter().product();
        if k_cache.len() as i64 != expect {
            bail!(
                "decode {artifact}: cache len {} != {expect}",
                k_cache.len()
            );
        }
        let tok =
            HostTensor::i32("token", vec![b as i64], token).to_literal()?;
        let pos_l =
            HostTensor::i32("pos", vec![b as i64], pos).to_literal()?;
        let len_l =
            HostTensor::i32("kv_len", vec![b as i64], kv_len).to_literal()?;
        let k_lit = HostTensor::f32("k", dims.clone(), k_cache).to_literal()?;
        let v_lit = HostTensor::f32("v", dims, v_cache).to_literal()?;
        let (parts, secs) = self.execute(
            artifact,
            binding,
            &[&tok, &pos_l, &k_lit, &v_lit, &len_l],
        )?;
        let mut it = parts.into_iter();
        let logits_lit = it.next().unwrap();
        let k = it.next().unwrap();
        let v = it.next().unwrap();
        let logits: Vec<f32> = logits_lit.to_vec()?;
        let vocab = logits.len() / b;
        Ok(DecodeOut {
            logits,
            batch: b,
            vocab,
            k_cache: k.to_vec()?,
            v_cache: v.to_vec()?,
            exec_secs: secs,
        })
    }
}

// NOTE on device-resident KV (§Perf L3, investigated and rejected):
// `execute_b` lets inputs stay as PJRT buffers, but this xla crate's
// execute path returns the whole output TUPLE as a single buffer —
// splitting it into (logits, k, v) requires `to_literal_sync`, i.e. a
// full host round-trip anyway, after which the caches must be
// re-uploaded. The buffer path therefore costs strictly more than the
// literal path here; the decode KV shuttle stays host-side and is
// measured in EXPERIMENTS.md §Perf (it is ~1% of decode exec time at
// this scale).
