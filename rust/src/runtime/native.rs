//! `NativeEngine` — pure-Rust CPU execution of the serving path.
//!
//! The default backend: no PJRT, no XLA, no network. It executes a small
//! decoder-only transformer (GQA attention + SwiGLU MLP, RMSNorm, no
//! positional encoding — causality alone breaks symmetry at this scale)
//! directly with the crate's own numeric substrate:
//!
//! * dense projections via `sparsity::spmm::dense_matmul`,
//! * N:M-pruned projections via `sparsity::spmm::NmCompressed` — the
//!   same compressed SpMM the paper's hardware would run, applied to
//!   exactly the module types the paper prunes (`sparsity::policy`),
//! * the W8A8 Outstanding-sparse compute path via `quant`.
//!
//! Per-request N:M configs arrive exactly as they do on the PJRT path:
//! the artifact name carries the ratio (`...nm2_4`) and the bound aux
//! file carries the setting (`naive` / `ls` / `all` / `dense`).
//!
//! Weights are synthesized deterministically (seeded by model name), so
//! the full coordinator stack — router, batcher, scheduler, KV slots,
//! TCP front-end — runs end-to-end out of the box: with a real
//! `artifacts/manifest.json` the engine adopts its model geometry and
//! artifact inventory; without one it serves a self-contained synthetic
//! inventory. Every pruned activation is checked against `validate_nm`
//! and accounted in a [`SparsityAudit`].

use std::collections::{BTreeMap, HashMap};
use std::path::Path;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use super::artifact::{ArtifactMeta, Manifest, ModelInfo};
use super::engine::{DecodeOut, Engine, PrefillOut, SparsityAudit};
use crate::quant;
use crate::sparsity::mask::validate_nm;
use crate::sparsity::policy::{self, Setting};
use crate::sparsity::spmm::{dense_matmul, NmCompressed};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// The N:M ratios every model's artifact inventory covers.
pub const RATIOS: [(usize, usize); 3] = [(2, 4), (4, 8), (8, 16)];

/// Geometry + serving shapes of one native model.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_q_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub d_ff: usize,
    pub prefill_batch: usize,
    pub prefill_seqs: Vec<usize>,
    pub decode_batch: usize,
    pub cache_len: usize,
    /// layers where q/gate stay dense under the `ls` / `all` settings
    pub skip_layers: Vec<usize>,
    pub seed: u64,
}

impl ModelSpec {
    /// Self-contained default: the tiny-lm geometry the repo's tests and
    /// token world (vocab 384) assume. All dims divide 16 so every
    /// supported N:M group size applies cleanly.
    pub fn tiny(name: &str) -> ModelSpec {
        ModelSpec {
            name: name.to_string(),
            vocab: 384,
            d_model: 32,
            n_layers: 2,
            n_q_heads: 2,
            n_kv_heads: 1,
            head_dim: 16,
            d_ff: 64,
            prefill_batch: 8,
            prefill_seqs: vec![64],
            decode_batch: 8,
            cache_len: 96,
            skip_layers: vec![1],
            seed: fnv1a(name.as_bytes()),
        }
    }

    /// Adopt geometry from a real manifest entry; anything missing keeps
    /// the tiny default. Dimensions are then sanitized so attention and
    /// pruning group math stay well-defined.
    pub fn from_manifest(
        info: &ModelInfo,
        manifest: &Manifest,
        dir: &Path,
    ) -> ModelSpec {
        let mut spec = ModelSpec::tiny(&info.name);
        let g = |k: &str| info.config.get(k).copied().unwrap_or(0);
        let adopt = |cur: &mut usize, v: usize| {
            if v > 0 {
                *cur = v;
            }
        };
        adopt(&mut spec.vocab, g("vocab_size"));
        adopt(&mut spec.d_model, g("d_model"));
        adopt(&mut spec.n_layers, g("n_layers"));
        adopt(&mut spec.n_q_heads, g("n_q_heads"));
        adopt(&mut spec.n_kv_heads, g("n_kv_heads"));
        adopt(&mut spec.head_dim, g("head_dim"));
        adopt(&mut spec.d_ff, g("d_ff"));
        // serving shapes from the artifact inventory
        let mut seqs: Vec<usize> = Vec::new();
        for a in manifest.artifacts.values() {
            if !a.name.starts_with(&format!("{}.", info.name)) {
                continue;
            }
            if a.kind == "prefill" {
                if !seqs.contains(&a.seq) && a.seq > 0 {
                    seqs.push(a.seq);
                }
                if a.batch > 0 {
                    spec.prefill_batch = a.batch;
                }
            } else if a.kind == "decode" {
                if a.batch > 0 {
                    spec.decode_batch = a.batch;
                }
                if a.cache > 0 {
                    spec.cache_len = a.cache;
                }
            }
        }
        if !seqs.is_empty() {
            seqs.sort_unstable();
            spec.prefill_seqs = seqs;
        }
        if let Some(skips) = stats_skip_layers(dir, &info.name) {
            spec.skip_layers = skips;
        } else {
            spec.skip_layers = vec![spec.n_layers.saturating_sub(1)];
        }
        spec.sanitize()
    }

    fn sanitize(mut self) -> ModelSpec {
        if self.n_kv_heads == 0 || self.n_q_heads % self.n_kv_heads != 0 {
            self.n_kv_heads = self.n_q_heads.max(1);
            self.n_q_heads = self.n_kv_heads;
        }
        self.vocab = self.vocab.max(16);
        self.cache_len = self.cache_len.max(self.max_prefill_seq() + 16);
        self
    }

    pub fn q_dim(&self) -> usize {
        self.n_q_heads * self.head_dim
    }

    pub fn kv_dim(&self) -> usize {
        self.n_kv_heads * self.head_dim
    }

    pub fn max_prefill_seq(&self) -> usize {
        self.prefill_seqs.iter().copied().max().unwrap_or(64)
    }

    /// Synthesize the manifest entries (artifacts + model info +
    /// settings) this model serves.
    fn manifest_entries(
        &self,
        artifacts: &mut BTreeMap<String, ArtifactMeta>,
        models: &mut BTreeMap<String, ModelInfo>,
        settings: &mut BTreeMap<String, Vec<String>>,
    ) {
        let prefill_meta = |name: &str,
                           variant: &str,
                           seq: usize,
                           nm: Option<(usize, usize)>| {
            ArtifactMeta {
                name: name.to_string(),
                hlo: String::new(),
                params: Vec::new(),
                runtime_inputs: vec![(
                    vec![self.prefill_batch, seq],
                    "int32".to_string(),
                )],
                outputs: vec!["logits".into(), "k".into(), "v".into()],
                kind: "prefill".to_string(),
                variant: variant.to_string(),
                batch: self.prefill_batch,
                seq,
                cache: 0,
                nm,
            }
        };
        for &seq in &self.prefill_seqs {
            for (variant, nm) in prefill_variants() {
                let name = match nm {
                    Some((n, m)) => format!(
                        "{}.prefill{seq}.{variant}{n}_{m}",
                        self.name
                    ),
                    None => format!("{}.prefill{seq}.{variant}", self.name),
                };
                artifacts
                    .insert(name.clone(), prefill_meta(&name, variant, seq, nm));
            }
        }
        let cache_shape = vec![
            self.n_layers,
            self.decode_batch,
            self.cache_len,
            self.n_kv_heads,
            self.head_dim,
        ];
        for variant in ["dense", "sq"] {
            let name = format!("{}.decode.{variant}", self.name);
            artifacts.insert(
                name.clone(),
                ArtifactMeta {
                    name: name.clone(),
                    hlo: String::new(),
                    params: Vec::new(),
                    runtime_inputs: vec![
                        (vec![self.decode_batch], "int32".to_string()),
                        (vec![self.decode_batch], "int32".to_string()),
                        (cache_shape.clone(), "float32".to_string()),
                        (cache_shape.clone(), "float32".to_string()),
                        (vec![self.decode_batch], "int32".to_string()),
                    ],
                    outputs: vec!["logits".into(), "k".into(), "v".into()],
                    kind: "decode".to_string(),
                    variant: variant.to_string(),
                    batch: self.decode_batch,
                    seq: 0,
                    cache: self.cache_len,
                    nm: None,
                },
            );
        }
        let mut config = BTreeMap::new();
        config.insert("vocab_size".to_string(), self.vocab);
        config.insert("d_model".to_string(), self.d_model);
        config.insert("n_layers".to_string(), self.n_layers);
        config.insert("n_q_heads".to_string(), self.n_q_heads);
        config.insert("n_kv_heads".to_string(), self.n_kv_heads);
        config.insert("head_dim".to_string(), self.head_dim);
        config.insert("d_ff".to_string(), self.d_ff);
        models.insert(
            self.name.clone(),
            ModelInfo {
                name: self.name.clone(),
                weights: format!("weights/{}.atw", self.name),
                is_moe: false,
                config,
            },
        );
        settings.insert(
            self.name.clone(),
            vec!["naive".into(), "ls".into(), "all".into()],
        );
    }
}

fn prefill_variants() -> Vec<(&'static str, Option<(usize, usize)>)> {
    let mut v: Vec<(&'static str, Option<(usize, usize)>)> =
        vec![("dense", None), ("sq", None)];
    for &(n, m) in &RATIOS {
        v.push(("nm", Some((n, m))));
        v.push(("sq_nm", Some((n, m))));
    }
    v
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

fn stats_skip_layers(dir: &Path, model: &str) -> Option<Vec<usize>> {
    let p = dir.join("stats").join(format!("sensitivity_{model}.json"));
    let text = std::fs::read_to_string(p).ok()?;
    let j = Json::parse(&text).ok()?;
    let arr = j.get("skip_layers")?.as_arr()?;
    Some(arr.iter().filter_map(|v| v.as_usize()).collect())
}

/// One transformer layer's weights; projections are `[din, dout]`
/// row-major (the `spmm` convention). `scale_*` are the per-input-channel
/// weight norms the `all` setting uses as Robust-Norm-style scores.
struct LayerWeights {
    attn_norm: Vec<f32>,
    wq: Vec<f32>,
    wk: Vec<f32>,
    wv: Vec<f32>,
    wo: Vec<f32>,
    mlp_norm: Vec<f32>,
    w_gate: Vec<f32>,
    w_up: Vec<f32>,
    w_down: Vec<f32>,
    scale_q: Vec<f32>,
    scale_gate: Vec<f32>,
    scale_down: Vec<f32>,
}

/// A native model: spec + deterministically synthesized weights.
pub struct NativeModel {
    pub spec: ModelSpec,
    embed: Vec<f32>,
    layers: Vec<LayerWeights>,
    final_norm: Vec<f32>,
    lm_head: Vec<f32>,
}

fn rand_mat(rng: &mut Rng, din: usize, dout: usize) -> Vec<f32> {
    let scale = 1.0 / (din.max(1) as f64).sqrt();
    (0..din * dout)
        .map(|_| (rng.normal() * scale) as f32)
        .collect()
}

/// Per-input-channel L2 norm of a `[din, dout]` weight matrix.
fn row_norms(w: &[f32], din: usize, dout: usize) -> Vec<f32> {
    (0..din)
        .map(|j| {
            w[j * dout..(j + 1) * dout]
                .iter()
                .map(|v| v * v)
                .sum::<f32>()
                .sqrt()
        })
        .collect()
}

fn rmsnorm(x: &[f32], t: usize, d: usize, w: &[f32]) -> Vec<f32> {
    let mut out = vec![0.0f32; t * d];
    for r in 0..t {
        let row = &x[r * d..(r + 1) * d];
        let ms = row.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let inv = 1.0 / (ms + 1e-5).sqrt();
        for j in 0..d {
            out[r * d + j] = row[j] * inv * w[j];
        }
    }
    out
}

fn silu(v: f32) -> f32 {
    v / (1.0 + (-v).exp())
}

/// Pruning directive for one projection: ratio + optional channel scores.
type PruneCfg<'a> = Option<(usize, usize, Option<&'a [f32]>)>;

/// Resolve the paper's policy for one module in one layer.
fn prune_cfg<'a>(
    nm: Option<(usize, usize)>,
    setting: Setting,
    module: &str,
    layer: usize,
    skip_layers: &[usize],
    scale: Option<&'a [f32]>,
) -> PruneCfg<'a> {
    let (n, m) = nm?;
    let pruned = match setting {
        Setting::Dense => false,
        Setting::Naive => policy::pruned_in_layer(module, layer, &[]),
        Setting::LayerSkip | Setting::All => {
            policy::pruned_in_layer(module, layer, skip_layers)
        }
    };
    if !pruned {
        return None;
    }
    let scale = if setting == Setting::All { scale } else { None };
    Some((n, m, scale))
}

/// One projection: dense, N:M-compressed, and/or W8A8 per the directive.
/// Pruned activations are validated against the exact-N:M contract and
/// accounted in `audit`.
#[allow(clippy::too_many_arguments)]
fn proj(
    x: &[f32],
    t: usize,
    din: usize,
    w: &[f32],
    dout: usize,
    prune: PruneCfg<'_>,
    quantized: bool,
    audit: &mut SparsityAudit,
    validate: bool,
) -> Vec<f32> {
    match prune {
        Some((n, m, scale)) if din % m == 0 => {
            let scale = scale.unwrap_or(&[]);
            let c = NmCompressed::compress(x, t, din, scale, n, m);
            audit.pruned_matmuls += 1;
            let st = c.stats(dout);
            audit.dense_flops += st.dense_flops;
            audit.sparse_flops += st.sparse_flops;
            // decompress at most once, shared by validation and the
            // int8 reference path
            let pruned_dense = if validate || quantized {
                Some(c.decompress())
            } else {
                None
            };
            if let Some(pd) = &pruned_dense {
                if validate {
                    audit.nm_checks += 1;
                    for row in pd.chunks_exact(din) {
                        if !validate_nm(row, n, m) {
                            audit.nm_violations += 1;
                        }
                    }
                }
            }
            if quantized {
                // NOTE: the int8 reference executes dense-shaped work
                // over the pruned input; the audit still records n/m
                // sparse FLOPs — the SpMM-hardware cost model (see
                // SparsityAudit docs)
                w8a8_dense(pruned_dense.as_deref().unwrap(), t, din, w, dout)
            } else {
                c.matmul(w, dout)
            }
        }
        other => {
            if other.is_some() {
                // pruning was requested but din is not a multiple of m:
                // execute dense and record the fallback loudly
                audit.pruned_fallbacks += 1;
            }
            audit.dense_matmuls += 1;
            let fl = 2 * (t * din * dout) as u64;
            audit.dense_flops += fl;
            audit.sparse_flops += fl;
            if quantized {
                w8a8_dense(x, t, din, w, dout)
            } else {
                dense_matmul(x, t, din, w, dout)
            }
        }
    }
}

/// W8A8 reference path: per-tensor activation scale, per-channel weight
/// scales. Weights are quantized per call — at native-model sizes this is
/// noise next to the matmul itself.
fn w8a8_dense(
    x: &[f32],
    t: usize,
    din: usize,
    w: &[f32],
    dout: usize,
) -> Vec<f32> {
    let (wq, ws) = quant::quantize_weight(w, din, dout);
    let absmax = x.iter().fold(0.0f32, |a, &b| a.max(b.abs()));
    let xs = (absmax / 127.0).max(1e-8);
    let xq = quant::quantize(x, xs);
    quant::w8a8_matmul(&xq, t, din, &wq, dout, xs, &ws)
}

impl NativeModel {
    pub fn build(spec: ModelSpec) -> NativeModel {
        let mut rng = Rng::new(spec.seed);
        let (d, qd, kvd, f) =
            (spec.d_model, spec.q_dim(), spec.kv_dim(), spec.d_ff);
        let layers = (0..spec.n_layers)
            .map(|_| {
                let wq = rand_mat(&mut rng, d, qd);
                let w_gate = rand_mat(&mut rng, d, f);
                let w_down = rand_mat(&mut rng, f, d);
                LayerWeights {
                    attn_norm: vec![1.0; d],
                    wk: rand_mat(&mut rng, d, kvd),
                    wv: rand_mat(&mut rng, d, kvd),
                    wo: rand_mat(&mut rng, qd, d),
                    mlp_norm: vec![1.0; d],
                    w_up: rand_mat(&mut rng, d, f),
                    scale_q: row_norms(&wq, d, qd),
                    scale_gate: row_norms(&w_gate, d, f),
                    scale_down: row_norms(&w_down, f, d),
                    wq,
                    w_gate,
                    w_down,
                }
            })
            .collect();
        NativeModel {
            embed: rand_mat(&mut rng, spec.vocab, spec.d_model),
            final_norm: vec![1.0; spec.d_model],
            lm_head: rand_mat(&mut rng, spec.d_model, spec.vocab),
            layers,
            spec,
        }
    }

    fn embed_tokens(&self, tokens: &[i32]) -> Vec<f32> {
        let d = self.spec.d_model;
        let mut x = vec![0.0f32; tokens.len() * d];
        for (i, &tok) in tokens.iter().enumerate() {
            let id = (tok.max(0) as usize).min(self.spec.vocab - 1);
            x[i * d..(i + 1) * d]
                .copy_from_slice(&self.embed[id * d..(id + 1) * d]);
        }
        x
    }

    fn logits(
        &self,
        x: &[f32],
        t: usize,
        audit: &mut SparsityAudit,
    ) -> Vec<f32> {
        let h = rmsnorm(x, t, self.spec.d_model, &self.final_norm);
        proj(
            &h,
            t,
            self.spec.d_model,
            &self.lm_head,
            self.spec.vocab,
            None,
            false,
            audit,
            false,
        )
    }

    /// Full prefill over `[b, s]` tokens with causal attention; N:M
    /// pruning per (`nm`, `setting`) on exactly the policy's modules.
    #[allow(clippy::too_many_arguments)]
    fn prefill(
        &self,
        tokens: &[i32],
        b: usize,
        s: usize,
        nm: Option<(usize, usize)>,
        setting: Setting,
        quantized: bool,
        audit: &mut SparsityAudit,
        validate: bool,
    ) -> PrefillOut {
        let sp = &self.spec;
        let (d, qd, kvd, f) = (sp.d_model, sp.q_dim(), sp.kv_dim(), sp.d_ff);
        let t = b * s;
        let t0 = Instant::now();
        let mut x = self.embed_tokens(tokens);
        let mut k_cache = vec![0.0f32; sp.n_layers * t * kvd];
        let mut v_cache = vec![0.0f32; sp.n_layers * t * kvd];
        for (l, lw) in self.layers.iter().enumerate() {
            let h = rmsnorm(&x, t, d, &lw.attn_norm);
            let q_cfg = prune_cfg(
                nm,
                setting,
                "q_proj",
                l,
                &sp.skip_layers,
                Some(&lw.scale_q),
            );
            let q =
                proj(&h, t, d, &lw.wq, qd, q_cfg, quantized, audit, validate);
            let k =
                proj(&h, t, d, &lw.wk, kvd, None, quantized, audit, validate);
            let v =
                proj(&h, t, d, &lw.wv, kvd, None, quantized, audit, validate);
            // stash this layer's K/V in [L, B, S, H_kv, D_h]
            let base = l * t * kvd;
            k_cache[base..base + t * kvd].copy_from_slice(&k);
            v_cache[base..base + t * kvd].copy_from_slice(&v);
            let attn = causal_attention(&q, &k, &v, b, s, sp);
            let o = proj(
                &attn, t, qd, &lw.wo, d, None, quantized, audit, validate,
            );
            for (xi, oi) in x.iter_mut().zip(o.iter()) {
                *xi += oi;
            }
            let h2 = rmsnorm(&x, t, d, &lw.mlp_norm);
            let gate_cfg = prune_cfg(
                nm,
                setting,
                "gate_proj",
                l,
                &sp.skip_layers,
                Some(&lw.scale_gate),
            );
            let gate = proj(
                &h2, t, d, &lw.w_gate, f, gate_cfg, quantized, audit,
                validate,
            );
            let up = proj(
                &h2, t, d, &lw.w_up, f, None, quantized, audit, validate,
            );
            let act: Vec<f32> = gate
                .iter()
                .zip(up.iter())
                .map(|(&g, &u)| silu(g) * u)
                .collect();
            let down_cfg = prune_cfg(
                nm,
                setting,
                "down_proj",
                l,
                &sp.skip_layers,
                Some(&lw.scale_down),
            );
            let down = proj(
                &act, t, f, &lw.w_down, d, down_cfg, quantized, audit,
                validate,
            );
            for (xi, di) in x.iter_mut().zip(down.iter()) {
                *xi += di;
            }
        }
        let logits = self.logits(&x, t, audit);
        PrefillOut {
            logits,
            batch: b,
            seq: s,
            vocab: sp.vocab,
            k_cache,
            v_cache,
            exec_secs: t0.elapsed().as_secs_f64(),
        }
    }

    /// One dense decode step over the slot cache (the paper confines
    /// sparsity to prefill; decode is always dense / W8A8).
    #[allow(clippy::too_many_arguments)]
    fn decode(
        &self,
        token: &[i32],
        pos: &[i32],
        k_cache: &mut [f32],
        v_cache: &mut [f32],
        kv_len: &[i32],
        cache: usize,
        quantized: bool,
        audit: &mut SparsityAudit,
    ) -> (Vec<f32>, f64) {
        let sp = &self.spec;
        let b = token.len();
        let (d, qd, kvd, f) = (sp.d_model, sp.q_dim(), sp.kv_dim(), sp.d_ff);
        let dh = sp.head_dim;
        let group = sp.n_q_heads / sp.n_kv_heads;
        let t0 = Instant::now();
        let mut x = self.embed_tokens(token);
        for (l, lw) in self.layers.iter().enumerate() {
            let h = rmsnorm(&x, b, d, &lw.attn_norm);
            let q = proj(&h, b, d, &lw.wq, qd, None, quantized, audit, false);
            let k = proj(&h, b, d, &lw.wk, kvd, None, quantized, audit, false);
            let v = proj(&h, b, d, &lw.wv, kvd, None, quantized, audit, false);
            let mut attn = vec![0.0f32; b * qd];
            for bi in 0..b {
                let p = (pos[bi].max(0) as usize).min(cache - 1);
                let span = (kv_len[bi].max(1) as usize).min(cache);
                // write this step's K/V at the row's position (assign,
                // not accumulate — stale slot data is harmless)
                let slot = ((l * b + bi) * cache + p) * kvd;
                k_cache[slot..slot + kvd]
                    .copy_from_slice(&k[bi * kvd..(bi + 1) * kvd]);
                v_cache[slot..slot + kvd]
                    .copy_from_slice(&v[bi * kvd..(bi + 1) * kvd]);
                for hq in 0..sp.n_q_heads {
                    let kvh = hq / group;
                    let qrow = &q[bi * qd + hq * dh..bi * qd + (hq + 1) * dh];
                    let mut scores = vec![0.0f32; span];
                    for (j, sc) in scores.iter_mut().enumerate() {
                        let kr = ((l * b + bi) * cache + j) * kvd + kvh * dh;
                        let krow = &k_cache[kr..kr + dh];
                        let dot: f32 = qrow
                            .iter()
                            .zip(krow.iter())
                            .map(|(a, c)| a * c)
                            .sum();
                        *sc = dot / (dh as f32).sqrt();
                    }
                    softmax_inplace(&mut scores);
                    let orow = &mut attn
                        [bi * qd + hq * dh..bi * qd + (hq + 1) * dh];
                    for (j, &wgt) in scores.iter().enumerate() {
                        let vr = ((l * b + bi) * cache + j) * kvd + kvh * dh;
                        for (oe, &ve) in
                            orow.iter_mut().zip(v_cache[vr..vr + dh].iter())
                        {
                            *oe += wgt * ve;
                        }
                    }
                }
            }
            let o =
                proj(&attn, b, qd, &lw.wo, d, None, quantized, audit, false);
            for (xi, oi) in x.iter_mut().zip(o.iter()) {
                *xi += oi;
            }
            let h2 = rmsnorm(&x, b, d, &lw.mlp_norm);
            let gate = proj(
                &h2, b, d, &lw.w_gate, f, None, quantized, audit, false,
            );
            let up =
                proj(&h2, b, d, &lw.w_up, f, None, quantized, audit, false);
            let act: Vec<f32> = gate
                .iter()
                .zip(up.iter())
                .map(|(&g, &u)| silu(g) * u)
                .collect();
            let down = proj(
                &act, b, f, &lw.w_down, d, None, quantized, audit, false,
            );
            for (xi, di) in x.iter_mut().zip(down.iter()) {
                *xi += di;
            }
        }
        let logits = self.logits(&x, b, audit);
        (logits, t0.elapsed().as_secs_f64())
    }
}

fn softmax_inplace(scores: &mut [f32]) {
    let mx = scores.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let mut denom = 0.0f32;
    for s in scores.iter_mut() {
        *s = (*s - mx).exp();
        denom += *s;
    }
    let inv = 1.0 / denom.max(1e-30);
    for s in scores.iter_mut() {
        *s *= inv;
    }
}

/// Causal GQA attention over a packed `[b, s]` prefill batch.
fn causal_attention(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    b: usize,
    s: usize,
    sp: &ModelSpec,
) -> Vec<f32> {
    let (qd, kvd, dh) = (sp.q_dim(), sp.kv_dim(), sp.head_dim);
    let group = sp.n_q_heads / sp.n_kv_heads;
    let inv_sqrt = 1.0 / (dh as f32).sqrt();
    let mut out = vec![0.0f32; b * s * qd];
    let mut scores = vec![0.0f32; s];
    for bi in 0..b {
        for p in 0..s {
            let qbase = (bi * s + p) * qd;
            for hq in 0..sp.n_q_heads {
                let kvh = hq / group;
                let qrow = &q[qbase + hq * dh..qbase + (hq + 1) * dh];
                for (j, sc) in scores.iter_mut().take(p + 1).enumerate() {
                    let kr = (bi * s + j) * kvd + kvh * dh;
                    let krow = &k[kr..kr + dh];
                    let dot: f32 = qrow
                        .iter()
                        .zip(krow.iter())
                        .map(|(a, c)| a * c)
                        .sum();
                    *sc = dot * inv_sqrt;
                }
                softmax_inplace(&mut scores[..p + 1]);
                let orow =
                    &mut out[qbase + hq * dh..qbase + (hq + 1) * dh];
                for (j, &wgt) in scores[..p + 1].iter().enumerate() {
                    let vr = (bi * s + j) * kvd + kvh * dh;
                    for (oe, &ve) in orow.iter_mut().zip(v[vr..vr + dh].iter())
                    {
                        *oe += wgt * ve;
                    }
                }
            }
        }
    }
    out
}

/// The native CPU execution engine (see module docs).
pub struct NativeEngine {
    manifest: Manifest,
    models: BTreeMap<String, NativeModel>,
    /// "artifact::binding-key" -> resolved setting
    bindings: HashMap<String, Setting>,
    audit: SparsityAudit,
    /// run `validate_nm` on every pruned activation (cheap; on by default)
    pub validate: bool,
}

impl NativeEngine {
    /// Engine over an artifacts directory: adopts `manifest.json` when
    /// present, otherwise serves the self-contained synthetic inventory.
    pub fn from_dir(dir: &Path) -> Result<NativeEngine> {
        if dir.join("manifest.json").exists() {
            let manifest = Manifest::load(dir)?;
            let models = manifest
                .models
                .values()
                .map(|info| {
                    let spec = ModelSpec::from_manifest(info, &manifest, dir);
                    (info.name.clone(), NativeModel::build(spec))
                })
                .collect();
            Ok(NativeEngine {
                manifest,
                models,
                bindings: HashMap::new(),
                audit: SparsityAudit::default(),
                validate: true,
            })
        } else {
            Ok(NativeEngine::synthetic(vec![ModelSpec::tiny("tiny-lm-a")]))
        }
    }

    /// Fully self-contained engine from explicit model specs.
    pub fn synthetic(specs: Vec<ModelSpec>) -> NativeEngine {
        let specs: Vec<ModelSpec> =
            specs.into_iter().map(ModelSpec::sanitize).collect();
        let mut artifacts = BTreeMap::new();
        let mut models_info = BTreeMap::new();
        let mut settings = BTreeMap::new();
        for spec in &specs {
            spec.manifest_entries(
                &mut artifacts,
                &mut models_info,
                &mut settings,
            );
        }
        let manifest = Manifest {
            dir: std::path::PathBuf::new(),
            artifacts,
            models: models_info,
            settings,
            raw: Json::Obj(BTreeMap::new()),
        };
        let models = specs
            .into_iter()
            .map(|spec| (spec.name.clone(), NativeModel::build(spec)))
            .collect();
        NativeEngine {
            manifest,
            models,
            bindings: HashMap::new(),
            audit: SparsityAudit::default(),
            validate: true,
        }
    }

    /// The default synthetic single-model engine.
    pub fn tiny() -> NativeEngine {
        NativeEngine::synthetic(vec![ModelSpec::tiny("tiny-lm-a")])
    }

    pub fn reset_audit(&mut self) {
        self.audit = SparsityAudit::default();
    }

    pub fn model(&self, name: &str) -> Option<&NativeModel> {
        self.models.get(name)
    }

    fn model_for_artifact(&self, artifact: &str) -> Result<&NativeModel> {
        let model_name = artifact.split('.').next().unwrap_or(artifact);
        self.models.get(model_name).ok_or_else(|| {
            anyhow!("artifact {artifact}: model '{model_name}' not loaded")
        })
    }

    fn binding_setting(
        &self,
        artifact: &str,
        binding: &str,
    ) -> Result<Setting> {
        self.bindings
            .get(&binding_key(artifact, binding))
            .copied()
            .ok_or_else(|| {
                anyhow!("artifact {artifact}: binding '{binding}' missing")
            })
    }
}

fn binding_key(artifact: &str, binding: &str) -> String {
    format!("{artifact}::{binding}")
}

/// Resolve the setting encoded in a bound file list: the aux file name
/// carries it (`<model>[.sq].aux_<tag>.atw`). N:M artifacts bound with no
/// aux default to naive magnitude scoring; dense artifacts to dense.
fn setting_from_files(files: &[&str], is_nm: bool) -> Result<Setting> {
    for f in files {
        let Some(idx) = f.find(".aux_") else { continue };
        let tag = f[idx + ".aux_".len()..].trim_end_matches(".atw");
        return match tag {
            "dense" => Ok(Setting::Dense),
            "naive" => Ok(Setting::Naive),
            "ls" => Ok(Setting::LayerSkip),
            "all" => Ok(Setting::All),
            other => Err(anyhow!("unknown aux setting '{other}' in {f}")),
        };
    }
    Ok(if is_nm { Setting::Naive } else { Setting::Dense })
}

impl Engine for NativeEngine {
    fn platform(&self) -> String {
        "native-cpu".to_string()
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn load_artifact(&mut self, name: &str) -> Result<f64> {
        self.manifest.artifact(name)?;
        self.model_for_artifact(name)?;
        Ok(0.0)
    }

    fn bind(&mut self, artifact: &str, files: &[&str]) -> Result<String> {
        let meta = self.manifest.artifact(artifact)?;
        let is_nm = meta.nm.is_some();
        self.model_for_artifact(artifact)?;
        let setting = setting_from_files(files, is_nm)?;
        let key = files.join("+");
        self.bindings
            .insert(binding_key(artifact, &key), setting);
        Ok(key)
    }

    fn prefill(
        &mut self,
        artifact: &str,
        binding: &str,
        tokens: &[i32],
    ) -> Result<PrefillOut> {
        let meta = self.manifest.artifact(artifact)?.clone();
        if meta.kind != "prefill" {
            bail!("artifact {artifact} is not a prefill artifact");
        }
        let (b, s) = (meta.batch, meta.seq);
        if tokens.len() != b * s {
            bail!(
                "prefill {artifact}: tokens len {} != {b}x{s}",
                tokens.len()
            );
        }
        let setting = self.binding_setting(artifact, binding)?;
        let quantized = meta.variant.starts_with("sq");
        let validate = self.validate;
        let mut audit = self.audit;
        let model = self.model_for_artifact(artifact)?;
        let out = model.prefill(
            tokens, b, s, meta.nm, setting, quantized, &mut audit, validate,
        );
        self.audit = audit;
        Ok(out)
    }

    fn decode(
        &mut self,
        artifact: &str,
        binding: &str,
        token: &[i32],
        pos: &[i32],
        k_cache: &[f32],
        v_cache: &[f32],
        kv_len: &[i32],
    ) -> Result<DecodeOut> {
        let meta = self.manifest.artifact(artifact)?.clone();
        if meta.kind != "decode" {
            bail!("artifact {artifact} is not a decode artifact");
        }
        self.binding_setting(artifact, binding)?;
        let b = meta.batch;
        let cache = meta.cache;
        if b == 0 || cache == 0 {
            bail!("decode {artifact}: degenerate batch {b} / cache {cache}");
        }
        if token.len() != b || pos.len() != b || kv_len.len() != b {
            bail!("decode {artifact}: batch inputs must have len {b}");
        }
        let quantized = meta.variant.starts_with("sq");
        let model = self.model_for_artifact(artifact)?;
        let expect =
            model.spec.n_layers * b * cache * model.spec.kv_dim();
        if k_cache.len() != expect || v_cache.len() != expect {
            bail!(
                "decode {artifact}: cache len {} != expected {expect}",
                k_cache.len()
            );
        }
        let vocab = model.spec.vocab;
        let mut kc = k_cache.to_vec();
        let mut vc = v_cache.to_vec();
        let mut audit = self.audit;
        let (logits, secs) = model.decode(
            token, pos, &mut kc, &mut vc, kv_len, cache, quantized,
            &mut audit,
        );
        self.audit = audit;
        Ok(DecodeOut {
            logits,
            batch: b,
            vocab,
            k_cache: kc,
            v_cache: vc,
            exec_secs: secs,
        })
    }

    fn audit(&self) -> Option<SparsityAudit> {
        Some(self.audit)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_spec() -> ModelSpec {
        ModelSpec {
            prefill_batch: 2,
            prefill_seqs: vec![16],
            decode_batch: 2,
            cache_len: 24,
            ..ModelSpec::tiny("tiny-lm-a")
        }
    }

    fn tokens_for(b: usize, s: usize) -> Vec<i32> {
        (0..b * s).map(|i| 1 + (i as i32 % 300)).collect()
    }

    #[test]
    fn prefill_shapes_and_finite() {
        let mut e = NativeEngine::synthetic(vec![small_spec()]);
        let art = "tiny-lm-a.prefill16.dense";
        let bind = e.bind(art, &["tiny-lm-a.atw"]).unwrap();
        let out = e.prefill(art, &bind, &tokens_for(2, 16)).unwrap();
        assert_eq!(out.vocab, 384);
        assert_eq!(out.logits.len(), 2 * 16 * 384);
        assert_eq!(out.k_cache.len(), 2 * 2 * 16 * 16); // L*B*S*kvd
        assert!(out.logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn nm_artifact_with_dense_aux_matches_dense_artifact() {
        // keep_dense everywhere must reproduce the dense path exactly —
        // the contract that lets one nm artifact serve dense requests.
        let mut e = NativeEngine::synthetic(vec![small_spec()]);
        let toks = tokens_for(2, 16);
        let b_dense = e
            .bind("tiny-lm-a.prefill16.dense", &["tiny-lm-a.atw"])
            .unwrap();
        let b_nm = e
            .bind(
                "tiny-lm-a.prefill16.nm2_4",
                &["tiny-lm-a.atw", "tiny-lm-a.aux_dense.atw"],
            )
            .unwrap();
        let a = e
            .prefill("tiny-lm-a.prefill16.dense", &b_dense, &toks)
            .unwrap();
        let c = e
            .prefill("tiny-lm-a.prefill16.nm2_4", &b_nm, &toks)
            .unwrap();
        for (x, y) in a.logits.iter().zip(c.logits.iter()) {
            assert_eq!(x, y);
        }
    }

    #[test]
    fn sparse_prefill_audits_and_differs_from_dense() {
        let mut e = NativeEngine::synthetic(vec![small_spec()]);
        let toks = tokens_for(2, 16);
        let b_dense = e
            .bind("tiny-lm-a.prefill16.dense", &["tiny-lm-a.atw"])
            .unwrap();
        let dense = e
            .prefill("tiny-lm-a.prefill16.dense", &b_dense, &toks)
            .unwrap();
        e.reset_audit();
        let b_nm = e
            .bind(
                "tiny-lm-a.prefill16.nm2_4",
                &["tiny-lm-a.atw", "tiny-lm-a.aux_ls.atw"],
            )
            .unwrap();
        let sparse = e
            .prefill("tiny-lm-a.prefill16.nm2_4", &b_nm, &toks)
            .unwrap();
        let audit = Engine::audit(&e).unwrap();
        assert!(audit.pruned_matmuls > 0, "no pruned projections ran");
        assert_eq!(audit.nm_violations, 0, "N:M contract violated");
        assert_eq!(audit.pruned_fallbacks, 0, "unexpected dense fallback");
        // 2:4 over layer-0 q/gate/down saves ~8% of this model's total
        // linear FLOPs (layer 1 is skipped by the ls policy)
        assert!(audit.flops_saved_frac() > 0.05);
        let diff = dense
            .logits
            .iter()
            .zip(sparse.logits.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(diff > 0.0, "2:4 pruning changed nothing");
        assert!(sparse.logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn decode_continues_from_prefill_cache() {
        let mut e = NativeEngine::synthetic(vec![small_spec()]);
        let art = "tiny-lm-a.prefill16.dense";
        let bind = e.bind(art, &["tiny-lm-a.atw"]).unwrap();
        let toks = tokens_for(2, 16);
        let out = e.prefill(art, &bind, &toks).unwrap();
        // scatter prefill row 0 into a fresh decode cache
        let spec = e.model("tiny-lm-a").unwrap().spec.clone();
        let (l, b, c, kvd) =
            (spec.n_layers, spec.decode_batch, spec.cache_len, spec.kv_dim());
        let plen = 5usize;
        let mut kc = vec![0.0f32; l * b * c * kvd];
        let mut vc = vec![0.0f32; l * b * c * kvd];
        for li in 0..l {
            let src = (li * 2 * 16) * kvd; // prefill [L, 2, 16, kvd]
            let dst = (li * b * c) * kvd;
            kc[dst..dst + plen * kvd]
                .copy_from_slice(&out.k_cache[src..src + plen * kvd]);
            vc[dst..dst + plen * kvd]
                .copy_from_slice(&out.v_cache[src..src + plen * kvd]);
        }
        let dec = "tiny-lm-a.decode.dense";
        let dbind = e.bind(dec, &["tiny-lm-a.atw"]).unwrap();
        let mut token = vec![0i32; b];
        token[0] = 7;
        let mut pos = vec![0i32; b];
        pos[0] = plen as i32;
        let mut kv_len = vec![1i32; b];
        kv_len[0] = (plen + 1) as i32;
        let d = e
            .decode(dec, &dbind, &token, &pos, &kc, &vc, &kv_len)
            .unwrap();
        assert_eq!(d.logits.len(), b * 384);
        assert!(d.logits.iter().all(|v| v.is_finite()));
        // the new K/V landed at position plen of slot 0
        let slot = plen * kvd;
        assert!(d.k_cache[slot..slot + kvd].iter().any(|&v| v != 0.0));
    }

    #[test]
    fn quantized_path_close_to_f32() {
        let mut e = NativeEngine::synthetic(vec![small_spec()]);
        let toks = tokens_for(2, 16);
        let bf = e
            .bind("tiny-lm-a.prefill16.dense", &["tiny-lm-a.atw"])
            .unwrap();
        let fp = e
            .prefill("tiny-lm-a.prefill16.dense", &bf, &toks)
            .unwrap();
        let bq = e
            .bind("tiny-lm-a.prefill16.sq", &["tiny-lm-a.sq.atw"])
            .unwrap();
        let q = e.prefill("tiny-lm-a.prefill16.sq", &bq, &toks).unwrap();
        let max_abs =
            fp.logits.iter().fold(0.0f32, |a, &b| a.max(b.abs()));
        let diff = fp
            .logits
            .iter()
            .zip(q.logits.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(
            diff < max_abs.max(1.0) * 0.5,
            "w8a8 drifted too far: {diff} vs absmax {max_abs}"
        );
    }

    #[test]
    fn unknown_binding_is_rejected() {
        let mut e = NativeEngine::tiny();
        let err = e
            .prefill("tiny-lm-a.prefill64.dense", "nope", &[0; 8 * 64])
            .unwrap_err();
        assert!(err.to_string().contains("binding"));
    }
}
