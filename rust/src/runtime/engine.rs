//! The execution-engine abstraction the coordinator schedules against.
//!
//! `Engine` is the backend-neutral contract: load/bind artifacts by name,
//! run a prefill batch, advance a decode batch. Two implementations ship:
//!
//! * [`crate::runtime::NativeEngine`] — pure-Rust CPU execution built on
//!   `tensor::math`, `sparsity::spmm::NmCompressed` and `quant`; the
//!   default backend, no external dependencies, runs the paper's
//!   N:M-sparse prefill semantics directly (and audits them).
//! * `crate::runtime::ModelRuntime` — the PJRT/XLA path over AOT HLO
//!   artifacts, behind the `pjrt` cargo feature.
//!
//! KV caches cross the trait boundary as host floats: prefill returns
//! `[L, B, S, H_kv, D_h]` (or the token-packed `[L, total, H_kv, D_h]`)
//! caches the coordinator stages into its block-paged store, and decode
//! reads/writes that store either through a [`PagedKv`] block-table
//! view ([`Engine::decode_paged`]) or, for backends with static
//! compiled shapes, through the contiguous `[L, B, C, H_kv, D_h]`
//! gather the default `decode_paged` implementation materializes.

use std::path::Path;

use anyhow::{anyhow, bail, Result};

use super::artifact::Manifest;

/// Output of one prefill execution.
pub struct PrefillOut {
    /// `[batch, seq, vocab]`, row-major
    pub logits: Vec<f32>,
    /// static batch of the executed artifact
    pub batch: usize,
    /// static sequence length of the executed artifact
    pub seq: usize,
    /// vocabulary size (logits row width)
    pub vocab: usize,
    /// `[L, B, S, H_kv, D_h]`
    pub k_cache: Vec<f32>,
    /// same layout as `k_cache`
    pub v_cache: Vec<f32>,
    /// backend execution seconds (excludes host staging)
    pub exec_secs: f64,
}

/// Output of one token-packed prefill execution: `prompts.len()`
/// requests laid out back-to-back with **no padding rows** — request `i`
/// owns rows `row_start(i) .. row_start(i) + lens[i]`.
pub struct PackedPrefillOut {
    /// `[total_tokens, vocab]`, row-major
    pub logits: Vec<f32>,
    /// per-request token counts after clamping to the artifact's seq
    pub lens: Vec<usize>,
    /// vocabulary size (logits row width)
    pub vocab: usize,
    /// `[L, total_tokens, H_kv, D_h]`
    pub k_cache: Vec<f32>,
    /// same layout as `k_cache`
    pub v_cache: Vec<f32>,
    /// PAD-row tokens the backend actually computed to serve this batch:
    /// 0 on a shape-flexible pipeline (native), the full right-padding
    /// cost on the pad-and-gather default path — keeps the coordinator's
    /// padding metric honest across backends
    pub padded_tokens: usize,
    /// backend execution seconds (excludes host staging)
    pub exec_secs: f64,
}

impl PackedPrefillOut {
    /// Valid (non-PAD) token rows in the packed batch.
    pub fn total_tokens(&self) -> usize {
        self.lens.iter().sum()
    }

    /// First token row of request `i` in the packed layout.
    pub fn row_start(&self, i: usize) -> usize {
        self.lens[..i].iter().sum()
    }
}

/// One request of a prefix-aware packed prefill
/// ([`Engine::prefill_packed_prefixed`]): the **full** prompt plus the
/// K/V of its already-cached leading tokens, so the backend only has to
/// compute (or, on the default path, only has to *return*) the suffix.
pub struct PrefixedPrompt {
    /// Full prompt tokens — cached prefix followed by the fresh suffix.
    pub tokens: Vec<i32>,
    /// Leading tokens whose K/V is already staged in the paged store;
    /// `0 <= cached_len < tokens.len()` after artifact-seq clamping.
    pub cached_len: usize,
    /// Cached-prefix keys, `[L, cached_len, H_kv * D_h]` (empty when
    /// `cached_len == 0`).
    pub prefix_k: Vec<f32>,
    /// Cached-prefix values, same layout as `prefix_k`.
    pub prefix_v: Vec<f32>,
}

/// Output of one decode step over caller-owned contiguous caches.
pub struct DecodeOut {
    /// `[batch, vocab]`
    pub logits: Vec<f32>,
    /// static decode batch of the executed artifact
    pub batch: usize,
    /// vocabulary size (logits row width)
    pub vocab: usize,
    /// `[L, B, C, H_kv, D_h]` — the caller's cache with this step's K/V
    /// written at each row's position
    pub k_cache: Vec<f32>,
    /// same layout as `k_cache`
    pub v_cache: Vec<f32>,
    /// backend execution seconds (excludes host staging)
    pub exec_secs: f64,
}

/// Borrowed view of a block-paged KV cache, the unit the coordinator
/// hands to [`Engine::decode_paged`].
///
/// Physical storage is `[L, n_blocks, block_size, H_kv * D_h]`: every
/// layer sees the same global pool of `n_blocks` blocks of `block_size`
/// token rows. A sequence's rows live wherever its **block table**
/// (from `coordinator::paged::BlockPool`) points — logical token `pos`
/// maps to physical block `table[pos / block_size]`, in-block row
/// `pos % block_size`. `tables[i]` is the table of the sequence
/// occupying decode-batch row `i`; an empty table marks an inactive
/// (static-shape filler) row that owns no storage.
pub struct PagedKv<'a> {
    /// transformer layers in the physical store
    pub n_layers: usize,
    /// physical blocks per layer
    pub n_blocks: usize,
    /// token rows per block
    pub block_size: usize,
    /// `H_kv * D_h` floats per token row
    pub kv_dim: usize,
    /// per decode-batch row: that sequence's block table (physical ids
    /// in token order); empty = inactive row
    pub tables: Vec<Vec<u32>>,
    /// keys, `[L, n_blocks, block_size, kv_dim]`
    pub k: &'a mut [f32],
    /// values, same layout as `k`
    pub v: &'a mut [f32],
}

impl PagedKv<'_> {
    /// Float offset of `(layer, physical block, in-block row)`.
    pub fn block_offset(&self, layer: usize, block: u32, row: usize)
                        -> usize {
        ((layer * self.n_blocks + block as usize) * self.block_size + row)
            * self.kv_dim
    }

    /// Float offset of logical token `pos` of the sequence owning
    /// `table`.
    pub fn pos_offset(&self, layer: usize, table: &[u32], pos: usize)
                      -> usize {
        self.block_offset(
            layer,
            table[pos / self.block_size],
            pos % self.block_size,
        )
    }

    /// Token rows addressable through `table`.
    pub fn capacity(&self, table: &[u32]) -> usize {
        table.len() * self.block_size
    }
}

/// Output of one decode step over a [`PagedKv`] view. The step's K/V
/// rows are written **in place** through the block tables, so unlike
/// [`DecodeOut`] there are no cache copies to absorb.
pub struct PagedDecodeOut {
    /// `[batch, vocab]`
    pub logits: Vec<f32>,
    /// static decode batch of the executed artifact
    pub batch: usize,
    /// vocabulary size (logits row width)
    pub vocab: usize,
    /// backend execution seconds (excludes host staging)
    pub exec_secs: f64,
}

/// The projection module types the audit attributes FLOPs to:
/// [`crate::sparsity::policy::MODULES`] plus the lm_head.
pub const AUDIT_MODULES: [&str; 8] = [
    "q_proj", "k_proj", "v_proj", "o_proj", "gate_proj", "up_proj",
    "down_proj", "lm_head",
];

/// Index of a module name in [`AUDIT_MODULES`].
pub fn audit_module_index(name: &str) -> Option<usize> {
    AUDIT_MODULES.iter().position(|m| *m == name)
}

/// Per-projection-module share of the audit.
#[derive(Debug, Default, Clone, Copy)]
pub struct ModuleAudit {
    /// matmuls of this module that ran through the N:M path
    pub pruned_matmuls: u64,
    /// matmuls of this module that executed densely
    pub dense_matmuls: u64,
    /// FLOPs this module's matmuls would cost densely
    pub dense_flops: u64,
    /// dense-equivalent FLOPs after pruning (see [`SparsityAudit`])
    pub sparse_flops: u64,
    /// dense-equivalent FLOPs of the matmuls that went through the N:M
    /// path (the paper's "computation accelerated" numerator)
    pub covered_flops: u64,
}

impl ModuleAudit {
    /// Fraction of this module's dense-equivalent FLOPs that went
    /// through the N:M path at all (coverage, not savings).
    pub fn coverage_frac(&self) -> f64 {
        if self.dense_flops == 0 {
            return 0.0;
        }
        self.covered_flops as f64 / self.dense_flops as f64
    }
}

/// Running account of how much linear compute went through the sparse
/// path, and whether every pruned activation satisfied the N:M contract.
/// Copy-cheap so engines can expose a snapshot.
#[derive(Debug, Default, Clone, Copy)]
pub struct SparsityAudit {
    /// matmuls that ran through the N:M path
    pub pruned_matmuls: u64,
    /// matmuls that executed densely
    pub dense_matmuls: u64,
    /// FLOPs the executed matmuls would cost densely
    pub dense_flops: u64,
    /// dense-equivalent FLOPs after pruning — what the paper's SpMM
    /// hardware would execute (pruned matmuls count n/m of dense; the
    /// native f32 compressed kernel really does this share, the int8
    /// reference path executes dense-shaped work over the pruned input)
    pub sparse_flops: u64,
    /// pruned activations run through `validate_nm`
    pub nm_checks: u64,
    /// pruned activation rows that violated exact N:M (must stay 0)
    pub nm_violations: u64,
    /// projections where pruning was requested but fell back to dense
    /// because `din % m != 0` (should stay 0 on sane geometry)
    pub pruned_fallbacks: u64,
    /// per-module breakdown over [`AUDIT_MODULES`] — the packed-batch
    /// per-projection coverage report
    pub per_module: [ModuleAudit; 8],
}

impl SparsityAudit {
    /// Fraction of dense-equivalent FLOPs eliminated by pruning.
    pub fn flops_saved_frac(&self) -> f64 {
        if self.dense_flops == 0 {
            return 0.0;
        }
        1.0 - self.sparse_flops as f64 / self.dense_flops as f64
    }

    /// Record one projection that ran through the N:M path.
    pub fn record_pruned(
        &mut self,
        module: &str,
        dense_flops: u64,
        sparse_flops: u64,
    ) {
        self.pruned_matmuls += 1;
        self.dense_flops += dense_flops;
        self.sparse_flops += sparse_flops;
        if let Some(mi) = audit_module_index(module) {
            let m = &mut self.per_module[mi];
            m.pruned_matmuls += 1;
            m.dense_flops += dense_flops;
            m.sparse_flops += sparse_flops;
            m.covered_flops += dense_flops;
        }
    }

    /// Record one projection that executed densely.
    pub fn record_dense(&mut self, module: &str, flops: u64) {
        self.dense_matmuls += 1;
        self.dense_flops += flops;
        self.sparse_flops += flops;
        if let Some(mi) = audit_module_index(module) {
            let m = &mut self.per_module[mi];
            m.dense_matmuls += 1;
            m.dense_flops += flops;
            m.sparse_flops += flops;
        }
    }

    /// Per-module audit entry by name.
    pub fn module(&self, name: &str) -> Option<&ModuleAudit> {
        audit_module_index(name).map(|mi| &self.per_module[mi])
    }
}

/// Cumulative bind-time weight-preparation accounting (the native
/// engine's prep cache): how many weights were panel-packed /
/// quantized, how often a bind or decode found its preparation already
/// cached, and what the one-time cost was. Copy-cheap snapshot; the
/// coordinator publishes it into `EngineMetrics` so prep amortization
/// is visible in serving reports.
#[derive(Debug, Default, Clone, Copy)]
pub struct PrepStats {
    /// weights packed into tile panels (one per distinct weight `Arc`
    /// per tile width — a miss)
    pub weights_packed: u64,
    /// weights quantized for the W8A8 path (at most one per weight
    /// `Arc` — a miss; never in a hot path)
    pub weights_quantized: u64,
    /// preparation lookups served from the cache (re-binds, decode,
    /// shared weights)
    pub cache_hits: u64,
    /// bytes of packed weight storage created (f32 panels + int8
    /// panels)
    pub bytes_packed: u64,
    /// bytes of row-major weight originals still resident alongside
    /// the panels; zero at steady state — `bind` releases originals
    /// once they are packed, so weights are not held twice
    pub bytes_resident: u64,
    /// wall seconds spent packing + quantizing (one-time, at bind)
    pub prep_secs: f64,
}

impl PrepStats {
    /// Total preparation executions (packs + quantizations) — the
    /// miss count, and the counter the native engine's debug
    /// assertion pins at zero across steady-state decode.
    pub fn prep_calls(&self) -> u64 {
        self.weights_packed + self.weights_quantized
    }
}

/// Backend-neutral execution engine. Object-safe: the coordinator holds
/// a `Box<dyn Engine>`.
pub trait Engine {
    /// Backend identifier (e.g. "native-cpu", a PJRT platform name).
    fn platform(&self) -> String;

    /// Artifact + model inventory this engine serves.
    fn manifest(&self) -> &Manifest;

    /// Load (and for compiled backends, compile) an artifact.
    /// Idempotent; returns preparation seconds.
    fn load_artifact(&mut self, name: &str) -> Result<f64>;

    /// Bind weight files to an artifact; returns the binding key used by
    /// `prefill`/`decode`.
    fn bind(&mut self, artifact: &str, files: &[&str]) -> Result<String>;

    /// Run a prefill artifact on a `[batch, seq]` token matrix.
    fn prefill(
        &mut self,
        artifact: &str,
        binding: &str,
        tokens: &[i32],
    ) -> Result<PrefillOut>;

    /// Run a prefill over a token-packed multi-request batch: no padding
    /// rows between requests, arbitrary per-request lengths (clamped to
    /// the artifact's seq). The default implementation right-pads into
    /// the artifact's static `[batch, seq]` shape — chunking when more
    /// requests arrive than the static batch holds — runs [`Engine::prefill`],
    /// and gathers the valid rows back into the packed layout, so every
    /// backend supports the packed calling convention; backends with a
    /// genuinely shape-flexible pipeline (the native engine) override it
    /// and skip the padding work entirely.
    fn prefill_packed(
        &mut self,
        artifact: &str,
        binding: &str,
        prompts: &[Vec<i32>],
    ) -> Result<PackedPrefillOut> {
        let meta = self.manifest().artifact(artifact)?.clone();
        if meta.kind != "prefill" {
            bail!("artifact {artifact} is not a prefill artifact");
        }
        let (b, s) = (meta.batch, meta.seq);
        if b == 0 || s == 0 {
            bail!("prefill {artifact}: degenerate shape {b}x{s}");
        }
        if prompts.is_empty() {
            bail!("prefill_packed {artifact}: empty batch");
        }
        // model geometry for the KV gather
        let model_name = artifact.split('.').next().unwrap_or(artifact);
        let (layers, kvd) = {
            let info =
                self.manifest().models.get(model_name).ok_or_else(|| {
                    anyhow!(
                        "artifact {artifact}: model '{model_name}' not in \
                         manifest"
                    )
                })?;
            let g = |k: &str| info.config.get(k).copied().unwrap_or(0);
            (g("n_layers"), g("n_kv_heads") * g("head_dim"))
        };
        if layers == 0 || kvd == 0 {
            bail!(
                "prefill_packed {artifact}: packed KV gather needs \
                 n_layers/n_kv_heads/head_dim in the manifest config"
            );
        }
        // empty prompts still occupy one (PAD) token row, mirroring the
        // scheduler's defensive clamping
        let lens: Vec<usize> =
            prompts.iter().map(|p| p.len().min(s).max(1)).collect();
        let total: usize = lens.iter().sum();
        let mut logits: Vec<f32> = Vec::new();
        let mut k_cache: Vec<f32> = Vec::new();
        let mut v_cache: Vec<f32> = Vec::new();
        let mut vocab = 0usize;
        let mut exec_secs = 0.0;
        let mut padded_tokens = 0usize;
        let mut start = 0usize; // packed row offset of the chunk head
        for (ci, chunk) in prompts.chunks(b).enumerate() {
            let clens = &lens[ci * b..ci * b + chunk.len()];
            padded_tokens += b * s - clens.iter().sum::<usize>();
            let mut tokens = vec![0i32; b * s];
            for (j, p) in chunk.iter().enumerate() {
                let n = p.len().min(s);
                tokens[j * s..j * s + n].copy_from_slice(&p[..n]);
            }
            let out = self.prefill(artifact, binding, &tokens)?;
            exec_secs += out.exec_secs;
            if vocab == 0 {
                vocab = out.vocab;
                logits = vec![0.0; total * vocab];
                k_cache = vec![0.0; layers * total * kvd];
                v_cache = vec![0.0; layers * total * kvd];
            }
            let mut row = start;
            for (j, &len) in clens.iter().enumerate() {
                logits[row * vocab..(row + len) * vocab].copy_from_slice(
                    &out.logits[j * s * vocab..(j * s + len) * vocab],
                );
                for l in 0..layers {
                    let src = (l * b + j) * s * kvd;
                    let dst = (l * total + row) * kvd;
                    k_cache[dst..dst + len * kvd].copy_from_slice(
                        &out.k_cache[src..src + len * kvd],
                    );
                    v_cache[dst..dst + len * kvd].copy_from_slice(
                        &out.v_cache[src..src + len * kvd],
                    );
                }
                row += len;
            }
            start = row;
        }
        Ok(PackedPrefillOut {
            logits,
            lens,
            vocab,
            k_cache,
            v_cache,
            padded_tokens,
            exec_secs,
        })
    }

    /// Run a token-packed prefill where each request may carry a cached
    /// K/V prefix (prefix-cache hit): the returned [`PackedPrefillOut`]
    /// covers **only the suffix rows** — `lens[i]` is request `i`'s
    /// suffix length, logits and K/V hold exactly those rows.
    ///
    /// The contract is bitwise: the suffix rows must equal the
    /// corresponding rows of a cold [`Engine::prefill_packed`] over the
    /// full prompts whenever `prefix_k/v` equal the cold run's prefix
    /// K/V. The default implementation guarantees this trivially by
    /// recomputing the full prompts and slicing the suffix out — correct
    /// for compiled static backends at zero kernel cost (the recomputed
    /// prefix rows are reported in `padded_tokens`, keeping the wasted-
    /// compute metric honest). Shape-flexible backends (the native
    /// engine) override it and genuinely skip the cached rows.
    fn prefill_packed_prefixed(
        &mut self,
        artifact: &str,
        binding: &str,
        reqs: &[PrefixedPrompt],
    ) -> Result<PackedPrefillOut> {
        if reqs.is_empty() {
            bail!("prefill_packed_prefixed {artifact}: empty batch");
        }
        let prompts: Vec<Vec<i32>> =
            reqs.iter().map(|r| r.tokens.clone()).collect();
        let full = self.prefill_packed(artifact, binding, &prompts)?;
        for (i, r) in reqs.iter().enumerate() {
            if r.cached_len >= full.lens[i] {
                bail!(
                    "prefill_packed_prefixed {artifact}: request {i} has \
                     cached_len {} but only {} prompt rows — at least one \
                     suffix token must be computed",
                    r.cached_len,
                    full.lens[i]
                );
            }
        }
        let model_name = artifact.split('.').next().unwrap_or(artifact);
        let layers = self
            .manifest()
            .models
            .get(model_name)
            .and_then(|m| m.config.get("n_layers").copied())
            .filter(|&l| l > 0)
            .ok_or_else(|| {
                anyhow!(
                    "prefill_packed_prefixed {artifact}: model \
                     '{model_name}' missing n_layers"
                )
            })?;
        let total_full = full.total_tokens();
        let kvd = full.k_cache.len() / (layers * total_full).max(1);
        let lens: Vec<usize> = reqs
            .iter()
            .zip(&full.lens)
            .map(|(r, &l)| l - r.cached_len)
            .collect();
        let total: usize = lens.iter().sum();
        let vocab = full.vocab;
        let mut logits = vec![0.0f32; total * vocab];
        let mut k_cache = vec![0.0f32; layers * total * kvd];
        let mut v_cache = vec![0.0f32; layers * total * kvd];
        let mut row = 0usize;
        for (i, r) in reqs.iter().enumerate() {
            let src0 = full.row_start(i) + r.cached_len;
            let n = lens[i];
            logits[row * vocab..(row + n) * vocab].copy_from_slice(
                &full.logits[src0 * vocab..(src0 + n) * vocab],
            );
            for l in 0..layers {
                let src = (l * total_full + src0) * kvd;
                let dst = (l * total + row) * kvd;
                k_cache[dst..dst + n * kvd]
                    .copy_from_slice(&full.k_cache[src..src + n * kvd]);
                v_cache[dst..dst + n * kvd]
                    .copy_from_slice(&full.v_cache[src..src + n * kvd]);
            }
            row += n;
        }
        let recomputed: usize = reqs.iter().map(|r| r.cached_len).sum();
        Ok(PackedPrefillOut {
            logits,
            lens,
            vocab,
            k_cache,
            v_cache,
            padded_tokens: full.padded_tokens + recomputed,
            exec_secs: full.exec_secs,
        })
    }

    /// Hint the backend's intra-op parallelism (projection thread-pool
    /// width). Backends without an internal pool ignore it.
    fn set_parallelism(&mut self, _threads: usize) {}

    /// Advance every batch row one decode step over caller-owned
    /// **contiguous** `[L, B, C, H_kv, D_h]` caches. `pos[i]` is the
    /// cache position the new token is written at; `kv_len[i]` the
    /// attention span (typically `pos[i] + 1`). Returns updated cache
    /// copies the caller absorbs.
    #[allow(clippy::too_many_arguments)]
    fn decode(
        &mut self,
        artifact: &str,
        binding: &str,
        token: &[i32],
        pos: &[i32],
        k_cache: &[f32],
        v_cache: &[f32],
        kv_len: &[i32],
    ) -> Result<DecodeOut>;

    /// Advance one decode step over a **block-paged** KV view: row `i`'s
    /// cache rows live wherever `kv.tables[i]` points, and this step's
    /// K/V row is appended in place at `pos[i]` through the table (the
    /// coordinator allocates the tail block before calling).
    ///
    /// The default implementation keeps every backend correct without a
    /// native paged kernel: it gathers each row's blocks into the
    /// artifact's static contiguous `[L, B, C, H_kv, D_h]` shape, runs
    /// [`Engine::decode`], and scatters the one written row per
    /// sequence back through its table — so the PJRT path sees exactly
    /// the contiguous cache its compiled graph expects. Backends that
    /// can address blocks directly (the native engine) override this
    /// and skip the gather entirely.
    #[allow(clippy::too_many_arguments)]
    fn decode_paged(
        &mut self,
        artifact: &str,
        binding: &str,
        token: &[i32],
        pos: &[i32],
        kv: &mut PagedKv<'_>,
        kv_len: &[i32],
    ) -> Result<PagedDecodeOut> {
        let meta = self.manifest().artifact(artifact)?.clone();
        if meta.kind != "decode" {
            bail!("artifact {artifact} is not a decode artifact");
        }
        let (b, c) = (meta.batch, meta.cache);
        if b == 0 || c == 0 {
            bail!("decode {artifact}: degenerate batch {b} / cache {c}");
        }
        if kv.tables.len() != b {
            bail!(
                "decode_paged {artifact}: {} row tables != batch {b}",
                kv.tables.len()
            );
        }
        if token.len() != b || pos.len() != b || kv_len.len() != b {
            bail!("decode_paged {artifact}: batch inputs must have len {b}");
        }
        // loud, not silent: a write position beyond a row's block table
        // means the caller forgot to allocate the tail block — clamping
        // would silently drop the new token's K/V
        for (row, table) in kv.tables.iter().enumerate() {
            if table.is_empty() {
                continue;
            }
            let p = pos[row].max(0) as usize;
            if p >= kv.capacity(table) || p >= c {
                bail!(
                    "decode_paged {artifact}: row {row} writes at {p} \
                     beyond its table ({} tokens) or cache {c} — \
                     allocate the tail block first",
                    kv.capacity(table)
                );
            }
        }
        let (layers, kvd, bs) = (kv.n_layers, kv.kv_dim, kv.block_size);
        // gather: block tables -> the static contiguous cache layout
        let mut kc = vec![0.0f32; layers * b * c * kvd];
        let mut vc = vec![0.0f32; layers * b * c * kvd];
        for l in 0..layers {
            for (row, table) in kv.tables.iter().enumerate() {
                let mut at = 0usize;
                for &blk in table {
                    if at >= c {
                        break;
                    }
                    let rows = bs.min(c - at);
                    let src = kv.block_offset(l, blk, 0);
                    let dst = ((l * b + row) * c + at) * kvd;
                    kc[dst..dst + rows * kvd]
                        .copy_from_slice(&kv.k[src..src + rows * kvd]);
                    vc[dst..dst + rows * kvd]
                        .copy_from_slice(&kv.v[src..src + rows * kvd]);
                    at += rows;
                }
            }
        }
        let out = self.decode(artifact, binding, token, pos, &kc, &vc,
                              kv_len)?;
        // scatter back the single K/V row each active sequence wrote
        // (positions validated against table + cache bounds above)
        for row in 0..b {
            if kv.tables[row].is_empty() {
                continue;
            }
            let p = pos[row].max(0) as usize;
            for l in 0..layers {
                let src = ((l * b + row) * c + p) * kvd;
                let dst = kv.pos_offset(l, &kv.tables[row], p);
                kv.k[dst..dst + kvd]
                    .copy_from_slice(&out.k_cache[src..src + kvd]);
                kv.v[dst..dst + kvd]
                    .copy_from_slice(&out.v_cache[src..src + kvd]);
            }
        }
        Ok(PagedDecodeOut {
            logits: out.logits,
            batch: out.batch,
            vocab: out.vocab,
            exec_secs: out.exec_secs,
        })
    }

    /// Sparsity accounting, if the backend tracks it (the native engine
    /// does; PJRT executes pruning inside the compiled graph).
    fn audit(&self) -> Option<SparsityAudit> {
        None
    }

    /// Bind-time weight-preparation accounting, if the backend prepares
    /// weights host-side (the native engine's prep cache; compiled
    /// backends bake layout into the artifact).
    fn prep_stats(&self) -> Option<PrepStats> {
        None
    }
}

/// Default engine for an artifacts directory: the native CPU backend,
/// using the on-disk manifest when present and a self-contained synthetic
/// model inventory otherwise. The PJRT backend is opt-in via
/// `ModelRuntime::new` under the `pjrt` feature.
pub fn engine_for(dir: &Path) -> Result<Box<dyn Engine>> {
    Ok(Box::new(super::native::NativeEngine::from_dir(dir)?))
}
