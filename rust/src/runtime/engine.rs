//! The execution-engine abstraction the coordinator schedules against.
//!
//! `Engine` is the backend-neutral contract: load/bind artifacts by name,
//! run a prefill batch, advance a decode batch. Two implementations ship:
//!
//! * [`crate::runtime::NativeEngine`] — pure-Rust CPU execution built on
//!   `tensor::math`, `sparsity::spmm::NmCompressed` and `quant`; the
//!   default backend, no external dependencies, runs the paper's
//!   N:M-sparse prefill semantics directly (and audits them).
//! * [`crate::runtime::ModelRuntime`] — the PJRT/XLA path over AOT HLO
//!   artifacts, behind the `pjrt` cargo feature.
//!
//! KV caches cross the trait boundary as host `Vec<f32>` in the
//! `[L, B, S|C, H_kv, D_h]` layout, which is what the KV slot manager
//! stages anyway; backends convert to device buffers internally.

use std::path::Path;

use anyhow::Result;

use super::artifact::Manifest;

/// Output of one prefill execution.
pub struct PrefillOut {
    /// `[batch, seq, vocab]`, row-major
    pub logits: Vec<f32>,
    pub batch: usize,
    pub seq: usize,
    pub vocab: usize,
    /// `[L, B, S, H_kv, D_h]`
    pub k_cache: Vec<f32>,
    pub v_cache: Vec<f32>,
    pub exec_secs: f64,
}

/// Output of one decode step.
pub struct DecodeOut {
    /// `[batch, vocab]`
    pub logits: Vec<f32>,
    pub batch: usize,
    pub vocab: usize,
    /// `[L, B, C, H_kv, D_h]` — the caller's cache with this step's K/V
    /// written at each row's position
    pub k_cache: Vec<f32>,
    pub v_cache: Vec<f32>,
    pub exec_secs: f64,
}

/// Running account of how much linear compute went through the sparse
/// path, and whether every pruned activation satisfied the N:M contract.
/// Copy-cheap so engines can expose a snapshot.
#[derive(Debug, Default, Clone, Copy)]
pub struct SparsityAudit {
    pub pruned_matmuls: u64,
    pub dense_matmuls: u64,
    /// FLOPs the executed matmuls would cost densely
    pub dense_flops: u64,
    /// dense-equivalent FLOPs after pruning — what the paper's SpMM
    /// hardware would execute (pruned matmuls count n/m of dense; the
    /// native f32 compressed kernel really does this share, the int8
    /// reference path executes dense-shaped work over the pruned input)
    pub sparse_flops: u64,
    /// pruned activations run through `validate_nm`
    pub nm_checks: u64,
    /// pruned activation rows that violated exact N:M (must stay 0)
    pub nm_violations: u64,
    /// projections where pruning was requested but fell back to dense
    /// because `din % m != 0` (should stay 0 on sane geometry)
    pub pruned_fallbacks: u64,
}

impl SparsityAudit {
    /// Fraction of dense-equivalent FLOPs eliminated by pruning.
    pub fn flops_saved_frac(&self) -> f64 {
        if self.dense_flops == 0 {
            return 0.0;
        }
        1.0 - self.sparse_flops as f64 / self.dense_flops as f64
    }
}

/// Backend-neutral execution engine. Object-safe: the coordinator holds
/// a `Box<dyn Engine>`.
pub trait Engine {
    /// Backend identifier (e.g. "native-cpu", a PJRT platform name).
    fn platform(&self) -> String;

    /// Artifact + model inventory this engine serves.
    fn manifest(&self) -> &Manifest;

    /// Load (and for compiled backends, compile) an artifact.
    /// Idempotent; returns preparation seconds.
    fn load_artifact(&mut self, name: &str) -> Result<f64>;

    /// Bind weight files to an artifact; returns the binding key used by
    /// `prefill`/`decode`.
    fn bind(&mut self, artifact: &str, files: &[&str]) -> Result<String>;

    /// Run a prefill artifact on a `[batch, seq]` token matrix.
    fn prefill(
        &mut self,
        artifact: &str,
        binding: &str,
        tokens: &[i32],
    ) -> Result<PrefillOut>;

    /// Advance every batch row one decode step. `pos[i]` is the cache
    /// position the new token is written at; `kv_len[i]` the attention
    /// span (typically `pos[i] + 1`).
    #[allow(clippy::too_many_arguments)]
    fn decode(
        &mut self,
        artifact: &str,
        binding: &str,
        token: &[i32],
        pos: &[i32],
        k_cache: &[f32],
        v_cache: &[f32],
        kv_len: &[i32],
    ) -> Result<DecodeOut>;

    /// Sparsity accounting, if the backend tracks it (the native engine
    /// does; PJRT executes pruning inside the compiled graph).
    fn audit(&self) -> Option<SparsityAudit> {
        None
    }
}

/// Default engine for an artifacts directory: the native CPU backend,
/// using the on-disk manifest when present and a self-contained synthetic
/// model inventory otherwise. The PJRT backend is opt-in via
/// `ModelRuntime::new` under the `pjrt` feature.
pub fn engine_for(dir: &Path) -> Result<Box<dyn Engine>> {
    Ok(Box::new(super::native::NativeEngine::from_dir(dir)?))
}
