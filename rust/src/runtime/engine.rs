//! The execution-engine abstraction the coordinator schedules against.
//!
//! `Engine` is the backend-neutral contract: load/bind artifacts by name,
//! run a prefill batch, advance a decode batch. Two implementations ship:
//!
//! * [`crate::runtime::NativeEngine`] — pure-Rust CPU execution built on
//!   `tensor::math`, `sparsity::spmm::NmCompressed` and `quant`; the
//!   default backend, no external dependencies, runs the paper's
//!   N:M-sparse prefill semantics directly (and audits them).
//! * [`crate::runtime::ModelRuntime`] — the PJRT/XLA path over AOT HLO
//!   artifacts, behind the `pjrt` cargo feature.
//!
//! KV caches cross the trait boundary as host `Vec<f32>` in the
//! `[L, B, S|C, H_kv, D_h]` layout, which is what the KV slot manager
//! stages anyway; backends convert to device buffers internally.

use std::path::Path;

use anyhow::{anyhow, bail, Result};

use super::artifact::Manifest;

/// Output of one prefill execution.
pub struct PrefillOut {
    /// `[batch, seq, vocab]`, row-major
    pub logits: Vec<f32>,
    pub batch: usize,
    pub seq: usize,
    pub vocab: usize,
    /// `[L, B, S, H_kv, D_h]`
    pub k_cache: Vec<f32>,
    pub v_cache: Vec<f32>,
    pub exec_secs: f64,
}

/// Output of one token-packed prefill execution: `prompts.len()`
/// requests laid out back-to-back with **no padding rows** — request `i`
/// owns rows `row_start(i) .. row_start(i) + lens[i]`.
pub struct PackedPrefillOut {
    /// `[total_tokens, vocab]`, row-major
    pub logits: Vec<f32>,
    /// per-request token counts after clamping to the artifact's seq
    pub lens: Vec<usize>,
    pub vocab: usize,
    /// `[L, total_tokens, H_kv, D_h]`
    pub k_cache: Vec<f32>,
    pub v_cache: Vec<f32>,
    /// PAD-row tokens the backend actually computed to serve this batch:
    /// 0 on a shape-flexible pipeline (native), the full right-padding
    /// cost on the pad-and-gather default path — keeps the coordinator's
    /// padding metric honest across backends
    pub padded_tokens: usize,
    pub exec_secs: f64,
}

impl PackedPrefillOut {
    pub fn total_tokens(&self) -> usize {
        self.lens.iter().sum()
    }

    /// First token row of request `i` in the packed layout.
    pub fn row_start(&self, i: usize) -> usize {
        self.lens[..i].iter().sum()
    }
}

/// Output of one decode step.
pub struct DecodeOut {
    /// `[batch, vocab]`
    pub logits: Vec<f32>,
    pub batch: usize,
    pub vocab: usize,
    /// `[L, B, C, H_kv, D_h]` — the caller's cache with this step's K/V
    /// written at each row's position
    pub k_cache: Vec<f32>,
    pub v_cache: Vec<f32>,
    pub exec_secs: f64,
}

/// The projection module types the audit attributes FLOPs to:
/// [`crate::sparsity::policy::MODULES`] plus the lm_head.
pub const AUDIT_MODULES: [&str; 8] = [
    "q_proj", "k_proj", "v_proj", "o_proj", "gate_proj", "up_proj",
    "down_proj", "lm_head",
];

/// Index of a module name in [`AUDIT_MODULES`].
pub fn audit_module_index(name: &str) -> Option<usize> {
    AUDIT_MODULES.iter().position(|m| *m == name)
}

/// Per-projection-module share of the audit.
#[derive(Debug, Default, Clone, Copy)]
pub struct ModuleAudit {
    pub pruned_matmuls: u64,
    pub dense_matmuls: u64,
    pub dense_flops: u64,
    pub sparse_flops: u64,
    /// dense-equivalent FLOPs of the matmuls that went through the N:M
    /// path (the paper's "computation accelerated" numerator)
    pub covered_flops: u64,
}

impl ModuleAudit {
    /// Fraction of this module's dense-equivalent FLOPs that went
    /// through the N:M path at all (coverage, not savings).
    pub fn coverage_frac(&self) -> f64 {
        if self.dense_flops == 0 {
            return 0.0;
        }
        self.covered_flops as f64 / self.dense_flops as f64
    }
}

/// Running account of how much linear compute went through the sparse
/// path, and whether every pruned activation satisfied the N:M contract.
/// Copy-cheap so engines can expose a snapshot.
#[derive(Debug, Default, Clone, Copy)]
pub struct SparsityAudit {
    pub pruned_matmuls: u64,
    pub dense_matmuls: u64,
    /// FLOPs the executed matmuls would cost densely
    pub dense_flops: u64,
    /// dense-equivalent FLOPs after pruning — what the paper's SpMM
    /// hardware would execute (pruned matmuls count n/m of dense; the
    /// native f32 compressed kernel really does this share, the int8
    /// reference path executes dense-shaped work over the pruned input)
    pub sparse_flops: u64,
    /// pruned activations run through `validate_nm`
    pub nm_checks: u64,
    /// pruned activation rows that violated exact N:M (must stay 0)
    pub nm_violations: u64,
    /// projections where pruning was requested but fell back to dense
    /// because `din % m != 0` (should stay 0 on sane geometry)
    pub pruned_fallbacks: u64,
    /// per-module breakdown over [`AUDIT_MODULES`] — the packed-batch
    /// per-projection coverage report
    pub per_module: [ModuleAudit; 8],
}

impl SparsityAudit {
    /// Fraction of dense-equivalent FLOPs eliminated by pruning.
    pub fn flops_saved_frac(&self) -> f64 {
        if self.dense_flops == 0 {
            return 0.0;
        }
        1.0 - self.sparse_flops as f64 / self.dense_flops as f64
    }

    /// Record one projection that ran through the N:M path.
    pub fn record_pruned(
        &mut self,
        module: &str,
        dense_flops: u64,
        sparse_flops: u64,
    ) {
        self.pruned_matmuls += 1;
        self.dense_flops += dense_flops;
        self.sparse_flops += sparse_flops;
        if let Some(mi) = audit_module_index(module) {
            let m = &mut self.per_module[mi];
            m.pruned_matmuls += 1;
            m.dense_flops += dense_flops;
            m.sparse_flops += sparse_flops;
            m.covered_flops += dense_flops;
        }
    }

    /// Record one projection that executed densely.
    pub fn record_dense(&mut self, module: &str, flops: u64) {
        self.dense_matmuls += 1;
        self.dense_flops += flops;
        self.sparse_flops += flops;
        if let Some(mi) = audit_module_index(module) {
            let m = &mut self.per_module[mi];
            m.dense_matmuls += 1;
            m.dense_flops += flops;
            m.sparse_flops += flops;
        }
    }

    /// Per-module audit entry by name.
    pub fn module(&self, name: &str) -> Option<&ModuleAudit> {
        audit_module_index(name).map(|mi| &self.per_module[mi])
    }
}

/// Backend-neutral execution engine. Object-safe: the coordinator holds
/// a `Box<dyn Engine>`.
pub trait Engine {
    /// Backend identifier (e.g. "native-cpu", a PJRT platform name).
    fn platform(&self) -> String;

    /// Artifact + model inventory this engine serves.
    fn manifest(&self) -> &Manifest;

    /// Load (and for compiled backends, compile) an artifact.
    /// Idempotent; returns preparation seconds.
    fn load_artifact(&mut self, name: &str) -> Result<f64>;

    /// Bind weight files to an artifact; returns the binding key used by
    /// `prefill`/`decode`.
    fn bind(&mut self, artifact: &str, files: &[&str]) -> Result<String>;

    /// Run a prefill artifact on a `[batch, seq]` token matrix.
    fn prefill(
        &mut self,
        artifact: &str,
        binding: &str,
        tokens: &[i32],
    ) -> Result<PrefillOut>;

    /// Run a prefill over a token-packed multi-request batch: no padding
    /// rows between requests, arbitrary per-request lengths (clamped to
    /// the artifact's seq). The default implementation right-pads into
    /// the artifact's static `[batch, seq]` shape — chunking when more
    /// requests arrive than the static batch holds — runs [`Engine::prefill`],
    /// and gathers the valid rows back into the packed layout, so every
    /// backend supports the packed calling convention; backends with a
    /// genuinely shape-flexible pipeline (the native engine) override it
    /// and skip the padding work entirely.
    fn prefill_packed(
        &mut self,
        artifact: &str,
        binding: &str,
        prompts: &[Vec<i32>],
    ) -> Result<PackedPrefillOut> {
        let meta = self.manifest().artifact(artifact)?.clone();
        if meta.kind != "prefill" {
            bail!("artifact {artifact} is not a prefill artifact");
        }
        let (b, s) = (meta.batch, meta.seq);
        if b == 0 || s == 0 {
            bail!("prefill {artifact}: degenerate shape {b}x{s}");
        }
        if prompts.is_empty() {
            bail!("prefill_packed {artifact}: empty batch");
        }
        // model geometry for the KV gather
        let model_name = artifact.split('.').next().unwrap_or(artifact);
        let (layers, kvd) = {
            let info =
                self.manifest().models.get(model_name).ok_or_else(|| {
                    anyhow!(
                        "artifact {artifact}: model '{model_name}' not in \
                         manifest"
                    )
                })?;
            let g = |k: &str| info.config.get(k).copied().unwrap_or(0);
            (g("n_layers"), g("n_kv_heads") * g("head_dim"))
        };
        if layers == 0 || kvd == 0 {
            bail!(
                "prefill_packed {artifact}: packed KV gather needs \
                 n_layers/n_kv_heads/head_dim in the manifest config"
            );
        }
        // empty prompts still occupy one (PAD) token row, mirroring the
        // scheduler's defensive clamping
        let lens: Vec<usize> =
            prompts.iter().map(|p| p.len().min(s).max(1)).collect();
        let total: usize = lens.iter().sum();
        let mut logits: Vec<f32> = Vec::new();
        let mut k_cache: Vec<f32> = Vec::new();
        let mut v_cache: Vec<f32> = Vec::new();
        let mut vocab = 0usize;
        let mut exec_secs = 0.0;
        let mut padded_tokens = 0usize;
        let mut start = 0usize; // packed row offset of the chunk head
        for (ci, chunk) in prompts.chunks(b).enumerate() {
            let clens = &lens[ci * b..ci * b + chunk.len()];
            padded_tokens += b * s - clens.iter().sum::<usize>();
            let mut tokens = vec![0i32; b * s];
            for (j, p) in chunk.iter().enumerate() {
                let n = p.len().min(s);
                tokens[j * s..j * s + n].copy_from_slice(&p[..n]);
            }
            let out = self.prefill(artifact, binding, &tokens)?;
            exec_secs += out.exec_secs;
            if vocab == 0 {
                vocab = out.vocab;
                logits = vec![0.0; total * vocab];
                k_cache = vec![0.0; layers * total * kvd];
                v_cache = vec![0.0; layers * total * kvd];
            }
            let mut row = start;
            for (j, &len) in clens.iter().enumerate() {
                logits[row * vocab..(row + len) * vocab].copy_from_slice(
                    &out.logits[j * s * vocab..(j * s + len) * vocab],
                );
                for l in 0..layers {
                    let src = (l * b + j) * s * kvd;
                    let dst = (l * total + row) * kvd;
                    k_cache[dst..dst + len * kvd].copy_from_slice(
                        &out.k_cache[src..src + len * kvd],
                    );
                    v_cache[dst..dst + len * kvd].copy_from_slice(
                        &out.v_cache[src..src + len * kvd],
                    );
                }
                row += len;
            }
            start = row;
        }
        Ok(PackedPrefillOut {
            logits,
            lens,
            vocab,
            k_cache,
            v_cache,
            padded_tokens,
            exec_secs,
        })
    }

    /// Hint the backend's intra-op parallelism (projection thread-pool
    /// width). Backends without an internal pool ignore it.
    fn set_parallelism(&mut self, _threads: usize) {}

    /// Advance every batch row one decode step. `pos[i]` is the cache
    /// position the new token is written at; `kv_len[i]` the attention
    /// span (typically `pos[i] + 1`).
    #[allow(clippy::too_many_arguments)]
    fn decode(
        &mut self,
        artifact: &str,
        binding: &str,
        token: &[i32],
        pos: &[i32],
        k_cache: &[f32],
        v_cache: &[f32],
        kv_len: &[i32],
    ) -> Result<DecodeOut>;

    /// Sparsity accounting, if the backend tracks it (the native engine
    /// does; PJRT executes pruning inside the compiled graph).
    fn audit(&self) -> Option<SparsityAudit> {
        None
    }
}

/// Default engine for an artifacts directory: the native CPU backend,
/// using the on-disk manifest when present and a self-contained synthetic
/// model inventory otherwise. The PJRT backend is opt-in via
/// `ModelRuntime::new` under the `pjrt` feature.
pub fn engine_for(dir: &Path) -> Result<Box<dyn Engine>> {
    Ok(Box::new(super::native::NativeEngine::from_dir(dir)?))
}
