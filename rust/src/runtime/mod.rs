//! Execution runtime: the backend-neutral [`Engine`] trait plus its two
//! implementations.
//!
//! * `engine`   — the trait, the host-side `PrefillOut`/`DecodeOut`
//!                types, and the `SparsityAudit` accounting
//! * `native`   — the default pure-Rust CPU backend (`NativeEngine`), a
//!                module tree (`model`/`layers`/`prefill`/`decode`): the
//!                batched, thread-pool-parallel projection pipeline with
//!                N:M-sparse prefill through `sparsity::spmm`, per-prefill
//!                `sparsity::plan::SparsityPlan`s, W8A8 through `quant`,
//!                no external dependencies
//! * `artifact` — manifest.json parsing (shared by both backends)
//! * `pjrt`     — the PJRT/XLA backend over AOT HLO artifacts produced
//!                by `python/compile/aot.py`; opt-in via the `pjrt`
//!                cargo feature

pub mod artifact;
pub mod engine;
pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;

pub use artifact::{ArtifactMeta, Manifest};
pub use engine::{
    engine_for, DecodeOut, Engine, ModuleAudit, PackedPrefillOut,
    PagedDecodeOut, PagedKv, PrefillOut, PrefixedPrompt, PrepStats,
    SparsityAudit,
};
pub use native::{ModelSpec, NativeEngine};
#[cfg(feature = "pjrt")]
pub use pjrt::ModelRuntime;
