//! PJRT runtime (Layer-3 side of the AOT bridge).
//!
//! Loads HLO-text artifacts produced by `python/compile/aot.py`, compiles
//! them on the PJRT CPU client once, binds the `.atw` weight files in the
//! executable's flattened-argument order, and exposes typed prefill /
//! decode entry points to the coordinator. Python never runs here.

pub mod artifact;
pub mod engine;

pub use artifact::{ArtifactMeta, Manifest};
pub use engine::{DecodeOut, ModelRuntime, PrefillOut};
